"""Benchmark harness — one section per paper table/figure.

Prints CSV rows ``table,name,us_per_call,derived`` (plus per-table columns)
and, with --json, dumps everything to the given path with a ``_meta``
provenance block (commit sha, jax version, XLA backend, timestamp) so
BENCH files are comparable across PRs.

  fig1/2/3    GEMM method timing sweeps (channels / filters / kernel)
  pack        Fig. 1's "binarize input" stage in isolation: fused Pallas
              quantize->pack prologue vs the jnp reference (1-bit sign
              pack + k-bit plane pack; every row checks bit-identity)
  kbit        beyond-paper: DoReFa bit-width sweep of the plane-packed GEMM
  shard       beyond-paper: tensor-parallel (shard-*) packed GEMM sweep
              (1/2/4/8-way; every row checks sharded == single-device)
  decode      beyond-paper: decode-shape (M in {1,8,32,64} at serving N,K)
              fused-prologue latency — dense f32 vs vpu-k vs mxu-k (every
              row checks mxu == vpu == oracle).  Run WITHOUT the virtual
              multi-device split: it divides the host thread pool and
              distorts these single-device timings
  overlap     beyond-paper: the overlap_collective on/off bit-identity
              gate on the sharded "k" layout (ring reduce-scatter ==
              sequential psum == single device; needs >= 2 devices)
  attn        beyond-paper: fused flash-decode attention vs the dense
              gather + masked-sdpa oracle — CI-gated fused==oracle
              allclose + quantized-KV error-bound rows (both layouts,
              kv_bits in {fp, int8, 1bit}), per-step latency at the
              serve shapes, and the pool-bytes reduction rows.  Like
              decode, run WITHOUT the virtual multi-device split
  table1      model size binary vs fp (LeNet, ResNet-18)
  table2      partial binarization sizes by ResNet stage
  accuracy    Table 1/2 accuracy mechanism (synthetic data; direction only)
  lm_sizes    beyond-paper: packed-weight accounting for the assigned pool
  equiv       §2.2.2 xnor==float + k-bit==DoReFa exactness spot check
  serve       continuous-batching scheduler vs fixed-batch decode: the
              greedy token-equivalence gate (per request, incl. the packed
              engine) + the mixed-length early-eos throughput/TTFT row
  train       sharded DP train-step gates: uncompressed-DP == single-device
              bit-identity (the psum oracle) + 1-bit EF compressed training
              within loss tolerance of uncompressed; also writes the
              tracker JSONL artifact (needs >= 2 devices)

--smoke shrinks the swept shapes (the CI bench-smoke job);
--fail-on-mismatch exits non-zero if any equivalence row disagrees with
its oracle (the CI correctness gate).  --merge-json seeds the output from
an existing --json file so one BENCH file can be assembled from several
invocations with different device setups (the CI job times decode on the
plain single-device platform, then merges the multi-device families on
top — merged rows are re-gated by --fail-on-mismatch, and a family
re-run in the current invocation replaces its merged copy).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def provenance() -> dict:
    """Stamp the environment a BENCH file was produced in."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=root, timeout=10,
        ).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        sha = ""
    sha = sha or os.environ.get("GITHUB_SHA", "") or "unknown"
    import jax

    return {
        "commit": sha,
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "timestamp_unix": int(time.time()),
    }


def _emit(table: str, rows, out, fresh: set | None = None):
    # with --merge-json a table may be seeded from the prior file; the
    # first emit for it THIS invocation replaces that stale copy, so
    # re-running a family is idempotent rather than appending duplicates
    if fresh is not None and table not in fresh:
        fresh.add(table)
        out[table] = []
    for r in rows:
        cols = ",".join(f"{k}={v}" for k, v in r.items())
        print(f"{table},{cols}", flush=True)
        out.setdefault(table, []).append(r)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,fig2,fig3,pack,kbit,shard,decode,"
                         "overlap,attn,table1,table2,accuracy,lm_sizes,"
                         "equiv,serve,train")
    ap.add_argument("--json", default=None)
    ap.add_argument("--merge-json", action="store_true",
                    help="seed output from the existing --json file "
                         "(multi-invocation BENCH assembly; merged rows "
                         "are re-gated by --fail-on-mismatch)")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes (CI bench-smoke job)")
    ap.add_argument("--fail-on-mismatch", action="store_true",
                    help="exit non-zero if any equivalence row reports "
                         "exact_match=False")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    out: dict = {"_meta": provenance()}
    fresh: set = set()
    if args.merge_json and args.json and os.path.exists(args.json):
        with open(args.json) as f:
            prior = json.load(f)
        for tbl, rows in prior.items():
            if tbl != "_meta":
                out[tbl] = rows
        print(f"# merged {len(out) - 1} table(s) from {args.json}",
              file=sys.stderr)
    print(f"# meta,{','.join(f'{k}={v}' for k, v in out['_meta'].items())}",
          flush=True)

    if (want("fig1") or want("fig2") or want("fig3") or want("pack")
            or want("kbit") or want("shard") or want("decode")
            or want("overlap")):
        from benchmarks import gemm_bench
        if want("fig1"):
            _emit("fig1_channels", gemm_bench.fig1_rows(args.smoke),
                  out, fresh)
        if want("fig2"):
            _emit("fig2_filters", gemm_bench.fig2_rows(args.smoke), out, fresh)
        if want("fig3"):
            _emit("fig3_kernel", gemm_bench.fig3_rows(args.smoke), out, fresh)
        if want("pack"):
            _emit("pack_prologue", gemm_bench.pack_rows(args.smoke),
                  out, fresh)
        if want("kbit"):
            _emit("kbit_sweep", gemm_bench.kbit_rows(args.smoke), out, fresh)
        if want("shard"):
            _emit("shard_sweep", gemm_bench.shard_rows(args.smoke), out, fresh)
        if want("decode"):
            _emit("decode", gemm_bench.decode_rows(args.smoke), out, fresh)
        if want("overlap"):
            _emit("overlap_gate", gemm_bench.overlap_rows(args.smoke),
                  out, fresh)

    if want("attn"):
        from benchmarks import attn_bench
        _emit("attn", attn_bench.rows(args.smoke), out, fresh)

    if want("table1") or want("table2") or want("lm_sizes"):
        from benchmarks import size_bench
        if want("table1"):
            _emit("table1_sizes", size_bench.table1_rows(), out, fresh)
        if want("table2"):
            _emit("table2_partial", size_bench.table2_rows(), out, fresh)
        if want("lm_sizes"):
            _emit("lm_packed_sizes", size_bench.lm_rows(), out, fresh)

    if want("accuracy"):
        from benchmarks import accuracy_bench
        _emit("accuracy_mechanism", accuracy_bench.accuracy_rows(), out, fresh)

    if want("equiv"):
        from benchmarks import equiv_bench
        _emit("equivalence", equiv_bench.rows(args.smoke), out, fresh)

    if want("serve"):
        from benchmarks import serve_bench
        _emit("serve", serve_bench.rows(args.smoke), out, fresh)

    if want("train"):
        from benchmarks import train_bench
        _emit("train", train_bench.rows(args.smoke), out, fresh)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"wrote {args.json}", file=sys.stderr)

    if args.fail_on_mismatch:
        # shard_sweep rows carry exact_match too (sharded == single-device),
        # pack_prologue rows gate the fused quantize->pack kernels against
        # the jnp reference, decode rows gate mxu-k == vpu-k == fake-quant
        # oracle, overlap_gate rows gate overlap_collective on == off ==
        # single-device, and serve equivalence rows gate continuous-batching
        # greedy tokens against the per-request fixed-batch engine
        # (throughput rows carry no exact_match and pass through), and
        # train rows gate uncompressed-DP == single-device bit-identity
        # plus compressed-vs-uncompressed loss tolerance, and attn rows
        # gate fused flash-decode == gather oracle (+ quantized-KV error
        # bounds and the pool-bytes reductions; latency rows pass through)
        rows = (out.get("equivalence", []) + out.get("shard_sweep", [])
                + out.get("pack_prologue", []) + out.get("decode", [])
                + out.get("overlap_gate", []) + out.get("attn", [])
                + out.get("serve", []) + out.get("train", []))
        if not rows:
            print("--fail-on-mismatch: no gated rows were produced "
                  "(include 'equiv', 'shard', 'pack', 'decode', 'overlap', "
                  "'attn', 'serve' and/or 'train' in --only)",
                  file=sys.stderr)
            raise SystemExit(1)
        bad = [r for r in rows if not r.get("exact_match", True)]
        if bad:
            for r in bad:
                print(f"EQUIVALENCE MISMATCH: {r}", file=sys.stderr)
            raise SystemExit(1)
        print(f"equivalence gate: all {len(rows)} rows exact",
              file=sys.stderr)


if __name__ == "__main__":
    main()
