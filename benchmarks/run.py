"""Benchmark harness — one section per paper table/figure.

Prints CSV rows ``table,name,us_per_call,derived`` (plus per-table columns)
and, with --json, dumps everything to benchmarks/results.json.

  fig1/2/3    GEMM method timing sweeps (channels / filters / kernel)
  table1      model size binary vs fp (LeNet, ResNet-18)
  table2      partial binarization sizes by ResNet stage
  accuracy    Table 1/2 accuracy mechanism (synthetic data; direction only)
  lm_sizes    beyond-paper: packed-weight accounting for the assigned pool
  equiv       §2.2.2 xnor==float timing + exactness spot check
"""

from __future__ import annotations

import argparse
import json
import sys


def _emit(table: str, rows, out):
    for r in rows:
        cols = ",".join(f"{k}={v}" for k, v in r.items())
        print(f"{table},{cols}", flush=True)
        out.setdefault(table, []).append(r)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,fig2,fig3,table1,table2,"
                         "accuracy,lm_sizes,equiv")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    out: dict = {}

    if want("fig1") or want("fig2") or want("fig3"):
        from benchmarks import gemm_bench
        if want("fig1"):
            _emit("fig1_channels", gemm_bench.fig1_rows(), out)
        if want("fig2"):
            _emit("fig2_filters", gemm_bench.fig2_rows(), out)
        if want("fig3"):
            _emit("fig3_kernel", gemm_bench.fig3_rows(), out)

    if want("table1") or want("table2") or want("lm_sizes"):
        from benchmarks import size_bench
        if want("table1"):
            _emit("table1_sizes", size_bench.table1_rows(), out)
        if want("table2"):
            _emit("table2_partial", size_bench.table2_rows(), out)
        if want("lm_sizes"):
            _emit("lm_packed_sizes", size_bench.lm_rows(), out)

    if want("accuracy"):
        from benchmarks import accuracy_bench
        _emit("accuracy_mechanism", accuracy_bench.accuracy_rows(), out)

    if want("equiv"):
        from benchmarks import equiv_bench
        _emit("equivalence", equiv_bench.rows(), out)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
