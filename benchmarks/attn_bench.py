"""Fused decode-attention benchmark: the Pallas flash-decode kernel
(kernels/attn_decode.py) vs the dense gather + masked-sdpa oracle, over
both KV layouts and the quantized KV storage tiers.

Rows:

* ``equivalence`` — fused kernel output vs ``_sdpa`` over the SAME
  storage's :meth:`gather` view (the oracle dequantizes the same codes
  the kernel reads), at ragged per-row lengths crossing block
  boundaries, for layout in {contiguous, paged} x kv_bits in
  {fp, int8, 1bit}.  ``max_err`` must sit at fp-accumulation level
  (<= 2e-5) for every tier — the fused path reorders the softmax
  accumulation but reads identical KV values.  Quantized rows ALSO
  report ``quant_err`` — the gathered dequantized cache vs the fp
  values that were written — against per-tier bounds (int8 per-group
  absmax: tight; 1-bit sign + per-head alpha: the XNOR tier, loose by
  construction).  Both checks fold into the CI-gated ``exact_match``.
* ``latency`` — per-decode-step wall time, fused vs gather, at the
  serve shapes (cache_len 2048, decode M in {1, 8, 32}), both layouts,
  kv_bits sweep.  The fused path reads the pool in place through the
  block table (split-KV grid, tuned via select_attn_tiles); the gather
  baseline materializes the dense (B, L) view every step — on paged
  storage that is a real per-step copy, on contiguous it is free, which
  is why the contiguous win comes only from the masked-sdpa's wasted
  NEG_INF lanes.  ``speedup`` > 1 means fused wins; rows carry no
  ``exact_match`` (timing, not correctness).
* ``pool-bytes`` — KV-cache bytes per cached token per layer for the
  fp32 / int8 / 1-bit storage tiers (codes + scale planes, from
  kv_code_shapes), with the reduction factor vs fp32.  The int8/1-bit
  rows gate ``exact_match`` on the bytes actually shrinking — paired
  with their ``equivalence`` error-bound rows this is the ISSUE's
  "pool-bytes reduction with its error-bound row passing" criterion.

Timing notes: interpret-mode Pallas on CPU; the fused kernel's win
grows with cache_len (the gather path's dense materialization + full
masked score matrix scale with L, the split-KV grid streams it).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import attention as A
from repro.kernels import attn_decode as AK

_TIERS = (None, 8, 1)
# int8 gates MAX abs err (per-group absmax keeps it ~scale/254); 1-bit
# gates MEAN abs err — sign + per-head alpha has per-element error up to
# ~max|x|, but its mean is E|x - alpha*sign(x)| ~ 0.6 at unit variance
_QUANT_ERR_BOUND = {8: 0.05, 1: 0.8}


def _mk_kv(layout: str, kv_bits, block_size: int):
    if layout == "pgd":
        return A.PagedKVCache(block_size=block_size, kv_bits=kv_bits)
    return A.ContiguousKVCache(kv_bits=kv_bits)


def _fill(kv, cfg, b, cache_len, lens, key, layout):
    """Build a cache with per-row ragged fills (fp values returned too)."""
    cache = kv.init(b, cfg, cache_len, jnp.float32)
    if layout == "pgd":
        bps = cache["table"].shape[1]
        cache["table"] = jnp.arange(b * bps, dtype=jnp.int32).reshape(b, bps)
    kvh, dh = cfg.n_kv_heads, cfg.d_head
    fp_k = np.zeros((b, cache_len, kvh, dh), np.float32)
    fp_v = np.zeros((b, cache_len, kvh, dh), np.float32)
    # one masked fill_window pass per DISTINCT length: the paged pool is
    # SHARED across slots, so ragged per-row writes go through write_mask
    # (rows of other lengths masked off), never by slicing cache leaves
    for ln in sorted(set(lens)):
        ks = jax.random.normal(jax.random.fold_in(key, ln), (b, ln, kvh, dh))
        vs = jax.random.normal(jax.random.fold_in(key, 1000 + ln),
                               (b, ln, kvh, dh))
        wm = np.asarray([x == ln for x in lens])
        for i in np.flatnonzero(wm):
            fp_k[i, :ln], fp_v[i, :ln] = np.asarray(ks[i]), np.asarray(vs[i])
        pos = jnp.broadcast_to(jnp.arange(ln, dtype=jnp.int32), (b, ln))
        cache = kv.fill_window(cache, ks, vs, pos, jnp.asarray(wm))
    return cache, fp_k, fp_v


def _dense_cache(kv, cfg, b, cache_len, key, layout, block_size):
    """A fully-populated cache straight through the layout's codec (the
    latency rows don't exercise the write path, so skip the one-hot
    fills and lay the encoded leaves out directly)."""
    kvh, dh = cfg.n_kv_heads, cfg.d_head
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (b, cache_len, kvh, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (b, cache_len, kvh, dh))
    enc = kv._encode(k, v)
    pos = jnp.broadcast_to(jnp.arange(cache_len, dtype=jnp.int32),
                           (b, cache_len))
    if layout == "ctg":
        return {**enc, "slot_pos": pos}
    bps = cache_len // block_size
    cache = {n: x.reshape((b * bps, block_size) + x.shape[2:])
             for n, x in enc.items()}
    cache["pool_pos"] = pos.reshape(b * bps, block_size)
    cache["table"] = jnp.arange(b * bps, dtype=jnp.int32).reshape(b, bps)
    return cache


def _oracle(cfg, kv, cache, qg, q_pos):
    """The gather + masked-sdpa reference over the same storage."""
    k, v, spos = kv.gather(cache)
    return A._sdpa(cfg, qg, k, v, A._mask(cfg, q_pos, spos))


def _bench(fn, *args, iters=10):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def rows(small: bool = False):
    kvh, g, dh = 2, 2, 16
    cfg = A.AttnConfig(d_model=kvh * g * dh, n_heads=kvh * g,
                       n_kv_heads=kvh, d_head=dh)
    key = jax.random.PRNGKey(0)

    # -- equivalence + quantization error bounds (ragged lengths crossing
    # block boundaries; oracle gathers the SAME quantized storage) --
    eq_len = 64 if small else 256
    bs = 16
    b = 4
    lens = [eq_len, eq_len - bs - 3, bs + 1, 2]
    for layout in ("ctg", "pgd"):
        for bits in _TIERS:
            kv = _mk_kv(layout, bits, bs)
            cache, fp_k, fp_v = _fill(kv, cfg, b, eq_len, lens, key, layout)
            q = jax.random.normal(jax.random.fold_in(key, 7),
                                  (b, 1, kvh, g, dh))
            q_pos = jnp.asarray([[ln] for ln in lens], jnp.int32)
            fused = kv.attend(cache, q, q_pos, cfg)
            ref = _oracle(cfg, kv, cache, q, q_pos)
            max_err = float(jnp.max(jnp.abs(fused - ref)))
            row = {
                "mode": "equivalence", "layout": layout,
                "kv_bits": bits or "fp", "batch": b, "cache_len": eq_len,
                "max_err": f"{max_err:.2e}",
            }
            ok = max_err <= 2e-5
            if bits is not None:
                dk, dv, dpos = kv.gather(cache)
                filled = np.asarray(dpos) >= 0  # (B, L)
                ek = np.abs(np.asarray(dk) - fp_k)[filled]
                ev = np.abs(np.asarray(dv) - fp_v)[filled]
                red = np.max if bits == 8 else np.mean
                qerr = max(float(red(ek)), float(red(ev)))
                row["quant_err"] = f"{qerr:.3f}"
                row["quant_err_bound"] = _QUANT_ERR_BOUND[bits]
                ok = ok and qerr <= _QUANT_ERR_BOUND[bits]
            row["exact_match"] = ok
            yield row

    # -- latency: fused vs gather per decode step at the serve shapes.
    # cache_len stays 2048 even under --smoke: the fused win scales with
    # L (that IS the measurement), only the decode-M sweep shrinks --
    L = 2048
    pbs = 256
    for layout in ("ctg", "pgd"):
        for m in (1, 8) if small else (1, 8, 32):
            for bits in _TIERS:
                kv = _mk_kv(layout, bits, pbs)
                cache = _dense_cache(kv, cfg, m, L, key, layout, pbs)
                q = jax.random.normal(jax.random.fold_in(key, 9),
                                      (m, 1, kvh, g, dh))
                q_pos = jnp.full((m, 1), L - 1, jnp.int32)

                fused = jax.jit(lambda c, q, p: kv.attend(c, q, p, cfg))
                gather = jax.jit(lambda c, q, p: _oracle(cfg, kv, c, q, p))
                t_f = _bench(fused, cache, q, q_pos)
                t_g = _bench(gather, cache, q, q_pos)
                yield {
                    "mode": "latency", "layout": layout,
                    "kv_bits": bits or "fp", "m": m, "cache_len": L,
                    "block_size": pbs if layout == "pgd" else "",
                    "fused_us": round(t_f, 1), "gather_us": round(t_g, 1),
                    "speedup": round(t_g / t_f, 2),
                }

    # -- pool-bytes: storage footprint per cached token per layer --
    fp_bytes = None
    for bits in _TIERS:
        (code, cdt), sc = AK.kv_code_shapes(bits, kvh, dh, jnp.float32)
        per_tok = 2 * (int(np.prod(code)) * jnp.dtype(cdt).itemsize
                       + (int(np.prod(sc[0])) * jnp.dtype(sc[1]).itemsize
                          if sc is not None else 0))
        if bits is None:
            fp_bytes = per_tok
        yield {
            "mode": "pool-bytes", "kv_bits": bits or "fp",
            "kv_heads": kvh, "d_head": dh,
            "bytes_per_token": per_tok,
            "reduction_vs_fp": round(fp_bytes / per_tok, 2),
            **({"exact_match": per_tok < fp_bytes} if bits else {}),
        }
