"""Continuous-batching serving benchmark: the scheduler vs the fixed-batch
decode loop over the same jitted prefill/decode steps.

Scenario (the daBNN-style serving-framework argument): a queue of
mixed-prompt-length requests where HALF finish early — eos at 25% of
``max_new_tokens`` (per-request ``eos_id`` + ``min_tokens`` pins the stop
deterministically).  Two execution modes:

* **fixed-batch** — the legacy engine semantics: rectangular batches only,
  so requests group by prompt length (batch width = group size), and every
  batch decodes the full ``max_new_tokens`` horizon regardless of eos.
  Useful tokens are truncated at eos after the fact.
* **continuous** — ``Scheduler.run``: one shape-static decode batch, slots
  recycle the step a request hits eos/budget, queued requests admit into
  freed slots, and the loop exits when queue+batch drain.

Rows:

* ``equivalence`` — continuous greedy tokens are IDENTICAL per request to
  the per-request fixed-batch engine (batch=1 ``Engine.generate``,
  truncated by the same eos/min_tokens rule).  Carries ``exact_match`` —
  the CI bench-smoke job gates on it (--fail-on-mismatch).  One row runs
  float, one runs a BMXNet-converted packed checkpoint (xla backend:
  packed weights, in-graph dequant — CPU-fast) so the gate covers the
  packed serving path end-to-end.
* ``equivalence`` / ``engine=paged`` — the SAME request set served on the
  block-table paged KV pool with chunked prefill + prefix sharing
  (``EngineConfig.kv_block_size``): greedy streams must stay bit-identical
  to the per-request reference.  Also CI-gated via ``exact_match``.
* ``equivalence`` / ``engine=fused-attn[-paged]`` — the same request set
  decoded through the Pallas flash-decode kernel
  (``EngineConfig.fused_attn``; kernels/attn_decode.py) on both KV
  layouts, fp KV storage: greedy streams must be IDENTICAL per request
  to the reference.  CI-gated via ``exact_match`` — the serve half of
  the fused kernel's gate (the ``attn`` family gates numeric allclose).
* ``shared-prefix`` — an identical-prefix request stream on the paged
  engine with sharing off vs on: prefill work must drop by EXACTLY
  ``(requests - batch) * prefix_len`` tokens (every request after the
  first admission wave reuses the registered prefix blocks) with
  bit-identical streams; both checks fold into the gated ``exact_match``.
* ``throughput`` — useful tokens/sec both modes, speedup, decode-step
  counts, and TTFT/TPOT telemetry (mean + p50/p95 from the scheduler's
  per-token emission timestamps).  Fixed-batch TTFT is measured at
  group START (a lower bound, i.e. favouring the baseline).  The ISSUE
  acceptance bar: speedup >= 1.5x with half the requests stopping at 25%.
* ``spec-equivalence`` — the speculative scheduler (w1a1 packed draft from
  ``converter.derive_draft`` over a deeper float target) must stream
  tokens BIT-IDENTICAL to the per-request reference: greedy spec output
  never depends on draft quality, only the acceptance rate does.
  CI-gated via ``exact_match``.
* ``spec-throughput`` — useful tok/s of speculative vs plain continuous
  batching on the same request set, plus acceptance rate, verify-call
  counts, and p50/p95 TPOT both modes.  The draft here is the float
  depth-slice (high agreement on the random-init smoke checkpoint —
  a random-weight w1a1 draft proposes near-noise, which costs rounds
  without accepted runs); with spec_len=2 the measured useful-tok/s
  beats non-spec continuous batching.  Identity vs the non-spec streams
  folds into the gated ``exact_match``.

Timing notes: both modes are warmed (jit) before the timed pass; the fp
smoke model is tiny so CPU numbers are call-count dominated — which is
exactly what the scheduler improves (fewer, fuller decode steps).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import converter
from repro.core.policy import QuantPolicy
from repro.kernels.dispatch import GemmConfig
from repro.models import lm, registry
from repro.nn.common import QCtx
from repro.serve.engine import (DraftModel, Engine, EngineConfig, Request,
                                Scheduler)


def _pct(xs, q) -> float:
    """Percentile in milliseconds, 0.0 for an empty sample."""
    return round(float(np.percentile(xs, q)) * 1e3, 2) if len(xs) else 0.0


def _expected_stream(full: np.ndarray, eos_id: int | None,
                     min_tokens: int) -> np.ndarray:
    """Apply the scheduler's retirement rule to a full-horizon stream."""
    if eos_id is None:
        return full
    for idx, t in enumerate(full):
        if idx + 1 >= min_tokens and int(t) == int(eos_id):
            return full[:idx + 1]
    return full


def _build(arch: str, policy, batch: int, cache_len: int, max_new: int,
           backend: str | None = None, packed: bool = False):
    spec = registry.get(arch)
    cfg = spec.smoke
    gc = GemmConfig(backend=backend) if backend else GemmConfig()
    ctx = QCtx(policy=policy, compute_dtype=jnp.float32, gemm_config=gc)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    if packed:
        host = jax.tree.map(np.asarray, params)
        params, rep = converter.convert(host, policy)
        assert rep.n_packed > 0
        params = jax.tree.map(jnp.asarray, params)
    ecfg = EngineConfig(batch=batch, cache_len=cache_len,
                        max_new_tokens=max_new)
    return spec, cfg, ctx, params, Engine(spec, cfg, ctx, params, ecfg)


def _requests(cfg, lens, max_new, ref_engine, rng):
    """One (early, late) request pair per prompt length.  Early requests
    stop via eos at 25% of max_new (eos_id = the reference stream's token
    there, min_tokens pins the trigger position); late requests run the
    full budget.  Returns (requests in interleaved submission order,
    {rid: expected tokens})."""
    k = max(1, max_new // 4)
    reqs, expected = [], {}
    rid = 0
    for length in lens:
        for early in (False, True):
            prompt = rng.integers(0, cfg.vocab_size, (length,)).astype(
                np.int32)
            full = ref_engine.generate(prompt[None])[0]
            eos = int(full[k - 1]) if early else None
            min_tok = k if early else 0
            reqs.append(Request(prompt=prompt, rid=rid, eos_id=eos,
                                min_tokens=min_tok))
            expected[rid] = _expected_stream(full, eos, min_tok)
            rid += 1
    return reqs, expected


def _run_continuous(engine, reqs):
    sched = Scheduler(engine)
    for r in reqs:
        sched.submit(Request(prompt=r.prompt, rid=r.rid, eos_id=r.eos_id,
                             min_tokens=r.min_tokens,
                             max_new_tokens=r.max_new_tokens))
    t0 = time.perf_counter()
    results = sched.run()
    dt = time.perf_counter() - t0
    return results, dt, sched.stats


def _run_fixed(fixed_engine, reqs, expected):
    """Legacy semantics: group by prompt length (rectangular batches of
    the fixed engine's width), full horizon each, truncate at eos after.
    Returns (wall seconds, useful tokens, per-request ttft lower bounds,
    decode steps)."""
    width = fixed_engine.ecfg.batch
    by_len: dict[int, list[Request]] = {}
    for r in reqs:
        by_len.setdefault(len(r.prompt), []).append(r)
    groups = []
    for _, rs in sorted(by_len.items()):
        for i in range(0, len(rs), width):
            groups.append(rs[i:i + width])
    t0 = time.perf_counter()
    useful, ttfts, steps = 0, [], 0
    for g in groups:
        t_start = time.perf_counter() - t0
        out = fixed_engine.generate(np.stack([r.prompt for r in g]))
        steps += fixed_engine.ecfg.max_new_tokens - 1
        for row, r in zip(out, g):
            np.testing.assert_array_equal(
                row[:len(expected[r.rid])], expected[r.rid])
            useful += len(expected[r.rid])
            ttfts.append(t_start)
    return time.perf_counter() - t0, useful, ttfts, steps


def rows(small: bool = False):
    rng = np.random.default_rng(0)
    max_new = 16 if small else 32
    lens = (4, 6, 8, 10) if small else (4, 6, 8, 10, 12, 14, 16, 18)
    cache_len = 32 if small else 64
    batch = 4

    # float engines: continuous (4 slots), fixed baseline (width 2 = the
    # per-length group size), per-request reference (batch=1)
    _, cfg, _, _, eng_cont = _build("granite-3-2b",
                                    QuantPolicy.full_precision(),
                                    batch, cache_len, max_new)
    eng_ref = Engine(eng_cont.spec, eng_cont.cfg, eng_cont.ctx,
                     eng_cont.params,
                     EngineConfig(batch=1, cache_len=cache_len,
                                  max_new_tokens=max_new))
    eng_fixed = Engine(eng_cont.spec, eng_cont.cfg, eng_cont.ctx,
                       eng_cont.params,
                       EngineConfig(batch=2, cache_len=cache_len,
                                    max_new_tokens=max_new))

    reqs, expected = _requests(cfg, lens, max_new, eng_ref, rng)

    # -- equivalence (float): continuous == per-request fixed, exactly --
    results, _, _ = _run_continuous(eng_cont, reqs)
    mismatch = [r.rid for r in reqs
                if not np.array_equal(results[r.rid], expected[r.rid])]
    yield {
        "mode": "equivalence", "engine": "float", "requests": len(reqs),
        "batch": batch, "max_new": max_new,
        "mismatches": len(mismatch),
        "exact_match": not mismatch,
    }

    # -- equivalence (packed, xla backend): the deployment-mode engine --
    pk_max_new = 6
    _, pcfg, _, _, pk_cont = _build(
        "granite-3-2b", QuantPolicy.binary(), 2, 24, pk_max_new,
        backend="xla", packed=True)
    pk_ref = Engine(pk_cont.spec, pk_cont.cfg, pk_cont.ctx, pk_cont.params,
                    EngineConfig(batch=1, cache_len=24,
                                 max_new_tokens=pk_max_new))
    pk_reqs, pk_expected = _requests(pcfg, (4, 5), pk_max_new, pk_ref, rng)
    pk_results, _, _ = _run_continuous(pk_cont, pk_reqs)
    pk_mismatch = [r.rid for r in pk_reqs
                   if not np.array_equal(pk_results[r.rid],
                                         pk_expected[r.rid])]
    yield {
        "mode": "equivalence", "engine": "packed-xla",
        "requests": len(pk_reqs), "batch": 2, "max_new": pk_max_new,
        "mismatches": len(pk_mismatch),
        "exact_match": not pk_mismatch,
    }

    # -- equivalence (paged): block-table pool + chunked prefill + prefix
    # sharing vs the SAME per-request fixed-batch reference streams --
    eng_paged = Engine(eng_cont.spec, eng_cont.cfg, eng_cont.ctx,
                       eng_cont.params,
                       EngineConfig(batch=batch, cache_len=cache_len,
                                    max_new_tokens=max_new,
                                    kv_block_size=8, prefill_chunk=5,
                                    shared_prefix=True))
    pg_results, _, pg_stats = _run_continuous(eng_paged, reqs)
    pg_mismatch = [r.rid for r in reqs
                   if not np.array_equal(pg_results[r.rid],
                                         expected[r.rid])]
    yield {
        "mode": "equivalence", "engine": "paged", "requests": len(reqs),
        "batch": batch, "max_new": max_new, "kv_block_size": 8,
        "prefill_chunk": 5,
        "prefill_tokens": pg_stats.prefill_tokens,
        "shared_tokens": pg_stats.shared_tokens,
        "mismatches": len(pg_mismatch),
        "exact_match": not pg_mismatch,
    }

    # -- equivalence (fused-attn): the SAME request set decoded through
    # the Pallas flash-decode kernel (kernels/attn_decode.py) instead of
    # gather + masked-sdpa, on both KV layouts.  fp KV storage, so greedy
    # streams must be IDENTICAL per request to the reference — the serve
    # half of the fused kernel's CI gate (the attn bench family gates the
    # numeric allclose) --
    for label, extra in (("fused-attn", {}),
                         ("fused-attn-paged",
                          {"kv_block_size": 8, "prefill_chunk": 5})):
        eng_fused = Engine(eng_cont.spec, eng_cont.cfg, eng_cont.ctx,
                           eng_cont.params,
                           EngineConfig(batch=batch, cache_len=cache_len,
                                        max_new_tokens=max_new,
                                        fused_attn=True, **extra))
        fa_results, _, _ = _run_continuous(eng_fused, reqs)
        fa_mismatch = [r.rid for r in reqs
                       if not np.array_equal(fa_results[r.rid],
                                             expected[r.rid])]
        yield {
            "mode": "equivalence", "engine": label, "requests": len(reqs),
            "batch": batch, "max_new": max_new,
            "mismatches": len(fa_mismatch),
            "exact_match": not fa_mismatch,
        }

    # -- shared-prefix throughput: identical-prefix stream, paged engine
    # with and without sharing.  Every request after the first admission
    # wave reuses the prefix's full blocks, so prefill work must drop by
    # exactly (requests - batch) * prefix_len tokens — gated alongside
    # stream identity --
    sp_batch, sp_new, sp_bs, prefix_len, n_sp = 2, 8, 8, 16, 6
    prefix = rng.integers(0, cfg.vocab_size, (prefix_len,)).astype(np.int32)
    sp_reqs = []
    for i in range(n_sp):
        suffix = rng.integers(0, cfg.vocab_size, (1 + i,)).astype(np.int32)
        sp_reqs.append(Request(prompt=np.concatenate([prefix, suffix]),
                               rid=i))

    def _sp_engine(share):
        return Engine(eng_cont.spec, eng_cont.cfg, eng_cont.ctx,
                      eng_cont.params,
                      EngineConfig(batch=sp_batch, cache_len=cache_len,
                                   max_new_tokens=sp_new,
                                   kv_block_size=sp_bs,
                                   shared_prefix=share))

    base_res, _, base_stats = _run_continuous(_sp_engine(False), sp_reqs)
    sh_res, _, sh_stats = _run_continuous(_sp_engine(True), sp_reqs)
    # warmed second passes for the timing comparison
    _, base_dt, _ = _run_continuous(_sp_engine(False), sp_reqs)
    _, sh_dt, _ = _run_continuous(_sp_engine(True), sp_reqs)
    identical = all(np.array_equal(base_res[i], sh_res[i])
                    for i in range(n_sp))
    saved = base_stats.prefill_tokens - sh_stats.prefill_tokens
    expected_saved = (n_sp - sp_batch) * prefix_len
    yield {
        "mode": "shared-prefix", "requests": n_sp, "batch": sp_batch,
        "kv_block_size": sp_bs, "prefix_len": prefix_len,
        "prefill_tokens_unshared": base_stats.prefill_tokens,
        "prefill_tokens_shared": sh_stats.prefill_tokens,
        "prefill_tokens_saved": saved,
        "expected_saved": expected_saved,
        "shared_tok_s_ratio": round(base_dt / sh_dt, 2),
        "exact_match": identical and saved == expected_saved
        and sh_stats.shared_tokens == expected_saved,
    }

    # -- throughput: fixed-batch vs continuous, half stopping at 25% --
    _run_fixed(eng_fixed, reqs, expected)  # warm the fixed engine's jits
    fx_dt, fx_useful, fx_ttfts, fx_steps = _run_fixed(
        eng_fixed, reqs, expected)
    # the equivalence pass above warmed the continuous engine's jits
    results, ct_dt, stats = _run_continuous(eng_cont, reqs)
    ct_useful = sum(len(v) for v in results.values())
    assert ct_useful == fx_useful, (ct_useful, fx_useful)
    fx_tps = fx_useful / fx_dt
    ct_tps = ct_useful / ct_dt
    yield {
        "mode": "throughput", "requests": len(reqs), "batch": batch,
        "max_new": max_new, "early_finish_frac": 0.5, "eos_at_frac": 0.25,
        "useful_tokens": ct_useful,
        "fixed_decode_steps": fx_steps,
        "cont_decode_steps": stats.steps,
        "fixed_tok_s": round(fx_tps, 1),
        "cont_tok_s": round(ct_tps, 1),
        "speedup": round(ct_tps / fx_tps, 2),
        "fixed_ttft_ms_mean": round(float(np.mean(fx_ttfts)) * 1e3, 1),
        "cont_ttft_ms_mean": round(
            float(np.mean(list(stats.t_first.values()))) * 1e3, 1),
        "cont_ttft_ms_p50": _pct(stats.ttfts(), 50),
        "cont_ttft_ms_p95": _pct(stats.ttfts(), 95),
        "cont_tpot_ms_p50": _pct(stats.tpots(), 50),
        "cont_tpot_ms_p95": _pct(stats.tpots(), 95),
    }

    # -- speculative decoding over a deeper float target.  The smoke stack
    # is only 2 blocks, so a depth-slice draft would be half the target;
    # a 4-block variant of the same arch gives the draft a real cost
    # edge (1 of 4 blocks) while staying CPU-cheap --
    sd_cfg = dataclasses.replace(cfg, n_layers=4)
    sd_new, sd_lens, sd_cache = 24, (4, 6, 8, 10), 64
    sd_params = lm.init(jax.random.PRNGKey(1), sd_cfg)
    sd_host = jax.tree.map(np.asarray, sd_params)
    sd_ref = Engine(eng_cont.spec, sd_cfg, eng_cont.ctx, sd_params,
                    EngineConfig(batch=1, cache_len=sd_cache,
                                 max_new_tokens=sd_new))
    sd_reqs, sd_expected = _requests(sd_cfg, sd_lens, sd_new, sd_ref, rng)

    def _sd_engine(draft, spec_len=0):
        return Engine(eng_cont.spec, sd_cfg, eng_cont.ctx, sd_params,
                      EngineConfig(batch=batch, cache_len=sd_cache,
                                   max_new_tokens=sd_new,
                                   draft=draft, spec_len=spec_len))

    # -- spec-equivalence: the paper-mode pairing — a w1a1 packed draft
    # (derive_draft's default) proposing for the float target.  On a
    # random-init checkpoint this draft is near-noise (acceptance ~0),
    # which is exactly the point of the gate: greedy spec streams must
    # equal the reference bit-for-bit NO MATTER what the draft says --
    w1_dp, w1_dcfg, w1_rep = converter.derive_draft(sd_host, sd_cfg,
                                                    n_layers=1)
    assert w1_rep.n_packed > 0
    w1_draft = DraftModel(
        cfg=w1_dcfg, params=jax.tree.map(jnp.asarray, w1_dp),
        ctx=QCtx(policy=QuantPolicy.binary(), compute_dtype=jnp.float32,
                 gemm_config=GemmConfig(backend="xla")))
    sd_res, _, sd_stats = _run_continuous(_sd_engine(w1_draft, 2), sd_reqs)
    sd_mismatch = [r.rid for r in sd_reqs
                   if not np.array_equal(sd_res[r.rid], sd_expected[r.rid])]
    yield {
        "mode": "spec-equivalence", "draft": "w1a1-slice1", "spec_len": 2,
        "requests": len(sd_reqs), "batch": batch, "max_new": sd_new,
        "target_layers": sd_cfg.n_layers, "draft_layers": w1_dcfg.n_layers,
        "acceptance_rate": round(sd_stats.acceptance_rate, 3),
        "spec_rounds": sd_stats.spec_rounds,
        "mismatches": len(sd_mismatch),
        "exact_match": not sd_mismatch,
    }

    # -- spec-throughput: float depth-slice draft (the high-agreement
    # pairing available without training) vs plain continuous batching --
    fp_dp, fp_dcfg, _ = converter.derive_draft(
        sd_host, sd_cfg, n_layers=1,
        policy=QuantPolicy.full_precision(), keep_float=True)
    fp_draft = DraftModel(cfg=fp_dcfg,
                          params=jax.tree.map(jnp.asarray, fp_dp),
                          ctx=eng_cont.ctx)
    spec_eng, plain_eng = _sd_engine(fp_draft, 2), _sd_engine(None)
    _run_continuous(spec_eng, sd_reqs)  # warm the spec jits
    _run_continuous(plain_eng, sd_reqs)  # warm the plain jits
    sp_res, sp_dt, sp_stats = _run_continuous(spec_eng, sd_reqs)
    pl_res, pl_dt, pl_stats = _run_continuous(plain_eng, sd_reqs)
    sp_identical = all(np.array_equal(sp_res[r.rid], pl_res[r.rid])
                       and np.array_equal(sp_res[r.rid], sd_expected[r.rid])
                       for r in sd_reqs)
    sp_useful = sum(len(v) for v in sp_res.values())
    sp_tps, pl_tps = sp_useful / sp_dt, sp_useful / pl_dt
    yield {
        "mode": "spec-throughput", "draft": "fp-slice1", "spec_len": 2,
        "requests": len(sd_reqs), "batch": batch, "max_new": sd_new,
        "target_layers": sd_cfg.n_layers, "draft_layers": fp_dcfg.n_layers,
        "useful_tokens": sp_useful,
        "acceptance_rate": round(sp_stats.acceptance_rate, 3),
        "spec_verify_steps": sp_stats.steps,
        "cont_decode_steps": pl_stats.steps,
        "spec_tok_s": round(sp_tps, 1),
        "cont_tok_s": round(pl_tps, 1),
        "speedup": round(sp_tps / pl_tps, 2),
        "spec_tpot_ms_p50": _pct(sp_stats.tpots(), 50),
        "spec_tpot_ms_p95": _pct(sp_stats.tpots(), 95),
        "cont_tpot_ms_p50": _pct(pl_stats.tpots(), 50),
        "cont_tpot_ms_p95": _pct(pl_stats.tpots(), 95),
        "spec_ttft_ms_p50": _pct(sp_stats.ttfts(), 50),
        "spec_ttft_ms_p95": _pct(sp_stats.ttfts(), 95),
        "exact_match": sp_identical,
    }
