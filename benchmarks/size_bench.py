"""Paper Table 1 + Table 2: model sizes under binarization and partial
binarization (exact, no training needed — pure accounting on real param
trees)."""

from __future__ import annotations

import jax

from repro.core import converter
from repro.core.policy import QuantPolicy
from repro.models import cnn, registry


def table1_rows():
    """LeNet + ResNet-18 binary vs full-precision sizes (paper: 206kB/4.6MB
    and 1.5MB/44.7MB)."""
    key = jax.random.PRNGKey(0)
    for arch, init in (("lenet-mnist", cnn.lenet_init),
                       ("resnet18-cifar10", cnn.resnet18_init)):
        cfg = registry.get(arch).config
        params = init(key, cfg)
        fp = converter.model_nbytes(params)
        _, rep = converter.convert(params, QuantPolicy.binary())
        yield {
            "arch": arch,
            "fp32_mb": round(fp / 1e6, 2),
            "binary_mb": round(rep.bytes_after / 1e6, 3),
            "ratio": round(rep.ratio, 1),
        }


def table2_rows():
    """ResNet-18 partial binarization by stage (paper Table 2 size column:
    3.6MB none-fp ... 47MB all-fp, ImageNet head)."""
    key = jax.random.PRNGKey(0)
    cfg = registry.get("resnet18-cifar10").config
    import dataclasses
    cfg = dataclasses.replace(cfg, n_classes=1000, stem_stride=2, in_hw=224)
    params = cnn.resnet18_init(key, cfg)
    stages = {
        "none": (), "1st": ("stage1",), "2nd": ("stage2",),
        "3rd": ("stage3",), "4th": ("stage4",),
        "1st,2nd": ("stage1", "stage2"),
        "all": ("stage1", "stage2", "stage3", "stage4"),
    }
    for name, fp_stages in stages.items():
        pol = QuantPolicy.binary().with_fp_stages(fp_stages)
        _, rep = converter.convert(params, pol)
        yield {"fp_stages": name, "size_mb": round(rep.bytes_after / 1e6, 2)}


def lm_rows():
    """Beyond-paper: the same accounting on the assigned LM pool — what the
    converter saves at LLM scale (the decode-roofline story)."""
    for arch in registry.ASSIGNED:
        spec = registry.get(arch)
        if spec.family != "lm":
            continue
        cfg = spec.config
        import numpy as np
        from repro.launch import specs as specs_lib
        params = specs_lib.abstract_params(spec, cfg)
        total = sum(x.size for x in jax.tree.leaves(params))
        packed = converter.abstract_packed(params, QuantPolicy.binary())
        pb = 0  # serving bytes: packed words u32, everything else bf16
        for leaf in jax.tree.leaves(packed):
            if np.issubdtype(leaf.dtype, np.floating):
                pb += leaf.size * 2
            else:
                pb += leaf.size * np.dtype(leaf.dtype).itemsize
        yield {
            "arch": arch,
            "params_b": total,
            "bf16_gb": round(total * 2 / 2**30, 2),
            "packed_gb": round(pb / 2**30, 2),
            "weight_traffic_ratio": round(total * 2 / pb, 1),
        }
