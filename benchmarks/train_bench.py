"""Train bench family: the sharded DP train step's correctness gates plus
throughput context.

Two gated rows (CI fails the build via ``run.py --fail-on-mismatch`` if
either reports ``exact_match=False``):

* ``dp_equivalence`` — the uncompressed DP step (psum-mean gradient
  exchange over the 'data' axis) is BIT-IDENTICAL to the single-device
  step with ``microbatch=dp``: same left-fold reduction order, so every
  per-step loss and every final parameter leaf must match exactly.  This
  is the oracle the 1-bit compressed path is measured against.
* ``compressed_vs_uncompressed`` — 1-bit EF gradient compression
  (dist/compress.compressed_psum) trains to within a loss tolerance of
  the uncompressed run over the same schedule (deterministic on CPU, so
  the gate is stable), while shrinking gradient wire bytes ~32x.

The compressed run also logs per-step metrics through a
``train.tracker.JsonlTracker`` to ``BENCH_train_tracker.jsonl`` — the CI
artifact that demonstrates the tracker layer end-to-end (loss, bit-flip
rates, compression ratio, tokens/sec).

Needs >= 2 devices (the CI bench-smoke job forces 8 virtual host
devices); on fewer it emits a single ungated ``skipped`` row.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import QuantPolicy
from repro.data import synthetic
from repro.models import registry
from repro.nn.common import QCtx
from repro.optim import adamw
from repro.train import trainer
from repro.train.tracker import JsonlTracker

TRACKER_ARTIFACT = "BENCH_train_tracker.jsonl"


def _setup(smoke: bool):
    spec = registry.get("granite-3-2b")
    cfg = spec.smoke
    policy = QuantPolicy.binary()
    ctx = QCtx(policy=policy, compute_dtype=jnp.float32)
    steps = 12 if smoke else 30
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=steps)
    dcfg = synthetic.DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                global_batch=8, seed=0)
    batches = [synthetic.batch_at(dcfg, i) for i in range(steps)]
    return spec, cfg, ctx, opt_cfg, steps, batches


def _run_dp(spec, cfg, ctx, opt_cfg, batches, mesh, *, compress,
            tracker=None):
    tc = trainer.TrainConfig(remat=False, grad_compress=compress,
                             bit_flip_metrics=compress)
    dp = dict(mesh.shape)["data"]
    state = trainer.train_state_init(
        spec, cfg, jax.random.PRNGKey(0), grad_compress=compress, dp=dp)
    step_fn = jax.jit(trainer.make_sharded_train_step(
        spec, cfg, ctx, opt_cfg, tc, mesh))
    losses, m = [], {}
    t0 = None
    with mesh:
        for i, b in enumerate(batches):
            if i == 1:
                jax.block_until_ready(state.params)
                t0 = time.perf_counter()
            state, m = step_fn(state, b)
            losses.append(float(m["loss"]))
            if tracker is not None:
                tracker.log(m, step=i + 1)
    jax.block_until_ready(state.params)
    us = (time.perf_counter() - t0) / max(len(batches) - 1, 1) * 1e6
    return state, losses, m, us


def rows(smoke: bool = False):
    if len(jax.devices()) < 2:
        yield {"name": "skipped", "reason": "needs >= 2 devices "
               "(CI forces 8 virtual host devices)"}
        return

    spec, cfg, ctx, opt_cfg, steps, batches = _setup(smoke)
    dp = 4
    mesh = jax.make_mesh((dp, 1), ("data", "model"))

    # --- single-device oracle: microbatch=dp is the same chunked fold ----
    params, opt = trainer.init_all(spec, cfg, jax.random.PRNGKey(0))
    single = jax.jit(trainer.make_train_step(
        spec, cfg, ctx, opt_cfg, remat=False, microbatch=dp))
    s_losses = []
    for b in batches:
        params, opt, m = single(params, opt, b)
        s_losses.append(float(m["loss"]))

    # --- uncompressed DP: must be bit-identical to the oracle -----------
    u_state, u_losses, _, us_u = _run_dp(
        spec, cfg, ctx, opt_cfg, batches, mesh, compress=False)
    leaves_eq = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(u_state.params))
    )
    exact = bool(leaves_eq and s_losses == u_losses)
    yield {"name": "dp_equivalence", "dp": dp, "steps": steps,
           "us_per_step": round(us_u, 1), "exact_match": exact}

    # --- compressed DP: loss tracks the uncompressed run ----------------
    with JsonlTracker(TRACKER_ARTIFACT) as trk:
        _, c_losses, c_m, us_c = _run_dp(
            spec, cfg, ctx, opt_cfg, batches, mesh, compress=True,
            tracker=trk)
    # EF keeps the compressed trajectory within a few percent of the
    # uncompressed one at these smoke scales; deterministic on CPU so a
    # fixed relative tolerance gates stably
    tol = 0.10
    gap = abs(c_losses[-1] - u_losses[-1]) / abs(u_losses[-1])
    yield {"name": "compressed_vs_uncompressed", "dp": dp, "steps": steps,
           "final_loss_uncompressed": round(u_losses[-1], 4),
           "final_loss_compressed": round(c_losses[-1], 4),
           "rel_gap": round(gap, 4), "tolerance": tol,
           "compress_ratio": round(float(c_m["grad_compress_ratio"]), 2),
           "bit_flip_rate": round(float(c_m["bit_flip_rate"]), 5),
           "us_per_step": round(us_c, 1),
           "tracker_artifact": TRACKER_ARTIFACT,
           "exact_match": bool(gap <= tol)}
