"""Paper Table 1/2 accuracy *mechanism* benchmark (offline container: no
MNIST/CIFAR/ImageNet downloads, so absolute numbers are not reproducible —
the DIRECTIONAL claims are):

  * binary model trains and reaches non-trivial accuracy on a synthetic
    classification task;
  * full precision >= binary accuracy (paper: 0.99 vs 0.97 MNIST);
  * partially-binarized (first stage fp) sits between fully-binary and fp
    (paper Table 2's key finding).

Task: 'procedural MNIST' — class = template index, images are fixed random
templates + noise.  Linearly separable-ish; LeNet learns it in ~60 steps.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import QuantPolicy
from repro.models import cnn, registry
from repro.nn.common import QCtx
from repro.optim import adamw


def _data(rng, n, hw, n_classes=10, noise=0.4):
    # class templates are FIXED (own seed) — the label->image map must be
    # stationary across batches for the task to be learnable
    tmpl_rng = np.random.default_rng(42)
    templates = tmpl_rng.standard_normal((n_classes, hw, hw, 1)).astype(
        np.float32)
    labels = rng.integers(0, n_classes, n)
    imgs = templates[labels] + noise * rng.standard_normal(
        (n, hw, hw, 1)).astype(np.float32)
    return imgs, labels


def train_lenet(policy: QuantPolicy, steps=80, seed=0):
    cfg = registry.get("lenet-mnist").smoke
    ctx = QCtx(policy=policy, compute_dtype=jnp.float32)
    params = cnn.lenet_init(jax.random.PRNGKey(seed), cfg)
    opt_cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=steps,
                                weight_decay=0.0)
    opt = adamw.init(params)
    rng = np.random.default_rng(seed)

    def loss_fn(p, x, y):
        logits = cnn.lenet_forward(p, cfg, ctx, x)
        onehot = jax.nn.one_hot(y, 10)
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, -1))

    @jax.jit
    def step(p, o, x, y):
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        p, o, _ = adamw.update(g, o, p, opt_cfg)
        return p, o, l

    for i in range(steps):
        x, y = _data(rng, 64, cfg.in_hw)
        params, opt, l = step(params, opt, jnp.asarray(x), jnp.asarray(y))

    xt, yt = _data(np.random.default_rng(seed + 1), 512, cfg.in_hw)
    logits = cnn.lenet_forward(params, cfg, ctx, jnp.asarray(xt))
    acc = float((jnp.argmax(logits, -1) == jnp.asarray(yt)).mean())
    return acc


def accuracy_rows():
    fp = train_lenet(QuantPolicy.full_precision())
    binary = train_lenet(QuantPolicy.binary())
    yield {"model": "lenet_fp32", "test_acc": round(fp, 3)}
    yield {"model": "lenet_binary", "test_acc": round(binary, 3)}
    yield {"model": "gap_fp_minus_binary", "test_acc": round(fp - binary, 3)}
