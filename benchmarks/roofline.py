"""Roofline aggregation: read experiments/dryrun/*.json and print the
§Roofline table (per arch x shape x mesh x quant: three terms, bottleneck,
useful-flop fraction, fits-HBM verdict)."""

from __future__ import annotations

import argparse
import glob
import json
import os

HBM_PER_CHIP = 16 * 2**30  # v5e


def load(outdir: str):
    recs = []
    for path in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r) -> str:
    if r.get("status") != "ok":
        return (f"{r['arch']:<18} {r['shape']:<12} {'-':<8} {'-':<14} "
                f"SKIPPED: {r.get('reason', '')[:40]}")
    t = r["roofline"]
    dom = max(t, key=t.get)
    lb = max(t.values())
    frac = {k: v / lb for k, v in t.items()}
    fits = "Y" if r["peak_bytes"] <= HBM_PER_CHIP else "OVER"
    return (
        f"{r['arch']:<18} {r['shape']:<12} {r['mesh']:<8} {r['quant']:<14} "
        f"C={t['compute_s']:.2e} M={t['memory_s']:.2e} "
        f"X={t['collective_s']:.2e} dom={dom[:-2]:<11} "
        f"step>={lb:.2e}s eff={t['compute_s'] / lb * 100:5.1f}% "
        f"useful={100 * (r.get('useful_flop_frac') or 0):5.1f}% "
        f"peak={r['peak_bytes'] / 2**30:6.2f}G fits={fits}"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    recs = load(args.dir)
    if args.csv:
        cols = ["arch", "shape", "mesh", "quant", "status"]
        print(",".join(cols + ["compute_s", "memory_s", "collective_s",
                               "bottleneck", "peak_gb", "useful_flop_frac"]))
        for r in recs:
            base = [str(r.get(c, "")) for c in cols]
            if r.get("status") == "ok":
                t = r["roofline"]
                base += [f"{t['compute_s']:.3e}", f"{t['memory_s']:.3e}",
                         f"{t['collective_s']:.3e}", r["bottleneck"],
                         f"{r['peak_bytes'] / 2**30:.2f}",
                         f"{r.get('useful_flop_frac') or 0:.3f}"]
            print(",".join(base))
        return
    print(f"{'arch':<18} {'shape':<12} {'mesh':<8} {'quant':<14} terms "
          f"(C=compute M=memory X=collective, seconds/step lower bound)")
    for r in recs:
        print(fmt_row(r))


if __name__ == "__main__":
    main()
