"""Roofline aggregation: read experiments/dryrun/*.json and print the
§Roofline table (per arch x shape x mesh x quant: three terms, bottleneck,
useful-flop fraction, fits-HBM verdict).

``--kbit`` instead prints the k-bit GEMM *path* model — the two ways the
dispatch layer can contract a DoReFa plane stack, side by side:

* popcount (``vpu-k*``): ``ka*kb`` AND+popcount plane-pair passes,
  ``ka*kb * M*N*K/32`` VPU word-ops, no MXU use at all;
* int8 code-lane (``mxu-k*``): a VPU unpack of ``(ka*M + kb*N)*K`` uint8
  lanes to reassemble the codes, then ONE ``M*N*K`` int8 MAC pass on the
  MXU.

Both stream the *same* packed plane bytes HBM->VMEM (``(ka*M + kb*N)*K/8``
plus the fp32 output), so the memory term is shared and the comparison is
pure arithmetic intensity: the popcount path's compute grows with
``ka*kb`` while the MXU path's is width-independent.  With ``r`` int8
MXU MACs per VPU word-op per unit time (``--mxu-vpu-ratio``), the
compute-side break-even is ``ka*kb = 32 / r`` — at the default r=2 that
is ka*kb=16, i.e. **w4a4 is the break-even and w8a8 a clear MXU win**,
matching what the decode bench family measures.  Real MXUs have r >> 2
(the systolic array retires orders of magnitude more MACs/cycle than the
VPU retires word-ops), which only moves the break-even *down*; the
conservative default keeps the crossover visible inside the swept widths.

``--attn`` prints the decode-attention *path* model — per decode step,
per layer, the bytes each execution path moves over the KV cache
(kernels/attn_decode.py vs the dense-gather oracle) at serving shapes:

* gather-fp: the paged oracle materialises the dense ``(B, L, KVH, Dh)``
  K AND V view before ``_sdpa`` — pool read + dense write + dense
  re-read, 3x the cache bytes (the contiguous layout skips the copy but
  still streams the full fp cache);
* fused-fp: the flash-decode kernel reads each mapped block in place,
  exactly once — 1x the fp cache bytes;
* fused-int8 / fused-1bit: same single pass over 4x / ~16x narrower
  codes (+ scale planes).

Attention FLOPs are identical across paths (2 MAC passes over H*L*Dh per
row), so the comparison is again pure arithmetic intensity: fp decode
attention sits far below the compute roof (intensity ~= G/4 MACs/byte at
fp32 — G the GQA group count), i.e. it is HBM-bound and time/step scales
with the bytes column; the quantized tiers raise intensity toward (and
past) the ``r * VPU_WORD_OPS / HBM_BW`` crossover, where the kernel
stops being a bandwidth problem at all.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

HBM_PER_CHIP = 16 * 2**30  # v5e


def load(outdir: str):
    recs = []
    for path in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r) -> str:
    if r.get("status") != "ok":
        return (f"{r['arch']:<18} {r['shape']:<12} {'-':<8} {'-':<14} "
                f"SKIPPED: {r.get('reason', '')[:40]}")
    t = r["roofline"]
    dom = max(t, key=t.get)
    lb = max(t.values())
    frac = {k: v / lb for k, v in t.items()}
    fits = "Y" if r["peak_bytes"] <= HBM_PER_CHIP else "OVER"
    return (
        f"{r['arch']:<18} {r['shape']:<12} {r['mesh']:<8} {r['quant']:<14} "
        f"C={t['compute_s']:.2e} M={t['memory_s']:.2e} "
        f"X={t['collective_s']:.2e} dom={dom[:-2]:<11} "
        f"step>={lb:.2e}s eff={t['compute_s'] / lb * 100:5.1f}% "
        f"useful={100 * (r.get('useful_flop_frac') or 0):5.1f}% "
        f"peak={r['peak_bytes'] / 2**30:6.2f}G fits={fits}"
    )


# ---------------------------------------------------------------------------
# --kbit: popcount vs int8-code-lane path model (see module docstring)
# ---------------------------------------------------------------------------

# v5e-flavored normalization: VPU word-op rate (one 32-lane AND+popcount+
# accumulate step) in ops/s.  Only RATIOS matter for the path comparison;
# the absolute scale just makes the second columns readable.
VPU_WORD_OPS = 2.4e12
HBM_BW = 819e9  # bytes/s, v5e
UNPACK_LANE_COST = 1 / 8  # uint8 unpack lane-ops per VPU-word-op equivalent


def _kbit_path_row(ka, kb, m, n, k, r):
    """One (widths x M) row of the path model: shared bytes, per-path
    compute ops normalized to VPU word-ops, bottleneck, winner."""
    bytes_ = (ka * m + kb * n) * k / 8 + 4 * m * n
    pop_ops = ka * kb * m * n * k / 32  # word-ops, VPU
    unpack_ops = (ka * m + kb * n) * k * UNPACK_LANE_COST  # word-op equiv
    macs = m * n * k  # int8 MACs, MXU
    t_mem = bytes_ / HBM_BW
    t_pop = pop_ops / VPU_WORD_OPS
    t_mxu = unpack_ops / VPU_WORD_OPS + macs / (r * VPU_WORD_OPS)
    return {
        "quant": f"w{kb}a{ka}", "M": m, "N": n, "K": k,
        "bytes": bytes_,
        "pop_intensity": pop_ops / bytes_,
        "mxu_intensity": (unpack_ops + macs) / bytes_,
        "t_mem": t_mem, "t_pop": t_pop, "t_mxu": t_mxu,
        "pop_bound": "compute" if t_pop > t_mem else "memory",
        "mxu_bound": "compute" if t_mxu > t_mem else "memory",
        "winner": ("mxu-k" if max(t_mxu, t_mem) < max(t_pop, t_mem)
                   else "vpu-k" if max(t_pop, t_mem) < max(t_mxu, t_mem)
                   else "tie"),
    }


def kbit_rows(n, k, r):
    for ka, kb in ((2, 2), (4, 4), (8, 4), (8, 8)):
        for m in (1, 8, 32, 64):
            yield _kbit_path_row(ka, kb, m, n, k, r)


def print_kbit(n, k, r):
    print(f"# k-bit GEMM path model: popcount (vpu-k*) vs int8 code-lane "
          f"(mxu-k*), N={n} K={k}")
    even = 32 / r
    print(f"# shared packed-plane bytes; r={r:g} int8 MACs per VPU word-op "
          f"-> compute break-even at ka*kb = {even:g}"
          + (" (w4a4)" if even == 16 else ""))
    hdr = (f"{'quant':<6} {'M':>3}  {'pop ops/B':>9} {'mxu ops/B':>9}  "
           f"{'t_pop':>9} {'t_mxu':>9} {'t_mem':>9}  "
           f"{'pop':<7} {'mxu':<7} winner")
    print(hdr)
    for row in kbit_rows(n, k, r):
        print(f"{row['quant']:<6} {row['M']:>3}  "
              f"{row['pop_intensity']:>9.2f} {row['mxu_intensity']:>9.2f}  "
              f"{row['t_pop']:>9.2e} {row['t_mxu']:>9.2e} "
              f"{row['t_mem']:>9.2e}  "
              f"{row['pop_bound']:<7} {row['mxu_bound']:<7} {row['winner']}")


# ---------------------------------------------------------------------------
# --attn: decode-attention gather vs fused path model (see module docstring)
# ---------------------------------------------------------------------------


def attn_path_rows(b, l, kvh, g, dh, r):
    """Per (path x decode-M) rows: KV bytes moved per decode step per
    layer, attention MACs (identical across paths), intensity, the
    roofline terms and the byte multiplier vs fused-fp."""
    from repro.kernels.attn_decode import kv_code_shapes

    import numpy as np

    macs = 2 * b * kvh * g * l * dh  # QK + PV MAC passes
    small = 4 * b * kvh * g * dh * 2  # q in + out, fp32 (negligible)
    paths = []
    for name, bits in (("gather-fp", None), ("fused-fp", None),
                       ("fused-int8", 8), ("fused-1bit", 1)):
        (code, cdt), sc = kv_code_shapes(bits, kvh, dh, np.float32)
        per_tok = 2 * (int(np.prod(code)) * np.dtype(cdt).itemsize
                       + (int(np.prod(sc[0])) * np.dtype(sc[1]).itemsize
                          if sc is not None else 0))
        mult = 3 if name == "gather-fp" else 1  # pool read+dense write+read
        paths.append((name, mult * b * l * per_tok + small))
    fp_bytes = dict(paths)["fused-fp"]
    for name, bytes_ in paths:
        t_mem = bytes_ / HBM_BW
        t_comp = macs / (r * VPU_WORD_OPS)
        yield {
            "path": name, "B": b, "L": l,
            "bytes": bytes_, "bytes_vs_fused_fp": bytes_ / fp_bytes,
            "intensity": macs / bytes_,
            "t_mem": t_mem, "t_comp": t_comp,
            "bound": "compute" if t_comp > t_mem else "memory",
        }


def print_attn(l, kvh, g, dh, r):
    crossover = r * VPU_WORD_OPS / HBM_BW
    print(f"# decode-attention path model: dense gather vs fused "
          f"flash-decode, L={l} KVH={kvh} G={g} Dh={dh}")
    print(f"# bytes/step/layer over the KV cache; MACs identical across "
          f"paths -> compute-bound past intensity {crossover:.1f} MAC/B")
    print(f"{'path':<11} {'B':>3}  {'KV bytes':>12} {'vs fused-fp':>11} "
          f"{'MAC/B':>7}  {'t_mem':>9} {'t_comp':>9}  bound")
    for b in (1, 8, 32, 64):
        for row in attn_path_rows(b, l, kvh, g, dh, r):
            print(f"{row['path']:<11} {row['B']:>3}  {row['bytes']:>12,} "
                  f"{row['bytes_vs_fused_fp']:>10.2f}x "
                  f"{row['intensity']:>7.2f}  {row['t_mem']:>9.2e} "
                  f"{row['t_comp']:>9.2e}  {row['bound']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--kbit", action="store_true",
                    help="print the popcount vs int8-code-lane path model "
                         "instead of the dryrun table")
    ap.add_argument("--kbit-n", type=int, default=4096,
                    help="serving N for --kbit (decode GEMM output width)")
    ap.add_argument("--kbit-k", type=int, default=4096,
                    help="serving K for --kbit")
    ap.add_argument("--mxu-vpu-ratio", type=float, default=2.0,
                    help="int8 MXU MACs per VPU word-op per unit time "
                         "(conservative; real MXUs are far higher)")
    ap.add_argument("--attn", action="store_true",
                    help="print the decode-attention gather-vs-fused path "
                         "model instead of the dryrun table")
    ap.add_argument("--attn-l", type=int, default=4096,
                    help="cache length for --attn")
    ap.add_argument("--attn-kvh", type=int, default=8,
                    help="KV heads for --attn")
    ap.add_argument("--attn-g", type=int, default=4,
                    help="GQA group count (query heads per KV head)")
    ap.add_argument("--attn-dh", type=int, default=128,
                    help="head dim for --attn")
    args = ap.parse_args()
    if args.kbit:
        print_kbit(args.kbit_n, args.kbit_k, args.mxu_vpu_ratio)
        return
    if args.attn:
        print_attn(args.attn_l, args.attn_kvh, args.attn_g, args.attn_dh,
                   args.mxu_vpu_ratio)
        return
    recs = load(args.dir)
    if args.csv:
        cols = ["arch", "shape", "mesh", "quant", "status"]
        print(",".join(cols + ["compute_s", "memory_s", "collective_s",
                               "bottleneck", "peak_gb", "useful_flop_frac"]))
        for r in recs:
            base = [str(r.get(c, "")) for c in cols]
            if r.get("status") == "ok":
                t = r["roofline"]
                base += [f"{t['compute_s']:.3e}", f"{t['memory_s']:.3e}",
                         f"{t['collective_s']:.3e}", r["bottleneck"],
                         f"{r['peak_bytes'] / 2**30:.2f}",
                         f"{r.get('useful_flop_frac') or 0:.3f}"]
            print(",".join(base))
        return
    print(f"{'arch':<18} {'shape':<12} {'mesh':<8} {'quant':<14} terms "
          f"(C=compute M=memory X=collective, seconds/step lower bound)")
    for r in recs:
        print(fmt_row(r))


if __name__ == "__main__":
    main()
