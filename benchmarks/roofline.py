"""Roofline aggregation: read experiments/dryrun/*.json and print the
§Roofline table (per arch x shape x mesh x quant: three terms, bottleneck,
useful-flop fraction, fits-HBM verdict).

``--kbit`` instead prints the k-bit GEMM *path* model — the two ways the
dispatch layer can contract a DoReFa plane stack, side by side:

* popcount (``vpu-k*``): ``ka*kb`` AND+popcount plane-pair passes,
  ``ka*kb * M*N*K/32`` VPU word-ops, no MXU use at all;
* int8 code-lane (``mxu-k*``): a VPU unpack of ``(ka*M + kb*N)*K`` uint8
  lanes to reassemble the codes, then ONE ``M*N*K`` int8 MAC pass on the
  MXU.

Both stream the *same* packed plane bytes HBM->VMEM (``(ka*M + kb*N)*K/8``
plus the fp32 output), so the memory term is shared and the comparison is
pure arithmetic intensity: the popcount path's compute grows with
``ka*kb`` while the MXU path's is width-independent.  With ``r`` int8
MXU MACs per VPU word-op per unit time (``--mxu-vpu-ratio``), the
compute-side break-even is ``ka*kb = 32 / r`` — at the default r=2 that
is ka*kb=16, i.e. **w4a4 is the break-even and w8a8 a clear MXU win**,
matching what the decode bench family measures.  Real MXUs have r >> 2
(the systolic array retires orders of magnitude more MACs/cycle than the
VPU retires word-ops), which only moves the break-even *down*; the
conservative default keeps the crossover visible inside the swept widths.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

HBM_PER_CHIP = 16 * 2**30  # v5e


def load(outdir: str):
    recs = []
    for path in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r) -> str:
    if r.get("status") != "ok":
        return (f"{r['arch']:<18} {r['shape']:<12} {'-':<8} {'-':<14} "
                f"SKIPPED: {r.get('reason', '')[:40]}")
    t = r["roofline"]
    dom = max(t, key=t.get)
    lb = max(t.values())
    frac = {k: v / lb for k, v in t.items()}
    fits = "Y" if r["peak_bytes"] <= HBM_PER_CHIP else "OVER"
    return (
        f"{r['arch']:<18} {r['shape']:<12} {r['mesh']:<8} {r['quant']:<14} "
        f"C={t['compute_s']:.2e} M={t['memory_s']:.2e} "
        f"X={t['collective_s']:.2e} dom={dom[:-2]:<11} "
        f"step>={lb:.2e}s eff={t['compute_s'] / lb * 100:5.1f}% "
        f"useful={100 * (r.get('useful_flop_frac') or 0):5.1f}% "
        f"peak={r['peak_bytes'] / 2**30:6.2f}G fits={fits}"
    )


# ---------------------------------------------------------------------------
# --kbit: popcount vs int8-code-lane path model (see module docstring)
# ---------------------------------------------------------------------------

# v5e-flavored normalization: VPU word-op rate (one 32-lane AND+popcount+
# accumulate step) in ops/s.  Only RATIOS matter for the path comparison;
# the absolute scale just makes the second columns readable.
VPU_WORD_OPS = 2.4e12
HBM_BW = 819e9  # bytes/s, v5e
UNPACK_LANE_COST = 1 / 8  # uint8 unpack lane-ops per VPU-word-op equivalent


def _kbit_path_row(ka, kb, m, n, k, r):
    """One (widths x M) row of the path model: shared bytes, per-path
    compute ops normalized to VPU word-ops, bottleneck, winner."""
    bytes_ = (ka * m + kb * n) * k / 8 + 4 * m * n
    pop_ops = ka * kb * m * n * k / 32  # word-ops, VPU
    unpack_ops = (ka * m + kb * n) * k * UNPACK_LANE_COST  # word-op equiv
    macs = m * n * k  # int8 MACs, MXU
    t_mem = bytes_ / HBM_BW
    t_pop = pop_ops / VPU_WORD_OPS
    t_mxu = unpack_ops / VPU_WORD_OPS + macs / (r * VPU_WORD_OPS)
    return {
        "quant": f"w{kb}a{ka}", "M": m, "N": n, "K": k,
        "bytes": bytes_,
        "pop_intensity": pop_ops / bytes_,
        "mxu_intensity": (unpack_ops + macs) / bytes_,
        "t_mem": t_mem, "t_pop": t_pop, "t_mxu": t_mxu,
        "pop_bound": "compute" if t_pop > t_mem else "memory",
        "mxu_bound": "compute" if t_mxu > t_mem else "memory",
        "winner": ("mxu-k" if max(t_mxu, t_mem) < max(t_pop, t_mem)
                   else "vpu-k" if max(t_pop, t_mem) < max(t_mxu, t_mem)
                   else "tie"),
    }


def kbit_rows(n, k, r):
    for ka, kb in ((2, 2), (4, 4), (8, 4), (8, 8)):
        for m in (1, 8, 32, 64):
            yield _kbit_path_row(ka, kb, m, n, k, r)


def print_kbit(n, k, r):
    print(f"# k-bit GEMM path model: popcount (vpu-k*) vs int8 code-lane "
          f"(mxu-k*), N={n} K={k}")
    even = 32 / r
    print(f"# shared packed-plane bytes; r={r:g} int8 MACs per VPU word-op "
          f"-> compute break-even at ka*kb = {even:g}"
          + (" (w4a4)" if even == 16 else ""))
    hdr = (f"{'quant':<6} {'M':>3}  {'pop ops/B':>9} {'mxu ops/B':>9}  "
           f"{'t_pop':>9} {'t_mxu':>9} {'t_mem':>9}  "
           f"{'pop':<7} {'mxu':<7} winner")
    print(hdr)
    for row in kbit_rows(n, k, r):
        print(f"{row['quant']:<6} {row['M']:>3}  "
              f"{row['pop_intensity']:>9.2f} {row['mxu_intensity']:>9.2f}  "
              f"{row['t_pop']:>9.2e} {row['t_mxu']:>9.2e} "
              f"{row['t_mem']:>9.2e}  "
              f"{row['pop_bound']:<7} {row['mxu_bound']:<7} {row['winner']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--kbit", action="store_true",
                    help="print the popcount vs int8-code-lane path model "
                         "instead of the dryrun table")
    ap.add_argument("--kbit-n", type=int, default=4096,
                    help="serving N for --kbit (decode GEMM output width)")
    ap.add_argument("--kbit-k", type=int, default=4096,
                    help="serving K for --kbit")
    ap.add_argument("--mxu-vpu-ratio", type=float, default=2.0,
                    help="int8 MXU MACs per VPU word-op per unit time "
                         "(conservative; real MXUs are far higher)")
    args = ap.parse_args()
    if args.kbit:
        print_kbit(args.kbit_n, args.kbit_k, args.mxu_vpu_ratio)
        return
    recs = load(args.dir)
    if args.csv:
        cols = ["arch", "shape", "mesh", "quant", "status"]
        print(",".join(cols + ["compute_s", "memory_s", "collective_s",
                               "bottleneck", "peak_gb", "useful_flop_frac"]))
        for r in recs:
            base = [str(r.get(c, "")) for c in cols]
            if r.get("status") == "ok":
                t = r["roofline"]
                base += [f"{t['compute_s']:.3e}", f"{t['memory_s']:.3e}",
                         f"{t['collective_s']:.3e}", r["bottleneck"],
                         f"{r['peak_bytes'] / 2**30:.2f}",
                         f"{r.get('useful_flop_frac') or 0:.3f}"]
            print(",".join(base))
        return
    print(f"{'arch':<18} {'shape':<12} {'mesh':<8} {'quant':<14} terms "
          f"(C=compute M=memory X=collective, seconds/step lower bound)")
    for r in recs:
        print(fmt_row(r))


if __name__ == "__main__":
    main()
