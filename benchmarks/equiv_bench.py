"""§2.2.2 equivalence spot-bench: the float-MXU path and the packed-xnor
path agree bit-for-bit, the Pallas kernels (interpret mode) match too, and
the k-bit (DoReFa) plane-packed path matches the fake-quant train path to
fp32 rounding.  Reports timing for context (interpret mode is slow on CPU
by design — the Pallas numbers are correctness evidence, not performance).

Every row carries ``exact_match`` — the CI bench-smoke job fails the build
if any row reports False (benchmarks/run.py --fail-on-mismatch)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitpack, quant
from repro.kernels import dispatch, ops, ref
from repro.kernels.dispatch import GemmConfig


def rows(small: bool = False):
    rng = np.random.default_rng(0)
    m, k, n = (64, 512, 48) if small else (256, 4096, 256)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    oracle = np.asarray(ref.sign_gemm_ref(a, w)).astype(np.int32)
    ap, wp = bitpack.pack_sign(a), bitpack.pack_sign(w.T)

    for backend in ("xla", "vpu", "mxu"):
        t0 = time.perf_counter()
        got = np.asarray(ops.xnor_gemm(ap, wp, k_true=k, backend=backend))
        dt = (time.perf_counter() - t0) * 1e6
        exact = bool((got == oracle).all())
        yield {"backend": backend, "bits": 1, "M": m, "K": k, "N": n,
               "us_per_call_cold": round(dt, 1), "exact_match": exact}

    # tensor-parallel: every shard-* backend must be BIT-IDENTICAL to the
    # oracle at every split (Kw-partial int32 popcounts psum exactly; pad
    # correction applies once on the reduced sum).  Rows appear when the
    # process has multiple devices — CI forces 8 virtual host devices.
    n_dev = len(jax.devices())
    for ways in (2, 8):
        if ways > n_dev:
            continue
        mesh = jax.make_mesh((ways,), ("model",))
        for backend in ("shard-vpu", "shard-mxu"):
            for layout in ("k", "n"):
                cfg = GemmConfig(backend=backend, mesh=mesh,
                                 shard_layout=layout)
                t0 = time.perf_counter()
                got = np.asarray(dispatch.packed_gemm(
                    ap, wp, k_true=k, config=cfg))
                dt = (time.perf_counter() - t0) * 1e6
                yield {"backend": f"{backend}/{layout}x{ways}", "bits": 1,
                       "M": m, "K": k, "N": n,
                       "us_per_call_cold": round(dt, 1),
                       "exact_match": bool((got == oracle).all())}

    # k-bit: plane-packed DoReFa GEMM vs the fake-quant oracle (allclose
    # at fp32 — the integer plane path differs from the float path only by
    # fp32 rounding of the quantized values)
    km, kk, kn = (32, 256, 24) if small else (64, 1024, 64)
    ak = jnp.asarray(rng.standard_normal((km, kk)), jnp.float32)
    wk = jnp.asarray(rng.standard_normal((kk, kn)), jnp.float32)
    for bits in (2, 4, 8):
        wk_planes = bitpack.pack_planes(
            quant.weight_codes(wk.T, bits), bits
        )
        want = np.asarray(ref.dorefa_gemm_ref(ak, wk, bits, bits))
        backends = ("xla", f"vpu-k{bits}", f"mxu-k{bits}")
        if bits == 4 and n_dev >= 2:  # sharded k-bit plane gate rows
            backends += (f"shard-vpu-k{bits}", f"shard-mxu-k{bits}")
        for backend in backends:
            cfg = GemmConfig(
                backend=backend,
                mesh=(jax.make_mesh((2,), ("model",))
                      if backend.startswith("shard-") else None),
            )
            t0 = time.perf_counter()
            got = np.asarray(dispatch.quant_gemm(
                ak, wk_planes, k_true=kk, config=cfg,
                w_bits=bits, a_bits=bits,
            ))
            dt = (time.perf_counter() - t0) * 1e6
            exact = bool(np.allclose(got, want, rtol=1e-5, atol=1e-4))
            yield {"backend": backend, "bits": bits, "M": km, "K": kk,
                   "N": kn, "us_per_call_cold": round(dt, 1),
                   "exact_match": exact}
