"""§2.2.2 equivalence spot-bench: the float-MXU path and the packed-xnor
path agree bit-for-bit, and the Pallas kernels (interpret mode) match too.
Reports timing for context (interpret mode is slow on CPU by design — the
Pallas numbers are correctness evidence, not performance)."""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import bitpack
from repro.kernels import ops, ref


def rows():
    rng = np.random.default_rng(0)
    m, k, n = 256, 4096, 256
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    oracle = np.asarray(ref.sign_gemm_ref(a, w)).astype(np.int32)
    ap, wp = bitpack.pack_sign(a), bitpack.pack_sign(w.T)

    for backend in ("xla", "vpu", "mxu"):
        t0 = time.perf_counter()
        got = np.asarray(ops.xnor_gemm(ap, wp, k_true=k, backend=backend))
        dt = (time.perf_counter() - t0) * 1e6
        exact = bool((got == oracle).all())
        yield {"backend": backend, "M": m, "K": k, "N": n,
               "us_per_call_cold": round(dt, 1), "exact_match": exact}
