"""Autotune the dispatch tile table over the benchmark GEMM shapes and
persist the winners — the committed ``benchmarks/tile_cache.json`` that
CI's bench-smoke job points ``REPRO_TILE_CACHE`` at, so every gated run
selects measured tiles instead of the heuristic table (the ROADMAP
follow-on to the PR-4 autotuning cache).

Shapes covered (the dispatch-routed GEMMs the smoke gate actually hits):

* fig1 conv-mapped sweep (M=filters, K=k*k*Cin, N=batch*spatial^2) in its
  --smoke form, 1-bit backends;
* the kbit sweep / k-bit equivalence shapes, ``vpu-k{2,4,8}`` AND
  ``mxu-k{2,4,8}`` (int8 code-lane) backends;
* the 1-bit equivalence spot-check shape;
* the decode family's serving shapes — M in {1, 8, 32, 64} at the
  serving (N, K), both k-bit families at the swept widths — so the
  decode latency rows (the mxu-k vs vpu-k acceptance comparison) run on
  measured tiles, M=1 rows included (the bm-clamp heuristic rows these
  entries override);
* the fused decode-attention split-KV knobs (kernels/attn_decode.py) at
  the attn-family latency shapes — decode M in {1, 8, 32} over the
  cache_len-2048 serve rig, contiguous kv-tile AND paged
  blocks-per-step — keyed ``attn-ctg``/``attn-pgd`` in the SAME cache,
  so ``KVCache.attend`` picks measured split sizes.

``--full`` adds the full-size fig1/kbit sweep shapes (slow on a CPU rig:
the Pallas kernels autotune in interpret mode there — winners are only
meaningful on real accelerators, but the cache plumbing is identical).

Run:  PYTHONPATH=src python benchmarks/autotune_cache.py [--full]
      [--out benchmarks/tile_cache.json]
"""

from __future__ import annotations

import argparse
import os
import time

# a pre-set REPRO_TILE_CACHE (the CI setting) would otherwise seed the
# in-process cache and silently merge stale entries into --out; this
# script always regenerates from scratch.  Must happen before dispatch's
# lazy _tuned_tiles() first runs.
os.environ.pop("REPRO_TILE_CACHE", None)

from repro.kernels import dispatch  # noqa: E402
from repro.kernels.dispatch import WORD_BITS  # noqa: E402


def _kw(k: int) -> int:
    return (k + WORD_BITS - 1) // WORD_BITS


def conv_shape(filters, kernel, channels, batch, spatial):
    """The fig1-3 conv->GEMM mapping (benchmarks/gemm_bench.conv_gemm_row):
    the packed GEMM runs (M=filters, N=batch*spatial^2, Kw=ceil(K/32))."""
    return filters, batch * spatial * spatial, _kw(kernel * kernel * channels)


_KBIT_BOTH = ("vpu-k2", "mxu-k2", "vpu-k4", "mxu-k4", "vpu-k8", "mxu-k8")


def shapes(full: bool):
    # fig1 --smoke sweep: filters=16, kernel=3, batch=16, spatial=2
    for ch in (16, 32):
        yield conv_shape(16, 3, ch, 16, 2), ("vpu", "mxu")
    # kbit --smoke sweep + k-bit equivalence: (M, K, N) = (32, 288, 16)
    yield (32, 16, _kw(288)), ("vpu", "mxu") + _KBIT_BOTH
    # k-bit equivalence row shape (32, 256, 24)
    yield (32, 24, _kw(256)), _KBIT_BOTH
    # 1-bit equivalence spot check: (64, 512, 48)
    yield (64, 48, _kw(512)), ("vpu", "mxu")
    # decode --smoke serving shape (N=64, K=512) at the swept widths
    for m in (1, 8, 32, 64):
        yield (m, 64, _kw(512)), ("vpu-k4", "mxu-k4", "vpu-k8", "mxu-k8")
    # speculative-draft decode shapes: the w1a1 draft decodes through the
    # 1-bit backends at tiny M — batch rows for draft steps, batch * 2
    # for the restart window (serve/engine.py's spec mode) — so its
    # per-token calls run measured tiles too
    for m in (2, 4, 8):
        yield (m, 64, _kw(512)), ("vpu", "mxu")
    if full:
        for ch in (64, 128, 256, 512):  # fig1 full: kernel=5, spatial=4
            yield conv_shape(64, 5, ch, 200, 4), ("vpu", "mxu")
        # kbit full sweep: (128, 2304, 64)
        yield (128, 64, _kw(2304)), _KBIT_BOTH
        # decode full serving shape (N=1024, K=4096)
        for m in (1, 8, 32, 64):
            yield (m, 1024, _kw(4096)), _KBIT_BOTH


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="benchmarks/tile_cache.json")
    ap.add_argument("--full", action="store_true",
                    help="also tune the full-size (non-smoke) sweep shapes")
    ap.add_argument("--iters", type=int, default=2)
    args = ap.parse_args()

    for (m, n, kw), backends in shapes(args.full):
        for backend in backends:
            t0 = time.perf_counter()
            win = dispatch.autotune_tiles(m, n, kw, backend,
                                          iters=args.iters, persist=False)
            dt = time.perf_counter() - t0
            print(f"M={m:4d} N={n:4d} Kw={kw:3d} {backend:8s} -> "
                  f"bm={win.bm} bn={win.bn} bkw={win.bkw} "
                  f"chunk={win.chunk_words}  ({dt:.1f}s)")

    # fused decode-attention split-KV knobs (benchmarks/attn_bench.py's
    # latency shapes; kvh/dh from the smoke-arch attention geometry)
    from repro.kernels import attn_decode
    kvh, dh, cache_len, block = 2, 16, 2048, 256
    for layout in ("ctg", "pgd"):
        for m in (1, 8, 32):
            t0 = time.perf_counter()
            # attn candidates differ by ~10-20% (not the 2-5x of GEMM
            # tiles), so time them on a larger sample
            win, timings = attn_decode.autotune_attn_tiles(
                m, 1, cache_len, kvh, dh, layout, g=2, block_size=block,
                iters=max(args.iters, 8))
            dt = time.perf_counter() - t0
            knob = "kv_tile" if layout == "ctg" else "blocks_per_step"
            print(f"M={m:4d} L={cache_len} attn-{layout} -> {knob}={win}  "
                  f"({dt:.1f}s)")
    dispatch._save_tile_cache(args.out)
    n = len(dispatch._tuned_tiles())
    print(f"wrote {n} entries to {args.out}")


if __name__ == "__main__":
    main()
