"""Paper Figures 1-3: GEMM method comparison.

The paper benchmarks (on x86): naive C GEMM, Cblas/Atlas, xnor_32/64(+omp)
within a conv layer (M=filters, N=spatial*batch, K=k*k*Cin).  The TPU-
framework equivalents measured here on the host CPU via XLA:

  * ``dense_f32``    — XLA float GEMM (the Cblas stand-in)
  * ``xnor_packed``  — packed xnor GEMM, jnp/XLA reference path (popcount)
  * ``xnor_packed+binarize`` — same, including on-the-fly input packing
    (Fig. 1's "binarize input and xnor_64_omp" bar)
  * ``naive_loop``   — tiny python-loop GEMM on a SUBSAMPLE, extrapolated
    (the paper's naive baseline; only for the speedup denominator)

Axes swept exactly like the paper: Fig1 varies input channels, Fig2 varies
filter count, Fig3 varies kernel size.  Wall-times are host-CPU; the TPU
projection lives in the roofline analysis, not here.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitpack
from repro.kernels import ref


def _time(fn, *args, warmup=2, iters=5) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


import functools


@jax.jit
def _dense(a, b):
    return a @ b


@functools.partial(jax.jit, static_argnums=(2,))
def _xnor_packed(ap, bp, k):
    return ref.xnor_gemm_ref(ap, bp, k)


@functools.partial(jax.jit, static_argnums=(2,))
def _xnor_with_binarize(a, bp, k):
    ap = bitpack.pack_sign(a)
    return ref.xnor_gemm_ref(ap, bp, k)


def _naive_us(m, n, k) -> float:
    """Extrapolated python/NumPy-loop GEMM time (paper's naive baseline)."""
    mm, nn = min(m, 16), min(n, 64)
    a = np.random.randn(mm, k).astype(np.float32)
    b = np.random.randn(k, nn).astype(np.float32)
    t0 = time.perf_counter()
    out = np.zeros((mm, nn), np.float32)
    for i in range(mm):
        for j in range(nn):
            out[i, j] = float(np.dot(a[i], b[:, j]))
    dt = (time.perf_counter() - t0) * 1e6
    return dt * (m / mm) * (n / nn)


def conv_gemm_row(filters=64, kernel=5, channels=256, batch=200, spatial=8):
    """One (M,N,K) point with the paper's conv-layer mapping."""
    m = filters
    k = kernel * kernel * channels
    n = batch * spatial * spatial
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    ap = bitpack.pack_sign(a)
    bp = bitpack.pack_sign(b.T)

    t_dense = _time(_dense, a, b)
    t_xnor = _time(_xnor_packed, ap, bp, k)
    t_xnor_bin = _time(_xnor_with_binarize, a, bp, k)
    t_naive = _naive_us(m, n, k)
    return {
        "M": m, "N": n, "K": k,
        "dense_f32_us": t_dense,
        "xnor_packed_us": t_xnor,
        "xnor_with_binarize_us": t_xnor_bin,
        "naive_us_extrapolated": t_naive,
        "speedup_vs_dense": t_dense / t_xnor,
        "speedup_vs_naive": t_naive / t_xnor,
    }


def fig1_rows(small: bool = False):
    """Fig 1: vary input channel size; filters=64, kernel=5, batch=200.
    ``small`` shrinks every axis for the CI bench-smoke job."""
    if small:
        for ch in (16, 32):
            yield {"sweep": "channels", "value": ch,
                   **conv_gemm_row(filters=16, kernel=3, channels=ch,
                                   batch=16, spatial=2)}
        return
    for ch in (64, 128, 256, 512):
        yield {"sweep": "channels", "value": ch,
               **conv_gemm_row(channels=ch, spatial=4)}


def fig2_rows(small: bool = False):
    """Fig 2: vary filter number; channels=256, kernel=5, batch=200."""
    for f in (8, 16) if small else (16, 32, 64, 128):
        yield {"sweep": "filters", "value": f,
               **(conv_gemm_row(filters=f, kernel=3, channels=32, batch=16,
                                spatial=2) if small
                  else conv_gemm_row(filters=f, spatial=4))}


def fig3_rows(small: bool = False):
    """Fig 3: vary kernel size; channels=256, batch=200, filters=64."""
    for ks in (1, 3) if small else (1, 3, 5, 7):
        yield {"sweep": "kernel", "value": ks,
               **(conv_gemm_row(filters=16, kernel=ks, channels=32,
                                batch=16, spatial=2) if small
                  else conv_gemm_row(kernel=ks, spatial=4))}


# ---------------------------------------------------------------------------
# Fig. 1's "binarize input" stage in isolation: the fused quantize->pack
# Pallas prologue (kernels/pack_bits.py, via dispatch.pack_activations /
# pack_act_planes) vs the jnp reference round trip (pack_sign /
# act_codes -> pack_planes).  Every row carries ``exact_match`` — the
# fused kernels must be BIT-IDENTICAL to the jnp oracle (code row-sums
# included), and the CI bench-smoke gate fails the build otherwise.  On
# this host-CPU rig the Pallas numbers run in interpret mode (correctness
# evidence, not performance).
# ---------------------------------------------------------------------------


def pack_rows(small: bool = False):
    from repro.core import quant
    from repro.kernels import dispatch

    m, k = (64, 512) if small else (512, 4096)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)

    def fused_sign(x):
        return dispatch.pack_activations(x, use_pallas=True)

    def jnp_sign(x):
        return dispatch.pack_activations(x, use_pallas=False)

    want = np.asarray(bitpack.pack_sign(a))
    got = np.asarray(fused_sign(a))
    yield {
        "stage": "pack_sign", "bits": 1, "M": m, "K": k,
        "jnp_us": round(_time(jnp_sign, a), 1),
        "fused_us": round(_time(fused_sign, a), 1),
        "exact_match": bool((got == want).all()),
    }

    for bits in (2, 4, 8) if not small else (2, 4):
        def fused_planes(x, b=bits):
            return dispatch.pack_act_planes(x, b, fused=True)

        def jnp_planes(x, b=bits):
            return dispatch.pack_act_planes(x, b, fused=False)

        codes = quant.act_codes(a, bits)
        want_p = np.asarray(bitpack.pack_planes(codes, bits))
        want_t = np.asarray(codes.astype(jnp.int32).sum(-1))
        got_p, got_t = fused_planes(a)
        exact = bool(
            (np.asarray(got_p) == want_p).all()
            and (np.asarray(got_t)[:, 0] == want_t).all()
        )
        yield {
            "stage": "quant_pack_planes", "bits": bits, "M": m, "K": k,
            "jnp_us": round(_time(jnp_planes, a), 1),
            "fused_us": round(_time(fused_planes, a), 1),
            "exact_match": exact,
        }


# ---------------------------------------------------------------------------
# Beyond-paper: the k-bit (DoReFa) sweep — how the bit-plane popcount GEMM
# scales with bit width.  Work grows as ka*kb plane pairs while packed HBM
# bytes grow as k/32 of fp32; the sweep reports both so the roofline can
# place w2/w4/w8 serving between the 1-bit xnor path and dense f32.
# ---------------------------------------------------------------------------


@jax.jit
def _plane_gemm(ap, wp):
    return ref.kbit_gemm_ref(ap, wp)


# ---------------------------------------------------------------------------
# Beyond-paper: the tensor-parallel (shard-*) sweep — the same packed GEMM
# partitioned across mesh devices (Kw-partial popcount + psum, or
# N-partitioned weights).  Every row carries ``exact_match`` against the
# single-device backend: the sharded path must be BIT-IDENTICAL, and the
# CI equivalence gate also covers it (benchmarks/equiv_bench.py).  On this
# host-CPU rig the timings measure collective/shard_map overhead, not TPU
# speedup — the correctness columns are the point.
# ---------------------------------------------------------------------------


def shard_rows(small: bool = False):
    """Sweep shard width (1/2/4/8-way) x backend over a fixed conv-mapped
    GEMM.  Multi-way rows need multiple devices — CI forces 8 virtual
    host devices via XLA_FLAGS.  In --smoke (CI gate) mode a single-device
    process emits an explicit ``exact_match=False`` row instead of
    silently skipping: otherwise a dropped/ignored XLA flag would turn
    the sharded-vs-single-device gate vacuously green."""
    from repro.kernels import dispatch
    from repro.kernels.dispatch import GemmConfig

    ndev = len(jax.devices())
    if small and ndev < 2:
        yield {
            "backend": "shard-*", "layout": "-", "ways": 0, "devices": ndev,
            "error": "smoke shard sweep needs >= 2 devices (set XLA_FLAGS="
                     "--xla_force_host_platform_device_count=8)",
            "exact_match": False,
        }
        return
    m, k, n = (32, 288, 16) if small else (128, 2304, 64)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    ap = bitpack.pack_sign(a)
    wp = bitpack.pack_sign(w.T)

    def run(cfg):
        return dispatch.packed_gemm(ap, wp, k_true=k, config=cfg)

    single = {}
    for inner in ("vpu", "mxu"):
        cfg = GemmConfig(backend=inner)
        # the correctness run doubles as the jit warm-up
        single[inner] = (np.asarray(run(cfg)),
                         _time(run, cfg, warmup=0, iters=2))

    for ways in (1, 2, 4, 8):
        if ways > ndev:
            continue
        mesh = jax.make_mesh((ways,), ("model",))
        for inner in ("vpu", "mxu"):
            for layout in ("k", "n"):
                cfg = GemmConfig(backend=f"shard-{inner}", mesh=mesh,
                                 shard_layout=layout)
                got = np.asarray(run(cfg))  # also the jit warm-up
                t_us = _time(run, cfg, warmup=0, iters=2)
                want, t_single = single[inner]
                yield {
                    "backend": f"shard-{inner}", "layout": layout,
                    "ways": ways, "M": m, "N": n, "K": k,
                    "devices": ndev,
                    "single_device_us": round(t_single, 1),
                    "sharded_us": round(t_us, 1),
                    "exact_match": bool((got == want).all()),
                }


# ---------------------------------------------------------------------------
# Beyond-paper: decode-shape latency — the serving regime the mxu-k*
# backends target.  Autoregressive decode runs the quantized GEMM at tiny
# M (the in-flight batch) against fixed serving (N, K); the plane-popcount
# path pays ka*kb plane-pair passes regardless of M while the int8
# code-lane MXU path pays one dot, so the win should show exactly here.
# Rows time the full fused-prologue from-float path (dispatch.quant_gemm)
# for dense f32 vs vpu-k{bits} vs mxu-k{bits} at M in {1, 8, 32, 64}.
# Every row carries ``exact_match``: the mxu-k result must be BIT-identical
# to the vpu-k result (same raw (S, T) -> same fp32 dequant) and both must
# match the fake-quant oracle to fp32 rounding.  The overlap rows gate
# ``GemmConfig.overlap_collective`` — the chunked ppermute ring on the
# sharded "k" layout must be bit-identical to the sequential-psum default
# at every split, overlap on AND off.  All rows are covered by the CI
# bench-smoke --fail-on-mismatch gate.
# ---------------------------------------------------------------------------


def decode_rows(small: bool = False):
    from repro.core import quant
    from repro.kernels import dispatch, ref
    from repro.kernels.dispatch import GemmConfig

    n, k = (64, 512) if small else (1024, 4096)
    bits_sweep = (4, 8) if small else (2, 4, 8)
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)

    def run(cfg, x, w_planes, bits):
        return dispatch.quant_gemm(x, w_planes, k_true=k, config=cfg,
                                   w_bits=bits, a_bits=bits)

    planes = {
        bits: bitpack.pack_planes(quant.weight_codes(w.T, bits), bits)
        for bits in bits_sweep
    }
    for bits in bits_sweep:
        w_planes = planes[bits]
        cfg_v = GemmConfig(backend=f"vpu-k{bits}")
        cfg_m = GemmConfig(backend=f"mxu-k{bits}")
        for m in (1, 8, 32, 64):
            x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
            want = np.asarray(ref.dorefa_gemm_ref(x, w, bits, bits))
            # the correctness runs double as the first jit warm-up; decode
            # calls are sub-ms here, so report the min over repeated
            # timing blocks (single-block means swing 2x on a shared host)
            got_v = np.asarray(run(cfg_v, x, w_planes, bits))
            got_m = np.asarray(run(cfg_m, x, w_planes, bits))
            t_dense = min(_time(_dense, x, w) for _ in range(3))
            t_v = min(_time(run, cfg_v, x, w_planes, bits, warmup=1,
                            iters=5) for _ in range(3))
            t_m = min(_time(run, cfg_m, x, w_planes, bits, warmup=1,
                            iters=5) for _ in range(3))
            exact = bool(
                (got_m == got_v).all()
                and np.allclose(got_m, want, rtol=1e-5, atol=1e-4)
            )
            yield {
                "M": m, "N": n, "K": k, "bits": bits,
                "plane_pairs": bits * bits,
                "dense_f32_us": round(t_dense, 1),
                "vpu_k_us": round(t_v, 1),
                "mxu_k_us": round(t_m, 1),
                "mxu_speedup_vs_vpu": round(t_v / t_m, 2),
                "exact_match": exact,
            }


def overlap_rows(small: bool = False):
    """overlap_collective gate: ring reduce-scatter == sequential psum ==
    single device on the sharded "k" layout (the decode serving layout),
    bit-identical for both k-bit families.  Split from ``decode_rows`` so
    the single-device decode latency sweep can run WITHOUT the virtual
    multi-device platform split (which divides the host thread pool and
    distorts single-device timings); this family needs the devices and
    runs alongside the other shard benches.  Like shard_rows, a smoke run
    without devices emits an explicit failing row instead of silently
    going vacuously green."""
    from repro.core import quant
    from repro.kernels import dispatch
    from repro.kernels.dispatch import GemmConfig

    n, k = (64, 512) if small else (1024, 4096)
    bits_sweep = (4, 8) if small else (2, 4, 8)
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)

    def run(cfg, x, w_planes, bits):
        return dispatch.quant_gemm(x, w_planes, k_true=k, config=cfg,
                                   w_bits=bits, a_bits=bits)

    ndev = len(jax.devices())
    if ndev < 2:
        if small:
            yield {
                "backend": "shard-*-k8/overlap", "ways": 0, "devices": ndev,
                "error": "overlap gate needs >= 2 devices (set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)",
                "exact_match": False,
            }
        return
    bits = max(bits_sweep)
    w_planes = bitpack.pack_planes(quant.weight_codes(w.T, bits), bits)
    m = 8
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    for ways in (2, 4):
        if ways > ndev:
            continue
        mesh = jax.make_mesh((ways,), ("model",))
        for fam in ("vpu", "mxu"):
            base = np.asarray(
                run(GemmConfig(backend=f"{fam}-k{bits}"), x, w_planes, bits))
            for overlap in (False, True):
                cfg = GemmConfig(backend=f"shard-{fam}", mesh=mesh,
                                 shard_layout="k",
                                 overlap_collective=overlap)
                got = np.asarray(run(cfg, x, w_planes, bits))
                t_us = _time(run, cfg, x, w_planes, bits, warmup=0, iters=2)
                yield {
                    "backend": f"shard-{fam}-k{bits}/k", "ways": ways,
                    "overlap": overlap, "M": m, "N": n, "K": k,
                    "bits": bits, "devices": ndev,
                    "sharded_us": round(t_us, 1),
                    "exact_match": bool((got == base).all()),
                }

    # packed-operand and grouped/expert shard paths ride the same ring
    # now (PR 9) — gate each entry point at one split
    ways = 4 if ndev >= 4 else 2
    mesh = jax.make_mesh((ways,), ("model",))
    ap = bitpack.pack_sign(jnp.where(x >= 0, 1.0, -1.0))
    wp1 = bitpack.pack_sign(jnp.where(w.T >= 0, 1.0, -1.0))
    base1 = np.asarray(dispatch.packed_gemm(
        ap, wp1, k_true=k, config=GemmConfig(backend="vpu")))

    def run_packed(cfg):
        return dispatch.packed_gemm(ap, wp1, k_true=k, config=cfg)

    for fam in ("vpu", "mxu"):
        for overlap in (False, True):
            cfg = GemmConfig(backend=f"shard-{fam}", mesh=mesh,
                             shard_layout="k", overlap_collective=overlap)
            got = np.asarray(run_packed(cfg))
            t_us = _time(run_packed, cfg, warmup=0, iters=2)
            yield {
                "backend": f"shard-{fam}-packed/k", "ways": ways,
                "overlap": overlap, "M": m, "N": n, "K": k,
                "bits": 1, "devices": ndev,
                "sharded_us": round(t_us, 1),
                "exact_match": bool((got == base1).all()),
            }

    e, t_rows = 2, m
    w_grp = jnp.stack([jnp.where(w.T >= 0, 1.0, -1.0),
                       jnp.where(w.T >= 0, -1.0, 1.0)])
    w_grp_p = jnp.stack([bitpack.pack_sign(w_grp[i]) for i in range(e)])
    gs = jnp.asarray([t_rows - 3, 3], jnp.int32)
    base_g = np.asarray(dispatch.quant_gemm_grouped(
        x, w_grp_p, gs, k_true=k, config=GemmConfig(backend="vpu")))

    def run_grouped(cfg):
        return dispatch.quant_gemm_grouped(x, w_grp_p, gs, k_true=k,
                                           config=cfg)

    for fam in ("vpu", "mxu"):
        for overlap in (False, True):
            cfg = GemmConfig(backend=f"shard-{fam}", mesh=mesh,
                             shard_layout="k", overlap_collective=overlap)
            got = np.asarray(run_grouped(cfg))
            t_us = _time(run_grouped, cfg, warmup=0, iters=2)
            yield {
                "backend": f"shard-{fam}-grouped/k", "ways": ways,
                "overlap": overlap, "E": e, "M": m, "N": n, "K": k,
                "bits": 1, "devices": ndev,
                "sharded_us": round(t_us, 1),
                "exact_match": bool((got == base_g).all()),
            }


def kbit_rows(small: bool = False):
    """Sweep bit width k over a fixed conv-mapped GEMM (jnp/XLA reference
    path, like the fig1-3 rows; the Pallas plane kernel is correctness-
    checked in the equiv table)."""
    from repro.core import quant

    m, k, n = (32, 288, 16) if small else (128, 2304, 64)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    t_dense = _time(_dense, a, w)
    for bits in (1, 2, 4, 8):
        if bits == 1:
            ap = bitpack.pack_sign(a)
            wp = bitpack.pack_sign(w.T)
            t_packed = _time(_xnor_packed, ap, wp, k)
        else:
            ap = bitpack.pack_planes(quant.act_codes(a, bits), bits)
            wp = bitpack.pack_planes(quant.weight_codes(w.T, bits), bits)
            t_packed = _time(_plane_gemm, ap, wp)
        yield {
            "bits": bits, "M": m, "N": n, "K": k,
            "plane_pairs": bits * bits,
            "dense_f32_us": t_dense,
            "packed_gemm_us": t_packed,
            "us_per_plane_pair": t_packed / (bits * bits),
            "packed_bytes_frac_of_f32": bits / 32,
            "speedup_vs_dense": t_dense / t_packed,
        }
