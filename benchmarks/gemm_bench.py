"""Paper Figures 1-3: GEMM method comparison.

The paper benchmarks (on x86): naive C GEMM, Cblas/Atlas, xnor_32/64(+omp)
within a conv layer (M=filters, N=spatial*batch, K=k*k*Cin).  The TPU-
framework equivalents measured here on the host CPU via XLA:

  * ``dense_f32``    — XLA float GEMM (the Cblas stand-in)
  * ``xnor_packed``  — packed xnor GEMM, jnp/XLA reference path (popcount)
  * ``xnor_packed+binarize`` — same, including on-the-fly input packing
    (Fig. 1's "binarize input and xnor_64_omp" bar)
  * ``naive_loop``   — tiny python-loop GEMM on a SUBSAMPLE, extrapolated
    (the paper's naive baseline; only for the speedup denominator)

Axes swept exactly like the paper: Fig1 varies input channels, Fig2 varies
filter count, Fig3 varies kernel size.  Wall-times are host-CPU; the TPU
projection lives in the roofline analysis, not here.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitpack
from repro.kernels import ref


def _time(fn, *args, warmup=2, iters=5) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


import functools


@jax.jit
def _dense(a, b):
    return a @ b


@functools.partial(jax.jit, static_argnums=(2,))
def _xnor_packed(ap, bp, k):
    return ref.xnor_gemm_ref(ap, bp, k)


@functools.partial(jax.jit, static_argnums=(2,))
def _xnor_with_binarize(a, bp, k):
    ap = bitpack.pack_sign(a)
    return ref.xnor_gemm_ref(ap, bp, k)


def _naive_us(m, n, k) -> float:
    """Extrapolated python/NumPy-loop GEMM time (paper's naive baseline)."""
    mm, nn = min(m, 16), min(n, 64)
    a = np.random.randn(mm, k).astype(np.float32)
    b = np.random.randn(k, nn).astype(np.float32)
    t0 = time.perf_counter()
    out = np.zeros((mm, nn), np.float32)
    for i in range(mm):
        for j in range(nn):
            out[i, j] = float(np.dot(a[i], b[:, j]))
    dt = (time.perf_counter() - t0) * 1e6
    return dt * (m / mm) * (n / nn)


def conv_gemm_row(filters=64, kernel=5, channels=256, batch=200, spatial=8):
    """One (M,N,K) point with the paper's conv-layer mapping."""
    m = filters
    k = kernel * kernel * channels
    n = batch * spatial * spatial
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    ap = bitpack.pack_sign(a)
    bp = bitpack.pack_sign(b.T)

    t_dense = _time(_dense, a, b)
    t_xnor = _time(_xnor_packed, ap, bp, k)
    t_xnor_bin = _time(_xnor_with_binarize, a, bp, k)
    t_naive = _naive_us(m, n, k)
    return {
        "M": m, "N": n, "K": k,
        "dense_f32_us": t_dense,
        "xnor_packed_us": t_xnor,
        "xnor_with_binarize_us": t_xnor_bin,
        "naive_us_extrapolated": t_naive,
        "speedup_vs_dense": t_dense / t_xnor,
        "speedup_vs_naive": t_naive / t_xnor,
    }


def fig1_rows(small: bool = False):
    """Fig 1: vary input channel size; filters=64, kernel=5, batch=200.
    ``small`` shrinks every axis for the CI bench-smoke job."""
    if small:
        for ch in (16, 32):
            yield {"sweep": "channels", "value": ch,
                   **conv_gemm_row(filters=16, kernel=3, channels=ch,
                                   batch=16, spatial=2)}
        return
    for ch in (64, 128, 256, 512):
        yield {"sweep": "channels", "value": ch,
               **conv_gemm_row(channels=ch, spatial=4)}


def fig2_rows(small: bool = False):
    """Fig 2: vary filter number; channels=256, kernel=5, batch=200."""
    for f in (8, 16) if small else (16, 32, 64, 128):
        yield {"sweep": "filters", "value": f,
               **(conv_gemm_row(filters=f, kernel=3, channels=32, batch=16,
                                spatial=2) if small
                  else conv_gemm_row(filters=f, spatial=4))}


def fig3_rows(small: bool = False):
    """Fig 3: vary kernel size; channels=256, batch=200, filters=64."""
    for ks in (1, 3) if small else (1, 3, 5, 7):
        yield {"sweep": "kernel", "value": ks,
               **(conv_gemm_row(filters=16, kernel=ks, channels=32,
                                batch=16, spatial=2) if small
                  else conv_gemm_row(kernel=ks, spatial=4))}


# ---------------------------------------------------------------------------
# Fig. 1's "binarize input" stage in isolation: the fused quantize->pack
# Pallas prologue (kernels/pack_bits.py, via dispatch.pack_activations /
# pack_act_planes) vs the jnp reference round trip (pack_sign /
# act_codes -> pack_planes).  Every row carries ``exact_match`` — the
# fused kernels must be BIT-IDENTICAL to the jnp oracle (code row-sums
# included), and the CI bench-smoke gate fails the build otherwise.  On
# this host-CPU rig the Pallas numbers run in interpret mode (correctness
# evidence, not performance).
# ---------------------------------------------------------------------------


def pack_rows(small: bool = False):
    from repro.core import quant
    from repro.kernels import dispatch

    m, k = (64, 512) if small else (512, 4096)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)

    def fused_sign(x):
        return dispatch.pack_activations(x, use_pallas=True)

    def jnp_sign(x):
        return dispatch.pack_activations(x, use_pallas=False)

    want = np.asarray(bitpack.pack_sign(a))
    got = np.asarray(fused_sign(a))
    yield {
        "stage": "pack_sign", "bits": 1, "M": m, "K": k,
        "jnp_us": round(_time(jnp_sign, a), 1),
        "fused_us": round(_time(fused_sign, a), 1),
        "exact_match": bool((got == want).all()),
    }

    for bits in (2, 4, 8) if not small else (2, 4):
        def fused_planes(x, b=bits):
            return dispatch.pack_act_planes(x, b, fused=True)

        def jnp_planes(x, b=bits):
            return dispatch.pack_act_planes(x, b, fused=False)

        codes = quant.act_codes(a, bits)
        want_p = np.asarray(bitpack.pack_planes(codes, bits))
        want_t = np.asarray(codes.astype(jnp.int32).sum(-1))
        got_p, got_t = fused_planes(a)
        exact = bool(
            (np.asarray(got_p) == want_p).all()
            and (np.asarray(got_t)[:, 0] == want_t).all()
        )
        yield {
            "stage": "quant_pack_planes", "bits": bits, "M": m, "K": k,
            "jnp_us": round(_time(jnp_planes, a), 1),
            "fused_us": round(_time(fused_planes, a), 1),
            "exact_match": exact,
        }


# ---------------------------------------------------------------------------
# Beyond-paper: the k-bit (DoReFa) sweep — how the bit-plane popcount GEMM
# scales with bit width.  Work grows as ka*kb plane pairs while packed HBM
# bytes grow as k/32 of fp32; the sweep reports both so the roofline can
# place w2/w4/w8 serving between the 1-bit xnor path and dense f32.
# ---------------------------------------------------------------------------


@jax.jit
def _plane_gemm(ap, wp):
    return ref.kbit_gemm_ref(ap, wp)


# ---------------------------------------------------------------------------
# Beyond-paper: the tensor-parallel (shard-*) sweep — the same packed GEMM
# partitioned across mesh devices (Kw-partial popcount + psum, or
# N-partitioned weights).  Every row carries ``exact_match`` against the
# single-device backend: the sharded path must be BIT-IDENTICAL, and the
# CI equivalence gate also covers it (benchmarks/equiv_bench.py).  On this
# host-CPU rig the timings measure collective/shard_map overhead, not TPU
# speedup — the correctness columns are the point.
# ---------------------------------------------------------------------------


def shard_rows(small: bool = False):
    """Sweep shard width (1/2/4/8-way) x backend over a fixed conv-mapped
    GEMM.  Multi-way rows need multiple devices — CI forces 8 virtual
    host devices via XLA_FLAGS.  In --smoke (CI gate) mode a single-device
    process emits an explicit ``exact_match=False`` row instead of
    silently skipping: otherwise a dropped/ignored XLA flag would turn
    the sharded-vs-single-device gate vacuously green."""
    from repro.kernels import dispatch
    from repro.kernels.dispatch import GemmConfig

    ndev = len(jax.devices())
    if small and ndev < 2:
        yield {
            "backend": "shard-*", "layout": "-", "ways": 0, "devices": ndev,
            "error": "smoke shard sweep needs >= 2 devices (set XLA_FLAGS="
                     "--xla_force_host_platform_device_count=8)",
            "exact_match": False,
        }
        return
    m, k, n = (32, 288, 16) if small else (128, 2304, 64)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    ap = bitpack.pack_sign(a)
    wp = bitpack.pack_sign(w.T)

    def run(cfg):
        return dispatch.packed_gemm(ap, wp, k_true=k, config=cfg)

    single = {}
    for inner in ("vpu", "mxu"):
        cfg = GemmConfig(backend=inner)
        # the correctness run doubles as the jit warm-up
        single[inner] = (np.asarray(run(cfg)),
                         _time(run, cfg, warmup=0, iters=2))

    for ways in (1, 2, 4, 8):
        if ways > ndev:
            continue
        mesh = jax.make_mesh((ways,), ("model",))
        for inner in ("vpu", "mxu"):
            for layout in ("k", "n"):
                cfg = GemmConfig(backend=f"shard-{inner}", mesh=mesh,
                                 shard_layout=layout)
                got = np.asarray(run(cfg))  # also the jit warm-up
                t_us = _time(run, cfg, warmup=0, iters=2)
                want, t_single = single[inner]
                yield {
                    "backend": f"shard-{inner}", "layout": layout,
                    "ways": ways, "M": m, "N": n, "K": k,
                    "devices": ndev,
                    "single_device_us": round(t_single, 1),
                    "sharded_us": round(t_us, 1),
                    "exact_match": bool((got == want).all()),
                }


def kbit_rows(small: bool = False):
    """Sweep bit width k over a fixed conv-mapped GEMM (jnp/XLA reference
    path, like the fig1-3 rows; the Pallas plane kernel is correctness-
    checked in the equiv table)."""
    from repro.core import quant

    m, k, n = (32, 288, 16) if small else (128, 2304, 64)
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    t_dense = _time(_dense, a, w)
    for bits in (1, 2, 4, 8):
        if bits == 1:
            ap = bitpack.pack_sign(a)
            wp = bitpack.pack_sign(w.T)
            t_packed = _time(_xnor_packed, ap, wp, k)
        else:
            ap = bitpack.pack_planes(quant.act_codes(a, bits), bits)
            wp = bitpack.pack_planes(quant.weight_codes(w.T, bits), bits)
            t_packed = _time(_plane_gemm, ap, wp)
        yield {
            "bits": bits, "M": m, "N": n, "K": k,
            "plane_pairs": bits * bits,
            "dense_f32_us": t_dense,
            "packed_gemm_us": t_packed,
            "us_per_plane_pair": t_packed / (bits * bits),
            "packed_bytes_frac_of_f32": bits / 32,
            "speedup_vs_dense": t_dense / t_packed,
        }
