"""Inject the roofline tables into EXPERIMENTS.md from the dry-run JSONs."""

import glob
import json
import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

HBM = 16 * 2**30

ARCH_ORDER = [
    "recurrentgemma-2b", "rwkv6-7b", "deepseek-7b", "granite-3-2b",
    "qwen2-72b", "gemma2-27b", "deepseek-moe-16b", "qwen2-moe-a2.7b",
    "internvl2-1b", "whisper-base",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(d):
    recs = {}
    for p in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(p))
        recs[(r["arch"], r["shape"], r.get("quant", "fp"))] = r
    return recs


def row(r):
    if r is None:
        return None
    if r.get("status") != "ok":
        return (f"| {r['arch']} | {r['shape']} | {r.get('quant','fp')} | "
                f"SKIP | — | — | — | — | — | {r.get('reason','')[:48]} |")
    t = r["roofline"]
    dom = {"compute_s": "compute", "memory_s": "memory",
           "collective_s": "collective"}[r["bottleneck"]]
    lb = r["step_time_lb_s"]
    eff = t["compute_s"] / lb * 100 if lb else 0
    fits = "yes" if r["peak_bytes"] <= HBM else f"OVER ({r['peak_bytes']/2**30:.0f}G)"
    uf = r.get("useful_flop_frac") or 0
    return (f"| {r['arch']} | {r['shape']} | {r.get('quant','fp')} | "
            f"{t['compute_s']:.2e} | {t['memory_s']:.2e} | "
            f"{t['collective_s']:.2e} | **{dom}** {eff:.0f}% | "
            f"{min(uf,9.99)*100:.0f}% | {r['peak_bytes']/2**30:.2f} | {fits} |")


def table(recs, quants=("fp",)):
    head = ("| arch | shape | quant | compute s | memory s | collective s | "
            "dominant → roofline-frac | useful | peak GiB/dev | fits 16G |\n"
            "|---|---|---|---|---|---|---|---|---|---|")
    lines = [head]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            for q in quants:
                r = recs.get((a, s, q)) or recs.get((a, s, q + "+sp"))
                rr = row(r)
                if rr:
                    lines.append(rr)
    return "\n".join(lines)


def main():
    single = load("experiments/dryrun")
    multi = load("experiments/dryrun_multipod")

    md = open("EXPERIMENTS.md").read()
    block = "### Single-pod 16×16 baselines (fp) + packed serving variants\n\n"
    block += table(single, quants=("fp", "binary_packed"))
    block += "\n\n### Multi-pod 2×16×16 (fp) — every cell compiles\n\n"
    block += table(multi, quants=("fp",))
    md = md.replace("<!-- ROOFLINE_TABLE -->", block)
    open("EXPERIMENTS.md", "w").write(md)
    print("injected", len(single), "single-pod +", len(multi),
          "multi-pod records")


if __name__ == "__main__":
    main()
