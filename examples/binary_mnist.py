"""The paper's flagship example (smd_hpi/examples/binary_mnist): train a
binary LeNet and compare with full precision — accuracy gap and model size
(paper Table 1: 0.97 vs 0.99, 206kB vs 4.6MB).

Offline container => procedurally generated MNIST-like data (10 fixed
templates + noise).  Absolute accuracies differ from the paper's MNIST
numbers; the *mechanism* (binary trains ~as well; 22x smaller) is the
reproduction target.

Run:  PYTHONPATH=src python examples/binary_mnist.py
"""

import jax

from benchmarks.accuracy_bench import train_lenet
from repro.core import converter
from repro.core.policy import QuantPolicy
from repro.models import cnn, registry


def main():
    print("== training LeNet fp32 vs binary (synthetic MNIST) ==")
    acc_fp = train_lenet(QuantPolicy.full_precision(), steps=100)
    acc_bin = train_lenet(QuantPolicy.binary(), steps=100)
    print(f"  test accuracy  fp32={acc_fp:.3f}  binary={acc_bin:.3f} "
          f"(paper MNIST: 0.99 / 0.97)")

    cfg = registry.get("lenet-mnist").config  # full-size for the size table
    params = cnn.lenet_init(jax.random.PRNGKey(0), cfg)
    fp_mb = converter.model_nbytes(params) / 1e6
    _, rep = converter.convert(params, QuantPolicy.binary())
    print(f"  model size     fp32={fp_mb:.2f}MB  "
          f"binary={rep.bytes_after / 1e6:.3f}MB  ratio={rep.ratio:.1f}x "
          f"(paper: 4.6MB / 0.206MB)")


if __name__ == "__main__":
    main()
