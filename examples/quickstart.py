"""Quickstart: the full BMXNet lifecycle on a reduced LM, end to end.

1. train a *binary* (1-bit weights & activations) granite-family LM on the
   synthetic pipeline — BLAS/MXU path, STE gradients;
2. export the packed 1-bit checkpoint with the model converter (§2.2.3);
3. serve it with the xnor+popcount path and verify the generations match
   the training path bit-for-bit (§2.2.2).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import converter
from repro.core.policy import QuantPolicy
from repro.data import synthetic
from repro.models import registry
from repro.nn.common import QCtx
from repro.optim import adamw
from repro.serve.engine import Engine, EngineConfig
from repro.train import trainer

ARCH = "granite-3-2b"
STEPS = 120


def main():
    spec = registry.get(ARCH)
    cfg = spec.smoke
    policy = QuantPolicy.binary()
    ctx = QCtx(policy=policy, compute_dtype=jnp.float32)

    print(f"== 1. training binary {ARCH} (reduced config) ==")
    opt_cfg = adamw.AdamWConfig(lr=5e-3, warmup_steps=10, total_steps=STEPS)
    params, opt_state = trainer.init_all(spec, cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(trainer.make_train_step(spec, cfg, ctx, opt_cfg,
                                              remat=False))
    dcfg = synthetic.DataConfig(cfg.vocab_size, seq_len=32, global_batch=16)
    for i in range(STEPS):
        params, opt_state, m = step_fn(params, opt_state,
                                       synthetic.batch_at(dcfg, i))
        if (i + 1) % 20 == 0:
            print(f"  step {i + 1:4d}  loss {float(m['loss']):.3f}")

    print("== 2. converting to packed 1-bit checkpoint ==")
    host = jax.tree.map(np.asarray, params)
    packed, report = converter.convert(host, policy)
    print(f"  {report.summary()}")

    print("== 3. serving packed vs fake-quant (must match exactly) ==")
    packed = jax.tree.map(jnp.asarray, packed)
    ecfg = EngineConfig(batch=2, cache_len=64, max_new_tokens=12)
    eng_float = Engine(spec, cfg, ctx, params, ecfg)
    eng_packed = Engine(spec, cfg, ctx, packed, ecfg)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 8)).astype(np.int32)
    out_f = eng_float.generate(prompts)
    out_p = eng_packed.generate(prompts)
    print(f"  float path : {out_f[0][:10]}")
    print(f"  packed path: {out_p[0][:10]}")
    assert np.array_equal(out_f, out_p), "§2.2.2 equivalence violated!"
    print("  EXACT MATCH — train-with-floats / serve-with-bits verified.")


if __name__ == "__main__":
    main()
