"""Beyond-paper example: the BMXNet deployment story at LLM scale.

Binarize an assigned-pool LM (reduced config), convert, and serve with the
packed xnor path — first the legacy rectangular batch, then the
continuous-batching scheduler (mixed prompt lengths, per-request budgets,
slot recycling off the per-slot positions) — then print what the same
conversion does to the FULL config's weight traffic (the decode-roofline
argument from EXPERIMENTS.md: decode is weight-streaming-bound; 1-bit
weights cut that stream ~10-12x end-to-end including the fp
embedding/head).

Run:  PYTHONPATH=src python examples/packed_llm_serving.py [--arch ID]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import converter
from repro.core.policy import QuantPolicy
from repro.kernels.dispatch import GemmConfig
from repro.launch import specs as specs_lib
from repro.models import lm, registry
from repro.nn.common import QCtx
from repro.serve.engine import Engine, EngineConfig, Request, Scheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-moe-16b")
    ap.add_argument("--backend", default="vpu", choices=["vpu", "mxu", "xla"])
    args = ap.parse_args()

    spec = registry.get(args.arch)
    cfg = spec.smoke
    policy = QuantPolicy.binary()
    ctx = QCtx(policy=policy, compute_dtype=jnp.float32,
               gemm_config=GemmConfig(backend=args.backend))

    print(f"== packed serving, {args.arch} (reduced config) ==")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    host = jax.tree.map(np.asarray, params)
    packed, rep = converter.convert(host, policy)
    print(f"  converter: {rep.summary()}")
    packed = jax.tree.map(jnp.asarray, packed)

    eng = Engine(spec, cfg, ctx, packed,
                 EngineConfig(batch=2, cache_len=64, max_new_tokens=10))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 8)).astype(np.int32)
    kwargs = {}
    if cfg.vision_prefix:
        kwargs["vision_embeds"] = jnp.asarray(
            np.random.default_rng(1).standard_normal(
                (2, cfg.vision_prefix, cfg.d_vision)), jnp.float32)
    out = eng.generate(prompts, **kwargs)
    print(f"  generated: {out[0]}")

    print("== continuous batching (packed engine, 2 slots, 4 requests) ==")
    rng = np.random.default_rng(2)
    sched = Scheduler(eng)
    for i, (length, budget) in enumerate(
            zip((6, 9, 7, 6), (10, 4, 6, 8))):
        prompt = rng.integers(0, cfg.vocab_size, (length,)).astype(np.int32)
        kw = {k: np.asarray(v)[0] for k, v in kwargs.items()}
        sched.submit(Request(prompt=prompt, max_new_tokens=budget,
                             prefill_kwargs=kw))
    results = sched.run()
    stats = sched.stats
    print(f"  {len(results)} requests, {stats.steps} decode steps, "
          f"{stats.prefills} prefills; admissions (rid, slot): "
          f"{stats.admissions}")
    for rid in sorted(results):
        print(f"  rid={rid}: {results[rid]}")

    print(f"== full-config weight traffic ({args.arch}) ==")
    full = spec.config
    aparams = specs_lib.abstract_params(spec, full)
    total = sum(x.size for x in jax.tree.leaves(aparams))
    apacked = converter.abstract_packed(aparams, policy)
    pb = sum(
        leaf.size * (2 if np.issubdtype(leaf.dtype, np.floating)
                     else np.dtype(leaf.dtype).itemsize)
        for leaf in jax.tree.leaves(apacked))
    print(f"  bf16 weights:   {total * 2 / 2**30:7.2f} GiB per decode step")
    print(f"  packed weights: {pb / 2**30:7.2f} GiB per decode step "
          f"({total * 2 / pb:.1f}x less HBM traffic)")


if __name__ == "__main__":
    main()
