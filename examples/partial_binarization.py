"""Paper Table 2: partially binarized ResNet-18 — keep chosen stages full
precision, binarize the rest.  Reproduces the size column exactly and the
accuracy ORDERING on synthetic data (fp >= partial >= binary).

Run:  PYTHONPATH=src python examples/partial_binarization.py [--train]
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import converter
from repro.core.policy import QuantPolicy
from repro.models import cnn, registry
from repro.nn.common import QCtx
from repro.optim import adamw

STAGES = {
    "none": (),
    "1st": ("stage1",),
    "1st,2nd": ("stage1", "stage2"),
    "all": ("stage1", "stage2", "stage3", "stage4"),
}


def sizes():
    print("== Table 2 size column (ImageNet-head ResNet-18) ==")
    cfg = dataclasses.replace(registry.get("resnet18-cifar10").config,
                              n_classes=1000, stem_stride=2, in_hw=224)
    params = cnn.resnet18_init(jax.random.PRNGKey(0), cfg)
    for name, fp_stages in STAGES.items():
        pol = QuantPolicy.binary().with_fp_stages(fp_stages)
        _, rep = converter.convert(params, pol)
        print(f"  fp_stages={name:<8} size={rep.bytes_after / 1e6:6.2f}MB")
    print("  (paper: none=3.6, 1st=4.1, 1st+2nd=6.2, all=47MB)")


def train_variant(fp_stages, steps=60, seed=0):
    cfg = registry.get("resnet18-cifar10").smoke
    pol = QuantPolicy.binary().with_fp_stages(fp_stages)
    ctx = QCtx(policy=pol, compute_dtype=jnp.float32)
    params = cnn.resnet18_init(jax.random.PRNGKey(seed), cfg)
    opt_cfg = adamw.AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=steps,
                                weight_decay=0.0)
    opt = adamw.init(params)
    rng = np.random.default_rng(seed)
    templates = rng.standard_normal((10, cfg.in_hw, cfg.in_hw, 3)).astype(
        np.float32)

    def data(n):
        y = rng.integers(0, 10, n)
        x = templates[y] + 0.5 * rng.standard_normal(
            (n, cfg.in_hw, cfg.in_hw, 3)).astype(np.float32)
        return jnp.asarray(x), jnp.asarray(y)

    def loss_fn(p, x, y):
        logits = cnn.resnet18_forward(p, cfg, ctx, x)
        return -jnp.mean(jnp.sum(
            jax.nn.log_softmax(logits) * jax.nn.one_hot(y, 10), -1))

    @jax.jit
    def step(p, o, x, y):
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        p, o, _ = adamw.update(g, o, p, opt_cfg)
        return p, o, l

    for _ in range(steps):
        x, y = data(32)
        params, opt, l = step(params, opt, x, y)
    xt, yt = data(256)
    logits = cnn.resnet18_forward(params, cfg, ctx, xt)
    return float((jnp.argmax(logits, -1) == yt).mean())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train", action="store_true",
                    help="also train each variant on synthetic data")
    args = ap.parse_args()
    sizes()
    if args.train:
        print("== accuracy ordering (synthetic; direction only) ==")
        for name, fp_stages in STAGES.items():
            acc = train_variant(fp_stages)
            print(f"  fp_stages={name:<8} acc={acc:.3f}")


if __name__ == "__main__":
    main()
