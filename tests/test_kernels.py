"""Pallas kernels vs pure-jnp oracle: exact equality across shape/dtype
sweeps + hypothesis-generated shapes (the per-kernel allclose deliverable)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bitpack
from repro.kernels import ops, ref

SHAPES = [
    (8, 32, 8),
    (5, 33, 7),  # K not multiple of 32
    (128, 256, 128),
    (17, 100, 39),
    (1, 1, 1),
    (130, 4096, 120),
    (256, 2048, 64),
]


def _mats(rng, m, k, n, dtype=np.float32):
    a = jnp.asarray(rng.standard_normal((m, k)), dtype)
    w = jnp.asarray(rng.standard_normal((k, n)), dtype)
    return a, w


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("backend", ["xla", "vpu", "mxu"])
def test_xnor_gemm_matches_float_sign_dot(rng, m, k, n, backend):
    a, w = _mats(rng, m, k, n)
    oracle = np.asarray(ref.sign_gemm_ref(a, w)).astype(np.int32)
    ap = bitpack.pack_sign(a)
    wp = bitpack.pack_sign(w.T)
    got = ops.xnor_gemm(ap, wp, k_true=k, backend=backend)
    np.testing.assert_array_equal(np.asarray(got), oracle)


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_binary_dot_end_to_end(rng, m, k, n):
    a, w = _mats(rng, m, k, n)
    oracle = np.asarray(ref.sign_gemm_ref(a, w))
    got = ops.binary_dot(a, bitpack.pack_sign(w.T), k_true=k)
    np.testing.assert_array_equal(np.asarray(got), oracle)


@pytest.mark.parametrize("dtype", [np.float32, np.float16, jnp.bfloat16])
def test_xnor_gemm_dtype_sweep(rng, dtype):
    a, w = _mats(rng, 32, 96, 16, dtype)
    oracle = np.asarray(ref.sign_gemm_ref(a, w)).astype(np.int32)
    got = ops.xnor_gemm(
        bitpack.pack_sign(a), bitpack.pack_sign(w.T), k_true=96, backend="vpu"
    )
    np.testing.assert_array_equal(np.asarray(got), oracle)


def test_pack_kernel_matches_ref(rng):
    x = jnp.asarray(rng.standard_normal((100, 1000)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(ops.pack_activations(x)), np.asarray(bitpack.pack_sign(x))
    )


def test_pack_unpack_roundtrip(rng):
    x = jnp.asarray(rng.standard_normal((10, 77)), jnp.float32)
    u = bitpack.unpack_sign(bitpack.pack_sign(x), 77)
    np.testing.assert_array_equal(
        np.asarray(u), np.where(np.asarray(x) >= 0, 1.0, -1.0)
    )


def test_counts_vs_dot_eq2():
    """Listing 3 counts and the ±1 dot satisfy Eq. 2 exactly."""
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.standard_normal((9, 130)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((130, 11)), jnp.float32)
    ap, wp = bitpack.pack_sign(a), bitpack.pack_sign(w.T)
    counts = np.asarray(ref.xnor_counts_ref(ap, wp, 130))
    dot = np.asarray(ref.xnor_gemm_ref(ap, wp, 130))
    np.testing.assert_array_equal(counts, (dot + 130) // 2)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 200),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31),
    backend=st.sampled_from(["vpu", "mxu", "xla"]),
)
def test_xnor_gemm_hypothesis(m, k, n, seed, backend):
    rng = np.random.default_rng(seed)
    a, w = _mats(rng, m, k, n)
    oracle = np.asarray(ref.sign_gemm_ref(a, w)).astype(np.int32)
    got = ops.xnor_gemm(
        bitpack.pack_sign(a), bitpack.pack_sign(w.T), k_true=k, backend=backend
    )
    np.testing.assert_array_equal(np.asarray(got), oracle)


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 50), k=st.integers(1, 130), seed=st.integers(0, 2**31)
)
def test_pack_hypothesis(rows, k, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((rows, k)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(ops.pack_activations(x)), np.asarray(bitpack.pack_sign(x))
    )
