"""The paper's §2.2.2 exact-match invariant: the float/MXU training path and
the packed xnor serving path produce IDENTICAL outputs, for dense and conv,
across hypothesis-generated shapes and all layer options."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import converter, qlayers
from repro.core.policy import QuantPolicy, QuantSpec


def _packed(params, policy=None):
    packed, _ = converter.convert({"l": params}, policy or QuantPolicy.binary())
    return packed["l"]


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 9),
    d_in=st.integers(1, 130),
    d_out=st.integers(1, 40),
    seed=st.integers(0, 2**31),
    backend=st.sampled_from(["vpu", "mxu", "xla"]),
)
def test_dense_train_eq_packed(b, d_in, d_out, seed, backend):
    key = jax.random.PRNGKey(seed)
    p = qlayers.dense_init(key, d_in, d_out)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, d_in))
    spec = QuantSpec(w_bits=1, a_bits=1)
    y_train = qlayers.qdense(p, x, spec, compute_dtype=jnp.float32)
    y_packed = qlayers.qdense(_packed(p), x, spec, compute_dtype=jnp.float32,
                              xnor_backend=backend)
    np.testing.assert_array_equal(np.asarray(y_train), np.asarray(y_packed))


@pytest.mark.parametrize("scale", [False, True])
@pytest.mark.parametrize("xnor_range", [False, True])
def test_dense_options_equivalence(scale, xnor_range):
    key = jax.random.PRNGKey(0)
    p = qlayers.dense_init(key, 64, 32)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 64))
    spec = QuantSpec(w_bits=1, a_bits=1, scale=scale, xnor_range=xnor_range)
    pol = QuantPolicy(w_bits=1, a_bits=1, scale=scale, xnor_range=xnor_range)
    y_train = qlayers.qdense(p, x, spec, compute_dtype=jnp.float32)
    y_packed = qlayers.qdense(_packed(p, pol), x, spec,
                              compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_packed),
                               rtol=1e-6, atol=1e-6)
    if xnor_range:  # outputs are match-counts: integers in [0, d_in]
        yv = np.asarray(y_packed)
        if not scale:
            np.testing.assert_array_equal(yv, np.round(yv))
            assert (yv >= 0).all() and (yv <= 64).all()


@settings(max_examples=10, deadline=None)
@given(
    hw=st.integers(4, 12),
    c_in=st.integers(1, 8),
    c_out=st.integers(1, 8),
    kh=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["SAME", "VALID"]),
    seed=st.integers(0, 2**31),
)
def test_conv_train_eq_packed(hw, c_in, c_out, kh, stride, padding, seed):
    if padding == "VALID" and kh > hw:
        return
    key = jax.random.PRNGKey(seed)
    p = qlayers.conv_init(key, kh, kh, c_in, c_out)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, hw, hw, c_in))
    spec = QuantSpec(w_bits=1, a_bits=1)
    y_train = qlayers.qconv(p, x, spec, stride=stride, padding=padding,
                            compute_dtype=jnp.float32)
    y_packed = qlayers.qconv(_packed(p), x, spec, stride=stride,
                             padding=padding, compute_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y_train), np.asarray(y_packed))


def test_kbit_dense_changes_with_bits():
    """k-bit (2..31) stays fake-quantized; more bits -> closer to fp."""
    key = jax.random.PRNGKey(0)
    p = qlayers.dense_init(key, 128, 64)
    x = jax.random.uniform(jax.random.PRNGKey(1), (8, 128))
    y_fp = qlayers.qdense(p, x, QuantSpec(), compute_dtype=jnp.float32)
    errs = []
    for k in (2, 4, 8):
        y_k = qlayers.qdense(p, x, QuantSpec(w_bits=k, a_bits=k),
                             compute_dtype=jnp.float32)
        errs.append(float(jnp.mean(jnp.abs(y_k - y_fp))))
    assert errs[0] > errs[1] > errs[2], errs


def test_gradients_flow_through_all_bit_widths():
    key = jax.random.PRNGKey(0)
    p = qlayers.dense_init(key, 32, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32)) * 0.5
    for bits in (1, 2, 8, 32):
        spec = QuantSpec(w_bits=bits, a_bits=bits)
        g = jax.grad(
            lambda p: (qlayers.qdense(p, x, spec,
                                      compute_dtype=jnp.float32) ** 2).sum()
        )(p)
        assert np.isfinite(np.asarray(g["w"])).all()
        assert np.abs(np.asarray(g["w"])).sum() > 0, bits
