"""1-bit error-feedback gradient compression (dist/compress.py)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import compress


def test_compress_leaf_is_sign_times_scale():
    g = jnp.asarray([1.0, -2.0, 3.0, -4.0])
    e = jnp.zeros_like(g)
    c, e_new = compress.compress_leaf(g, e)
    scale = np.mean(np.abs(np.asarray(g)))
    np.testing.assert_allclose(np.asarray(c),
                               [scale, -scale, scale, -scale], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(c) + np.asarray(e_new),
                               np.asarray(g), rtol=1e-6)


def test_error_feedback_accumulates_residual():
    """EF property: running sum of compressed grads tracks the running sum
    of true grads to within one step's worth of error."""
    rng = np.random.default_rng(0)
    e = jnp.zeros((64,))
    total_true = np.zeros(64)
    total_comp = np.zeros(64)
    for i in range(200):
        g = jnp.asarray(rng.standard_normal(64) * (1 + 0.1 * i % 3))
        c, e = compress.compress_leaf(g, e)
        total_true += np.asarray(g)
        total_comp += np.asarray(c)
    # residual bounded by the error-feedback state, not growing with T
    resid = np.abs(total_true - total_comp)
    np.testing.assert_allclose(resid, np.abs(np.asarray(e)), rtol=1e-4,
                               atol=1e-4)
    assert resid.max() < 10.0  # bounded, not O(T)


def test_compress_tree_shapes():
    grads = {"a": jnp.ones((4, 4)), "b": {"c": jnp.ones((3,))}}
    ef = compress.ef_init(grads)
    comp, ef2 = compress.compress(grads, ef)
    assert jax.tree.structure(comp) == jax.tree.structure(grads)
    assert jax.tree.structure(ef2) == jax.tree.structure(grads)


def test_payload_accounting():
    grads = {"w": jnp.zeros((1024, 1024))}
    full = compress.payload_bytes(grads, compressed=False)
    packed = compress.payload_bytes(grads, compressed=True)
    assert full == 1024 * 1024 * 4
    assert packed == 1024 * 1024 // 8 + 4
    assert full / packed > 31  # ~32x, paper's compression on the wire


def test_compressed_psum_shard_map():
    """compressed_psum under shard_map on a 1-device 'pod' axis: with a
    single member the mean equals the compressed grad itself."""
    mesh = jax.make_mesh((1,), ("pod",))
    grads = {"w": jnp.asarray([[1.0, -2.0], [0.5, -0.5]])}
    ef = compress.ef_init(grads)

    from repro.compat import shard_map

    def f(g, e):
        return compress.compressed_psum(g, e, "pod")

    fn = shard_map(
        f, mesh=mesh,
        in_specs=(jax.sharding.PartitionSpec(),) * 2,
        out_specs=(jax.sharding.PartitionSpec(),) * 2,
        check_vma=False,
    )
    summed, ef2 = fn(grads, ef)
    c, _ = compress.compress_leaf(grads["w"], ef["w"])
    np.testing.assert_allclose(np.asarray(summed["w"]), np.asarray(c),
                               rtol=1e-6)


def test_compress_tuple_structured_tree():
    """2-tuples in the gradient pytree STRUCTURE must survive compression
    (regression: a naive is_leaf on 2-tuples mistook structure for leaf
    pairs and dropped half the tree)."""
    grads = ({"a": jnp.ones((4,))}, {"b": 2.0 * jnp.ones((3,))})
    ef = compress.ef_init(grads)
    comp, ef2 = compress.compress(grads, ef)
    assert jax.tree.structure(comp) == jax.tree.structure(grads)
    assert jax.tree.structure(ef2) == jax.tree.structure(grads)
    np.testing.assert_allclose(np.asarray(comp[1]["b"]),
                               2.0 * np.ones(3), rtol=1e-6)
