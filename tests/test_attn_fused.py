"""Fused flash-decode attention (kernels/attn_decode.py) vs the gather +
masked-sdpa oracle, over the storage the kernel actually reads: hypothesis
sweeps of ragged lengths crossing block boundaries, both KV layouts,
every kv_bits storage tier (the oracle dequantizes the SAME codes, so
agreement is tight fp32 allclose even for the quantized tiers), the
speculative truncate-then-decode round, write-masked retired rows, the
(B, C) window query tile, and the quantization codec round-trip bounds.

Fully-masked rows (no visible key) are the one intended divergence: the
kernel emits exact zeros where the dense oracle's softmax-over-NEG_INF
returns mean(v) — compared on active rows only, with the zero contract
asserted separately."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import attn_decode as AK
from repro.nn import attention as attn_lib

_TOL = dict(rtol=0, atol=2e-5)


def _cfg(dh=8, window=None, softcap=None):
    return attn_lib.AttnConfig(d_model=4 * dh, n_heads=4, n_kv_heads=2,
                               d_head=dh, window=window,
                               logit_softcap=softcap, fused_attn=True)


def _mk_kv(layout, kv_bits, bs):
    if layout == "pgd":
        return attn_lib.PagedKVCache(block_size=bs, kv_bits=kv_bits)
    return attn_lib.ContiguousKVCache(kv_bits=kv_bits)


def _fill(kv, cfg, lens, cache_len, rng, layout):
    """Ragged per-row prefill through the real write path (fill_window,
    per distinct length with write_mask — the paged pool is shared, so
    rows are never written by slicing cache leaves)."""
    b = len(lens)
    cache = kv.init(b, cfg, cache_len, jnp.float32)
    if layout == "pgd":
        bps = cache["table"].shape[1]
        cache["table"] = jnp.arange(b * bps, dtype=jnp.int32).reshape(b, bps)
    kvh, dh = cfg.n_kv_heads, cfg.d_head
    for ln in sorted({x for x in lens if x > 0}):
        k = jnp.asarray(rng.standard_normal((b, ln, kvh, dh)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, ln, kvh, dh)), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(ln, dtype=jnp.int32), (b, ln))
        wm = jnp.asarray([x == ln for x in lens])
        if ln == 1:
            cache = kv.fill(cache, k, v, pos, wm)
        else:
            cache = kv.fill_window(cache, k, v, pos, wm)
    return cache


def _oracle(cfg, kv, cache, q, q_pos):
    k, v, spos = kv.gather(cache)
    return attn_lib._sdpa(cfg, q, k, v, attn_lib._mask(cfg, q_pos, spos))


def _compare(cfg, kv, cache, q, q_pos):
    fused = kv.attend(cache, q, q_pos, cfg)
    ref = _oracle(cfg, kv, cache, q, q_pos)
    _, _, spos = kv.gather(cache)
    vis = attn_lib._mask(cfg, q_pos, spos).any(-1)  # (B, C) any visible key
    np.testing.assert_allclose(np.asarray(fused)[np.asarray(vis)],
                               np.asarray(ref)[np.asarray(vis)], **_TOL)
    # fully-masked rows: the kernel's documented zero contract
    np.testing.assert_array_equal(
        np.asarray(fused)[~np.asarray(vis)], 0.0)
    return fused


@settings(max_examples=20, deadline=None)
@given(
    layout=st.sampled_from(["ctg", "pgd"]),
    kv_bits=st.sampled_from([None, 8, 1]),
    bs=st.sampled_from([4, 8]), bps=st.integers(2, 4),
    l1=st.integers(0, 31), l2=st.integers(0, 31), l3=st.integers(0, 31),
)
def test_fused_matches_oracle_ragged(layout, kv_bits, bs, bps, l1, l2, l3):
    """Decode-step agreement over ragged lengths crossing block
    boundaries, both layouts x every storage tier."""
    cache_len = bs * bps
    lens = [l % (cache_len) for l in (l1, l2, l3)]
    cfg = _cfg()
    kv = _mk_kv(layout, kv_bits, bs)
    rng = np.random.default_rng(
        [bs, bps, l1, l2, l3, layout == "pgd", kv_bits or 0])
    cache = _fill(kv, cfg, lens, cache_len, rng, layout)
    q = jnp.asarray(rng.standard_normal(
        (3, 1, cfg.n_kv_heads, cfg.groups, cfg.d_head)), jnp.float32)
    q_pos = jnp.asarray([[ln] for ln in lens], jnp.int32)
    _compare(cfg, kv, cache, q, q_pos)


@settings(max_examples=10, deadline=None)
@given(
    layout=st.sampled_from(["ctg", "pgd"]),
    kv_bits=st.sampled_from([None, 8, 1]),
    keep1=st.integers(0, 15), keep2=st.integers(0, 15),
)
def test_fused_truncate_then_decode(layout, kv_bits, keep1, keep2):
    """The speculative rollback round: fill, truncate to per-row keep
    lengths (rejected proposals -> slot_pos = -1), decode-append one
    token at the new frontier, then attend — the truncated tail must be
    invisible to the fused kernel exactly as it is to the oracle."""
    bs, cache_len = 4, 16
    lens = [16, 11]
    cfg = _cfg()
    kv = _mk_kv(layout, kv_bits, bs)
    rng = np.random.default_rng(
        [keep1, keep2, layout == "pgd", kv_bits or 0])
    cache = _fill(kv, cfg, lens, cache_len, rng, layout)
    keep = jnp.asarray([min(keep1, lens[0]), min(keep2, lens[1])],
                       jnp.int32)
    cache = kv.truncate(cache, keep)
    k1 = jnp.asarray(rng.standard_normal((2, 1, 2, cfg.d_head)), jnp.float32)
    v1 = jnp.asarray(rng.standard_normal((2, 1, 2, cfg.d_head)), jnp.float32)
    cache = kv.fill(cache, k1, v1, keep[:, None])
    q = jnp.asarray(rng.standard_normal(
        (2, 1, cfg.n_kv_heads, cfg.groups, cfg.d_head)), jnp.float32)
    _compare(cfg, kv, cache, q, keep[:, None])


@settings(max_examples=8, deadline=None)
@given(
    layout=st.sampled_from(["ctg", "pgd"]),
    kv_bits=st.sampled_from([None, 8, 1]),
    c=st.integers(2, 5),
)
def test_fused_window_query_tile(layout, kv_bits, c):
    """The (B, C) query tile (chunked prefill / speculative verify):
    per-row causal masking from absolute positions must match the oracle
    at every window offset."""
    bs, cache_len = 4, 24
    lens = [20, 13]
    cfg = _cfg()
    kv = _mk_kv(layout, kv_bits, bs)
    rng = np.random.default_rng(1000 + c)
    cache = _fill(kv, cfg, lens, cache_len, rng, layout)
    q = jnp.asarray(rng.standard_normal(
        (2, c, cfg.n_kv_heads, cfg.groups, cfg.d_head)), jnp.float32)
    # verify-window positions: rows start at their frontier minus c
    starts = [max(0, ln - c) for ln in lens]
    q_pos = jnp.asarray([[s + j for j in range(c)] for s in starts],
                        jnp.int32)
    _compare(cfg, kv, cache, q, q_pos)


def test_fused_write_masked_retired_rows():
    """A retired row's decode writes are dropped (write_mask=False) while
    live rows keep appending; the fused kernel over the resulting pool
    must match the oracle for the live rows AND the retired row's stale
    prefix — junk from the shape-static step never lands, so it cannot
    poison anyone's online softmax."""
    bs, cache_len = 4, 16
    lens = [10, 8]
    cfg = _cfg()
    rng = np.random.default_rng(7)
    for layout in ("ctg", "pgd"):
        for kv_bits in (None, 8, 1):
            kv = _mk_kv(layout, kv_bits, bs)
            cache = _fill(kv, cfg, lens, cache_len, rng, layout)
            cur = np.asarray(lens, np.int32)
            wm = jnp.asarray([True, False])  # row 1 retired
            for _ in range(3):
                k1 = jnp.asarray(rng.standard_normal((2, 1, 2, cfg.d_head)),
                                 jnp.float32)
                v1 = jnp.asarray(rng.standard_normal((2, 1, 2, cfg.d_head)),
                                 jnp.float32)
                cache = kv.fill(cache, k1, v1,
                                jnp.asarray(cur)[:, None], wm)
                cur = cur + 1
            q = jnp.asarray(rng.standard_normal(
                (2, 1, cfg.n_kv_heads, cfg.groups, cfg.d_head)), jnp.float32)
            # live row queries its frontier; retired row its stale one
            q_pos = jnp.asarray([[int(cur[0])], [lens[1]]], jnp.int32)
            _compare(cfg, kv, cache, q, q_pos)


def test_fused_junk_blocks_invisible_paged():
    """Unmapped table entries (-1) skip at the grid level: poisoning the
    orphaned pool blocks with huge values must not change the fused
    output at all."""
    bs, cache_len = 4, 16
    cfg = _cfg()
    kv = _mk_kv("pgd", None, bs)
    rng = np.random.default_rng(11)
    cache = _fill(kv, cfg, [9, 5], cache_len, rng, "pgd")
    # orphan row 1's last two blocks
    cache["table"] = cache["table"].at[1, 2:].set(-1)
    q = jnp.asarray(rng.standard_normal(
        (2, 1, cfg.n_kv_heads, cfg.groups, cfg.d_head)), jnp.float32)
    q_pos = jnp.asarray([[9], [5]], jnp.int32)
    out = _compare(cfg, kv, cache, q, q_pos)
    # blocks 6 and 7 are row 1's orphaned range (the identity table maps
    # row 1 -> blocks 4..7; entries 2 and 3 were just unmapped)
    poisoned = dict(cache)
    for name in ("pool_k", "pool_v"):
        poisoned[name] = poisoned[name].at[6:].set(1e30)
    out2 = kv.attend(poisoned, q, q_pos, cfg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_fused_softcap_and_window():
    """Logit softcap + sliding-window masking run in-kernel with the same
    semantics as the jnp path."""
    bs, cache_len = 4, 16
    lens = [14, 9]
    cfg = _cfg(window=6, softcap=8.0)
    rng = np.random.default_rng(13)
    for layout in ("ctg", "pgd"):
        kv = _mk_kv(layout, None, bs)
        cache = _fill(kv, cfg, lens, cache_len, rng, layout)
        q = jnp.asarray(rng.standard_normal(
            (2, 1, cfg.n_kv_heads, cfg.groups, cfg.d_head)), jnp.float32)
        q_pos = jnp.asarray([[ln] for ln in lens], jnp.int32)
        _compare(cfg, kv, cache, q, q_pos)


@settings(max_examples=15, deadline=None)
@given(bits=st.sampled_from([8, 1]), dh=st.sampled_from([8, 16, 32, 64]))
def test_kv_codec_round_trip_bounds(bits, dh):
    """Codec contract: int8 per-(head, dh-group) absmax keeps max error
    <= scale/2 per group; 1-bit reproduces alpha * sign exactly."""
    rng = np.random.default_rng(bits * 100 + dh)
    x = jnp.asarray(rng.standard_normal((3, 5, 2, dh)), jnp.float32)
    codes, scale = AK.kv_quantize(bits, x)
    back = AK.kv_dequantize(bits, codes, scale, dh)
    if bits == 8:
        g = AK.kv_scale_groups(dh)
        half_step = np.asarray(scale)[..., None] / 2 + 1e-7
        err = np.abs(np.asarray(back) - np.asarray(x)).reshape(
            3, 5, 2, g, dh // g)
        assert (err <= half_step).all()
    else:
        alpha = np.abs(np.asarray(x)).mean(-1, keepdims=True)
        signs = np.where(np.asarray(x) >= 0, 1.0, -1.0)
        np.testing.assert_allclose(np.asarray(back), alpha * signs,
                                   rtol=0, atol=1e-6)
