"""int8 code-lane MXU k-bit backends (kernels/kbit_mxu.py, `mxu-k*` /
`shard-mxu-k*`) and the `overlap_collective` ring reduction.

The MXU path must be BIT-IDENTICAL to the plane popcount path — both
compute the same integer S, one via ka*kb weighted popcount passes, the
other via one offset int8 dot per tile — so every equality here is exact
(`assert_array_equal`), not tolerance-based.  The property sweeps run over
odd k_true values (word-unaligned tails) since pad handling is where the
offset trick could silently break.

Runs on the virtual 8-device CPU platform from tests/conftest.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import bitpack, quant
from repro.kernels import dispatch, ref
from repro.kernels.dispatch import GemmConfig

BITS = [2, 4, 8]
WAYS = [1, 2, 4, 8]
# fake-quant train path vs integer path differ only by fp32 rounding
TOL = dict(rtol=1e-4, atol=2e-4)


def _plane_operands(seed, m, k, n, bits):
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n), jnp.float32)
    ap = bitpack.pack_planes(quant.act_codes(a, bits), bits)
    wp = bitpack.pack_planes(quant.weight_codes(w.T, bits), bits)
    return a, w, ap, wp


# ---------------------------------------------------------------------------
# single device: mxu-k* == vpu-k* == jnp oracle (bit-exact)
# ---------------------------------------------------------------------------


@settings(max_examples=24, deadline=None)
@given(
    bits=st.sampled_from(BITS),
    m=st.integers(min_value=1, max_value=17),
    n=st.integers(min_value=1, max_value=19),
    kw=st.integers(min_value=1, max_value=6),
    tail=st.integers(min_value=1, max_value=31),  # odd k_true: ragged tail
)
def test_mxu_kbit_matches_vpu_and_oracle(bits, m, n, kw, tail):
    """Property sweep over word-unaligned shapes: the int8 code-lane S
    equals the plane popcount S equals the integer-code oracle, exactly."""
    k = (kw - 1) * 32 + tail
    _, _, ap, wp = _plane_operands(bits * 1000 + k, m, k, n, bits)
    want = np.asarray(ref.kbit_gemm_ref(ap, wp))
    for backend in (f"vpu-k{bits}", f"mxu-k{bits}"):
        got = np.asarray(dispatch.packed_kbit_gemm(
            ap, wp, config=GemmConfig(backend=backend)))
        np.testing.assert_array_equal(got, want, err_msg=backend)
        assert got.dtype == np.int32


@pytest.mark.parametrize("bits", BITS)
def test_mxu_kbit_quant_gemm_matches_fakequant(bits):
    """Float-activation entry point through the mxu-k* backends (base-name
    resolution 'mxu' + w_bits included) equals the DoReFa fake-quant
    oracle within fp32 rounding."""
    m, k, n = 5, 3 * 32 + 7, 9
    a, w, _, wp = _plane_operands(bits, m, k, n, bits)
    want = np.asarray(ref.dorefa_gemm_ref(a, w, bits, bits))
    for base in ("mxu", f"mxu-k{bits}"):
        got = np.asarray(dispatch.quant_gemm(
            a, wp, k_true=k, w_bits=bits, a_bits=bits,
            config=GemmConfig(backend=base)))
        np.testing.assert_allclose(got, want, err_msg=base, **TOL)


def test_mxu_kbit_asymmetric_widths():
    """ka != kb plane stacks (w4a8): the offset trick uses per-operand
    offsets, so asymmetric widths must stay exact too."""
    m, k, n = 4, 70, 6
    key = jax.random.PRNGKey(42)
    a = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n), jnp.float32)
    ap = bitpack.pack_planes(quant.act_codes(a, 8), 8)
    wp = bitpack.pack_planes(quant.weight_codes(w.T, 4), 4)
    want = np.asarray(ref.kbit_gemm_ref(ap, wp))
    got = np.asarray(dispatch.packed_kbit_gemm(
        ap, wp, config=GemmConfig(backend="mxu-k4")))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("bits", BITS)
def test_mxu_kbit_grouped_matches_vpu(bits):
    """Expert-batched int8 code-lane kernel == expert-batched popcount."""
    e, m, k, n = 3, 6, 50, 5
    key = jax.random.PRNGKey(bits)
    xs = jax.random.normal(key, (e, m, k), jnp.float32)
    ws = jax.random.normal(jax.random.fold_in(key, 1), (e, k, n),
                           jnp.float32)
    buckets = jnp.stack([
        bitpack.pack_planes(quant.act_codes(xs[i], bits), bits)
        for i in range(e)])
    w_stack = jnp.stack([
        bitpack.pack_planes(quant.weight_codes(ws[i].T, bits), bits)
        for i in range(e)])
    cfg_v = GemmConfig(backend=f"vpu-k{bits}")
    cfg_m = GemmConfig(backend=f"mxu-k{bits}")
    t = cfg_v.tiles(m, n, buckets.shape[-1], backend=f"vpu-k{bits}")
    tm = cfg_m.tiles(m, n, buckets.shape[-1], backend=f"mxu-k{bits}")
    want = dispatch.get_backend(f"vpu-k{bits}").gemm_kbit_grouped(
        buckets, w_stack, t, cfg_v)
    got = dispatch.get_backend(f"mxu-k{bits}").gemm_kbit_grouped(
        buckets, w_stack, tm, cfg_m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# trace-time int32 bound: the re-derived mxu-path check
# ---------------------------------------------------------------------------


def test_mxu_kbit_accumulator_bound_rejected():
    """The int8 code-lane path accumulates the FULL code dot in one int32
    partial; dispatch must reject an overflowing K at trace time with the
    MXU-specific message (not the plane-pair one)."""
    big_k = 20_000  # w8a8 bound: 2*K*255*255 >= 2^31 at K ~ 16.5k
    xb = jnp.zeros((1, big_k), jnp.float32)
    wb = jnp.zeros((8, 1, bitpack.packed_width(big_k)), jnp.uint32)
    with pytest.raises(ValueError, match="k-bit MXU GEMM overflows"):
        dispatch.quant_gemm(xb, wb, k_true=big_k,
                            config=GemmConfig(backend="mxu"),
                            w_bits=8, a_bits=8)
    # packed-operand entry point checks the same bound
    ap = jnp.zeros((8, 1, bitpack.packed_width(big_k)), jnp.uint32)
    with pytest.raises(ValueError, match="ONE int32 partial"):
        dispatch.packed_kbit_gemm(ap, wb,
                                  config=GemmConfig(backend="mxu-k8"))
    # the plane popcount family keeps its own message
    with pytest.raises(ValueError, match="k-bit GEMM overflows"):
        dispatch.packed_kbit_gemm(ap, wb,
                                  config=GemmConfig(backend="vpu-k8"))


def test_mxu_kbit_bound_not_overtight():
    """K just under the ceiling must trace (the check may not be MORE
    conservative than 2*K*Na*Nw < 2^31): w2a2 at K = 16k is fine."""
    k = 16 * 1024
    x = jnp.zeros((1, k), jnp.float32)
    w = jnp.zeros((2, 1, bitpack.packed_width(k)), jnp.uint32)
    out = dispatch.quant_gemm(x, w, k_true=k, w_bits=2, a_bits=2,
                              config=GemmConfig(backend="mxu"))
    assert out.shape == (1, 1)


# ---------------------------------------------------------------------------
# shard-mxu-k*: 1/2/4/8-way splits, bit-identical to single device
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ways", WAYS)
@pytest.mark.parametrize("bits", BITS)
def test_shard_mxu_kbit_matches_single_device(mesh_factory, bits, ways):
    """Raw S psums exactly over Kw shards on the int8 code-lane path too
    (pad words unpack to code 0 -> offset identity cancels per lane)."""
    mesh = mesh_factory(ways)
    m, k, n = 9, 5 * 32 + 17, 7  # Kw = 6: non-divisible for most splits
    _, _, ap, wp = _plane_operands(bits + 100, m, k, n, bits)
    want = np.asarray(dispatch.packed_kbit_gemm(
        ap, wp, config=GemmConfig(backend=f"mxu-k{bits}")))
    got = np.asarray(dispatch.packed_kbit_gemm(
        ap, wp,
        config=GemmConfig(backend=f"shard-mxu-k{bits}", mesh=mesh)))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=6, deadline=None)
@given(
    tail=st.integers(min_value=1, max_value=31),
    bits=st.sampled_from(BITS),
    ways=st.sampled_from([2, 4, 8]),
)
def test_shard_mxu_kbit_from_float_property(tail, bits, ways):
    """Property sweep over odd k_true: the float-activation shard path
    (fused pack inside the body) matches the single-device mxu-k* dot
    bit-for-bit after the shared dequant.  (Builds its mesh inline: the
    conftest hypothesis fallback wraps the signature, hiding fixture
    params from pytest.)"""
    if len(jax.devices()) < ways:
        pytest.skip(f"{ways}-way mesh needs virtual host devices")
    mesh = jax.make_mesh((ways,), ("model",))
    k = 3 * 32 + tail
    m, n = 5, 6
    a, _, _, wp = _plane_operands(tail * 7 + bits, m, k, n, bits)
    want = np.asarray(dispatch.quant_gemm(
        a, wp, k_true=k, w_bits=bits, a_bits=bits,
        config=GemmConfig(backend=f"mxu-k{bits}")))
    got = np.asarray(dispatch.quant_gemm(
        a, wp, k_true=k, w_bits=bits, a_bits=bits,
        config=GemmConfig(backend="shard-mxu", mesh=mesh)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("layout", ["k", "n"])
def test_shard_mxu_kbit_layouts(mesh_factory, layout):
    """Both operand layouts of the shard-mxu-k* family stay bit-identical
    (the "n" layout runs the full contraction per weight slice)."""
    mesh = mesh_factory(4)
    m, k, n = 8, 90, 6
    a, _, _, wp = _plane_operands(11, m, k, n, 4)
    want = np.asarray(dispatch.quant_gemm(
        a, wp, k_true=k, w_bits=4, a_bits=4,
        config=GemmConfig(backend="mxu-k4")))
    got = np.asarray(dispatch.quant_gemm(
        a, wp, k_true=k, w_bits=4, a_bits=4,
        config=GemmConfig(backend="shard-mxu", mesh=mesh,
                          shard_layout=layout)))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# overlap_collective: ring reduction must be bit-identical to the psum
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ways", WAYS)
@pytest.mark.parametrize("family", ["vpu", "mxu"])
def test_overlap_collective_kbit_bit_identity(mesh_factory, family, ways):
    """overlap_collective=True (chunked ppermute ring) vs False (psum):
    int32 partials add exactly in any order, so outputs must be EQUAL —
    including N (=7) not divisible by the shard count."""
    mesh = mesh_factory(ways)
    m, k, n = 5, 4 * 32 + 9, 7
    a, _, _, wp = _plane_operands(ways + 13, m, k, n, 4)
    base = GemmConfig(backend=f"shard-{family}", mesh=mesh)
    seq = np.asarray(dispatch.quant_gemm(
        a, wp, k_true=k, w_bits=4, a_bits=4, config=base))
    ring = np.asarray(dispatch.quant_gemm(
        a, wp, k_true=k, w_bits=4, a_bits=4,
        config=GemmConfig(backend=f"shard-{family}", mesh=mesh,
                          overlap_collective=True)))
    np.testing.assert_array_equal(ring, seq)


@pytest.mark.parametrize("family", ["vpu", "mxu"])
def test_overlap_collective_1bit_bit_identity(mesh_factory, family):
    """The 1-bit from_float shard path honors the flag too (mismatch
    counts / padded dots ride the same ring)."""
    mesh = mesh_factory(4)
    m, k, n = 6, 100, 9
    key = jax.random.PRNGKey(3)
    a = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n), jnp.float32)
    wp = bitpack.pack_sign(w.T)
    seq = np.asarray(dispatch.quant_gemm(
        a, wp, k_true=k,
        config=GemmConfig(backend=f"shard-{family}", mesh=mesh)))
    ring = np.asarray(dispatch.quant_gemm(
        a, wp, k_true=k,
        config=GemmConfig(backend=f"shard-{family}", mesh=mesh,
                          overlap_collective=True)))
    np.testing.assert_array_equal(ring, seq)


def test_overlap_collective_default_off():
    """The safe sequential psum stays the default (the flag is opt-in)."""
    assert GemmConfig().overlap_collective is False


@pytest.mark.parametrize("family", ["vpu", "mxu"])
def test_overlap_collective_packed_operand_bit_identity(mesh_factory,
                                                        family):
    """The packed-operand entry points (packed_gemm / packed_kbit_gemm)
    ride the same ring now — raw int32 partials, exact in any order."""
    mesh = mesh_factory(4)
    m, k, n = 6, 100, 9
    rng = np.random.default_rng(5)
    ap = bitpack.pack_sign(jnp.asarray(
        np.sign(rng.standard_normal((m, k))), jnp.float32))
    wp = bitpack.pack_sign(jnp.asarray(
        np.sign(rng.standard_normal((n, k))), jnp.float32))
    seq = np.asarray(dispatch.packed_gemm(
        ap, wp, k_true=k,
        config=GemmConfig(backend=f"shard-{family}", mesh=mesh)))
    ring = np.asarray(dispatch.packed_gemm(
        ap, wp, k_true=k,
        config=GemmConfig(backend=f"shard-{family}", mesh=mesh,
                          overlap_collective=True)))
    np.testing.assert_array_equal(ring, seq)
    a4, _, ap4, wp4 = _plane_operands(7, m, k, n, 4)
    seq4 = np.asarray(dispatch.packed_kbit_gemm(
        ap4, wp4, config=GemmConfig(backend=f"shard-{family}", mesh=mesh)))
    ring4 = np.asarray(dispatch.packed_kbit_gemm(
        ap4, wp4, config=GemmConfig(backend=f"shard-{family}", mesh=mesh,
                                    overlap_collective=True)))
    np.testing.assert_array_equal(ring4, seq4)


@pytest.mark.parametrize("family", ["vpu", "mxu"])
def test_overlap_collective_grouped_bit_identity(mesh_factory, family):
    """The grouped (MoE expert-stacked) shard path honors the flag too —
    the ring runs inside each expert-axis group (1-bit and k-bit)."""
    mesh = mesh_factory(4)
    t_rows, k, n, e = 10, 90, 7, 3
    rng = np.random.default_rng(9)
    xs = jnp.asarray(rng.standard_normal((t_rows, k)), jnp.float32)
    gs = jnp.asarray([4, 3, 3], jnp.int32)
    w1p = jnp.stack([bitpack.pack_sign(jnp.asarray(
        np.sign(rng.standard_normal((n, k))), jnp.float32))
        for _ in range(e)])
    for kw in ({}, {"w_bits": 4, "a_bits": 4}):
        wstack = w1p if not kw else jnp.stack(
            [_plane_operands(e * 31 + i, 2, k, n, 4)[3] for i in range(e)])
        seq = np.asarray(dispatch.quant_gemm_grouped(
            xs, wstack, gs, k_true=k,
            config=GemmConfig(backend=f"shard-{family}", mesh=mesh), **kw))
        ring = np.asarray(dispatch.quant_gemm_grouped(
            xs, wstack, gs, k_true=k,
            config=GemmConfig(backend=f"shard-{family}", mesh=mesh,
                              overlap_collective=True), **kw))
        np.testing.assert_array_equal(ring, seq)


# ---------------------------------------------------------------------------
# decode-shape tile clamp (satellite): bm follows next-pow2(M) below 8
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["vpu", "mxu", "vpu-k8", "mxu-k8"])
def test_decode_tile_rows_clamp(backend):
    """M in 1..7 must clamp bm to next-pow2(M) instead of padding to 8."""
    for m, want_bm in [(1, 1), (2, 2), (3, 4), (4, 4), (5, 8), (8, 8),
                       (64, 64)]:
        t = dispatch.select_tiles(m, 256, 16, backend)
        assert t.bm == want_bm, (backend, m, t)
    # N rows use the same ladder; serving N stays on the big tiles
    assert dispatch.select_tiles(1, 256, 16, backend).bn == 128
