"""Per-arch smoke tests (deliverable f): every assigned architecture
instantiates a reduced same-family config, runs one forward + one train
step on CPU, asserts output shapes and no NaNs — in fp AND binary."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import QuantPolicy
from repro.data import synthetic
from repro.models import cnn, lm, registry, whisper
from repro.nn.common import QCtx
from repro.optim import adamw
from repro.train import trainer

LM_ARCHS = [a for a in registry.ASSIGNED if registry.get(a).family == "lm"]


def _ctx(quant):
    pol = QuantPolicy.binary() if quant == "binary" else QuantPolicy.full_precision()
    return QCtx(policy=pol, compute_dtype=jnp.float32)


@pytest.mark.parametrize("arch", LM_ARCHS)
@pytest.mark.parametrize("quant", ["fp", "binary"])
def test_lm_forward_smoke(arch, quant):
    spec = registry.get(arch)
    cfg = spec.smoke
    ctx = _ctx(quant)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    vis = (jax.random.normal(jax.random.PRNGKey(2),
                             (b, cfg.vision_prefix, cfg.d_vision))
           if cfg.vision_prefix else None)
    logits, aux = lm.forward(params, cfg, ctx, toks, vis)
    assert logits.shape == (b, s + cfg.vision_prefix, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_train_step_smoke(arch):
    spec = registry.get(arch)
    cfg = spec.smoke
    ctx = _ctx("binary")
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    params, opt_state = trainer.init_all(spec, cfg, jax.random.PRNGKey(0))
    step = jax.jit(trainer.make_train_step(spec, cfg, ctx, opt, remat=False))
    dcfg = synthetic.DataConfig(cfg.vocab_size, seq_len=16, global_batch=4)
    if cfg.vision_prefix:
        batch = synthetic.vlm_batch_at(dcfg, 0, cfg.vision_prefix, cfg.d_vision)
    else:
        batch = synthetic.batch_at(dcfg, 0)
    params, opt_state, m = step(params, opt_state, batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["grad_norm"]) > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_scan_blocks_matches_unrolled(arch):
    spec = registry.get(arch)
    cfg = spec.smoke
    ctx = _ctx("fp")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    vis = (jax.random.normal(jax.random.PRNGKey(2),
                             (2, cfg.vision_prefix, cfg.d_vision))
           if cfg.vision_prefix else None)
    l1, _ = lm.forward(params, cfg, ctx, toks, vis, scan_blocks=False)
    l2, _ = lm.forward(params, cfg, ctx, toks, vis, scan_blocks=True)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-5, atol=2e-5)


def test_whisper_smoke():
    spec = registry.get("whisper-base")
    cfg = spec.smoke
    ctx = _ctx("binary")
    params = whisper.init(jax.random.PRNGKey(0), cfg)
    frames = jax.random.normal(jax.random.PRNGKey(1), (2, cfg.t_enc, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab_size)
    logits, _ = whisper.forward(params, cfg, ctx, frames, toks)
    assert logits.shape == (2, 8, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


def test_whisper_train_step():
    spec = registry.get("whisper-base")
    cfg = spec.smoke
    ctx = _ctx("fp")
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    params, opt_state = trainer.init_all(spec, cfg, jax.random.PRNGKey(0))
    step = jax.jit(trainer.make_train_step(spec, cfg, ctx, opt, remat=False))
    dcfg = synthetic.DataConfig(cfg.vocab_size, seq_len=12, global_batch=2)
    batch = synthetic.whisper_batch_at(dcfg, 0, cfg.t_enc, cfg.d_model)
    params, opt_state, m = step(params, opt_state, batch)
    assert np.isfinite(float(m["loss"]))


@pytest.mark.parametrize("arch", ["lenet-mnist", "resnet18-cifar10"])
@pytest.mark.parametrize("quant", ["fp", "binary"])
def test_cnn_smoke(arch, quant):
    spec = registry.get(arch)
    cfg = spec.smoke
    ctx = _ctx(quant)
    if arch == "lenet-mnist":
        params = cnn.lenet_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (4, cfg.in_hw, cfg.in_hw, cfg.in_c))
        out = cnn.lenet_forward(params, cfg, ctx, x)
    else:
        params = cnn.resnet18_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (2, cfg.in_hw, cfg.in_hw, cfg.in_c))
        out = cnn.resnet18_forward(params, cfg, ctx, x)
    assert out.shape[-1] == cfg.n_classes
    assert np.isfinite(np.asarray(out)).all()


DECODE_ARCHS = ["deepseek-7b", "gemma2-27b", "recurrentgemma-2b", "rwkv6-7b",
                "deepseek-moe-16b", "qwen2-moe-a2.7b", "internvl2-1b",
                "granite-3-2b", "qwen2-72b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    """Prefill+decode produce the same logits as the full forward."""
    spec = registry.get(arch)
    cfg = spec.smoke
    ctx = QCtx(policy=QuantPolicy.full_precision(), compute_dtype=jnp.float32)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    vis = (jax.random.normal(jax.random.PRNGKey(2),
                             (b, cfg.vision_prefix, cfg.d_vision))
           if cfg.vision_prefix else None)
    full, _ = lm.forward(params, cfg, ctx, toks, vis)
    lp, cache = lm.prefill(params, cfg, ctx, toks[:, :-1],
                           cache_len=s + cfg.vision_prefix, vision_embeds=vis)
    np.testing.assert_allclose(np.asarray(lp[:, 0]), np.asarray(full[:, -2]),
                               rtol=2e-4, atol=2e-4)
    pos = jnp.full((b,), s - 1 + cfg.vision_prefix, jnp.int32)
    ld, _ = lm.decode_step(params, cfg, ctx, cache, toks[:, -1:], pos)
    np.testing.assert_allclose(np.asarray(ld[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_local_attention_window_masks():
    """Sliding-window attention must ignore tokens beyond the window."""
    spec = registry.get("gemma2-27b")
    import dataclasses
    cfg = spec.smoke
    ctx = _ctx("fp")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 40), 0,
                              cfg.vocab_size)
    # perturb a token far outside the window of the last position
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    w = cfg.local_attn.window  # 32
    f1, _ = lm.forward(params, cfg, ctx, toks)
    f2, _ = lm.forward(params, cfg, ctx, toks2)
    # global layers see token 0 => earlier positions differ; if we only had
    # local layers the tail would match.  Build a local-only variant:
    cfg_local = dataclasses.replace(cfg, mixer_pattern=("local_attn",))
    p3 = lm.init(jax.random.PRNGKey(0), cfg_local)
    g1, _ = lm.forward(p3, cfg_local, ctx, toks)
    g2, _ = lm.forward(p3, cfg_local, ctx, toks2)
    # last position attends to [40-32, 40): token 0 invisible through 2
    # local layers... receptive field grows per layer: with 2 layers the
    # last position can see back 2*(w-1); 2*31 > 40 so use position checks
    # structurally instead: first w-1 positions AFTER the perturbed token
    # differ, but the perturbed token cannot affect position j if
    # j - 0 >= n_layers * (w - 1) + 1.  40 - 0 < 2*31+1 -> not testable
    # with these dims; instead check window masking directly at layer 1.
    diff = np.abs(np.asarray(g1) - np.asarray(g2)).max(axis=-1)[0]
    assert diff[0] > 0  # perturbed position itself differs
    # position within window certainly differs too (sanity)
    assert diff[5] > 0
