"""Sharding resolver unit tests (AbstractMesh — no devices needed)."""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import abstract_mesh
from repro.dist.sharding import Resolver


def _mesh(shape=(16, 16), axes=("data", "model")):
    return abstract_mesh(shape, axes)


def test_divisible_dims_shard():
    rs = Resolver(_mesh())
    got = rs.resolve((None, "model"), (4096, 11008), "mlp/up/w")
    assert got == P(None, "model")
    assert not rs.demotions


def test_non_divisible_demotes_to_replicated():
    rs = Resolver(_mesh())
    got = rs.resolve(("model",), (49155,), "embed/table")  # granite vocab
    assert got == P()
    assert len(rs.demotions) == 1
    assert "49155" in rs.demotion_log()


def test_multi_axis_partial_demotion():
    rs = Resolver(_mesh((2, 16, 16), ("pod", "data", "model")))
    # batch 16 divides data(16) but not pod*data(32): drop 'pod' only
    got = rs.resolve((("pod", "data"),), (16,), "batch")
    assert got == P("data")


def test_param_rules_paths():
    rs = Resolver(_mesh())
    params = {
        "embed": {"table": jax.ShapeDtypeStruct((102400, 4096), jnp.float32)},
        "layers": [{
            "attn": {
                "q": {"w": jax.ShapeDtypeStruct((4096, 4096), jnp.float32)},
                "o": {"w": jax.ShapeDtypeStruct((4096, 4096), jnp.float32)},
            },
            "pre_norm": {"scale": jax.ShapeDtypeStruct((4096,), jnp.float32)},
        }],
        "lm_head": {"w": jax.ShapeDtypeStruct((4096, 102400), jnp.float32)},
    }
    specs = rs.params_pspecs(params)
    assert specs["embed"]["table"] == P("model")
    assert specs["layers"][0]["attn"]["q"]["w"] == P(None, "model")
    assert specs["layers"][0]["attn"]["o"]["w"] == P("model")
    assert specs["layers"][0]["pre_norm"]["scale"] == P()
    assert specs["lm_head"]["w"] == P(None, "model")


def test_master_pspecs_adds_data_axis():
    rs = Resolver(_mesh())
    params = {
        "mlp": {"up": {"w": jax.ShapeDtypeStruct((4096, 11008), jnp.float32)}},
        "norm": {"scale": jax.ShapeDtypeStruct((4096,), jnp.float32)},
    }
    m = rs.master_pspecs(params)
    assert m["mlp"]["up"]["w"] == P("data", "model")
    assert m["norm"]["scale"] == P("data")  # 4096 % 16 == 0


def test_cache_pspecs_sequence_sharded():
    """Flash-decoding layout: cache sequence dim over 'model' for every
    arch (kv-head count irrelevant — see dist/sharding.py docstring)."""
    rs = Resolver(_mesh())
    cache = {
        "layers": [{
            "k": jax.ShapeDtypeStruct((128, 32768, 8, 128), jnp.bfloat16),
            "v": jax.ShapeDtypeStruct((128, 32768, 8, 128), jnp.bfloat16),
            "slot_pos": jax.ShapeDtypeStruct((128, 32768), jnp.int32),
        }]
    }
    specs = rs.cache_pspecs(cache)
    assert specs["layers"][0]["k"] == P("data", "model")
    assert specs["layers"][0]["slot_pos"] == P("data", "model")

    # local-attention ring (window 2048) still divides the model axis
    cache2 = {"k": jax.ShapeDtypeStruct((128, 2048, 1, 256), jnp.bfloat16)}
    assert rs.cache_pspecs(cache2)["k"] == P("data", "model")


def test_batch_pspec_b1_replicates():
    rs = Resolver(_mesh())
    specs = rs.batch_pspecs({"tokens": jax.ShapeDtypeStruct((1, 128),
                                                            jnp.int32)})
    assert specs["tokens"] == P()  # long_500k: batch 1 can't shard


def test_rwkv_state_pspec():
    rs = Resolver(_mesh())
    cache = {"S": jax.ShapeDtypeStruct((128, 64, 64, 64), jnp.float32)}
    assert rs.cache_pspecs(cache)["S"] == P("data", "model")


def test_gemm_pspecs_layouts():
    """Resolver.gemm_pspecs: the packed-GEMM operand layouts the shard-*
    dispatch backends shard_map over (validated against this mesh)."""
    import pytest

    from repro.dist.sharding import packed_gemm_pspecs

    rs = Resolver(_mesh())
    k = rs.gemm_pspecs("k")
    assert k.a == P(None, "model") and k.w == P(None, "model")
    assert k.out == P(None, None) and k.reduce_axis == "model"
    n = rs.gemm_pspecs("n")
    assert n.w == P("model", None) and n.out == P(None, "model")
    assert n.reduce_axis is None  # column-parallel: no collective
    g = rs.gemm_pspecs("k", grouped=True, expert_axis="data")
    assert g.a == P("data", None, "model") and g.out == P("data", None, None)
    p = rs.gemm_pspecs("k", planes=True)
    assert p.a == P(None, None, "model")

    # prologue form: the activation operand is the FLOAT (M, K) tensor,
    # quantized+packed inside the shard_map body — its spec is 2-D with
    # the K dim partitioned ("k") or replicated ("n"); w/out unchanged
    kp = rs.gemm_pspecs("k", prologue=True)
    assert kp.a == P(None, "model") and kp.w == k.w and kp.out == k.out
    kpp = rs.gemm_pspecs("k", planes=True, prologue=True)
    assert kpp.a == P(None, "model") and kpp.w == p.w
    np_ = rs.gemm_pspecs("n", planes=True, prologue=True)
    assert np_.a == P(None, None) and np_.reduce_axis is None

    # validation: unknown mesh axes / layouts raise at resolve time,
    # not deep inside shard_map
    with pytest.raises(ValueError, match="not on mesh"):
        rs.gemm_pspecs("k", axis="nope")
    with pytest.raises(ValueError, match="not on mesh"):
        rs.gemm_pspecs("k", grouped=True, expert_axis="nope")
    with pytest.raises(ValueError, match="layout"):
        packed_gemm_pspecs("zigzag", "model")
    with pytest.raises(ValueError, match="no 'n' layout"):
        packed_gemm_pspecs("n", "model", grouped=True)
    with pytest.raises(ValueError, match="no prologue"):
        packed_gemm_pspecs("k", "model", grouped=True, prologue=True)


def test_master_pspecs_does_not_double_log_demotions():
    """specs.py resolves compute AND master layouts on one Resolver; each
    real demotion must appear once in the operator-facing log."""
    rs = Resolver(_mesh())
    params = {"embed": {"table": jax.ShapeDtypeStruct((49155, 64),
                                                      jnp.float32)}}
    rs.params_pspecs(params)
    rs.master_pspecs(params)
    assert len(rs.demotions) == 1
