"""The unified quantized-GEMM dispatch layer (kernels/dispatch.py):
epilogue fusion, backend parity on odd shapes, tile heuristics, and the
grouped (MoE expert-stacked) packed path."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitpack, converter, quant
from repro.core.policy import QuantPolicy, QuantSpec
from repro.kernels import dispatch, ref
from repro.kernels.dispatch import EpilogueSpec, GemmConfig, QuantGemmCall

BACKENDS = ["vpu", "mxu", "xla"]
ODD_SHAPES = [(5, 33, 7), (17, 100, 39), (1, 1, 1), (130, 260, 120)]


def _mats(seed, m, k, n):
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n), jnp.float32)
    return a, w


# ---------------------------------------------------------------------------
# epilogue fusion: dispatch output == unfused reference for every
# combination of scale / xnor_range / bias
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "use_scale,use_range,use_bias",
    list(itertools.product([False, True], repeat=3)),
)
def test_epilogue_fusion_equivalence(use_scale, use_range, use_bias):
    m, k, n = 9, 70, 13
    a, w = _mats(0, m, k, n)
    scale = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (n,))) + 0.1
    bias = jax.random.normal(jax.random.PRNGKey(3), (n,))

    # unfused reference: exact ±1 dot, then each epilogue step by hand
    y = np.asarray(ref.sign_gemm_ref(a, w), np.float64)
    if use_scale:
        y = y * np.asarray(scale, np.float64)
    if use_range:
        y = np.asarray(quant.xnor_range_map(jnp.asarray(y), k))
    if use_bias:
        y = y + np.asarray(bias, np.float64)

    wp = bitpack.pack_sign(w.T)
    got = dispatch.quant_gemm(
        a, wp, k_true=k,
        epilogue=EpilogueSpec(scale=use_scale, xnor_range=use_range,
                              bias=use_bias, out_dtype=jnp.float32),
        scale=scale if use_scale else None,
        bias=bias if use_bias else None,
    )
    np.testing.assert_allclose(np.asarray(got), y, rtol=1e-6, atol=1e-6)


def test_quant_gemm_call_object():
    m, k, n = 4, 40, 6
    a, w = _mats(1, m, k, n)
    call = QuantGemmCall(k_true=k, config=GemmConfig(backend="vpu"),
                         epilogue=EpilogueSpec(out_dtype=jnp.bfloat16))
    got = call(a, bitpack.pack_sign(w.T))
    assert got.dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(got, np.float32), np.asarray(ref.sign_gemm_ref(a, w))
    )


# ---------------------------------------------------------------------------
# backend parity on odd (non-multiple) shapes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", ODD_SHAPES)
def test_backend_parity_odd_shapes(m, k, n):
    a, w = _mats(42, m, k, n)
    oracle = np.asarray(ref.sign_gemm_ref(a, w)).astype(np.int32)
    wp = bitpack.pack_sign(w.T)
    for backend in BACKENDS:
        got = dispatch.quant_gemm(
            a, wp, k_true=k, config=GemmConfig(backend=backend)
        )
        np.testing.assert_array_equal(np.asarray(got), oracle, err_msg=backend)


def test_packed_gemm_primitive_parity():
    m, k, n = 17, 100, 39
    a, w = _mats(7, m, k, n)
    ap, wp = bitpack.pack_sign(a), bitpack.pack_sign(w.T)
    oracle = np.asarray(ref.xnor_gemm_ref(ap, wp, k))
    for backend in BACKENDS:
        got = dispatch.packed_gemm(
            ap, wp, k_true=k, config=GemmConfig(backend=backend)
        )
        np.testing.assert_array_equal(np.asarray(got), oracle, err_msg=backend)


def test_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown gemm backend"):
        dispatch.get_backend("tpu_v7")


def test_unknown_backend_raises_through_entry_points():
    """A typo'd base name must raise from every entry point, not fall back
    to some default kernel."""
    a = jnp.ones((2, 64), jnp.float32)
    wp = bitpack.pack_sign(jnp.ones((3, 64), jnp.float32))
    with pytest.raises(ValueError, match="unknown gemm backend"):
        dispatch.quant_gemm(a, wp, k_true=64,
                            config=GemmConfig(backend="vpuu"))
    with pytest.raises(ValueError, match="unknown gemm backend"):
        dispatch.quant_gemm(a, jnp.zeros((4, 3, 2), jnp.uint32), k_true=64,
                            config=GemmConfig(backend="vpuu"),
                            w_bits=4, a_bits=4)
    with pytest.raises(ValueError, match="unknown gemm backend"):
        dispatch.quant_gemm_grouped(
            a, jnp.zeros((2, 3, 2), jnp.uint32),
            jnp.asarray([1, 1], jnp.int32), k_true=64,
            config=GemmConfig(backend="shard-xla"))  # no such shard entry


def test_resolve_backend_down_resolution():
    """resolve_backend maps (base name, w_bits) onto the entry that runs
    it — including 1-bit down-resolution within each family and k-bit
    up-resolution onto the plane entries."""
    # 1-bit: plane backends down-resolve to their family's ±1 entry
    assert dispatch.resolve_backend("vpu-k4", 1) == "vpu"
    assert dispatch.resolve_backend("vpu-k8", 1) == "vpu"
    assert dispatch.resolve_backend("mxu-k4", 1) == "mxu"
    assert dispatch.resolve_backend("shard-vpu-k4", 1) == "shard-vpu"
    assert dispatch.resolve_backend("shard-mxu-k8", 1) == "shard-mxu"
    assert dispatch.resolve_backend("vpu", 1) == "vpu"
    assert dispatch.resolve_backend("shard-mxu", 1) == "shard-mxu"
    assert dispatch.resolve_backend("xla", 1) == "xla"
    # k-bit: base names resolve onto THEIR OWN family's plane entry
    assert dispatch.resolve_backend("vpu", 4) == "vpu-k4"
    assert dispatch.resolve_backend("mxu", 2) == "mxu-k2"
    assert dispatch.resolve_backend("mxu", 8) == "mxu-k8"
    assert dispatch.resolve_backend("shard-vpu", 8) == "shard-vpu-k8"
    assert dispatch.resolve_backend("shard-mxu", 4) == "shard-mxu-k4"
    # a k-bit entry asked for another width re-resolves within its family
    assert dispatch.resolve_backend("mxu-k2", 4) == "mxu-k4"
    # widths with no plane entry fall back to the xla dequant path
    assert dispatch.resolve_backend("vpu", 5) == "xla"
    assert dispatch.resolve_backend("shard-vpu", 3) == "xla"
    # xla handles every width itself (from_float_kbit)
    assert dispatch.resolve_backend("xla", 4) == "xla"


def test_tile_overrides_reach_kernel(monkeypatch):
    """GemmConfig tile overrides must reach the traced Pallas call — a
    spy on the kernel wrapper records the tile kwargs it was invoked
    with (unique shape so the jit cache cannot satisfy the call)."""
    seen = {}
    real = dispatch.xnor_mismatch_pallas

    def spy(ap, bp, **kw):
        seen.update(kw)
        return real(ap, bp, **kw)

    monkeypatch.setattr(dispatch, "xnor_mismatch_pallas", spy)
    m, k, n = 21, 6 * 32, 19
    a, w = _mats(23, m, k, n)
    cfg = GemmConfig(backend="vpu", bm=16, bn=8, bkw=3, chunk_words=3)
    got = dispatch.quant_gemm(a, bitpack.pack_sign(w.T), k_true=k,
                              config=cfg)
    assert (seen["bm"], seen["bn"], seen["bkw"], seen["chunk_words"]) == (
        16, 8, 3, 3)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.sign_gemm_ref(a, w)))


def test_tile_table_covers_and_divides():
    for m, n, kw in [(1, 1, 1), (5, 33, 3), (128, 128, 64), (1000, 7, 200)]:
        for backend in ("vpu", "mxu"):
            t = dispatch.select_tiles(m, n, kw, backend)
            assert t.bkw % t.chunk_words == 0
            assert t.bm <= 128 and t.bn <= 128
            # tiles never exceed the padded operand by more than one step
            assert t.bm >= min(m, 8) and t.bn >= min(n, 8)


def test_config_tile_overrides_win():
    cfg = GemmConfig(backend="vpu", bm=16, bkw=8, chunk_words=4)
    t = cfg.tiles(100, 100, 64)
    assert (t.bm, t.bkw, t.chunk_words) == (16, 8, 4)
    assert t.bn == 128  # unset override falls back to the table


def test_tile_override_chunk_divisibility():
    """A bkw override that the default chunk does not divide must still be
    exact (the kernel iterates bkw // chunk_words chunks — a non-divisor
    would silently skip K-tail words)."""
    m, k, n = 6, 12 * 32, 5  # Kw = 12, not a multiple of chunk 8
    a, w = _mats(11, m, k, n)
    oracle = np.asarray(ref.sign_gemm_ref(a, w)).astype(np.int32)
    for cfg in (GemmConfig(backend="vpu", bkw=12),
                GemmConfig(backend="vpu", bkw=12, chunk_words=8),
                GemmConfig(backend="vpu", chunk_words=5)):
        assert cfg.tiles(m, n, 12).bkw % cfg.tiles(m, n, 12).chunk_words == 0
        got = dispatch.quant_gemm(a, bitpack.pack_sign(w.T), k_true=k,
                                  config=cfg)
        np.testing.assert_array_equal(np.asarray(got), oracle)


# ---------------------------------------------------------------------------
# grouped (expert-stacked) packed GEMM
# ---------------------------------------------------------------------------


def _grouped_reference(x, w, gs):
    t = x.shape[0]
    e = w.shape[0]
    ends = np.cumsum(np.asarray(gs))
    out = np.zeros((t, w.shape[1]), np.float32)
    for i in range(t):
        g = int(np.searchsorted(ends, i, side="right"))
        if g < e:
            out[i] = np.asarray(
                ref.sign_gemm_ref(x[i:i + 1], np.asarray(w[g]).T)
            )[0]
    return out


@pytest.mark.parametrize("backend", BACKENDS)
def test_grouped_gemm_matches_per_group_reference(backend):
    t, k, e, n = 23, 45, 4, 13
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (t, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (e, n, k), jnp.float32)
    gs = jnp.asarray([5, 0, 11, 4], jnp.int32)  # ragged, sum < t
    got = dispatch.quant_gemm_grouped(
        x, bitpack.pack_sign(w), gs, k_true=k,
        config=GemmConfig(backend=backend),
    )
    np.testing.assert_array_equal(np.asarray(got),
                                  _grouped_reference(x, w, gs))


@pytest.mark.parametrize("backend", BACKENDS)
def test_grouped_gemm_capacity_drops_overflow(backend):
    """expert_capacity drops overflow rows identically on EVERY backend."""
    t, k, e, n = 12, 33, 3, 5
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(key, (t, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (e, n, k), jnp.float32)
    gs = jnp.asarray([8, 2, 2], jnp.int32)
    got = dispatch.quant_gemm_grouped(
        x, bitpack.pack_sign(w), gs, k_true=k,
        config=GemmConfig(backend=backend), expert_capacity=4,
    )
    full = _grouped_reference(x, w, gs)
    got = np.asarray(got)
    # within capacity: exact; overflowed rows (4..7 of expert 0): zeros
    np.testing.assert_array_equal(got[:4], full[:4])
    np.testing.assert_array_equal(got[4:8], np.zeros((4, n), np.float32))
    np.testing.assert_array_equal(got[8:], full[8:])


@pytest.mark.parametrize("backend", BACKENDS)
def test_grouped_gemm_multi_stack(backend):
    """Tuple of weight stacks: one pack+bucket pass, per-stack outputs."""
    t, k, e, n = 17, 40, 3, 9
    key = jax.random.PRNGKey(13)
    x = jax.random.normal(key, (t, k), jnp.float32)
    w1 = jax.random.normal(jax.random.fold_in(key, 1), (e, n, k), jnp.float32)
    w2 = jax.random.normal(jax.random.fold_in(key, 2), (e, n, k), jnp.float32)
    gs = jnp.asarray([6, 7, 4], jnp.int32)
    y1, y2 = dispatch.quant_gemm_grouped(
        x, (bitpack.pack_sign(w1), bitpack.pack_sign(w2)), gs, k_true=k,
        config=GemmConfig(backend=backend),
    )
    np.testing.assert_array_equal(np.asarray(y1), _grouped_reference(x, w1, gs))
    np.testing.assert_array_equal(np.asarray(y2), _grouped_reference(x, w2, gs))


def test_qctx_replace_gemm_config_sticks():
    """dataclasses.replace(ctx, gemm_config=...) must not be reverted by a
    stale legacy xnor_backend alias."""
    import dataclasses as dc

    from repro.nn.common import QCtx

    ctx = QCtx(policy=QuantPolicy.binary(), xnor_backend="vpu")
    assert ctx.gemm_config.backend == "vpu"
    ctx2 = dc.replace(ctx, gemm_config=GemmConfig(backend="xla"))
    assert ctx2.gemm_config.backend == "xla"


# ---------------------------------------------------------------------------
# packed MoE == fake-quant MoE (end-to-end through nn/mlp.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["vpu", "xla"])
def test_moe_packed_matches_fakequant(backend):
    from repro.nn import mlp
    from repro.nn.common import QCtx

    cfg = mlp.MoEConfig(d_model=64, d_expert=48, n_routed=8, n_shared=1,
                        top_k=2)
    params = mlp.moe_init(jax.random.PRNGKey(0), cfg)
    policy = QuantPolicy.binary()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 64))

    ctx_fq = QCtx(policy=policy, compute_dtype=jnp.float32)
    y_fq, aux_fq = mlp.moe_apply(params, x, cfg, ctx_fq, "layers/0/moe")

    packed, rep = converter.convert(jax.tree.map(np.asarray, params), policy)
    assert rep.n_packed > 0
    packed = jax.tree.map(jnp.asarray, packed)
    # the packed expert stacks must flow to the GEMM still bit-packed
    assert "up_packed" in packed["experts"]

    ctx_pk = QCtx(policy=policy, compute_dtype=jnp.float32,
                  gemm_config=GemmConfig(backend=backend))
    y_pk, aux_pk = mlp.moe_apply(packed, x, cfg, ctx_pk, "layers/0/moe")
    np.testing.assert_array_equal(np.asarray(y_fq), np.asarray(y_pk))


def test_mlp_no_unpack_on_expert_weights():
    """The 32x HBM win: nn/mlp.py must not unpack packed expert weights
    in-graph (the dispatch layer owns the packed contraction)."""
    import inspect

    from repro.nn import mlp

    src = inspect.getsource(mlp)
    assert "unpack_sign" not in src


def test_qdense_packed_epilogue_matches_train():
    """Dense layer: both paths share dispatch.apply_epilogue — exact match
    with scale+xnor_range+bias all on."""
    from repro.core import qlayers

    key = jax.random.PRNGKey(0)
    p = qlayers.dense_init(key, 96, 24, bias=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (7, 96))
    spec = QuantSpec(w_bits=1, a_bits=1, scale=True, xnor_range=True)
    pol = QuantPolicy(w_bits=1, a_bits=1, scale=True, xnor_range=True)
    y_train = qlayers.qdense(p, x, spec, compute_dtype=jnp.float32)
    packed, _ = converter.convert({"l": p}, pol)
    y_packed = qlayers.qdense(packed["l"], x, spec,
                              compute_dtype=jnp.float32,
                              gemm_config=GemmConfig(backend="vpu"))
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_packed),
                               rtol=1e-6, atol=1e-6)
