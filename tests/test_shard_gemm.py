"""Tensor-parallel packed GEMM (the `shard-*` dispatch backends): every
sharded result must be BIT-IDENTICAL (int32 accumulators and all) to its
single-device counterpart, across K-split widths, non-divisible Kw, both
operand layouts, k-bit plane stacks, and the grouped (MoE) path — plus a
pad-correction property sweep over odd k_true values.

Runs on the virtual 8-device CPU platform from tests/conftest.py
(``mesh_factory`` skips gracefully when the devices are unavailable)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import bitpack, quant
from repro.kernels import dispatch, ref
from repro.kernels.dispatch import EpilogueSpec, GemmConfig

WAYS = [1, 2, 4, 8]


def _mats(seed, m, k, n):
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n), jnp.float32)
    return a, w


# ---------------------------------------------------------------------------
# 1-bit: shard-vpu / shard-mxu vs vpu / mxu
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ways", WAYS)
@pytest.mark.parametrize("inner", ["vpu", "mxu"])
def test_shard_1bit_matches_single_device(mesh_factory, inner, ways):
    """K-partitioned packed GEMM: bit-identical int32 dots at every split
    width, including Kw (=11 words) not divisible by the split."""
    mesh = mesh_factory(ways)
    m, k, n = 17, 10 * 32 + 3, 13  # Kw = 11: non-divisible for ways > 1
    a, w = _mats(0, m, k, n)
    ap, wp = bitpack.pack_sign(a), bitpack.pack_sign(w.T)
    want = np.asarray(dispatch.packed_gemm(
        ap, wp, k_true=k, config=GemmConfig(backend=inner)))
    got = np.asarray(dispatch.packed_gemm(
        ap, wp, k_true=k,
        config=GemmConfig(backend=f"shard-{inner}", mesh=mesh)))
    np.testing.assert_array_equal(got, want)
    assert got.dtype == np.int32


@pytest.mark.parametrize("inner", ["vpu", "mxu"])
def test_shard_1bit_n_layout(mesh_factory, inner):
    """The second (column-parallel) layout: N-partitioned weights with
    replicated activations, no collective — still bit-identical."""
    mesh = mesh_factory(4)
    m, k, n = 9, 100, 13  # N = 13: non-divisible by 4 shards
    a, w = _mats(1, m, k, n)
    ap, wp = bitpack.pack_sign(a), bitpack.pack_sign(w.T)
    want = np.asarray(dispatch.packed_gemm(
        ap, wp, k_true=k, config=GemmConfig(backend=inner)))
    got = np.asarray(dispatch.packed_gemm(
        ap, wp, k_true=k,
        config=GemmConfig(backend=f"shard-{inner}", mesh=mesh,
                          shard_layout="n")))
    np.testing.assert_array_equal(got, want)


def test_shard_quant_gemm_epilogue_end_to_end(mesh_factory):
    """Float activations -> pack -> sharded GEMM -> fused epilogue equals
    the single-device path with scale+range+bias all on."""
    mesh = mesh_factory(2)
    m, k, n = 7, 70, 11
    a, w = _mats(2, m, k, n)
    wp = bitpack.pack_sign(w.T)
    scale = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (n,))) + 0.1
    bias = jax.random.normal(jax.random.PRNGKey(4), (n,))
    epi = EpilogueSpec(scale=True, xnor_range=True, bias=True)
    want = np.asarray(dispatch.quant_gemm(
        a, wp, k_true=k, config=GemmConfig(backend="vpu"),
        epilogue=epi, scale=scale, bias=bias))
    got = np.asarray(dispatch.quant_gemm(
        a, wp, k_true=k,
        config=GemmConfig(backend="shard-vpu", mesh=mesh),
        epilogue=epi, scale=scale, bias=bias))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# k-bit plane stacks: shard-vpu-k* vs vpu-k*
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ways", [2, 4, 8])
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_shard_kbit_planes_match_single_device(mesh_factory, bits, ways):
    """Raw weighted-plane popcount S psums exactly over Kw shards."""
    mesh = mesh_factory(ways)
    m, k, n = 9, 5 * 32 + 17, 7  # Kw = 6: non-divisible for most splits
    a, w = _mats(bits, m, k, n)
    ap = bitpack.pack_planes(quant.act_codes(a, bits), bits)
    wp = bitpack.pack_planes(quant.weight_codes(w.T, bits), bits)
    want = np.asarray(dispatch.packed_kbit_gemm(
        ap, wp, config=GemmConfig(backend=f"vpu-k{bits}")))
    got = np.asarray(dispatch.packed_kbit_gemm(
        ap, wp,
        config=GemmConfig(backend=f"shard-vpu-k{bits}", mesh=mesh)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("layout", ["k", "n"])
def test_shard_kbit_quant_gemm(mesh_factory, layout):
    """w4a4 float-activation entry point through the shard plane backend
    (base name resolution included: 'shard-vpu' + w_bits=4)."""
    mesh = mesh_factory(4)
    m, k, n = 8, 90, 6
    a, w = _mats(7, m, k, n)
    wp = bitpack.pack_planes(quant.weight_codes(w.T, 4), 4)
    want = np.asarray(dispatch.quant_gemm(
        a, wp, k_true=k, config=GemmConfig(backend="vpu"),
        w_bits=4, a_bits=4))
    got = np.asarray(dispatch.quant_gemm(
        a, wp, k_true=k,
        config=GemmConfig(backend="shard-vpu", mesh=mesh,
                          shard_layout=layout),
        w_bits=4, a_bits=4))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# grouped (MoE expert-stacked): expert-parallel x Kw-parallel
# ---------------------------------------------------------------------------


def _grouped_case(seed=5, t=23, k=45, e=4, n=13):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (t, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (e, n, k), jnp.float32)
    gs = jnp.asarray([5, 0, 11, 4], jnp.int32)  # ragged, sum < t
    return x, w, gs


@pytest.mark.parametrize("ways", [2, 4])
@pytest.mark.parametrize("inner", ["vpu", "mxu"])
def test_shard_grouped_matches_single_device(mesh_factory, inner, ways):
    mesh = mesh_factory(ways)
    x, w, gs = _grouped_case()
    wp = bitpack.pack_sign(w)
    want = np.asarray(dispatch.quant_gemm_grouped(
        x, wp, gs, k_true=x.shape[1], config=GemmConfig(backend=inner)))
    got = np.asarray(dispatch.quant_gemm_grouped(
        x, wp, gs, k_true=x.shape[1],
        config=GemmConfig(backend=f"shard-{inner}", mesh=mesh)))
    np.testing.assert_array_equal(got, want)


def test_shard_grouped_expert_parallel_x_kw_parallel(mesh_factory):
    """2x2 mesh: expert stacks partition over 'expert' while each expert's
    contraction partitions over 'model' — still bit-identical."""
    mesh = mesh_factory((2, 2), axes=("expert", "model"))
    x, w, gs = _grouped_case()
    wp = bitpack.pack_sign(w)
    want = np.asarray(dispatch.quant_gemm_grouped(
        x, wp, gs, k_true=x.shape[1], config=GemmConfig(backend="vpu")))
    got = np.asarray(dispatch.quant_gemm_grouped(
        x, wp, gs, k_true=x.shape[1],
        config=GemmConfig(backend="shard-vpu", mesh=mesh,
                          expert_axis="expert")))
    np.testing.assert_array_equal(got, want)


def test_shard_grouped_kbit(mesh_factory):
    """Grouped k-bit plane stacks (w4a4 MoE) through shard-vpu-k4."""
    mesh = mesh_factory(2)
    x, w, gs = _grouped_case(seed=9)
    k = 4
    wp = jnp.moveaxis(bitpack.pack_planes(quant.weight_codes(w, k), k),
                      0, 1)  # (E, k, N, Kw)
    want = np.asarray(dispatch.quant_gemm_grouped(
        x, wp, gs, k_true=x.shape[1], config=GemmConfig(backend="vpu"),
        w_bits=k, a_bits=k))
    got = np.asarray(dispatch.quant_gemm_grouped(
        x, wp, gs, k_true=x.shape[1],
        config=GemmConfig(backend="shard-vpu", mesh=mesh),
        w_bits=k, a_bits=k))
    np.testing.assert_array_equal(got, want)


def test_shard_grouped_capacity_drops_match(mesh_factory):
    """expert_capacity semantics are backend-invariant on the shard path."""
    mesh = mesh_factory(2)
    x, w, gs = _grouped_case()
    wp = bitpack.pack_sign(w)
    want = np.asarray(dispatch.quant_gemm_grouped(
        x, wp, gs, k_true=x.shape[1], config=GemmConfig(backend="vpu"),
        expert_capacity=4))
    got = np.asarray(dispatch.quant_gemm_grouped(
        x, wp, gs, k_true=x.shape[1],
        config=GemmConfig(backend="shard-vpu", mesh=mesh),
        expert_capacity=4))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# fused prologue inside the shard_map body (the PR-4 refactor): float-
# activation entry points must NOT pack globally and reshard — each shard
# packs its own word-aligned K-slab
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("inner", ["vpu", "mxu"])
def test_shard_k_layout_packs_inside_body(mesh_factory, inner, monkeypatch):
    """On the "k" layout the pack kernel must only ever see LOCAL K-slabs
    (K/ways floats), never the global K — proof the quantize+pack stage
    moved inside the shard_map body."""
    widths = []
    real = dispatch.pack_activations

    def spy(x, **kw):
        widths.append(x.shape[-1])
        return real(x, **kw)

    monkeypatch.setattr(dispatch, "pack_activations", spy)
    ways = 4
    mesh = mesh_factory(ways)
    m, k, n = 6, 8 * 32, 10  # Kw = 8: 2 words (64 floats) per shard
    a, w = _mats(31, m, k, n)
    wp = bitpack.pack_sign(w.T)
    got = np.asarray(dispatch.quant_gemm(
        a, wp, k_true=k,
        config=GemmConfig(backend=f"shard-{inner}", mesh=mesh)))
    assert widths and max(widths) == k // ways  # local slabs only
    np.testing.assert_array_equal(
        got, np.asarray(ref.sign_gemm_ref(a, w)).astype(np.int32))


def test_shard_kbit_k_layout_packs_inside_body(mesh_factory, monkeypatch):
    """Same invariant for the fused k-bit plane prologue (S and the code
    row-sums T both psum from local slabs)."""
    ways = 2
    mesh = mesh_factory(ways)
    m, k, n = 5, 6 * 32, 7
    a, w = _mats(33, m, k, n)
    wp = bitpack.pack_planes(quant.weight_codes(w.T, 4), 4)
    want = np.asarray(dispatch.quant_gemm(
        a, wp, k_true=k, config=GemmConfig(backend="vpu"),
        w_bits=4, a_bits=4))

    widths = []
    real = dispatch.pack_act_planes

    def spy(x, a_bits, **kw):
        widths.append(x.shape[-1])
        return real(x, a_bits, **kw)

    monkeypatch.setattr(dispatch, "pack_act_planes", spy)
    got = np.asarray(dispatch.quant_gemm(
        a, wp, k_true=k,
        config=GemmConfig(backend="shard-vpu", mesh=mesh),
        w_bits=4, a_bits=4))
    assert widths and max(widths) == k // ways
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(k_true=st.integers(min_value=1, max_value=150),
       ways=st.sampled_from([2, 4]),
       inner=st.sampled_from(["vpu", "mxu"]))
def test_shard_prologue_property(k_true, ways, inner):
    """For ANY k_true (odd word tails, K smaller than the split, word
    counts not divisible by ways) the float-activation shard path — local
    word-aligned quantize+pack inside the body — returns the exact ±1 dot
    (pad bits are 0 in both operands on every shard)."""
    if len(jax.devices()) < ways:
        pytest.skip(f"{ways}-way mesh needs virtual host devices")
    mesh = jax.make_mesh((ways,), ("model",))
    m, n = 3, 5
    a, w = _mats(k_true * 5 + ways, m, k_true, n)
    oracle = np.asarray(ref.sign_gemm_ref(a, w)).astype(np.int32)
    wp = bitpack.pack_sign(w.T)
    got = np.asarray(dispatch.quant_gemm(
        a, wp, k_true=k_true,
        config=GemmConfig(backend=f"shard-{inner}", mesh=mesh)))
    np.testing.assert_array_equal(got, oracle)


# ---------------------------------------------------------------------------
# pad-correction property sweep (hypothesis; odd k_true on both paths)
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(k_true=st.integers(min_value=1, max_value=150),
       ways=st.sampled_from([1, 2, 4]),
       inner=st.sampled_from(["vpu", "mxu"]))
def test_pad_correction_property(k_true, ways, inner):
    """For ANY k_true (odd word tails, tiny K, K < split width) the exact
    ±1 dot comes back from both the sharded and unsharded paths — the pad
    correction is applied once and only once on each.  (Builds its mesh
    inline: the conftest hypothesis fallback wraps the signature, hiding
    fixture params from pytest.)"""
    if len(jax.devices()) < ways:
        pytest.skip(f"{ways}-way mesh needs virtual host devices")
    mesh = jax.make_mesh((ways,), ("model",))
    m, n = 3, 5
    a, w = _mats(k_true * 7 + ways, m, k_true, n)
    oracle = np.asarray(ref.sign_gemm_ref(a, w)).astype(np.int32)
    ap, wp = bitpack.pack_sign(a), bitpack.pack_sign(w.T)
    single = np.asarray(dispatch.packed_gemm(
        ap, wp, k_true=k_true, config=GemmConfig(backend=inner)))
    sharded = np.asarray(dispatch.packed_gemm(
        ap, wp, k_true=k_true,
        config=GemmConfig(backend=f"shard-{inner}", mesh=mesh)))
    np.testing.assert_array_equal(single, oracle)
    np.testing.assert_array_equal(sharded, oracle)


# ---------------------------------------------------------------------------
# negative paths
# ---------------------------------------------------------------------------


def test_shard_backend_without_mesh_raises():
    ap = jnp.zeros((4, 2), jnp.uint32)
    wp = jnp.zeros((4, 2), jnp.uint32)
    with pytest.raises(ValueError, match="needs GemmConfig.mesh"):
        dispatch.packed_gemm(ap, wp, k_true=64,
                             config=GemmConfig(backend="shard-vpu"))


def test_shard_axis_not_on_mesh_raises(mesh_factory):
    mesh = mesh_factory(2)
    ap = jnp.zeros((4, 2), jnp.uint32)
    with pytest.raises(ValueError, match="shard_axis"):
        dispatch.packed_gemm(
            ap, ap, k_true=64,
            config=GemmConfig(backend="shard-vpu", mesh=mesh,
                              shard_axis="nope"))


def test_unknown_shard_layout_raises(mesh_factory):
    mesh = mesh_factory(2)
    ap = jnp.zeros((4, 2), jnp.uint32)
    with pytest.raises(ValueError, match="layout"):
        dispatch.packed_gemm(
            ap, ap, k_true=64,
            config=GemmConfig(backend="shard-vpu", mesh=mesh,
                              shard_layout="zigzag"))


def test_unsharded_strips_family():
    cfg = GemmConfig(backend="shard-mxu", mesh=object())
    down = dispatch.unsharded(cfg)
    assert down.backend == "mxu" and down.mesh is None
    assert dispatch.unsharded(down) is down  # non-shard configs untouched
