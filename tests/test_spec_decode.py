"""Speculative decoding (PR 9): greedy spec-mode serving must be
TOKEN-IDENTICAL to target-only greedy serving — for ANY draft, any
spec_len, both KV layouts — because the target's own windowed greedy
picks gate every emission (serve/engine.py module docstring has the
invariants).  Plus units for the rollback primitives it rides on:
``KVCache.truncate`` on both layouts, ``ContiguousKVCache.fill_window``
(the one-hot scatter-free window write), and ``BlockAllocator.trim``
(tail release drains back, double release stays loud)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import converter
from repro.core.policy import QuantPolicy
from repro.models import lm, registry
from repro.nn import attention as attn_lib
from repro.nn.common import QCtx
from repro.serve.engine import (BlockAllocator, DraftModel, Engine,
                                EngineConfig, Request, Scheduler)

SPEC = registry.get("granite-3-2b")
CFG = SPEC.smoke
CTX = QCtx(policy=QuantPolicy.full_precision(), compute_dtype=jnp.float32)

_cache: dict = {}


def _params():
    if "params" not in _cache:
        _cache["params"] = lm.init(jax.random.PRNGKey(0), CFG)
    return _cache["params"]


def _draft(kind: str) -> DraftModel:
    """'slice': 1-layer float slice of the target (high agreement);
    'same': the target itself (forced accept — proposals ARE the target's
    greedy picks); 'doomed': embed-table-zeroed slice (constant logits ->
    always proposes token 0: forced reject almost every round)."""
    key = ("draft", kind)
    if key in _cache:
        return _cache[key]
    if kind == "same":
        dm = DraftModel(cfg=CFG, params=_params(), ctx=CTX)
    else:
        host = jax.tree.map(np.asarray, _params())
        dp, dcfg, _ = converter.derive_draft(
            host, CFG, n_layers=1, policy=QuantPolicy.full_precision(),
            keep_float=True)
        dp = jax.tree.map(jnp.asarray, dp)
        if kind == "doomed":
            # a zero (tied) embedding table makes every logit identical,
            # so greedy always proposes token 0 — maximally wrong against
            # a target whose picks are almost never 0
            dp = dict(dp, embed=jax.tree.map(lambda a: a * 0, dp["embed"]))
        dm = DraftModel(cfg=dcfg, params=dp, ctx=CTX)
    _cache[key] = dm
    return dm


def _engine(draft=None, spec_len=2, paged=False, batch=2, new_tokens=6):
    key = ("eng", id(draft), spec_len, paged, batch, new_tokens)
    if key not in _cache:
        kw = dict(batch=batch, cache_len=64, max_new_tokens=new_tokens)
        if paged:
            kw.update(kv_block_size=8, prefill_chunk=4)
        _cache[key] = Engine(SPEC, CFG, CTX, _params(),
                             EngineConfig(**kw, draft=draft,
                                          spec_len=spec_len))
    return _cache[key]


def _run(eng, lens, seed=0):
    rng = np.random.default_rng(seed)
    sched = Scheduler(eng)
    for i, ln in enumerate(lens):
        sched.submit(Request(prompt=rng.integers(
            0, CFG.vocab_size, (ln,)).astype(np.int32), rid=i))
    return sched.run(), sched.last_stats


# ---------------------------------------------------------------------------
# scheduler-level identity
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6),
       spec_len=st.integers(min_value=1, max_value=4))
def test_spec_greedy_identical_ragged(seed, spec_len):
    """Property sweep: ragged prompt lengths through slot recycling, any
    spec_len — the speculative stream equals the target-only stream
    exactly."""
    rng = np.random.default_rng(seed)
    lens = [int(rng.integers(2, 9)) for _ in range(4)]
    ref, _ = _run(_engine(), lens, seed=seed)
    got, stats = _run(_engine(draft=_draft("slice"), spec_len=spec_len),
                      lens, seed=seed)
    assert set(got) == set(ref)
    for rid in ref:
        np.testing.assert_array_equal(got[rid], ref[rid])
    assert stats.spec_rounds > 0
    assert stats.spec_proposed == spec_len * stats.spec_rounds


@pytest.mark.parametrize("spec_len", [1, 3])
def test_spec_greedy_identical_paged(spec_len):
    """Paged engine (block tables + chunked prefill): same identity; the
    per-row rollback releases visibility through pool_pos, never blocks
    (allocation stays full-table for the slot's lifetime)."""
    lens = [3, 7, 5, 6]
    ref, _ = _run(_engine(paged=True), lens)
    got, stats = _run(_engine(draft=_draft("slice"), spec_len=spec_len,
                              paged=True), lens)
    for rid in ref:
        np.testing.assert_array_equal(got[rid], ref[rid])
    assert stats.spec_rounds > 0


def test_spec_forced_accept_all():
    """Draft == target: every proposal matches the target's greedy pick,
    acceptance is exactly 1.0, and each round emits spec_len + 1 tokens
    (the free rides show up as fewer verify steps than target-only decode
    steps)."""
    lens = [5, 5]
    ref, ref_stats = _run(_engine(), lens)
    got, stats = _run(_engine(draft=_draft("same"), spec_len=3), lens)
    for rid in ref:
        np.testing.assert_array_equal(got[rid], ref[rid])
    assert stats.acceptance_rate == 1.0
    assert stats.steps < ref_stats.steps


def test_spec_forced_reject_rolls_back():
    """A doomed draft (constant logits -> always proposes token 0) forces
    a rollback nearly every round; the output must STILL be identical —
    the target's pick after the first rejection rides along, so progress
    is one token per round, never zero."""
    lens = [4, 6]
    ref, _ = _run(_engine(), lens)
    got, stats = _run(_engine(draft=_draft("doomed"), spec_len=2), lens)
    for rid in ref:
        np.testing.assert_array_equal(got[rid], ref[rid])
    assert stats.acceptance_rate < 0.5
    # token 0 can legitimately be the target's pick sometimes, but a
    # constant proposer must not look like a good one
    assert stats.spec_accepted < stats.spec_proposed


def test_spec_telemetry_per_token_times():
    """Satellite: per-request TTFT/TPOT lists cover every emitted token
    (t_tokens has one stamp per token, TPOT count = tokens - requests)."""
    lens = [4, 5, 6]
    got, stats = _run(_engine(draft=_draft("slice"), spec_len=2,
                              batch=2), lens)
    n_tok = sum(len(v) for v in got.values())
    assert sum(len(v) for v in stats.t_tokens.values()) == n_tok
    assert len(stats.ttfts()) == len(lens)
    assert len(stats.tpots()) == n_tok - len(lens)
    assert all(b >= a for v in stats.t_tokens.values()
               for a, b in zip(v, v[1:]))


def test_spec_validation():
    """Greedy-only, cache headroom, spec_len >= 1 — all loud."""
    dm = _draft("slice")
    with pytest.raises(ValueError, match="greedy-only"):
        Engine(SPEC, CFG, CTX, _params(),
               EngineConfig(batch=2, cache_len=64, max_new_tokens=4,
                            temperature=0.7, draft=dm))
    with pytest.raises(ValueError, match="spec_len"):
        Engine(SPEC, CFG, CTX, _params(),
               EngineConfig(batch=2, cache_len=64, max_new_tokens=4,
                            draft=dm, spec_len=0))
    eng = _engine(draft=dm, spec_len=2)
    sched = Scheduler(eng)
    sched.submit(Request(prompt=np.zeros((60,), np.int32), rid=0))
    with pytest.raises(ValueError, match="cache_len"):
        sched.run()  # 60 + 6 + 2 > 64: the verify window would overflow


def test_derive_draft_bounds():
    host = jax.tree.map(np.asarray, _params())
    dp, dcfg, report = converter.derive_draft(host, CFG)
    assert dcfg.n_layers == max(1, CFG.n_layers // 4)
    assert len(dp["layers"]) == dcfg.n_layers
    assert report.n_packed > 0  # the default policy binarizes the slice
    with pytest.raises(ValueError, match="n_layers"):
        converter.derive_draft(host, CFG, n_layers=CFG.n_layers + 1)
    with pytest.raises(ValueError, match="n_layers"):
        converter.derive_draft(host, CFG, n_layers=0)


# ---------------------------------------------------------------------------
# rollback / window-write primitives
# ---------------------------------------------------------------------------

_ACFG = attn_lib.AttnConfig(d_model=16, n_heads=2, n_kv_heads=2, d_head=8)


def _rand_kv(rng, b, s):
    k = jnp.asarray(rng.standard_normal((b, s, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, 2, 8)), jnp.float32)
    return k, v


def test_contiguous_fill_window_matches_sequential_fill():
    """fill_window (one-hot 0/1-coefficient einsum write) == one fill per
    position, bit-for-bit, at per-row window starts."""
    rng = np.random.default_rng(0)
    kv = attn_lib.CONTIGUOUS
    b, s, cache_len = 3, 4, 16
    k, v = _rand_kv(rng, b, s)
    starts = np.asarray([0, 5, 11], np.int32)
    positions = jnp.asarray(starts[:, None] + np.arange(s)[None, :])
    wm = jnp.asarray([True, True, False])
    base = kv.init(b, _ACFG, cache_len, jnp.float32)
    got = kv.fill_window(base, k, v, positions, write_mask=wm)
    want = base
    for c in range(s):
        want = kv.fill(want, k[:, c:c + 1], v[:, c:c + 1],
                       positions[:, c:c + 1], write_mask=wm)
    for key in ("k", "v", "slot_pos"):
        np.testing.assert_array_equal(np.asarray(got[key]),
                                      np.asarray(want[key]))
    # masked row wrote nothing
    assert (np.asarray(got["slot_pos"][2]) == -1).all()


def test_contiguous_truncate():
    """truncate flips slot_pos >= lengths back to empty (-1); the stale
    k/v bytes are unreachable (attention masks on slot_pos) and the next
    fill overwrites them."""
    rng = np.random.default_rng(1)
    kv = attn_lib.CONTIGUOUS
    b, s = 2, 6
    k, v = _rand_kv(rng, b, s)
    cache = kv.init(b, _ACFG, 16, jnp.float32)
    cache = kv.fill_window(
        cache, k, v, jnp.asarray(np.tile(np.arange(s), (b, 1))))
    out = kv.truncate(cache, jnp.asarray([4, 1 << 30], jnp.int32))
    sp = np.asarray(out["slot_pos"])
    assert set(sp[0][sp[0] >= 0]) == {0, 1, 2, 3}
    assert set(sp[1][sp[1] >= 0]) == set(range(s))  # NO_TRUNC row intact
    np.testing.assert_array_equal(np.asarray(out["k"]),
                                  np.asarray(cache["k"]))


def test_paged_truncate_shared_block_safe():
    """Paged truncate is visibility-only (pool_pos), and a block SHARED
    by two slots survives one holder's rollback: both holders' lengths
    exceed every shared position, so the scatter writes back identical
    bytes."""
    rng = np.random.default_rng(2)
    kv = attn_lib.PagedKVCache(block_size=4)
    b, cache_len = 2, 16
    cache = kv.init(b, _ACFG, cache_len, jnp.float32)
    # slot 0 -> blocks [0,1,2,3]; slot 1 -> [0,5,6,7] (block 0 shared)
    cache["table"] = jnp.asarray([[0, 1, 2, 3], [0, 5, 6, 7]], jnp.int32)
    s = 10
    k, v = _rand_kv(rng, b, s)
    pos = jnp.asarray(np.tile(np.arange(s), (b, 1)))
    cache = kv.fill(cache, k, v, pos, write_mask=jnp.asarray([True, True]))
    out = kv.truncate(cache, jnp.asarray([6, 1 << 30], jnp.int32))
    pool = np.asarray(out["pool_pos"])
    # slot 0's tail (pos 6..9, blocks 1-2) is released to -1 ...
    assert (pool[1][2:] == -1).all() and (pool[2][:2] == -1).all()
    # ... the shared block 0 (pos 0..3, < both lengths) is untouched ...
    np.testing.assert_array_equal(pool[0], np.arange(4))
    # ... and slot 1's view (through blocks 5,6) is fully intact
    np.testing.assert_array_equal(pool[5], np.arange(4, 8))
    np.testing.assert_array_equal(pool[6], np.asarray([8, 9, -1, -1]))


def test_paged_truncate_then_refill_bit_identical():
    """Rolling back and re-writing the same tokens reproduces the exact
    cache bytes — the property the spec rollback relies on."""
    rng = np.random.default_rng(3)
    kv = attn_lib.PagedKVCache(block_size=4)
    cache = kv.init(1, _ACFG, 12, jnp.float32)
    cache = {**cache, "table": jnp.asarray([[0, 1, 2]], jnp.int32)}
    k, v = _rand_kv(rng, 1, 8)
    pos = jnp.arange(8)[None, :]
    wm = jnp.asarray([True])
    full = kv.fill(cache, k, v, pos, write_mask=wm)
    rolled = kv.truncate(full, jnp.asarray([5], jnp.int32))
    refill = kv.fill(rolled, k[:, 5:], v[:, 5:], pos[:, 5:],
                     write_mask=wm)
    for key in ("pool_k", "pool_v", "pool_pos"):
        np.testing.assert_array_equal(np.asarray(refill[key]),
                                      np.asarray(full[key]))


def test_block_allocator_trim():
    """trim releases exactly the tail references: freed blocks drain back
    to the pool, kept blocks stay live, and releasing the same tail twice
    is a loud error (the caller adopted the kept prefix)."""
    alloc = BlockAllocator(num_blocks=6, block_size=4)
    blocks = [alloc.alloc() for _ in range(4)]
    assert alloc.live_blocks == 4
    kept = alloc.trim(blocks, 2)
    assert kept == blocks[:2]
    assert alloc.live_blocks == 2
    with pytest.raises(RuntimeError, match="double release"):
        alloc.release(blocks[2])  # tail ref already dropped by trim
    # the freed tail is allocatable again
    again = [alloc.alloc() for _ in range(4)]
    assert set(again) >= set(blocks[2:])
    # shared-tail trim: a refcounted block survives the first holder
    alloc2 = BlockAllocator(num_blocks=4, block_size=4)
    blk = alloc2.alloc()
    alloc2.refs[blk] += 1  # second holder (prefix sharing)
    assert alloc2.trim([blk], 0) == []
    assert alloc2.live_blocks == 1  # still held by the survivor
    alloc2.release(blk)
    assert alloc2.live_blocks == 0
