import random
import sys
import types
import zlib

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# hypothesis fallback: this container has no `hypothesis` package and
# nothing may be pip-installed.  Rather than skip the property tests, a
# minimal deterministic stand-in runs each @given test over `max_examples`
# seeded random draws (seeded from the test name, so failures reproduce).
# If real hypothesis is installed it is used untouched.
# ---------------------------------------------------------------------------

try:
    import hypothesis  # noqa: F401
except ImportError:

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: elements[r.randrange(len(elements))])

    def _booleans():
        return _Strategy(lambda r: bool(r.getrandbits(1)))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def _settings(max_examples=20, deadline=None, **_kw):
        def deco(f):
            f._fallback_max_examples = max_examples
            return f

        return deco

    def _given(**strategies):
        def deco(f):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_fallback_max_examples", 20)
                r = random.Random(zlib.crc32(f.__qualname__.encode()))
                for _ in range(n):
                    drawn = {k: s.draw(r) for k, s in strategies.items()}
                    f(*args, **kwargs, **drawn)

            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            wrapper.__module__ = f.__module__
            return wrapper

        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans
    _st.floats = _floats

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_fallback__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
