"""Shared test fixtures.

Virtual multi-device CPU: this conftest sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` at import time —
BEFORE any test module imports jax (jax locks the device count on first
init) — so the mesh/shard_map tests (tests/test_shard_gemm.py, the
sharded-engine smoke in tests/test_serve.py) run on plain CPU CI with an
8-device host platform.  The session-scoped ``mesh_factory`` fixture
builds 1-D/2-D meshes from those devices and gracefully skips a test when
the flag did not take effect (jax already imported, or an XLA build that
ignores it).  An explicit device count in a pre-set XLA_FLAGS is
respected.

Also provides a deterministic ``hypothesis`` stand-in (below) since the
container has no hypothesis package and nothing may be pip-installed.
"""

import os
import random
import sys
import types
import zlib

if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=8 " + _flags
        ).strip()

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def mesh_factory():
    """``make(shape, axes=("model",)) -> jax.Mesh`` over the virtual host
    devices; skips the requesting test when the device pool is too small
    (see module docstring)."""
    import jax

    n_dev = len(jax.devices())

    def make(shape, axes=("model",)):
        if isinstance(shape, int):
            shape = (shape,)
        need = 1
        for s in shape:
            need *= s
        if need > n_dev:
            pytest.skip(
                f"mesh {shape} needs {need} devices, have {n_dev} "
                "(XLA_FLAGS=--xla_force_host_platform_device_count "
                "unavailable?)"
            )
        return jax.make_mesh(tuple(shape), tuple(axes))

    return make


# ---------------------------------------------------------------------------
# hypothesis fallback: this container has no `hypothesis` package and
# nothing may be pip-installed.  Rather than skip the property tests, a
# minimal deterministic stand-in runs each @given test over `max_examples`
# seeded random draws (seeded from the test name, so failures reproduce).
# If real hypothesis is installed it is used untouched.
# ---------------------------------------------------------------------------

try:
    import hypothesis  # noqa: F401
except ImportError:

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: elements[r.randrange(len(elements))])

    def _booleans():
        return _Strategy(lambda r: bool(r.getrandbits(1)))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def _settings(max_examples=20, deadline=None, **_kw):
        def deco(f):
            f._fallback_max_examples = max_examples
            return f

        return deco

    def _given(**strategies):
        def deco(f):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_fallback_max_examples", 20)
                r = random.Random(zlib.crc32(f.__qualname__.encode()))
                for _ in range(n):
                    drawn = {k: s.draw(r) for k, s in strategies.items()}
                    f(*args, **kwargs, **drawn)

            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            wrapper.__module__ = f.__module__
            return wrapper

        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans
    _st.floats = _floats

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_fallback__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
