"""Checkpoint manager: atomicity, corruption fallback, retention, async,
packed export."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.manager import CheckpointManager, export_packed
from repro.core.policy import QuantPolicy
from repro.core import qlayers


def _tree(seed=0):
    key = jax.random.PRNGKey(seed)
    return {
        "params": {"layers": [
            {"w": jax.random.normal(jax.random.fold_in(key, i), (8, 8))}
            for i in range(3)
        ]},
        "opt": {"step": jnp.asarray(7, jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(10, tree)
    step, got = mgr.restore(tree)
    assert step == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_skips_corrupt_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(1, tree)
    mgr.save(2, _tree(seed=1))
    # corrupt the newest
    arr = os.path.join(str(tmp_path), "step_00000002", "arrays.npz")
    with open(arr, "r+b") as f:
        f.seek(200)
        f.write(b"\xde\xad\xbe\xef" * 8)
    step, got = mgr.restore(tree)
    assert step == 1  # fell back past the corrupt one


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.all_steps() == [3, 4]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_no_tmp_dir_left_behind(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_export_packed(tmp_path):
    params = {"lay": qlayers.dense_init(jax.random.PRNGKey(0), 256, 128),
              "head": qlayers.dense_init(jax.random.PRNGKey(1), 128, 16)}
    params = jax.tree.map(np.asarray, params)
    path = str(tmp_path / "packed.npz")
    rep = export_packed(params, QuantPolicy.binary(), path)
    assert rep.n_packed == 1  # 'head' stays fp
    assert os.path.exists(path)
    data = np.load(path)
    assert any("w_packed" in k for k in data.files)


def test_dataclass_roundtrip_empty_ef(tmp_path):
    """TrainState (a dataclass pytree) flattens field-wise; the empty-ef
    form (compression off) round-trips to an empty dict."""
    from repro.train.trainer import TrainState

    state = TrainState(params=_tree()["params"],
                       opt_state={"step": jnp.asarray(3, jnp.int32)},
                       ef={})
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state)
    step, got = mgr.restore(state)
    assert step == 1 and isinstance(got, TrainState)
    assert got.ef == {}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dataclass_roundtrip_ef_tree(tmp_path):
    """The EF residual tree — leaves with a leading (dp,) member axis —
    survives save/restore bit-exactly (compressed-resume correctness)."""
    from repro.train.trainer import TrainState

    rng = np.random.default_rng(0)
    ef = {"layers": [{"w": rng.standard_normal((4, 8, 8)).astype(np.float32)}
                     for _ in range(2)]}
    state = TrainState(params=_tree()["params"],
                       opt_state={"step": jnp.asarray(9, jnp.int32)},
                       ef=ef)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, state)
    _, got = mgr.restore(state)
    assert isinstance(got, TrainState)
    for lay_a, lay_b in zip(ef["layers"], got.ef["layers"]):
        np.testing.assert_array_equal(lay_a["w"], lay_b["w"])
        assert lay_b["w"].shape[0] == 4


def test_restore_with_shardings(tmp_path):
    """Elastic restore: restore onto explicit (1-device) shardings."""
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(3, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(
        lambda _: jax.NamedSharding(mesh, jax.sharding.PartitionSpec()), tree)
    step, got = mgr.restore(tree, shardings=sh)
    assert step == 3
    leaf = jax.tree.leaves(got)[0]
    assert leaf.sharding.mesh.shape == {"data": 1}
