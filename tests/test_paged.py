"""Paged KV cache: the paged gather reassembles EXACTLY the contiguous
storage over ragged lengths and block boundaries (hypothesis sweeps, at
the layout level and through ``attn_decode``), the BlockAllocator holds
its refcount invariants (a shared block is released exactly once when the
last holder retires; no reuse-after-free), the paged + prefix-shared +
chunked-prefill engine emits bit-identical greedy tokens to the
contiguous scheduler, and ``SamplingParams`` resolution / per-request
sampling streams are scheduler-invariant."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.policy import QuantPolicy
from repro.models import lm, registry
from repro.nn import attention as attn_lib
from repro.nn.common import QCtx
from repro.serve.engine import (BlockAllocator, Engine, EngineConfig,
                                Request, SamplingParams, Scheduler,
                                resolve_sampling)

# ---------------------------------------------------------------------------
# layout equivalence
# ---------------------------------------------------------------------------

_ACFG = attn_lib.AttnConfig(d_model=8, n_heads=2, n_kv_heads=2, d_head=4)
_CTX = QCtx(policy=QuantPolicy.full_precision(), compute_dtype=jnp.float32)


def _identity_table(b, bps):
    """The trivial allocator assignment: slot r owns blocks r*bps..+bps."""
    return jnp.arange(b * bps, dtype=jnp.int32).reshape(b, bps)


def _fill_both(rng, b, cache_len, bs, lens, n_decode):
    """Prefill-style ragged fill + ``n_decode`` decode-style width-1 fills
    applied identically to both layouts; returns (contiguous, paged_kv,
    paged_cache)."""
    kvh, dh = _ACFG.n_kv_heads, _ACFG.d_head
    cont = attn_lib.CONTIGUOUS.init(b, _ACFG, cache_len, jnp.float32)
    pkv = attn_lib.PagedKVCache(block_size=bs)
    paged = pkv.init(b, _ACFG, cache_len, jnp.float32)
    paged = {**paged, "table": _identity_table(b, cache_len // bs)}

    ar = np.arange(cache_len)[None, :]
    pos = np.where(ar < np.asarray(lens)[:, None], ar, -1).astype(np.int32)
    k = rng.standard_normal((b, cache_len, kvh, dh)).astype(np.float32)
    v = rng.standard_normal((b, cache_len, kvh, dh)).astype(np.float32)
    cont = attn_lib.CONTIGUOUS.fill(cont, jnp.asarray(k), jnp.asarray(v),
                                    jnp.asarray(pos))
    paged = pkv.fill(paged, jnp.asarray(k), jnp.asarray(v), jnp.asarray(pos))

    assert all(ln + n_decode <= cache_len for ln in lens)
    cur = np.asarray(lens, np.int32)
    for _ in range(n_decode):
        dpos = cur[:, None].astype(np.int32)
        k1 = rng.standard_normal((b, 1, kvh, dh)).astype(np.float32)
        v1 = rng.standard_normal((b, 1, kvh, dh)).astype(np.float32)
        cont = attn_lib.CONTIGUOUS.fill(
            cont, jnp.asarray(k1), jnp.asarray(v1), jnp.asarray(dpos))
        paged = pkv.fill(paged, jnp.asarray(k1), jnp.asarray(v1),
                         jnp.asarray(dpos))
        cur = cur + 1
    return cont, pkv, paged


@settings(max_examples=25, deadline=None)
@given(
    bs=st.sampled_from([2, 4, 8]), bps=st.integers(1, 4),
    b=st.integers(1, 3), s1=st.integers(0, 31), s2=st.integers(0, 31),
    s3=st.integers(0, 31), n_dec=st.integers(0, 9),
)
def test_paged_gather_matches_contiguous(bs, bps, b, s1, s2, s3, n_dec):
    """The dense view ``gather`` reassembles from the block pool is
    value-identical to the contiguous layout's storage: same position
    rows, same k/v at every visible position — across block sizes, ragged
    lengths, block-boundary-crossing fills and decode appends."""
    cache_len = bs * bps
    lens = [s % (cache_len + 1) for s in (s1, s2, s3)][:b]
    n_dec = min(n_dec, cache_len - max(lens))
    rng = np.random.default_rng(bs * 1000 + bps * 100 + b + s1 + s2)
    cont, pkv, paged = _fill_both(rng, b, cache_len, bs, lens, n_dec)
    ck, cv, cpos = attn_lib.CONTIGUOUS.gather(cont)
    pk, pv, ppos = pkv.gather(paged)
    np.testing.assert_array_equal(np.asarray(cpos), np.asarray(ppos))
    vis = np.asarray(cpos) >= 0
    np.testing.assert_array_equal(np.asarray(ck)[vis], np.asarray(pk)[vis])
    np.testing.assert_array_equal(np.asarray(cv)[vis], np.asarray(pv)[vis])


_ATTN_PARAMS = {}


@settings(max_examples=10, deadline=None)
@given(
    bs=st.sampled_from([2, 4]), bps=st.integers(2, 4),
    l1=st.integers(1, 7), l2=st.integers(0, 7),
)
def test_paged_attn_decode_bit_identical(bs, bps, l1, l2):
    """One decode step through ``attn_decode`` on the two layouts (same
    ragged fills) produces BIT-identical outputs: the -1 rows mask to
    exactly-zero softmax weights, so the junk the contiguous layout keeps
    beyond each prompt (vs the paged pool's zeros) never contributes."""
    cache_len = bs * bps
    lens = [min(l1, cache_len - 1), min(l2, cache_len - 1)]
    if "p" not in _ATTN_PARAMS:
        _ATTN_PARAMS["p"] = attn_lib.attn_init(jax.random.PRNGKey(1), _ACFG,
                                               dtype=jnp.float32)
    params = _ATTN_PARAMS["p"]
    rng = np.random.default_rng(bs * 100 + bps * 10 + l1 + l2)
    cont, pkv, paged = _fill_both(rng, 2, cache_len, bs, lens, 0)
    x = jnp.asarray(rng.standard_normal((2, 1, _ACFG.d_model)),
                    jnp.float32)
    pos = jnp.asarray(lens, jnp.int32)
    out_c, _ = attn_lib.attn_decode(params, x, pos, cont, _ACFG, _CTX,
                                    "t.attn", kv=attn_lib.CONTIGUOUS)
    out_p, _ = attn_lib.attn_decode(params, x, pos, paged, _ACFG, _CTX,
                                    "t.attn", kv=pkv)
    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_p))


def test_paged_write_mask_drops_junk_writes():
    """A masked-out row's decode write lands NOWHERE in the pool — the
    invariant retirement relies on, since a retired slot's blocks may
    already belong to another request."""
    pkv = attn_lib.PagedKVCache(block_size=2)
    paged = pkv.init(2, _ACFG, 4, jnp.float32)
    paged = {**paged, "table": _identity_table(2, 2)}
    k = jnp.ones((2, 1, _ACFG.n_kv_heads, _ACFG.d_head), jnp.float32)
    pos = jnp.asarray([[0], [0]], jnp.int32)
    out = pkv.fill(paged, k, k, pos,
                   write_mask=jnp.asarray([True, False]))
    assert np.asarray(out["pool_pos"])[0, 0] == 0
    # row 1's write was dropped: its blocks (2, 3) stay empty
    assert (np.asarray(out["pool_pos"])[2:] == -1).all()
    assert (np.asarray(out["pool_k"])[2:] == 0).all()


# ---------------------------------------------------------------------------
# BlockAllocator refcounts
# ---------------------------------------------------------------------------


def test_allocator_shared_block_released_exactly_once():
    """A shared block survives until its LAST holder releases it, retires
    into the cached state (registered hash retained), and a further
    release raises instead of corrupting the free list."""
    al = BlockAllocator(4, 2)
    blk = al.alloc()
    al.register(blk, "h")
    assert al.lookup("h") == blk  # second holder: rc 2
    al.release(blk)
    assert al.live_blocks == 1  # first release: still held
    assert blk not in al.free
    al.release(blk)  # last holder retires
    assert al.live_blocks == 0
    assert blk in al.cached and blk not in al.free  # contents retained
    with pytest.raises(RuntimeError, match="double release"):
        al.release(blk)
    assert al.lookup("h") == blk  # revived from cached, rc 1 again
    al.release(blk)
    # an UNregistered block frees straight back to the free list
    b2 = al.alloc()
    al.release(b2)
    assert b2 in al.free and b2 not in al.cached


def test_allocator_no_reuse_after_free():
    """Active blocks are never handed out again; eviction of a cached
    block unpublishes its hash so a later lookup cannot resurrect it."""
    al = BlockAllocator(2, 2)
    a, b = al.alloc(), al.alloc()
    with pytest.raises(RuntimeError, match="exhausted"):
        al.alloc()  # both active: allocation must fail, not recycle
    al.register(a, "h")
    al.release(a)  # a: cached
    c = al.alloc()  # must evict a, NOT touch the still-active b
    assert c == a
    assert al.lookup("h") is None  # evicted hash is gone
    assert b not in al.free and b not in al.cached  # b still active


# ---------------------------------------------------------------------------
# engine equivalence + sampling
# ---------------------------------------------------------------------------

_STATE: dict = {}


def _engine(batch, max_new=6, cache_len=32, **ecfg_kw):
    key = (batch, max_new, cache_len, tuple(sorted(ecfg_kw.items())))
    if key not in _STATE:
        if "params" not in _STATE:
            spec = registry.get("granite-3-2b")
            _STATE["spec"], _STATE["cfg"] = spec, spec.smoke
            _STATE["ctx"] = QCtx(policy=QuantPolicy.full_precision(),
                                 compute_dtype=jnp.float32)
            _STATE["params"] = lm.init(jax.random.PRNGKey(0), spec.smoke)
        _STATE[key] = Engine(
            _STATE["spec"], _STATE["cfg"], _STATE["ctx"], _STATE["params"],
            EngineConfig(batch=batch, cache_len=cache_len,
                         max_new_tokens=max_new, **ecfg_kw))
    return _STATE[key]


def _run(eng, prompts, **req_kw):
    sched = Scheduler(eng)
    for p in prompts:
        sched.submit(Request(prompt=p, **req_kw))
    return sched.run(), sched


def test_paged_engine_matches_contiguous_greedy():
    """Ragged prompts through the paged + chunked + prefix-shared
    scheduler = bit-identical greedy streams to the contiguous scheduler;
    identical-prefix requests reuse blocks and the allocator drains to
    zero live blocks (every block released exactly once)."""
    cfg = _engine(2).cfg
    rng = np.random.default_rng(11)
    prefix = rng.integers(0, cfg.vocab_size, (9,)).astype(np.int32)
    prompts = [np.concatenate(
        [prefix, rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)])
        for n in (6, 2, 11, 4)]

    base, _ = _run(_engine(2), prompts)
    paged, sched = _run(
        _engine(2, kv_block_size=4, prefill_chunk=5, shared_prefix=True),
        prompts)
    for rid in base:
        np.testing.assert_array_equal(base[rid], paged[rid])
    # the first TWO requests admit together (nothing registered yet); the
    # last two each reuse the full-block prefix: 2 * (9 // 4) blocks
    assert sched.stats.shared_tokens == 2 * (9 // 4) * 4
    assert sched.stats.prefill_tokens == (
        sum(len(p) for p in prompts) - sched.stats.shared_tokens)
    assert sched.alloc.live_blocks == 0


def test_paged_engine_validation():
    eng = _engine(1)  # warm the cached params
    spec, cfg, ctx, params = (_STATE["spec"], _STATE["cfg"], _STATE["ctx"],
                              _STATE["params"])
    with pytest.raises(ValueError, match="not a multiple"):
        Engine(spec, cfg, ctx, params,
               EngineConfig(batch=1, cache_len=30, kv_block_size=4))
    hybrid = dataclasses.replace(cfg, mixer_pattern=("local_attn", "attn"))
    with pytest.raises(ValueError, match="pure-'attn'"):
        Engine(spec, hybrid, ctx, params,
               EngineConfig(batch=1, cache_len=32, kv_block_size=4))
    assert eng.paged is False


def test_resolve_sampling_precedence():
    """request.sampling > request legacy fields > EngineConfig.sampling >
    EngineConfig legacy fields."""
    ecfg = EngineConfig(batch=1, cache_len=32, max_new_tokens=7,
                        temperature=0.5, seed=3, eos_id=9,
                        sampling=SamplingParams(temperature=0.25,
                                                min_tokens=2))
    sp = resolve_sampling(Request(prompt=np.zeros(3, np.int32)), ecfg)
    assert sp == SamplingParams(0.25, 3, 9, 2, 7)
    r = Request(prompt=np.zeros(3, np.int32), eos_id=4, max_new_tokens=2,
                sampling=SamplingParams(temperature=0.0, seed=11))
    assert resolve_sampling(r, ecfg) == SamplingParams(0.0, 11, 4, 2, 2)


def test_sampled_streams_are_scheduler_invariant():
    """temperature > 0: a request's sampled stream depends only on its
    (seed, rid) — NOT on batchmates, slot, or the contiguous/paged loop —
    because every row draws from fold_in(fold_in(key(seed), rid),
    n_emitted)."""
    cfg = _engine(1).cfg
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, (5,)).astype(np.int32)
               for _ in range(3)]
    # high temperature: random-init logits are peaked enough that mild
    # temperatures still sample argmax every step, which would make the
    # different-seed check vacuous
    sp = SamplingParams(temperature=8.0, seed=21)

    solo, _ = _run(_engine(1), prompts[:1], sampling=sp)
    batched, _ = _run(_engine(2), prompts, sampling=sp)
    np.testing.assert_array_equal(solo[0], batched[0])

    pg, _ = _run(_engine(2, kv_block_size=4, prefill_chunk=3,
                         shared_prefix=True), prompts, sampling=sp)
    np.testing.assert_array_equal(solo[0], pg[0])

    other, _ = _run(_engine(1), prompts[:1],
                    sampling=SamplingParams(temperature=8.0, seed=22))
    assert not np.array_equal(solo[0], other[0])  # seed actually matters
