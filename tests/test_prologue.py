"""The dispatch-owned fused activation prologue (PrologueSpec + the
quantize->pack Pallas kernel family in kernels/pack_bits.py):

* bit-identity of the fused kernels against the jnp reference
  (``bitpack.pack_sign`` / ``quant.act_codes`` -> ``bitpack.pack_planes``),
  hypothesis-swept over odd ``k_true`` values,
* pad bits zero in both operands (the exactness precondition),
* GemmConfig.interpret reaching the pack kernels (the kernels used to
  hard-default to interpret mode),
* prologue resolution per backend (``Backend.prologue`` declarations),
* the grouped route-first rule (capacity-dropped rows are never packed),
* GemmConfig.capacity_factor reaching the MoE EP path,
* the select_tiles autotuning cache.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import bitpack, converter, quant
from repro.core.policy import QuantPolicy, QuantSpec
from repro.kernels import dispatch, ref
from repro.kernels.dispatch import GemmConfig, PrologueSpec


def _acts(seed, m, k):
    return jax.random.normal(jax.random.PRNGKey(seed), (m, k), jnp.float32)


# ---------------------------------------------------------------------------
# fused == jnp reference, bit for bit
# ---------------------------------------------------------------------------


@settings(max_examples=16, deadline=None)
@given(k_true=st.integers(min_value=1, max_value=300),
       m=st.integers(min_value=1, max_value=40))
def test_fused_sign_pack_matches_jnp(k_true, m):
    """Odd shapes, word tails, tiny K: the fused 1-bit pack is
    bit-identical to bitpack.pack_sign."""
    x = _acts(k_true * 31 + m, m, k_true)
    want = np.asarray(bitpack.pack_sign(x))
    got = np.asarray(dispatch.pack_activations(x, use_pallas=True))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=12, deadline=None)
@given(k_true=st.integers(min_value=1, max_value=300),
       a_bits=st.sampled_from([2, 4, 8]))
def test_fused_plane_pack_matches_jnp(k_true, a_bits):
    """The fused DoReFa quantize->plane-pack emits the SAME plane stack
    AND the same code row-sums as the jnp act_codes -> pack_planes round
    trip, at any odd k_true."""
    x = _acts(k_true * 13 + a_bits, 7, k_true)
    codes = quant.act_codes(x, a_bits)
    want_p = np.asarray(bitpack.pack_planes(codes, a_bits))
    want_t = np.asarray(codes.astype(jnp.int32).sum(-1))
    got_p, got_t = dispatch.pack_act_planes(x, a_bits, fused=True)
    np.testing.assert_array_equal(np.asarray(got_p), want_p)
    np.testing.assert_array_equal(np.asarray(got_t)[:, 0], want_t)


def test_pad_bits_zero_in_packed_tail():
    """K tails beyond k_true must pack to 0 bits in every output word of
    both prologue forms — the precondition for exactness without pad
    correction (1-bit pads match; k-bit pads AND to nothing)."""
    k_true = 40  # Kw = 2, 24 tail bits in the last word
    x = jnp.abs(_acts(3, 5, k_true)) + 1.0  # all positive: every bit 1
    packed = np.asarray(dispatch.pack_activations(x, use_pallas=True))
    assert (packed[:, -1] >> 8 == 0).all()  # bits 8..31 of word 1 are pad
    planes, _ = dispatch.pack_act_planes(x, 4, fused=True)
    planes = np.asarray(planes)
    assert (planes[:, :, -1] >> 8 == 0).all()
    # and the valid region is NOT all zero (the mask is real)
    assert packed.any() and planes.any()


@pytest.mark.parametrize("use_fused", [True, False])
def test_quant_gemm_identical_across_prologues(use_fused):
    """quant_gemm output is invariant to PrologueSpec.fused (1-bit and
    k-bit) — the fused kernels are drop-in."""
    m, k, n = 9, 70, 11
    x = _acts(0, m, k)
    w = _acts(1, n, k).T
    wp = bitpack.pack_sign(w.T)
    cfg = GemmConfig(backend="vpu", fused_prologue=use_fused)
    got = dispatch.quant_gemm(x, wp, k_true=k, config=cfg)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.sign_gemm_ref(x, w)))
    wkp = bitpack.pack_planes(quant.weight_codes(w.T, 4), 4)
    got4 = dispatch.quant_gemm(x, wkp, k_true=k, config=cfg,
                               w_bits=4, a_bits=4)
    want4 = dispatch.quant_gemm(
        x, wkp, k_true=k, config=GemmConfig(backend="vpu"),
        w_bits=4, a_bits=4)
    np.testing.assert_array_equal(np.asarray(got4), np.asarray(want4))


def test_prologue_spec_overrides_config():
    """An explicit PrologueSpec wins over GemmConfig.fused_prologue and
    still produces identical results (it is threaded into the config so
    shard bodies see it too)."""
    m, k, n = 5, 45, 7
    x = _acts(5, m, k)
    wp = bitpack.pack_sign(_acts(6, n, k))
    base = np.asarray(dispatch.quant_gemm(x, wp, k_true=k))
    got = np.asarray(dispatch.quant_gemm(
        x, wp, k_true=k,
        prologue=PrologueSpec(kind="pack_sign", fused=False)))
    np.testing.assert_array_equal(got, base)


# ---------------------------------------------------------------------------
# interpret threading: the pack kernels honor GemmConfig.interpret
# ---------------------------------------------------------------------------


def test_pack_kernels_honor_interpret_flag(monkeypatch):
    """GemmConfig.interpret must reach the prologue pallas_call like it
    reaches the GEMM kernels — the env default must NOT win when the
    config is explicit (the pack kernels used to hard-default to
    interpret=True)."""
    seen = {}
    real = dispatch.pack_sign_pallas

    def spy(x, **kw):
        seen["interpret"] = kw.get("interpret")
        return real(x, **kw)

    monkeypatch.setattr(dispatch, "pack_sign_pallas", spy)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")  # env says compile
    m, k, n = 3, 51, 4  # unique shape: the jit caches cannot satisfy this
    x = _acts(7, m, k)
    wp = bitpack.pack_sign(_acts(8, n, k))
    got = dispatch.quant_gemm(
        x, wp, k_true=k,
        config=GemmConfig(backend="vpu", interpret=True))
    assert seen["interpret"] is True  # config won over the env's False
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(ref.xnor_gemm_ref(bitpack.pack_sign(x), wp, k)))


def test_pack_sign_pallas_default_reads_env(monkeypatch):
    """interpret=None resolves REPRO_PALLAS_INTERPRET instead of a
    hardcoded True (the satellite fix)."""
    from repro.kernels import pack_bits

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    x = jnp.ones((8, 8 * 32), jnp.float32)
    out = pack_bits.pack_sign_pallas(x, bm=8, bkw=8)  # interpret unset
    assert np.asarray(out).shape == (8, 8)
    assert (np.asarray(out) == np.uint32(0xFFFFFFFF)).all()


# ---------------------------------------------------------------------------
# PrologueSpec resolution (Backend.prologue declarations)
# ---------------------------------------------------------------------------


def test_resolve_prologue_per_backend():
    assert dispatch.resolve_prologue("vpu", 1, 1).kind == "pack_sign"
    assert dispatch.resolve_prologue("mxu", 1, 1).kind == "pack_sign"
    assert dispatch.resolve_prologue("xla", 1, 1).kind == "float"
    assert dispatch.resolve_prologue("vpu", 4, 4).kind == "pack_planes"
    assert dispatch.resolve_prologue("xla", 4, 4).kind == "float"
    assert dispatch.resolve_prologue("vpu", 5, 5).kind == "float"  # xla fb
    sh = dispatch.resolve_prologue("shard-vpu", 1, 1)
    assert sh.kind == "pack_sign" and sh.local  # packs inside shard_map
    shn = dispatch.resolve_prologue(
        "shard-vpu", 1, 1, GemmConfig(backend="shard-vpu",
                                      shard_layout="n"))
    assert not shn.local  # "n" packs once and broadcasts
    shk = dispatch.resolve_prologue("shard-vpu", 4, 4)
    assert shk.kind == "pack_planes" and shk.local


def test_prologue_from_spec_layer_path():
    spec = QuantSpec(w_bits=4, a_bits=4)
    p = dispatch.prologue_from_spec(spec, config=GemmConfig(backend="vpu"))
    assert p == PrologueSpec(kind="pack_planes", a_bits=4, fused=True,
                             local=False)
    p2 = dispatch.prologue_from_spec(
        spec, config=GemmConfig(backend="vpu", fused_prologue=False))
    assert not p2.fused


def test_qdense_packed_builds_prologue():
    """The layer path threads a PrologueSpec through QuantGemmCall and
    stays bit-exact with the train path."""
    from repro.core import qlayers

    key = jax.random.PRNGKey(0)
    p = qlayers.dense_init(key, 96, 24)
    x = jax.random.normal(jax.random.PRNGKey(1), (7, 96))
    spec = QuantSpec(w_bits=1, a_bits=1)
    pol = QuantPolicy(w_bits=1, a_bits=1)
    y_train = qlayers.qdense(p, x, spec, compute_dtype=jnp.float32)
    packed, _ = converter.convert({"l": p}, pol)
    for fused in (True, False):
        y_packed = qlayers.qdense(
            packed["l"], x, spec, compute_dtype=jnp.float32,
            gemm_config=GemmConfig(backend="vpu", fused_prologue=fused))
        np.testing.assert_allclose(np.asarray(y_train),
                                   np.asarray(y_packed),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# grouped route-first rule: capacity-dropped rows are never packed
# ---------------------------------------------------------------------------


def test_grouped_capacity_packs_only_bucket_rows(monkeypatch):
    """With a bounding expert_capacity the prologue packs the (E, ec)
    bucket rows — NOT the T sorted rows — so dropped rows never reach the
    pack kernel; without a bound the T rows pack once."""
    rows_seen = []
    real = dispatch.pack_activations

    def spy(x, **kw):
        rows_seen.append(x.shape[0])
        return real(x, **kw)

    monkeypatch.setattr(dispatch, "pack_activations", spy)
    t, k, e, n, ec = 12, 40, 3, 5, 2
    x = _acts(11, t, k)
    w = jax.random.normal(jax.random.PRNGKey(12), (e, n, k), jnp.float32)
    gs = jnp.asarray([6, 3, 3], jnp.int32)
    got = dispatch.quant_gemm_grouped(
        x, bitpack.pack_sign(w), gs, k_true=k,
        config=GemmConfig(backend="vpu"), expert_capacity=ec)
    assert rows_seen == [e * ec]
    rows_seen.clear()
    dispatch.quant_gemm_grouped(
        x, bitpack.pack_sign(w), gs, k_true=k,
        config=GemmConfig(backend="vpu"))
    assert rows_seen == [t]
    # and capacity semantics are unchanged (matches the xla oracle)
    want = dispatch.quant_gemm_grouped(
        x, bitpack.pack_sign(w), gs, k_true=k,
        config=GemmConfig(backend="xla"), expert_capacity=ec)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_grouped_kbit_capacity_matches_oracle():
    """k-bit bucket-packed prologue (route first) against the xla dequant
    oracle, with drops."""
    t, k, e, n, ec, bits = 10, 33, 3, 4, 2, 4
    x = _acts(13, t, k)
    w = jax.random.normal(jax.random.PRNGKey(14), (e, n, k), jnp.float32)
    wp = jnp.moveaxis(
        bitpack.pack_planes(quant.weight_codes(w, bits), bits), 0, 1)
    gs = jnp.asarray([5, 2, 3], jnp.int32)
    got = dispatch.quant_gemm_grouped(
        x, wp, gs, k_true=k, config=GemmConfig(backend="vpu"),
        w_bits=bits, a_bits=bits, expert_capacity=ec)
    want = dispatch.quant_gemm_grouped(
        x, wp, gs, k_true=k, config=GemmConfig(backend="xla"),
        w_bits=bits, a_bits=bits, expert_capacity=ec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# capacity_factor wiring (MoE EP path)
# ---------------------------------------------------------------------------


def test_capacity_factor_reaches_ep_path(mesh_factory, monkeypatch):
    from repro.nn import mlp
    from repro.nn.common import QCtx

    mesh = mesh_factory(2)
    caps = []
    real = mlp._moe_compute_local

    def spy(*args):
        caps.append(args[-1])
        return real(*args)

    monkeypatch.setattr(mlp, "_moe_compute_local", spy)
    cfg = mlp.MoEConfig(d_model=32, d_expert=16, n_routed=4, n_shared=0,
                        top_k=2)
    params = mlp.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
    t = 2 * 32  # t * top_k = 128 > the 64-row floor: the factor is visible

    def run(gc):
        ctx = QCtx(policy=QuantPolicy.binary(), compute_dtype=jnp.float32,
                   gemm_config=gc, mesh=mesh)
        return mlp.moe_apply(params, x, cfg, ctx, "layers/0/moe")

    y_def, _ = run(GemmConfig(backend="vpu"))
    assert caps[-1] == min(max(2 * t * cfg.top_k // 2, 64), t * cfg.top_k)
    y_2x, _ = run(GemmConfig(backend="vpu", capacity_factor=2.0))
    assert caps[-1] == caps[0]  # explicit 2.0 == the default
    np.testing.assert_array_equal(np.asarray(y_def), np.asarray(y_2x))
    run(GemmConfig(backend="vpu", capacity_factor=0.5))
    assert caps[-1] == min(max(int(0.5 * t * cfg.top_k) // 2, 64),
                           t * cfg.top_k)
    assert caps[-1] < caps[0]  # a tighter factor shrinks the bucket


# ---------------------------------------------------------------------------
# autotune cache over select_tiles
# ---------------------------------------------------------------------------


def test_autotune_cache_wins_over_heuristic(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TILE_CACHE", str(tmp_path / "tiles.json"))
    monkeypatch.setattr(dispatch, "_TUNED", None)  # fresh cache
    dispatch.select_tiles.cache_clear()
    m, n, kw = 6, 5, 3
    heur = dispatch.select_tiles(m, n, kw, "vpu")
    won = dispatch.autotune_tiles(m, n, kw, "vpu", iters=1)
    assert dispatch.select_tiles(m, n, kw, "vpu") == won
    # other shapes keep the heuristic table
    assert dispatch.select_tiles(64, 64, 64, "vpu") == dispatch.TileConfig(
        64, 64, 64, 8)
    # plane backends tune their OWN kernel (not the 1-bit down-resolution)
    import dataclasses as dc

    be4 = dispatch.get_backend("vpu-k4")
    spied = []

    def spy_kbit(a, b, tiles, cfg):
        spied.append(a.shape[0])
        return dispatch._vpu_kbit_gemm(a, b, tiles, cfg)

    monkeypatch.setitem(dispatch._REGISTRY, "vpu-k4",
                        dc.replace(be4, gemm_kbit=spy_kbit))
    won4 = dispatch.autotune_tiles(4, 4, 2, "vpu-k4", iters=1)
    assert spied and spied[0] == 4  # timed the 4-plane stacks
    assert dispatch.select_tiles(4, 4, 2, "vpu-k4") == won4
    # shard names are rejected (tiles are selected per shard)
    with pytest.raises(ValueError, match="PER-SHARD"):
        dispatch.autotune_tiles(m, n, kw, "shard-vpu")
    # persisted winners reload into a fresh process-level cache
    monkeypatch.setattr(dispatch, "_TUNED", None)
    dispatch.select_tiles.cache_clear()
    assert dispatch.select_tiles(m, n, kw, "vpu") == won
    # GEMMs through an autotuned shape stay exact
    x = _acts(21, m, kw * 32)
    w = jax.random.normal(jax.random.PRNGKey(22), (kw * 32, n), jnp.float32)
    got = dispatch.quant_gemm(x, bitpack.pack_sign(w.T), k_true=kw * 32,
                              config=GemmConfig(backend="vpu"))
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.sign_gemm_ref(x, w)))
    del heur
    monkeypatch.setattr(dispatch, "_TUNED", None)
    dispatch.select_tiles.cache_clear()
