"""Serving engine: generation works, and the packed (xnor) engine produces
IDENTICAL greedy generations to the fake-quant engine on the same binary
checkpoint — the end-to-end version of the paper's §2.2.2 invariant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import converter
from repro.core.policy import QuantPolicy
from repro.models import lm, registry
from repro.nn.common import QCtx
from repro.serve.engine import Engine, EngineConfig


def test_engine_generates():
    spec = registry.get("granite-3-2b")
    cfg = spec.smoke
    ctx = QCtx(policy=QuantPolicy.full_precision(), compute_dtype=jnp.float32)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(spec, cfg, ctx, params,
                 EngineConfig(batch=3, cache_len=64, max_new_tokens=8))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (3, 10)).astype(np.int32)
    out = eng.generate(prompts)
    assert out.shape == (3, 8)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


@pytest.mark.parametrize("backend", ["vpu", "xla"])
def test_packed_engine_matches_fakequant(backend):
    """Same binary checkpoint, two execution paths, identical greedy text."""
    spec = registry.get("deepseek-7b")
    cfg = spec.smoke
    policy = QuantPolicy.binary()
    params = lm.init(jax.random.PRNGKey(0), cfg)

    ctx_fq = QCtx(policy=policy, compute_dtype=jnp.float32)
    eng_fq = Engine(spec, cfg, ctx_fq, params,
                    EngineConfig(batch=2, cache_len=48, max_new_tokens=6))

    host = jax.tree.map(np.asarray, params)
    packed, rep = converter.convert(host, policy)
    assert rep.n_packed > 0
    packed = jax.tree.map(jnp.asarray, packed)
    ctx_pk = QCtx(policy=policy, compute_dtype=jnp.float32,
                  xnor_backend=backend)
    eng_pk = Engine(spec, cfg, ctx_pk, packed,
                    EngineConfig(batch=2, cache_len=48, max_new_tokens=6))

    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (2, 8)).astype(np.int32)
    out_fq = eng_fq.generate(prompts)
    out_pk = eng_pk.generate(prompts)
    np.testing.assert_array_equal(out_fq, out_pk)


def test_sharded_engine_matches_unsharded(mesh_factory):
    """Tensor-parallel packed serving: the same packed checkpoint served
    through the 'shard-vpu' backend on a 2-device mesh matches the
    single-device packed engine — identical greedy generations, and
    logits equal to fp rounding (the sharded GEMM's int32 partials psum
    exactly — tests/test_shard_gemm.py asserts bit-identity there — but
    XLA may repartition the surrounding FLOAT ops (fp lm_head, norms)
    across the mesh, reordering their accumulations by ~1 ulp)."""
    from repro.kernels.dispatch import GemmConfig

    mesh = mesh_factory(2)
    spec = registry.get("deepseek-7b")
    cfg = spec.smoke
    policy = QuantPolicy.binary()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    host = jax.tree.map(np.asarray, params)
    packed, rep = converter.convert(host, policy)
    assert rep.n_packed > 0
    packed = jax.tree.map(jnp.asarray, packed)

    ecfg = EngineConfig(batch=2, cache_len=48, max_new_tokens=6)
    ctx_1d = QCtx(policy=policy, compute_dtype=jnp.float32,
                  gemm_config=GemmConfig(backend="vpu"))
    eng_1d = Engine(spec, cfg, ctx_1d, packed, ecfg)

    ctx_sh = QCtx(policy=policy, compute_dtype=jnp.float32,
                  gemm_config=GemmConfig(backend="shard-vpu", mesh=mesh))
    assert ctx_sh.gemm_config.mesh is mesh
    eng_sh = Engine(spec, cfg, ctx_sh, packed, ecfg)

    prompts = np.random.default_rng(3).integers(
        0, cfg.vocab_size, (2, 8)).astype(np.int32)
    logits_1d, _ = eng_1d._prefill(packed, jnp.asarray(prompts))
    logits_sh, _ = eng_sh._prefill(packed, jnp.asarray(prompts))
    np.testing.assert_allclose(np.asarray(logits_1d),
                               np.asarray(logits_sh),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(eng_1d.generate(prompts),
                                  eng_sh.generate(prompts))


def test_engine_mesh_threads_into_shard_config(mesh_factory):
    """EngineConfig.mesh reaches a mesh-less shard gemm_config via the
    QCtx post-init threading (the launcher/engine wiring path), and — as
    the per-engine override — beats a mesh the QCtx already threaded in."""
    from repro.kernels.dispatch import GemmConfig

    mesh = mesh_factory(2)
    spec = registry.get("granite-3-2b")
    cfg = spec.smoke
    ctx = QCtx(policy=QuantPolicy.full_precision(),
               compute_dtype=jnp.float32)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(spec, cfg, ctx, params,
                 EngineConfig(batch=1, cache_len=32, max_new_tokens=2,
                              gemm_config=GemmConfig(backend="shard-vpu"),
                              mesh=mesh))
    assert eng.ctx.gemm_config.backend == "shard-vpu"
    assert eng.ctx.gemm_config.mesh is mesh
    out = eng.generate(np.zeros((1, 4), np.int32))
    assert out.shape == (1, 2)

    # ctx auto-threaded mesh_a into its shard config; the per-engine
    # EngineConfig.mesh must still win over it
    mesh_a = mesh_factory(1)
    ctx_a = QCtx(policy=QuantPolicy.full_precision(),
                 compute_dtype=jnp.float32, mesh=mesh_a,
                 gemm_config=GemmConfig(backend="shard-vpu"))
    assert ctx_a.gemm_config.mesh is mesh_a
    eng2 = Engine(spec, cfg, ctx_a, params,
                  EngineConfig(batch=1, cache_len=32, max_new_tokens=2,
                               mesh=mesh))
    assert eng2.ctx.gemm_config.mesh is mesh
    assert eng2.ctx.mesh is mesh


def test_continuous_positions_decode():
    """Per-batch positions: two sequences at different positions decode
    correctly (continuous batching property)."""
    spec = registry.get("granite-3-2b")
    cfg = spec.smoke
    ctx = QCtx(policy=QuantPolicy.full_precision(), compute_dtype=jnp.float32)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    full, _ = lm.forward(params, cfg, ctx, toks)

    # prefill seq0 with 9 tokens, seq1 with 6 (padded batch prefill of 6,
    # then 3 extra decode steps for seq0 only; we just check seq1's path)
    _, cache = lm.prefill(params, cfg, ctx, toks[:, :6], cache_len=16)
    pos = jnp.asarray([6, 6], jnp.int32)
    logits, cache = lm.decode_step(params, cfg, ctx, cache, toks[:, 6:7], pos)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, 6]), rtol=2e-3, atol=2e-3)
