"""Serving engine: generation works, and the packed (xnor) engine produces
IDENTICAL greedy generations to the fake-quant engine on the same binary
checkpoint — the end-to-end version of the paper's §2.2.2 invariant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import converter
from repro.core.policy import QuantPolicy
from repro.models import lm, registry
from repro.nn.common import QCtx
from repro.serve.engine import Engine, EngineConfig


def test_engine_generates():
    spec = registry.get("granite-3-2b")
    cfg = spec.smoke
    ctx = QCtx(policy=QuantPolicy.full_precision(), compute_dtype=jnp.float32)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(spec, cfg, ctx, params,
                 EngineConfig(batch=3, cache_len=64, max_new_tokens=8))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (3, 10)).astype(np.int32)
    out = eng.generate(prompts)
    assert out.shape == (3, 8)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


@pytest.mark.parametrize("backend", ["vpu", "xla"])
def test_packed_engine_matches_fakequant(backend):
    """Same binary checkpoint, two execution paths, identical greedy text."""
    spec = registry.get("deepseek-7b")
    cfg = spec.smoke
    policy = QuantPolicy.binary()
    params = lm.init(jax.random.PRNGKey(0), cfg)

    ctx_fq = QCtx(policy=policy, compute_dtype=jnp.float32)
    eng_fq = Engine(spec, cfg, ctx_fq, params,
                    EngineConfig(batch=2, cache_len=48, max_new_tokens=6))

    host = jax.tree.map(np.asarray, params)
    packed, rep = converter.convert(host, policy)
    assert rep.n_packed > 0
    packed = jax.tree.map(jnp.asarray, packed)
    ctx_pk = QCtx(policy=policy, compute_dtype=jnp.float32,
                  xnor_backend=backend)
    eng_pk = Engine(spec, cfg, ctx_pk, packed,
                    EngineConfig(batch=2, cache_len=48, max_new_tokens=6))

    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (2, 8)).astype(np.int32)
    out_fq = eng_fq.generate(prompts)
    out_pk = eng_pk.generate(prompts)
    np.testing.assert_array_equal(out_fq, out_pk)


def test_continuous_positions_decode():
    """Per-batch positions: two sequences at different positions decode
    correctly (continuous batching property)."""
    spec = registry.get("granite-3-2b")
    cfg = spec.smoke
    ctx = QCtx(policy=QuantPolicy.full_precision(), compute_dtype=jnp.float32)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    full, _ = lm.forward(params, cfg, ctx, toks)

    # prefill seq0 with 9 tokens, seq1 with 6 (padded batch prefill of 6,
    # then 3 extra decode steps for seq0 only; we just check seq1's path)
    _, cache = lm.prefill(params, cfg, ctx, toks[:, :6], cache_len=16)
    pos = jnp.asarray([6, 6], jnp.int32)
    logits, cache = lm.decode_step(params, cfg, ctx, cache, toks[:, 6:7], pos)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, 6]), rtol=2e-3, atol=2e-3)
