"""Serving engine: generation works, the packed (xnor) engine produces
IDENTICAL greedy generations to the fake-quant engine on the same binary
checkpoint — the end-to-end version of the paper's §2.2.2 invariant —
and the continuous-batching scheduler (slot recycling, per-request eos,
queue admission) emits exactly the tokens the per-request fixed-batch
path would."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import converter
from repro.core.policy import QuantPolicy
from repro.models import lm, registry
from repro.nn.common import QCtx
from repro.serve.engine import Engine, EngineConfig, Request, Scheduler


def test_engine_generates():
    spec = registry.get("granite-3-2b")
    cfg = spec.smoke
    ctx = QCtx(policy=QuantPolicy.full_precision(), compute_dtype=jnp.float32)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(spec, cfg, ctx, params,
                 EngineConfig(batch=3, cache_len=64, max_new_tokens=8))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (3, 10)).astype(np.int32)
    # the fixed-batch surface is deprecated in favour of Scheduler
    # requests; it must say so (stacklevel=2: the warning points here)
    with pytest.warns(DeprecationWarning, match="Scheduler"):
        out = eng.generate(prompts)
    assert out.shape == (3, 8)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


@pytest.mark.parametrize("backend", ["vpu", "xla"])
def test_packed_engine_matches_fakequant(backend):
    """Same binary checkpoint, two execution paths, identical greedy text."""
    spec = registry.get("deepseek-7b")
    cfg = spec.smoke
    policy = QuantPolicy.binary()
    params = lm.init(jax.random.PRNGKey(0), cfg)

    ctx_fq = QCtx(policy=policy, compute_dtype=jnp.float32)
    eng_fq = Engine(spec, cfg, ctx_fq, params,
                    EngineConfig(batch=2, cache_len=48, max_new_tokens=6))

    host = jax.tree.map(np.asarray, params)
    packed, rep = converter.convert(host, policy)
    assert rep.n_packed > 0
    packed = jax.tree.map(jnp.asarray, packed)
    ctx_pk = QCtx(policy=policy, compute_dtype=jnp.float32,
                  xnor_backend=backend)
    eng_pk = Engine(spec, cfg, ctx_pk, packed,
                    EngineConfig(batch=2, cache_len=48, max_new_tokens=6))

    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (2, 8)).astype(np.int32)
    out_fq = eng_fq.generate(prompts)
    out_pk = eng_pk.generate(prompts)
    np.testing.assert_array_equal(out_fq, out_pk)


def test_sharded_engine_matches_unsharded(mesh_factory):
    """Tensor-parallel packed serving: the same packed checkpoint served
    through the 'shard-vpu' backend on a 2-device mesh matches the
    single-device packed engine — identical greedy generations, and
    logits equal to fp rounding (the sharded GEMM's int32 partials psum
    exactly — tests/test_shard_gemm.py asserts bit-identity there — but
    XLA may repartition the surrounding FLOAT ops (fp lm_head, norms)
    across the mesh, reordering their accumulations by ~1 ulp)."""
    from repro.kernels.dispatch import GemmConfig

    mesh = mesh_factory(2)
    spec = registry.get("deepseek-7b")
    cfg = spec.smoke
    policy = QuantPolicy.binary()
    params = lm.init(jax.random.PRNGKey(0), cfg)
    host = jax.tree.map(np.asarray, params)
    packed, rep = converter.convert(host, policy)
    assert rep.n_packed > 0
    packed = jax.tree.map(jnp.asarray, packed)

    ecfg = EngineConfig(batch=2, cache_len=48, max_new_tokens=6)
    ctx_1d = QCtx(policy=policy, compute_dtype=jnp.float32,
                  gemm_config=GemmConfig(backend="vpu"))
    eng_1d = Engine(spec, cfg, ctx_1d, packed, ecfg)

    ctx_sh = QCtx(policy=policy, compute_dtype=jnp.float32,
                  gemm_config=GemmConfig(backend="shard-vpu", mesh=mesh))
    assert ctx_sh.gemm_config.mesh is mesh
    eng_sh = Engine(spec, cfg, ctx_sh, packed, ecfg)

    prompts = np.random.default_rng(3).integers(
        0, cfg.vocab_size, (2, 8)).astype(np.int32)
    logits_1d, _ = eng_1d._prefill(packed, jnp.asarray(prompts))
    logits_sh, _ = eng_sh._prefill(packed, jnp.asarray(prompts))
    np.testing.assert_allclose(np.asarray(logits_1d),
                               np.asarray(logits_sh),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(eng_1d.generate(prompts),
                                  eng_sh.generate(prompts))


def test_engine_mesh_threads_into_shard_config(mesh_factory):
    """EngineConfig.mesh reaches a mesh-less shard gemm_config via the
    QCtx post-init threading (the launcher/engine wiring path), and — as
    the per-engine override — beats a mesh the QCtx already threaded in."""
    from repro.kernels.dispatch import GemmConfig

    mesh = mesh_factory(2)
    spec = registry.get("granite-3-2b")
    cfg = spec.smoke
    ctx = QCtx(policy=QuantPolicy.full_precision(),
               compute_dtype=jnp.float32)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(spec, cfg, ctx, params,
                 EngineConfig(batch=1, cache_len=32, max_new_tokens=2,
                              gemm_config=GemmConfig(backend="shard-vpu"),
                              mesh=mesh))
    assert eng.ctx.gemm_config.backend == "shard-vpu"
    assert eng.ctx.gemm_config.mesh is mesh
    out = eng.generate(np.zeros((1, 4), np.int32))
    assert out.shape == (1, 2)

    # ctx auto-threaded mesh_a into its shard config; the per-engine
    # EngineConfig.mesh must still win over it
    mesh_a = mesh_factory(1)
    ctx_a = QCtx(policy=QuantPolicy.full_precision(),
                 compute_dtype=jnp.float32, mesh=mesh_a,
                 gemm_config=GemmConfig(backend="shard-vpu"))
    assert ctx_a.gemm_config.mesh is mesh_a
    eng2 = Engine(spec, cfg, ctx_a, params,
                  EngineConfig(batch=1, cache_len=32, max_new_tokens=2,
                               mesh=mesh))
    assert eng2.ctx.gemm_config.mesh is mesh
    assert eng2.ctx.mesh is mesh


# ---------------------------------------------------------------------------
# continuous-batching scheduler
# ---------------------------------------------------------------------------

_FP_STATE: dict = {}


def _fp_engine(batch, max_new=6, cache_len=32, **ecfg_kw):
    """Module-cached fp engines over shared granite-smoke params, so the
    scheduler tests (and every hypothesis example) reuse jit compiles."""
    key = (batch, max_new, cache_len, tuple(sorted(ecfg_kw.items())))
    if key not in _FP_STATE:
        if "params" not in _FP_STATE:
            spec = registry.get("granite-3-2b")
            _FP_STATE["spec"], _FP_STATE["cfg"] = spec, spec.smoke
            _FP_STATE["ctx"] = QCtx(policy=QuantPolicy.full_precision(),
                                    compute_dtype=jnp.float32)
            _FP_STATE["params"] = lm.init(jax.random.PRNGKey(0),
                                          spec.smoke)
        _FP_STATE[key] = Engine(
            _FP_STATE["spec"], _FP_STATE["cfg"], _FP_STATE["ctx"],
            _FP_STATE["params"],
            EngineConfig(batch=batch, cache_len=cache_len,
                         max_new_tokens=max_new, **ecfg_kw))
    return _FP_STATE[key]


def _solo_stream(prompt, max_new=6):
    """Per-request fixed-batch reference (batch=1 engine), cached."""
    key = ("solo", prompt.tobytes(), max_new)
    if key not in _FP_STATE:
        _FP_STATE[key] = _fp_engine(1, max_new).generate(prompt[None])[0]
    return _FP_STATE[key]


def _expected(full, eos_id, min_tokens):
    """The scheduler's retirement rule applied to a full-horizon stream."""
    if eos_id is not None:
        for idx, t in enumerate(full):
            if idx + 1 >= min_tokens and int(t) == int(eos_id):
                return full[:idx + 1]
    return full


def _prompt(rng, length):
    vocab = _FP_STATE["cfg"].vocab_size
    return rng.integers(0, vocab, (length,)).astype(np.int32)


def test_scheduler_slot_recycling():
    """Queue (4 requests) > slots (2): freed slots are reused by queued
    requests, same-length neighbours prefill as one group, and every
    recycled request's tokens equal its per-request fixed-batch run."""
    eng = _fp_engine(2)
    rng = np.random.default_rng(0)
    prompts = [_prompt(rng, length) for length in (4, 4, 7, 7)]
    sched = Scheduler(eng)
    for p in prompts:
        sched.submit(Request(prompt=p))
    results = sched.run()
    for rid, p in enumerate(prompts):
        np.testing.assert_array_equal(results[rid], _solo_stream(p))
    slots_used = [slot for _, slot in sched.stats.admissions]
    assert sorted(sched.stats.admissions) == [(0, 0), (1, 1), (2, 0),
                                              (3, 1)]
    assert len(slots_used) == 4 and set(slots_used) == {0, 1}
    assert sched.stats.prefills == 2  # (4,4) then (7,7) groups
    # both generations ran concurrently: 2 waves of (max_new - 1) steps
    assert sched.stats.steps == 2 * (6 - 1)


def test_scheduler_eos_early_exit():
    """A request retires the step it emits eos (budget untouched), the
    drained loop exits immediately, and min_tokens suppresses an earlier
    occurrence of the same token."""
    eng = _fp_engine(2)
    rng = np.random.default_rng(1)
    p = _prompt(rng, 5)
    full = _solo_stream(p)
    eos = int(full[2])

    sched = Scheduler(eng)
    rid = sched.submit(Request(prompt=p, eos_id=eos, min_tokens=3))
    res = sched.run()
    np.testing.assert_array_equal(res[rid], _expected(full, eos, 3))
    # early exit: only as many decode steps as emitted tokens need
    assert sched.stats.steps == len(res[rid]) - 1 < 5

    # same eos with min_tokens=0 may retire earlier, never later
    sched2 = Scheduler(eng)
    rid2 = sched2.submit(Request(prompt=p, eos_id=eos))
    res2 = sched2.run()
    np.testing.assert_array_equal(res2[rid2], _expected(full, eos, 0))
    assert len(res2[rid2]) <= len(res[rid])


@settings(max_examples=5, deadline=None)
@given(
    l1=st.integers(3, 8), l2=st.integers(3, 8), l3=st.integers(3, 8),
    e1=st.integers(1, 6), e2=st.integers(1, 6),
)
def test_scheduler_mixed_lengths_match_fixed(l1, l2, l3, e1, e2):
    """Hypothesis sweep: ragged prompt lengths + per-request eos positions
    — continuous-batching greedy output equals the per-request fixed-batch
    output for every request, through recycling and ragged admission."""
    eng = _fp_engine(2)
    rng = np.random.default_rng(l1 * 64 + l2 * 8 + l3)
    prompts = [_prompt(rng, length) for length in (l1, l2, l3)]
    streams = [_solo_stream(p) for p in prompts]
    eos_mins = [(int(streams[0][e1 - 1]), e1),
                (int(streams[1][e2 - 1]), e2),
                (None, 0)]
    sched = Scheduler(eng)
    for p, (eos, mn) in zip(prompts, eos_mins):
        sched.submit(Request(prompt=p, eos_id=eos, min_tokens=mn))
    results = sched.run()
    for rid, (full, (eos, mn)) in enumerate(zip(streams, eos_mins)):
        np.testing.assert_array_equal(results[rid],
                                      _expected(full, eos, mn))


def test_scheduler_zero_budget_and_rid_collision():
    """A max_new_tokens=0 request returns an EMPTY stream (the prefill
    token is not emitted), and a duplicate rid is rejected instead of
    silently overwriting another request's results."""
    eng = _fp_engine(2)
    rng = np.random.default_rng(5)
    p = _prompt(rng, 4)
    sched = Scheduler(eng)
    rid0 = sched.submit(Request(prompt=p, max_new_tokens=0))
    rid1 = sched.submit(Request(prompt=p))
    with pytest.raises(ValueError, match="duplicate rid"):
        sched.submit(Request(prompt=p, rid=rid0))
    results = sched.run()
    assert len(results[rid0]) == 0
    np.testing.assert_array_equal(results[rid1], _solo_stream(p))


def test_generate_eos_stops_early_and_pads():
    """EngineConfig.eos_id reaches the compat wrapper: rows stop the step
    they emit eos, pad with it, and the loop early-exits (fewer decode
    steps than the fixed horizon)."""
    rng = np.random.default_rng(2)
    p = _prompt(rng, 6)
    full = _solo_stream(p)
    eos = int(full[0])
    eng = _fp_engine(1, eos_id=eos)
    out = eng.generate(p[None])
    assert out.shape == (1, 6)
    assert (out == eos).all()  # one emitted token + eos padding
    # retired on the prefill token -> whole-loop early exit, zero decode
    # steps (the no-eos horizon would run max_new - 1 = 5)
    assert eng.last_stats.steps == 0


def test_generate_seed_reproducible():
    """EngineConfig.seed drives sampled decoding: same seed -> identical
    streams, different seed -> different streams, and the first token no
    longer reuses the step key (the PRNG satellite fix).

    Temperature is high because random-init logits are peaked: at low
    temperature every per-row key draws the argmax token, which keeps the
    same-seed check but makes the different-seed assertion vacuous."""
    rng = np.random.default_rng(3)
    prompts = np.stack([_prompt(rng, 5), _prompt(rng, 5)])
    out_a = _fp_engine(2, temperature=8.0, seed=5).generate(prompts)
    out_b = _fp_engine(2, temperature=8.0, seed=5).generate(prompts)
    out_c = _fp_engine(2, temperature=8.0, seed=6).generate(prompts)
    np.testing.assert_array_equal(out_a, out_b)
    assert not np.array_equal(out_a, out_c)


def test_whisper_scheduler_roundtrip():
    """Whisper through the scheduler: per-request ``frames`` prefill
    kwargs, cross+self cache insertion, and generate()-wrapper parity."""
    spec = registry.get("whisper-base")
    cfg = spec.smoke
    ctx = QCtx(policy=QuantPolicy.full_precision(), compute_dtype=jnp.float32)
    from repro.models import whisper
    params = whisper.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    frames = rng.standard_normal((2, cfg.t_enc, cfg.d_model)).astype(
        np.float32)
    prompts = rng.integers(0, cfg.vocab_size, (2, 5)).astype(np.int32)

    eng = Engine(spec, cfg, ctx, params,
                 EngineConfig(batch=2, cache_len=32, max_new_tokens=4))
    out = eng.generate(prompts, frames=frames)
    assert out.shape == (2, 4)

    eng1 = Engine(spec, cfg, ctx, params,
                  EngineConfig(batch=1, cache_len=32, max_new_tokens=4))
    for i in range(2):
        solo = eng1.generate(prompts[i][None], frames=frames[i][None])
        np.testing.assert_array_equal(out[i], solo[0])


def test_continuous_positions_decode():
    """Per-batch positions: two sequences at different positions decode
    correctly (continuous batching property)."""
    spec = registry.get("granite-3-2b")
    cfg = spec.smoke
    ctx = QCtx(policy=QuantPolicy.full_precision(), compute_dtype=jnp.float32)
    params = lm.init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    full, _ = lm.forward(params, cfg, ctx, toks)

    # prefill seq0 with 9 tokens, seq1 with 6 (padded batch prefill of 6,
    # then 3 extra decode steps for seq0 only; we just check seq1's path)
    _, cache = lm.prefill(params, cfg, ctx, toks[:, :6], cache_len=16)
    pos = jnp.asarray([6, 6], jnp.int32)
    logits, cache = lm.decode_step(params, cfg, ctx, cache, toks[:, 6:7], pos)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, 6]), rtol=2e-3, atol=2e-3)
