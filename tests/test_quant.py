"""Quantizer properties (paper Eq. 1/Eq. 2 + STE semantics), hypothesis-
driven."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import quant


@settings(max_examples=50, deadline=None)
@given(
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31),
)
def test_quantize_k_grid_and_range(k, seed):
    """Eq. 1: output lies on the k-bit grid in [0, 1]."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random(100), jnp.float32)
    q = np.asarray(quant.quantize_k(x, k))
    n = 2**k - 1
    np.testing.assert_allclose(q * n, np.round(q * n), atol=1e-4)
    assert (q >= 0).all() and (q <= 1).all()


@settings(max_examples=20, deadline=None)
@given(k=st.integers(1, 8), seed=st.integers(0, 2**31))
def test_quantize_k_idempotent(k, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random(64), jnp.float32)
    q1 = quant.quantize_k(x, k)
    q2 = quant.quantize_k(q1, k)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(k=st.integers(2, 8), seed=st.integers(0, 2**31))
def test_quantize_weight_range(k, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal(128) * 3, jnp.float32)
    q = np.asarray(quant.quantize_weight(w, k))
    assert (q >= -1 - 1e-5).all() and (q <= 1 + 1e-5).all()
    # monotone non-decreasing w.r.t. input ordering
    order = np.argsort(np.asarray(w))
    assert (np.diff(q[order]) >= -1e-6).all()


def test_sign_ste_values_and_grad():
    x = jnp.asarray([-2.0, -0.5, 0.0, 0.5, 2.0])
    s = np.asarray(quant.sign_ste(x))
    np.testing.assert_array_equal(s, [-1, -1, 1, 1, 1])
    g = jax.grad(lambda x: quant.sign_ste(x).sum())(x)
    # clipped STE: gradient only where |x| <= 1
    np.testing.assert_array_equal(np.asarray(g), [0, 1, 1, 1, 0])


def test_quantize_act_binary_is_sign():
    x = jnp.asarray([-3.0, -0.1, 0.0, 0.2])
    np.testing.assert_array_equal(
        np.asarray(quant.quantize_act(x, 1)), [-1, -1, 1, 1]
    )


def test_bits_32_identity():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(16), jnp.float32)
    np.testing.assert_array_equal(np.asarray(quant.quantize_act(x, 32)),
                                  np.asarray(x))
    np.testing.assert_array_equal(np.asarray(quant.quantize_weight(x, 32)),
                                  np.asarray(x))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 500), seed=st.integers(0, 2**31))
def test_eq2_roundtrip(n, seed):
    """Eq. 2 maps [-n, n] step 2 <-> [0, n] step 1, exactly."""
    rng = np.random.default_rng(seed)
    matches = rng.integers(0, n + 1, 50)
    dot = 2 * matches - n  # ±1 dot with n terms
    got = np.asarray(quant.xnor_range_map(jnp.asarray(dot, jnp.float32), n))
    np.testing.assert_array_equal(got, matches)
    back = np.asarray(quant.dot_range_map(jnp.asarray(matches, jnp.float32), n))
    np.testing.assert_array_equal(back, dot)


def test_dorefa_act_clip_range():
    x = jnp.asarray([-1.0, 0.3, 0.9, 2.0])
    q = np.asarray(quant.quantize_act(x, 2))
    assert q[0] == 0.0 and q[-1] == 1.0
    grid = np.round(q * 3) / 3
    np.testing.assert_allclose(q, grid, atol=1e-6)
