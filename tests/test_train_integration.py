"""Integration: training actually learns (fp and binary), microbatching is
consistent, remat doesn't change the math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import QuantPolicy
from repro.data import synthetic
from repro.models import registry
from repro.nn.common import QCtx
from repro.optim import adamw
from repro.train import trainer


def _run(quant, steps=60, arch="deepseek-7b", lr=6e-3):
    spec = registry.get(arch)
    cfg = spec.smoke
    pol = (QuantPolicy.binary() if quant == "binary"
           else QuantPolicy.full_precision())
    ctx = QCtx(policy=pol, compute_dtype=jnp.float32)
    opt = adamw.AdamWConfig(lr=lr, warmup_steps=5, total_steps=steps)
    params, opt_state = trainer.init_all(spec, cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(trainer.make_train_step(spec, cfg, ctx, opt,
                                              remat=False))
    dcfg = synthetic.DataConfig(cfg.vocab_size, seq_len=32, global_batch=16)
    losses = []
    for i in range(steps):
        params, opt_state, m = step_fn(params, opt_state,
                                       synthetic.batch_at(dcfg, i))
        losses.append(float(m["loss"]))
    return losses


def test_fp_training_learns():
    losses = _run("fp")
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 1.0, losses[-5:]


def test_binary_training_learns():
    """The BNN trains too (paper Table 1: binary accuracy close to fp)."""
    losses = _run("binary", steps=80)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses[-5:]


def test_microbatch_equivalence():
    """4 microbatches == single batch, same loss trajectory (fp32)."""
    spec = registry.get("granite-3-2b")
    cfg = spec.smoke
    ctx = QCtx(policy=QuantPolicy.full_precision(), compute_dtype=jnp.float32)
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    dcfg = synthetic.DataConfig(cfg.vocab_size, seq_len=16, global_batch=8)

    def run(micro):
        params, opt_state = trainer.init_all(spec, cfg, jax.random.PRNGKey(0))
        fn = jax.jit(trainer.make_train_step(spec, cfg, ctx, opt,
                                             remat=False, microbatch=micro))
        out = []
        for i in range(3):
            params, opt_state, m = fn(params, opt_state,
                                      synthetic.batch_at(dcfg, i))
            out.append(float(m["loss"]))
        return out

    # CE is per-token mean; microbatches have equal token counts
    np.testing.assert_allclose(run(None), run(4), rtol=2e-3)


def test_remat_matches_no_remat():
    spec = registry.get("deepseek-7b")
    cfg = spec.smoke
    ctx = QCtx(policy=QuantPolicy.full_precision(), compute_dtype=jnp.float32)
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    dcfg = synthetic.DataConfig(cfg.vocab_size, seq_len=16, global_batch=4)
    batch = synthetic.batch_at(dcfg, 0)

    outs = []
    for remat in (False, True):
        params, opt_state = trainer.init_all(spec, cfg, jax.random.PRNGKey(0))
        fn = jax.jit(trainer.make_train_step(spec, cfg, ctx, opt, remat=remat))
        _, _, m = fn(params, opt_state, batch)
        outs.append(float(m["loss"]))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5)


@pytest.mark.parametrize("kbits", [2, 4])
def test_kbit_quantized_training_learns(kbits):
    """DoReFa path (paper §2.1, 2<=k<=31) also trains."""
    spec = registry.get("deepseek-7b")
    cfg = spec.smoke
    ctx = QCtx(policy=QuantPolicy.quantized(kbits), compute_dtype=jnp.float32)
    opt = adamw.AdamWConfig(lr=6e-3, warmup_steps=5, total_steps=50)
    params, opt_state = trainer.init_all(spec, cfg, jax.random.PRNGKey(0))
    fn = jax.jit(trainer.make_train_step(spec, cfg, ctx, opt, remat=False))
    dcfg = synthetic.DataConfig(cfg.vocab_size, seq_len=32, global_batch=16)
    losses = []
    for i in range(50):
        params, opt_state, m = fn(params, opt_state, synthetic.batch_at(dcfg, i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5
