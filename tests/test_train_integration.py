"""Integration: training actually learns (fp and binary), microbatching is
consistent (including aux metrics), remat doesn't change the math, and the
sharded DP step is bit-identical to the single-device step (uncompressed)
or still learns (1-bit EF compressed)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import QuantPolicy
from repro.data import synthetic
from repro.models import registry
from repro.nn.common import QCtx
from repro.optim import adamw
from repro.train import trainer


def _run(quant, steps=60, arch="deepseek-7b", lr=6e-3):
    spec = registry.get(arch)
    cfg = spec.smoke
    pol = (QuantPolicy.binary() if quant == "binary"
           else QuantPolicy.full_precision())
    ctx = QCtx(policy=pol, compute_dtype=jnp.float32)
    opt = adamw.AdamWConfig(lr=lr, warmup_steps=5, total_steps=steps)
    params, opt_state = trainer.init_all(spec, cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(trainer.make_train_step(spec, cfg, ctx, opt,
                                              remat=False))
    dcfg = synthetic.DataConfig(cfg.vocab_size, seq_len=32, global_batch=16)
    losses = []
    for i in range(steps):
        params, opt_state, m = step_fn(params, opt_state,
                                       synthetic.batch_at(dcfg, i))
        losses.append(float(m["loss"]))
    return losses


def test_fp_training_learns():
    losses = _run("fp")
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 1.0, losses[-5:]


def test_binary_training_learns():
    """The BNN trains too (paper Table 1: binary accuracy close to fp)."""
    losses = _run("binary", steps=80)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses[-5:]


def test_microbatch_equivalence():
    """4 microbatches == single batch, same loss trajectory (fp32)."""
    spec = registry.get("granite-3-2b")
    cfg = spec.smoke
    ctx = QCtx(policy=QuantPolicy.full_precision(), compute_dtype=jnp.float32)
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    dcfg = synthetic.DataConfig(cfg.vocab_size, seq_len=16, global_batch=8)

    def run(micro):
        params, opt_state = trainer.init_all(spec, cfg, jax.random.PRNGKey(0))
        fn = jax.jit(trainer.make_train_step(spec, cfg, ctx, opt,
                                             remat=False, microbatch=micro))
        out = []
        for i in range(3):
            params, opt_state, m = fn(params, opt_state,
                                      synthetic.batch_at(dcfg, i))
            out.append(float(m["loss"]))
        return out

    # CE is per-token mean; microbatches have equal token counts
    np.testing.assert_allclose(run(None), run(4), rtol=2e-3)


def test_microbatch_aux_metrics_parity():
    """Regression: the microbatch scan used to drop aux metrics (aux = {});
    now both paths report the full set, with counters summed and the rest
    averaged across chunks."""
    spec = registry.get("granite-3-2b")
    cfg = spec.smoke
    ctx = QCtx(policy=QuantPolicy.full_precision(), compute_dtype=jnp.float32)
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    dcfg = synthetic.DataConfig(cfg.vocab_size, seq_len=16, global_batch=8)
    batch = synthetic.batch_at(dcfg, 0)

    def metrics(micro):
        params, opt_state = trainer.init_all(spec, cfg, jax.random.PRNGKey(0))
        fn = jax.jit(trainer.make_train_step(spec, cfg, ctx, opt,
                                             remat=False, microbatch=micro))
        _, _, m = fn(params, opt_state, batch)
        return m

    m1, m4 = metrics(None), metrics(4)
    for key in ("ce", "aux", "n_tokens"):
        assert key in m1 and key in m4, key
    assert set(m1) == set(m4)
    # n_tokens is a counter: summed over chunks, not averaged
    assert float(m1["n_tokens"]) == float(m4["n_tokens"]) == 8 * 16
    np.testing.assert_allclose(float(m1["ce"]), float(m4["ce"]), rtol=2e-3)


def _dp_mesh_or_skip(dp):
    if len(jax.devices()) < dp:
        pytest.skip(f"needs {dp} devices, have {len(jax.devices())}")
    return jax.make_mesh((dp, 1), ("data", "model"))


@pytest.mark.parametrize("dp", [2, 4, 8])
def test_dp_uncompressed_bit_identical(dp):
    """The uncompressed DP step is BIT-identical to the single-device step
    with microbatch=dp at every split: XLA's psum over 'data' continues
    the same left-fold reduction order as the microbatch scan."""
    mesh = _dp_mesh_or_skip(dp)
    spec = registry.get("granite-3-2b")
    cfg = spec.smoke
    ctx = QCtx(policy=QuantPolicy.binary(), compute_dtype=jnp.float32)
    opt = adamw.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=10)
    dcfg = synthetic.DataConfig(cfg.vocab_size, seq_len=16, global_batch=8)

    params, opt_state = trainer.init_all(spec, cfg, jax.random.PRNGKey(0))
    single = jax.jit(trainer.make_train_step(spec, cfg, ctx, opt,
                                             remat=False, microbatch=dp))
    state = trainer.train_state_init(spec, cfg, jax.random.PRNGKey(0))
    sharded = jax.jit(trainer.make_sharded_train_step(
        spec, cfg, ctx, opt, trainer.TrainConfig(grad_compress=False), mesh))

    with mesh:
        for i in range(3):
            batch = synthetic.batch_at(dcfg, i)
            params, opt_state, ms = single(params, opt_state, batch)
            state, md = sharded(state, batch)
            assert float(ms["loss"]) == float(md["loss"]), (i, dp)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dp_compressed_learns():
    """1-bit EF gradient compression still trains the BNN (the residual
    feedback repays the quantization error over steps)."""
    dp, steps = 4, 40
    mesh = _dp_mesh_or_skip(dp)
    spec = registry.get("granite-3-2b")
    cfg = spec.smoke
    ctx = QCtx(policy=QuantPolicy.binary(), compute_dtype=jnp.float32)
    opt = adamw.AdamWConfig(lr=6e-3, warmup_steps=5, total_steps=steps)
    dcfg = synthetic.DataConfig(cfg.vocab_size, seq_len=32, global_batch=8)
    state = trainer.train_state_init(spec, cfg, jax.random.PRNGKey(0),
                                     grad_compress=True, dp=dp)
    fn = jax.jit(trainer.make_sharded_train_step(
        spec, cfg, ctx, opt, trainer.TrainConfig(grad_compress=True), mesh))
    losses = []
    with mesh:
        for i in range(steps):
            state, m = fn(state, synthetic.batch_at(dcfg, i))
            losses.append(float(m["loss"]))
    assert float(m["grad_compress_ratio"]) > 25.0
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses[-5:]


def test_remat_matches_no_remat():
    spec = registry.get("deepseek-7b")
    cfg = spec.smoke
    ctx = QCtx(policy=QuantPolicy.full_precision(), compute_dtype=jnp.float32)
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    dcfg = synthetic.DataConfig(cfg.vocab_size, seq_len=16, global_batch=4)
    batch = synthetic.batch_at(dcfg, 0)

    outs = []
    for remat in (False, True):
        params, opt_state = trainer.init_all(spec, cfg, jax.random.PRNGKey(0))
        fn = jax.jit(trainer.make_train_step(spec, cfg, ctx, opt, remat=remat))
        _, _, m = fn(params, opt_state, batch)
        outs.append(float(m["loss"]))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5)


@pytest.mark.parametrize("kbits", [2, 4])
def test_kbit_quantized_training_learns(kbits):
    """DoReFa path (paper §2.1, 2<=k<=31) also trains."""
    spec = registry.get("deepseek-7b")
    cfg = spec.smoke
    ctx = QCtx(policy=QuantPolicy.quantized(kbits), compute_dtype=jnp.float32)
    opt = adamw.AdamWConfig(lr=6e-3, warmup_steps=5, total_steps=50)
    params, opt_state = trainer.init_all(spec, cfg, jax.random.PRNGKey(0))
    fn = jax.jit(trainer.make_train_step(spec, cfg, ctx, opt, remat=False))
    dcfg = synthetic.DataConfig(cfg.vocab_size, seq_len=32, global_batch=16)
    losses = []
    for i in range(50):
        params, opt_state, m = fn(params, opt_state, synthetic.batch_at(dcfg, i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5
