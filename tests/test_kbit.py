"""The k-bit (DoReFa, paper §2.1 Eq. 1) packed serving path: quantizer
code/level properties, bit-plane packing, the plane-popcount Pallas kernel,
dispatch backend resolution, and fake-quant == plane-packed equivalence on
dense, conv-im2col and grouped (MoE) shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitpack, converter, qlayers, quant
from repro.core.policy import QuantPolicy
from repro.kernels import dispatch, ref
from repro.kernels.dispatch import GemmConfig

BITS = [2, 4, 8]
# fake-quant train path vs integer plane path differ only by fp32 rounding
# of the quantized values; 2e-4 absorbs it across every swept shape
TOL = dict(rtol=1e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# quantizer levels + integer codes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", BITS)
def test_quantize_k_level_count(k):
    """Eq. 1 has exactly 2^k levels on [0, 1] and is idempotent."""
    x = jnp.linspace(0.0, 1.0, 4097)
    q = np.asarray(quant.quantize_k(x, k))
    assert len(np.unique(q)) == 2**k
    np.testing.assert_array_equal(
        np.asarray(quant.quantize_k(jnp.asarray(q), k)), q
    )


@pytest.mark.parametrize("k", BITS)
def test_act_codes_match_quantizer(k):
    """quantize_act(x, k) == act_codes(x, k) / (2^k - 1), codes in range."""
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal(512) * 1.5, jnp.float32
    )
    codes = np.asarray(quant.act_codes(x, k))
    assert codes.min() >= 0 and codes.max() <= 2**k - 1
    np.testing.assert_allclose(
        np.asarray(quant.quantize_act(x, k)),
        codes.astype(np.float32) / (2**k - 1),
        rtol=0, atol=1e-6,
    )


@pytest.mark.parametrize("k", BITS)
def test_weight_codes_match_quantizer(k):
    """quantize_weight(w, k) == (2*codes - n) / n."""
    w = jnp.asarray(
        np.random.default_rng(1).standard_normal(512) * 2, jnp.float32
    )
    n = 2**k - 1
    codes = np.asarray(quant.weight_codes(w, k), np.float32)
    np.testing.assert_allclose(
        np.asarray(quant.quantize_weight(w, k)),
        (2 * codes - n) / n,
        rtol=0, atol=1e-6,
    )


@pytest.mark.parametrize("k", BITS)
def test_pack_unpack_planes_roundtrip(k):
    codes = jnp.asarray(
        np.random.default_rng(2).integers(0, 2**k, (5, 77)), jnp.uint32
    )
    planes = bitpack.pack_planes(codes, k)
    assert planes.shape == (k, 5, bitpack.packed_width(77))
    back = bitpack.unpack_planes(planes, 77)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


# ---------------------------------------------------------------------------
# plane kernel vs oracle + backend resolution
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ka,kb", [(2, 2), (4, 4), (8, 8), (8, 4)])
def test_plane_kernel_matches_integer_dot(ka, kb):
    """Pallas plane kernel == ref == the plain integer code GEMM, on an
    odd (non-multiple) shape."""
    rng = np.random.default_rng(3)
    m, n, k = 13, 9, 70
    ca = jnp.asarray(rng.integers(0, 2**ka, (m, k)), jnp.uint32)
    cb = jnp.asarray(rng.integers(0, 2**kb, (n, k)), jnp.uint32)
    ap, bp = bitpack.pack_planes(ca, ka), bitpack.pack_planes(cb, kb)
    want = np.asarray(ca, np.int64) @ np.asarray(cb, np.int64).T
    np.testing.assert_array_equal(np.asarray(ref.kbit_gemm_ref(ap, bp)),
                                  want)
    got = dispatch.packed_kbit_gemm(
        ap, bp, config=GemmConfig(backend="vpu")
    )
    np.testing.assert_array_equal(np.asarray(got), want)


def test_resolve_backend_rules():
    assert dispatch.resolve_backend("vpu", 1) == "vpu"
    assert dispatch.resolve_backend("mxu", 1) == "mxu"
    # a plane backend asked to run a 1-bit GEMM down-resolves (per-layer
    # policies mix 1-bit and k-bit layers under one configured base name)
    assert dispatch.resolve_backend("vpu-k4", 1) == "vpu"
    assert dispatch.resolve_backend("mxu-k4", 1) == "mxu"
    for base in ("vpu", "mxu"):
        for k in BITS:
            # family-aware: each base resolves onto ITS k-bit entries
            assert dispatch.resolve_backend(base, k) == f"{base}-k{k}"
    assert dispatch.resolve_backend("xla", 4) == "xla"
    assert dispatch.resolve_backend("vpu-k4", 4) == "vpu-k4"
    # no plane backend registered for w3 -> dequant fallback
    assert dispatch.resolve_backend("vpu", 3) == "xla"
    # typo'd base names surface instead of silently falling back by width
    with pytest.raises(ValueError, match="unknown gemm backend"):
        dispatch.resolve_backend("vpux", 4)
    # a k2 entry asked to run a 4-bit GEMM re-resolves to the right width
    assert dispatch.resolve_backend("vpu-k2", 4) == "vpu-k4"


# ---------------------------------------------------------------------------
# packed k-bit GEMM == fake-quant DoReFa reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", BITS)
@pytest.mark.parametrize("backend", ["vpu", "xla"])
def test_quant_gemm_kbit_matches_fakequant(k, backend):
    rng = np.random.default_rng(4)
    m, kk, n = 9, 70, 13
    x = jnp.asarray(rng.standard_normal((m, kk)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((kk, n)), jnp.float32)
    wp = bitpack.pack_planes(quant.weight_codes(w.T, k), k)
    got = dispatch.quant_gemm(
        x, wp, k_true=kk, config=GemmConfig(backend=backend),
        w_bits=k, a_bits=k,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.dorefa_gemm_ref(x, w, k, k)), **TOL
    )


def test_quant_gemm_kbit_asymmetric_w4a8():
    rng = np.random.default_rng(5)
    m, kk, n = 6, 100, 8
    x = jnp.asarray(rng.standard_normal((m, kk)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((kk, n)), jnp.float32)
    wp = bitpack.pack_planes(quant.weight_codes(w.T, 4), 4)
    got = dispatch.quant_gemm(
        x, wp, k_true=kk, config=GemmConfig(backend="vpu"),
        w_bits=4, a_bits=8,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.dorefa_gemm_ref(x, w, 4, 8)), **TOL
    )


@pytest.mark.parametrize("k", BITS)
def test_qdense_packed_kbit_matches_train(k):
    """Converted dense layer, bias + scale on: packed == fake-quant."""
    key = jax.random.PRNGKey(0)
    p = qlayers.dense_init(key, 96, 24, bias=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (7, 96))
    pol = QuantPolicy(w_bits=k, a_bits=k, scale=True)
    spec = pol.spec("layers/0/up")
    y_train = qlayers.qdense(p, x, spec, compute_dtype=jnp.float32)
    packed, rep = converter.convert({"l": p}, pol)
    assert rep.n_packed == 1
    assert packed["l"]["w_packed"].shape == (k, 24, 3)
    assert "scale" in packed["l"]
    y_packed = qlayers.qdense(
        packed["l"], x, spec, compute_dtype=jnp.float32,
        gemm_config=GemmConfig(backend="vpu"),
    )
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_packed),
                               **TOL)


@pytest.mark.parametrize("k", BITS)
@pytest.mark.parametrize("padding,stride", [("SAME", 2), ("VALID", 1)])
def test_qconv_packed_kbit_matches_train(k, padding, stride):
    """Converted conv layer on conv-im2col shapes: packed == fake-quant
    (including the SAME-padding zero-code correspondence)."""
    key = jax.random.PRNGKey(2)
    p = qlayers.conv_init(key, 3, 3, 8, 16)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 10, 10, 8))
    pol = QuantPolicy.quantized(k)
    spec = pol.spec("stage/conv")
    y_train = qlayers.qconv(p, x, spec, stride=stride, padding=padding,
                            compute_dtype=jnp.float32)
    packed, _ = converter.convert({"c": p}, pol)
    y_packed = qlayers.qconv(
        packed["c"], x, spec, stride=stride, padding=padding,
        compute_dtype=jnp.float32, gemm_config=GemmConfig(backend="vpu"),
    )
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_packed),
                               **TOL)


@pytest.mark.parametrize("backend", ["vpu", "xla"])
def test_grouped_kbit_matches_fakequant(backend):
    """Expert-stacked (MoE) k-bit GEMM vs per-group fake-quant reference,
    ragged group sizes with an empty group."""
    t, kk, e, n, k = 23, 45, 4, 13, 4
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (t, kk), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (e, n, kk),
                          jnp.float32)
    gs = jnp.asarray([5, 0, 11, 4], jnp.int32)  # ragged, sum < t
    # codes over the FULL stack (global tanh-max, like the train path)
    wp = jnp.moveaxis(bitpack.pack_planes(quant.weight_codes(w, k), k),
                      0, 1)  # (E, k, N, Kw)
    got = np.asarray(dispatch.quant_gemm_grouped(
        x, wp, gs, k_true=kk, config=GemmConfig(backend=backend),
        w_bits=k, a_bits=k,
    ))
    xq = np.asarray(quant.quantize_act(x, k))
    wq = np.asarray(quant.quantize_weight(w, k))
    ends = np.cumsum(np.asarray(gs))
    want = np.zeros((t, n), np.float32)
    for i in range(t):
        g = int(np.searchsorted(ends, i, side="right"))
        if g < e:
            want[i] = xq[i] @ wq[g].T
    np.testing.assert_allclose(got, want, **TOL)


def test_moe_packed_kbit_end_to_end():
    """w4a4 MoE through nn/mlp.py: converted plane stacks == fake-quant."""
    from repro.nn import mlp
    from repro.nn.common import QCtx

    cfg = mlp.MoEConfig(d_model=64, d_expert=48, n_routed=8, n_shared=1,
                        top_k=2)
    params = mlp.moe_init(jax.random.PRNGKey(0), cfg)
    pol = QuantPolicy.quantized(4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 64))

    y_fq, _ = mlp.moe_apply(params, x, cfg,
                            QCtx(policy=pol, compute_dtype=jnp.float32),
                            "layers/0/moe")
    packed, rep = converter.convert(jax.tree.map(np.asarray, params), pol)
    assert rep.n_packed > 0
    packed = jax.tree.map(jnp.asarray, packed)
    assert packed["experts"]["up_packed"].shape[1] == 4  # plane dim
    ctx = QCtx(policy=pol, compute_dtype=jnp.float32,
               gemm_config=GemmConfig(backend="vpu"))
    y_pk, _ = mlp.moe_apply(packed, x, cfg, ctx, "layers/0/moe")
    np.testing.assert_allclose(np.asarray(y_fq), np.asarray(y_pk), **TOL)


# ---------------------------------------------------------------------------
# converter accounting + abstract layout
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", BITS)
def test_converter_kbit_compression_ratio(k):
    """Plane-packed weights store k/32 of the fp32 bytes."""
    p = {"l": qlayers.dense_init(jax.random.PRNGKey(0), 1024, 256)}
    _, rep = converter.convert(p, QuantPolicy.quantized(k))
    leaf = [x for x in rep.leaves if x.packed][0]
    assert leaf.bytes_after == leaf.bytes_fp32 * k // 32


def test_abstract_packed_matches_convert_kbit():
    pol = QuantPolicy.quantized(4)
    params = {
        "mlp": {"up": qlayers.dense_init(jax.random.PRNGKey(0), 64, 32,
                                         bias=True)},
        "conv": {"c": qlayers.conv_init(jax.random.PRNGKey(1), 3, 3, 4, 8)},
        "experts": {"up": jnp.zeros((4, 64, 32)),
                    "gate": jnp.zeros((4, 64, 32)),
                    "down": jnp.zeros((4, 32, 64))},
    }
    conc, _ = converter.convert(jax.tree.map(np.asarray, params), pol)
    abst = converter.abstract_packed(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                     params), pol)
    assert (jax.tree.map(lambda x: tuple(x.shape), abst)
            == jax.tree.map(lambda x: tuple(x.shape), conc))


def test_kbit_base_backend_serves_1bit_layers():
    """GemmConfig(backend='vpu-k4') on a 1-bit GEMM (e.g. the fp->binary
    layers of a mixed policy) must run, not crash on the plane entry."""
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 5)), jnp.float32)
    wp = bitpack.pack_sign(w.T)
    got = dispatch.quant_gemm(x, wp, k_true=64,
                              config=GemmConfig(backend="vpu-k4"))
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.sign_gemm_ref(x, w))
    )


def test_mixed_and_oversized_widths_rejected():
    """Mixed 1-bit/k-bit widths and int32-overflowing contractions must
    fail loudly (silent wrong numbers otherwise)."""
    x = jnp.zeros((2, 64), jnp.float32)
    wp1 = jnp.zeros((8, 2), jnp.uint32)  # 1-bit layout
    wp4 = jnp.zeros((4, 8, 2), jnp.uint32)  # 4-bit plane stack
    with pytest.raises(ValueError, match="mixed 1-bit/k-bit"):
        dispatch.quant_gemm(x, wp4, k_true=64, w_bits=4)  # a_bits=1
    with pytest.raises(ValueError, match="mixed 1-bit/k-bit"):
        dispatch.quant_gemm(x, wp1, k_true=64, a_bits=4)  # w_bits=1
    with pytest.raises(ValueError, match="widths 2..8"):
        dispatch.quant_gemm(x, wp4, k_true=64, w_bits=4, a_bits=9)
    big_k = 20_000  # w8a8 int32 bound is ~16.5k
    xb = jnp.zeros((1, big_k), jnp.float32)
    wb = jnp.zeros((8, 1, bitpack.packed_width(big_k)), jnp.uint32)
    with pytest.raises(ValueError, match="int32 accumulator"):
        dispatch.quant_gemm(xb, wb, k_true=big_k,
                            config=GemmConfig(backend="vpu"),
                            w_bits=8, a_bits=8)


def test_kbit_dequant_precision_large_k():
    """w8a8 at K=4096: S > 2^24, so the dequant numerator must stay in
    int32 (an fp32 cast of S first loses bits before the cancellation-
    prone subtraction)."""
    rng = np.random.default_rng(7)
    k = 4096
    x = jnp.asarray(rng.random((2, k)), jnp.float32)  # dense in [0,1]
    w = jnp.asarray(rng.standard_normal((k, 3)), jnp.float32)
    wp = bitpack.pack_planes(quant.weight_codes(w.T, 8), 8)
    got = np.asarray(dispatch.quant_gemm(
        x, wp, k_true=k, config=GemmConfig(backend="vpu"),
        w_bits=8, a_bits=8,
    ))
    # float64 oracle: exact integer S/T far beyond fp32 mantissa
    ca = np.asarray(quant.act_codes(x, 8), np.int64)
    cw = np.asarray(quant.weight_codes(w.T, 8), np.int64)
    s = ca @ cw.T
    t = ca.sum(-1, keepdims=True)
    want = (2 * s - 255 * t) / float(255 * 255)
    # residual = one fp32 cast of the int32 numerator (~2^-24 relative);
    # casting S to fp32 BEFORE the subtraction would sit near 5e-5 here
    np.testing.assert_allclose(got, want, rtol=0, atol=5e-6)


def test_full_precision_and_binary_unchanged():
    """k-bit plumbing must not disturb the fp and 1-bit convert rules."""
    p = {"l": qlayers.dense_init(jax.random.PRNGKey(0), 64, 32)}
    _, rep_fp = converter.convert(p, QuantPolicy.full_precision())
    assert rep_fp.n_packed == 0
    conv_b, rep_b = converter.convert(p, QuantPolicy.binary())
    assert rep_b.n_packed == 1
    assert conv_b["l"]["w_packed"].ndim == 2  # flat sign words, no planes
