"""Unit tests for dry-run mechanics that don't need 512 devices."""

from repro.configs.shapes import SHAPES
from repro.launch.dryrun import collective_bytes, model_flops
from repro.models import registry

HLO = """
HloModule jit_step

%wide.body_comp (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %ar = f32[8,128] all-reduce(f32[8,128] %x), replica_groups={}
  ROOT %t = (s32[], f32[8,128]) tuple(%i, %ar)
}

ENTRY %main (a: f32[16,256]) -> f32[16,256] {
  %ag = f32[16,256] all-gather(f32[16,16] %a), dimensions={1}
  %w = (s32[], f32[8,128]) while(%init), condition=%cond, body=%wide.body_comp
  %rs = f32[4,256] reduce-scatter(f32[16,256] %ag), dimensions={0}
  %cp = f32[16,256]{1,0} collective-permute(f32[16,256] %rs)
  ROOT %r = f32[16,256] add(%cp, %cp)
}
"""


def test_collective_parser_kinds_and_sizes():
    out = collective_bytes(HLO)
    assert out["all-gather"] == 16 * 256 * 4
    assert out["all-reduce"] == 8 * 128 * 4 * 2  # 2x for all-reduce
    assert out["reduce-scatter"] == 4 * 256 * 4
    assert out["collective-permute"] == 16 * 256 * 4


def test_collective_parser_loop_scaling():
    base = collective_bytes(HLO)
    scaled = collective_bytes(HLO, loop_trip=10)
    # only the in-body all-reduce scales
    assert scaled["all-reduce"] == base["all-reduce"] * 10
    assert scaled["all-gather"] == base["all-gather"]


def test_model_flops_train_dominated_by_6nd():
    spec = registry.get("deepseek-7b")
    mf = model_flops(spec, spec.config, SHAPES["train_4k"])
    n = 6.9e9
    tokens = 256 * 4096
    assert mf > 6 * n * tokens  # includes attention term
    assert mf < 6 * n * tokens * 1.5


def test_model_flops_decode_small():
    spec = registry.get("deepseek-7b")
    mf = model_flops(spec, spec.config, SHAPES["decode_32k"])
    # decode: 2*N*B + attention-over-cache
    assert 2 * 6.9e9 * 128 < mf < 2 * 6.9e9 * 128 * 3


def test_long_500k_skip_flags():
    assert registry.get("rwkv6-7b").supports_long
    assert registry.get("recurrentgemma-2b").supports_long
    assert not registry.get("qwen2-72b").supports_long
    assert not registry.get("gemma2-27b").supports_long
