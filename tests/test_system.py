"""End-to-end system test: train a binary LM on synthetic data, checkpoint,
resume, convert to the packed serving format, and serve — the full BMXNet
lifecycle (train with floats -> pack bits -> serve with xnor)."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.core import converter
from repro.core.policy import QuantPolicy
from repro.data import synthetic
from repro.models import registry
from repro.nn.common import QCtx
from repro.optim import adamw
from repro.serve.engine import Engine, EngineConfig
from repro.train import trainer


def test_full_lifecycle(tmp_path):
    spec = registry.get("granite-3-2b")
    cfg = spec.smoke
    policy = QuantPolicy.binary()
    ctx = QCtx(policy=policy, compute_dtype=jnp.float32)
    opt = adamw.AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=30)

    params, opt_state = trainer.init_all(spec, cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(trainer.make_train_step(spec, cfg, ctx, opt,
                                              remat=False))
    dcfg = synthetic.DataConfig(cfg.vocab_size, seq_len=24, global_batch=8)
    mgr = CheckpointManager(str(tmp_path / "ckpt"))

    losses = []
    for i in range(15):
        params, opt_state, m = step_fn(params, opt_state,
                                       synthetic.batch_at(dcfg, i))
        losses.append(float(m["loss"]))
    mgr.save(15, {"params": params, "opt": opt_state})

    # ---- simulated preemption: restore and continue -----------------------
    step, tree = mgr.restore({"params": params, "opt": opt_state})
    assert step == 15
    params2, opt2 = tree["params"], tree["opt"]
    for i in range(15, 30):
        params2, opt2, m = step_fn(params2, opt2, synthetic.batch_at(dcfg, i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses

    # ---- convert + packed serving -----------------------------------------
    host = jax.tree.map(np.asarray, params2)
    packed, report = converter.convert(host, policy)
    # smoke config: the fp embedding table dominates a d=64/V=512 model, so
    # the end-to-end ratio is ~3x here (full-size LMs reach ~10x, see
    # benchmarks lm_sizes; the per-layer ratio is ~25-32x either way)
    assert report.ratio > 3, report.summary()
    packed = jax.tree.map(jnp.asarray, packed)

    eng_fq = Engine(spec, cfg, ctx, params2,
                    EngineConfig(batch=2, cache_len=48, max_new_tokens=5))
    eng_pk = Engine(spec, cfg, ctx, packed,
                    EngineConfig(batch=2, cache_len=48, max_new_tokens=5))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 8)).astype(np.int32)
    np.testing.assert_array_equal(eng_fq.generate(prompts),
                                  eng_pk.generate(prompts))


def test_train_launcher_cli(tmp_path):
    """The actual CLI driver runs (deliverable b)."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "granite-3-2b",
         "--smoke", "--steps", "6", "--batch", "4", "--seq", "16",
         "--quant", "binary", "--ckpt-dir", str(tmp_path / "c"),
         "--ckpt-every", "3", "--log-every", "2",
         "--export-packed", str(tmp_path / "packed.npz")],
        capture_output=True, text=True, timeout=540,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin",
             "HOME": "/root"},
        cwd="/root/repo",
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "loss=" in out.stdout
    assert "packed export" in out.stdout
    assert (tmp_path / "packed.npz").exists()
