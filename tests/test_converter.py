"""Model converter (paper §2.2.3): compression accounting reproduces the
paper's Table 1 numbers (LeNet 4.6MB -> ~206kB, ResNet-18 44.7MB -> ~1.5MB,
29x)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import converter, qlayers
from repro.core.policy import QuantPolicy
from repro.models import cnn, registry


def test_dense_pack_ratio_approaches_32x():
    key = jax.random.PRNGKey(0)
    p = {"big": qlayers.dense_init(key, 4096, 4096)}
    _, rep = converter.convert(p, QuantPolicy.binary())
    assert rep.ratio > 31.5, rep.summary()


def test_first_last_left_untouched():
    key = jax.random.PRNGKey(0)
    p = {
        "first_conv": qlayers.conv_init(key, 3, 3, 3, 8),
        "mid": qlayers.dense_init(key, 64, 64),
        "head": qlayers.dense_init(key, 64, 10),
    }
    packed, rep = converter.convert(p, QuantPolicy.binary())
    assert "w" in packed["first_conv"] and "w_packed" not in packed["first_conv"]
    assert "w" in packed["head"]
    assert "w_packed" in packed["mid"]
    assert rep.n_packed == 1


def test_lenet_sizes_match_paper_table1():
    """Paper: full-precision LeNet 4.6MB -> binary 206kB."""
    cfg = registry.get("lenet-mnist").config
    params = cnn.lenet_init(jax.random.PRNGKey(0), cfg)
    fp_bytes = converter.model_nbytes(params)
    assert 4.0e6 < fp_bytes < 5.2e6, fp_bytes  # ~4.6MB
    _, rep = converter.convert(params, QuantPolicy.binary())
    assert 0.15e6 < rep.bytes_after < 0.3e6, rep.summary()  # ~206kB


def test_resnet18_sizes_match_paper_table1():
    """Paper: ResNet-18 44.7MB -> 1.5MB (29x)."""
    cfg = registry.get("resnet18-cifar10").config
    params = cnn.resnet18_init(jax.random.PRNGKey(0), cfg)
    fp_bytes = converter.model_nbytes(params)
    assert 40e6 < fp_bytes < 50e6, fp_bytes  # ~44.7MB
    _, rep = converter.convert(params, QuantPolicy.binary())
    assert rep.ratio > 25, rep.summary()  # paper: 29x
    assert rep.bytes_after < 2.0e6, rep.summary()  # ~1.5MB


def test_partial_binarization_size_ordering():
    """Table 2: more fp stages => bigger model, monotonically."""
    cfg = registry.get("resnet18-cifar10").config
    params = cnn.resnet18_init(jax.random.PRNGKey(0), cfg)
    sizes = []
    for fp_stages in [(), ("stage1",), ("stage1", "stage2"),
                      ("stage1", "stage2", "stage3"),
                      ("stage1", "stage2", "stage3", "stage4")]:
        pol = QuantPolicy.binary().with_fp_stages(fp_stages)
        _, rep = converter.convert(params, pol)
        sizes.append(rep.bytes_after)
    assert all(a < b for a, b in zip(sizes, sizes[1:])), sizes


def test_abstract_packed_matches_concrete():
    key = jax.random.PRNGKey(0)
    p = {"lay": qlayers.dense_init(key, 100, 48),
         "conv": qlayers.conv_init(key, 3, 3, 8, 16),
         "norm": {"scale": jnp.zeros((48,))}}
    pol = QuantPolicy.binary(scale=True)
    concrete, _ = converter.convert(p, pol)
    abstract = converter.abstract_packed(jax.eval_shape(lambda: p), pol)
    c_flat = jax.tree.map(lambda x: (x.shape, str(x.dtype)), concrete)
    a_flat = jax.tree.map(lambda x: (x.shape, str(x.dtype)), abstract)
    # shape_hwio dtype may differ int64/int32 across paths; compare w_packed
    assert c_flat["lay"]["w_packed"] == a_flat["lay"]["w_packed"]
    assert c_flat["conv"]["w_packed"] == a_flat["conv"]["w_packed"]
    assert c_flat["lay"]["scale"] == a_flat["lay"]["scale"]


def test_keep_float_roundtrip_values():
    key = jax.random.PRNGKey(0)
    p = {"lay": qlayers.dense_init(key, 64, 32)}
    packed, _ = converter.convert(p, QuantPolicy.binary(), keep_float=True)
    from repro.core import bitpack
    w = np.asarray(packed["lay"]["w"])
    unpacked = np.asarray(
        bitpack.unpack_sign(packed["lay"]["w_packed"], 64)
    )  # (d_out, d_in)
    np.testing.assert_array_equal(unpacked.T, np.where(w >= 0, 1.0, -1.0))
