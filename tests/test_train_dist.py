"""Sharded DP training: the 1-bit EF compressed collective under a real
multi-member shard_map (property sweep over 2/4/8-way 'data' splits), EF
residual member-locality, compressed-resume exactness through the
checkpoint manager, the tracker layer, and policy schedules."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import PartitionSpec as P

from repro.ckpt.manager import CheckpointManager
from repro.compat import shard_map
from repro.core.policy import PolicySchedule, QuantPolicy
from repro.data import synthetic
from repro.dist import compress
from repro.models import registry
from repro.nn.common import QCtx
from repro.optim import adamw
from repro.train import tracker as tracker_mod
from repro.train import trainer


def _n_dev():
    return len(jax.devices())


def _mesh_or_skip(dp, tp=1):
    if _n_dev() < dp * tp:
        pytest.skip(f"needs {dp * tp} devices, have {_n_dev()}")
    return jax.make_mesh((dp, tp), ("data", "model"))


# ---------------------------------------------------------------------------
# compressed_psum under real multi-member shard_map
# ---------------------------------------------------------------------------


def _sim_member(g, e):
    """numpy re-implementation of dist.compress.compress_leaf."""
    acc = g + e
    scale = np.mean(np.abs(acc), dtype=np.float32)
    c = np.where(acc >= 0, scale, -scale).astype(np.float32)
    return c, acc - c


@settings(max_examples=10)
@given(seed=st.integers(0, 2**31 - 1), dp=st.sampled_from([2, 4, 8]))
def test_compressed_psum_property(seed, dp):
    """Per-member EF residual locality + the EF-SGD invariant, on a real
    dp-member 'data' mesh: every member's returned residual is exactly its
    own quantization error, the psum mean matches a per-member numpy
    simulation, and mean(true) - mean(compressed) == mean(residual)."""
    if _n_dev() < dp:
        return  # this draw needs a bigger rig; other draws still run
    mesh = jax.make_mesh((dp,), ("data",))
    rng = np.random.default_rng(seed)
    shapes = {"a": (3, 5), "b": (7,), "c": (2, 2, 4)}
    g = {k: (rng.standard_normal((dp,) + s) * rng.uniform(0.1, 10.0))
         .astype(np.float32) for k, s in shapes.items()}
    e = {k: (rng.standard_normal((dp,) + s) * 0.1).astype(np.float32)
         for k, s in shapes.items()}

    def body(gm, em):
        gl = jax.tree.map(lambda x: x[0], gm)
        el = jax.tree.map(lambda x: x[0], em)
        mean, e_new = compress.compressed_psum(gl, el, "data")
        return (jax.tree.map(lambda x: x[None], mean),
                jax.tree.map(lambda x: x[None], e_new))

    f = shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")),
                  out_specs=(P("data"), P("data")), check_vma=False)
    got_m, got_e = jax.jit(f)(g, e)

    for k in shapes:
        comp = np.empty_like(g[k])
        enew = np.empty_like(g[k])
        for mbr in range(dp):
            comp[mbr], enew[mbr] = _sim_member(g[k][mbr], e[k][mbr])
        mean = comp.sum(0) / dp
        gm, ge = np.asarray(got_m[k]), np.asarray(got_e[k])
        # the psum mean is replicated to every member and matches the sim
        for mbr in range(dp):
            np.testing.assert_allclose(gm[mbr], mean, rtol=2e-5, atol=1e-4)
        # EF locality: member i's residual is exactly its own error
        np.testing.assert_allclose(ge, enew, rtol=2e-5, atol=1e-4)
        # EF-SGD invariant: the compressed mean undershoots the true mean
        # by exactly the mean residual (what error feedback repays next
        # step)
        acc_mean = (g[k].astype(np.float64) + e[k]).sum(0) / dp
        np.testing.assert_allclose(acc_mean - gm[0], ge.sum(0) / dp,
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# trainer-level DP behavior
# ---------------------------------------------------------------------------


def _setup(seq=16, batch=8, steps=20):
    spec = registry.get("granite-3-2b")
    cfg = spec.smoke
    ctx = QCtx(policy=QuantPolicy.binary(), compute_dtype=jnp.float32)
    opt = adamw.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=steps)
    dcfg = synthetic.DataConfig(cfg.vocab_size, seq_len=seq,
                                global_batch=batch)
    return spec, cfg, ctx, opt, dcfg


def _run_compressed(mesh, spec, cfg, ctx, opt, dcfg, state, lo, hi):
    tc = trainer.TrainConfig(grad_compress=True)
    fn = jax.jit(trainer.make_sharded_train_step(spec, cfg, ctx, opt, tc,
                                                 mesh))
    with mesh:
        for i in range(lo, hi):
            state, m = fn(state, synthetic.batch_at(dcfg, i))
    return state, m


def test_ef_residual_is_member_local():
    """After compressed steps the EF leaves differ across members — the
    residual is per-member state, not a broadcast."""
    mesh = _mesh_or_skip(4)
    spec, cfg, ctx, opt, dcfg = _setup()
    state = trainer.train_state_init(spec, cfg, jax.random.PRNGKey(0),
                                     grad_compress=True, dp=4)
    state, _ = _run_compressed(mesh, spec, cfg, ctx, opt, dcfg, state, 0, 2)
    leaves = jax.tree.leaves(state.ef)
    assert all(leaf.shape[0] == 4 for leaf in leaves)
    distinct = any(
        not np.array_equal(np.asarray(leaf[0]), np.asarray(leaf[m]))
        for leaf in leaves for m in range(1, 4)
    )
    assert distinct, "EF residuals identical across members"


def test_compressed_resume_bit_identical(tmp_path):
    """Save mid-run, restore, continue: bit-identical to uninterrupted
    compressed training (the EF residual rides in TrainState)."""
    mesh = _mesh_or_skip(4)
    spec, cfg, ctx, opt, dcfg = _setup()

    def fresh():
        return trainer.train_state_init(spec, cfg, jax.random.PRNGKey(0),
                                        grad_compress=True, dp=4)

    full, _ = _run_compressed(mesh, spec, cfg, ctx, opt, dcfg, fresh(), 0, 6)

    half, _ = _run_compressed(mesh, spec, cfg, ctx, opt, dcfg, fresh(), 0, 3)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, half)
    step, restored = mgr.restore(fresh())
    assert step == 3 and isinstance(restored, trainer.TrainState)
    assert trainer.ef_matches(restored, 4)
    resumed, _ = _run_compressed(mesh, spec, cfg, ctx, opt, dcfg, restored,
                                 3, 6)

    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dp_tp_2d_mesh_uncompressed_matches_single_device():
    """A 2-D ('data','model') mesh passes the model axis through
    replicated: DP=2 x TP=2 uncompressed == single-device microbatch=2."""
    mesh = _mesh_or_skip(2, tp=2)
    spec, cfg, ctx, opt, dcfg = _setup()

    params, opt_state = trainer.init_all(spec, cfg, jax.random.PRNGKey(0))
    single = jax.jit(trainer.make_train_step(spec, cfg, ctx, opt,
                                             remat=False, microbatch=2))
    tc = trainer.TrainConfig(grad_compress=False)
    state = trainer.train_state_init(spec, cfg, jax.random.PRNGKey(0))
    sharded = jax.jit(trainer.make_sharded_train_step(spec, cfg, ctx, opt,
                                                      tc, mesh))
    with mesh:
        for i in range(2):
            b = synthetic.batch_at(dcfg, i)
            params, opt_state, ms = single(params, opt_state, b)
            state, md = sharded(state, b)
            assert float(ms["loss"]) == float(md["loss"])
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# tracker
# ---------------------------------------------------------------------------


def test_jsonl_tracker_roundtrip(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with tracker_mod.JsonlTracker(path) as trk:
        trk.log({"loss": jnp.float32(1.5), "n": 3}, step=1)
        trk.log({"loss": np.float64(0.75)}, step=2)
    rows = tracker_mod.read_jsonl(path)
    assert rows == [{"step": 1, "loss": 1.5, "n": 3.0},
                    {"step": 2, "loss": 0.75}]


def test_jsonl_tracker_finish_then_log_raises(tmp_path):
    trk = tracker_mod.JsonlTracker(str(tmp_path / "m.jsonl"))
    trk.finish()
    trk.finish()  # idempotent
    with pytest.raises(ValueError):
        trk.log({"x": 1.0}, step=1)


def test_jsonl_tracker_append_mode(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with tracker_mod.JsonlTracker(path) as trk:
        trk.log({"a": 1.0}, step=1)
    with tracker_mod.JsonlTracker(path, append=True) as trk:
        trk.log({"a": 2.0}, step=2)
    assert [r["step"] for r in tracker_mod.read_jsonl(path)] == [1, 2]


def test_tracker_coerces_bad_values_to_nan(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with tracker_mod.JsonlTracker(path) as trk:
        trk.log({"bad": object()}, step=1)
    assert math.isnan(tracker_mod.read_jsonl(path)[0]["bad"])


def test_composite_and_noop_trackers(tmp_path):
    a = tracker_mod.JsonlTracker(str(tmp_path / "a.jsonl"))
    b = tracker_mod.JsonlTracker(str(tmp_path / "b.jsonl"))
    with tracker_mod.CompositeTracker([a, b, tracker_mod.NoopTracker()]) as c:
        c.log({"x": 1.0}, step=5)
    for t in (a, b):
        assert tracker_mod.read_jsonl(t.path) == [{"step": 5, "x": 1.0}]
    assert a._f is None and b._f is None  # finish fanned out


# ---------------------------------------------------------------------------
# policy schedules
# ---------------------------------------------------------------------------


def test_schedule_validation():
    with pytest.raises(ValueError):
        PolicySchedule(stages=())
    with pytest.raises(ValueError):
        PolicySchedule(stages=((5, QuantPolicy.binary()),))
    with pytest.raises(ValueError):
        PolicySchedule(stages=((0, QuantPolicy.binary()),
                               (10, QuantPolicy.binary()),
                               (10, QuantPolicy.full_precision())))


def test_schedule_lookup():
    fp, bn = QuantPolicy.full_precision(), QuantPolicy.binary()
    s = PolicySchedule(stages=((0, fp), (10, bn)))
    assert s.at(0) == fp and s.at(9) == fp
    assert s.at(10) == bn and s.at(10_000) == bn
    assert s.stage_index(9) == 0 and s.stage_index(10) == 1
    assert s.boundaries() == (10,)
    assert PolicySchedule.constant(bn).boundaries() == ()


def test_two_stage_binarization_schedule():
    s = PolicySchedule.two_stage_binarization(100, scale=True)
    (s0, p1), (s1, p2) = s.stages
    assert (s0, s1) == (0, 100)
    assert p1.w_bits == 1 and p1.a_bits != 1  # stage 1: fp activations
    assert p2.w_bits == 1 and p2.a_bits == 1  # stage 2: fully binary
    assert p1.scale and p2.scale


def test_scale_schedule():
    s = PolicySchedule.scale_schedule(50)
    assert s.at(0).scale and not s.at(50).scale
    s = PolicySchedule.scale_schedule(50, scale_first=False)
    assert not s.at(0).scale and s.at(50).scale


def test_scheduled_training_crosses_boundary():
    """PolicyScheduledStep compiles one step per stage and carries state
    across the recompile boundary."""
    spec, cfg, _, opt, dcfg = _setup()
    schedule = PolicySchedule.two_stage_binarization(3)

    def build(pol):
        base = jax.jit(trainer.make_train_step(
            spec, cfg, QCtx(policy=pol, compute_dtype=jnp.float32), opt,
            remat=False))

        def step(state, batch):
            p, o, m = base(state.params, state.opt_state, batch)
            return trainer.TrainState(p, o, state.ef), m

        return step

    stepper = trainer.PolicyScheduledStep(build, schedule)
    state = trainer.train_state_init(spec, cfg, jax.random.PRNGKey(0))
    losses = []
    for i in range(6):
        state, m = stepper(state, synthetic.batch_at(dcfg, i), step=i)
        losses.append(float(m["loss"]))
    assert stepper.compiled_stages == 2
    assert all(np.isfinite(losses))
