"""Synthetic data pipeline: determinism, host sharding, prefetch,
learnability structure."""

import numpy as np

from repro.data import synthetic


def test_batch_deterministic():
    cfg = synthetic.DataConfig(vocab_size=97, seq_len=16, global_batch=4)
    b1 = synthetic.batch_at(cfg, 5)
    b2 = synthetic.batch_at(cfg, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["targets"], b2["targets"])


def test_steps_differ():
    cfg = synthetic.DataConfig(vocab_size=97, seq_len=16, global_batch=4)
    assert not np.array_equal(synthetic.batch_at(cfg, 1)["tokens"],
                              synthetic.batch_at(cfg, 2)["tokens"])


def test_targets_are_shifted_tokens():
    cfg = synthetic.DataConfig(vocab_size=97, seq_len=16, global_batch=4)
    b = synthetic.batch_at(cfg, 0)
    # targets[t] is the next token: tokens[t+1] == targets[t] for t < S-1
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_host_sharding_disjoint():
    c0 = synthetic.DataConfig(97, 16, 8, n_hosts=2, host_id=0)
    c1 = synthetic.DataConfig(97, 16, 8, n_hosts=2, host_id=1)
    b0 = synthetic.batch_at(c0, 3)
    b1 = synthetic.batch_at(c1, 3)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_transition_structure_learnable():
    """Most transitions follow ONE affine map (seed-fixed), so the mapping
    is a function of the current token — the learnability property the
    integration tests rely on."""
    cfg = synthetic.DataConfig(vocab_size=211, seq_len=256, global_batch=8,
                               noise=0.05)
    b = synthetic.batch_at(cfg, 0)
    x, y = b["tokens"][:, :-1].ravel(), b["tokens"][:, 1:].ravel()
    # find the dominant (a, c): check all multipliers
    best = 0
    for a in [3, 5, 7, 11, 13, 17, 19, 23]:
        for c in range(0, 211, 1):
            frac = np.mean((a * x + c) % 211 == y)
            best = max(best, frac)
            if frac > 0.8:
                break
        if best > 0.8:
            break
    assert best > 0.8, best


def test_prefetcher():
    cfg = synthetic.DataConfig(97, 8, 4)
    pf = synthetic.Prefetcher(lambda s: synthetic.batch_at(cfg, s), 0, depth=2)
    try:
        s0, b0 = pf.next()
        s1, b1 = pf.next()
        assert (s0, s1) == (0, 1)
        np.testing.assert_array_equal(b0["tokens"],
                                      synthetic.batch_at(cfg, 0)["tokens"])
    finally:
        pf.close()


def test_vlm_whisper_batches():
    cfg = synthetic.DataConfig(97, 8, 4)
    v = synthetic.vlm_batch_at(cfg, 0, prefix=7, d_vision=16)
    assert v["vision_embeds"].shape == (4, 7, 16)
    w = synthetic.whisper_batch_at(cfg, 0, t_enc=30, d_model=12)
    assert w["frames"].shape == (4, 30, 12)
