"""Quantized layers — BMXNet's QFullyConnected / QConvolution / QActivation
as JAX functions.

Two execution paths per layer, switched by what the params pytree contains:

* **train / fake-quant** (params have ``w``): weights and activations are
  quantized with STE and the contraction runs on the MXU in ``compute_dtype``
  — the paper's GPU-training path (§2.2.2), bit-exact with the packed path.
* **packed serving** (params have ``w_packed``): weights are stored as uint32
  words (32 per word, paper §2.2.3) — flat ``(d_out, Kw)`` sign bits at
  1 bit, a ``(w_bits, d_out, Kw)`` DoReFa bit-plane stack at 2..8 bits —
  and the contraction goes through ``kernels/dispatch.quant_gemm`` — the
  single dispatch layer that owns activation packing, backend/tile
  selection and pad correction.  The layer's :class:`QuantSpec` carries
  the bit widths, so w4a4 / w8a8 serving needs no layer-level switches.

Both paths share ONE epilogue (scale / Eq. 2 range map / bias / cast): the
layer builds an :class:`~repro.kernels.dispatch.EpilogueSpec` from its
:class:`QuantSpec` and ``dispatch.apply_epilogue`` applies it — that single
implementation is what keeps the two paths bit-exact.  The packed path's
activation side is symmetric: the layer builds a
:class:`~repro.kernels.dispatch.PrologueSpec` (``prologue_from_spec``) and
the dispatch layer runs the fused quantize->pack Pallas prologue (1-bit
sign-pack, or the DoReFa plane-pack + code row-sums) — the layer never
touches codes or packed words itself.

Packed layout: ``w_packed`` is ``(d_out, Kw)`` — the *transposed* weight
packed along the contraction axis, which is the layout the xnor GEMM wants
and the layout the model converter emits.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.policy import QuantSpec
from repro.kernels import dispatch
from repro.kernels.dispatch import GemmConfig

Params = dict[str, Any]


def _gemm_config(
    gemm_config: GemmConfig | None, xnor_backend: str | None
) -> GemmConfig:
    """Resolve the layer's GemmConfig.  ``xnor_backend`` is the legacy
    string knob, kept as an alias for callers that predate dispatch."""
    if gemm_config is not None:
        return gemm_config
    if xnor_backend is not None:
        return GemmConfig(backend=xnor_backend)
    return dispatch.DEFAULT_GEMM_CONFIG


def dense_init(
    key: jax.Array,
    d_in: int,
    d_out: int,
    *,
    bias: bool = False,
    dtype=jnp.float32,
    scale: float | None = None,
) -> Params:
    """Init a (quantizable) dense layer.  LeCun-normal by default."""
    std = scale if scale is not None else d_in**-0.5
    p: Params = {"w": jax.random.normal(key, (d_in, d_out), dtype) * std}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def qdense(
    params: Params,
    x: jax.Array,
    spec: QuantSpec,
    *,
    compute_dtype=jnp.bfloat16,
    gemm_config: GemmConfig | None = None,
    xnor_backend: str | None = None,
) -> jax.Array:
    """Apply a dense layer under a :class:`QuantSpec`.

    Returns ``(..., d_out)`` in ``compute_dtype`` (packed path returns the
    same values — §2.2.2's exact-match invariant, enforced by tests).
    """
    cfg = _gemm_config(gemm_config, xnor_backend)
    if "w_packed" in params:
        return _qdense_packed(params, x, spec,
                              compute_dtype=compute_dtype, config=cfg)

    w = params["w"]
    d_in = w.shape[0]
    bias = params.get("b")
    if spec.is_fp:
        y = jnp.matmul(x.astype(compute_dtype), w.astype(compute_dtype))
        ep = dispatch.EpilogueSpec(bias=bias is not None,
                                   out_dtype=compute_dtype)
        scale_op = None
    else:
        wq = quant.quantize_weight(w.astype(jnp.float32), spec.w_bits)
        xq = quant.quantize_act(x.astype(jnp.float32), spec.a_bits)
        y = jnp.matmul(xq.astype(compute_dtype), wq.astype(compute_dtype))
        ep = dispatch.epilogue_from_spec(spec, bias=bias is not None,
                                         out_dtype=compute_dtype)
        scale_op = (quant.weight_scale(w)[0].astype(compute_dtype)
                    if ep.scale else None)
    if bias is not None:
        bias = bias.astype(compute_dtype)
    return dispatch.apply_epilogue(y, k_true=d_in, epilogue=ep,
                                   scale=scale_op, bias=bias)


def _packed_bits(params: Params, spec: QuantSpec) -> tuple[int, int]:
    """Bit widths of a packed layer, validated against its plane layout:
    1-bit layers store flat (d_out, Kw) words, k-bit layers store a
    (w_bits, d_out, Kw) plane stack (converter layouts)."""
    wp = params["w_packed"]
    if spec.is_binary and spec.a_bits == 1:
        assert wp.ndim == 2, ("1-bit packed weights must be (d_out, Kw)",
                              wp.shape)
        return 1, 1
    assert wp.ndim == 3 and wp.shape[0] == spec.w_bits, (
        "k-bit packed weights must be a (w_bits, d_out, Kw) plane stack",
        wp.shape, spec,
    )
    return spec.w_bits, spec.a_bits


def _qdense_packed(
    params: Params, x: jax.Array, spec: QuantSpec, *, compute_dtype,
    config: GemmConfig
) -> jax.Array:
    w_bits, a_bits = _packed_bits(params, spec)
    k_true = x.shape[-1]
    call = dispatch.QuantGemmCall(
        k_true=k_true,
        config=config,
        epilogue=dispatch.epilogue_from_spec(
            spec, bias="b" in params, out_dtype=compute_dtype
        ),
        w_bits=w_bits,
        a_bits=a_bits,
        prologue=dispatch.prologue_from_spec(spec, config=config),
    )
    return call(x.astype(jnp.float32), params["w_packed"],
                scale=params.get("scale"), bias=params.get("b"))


# ---------------------------------------------------------------------------
# QConvolution: 2D conv for the paper-fidelity CNNs (LeNet / ResNet-18).
# Train path uses lax.conv on fake-quantized weights; packed path is
# im2col + the packed GEMM (exactly how BMXNet implements binary conv).
# ---------------------------------------------------------------------------


def conv_init(
    key: jax.Array,
    h: int,
    w: int,
    c_in: int,
    c_out: int,
    *,
    dtype=jnp.float32,
) -> Params:
    fan_in = h * w * c_in
    return {"w": jax.random.normal(key, (h, w, c_in, c_out), dtype) * fan_in**-0.5}


def qconv(
    params: Params,
    x: jax.Array,  # NHWC
    spec: QuantSpec,
    *,
    stride: int = 1,
    padding: str = "SAME",
    compute_dtype=jnp.bfloat16,
    gemm_config: GemmConfig | None = None,
    xnor_backend: str | None = None,
) -> jax.Array:
    cfg = _gemm_config(gemm_config, xnor_backend)
    if "w_packed" in params:
        return _qconv_packed(
            params, x, spec, stride=stride, padding=padding,
            compute_dtype=compute_dtype, config=cfg,
        )
    w = params["w"]
    if spec.is_fp:
        wq, xq = w, x
    else:
        wq = quant.quantize_weight(w.astype(jnp.float32), spec.w_bits)
        xq = quant.quantize_act(x.astype(jnp.float32), spec.a_bits)
        if spec.is_binary and spec.a_bits == 1 and padding == "SAME":
            # binary conv pads with -1 (bit 0) AFTER binarization so the
            # train path and the packed im2col path see identical patches
            xq = _pad_same_pm1(xq, w.shape[0], w.shape[1], stride)
            padding = "VALID"
    y = jax.lax.conv_general_dilated(
        xq.astype(compute_dtype),
        wq.astype(compute_dtype),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if spec.is_fp:
        ep = dispatch.EpilogueSpec(out_dtype=compute_dtype)
        scale_op = None
    else:
        ep = dispatch.epilogue_from_spec(spec, bias=False,
                                         out_dtype=compute_dtype)
        scale_op = (jnp.mean(jnp.abs(w), axis=(0, 1, 2)).astype(compute_dtype)
                    if ep.scale else None)
    return dispatch.apply_epilogue(
        y, k_true=w.shape[0] * w.shape[1] * w.shape[2], epilogue=ep,
        scale=scale_op,
    )


def _pad_same_pm1(x: jax.Array, h: int, w: int, stride: int) -> jax.Array:
    """SAME-geometry padding with -1 (the binary pad value, bit 0)."""
    _, xh, xw, _ = x.shape
    oh, ow = -(-xh // stride), -(-xw // stride)
    ph = max((oh - 1) * stride + h - xh, 0)
    pw = max((ow - 1) * stride + w - xw, 0)
    return jnp.pad(
        x,
        ((0, 0), (ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2), (0, 0)),
        constant_values=-1.0,
    )


def _im2col(x: jax.Array, h: int, w: int, stride: int, padding: str):
    """(N,H,W,C) -> (N*OH*OW, h*w*C) patches, matching HWIO weight flatten."""
    n, xh, xw, c = x.shape
    if padding == "SAME":
        oh = -(-xh // stride)
        ow = -(-xw // stride)
        ph = max((oh - 1) * stride + h - xh, 0)
        pw = max((ow - 1) * stride + w - xw, 0)
        # pad value -1 => bit 0, matching packed-weight pad convention; the
        # float oracle uses the same pad so both paths see identical patches
        x = jnp.pad(
            x,
            ((0, 0), (ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2), (0, 0)),
            constant_values=-1.0,
        )
    else:
        oh = (xh - h) // stride + 1
        ow = (xw - w) // stride + 1
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(h, w),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (N, OH, OW, C*h*w) with feature order (C, h, w)
    patches = patches.reshape(n, oh, ow, c, h, w)
    patches = patches.transpose(0, 1, 2, 4, 5, 3)  # -> (..., h, w, C)
    return patches.reshape(n * oh * ow, h * w * c), (n, oh, ow)


def _qconv_packed(
    params, x, spec, *, stride, padding, compute_dtype, config: GemmConfig
):
    h, w, c_in, c_out = params["shape_hwio"]
    w_bits, a_bits = _packed_bits(params, spec)
    # im2col pads raw floats with -1.0: bit 0 at 1 bit, and code 0 after
    # the k-bit clip(x, 0, 1) — both match the train path's pad exactly
    # (binary: _pad_same_pm1; k-bit: lax.conv zero-pads the quantized xq).
    cols, (n, oh, ow) = _im2col(
        x.astype(jnp.float32), h, w, stride, padding
    )
    call = dispatch.QuantGemmCall(
        k_true=h * w * c_in,
        config=config,
        epilogue=dispatch.epilogue_from_spec(
            spec, bias=False, out_dtype=compute_dtype
        ),
        w_bits=w_bits,
        a_bits=a_bits,
        prologue=dispatch.prologue_from_spec(spec, config=config),
    )
    dot = call(cols, params["w_packed"], scale=params.get("scale"))
    return dot.reshape(n, oh, ow, c_out)
