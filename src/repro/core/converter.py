"""Model converter — BMXNet §2.2.3, extended to the DoReFa k-bit family.

Walks a trained float checkpoint (a nested-dict pytree) and, for every layer
the :class:`QuantPolicy` marks binary OR k-bit (2 <= w_bits, a_bits <= 8),
replaces the float weight with its bit-packed form:

* 1-bit dense ``w (d_in, d_out)`` -> ``w_packed (d_out, Kw) uint32``
* 1-bit conv ``w (h, w, c_in, c_out)`` -> ``w_packed (c_out, Kw) uint32``
  packed along the flattened ``h*w*c_in`` patch axis (+ ``shape_hwio``)
* k-bit dense/conv -> ``w_packed (w_bits, d_out, Kw)`` — the DoReFa weight
  CODES (quant.weight_codes) split into bit planes (bitpack.pack_planes),
  the layout kernels/kbit_gemm.py contracts; k/32 of the fp32 bytes
* MoE expert stacks -> ``{name}_packed`` ``(E, d_out, Kw)`` at 1 bit,
  ``(E, w_bits, d_out, Kw)`` at k bits (codes taken over the FULL stack,
  matching the train path's global tanh-max normalisation)

and optionally a per-output-channel ``scale`` (XNOR-Net alpha).  Everything
else (first/last layers, norms, biases, recurrence gates) is left untouched.

``convert(...)`` returns the new pytree plus a :class:`SizeReport` with the
paper's accounting: float bytes before, bytes after, compression ratio
(ResNet-18: 44.7 MB -> 1.5 MB, 29x — reproduced in benchmarks/size_bench.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitpack, quant
from repro.core.policy import QuantPolicy, QuantSpec

Pytree = Any


@dataclasses.dataclass
class LeafReport:
    path: str
    shape: tuple[int, ...]
    bytes_fp32: int
    bytes_after: int
    packed: bool


@dataclasses.dataclass
class SizeReport:
    leaves: list[LeafReport]

    @property
    def bytes_fp32(self) -> int:
        return sum(l.bytes_fp32 for l in self.leaves)

    @property
    def bytes_after(self) -> int:
        return sum(l.bytes_after for l in self.leaves)

    @property
    def ratio(self) -> float:
        return self.bytes_fp32 / max(self.bytes_after, 1)

    @property
    def n_packed(self) -> int:
        return sum(1 for l in self.leaves if l.packed)

    def summary(self) -> str:
        return (
            f"fp32={self.bytes_fp32 / 1e6:.2f}MB "
            f"packed={self.bytes_after / 1e6:.2f}MB "
            f"ratio={self.ratio:.1f}x ({self.n_packed} layers packed)"
        )


def _walk(tree: Pytree, prefix: str = ""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _walk(tree[k], f"{prefix}/{k}" if prefix else str(k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _walk(v, f"{prefix}/{i}" if prefix else str(i))
    else:
        yield prefix, tree


def _fp32_bytes(x) -> int:
    return int(np.prod(x.shape, dtype=np.int64)) * 4  # paper stores fp32


def convert(
    params: Pytree, policy: QuantPolicy, *, keep_float: bool = False
) -> tuple[Pytree, SizeReport]:
    """Pack all binary-policy weights.  Pure host-side transformation.

    ``keep_float`` additionally retains the float weight next to the packed
    one (useful for tests comparing both paths on the same checkpoint).
    """
    report = SizeReport(leaves=[])

    def rec(node: Pytree, path: str) -> Pytree:
        if isinstance(node, (list, tuple)):
            return type(node)(
                rec(v, f"{path}/{i}" if path else str(i))
                for i, v in enumerate(node)
            )
        if not isinstance(node, dict):
            report.leaves.append(
                LeafReport(path, tuple(node.shape), _fp32_bytes(node),
                           int(node.size * np.dtype(node.dtype).itemsize),
                           False)
            )
            return node
        spec = policy.spec(path) if path else None
        if (
            "w" in node
            and not isinstance(node["w"], dict)
            and node["w"].ndim in (2, 4)
            and spec is not None
            and _packable(spec)
        ):
            return _pack_layer(node, path, spec, report, keep_float)
        if (
            "up" in node
            and not isinstance(node.get("up"), dict)
            and getattr(node.get("up"), "ndim", 0) == 3
            and spec is not None
            and _packable(spec)
        ):  # MoE expert stack (E, d_in, d_out): pack along d_in per expert
            return _pack_experts(node, path, spec, report, keep_float)
        return {k: rec(v, f"{path}/{k}" if path else k) for k, v in node.items()}

    return rec(params, ""), report


def _packable(spec: QuantSpec) -> bool:
    """Does a packed serving layout exist for this spec?  1-bit (xnor) or
    the plane-packed DoReFa family (both widths in 2..8; wider stays
    fake-quantized — plane stacks above 8 planes stop paying for
    themselves)."""
    if spec.is_binary and spec.a_bits == 1:
        return True
    return 2 <= spec.w_bits <= 8 and 2 <= spec.a_bits <= 8


def _pack_flat(flat, spec: QuantSpec):
    """(d_out, K) float -> packed words: sign bits at 1 bit, a
    (w_bits, d_out, Kw) plane stack of DoReFa weight codes at k bits."""
    if spec.is_binary:
        return bitpack.pack_sign(flat)
    return bitpack.pack_planes(quant.weight_codes(flat, spec.w_bits),
                               spec.w_bits)


def _pack_experts(node, path, spec: QuantSpec, report: SizeReport,
                  keep_float: bool):
    out = {}
    for name, w in node.items():  # up / gate / down, each (E, d_in, d_out)
        e, d_in, d_out = w.shape
        flat = jnp.transpose(jnp.asarray(w), (0, 2, 1))  # (E, d_out, d_in)
        if spec.is_binary:
            w_packed = bitpack.pack_sign(flat)  # (E, d_out, Kw)
        else:
            # codes over the FULL stack: quantize_weight normalises by the
            # global tanh-max, so per-expert packing would drift from the
            # train path
            codes = quant.weight_codes(flat, spec.w_bits)
            w_packed = jnp.moveaxis(
                bitpack.pack_planes(codes, spec.w_bits), 0, 1
            )  # (E, w_bits, d_out, Kw)
        out[name + "_packed"] = w_packed
        if keep_float:
            out[name] = w
        report.leaves.append(
            LeafReport(f"{path}/{name}", tuple(w.shape), _fp32_bytes(w),
                       int(w_packed.size * 4), True)
        )
    return out


def _pack_layer(node, path, spec, report: SizeReport, keep_float: bool):
    w = node["w"]
    if w.ndim == 2:  # (d_in, d_out)
        flat = w.T  # (d_out, d_in); pack along contraction axis
        meta = {}
        alpha_axes = (0,)
    else:  # (h, w, c_in, c_out)
        h, ww, c_in, c_out = w.shape
        flat = w.reshape(h * ww * c_in, c_out).T
        meta = {"shape_hwio": np.array([h, ww, c_in, c_out])}
        alpha_axes = (0, 1, 2)

    w_packed = _pack_flat(jnp.asarray(flat, jnp.float32), spec)
    out = dict(meta)
    out["w_packed"] = w_packed
    if spec.scale:
        out["scale"] = jnp.mean(jnp.abs(w), axis=alpha_axes)
    if keep_float:
        out["w"] = w
    if "b" in node:
        out["b"] = node["b"]

    after = int(w_packed.size * 4)
    if spec.scale:
        after += int(out["scale"].size * 4)
    if "b" in node:
        after += _fp32_bytes(node["b"])
    report.leaves.append(
        LeafReport(f"{path}/w", tuple(w.shape), _fp32_bytes(w) +
                   (_fp32_bytes(node["b"]) if "b" in node else 0),
                   after, True)
    )
    return out


def derive_draft(
    params: Pytree, cfg, *, n_layers: int | None = None,
    policy: QuantPolicy | None = None, keep_float: bool = False,
) -> tuple[Pytree, Any, SizeReport]:
    """Derive a 1-bit draft model from a target LM checkpoint: depth-slice
    the leading ``n_layers`` blocks (default: a quarter of the stack,
    minimum one) and bit-pack the slice under ``policy`` (default
    ``QuantPolicy.binary()`` — the paper's w1a1 xnor tier).

    The draft keeps the target's embedding, final norm, and lm head, so
    it is the "early exit" of the target through the cheap packed-GEMM
    path: the serving-side cash-out of Fig. 1's xnor speedup, because a
    draft token costs ``n_layers/N`` binarized blocks while the target
    verifies whole windows per call (serve/engine.py's speculative mode —
    greedy output stays token-identical to the target regardless of how
    good this draft is, the draft only sets the acceptance rate).

    ``cfg`` is any config with an ``n_layers`` field (duck-typed via
    ``dataclasses.replace`` so this works for LMConfig without importing
    the models package).  Returns ``(draft_params, draft_cfg, report)``.
    """
    policy = QuantPolicy.binary() if policy is None else policy
    total = len(params["layers"])
    n = max(1, total // 4) if n_layers is None else n_layers
    if not 1 <= n <= total:
        raise ValueError(f"draft n_layers {n} not in [1, {total}]")
    sliced = {k: v for k, v in params.items() if k != "layers"}
    sliced["layers"] = list(params["layers"][:n])
    draft_cfg = dataclasses.replace(cfg, n_layers=n)
    draft_params, report = convert(sliced, policy, keep_float=keep_float)
    return draft_params, draft_cfg, report


def abstract_packed(params: Pytree, policy: QuantPolicy) -> Pytree:
    """Shape-only version of :func:`convert` for the multi-pod dry-run:
    maps a pytree of ShapeDtypeStructs to the packed layout without
    touching any data."""
    import jax.numpy as _jnp

    def rec(node: Pytree, path: str) -> Pytree:
        if isinstance(node, (list, tuple)):
            return type(node)(
                rec(v, f"{path}/{i}" if path else str(i))
                for i, v in enumerate(node)
            )
        if not isinstance(node, dict):
            return node
        spec = policy.spec(path) if path else None
        if (
            "w" in node
            and not isinstance(node["w"], dict)
            and len(node["w"].shape) in (2, 4)
            and spec is not None
            and _packable(spec)
        ):
            w = node["w"]
            if len(w.shape) == 2:
                d_in, d_out = w.shape
                meta = {}
            else:
                h, ww, c_in, d_out = w.shape
                d_in = h * ww * c_in
                meta = {"shape_hwio": jax.ShapeDtypeStruct((4,), _jnp.int64)}
            out = dict(meta)
            kw = bitpack.packed_width(d_in)
            shape = ((d_out, kw) if spec.is_binary
                     else (spec.w_bits, d_out, kw))
            out["w_packed"] = jax.ShapeDtypeStruct(shape, _jnp.uint32)
            if spec.scale:
                out["scale"] = jax.ShapeDtypeStruct((d_out,), _jnp.float32)
            if "b" in node:
                out["b"] = node["b"]
            return out
        if (
            "up" in node
            and not isinstance(node.get("up"), dict)
            and len(getattr(node.get("up"), "shape", ())) == 3
            and spec is not None
            and _packable(spec)
        ):
            out = {}
            for name, w in node.items():
                e, d_in, d_out = w.shape
                kw = bitpack.packed_width(d_in)
                shape = ((e, d_out, kw) if spec.is_binary
                         else (e, spec.w_bits, d_out, kw))
                out[name + "_packed"] = jax.ShapeDtypeStruct(
                    shape, _jnp.uint32
                )
            return out
        return {k: rec(v, f"{path}/{k}" if path else k) for k, v in node.items()}

    return rec(params, "")


def model_nbytes(params: Pytree, *, as_fp32: bool = True) -> int:
    """Size of a checkpoint in bytes (paper counts fp32 storage)."""
    total = 0
    for _, leaf in _walk(params):
        if as_fp32 and jnp.issubdtype(leaf.dtype, jnp.floating):
            total += _fp32_bytes(leaf)
        else:
            total += int(leaf.size * np.dtype(leaf.dtype).itemsize)
    return total
