"""Quantizers — BMXNet §2.1 (Eq. 1), §2.2 (binarization), §2.2.2 (Eq. 2).

All quantizers are straight-through-estimator (STE) functions: forward is the
discrete map, backward passes the gradient through (clipped for sign, as in
XNOR-Net / BinaryConnect, which BMXNet follows).

``act_bit`` semantics follow the paper exactly:
  * 32      -> identity (full precision)
  * 1       -> binarization with ``sign`` into {-1, +1}
  * 2..31   -> DoReFa linear quantization (Eq. 1) on the appropriate range
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

FULL_PRECISION = 32


def _ste(x: jax.Array, q: jax.Array) -> jax.Array:
    """Forward ``q``, gradient of identity w.r.t. ``x``."""
    return x + jax.lax.stop_gradient(q - x)


@jax.custom_vjp
def sign_ste(x: jax.Array) -> jax.Array:
    """sign into {-1,+1} with sign(0)=+1; clipped STE: dy/dx = 1[|x|<=1]."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def _sign_fwd(x):
    return sign_ste(x), x


def _sign_bwd(x, g):
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


sign_ste.defvjp(_sign_fwd, _sign_bwd)


def quantize_k(x: jax.Array, k: int) -> jax.Array:
    """Paper Eq. 1: quantize ``x`` in [0,1] onto the k-bit grid, with STE.

        quantize(input, k) = round((2^k - 1) * input) / (2^k - 1)
    """
    n = float(2**k - 1)
    return _ste(x, jnp.round(x * n) / n)


def quantize_act(x: jax.Array, bits: int) -> jax.Array:
    """QActivation: binarize (1 bit) or DoReFa-quantize activations.

    1 bit  -> sign(x) in {-1,+1}   (xnor-compatible)
    k bits -> quantize_k(clip(x, 0, 1), k)   (DoReFa activation quantizer)
    32     -> identity
    """
    if bits >= FULL_PRECISION:
        return x
    if bits == 1:
        return sign_ste(x)
    return quantize_k(jnp.clip(x, 0.0, 1.0), bits)


def quantize_weight(w: jax.Array, bits: int) -> jax.Array:
    """Weight quantizer used by QConvolution / QFullyConnected.

    1 bit  -> sign(w) in {-1,+1}
    k bits -> DoReFa: 2 * quantize_k(tanh(w)/(2 max|tanh(w)|) + 1/2, k) - 1
    32     -> identity
    """
    if bits >= FULL_PRECISION:
        return w
    if bits == 1:
        return sign_ste(w)
    t = jnp.tanh(w)
    t = t / (2.0 * jnp.max(jnp.abs(t)) + 1e-12) + 0.5
    return 2.0 * quantize_k(t, bits) - 1.0


def weight_scale(w: jax.Array, axis: int = 0) -> jax.Array:
    """Per-output-channel alpha = mean|W| (XNOR-Net style, optional in BMXNet).

    ``axis`` is the contraction (input) axis of the weight.
    """
    return jnp.mean(jnp.abs(w), axis=axis, keepdims=True)


def xnor_range_map(dot: jax.Array, n: int) -> jax.Array:
    """Paper Eq. 2: map a ±1 dot product in [-n, n] (step 2) to the
    xnor+popcount count in [0, n] (step 1): out = (dot + n) / 2."""
    return (dot + n) / 2


def dot_range_map(counts: jax.Array, n: int) -> jax.Array:
    """Inverse of Eq. 2: xnor match count -> ±1 dot product."""
    return 2 * counts - n
