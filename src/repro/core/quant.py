"""Quantizers — BMXNet §2.1 (Eq. 1), §2.2 (binarization), §2.2.2 (Eq. 2).

All quantizers are straight-through-estimator (STE) functions: forward is the
discrete map, backward passes the gradient through (clipped for sign, as in
XNOR-Net / BinaryConnect, which BMXNet follows).

``act_bit`` semantics follow the paper exactly:
  * 32      -> identity (full precision)
  * 1       -> binarization with ``sign`` into {-1, +1}
  * 2..31   -> DoReFa linear quantization (Eq. 1) on the appropriate range
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

FULL_PRECISION = 32


def _ste(x: jax.Array, q: jax.Array) -> jax.Array:
    """Forward ``q``, gradient of identity w.r.t. ``x``."""
    return x + jax.lax.stop_gradient(q - x)


@jax.custom_vjp
def sign_ste(x: jax.Array) -> jax.Array:
    """sign into {-1,+1} with sign(0)=+1; clipped STE: dy/dx = 1[|x|<=1]."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def _sign_fwd(x):
    return sign_ste(x), x


def _sign_bwd(x, g):
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


sign_ste.defvjp(_sign_fwd, _sign_bwd)


def quantize_k(x: jax.Array, k: int) -> jax.Array:
    """Paper Eq. 1: quantize ``x`` in [0,1] onto the k-bit grid, with STE.

        quantize(input, k) = round((2^k - 1) * input) / (2^k - 1)
    """
    n = float(2**k - 1)
    return _ste(x, jnp.round(x * n) / n)


def _act_unit(x: jax.Array) -> jax.Array:
    """DoReFa activation pre-transform: clip into the [0, 1] grid domain.
    Shared by :func:`quantize_act` and :func:`act_codes` so the fake-quant
    values and the packed integer codes cannot drift."""
    return jnp.clip(x, 0.0, 1.0)


def _weight_unit(w: jax.Array) -> jax.Array:
    """DoReFa weight pre-transform: ``tanh(w)/(2 max|tanh(w)|) + 1/2`` into
    [0, 1].  The max runs over the WHOLE tensor.  Shared by
    :func:`quantize_weight` and :func:`weight_codes` (same no-drift rule)."""
    t = jnp.tanh(w)
    return t / (2.0 * jnp.max(jnp.abs(t)) + 1e-12) + 0.5


def quantize_act(x: jax.Array, bits: int) -> jax.Array:
    """QActivation: binarize (1 bit) or DoReFa-quantize activations.

    1 bit  -> sign(x) in {-1,+1}   (xnor-compatible)
    k bits -> quantize_k(clip(x, 0, 1), k)   (DoReFa activation quantizer)
    32     -> identity
    """
    if bits >= FULL_PRECISION:
        return x
    if bits == 1:
        return sign_ste(x)
    return quantize_k(_act_unit(x), bits)


def quantize_weight(w: jax.Array, bits: int) -> jax.Array:
    """Weight quantizer used by QConvolution / QFullyConnected.

    1 bit  -> sign(w) in {-1,+1}
    k bits -> DoReFa: 2 * quantize_k(tanh(w)/(2 max|tanh(w)|) + 1/2, k) - 1
    32     -> identity
    """
    if bits >= FULL_PRECISION:
        return w
    if bits == 1:
        return sign_ste(w)
    return 2.0 * quantize_k(_weight_unit(w), bits) - 1.0


# ---------------------------------------------------------------------------
# Integer-code views of the DoReFa quantizers — the packed k-bit serving
# path (kernels/kbit_gemm.py) stores bit-plane stacks of these codes.  Both
# share the pre-transforms (_act_unit / _weight_unit) with the float
# quantizers and round the SAME product, so the codes and the fake-quant
# values cannot drift; tests assert quantize_act(x, k) ==
# act_codes(x, k) / (2^k - 1) and the weight analogue.
# ---------------------------------------------------------------------------


def act_codes(x: jax.Array, bits: int) -> jax.Array:
    """DoReFa activation codes: ``round(clip(x, 0, 1) * (2^bits - 1))`` as
    uint32 in [0, 2^bits - 1].  ``quantize_act(x, bits) == codes / n``.

    This function is also called INSIDE the fused quantize->pack Pallas
    prologue (``kernels/pack_bits.quant_pack_planes_pallas``) on each VMEM
    tile — pure elementwise jnp, so it traces in a kernel body — which is
    what guarantees the fused serving prologue and this jnp reference
    cannot drift.  Note x <= 0 (the dispatch layer's float pad value is
    -1.0) maps to code 0: all plane bits 0, contributing nothing to the
    plane GEMM or the row-sums."""
    n = float(2**bits - 1)
    return jnp.round(_act_unit(x) * n).astype(jnp.uint32)


def weight_codes(w: jax.Array, bits: int) -> jax.Array:
    """DoReFa weight codes (uint32 in [0, 2^bits - 1]):

        quantize_weight(w, bits) == (2 * codes - n) / n,  n = 2^bits - 1.

    ``_weight_unit``'s global max runs over the WHOLE tensor, so callers
    must pass the same tensor extent the training path quantizes (e.g. the
    full MoE expert stack, not one expert)."""
    n = float(2**bits - 1)
    return jnp.round(_weight_unit(w) * n).astype(jnp.uint32)


def weight_scale(w: jax.Array, axis: int = 0) -> jax.Array:
    """Per-output-channel alpha = mean|W| (XNOR-Net style, optional in BMXNet).

    ``axis`` is the contraction (input) axis of the weight.
    """
    return jnp.mean(jnp.abs(w), axis=axis, keepdims=True)


def xnor_range_map(dot: jax.Array, n: int) -> jax.Array:
    """Paper Eq. 2: map a ±1 dot product in [-n, n] (step 2) to the
    xnor+popcount count in [0, n] (step 1): out = (dot + n) / 2."""
    return (dot + n) / 2


def dot_range_map(counts: jax.Array, n: int) -> jax.Array:
    """Inverse of Eq. 2: xnor match count -> ±1 dot product."""
    return 2 * counts - n
