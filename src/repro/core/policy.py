"""Quantization policy — which layers compute at which bit width.

BMXNet exposes ``act_bit`` per layer and follows two structural rules the
paper validates experimentally:

* never binarize the first and the last layer (§2, confirming XNOR-Net);
* optionally keep whole *stages* full precision (Table 2's partially
  binarized ResNet-18).

Here that becomes a :class:`QuantPolicy`: an ordered list of (regex, spec)
rules over layer *paths* (e.g. ``"layers/17/mlp/up"``), with a default spec
and a set of always-full-precision patterns.  Models query
``policy.spec(path)`` for every internal GEMM.
"""

from __future__ import annotations

import dataclasses
import re

from repro.core.quant import FULL_PRECISION


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Bit widths for one GEMM: weights / activations (paper's act_bit)."""

    w_bits: int = FULL_PRECISION
    a_bits: int = FULL_PRECISION
    scale: bool = False  # XNOR-Net per-output-channel alpha (opt-in)
    xnor_range: bool = False  # apply Eq. 2 map to the layer output

    @property
    def is_binary(self) -> bool:
        return self.w_bits == 1

    @property
    def is_fp(self) -> bool:
        return self.w_bits >= FULL_PRECISION and self.a_bits >= FULL_PRECISION


FP32_SPEC = QuantSpec()


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Per-path quantization rules.  First matching rule wins; ``fp_patterns``
    beat everything (the paper's first/last-layer rule)."""

    w_bits: int = FULL_PRECISION
    a_bits: int = FULL_PRECISION
    scale: bool = False
    xnor_range: bool = False
    rules: tuple[tuple[str, QuantSpec], ...] = ()
    # first conv / embedding / classifier head stay full precision (paper §2);
    # router + elementwise-recurrence auxiliaries are not GEMMs (DESIGN §4)
    fp_patterns: tuple[str, ...] = ("embed", "lm_head", "head", "first",
                                    "frontend", "router", "rglru/conv")

    def spec(self, path: str) -> QuantSpec:
        for pat in self.fp_patterns:
            if re.search(pat, path):
                return FP32_SPEC
        for pat, spec in self.rules:
            if re.search(pat, path):
                return spec
        return QuantSpec(
            w_bits=self.w_bits,
            a_bits=self.a_bits,
            scale=self.scale,
            xnor_range=self.xnor_range,
        )

    @classmethod
    def full_precision(cls) -> "QuantPolicy":
        return cls()

    @classmethod
    def binary(cls, scale: bool = False, xnor_range: bool = False) -> "QuantPolicy":
        """The paper's BNN: 1-bit weights and activations everywhere except
        first/last."""
        return cls(w_bits=1, a_bits=1, scale=scale, xnor_range=xnor_range)

    @classmethod
    def quantized(cls, w_bits: int, a_bits: int | None = None) -> "QuantPolicy":
        """DoReFa-style k-bit (paper §2.1, 2 <= k <= 31)."""
        return cls(w_bits=w_bits, a_bits=a_bits if a_bits is not None else w_bits)

    def with_fp_stages(self, stage_patterns: tuple[str, ...]) -> "QuantPolicy":
        """Table 2: keep given stages full precision (e.g. ``("stage1",)``)."""
        rules = tuple((p, FP32_SPEC) for p in stage_patterns) + self.rules
        return dataclasses.replace(self, rules=rules)


# ---------------------------------------------------------------------------
# Step-indexed policy schedules — the BNN-training knobs of Bethge et al.
# 1809.10463, consumed by the trainer (train/trainer.PolicyScheduledStep):
# the active QuantPolicy is a pure function of the step index, and since a
# policy is jit-static each stage owns one compiled train step.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PolicySchedule:
    """Piecewise-constant ``step -> QuantPolicy`` schedule.

    ``stages`` is a sorted tuple of ``(start_step, policy)`` pairs; the
    first stage must start at 0.  ``at(step)`` returns the policy whose
    stage contains ``step``; ``stage_index`` gives the stage ordinal (the
    trainer keys its per-stage compiled steps on it).
    """

    stages: tuple[tuple[int, QuantPolicy], ...]

    def __post_init__(self):
        if not self.stages:
            raise ValueError("PolicySchedule needs at least one stage")
        starts = [s for s, _ in self.stages]
        if starts[0] != 0:
            raise ValueError(f"first stage must start at step 0, got {starts[0]}")
        if starts != sorted(starts) or len(set(starts)) != len(starts):
            raise ValueError(f"stage starts must be strictly increasing: {starts}")

    def stage_index(self, step: int) -> int:
        idx = 0
        for i, (start, _) in enumerate(self.stages):
            if step >= start:
                idx = i
        return idx

    def at(self, step: int) -> QuantPolicy:
        return self.stages[self.stage_index(step)][1]

    def boundaries(self) -> tuple[int, ...]:
        """Steps at which the active policy changes (recompile points)."""
        return tuple(s for s, _ in self.stages[1:])

    @classmethod
    def constant(cls, policy: QuantPolicy) -> "PolicySchedule":
        return cls(stages=((0, policy),))

    @classmethod
    def two_stage_binarization(
        cls,
        switch_step: int,
        *,
        stage1_a_bits: int = FULL_PRECISION,
        scale: bool = False,
        xnor_range: bool = False,
    ) -> "PolicySchedule":
        """1809.10463 two-stage training: binarize weights from step 0 but
        keep activations at ``stage1_a_bits`` (default full precision) until
        ``switch_step``, then binarize both — the activation quantizer is
        the harsher gradient bottleneck, so the weights settle first."""
        stage1 = QuantPolicy(w_bits=1, a_bits=stage1_a_bits, scale=scale,
                             xnor_range=xnor_range)
        stage2 = QuantPolicy.binary(scale=scale, xnor_range=xnor_range)
        return cls(stages=((0, stage1), (switch_step, stage2)))

    @classmethod
    def scale_schedule(
        cls, switch_step: int, *, scale_first: bool = True,
        xnor_range: bool = False,
    ) -> "PolicySchedule":
        """Scaling policy: run the XNOR-Net per-channel alpha for the first
        ``switch_step`` steps, then drop it (1809.10463 finds the scaling
        unnecessary once training stabilizes — ``scale_first=False`` flips
        the order for the ablation)."""
        on = QuantPolicy.binary(scale=True, xnor_range=xnor_range)
        off = QuantPolicy.binary(scale=False, xnor_range=xnor_range)
        first, second = (on, off) if scale_first else (off, on)
        return cls(stages=((0, first), (switch_step, second)))
