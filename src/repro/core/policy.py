"""Quantization policy — which layers compute at which bit width.

BMXNet exposes ``act_bit`` per layer and follows two structural rules the
paper validates experimentally:

* never binarize the first and the last layer (§2, confirming XNOR-Net);
* optionally keep whole *stages* full precision (Table 2's partially
  binarized ResNet-18).

Here that becomes a :class:`QuantPolicy`: an ordered list of (regex, spec)
rules over layer *paths* (e.g. ``"layers/17/mlp/up"``), with a default spec
and a set of always-full-precision patterns.  Models query
``policy.spec(path)`` for every internal GEMM.
"""

from __future__ import annotations

import dataclasses
import re

from repro.core.quant import FULL_PRECISION


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Bit widths for one GEMM: weights / activations (paper's act_bit)."""

    w_bits: int = FULL_PRECISION
    a_bits: int = FULL_PRECISION
    scale: bool = False  # XNOR-Net per-output-channel alpha (opt-in)
    xnor_range: bool = False  # apply Eq. 2 map to the layer output

    @property
    def is_binary(self) -> bool:
        return self.w_bits == 1

    @property
    def is_fp(self) -> bool:
        return self.w_bits >= FULL_PRECISION and self.a_bits >= FULL_PRECISION


FP32_SPEC = QuantSpec()


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Per-path quantization rules.  First matching rule wins; ``fp_patterns``
    beat everything (the paper's first/last-layer rule)."""

    w_bits: int = FULL_PRECISION
    a_bits: int = FULL_PRECISION
    scale: bool = False
    xnor_range: bool = False
    rules: tuple[tuple[str, QuantSpec], ...] = ()
    # first conv / embedding / classifier head stay full precision (paper §2);
    # router + elementwise-recurrence auxiliaries are not GEMMs (DESIGN §4)
    fp_patterns: tuple[str, ...] = ("embed", "lm_head", "head", "first",
                                    "frontend", "router", "rglru/conv")

    def spec(self, path: str) -> QuantSpec:
        for pat in self.fp_patterns:
            if re.search(pat, path):
                return FP32_SPEC
        for pat, spec in self.rules:
            if re.search(pat, path):
                return spec
        return QuantSpec(
            w_bits=self.w_bits,
            a_bits=self.a_bits,
            scale=self.scale,
            xnor_range=self.xnor_range,
        )

    @classmethod
    def full_precision(cls) -> "QuantPolicy":
        return cls()

    @classmethod
    def binary(cls, scale: bool = False, xnor_range: bool = False) -> "QuantPolicy":
        """The paper's BNN: 1-bit weights and activations everywhere except
        first/last."""
        return cls(w_bits=1, a_bits=1, scale=scale, xnor_range=xnor_range)

    @classmethod
    def quantized(cls, w_bits: int, a_bits: int | None = None) -> "QuantPolicy":
        """DoReFa-style k-bit (paper §2.1, 2 <= k <= 31)."""
        return cls(w_bits=w_bits, a_bits=a_bits if a_bits is not None else w_bits)

    def with_fp_stages(self, stage_patterns: tuple[str, ...]) -> "QuantPolicy":
        """Table 2: keep given stages full precision (e.g. ``("stage1",)``)."""
        rules = tuple((p, FP32_SPEC) for p in stage_patterns) + self.rules
        return dataclasses.replace(self, rules=rules)
