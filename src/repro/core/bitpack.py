"""Bit-packing utilities — BMXNet §2.2 / §2.2.3.

The paper packs 32 (x86/ARMv7) or 64 (x64) binary weights into one machine
word (``BINARY_WORD``).  On TPU the natural lane type is ``uint32`` so we use
WORD_BITS = 32 everywhere.

Conventions (shared by the jnp reference, the Pallas kernels and the model
converter — tests enforce them):

* a binary value is ``+1`` iff the stored bit is ``1``; ``-1`` iff ``0``.
* ``sign(0) == +1`` (i.e. the bit for ``x >= 0`` is 1).
* packing is always along the **last** axis; for a GEMM ``A(M,K) @ B(K,N)``
  both operands are packed along K, with B stored transposed as ``(N, Kw)``.
* when K is not a multiple of 32 the tail bits are **0 in both operands**, so
  they contribute 0 to the xor-mismatch count and the dot product
  ``dot = K_true - 2 * mismatches`` stays exact.  ``K_true`` therefore has to
  travel with packed tensors (the converter records it).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32
WORD_DTYPE = jnp.uint32


def packed_width(k: int) -> int:
    """Number of uint32 words needed to store ``k`` bits."""
    return (k + WORD_BITS - 1) // WORD_BITS


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack a boolean array along its last axis into uint32 words.

    ``bits[..., k]`` becomes bit ``k % 32`` of word ``k // 32``.  The tail of
    the final word is zero-padded.
    """
    *lead, k = bits.shape
    kw = packed_width(k)
    pad = kw * WORD_BITS - k
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros((*lead, pad), dtype=bits.dtype)], axis=-1
        )
    bits = bits.reshape(*lead, kw, WORD_BITS).astype(WORD_DTYPE)
    shifts = jnp.arange(WORD_BITS, dtype=WORD_DTYPE)
    return (bits << shifts).sum(axis=-1, dtype=WORD_DTYPE)


def unpack_bits(words: jax.Array, k_true: int) -> jax.Array:
    """Inverse of :func:`pack_bits`; returns bool ``(..., k_true)``."""
    shifts = jnp.arange(WORD_BITS, dtype=WORD_DTYPE)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    *lead, kw, _ = bits.shape
    return bits.reshape(*lead, kw * WORD_BITS)[..., :k_true].astype(bool)


def pack_sign(x: jax.Array) -> jax.Array:
    """Binarize ``x`` with sign (>= 0 -> +1) and pack along the last axis."""
    return pack_bits(x >= 0)


def unpack_sign(words: jax.Array, k_true: int, dtype=jnp.float32) -> jax.Array:
    """Unpack to ±1 values of ``dtype``."""
    bits = unpack_bits(words, k_true)
    return jnp.where(bits, jnp.ones((), dtype), -jnp.ones((), dtype))


def pack_planes(codes: jax.Array, bits: int) -> jax.Array:
    """Split k-bit unsigned ``codes`` (..., K) into ``bits`` bit planes and
    pack each along the last axis: returns (bits, ..., Kw) uint32.

    Plane ``i`` holds bit ``i`` of every code (LSB first), packed exactly
    like the 1-bit operands (:func:`pack_bits`), so the k-bit GEMM kernels
    reuse the same word layout — tail bits of the last word are 0 in every
    plane, and AND against zero words contributes nothing (the k-bit path
    needs no pad correction).

    On the serving hot path this jnp round trip only runs for WEIGHTS at
    convert time: activations go through the fused one-pass Pallas
    prologue (``kernels/pack_bits.quant_pack_planes_pallas``), which this
    function is the bit-identity oracle for (the CI pack_prologue gate)."""
    codes = codes.astype(WORD_DTYPE)
    return jnp.stack(
        [pack_bits((codes >> jnp.uint32(i)) & jnp.uint32(1))
         for i in range(bits)],
        axis=0,
    )


def unpack_planes(planes: jax.Array, k_true: int) -> jax.Array:
    """Inverse of :func:`pack_planes`: (bits, ..., Kw) -> (..., k_true)
    uint32 codes."""
    bits = planes.shape[0]
    codes = None
    for i in range(bits):
        b = unpack_bits(planes[i], k_true).astype(WORD_DTYPE) << jnp.uint32(i)
        codes = b if codes is None else codes + b
    return codes


def packed_nbytes(shape: tuple[int, ...]) -> int:
    """Bytes used by a packed tensor whose *unpacked* shape is ``shape``.

    Packing is along the last axis; words are 4 bytes.
    """
    *lead, k = shape
    return int(np.prod(lead, dtype=np.int64)) * packed_width(k) * 4
