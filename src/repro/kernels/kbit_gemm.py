"""k-bit packed GEMM Pallas kernels — the DoReFa (paper Eq. 1, 2..31-bit)
serving path, executed as bit-plane popcount GEMM.

A k-bit unsigned code ``n = sum_i 2^i b_i`` splits into k bit planes, each
packed into uint32 words exactly like the 1-bit operands
(``core/bitpack.pack_planes`` for weights at convert time; activations
arrive through the FUSED quantize->plane-pack prologue,
``kernels/pack_bits.quant_pack_planes_pallas``, which also emits the code
row-sums T below — the serving hot path never materializes the (M, K)
code tensor).  The integer GEMM of activation codes ``n_a`` against
weight codes ``n_w`` then decomposes into per-plane-pair AND+popcount
passes (the daBNN-style generalization of the paper's xnor+popcount
Listing 3):

    S[m, n] = sum_{i < ka, j < kb} 2^(i+j) * popcount(A_i[m] & B_j[n])

``kernels/dispatch.py`` recovers the fake-quant DoReFa dot outside as

    dot = (2*S - Nw*T) / (Na*Nw),   N* = 2^bits - 1,

with ``T[m] = sum_k n_a[m, k]`` the activation code row-sums — because
``a_q = n_a/Na`` and ``w_q = (2*n_w - Nw)/Nw`` (Eq. 1's activation and
weight grids).  That single rewrite is what keeps the packed serving path
bit-exact (to fp32 rounding) with the fake-quant train path, the same
§2.2.2 argument the 1-bit path makes.

Unlike both 1-bit kernels there is NO pad correction: tail/pad bits are 0
in every plane of both operands and AND against a zero word contributes 0.
That also makes the raw S **K-partial-safe** at any split point: S over
disjoint Kw slices sums exactly (integer adds; zero pad words introduced
by a split contribute 0), so the tensor-parallel ``shard-vpu-k*`` dispatch
backends partition Kw across mesh shards and ``psum`` the per-shard S with
no correction term anywhere — the dequant rewrite runs once on the sum.
The row-sums T are K-partial-safe for the same reason (integer sums of
codes over disjoint K slabs; pad floats quantize to code 0), which is what
lets the shard family run the fused quantize->pack prologue INSIDE its
shard_map body and psum (S, T) pairs.

int32 accumulator bound: ``S <= K * Na * Nw``, and the dequant numerator
``2S - Nw*T`` doubles it — dispatch rejects ``2 * K * Na * Nw >= 2^31``
at trace time (w8a8: K < ~16.5k; w4a4: K < ~4.7M).  The bound's *shape*
differs between the two k-bit executions even though the ceiling is the
same number: THIS kernel accumulates each plane-pair popcount pass
separately (each pass sums at most K ones; the ``2^(i+j)`` weights are
applied to the finished pass), so no intermediate ever exceeds the final
S — whereas the int8 code-lane MXU path (kernels/kbit_mxu.py, the
``mxu-k*`` backends) accumulates the FULL code dot ``<= K * Na * Nw`` in
ONE int32 partial per output element.  Dispatch therefore re-derives the
check per family (``_check_kbit_accumulator`` vs
``_check_kbit_accumulator_mxu``) so an overflowing decode config fails
naming the path that actually wraps.

Both kernels tile (M, N, K) with a sequential-K innermost grid axis and the
plane dimension carried whole in each block (ka/kb <= 8 planes: a (8, 128,
16)-word block is 64 KiB of VMEM), the same grid pattern as xnor_gemm.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BKW = 16  # words: 16 * 32 = 512 binary values per plane per K-step


def _plane_popcount(a_ref, b_ref, out_shape, chunk_words, a_idx=None,
                    b_idx=None):
    """Accumulate 2^(i+j)-weighted AND popcounts over every plane pair of
    one K-block.  ``a_idx``/``b_idx`` prefix-index the expert dim of the
    batched refs (None for the 2D kernel)."""
    ka = a_ref.shape[1] if a_idx is not None else a_ref.shape[0]
    kb = b_ref.shape[1] if b_idx is not None else b_ref.shape[0]
    bkw = a_ref.shape[-1]
    n_chunks = bkw // chunk_words

    acc = jnp.zeros(out_shape, jnp.int32)
    for i in range(ka):
        for j in range(kb):

            def body(c, pacc, i=i, j=j):
                sl = pl.ds(c * chunk_words, chunk_words)
                a = (a_ref[a_idx, i, :, sl] if a_idx is not None
                     else a_ref[i, :, sl])  # (bm, cw)
                b = (b_ref[b_idx, j, :, sl] if b_idx is not None
                     else b_ref[j, :, sl])  # (bn, cw)
                x = a[:, None, :] & b[None, :, :]  # (bm, bn, cw)
                pc = jax.lax.population_count(x).astype(jnp.int32).sum(-1)
                return pacc + pc

            pc = jax.lax.fori_loop(
                0, n_chunks, body, jnp.zeros(out_shape, jnp.int32)
            )
            acc = acc + (1 << (i + j)) * pc
    return acc


def _kbit_kernel(a_ref, b_ref, out_ref, *, chunk_words: int):
    """One (bm, bn) tile: weighted plane popcounts over this K-block."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += _plane_popcount(a_ref, b_ref, out_ref.shape, chunk_words)


def _grid_call(kernel, a_planes, b_planes, bm, bn, bkw, interpret):
    ka, m, kw = a_planes.shape
    kb, n, kw_b = b_planes.shape
    assert kw == kw_b, (kw, kw_b)
    assert m % bm == 0 and n % bn == 0 and kw % bkw == 0, (
        f"shapes must be pre-padded to block multiples: "
        f"M={m}%{bm}, N={n}%{bn}, Kw={kw}%{bkw}"
    )
    grid = (m // bm, n // bn, kw // bkw)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ka, bm, bkw), lambda i, j, k: (0, i, k)),
            pl.BlockSpec((kb, bn, bkw), lambda i, j, k: (0, j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(a_planes, b_planes)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bkw", "chunk_words", "interpret")
)
def kbit_plane_gemm_pallas(
    a_planes: jax.Array,  # (ka, M, Kw) uint32, M % bm == 0, Kw % bkw == 0
    b_planes: jax.Array,  # (kb, N, Kw) uint32, N % bn == 0
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bkw: int = DEFAULT_BKW,
    chunk_words: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """Weighted bit-plane AND popcount GEMM: returns S (M, N) int32."""
    kernel = functools.partial(_kbit_kernel, chunk_words=chunk_words)
    return _grid_call(kernel, a_planes, b_planes, bm, bn, bkw, interpret)


# ---------------------------------------------------------------------------
# Batched (expert-stacked) variant — the MoE grouped k-bit GEMM: a leading
# grid axis iterates the expert dimension, same inner tiles.
# ---------------------------------------------------------------------------


def _kbit_kernel_batched(a_ref, b_ref, out_ref, *, chunk_words: int):
    """One (1, bm, bn) tile of one expert."""
    k_step = pl.program_id(3)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[0, :, :] += _plane_popcount(
        a_ref, b_ref, out_ref.shape[1:], chunk_words, a_idx=0, b_idx=0
    )


def _grid_call_batched(kernel, a_planes, b_planes, bm, bn, bkw, interpret):
    e, ka, m, kw = a_planes.shape
    e_b, kb, n, kw_b = b_planes.shape
    assert e == e_b and kw == kw_b, (a_planes.shape, b_planes.shape)
    assert m % bm == 0 and n % bn == 0 and kw % bkw == 0, (
        f"shapes must be pre-padded to block multiples: "
        f"M={m}%{bm}, N={n}%{bn}, Kw={kw}%{bkw}"
    )
    grid = (e, m // bm, n // bn, kw // bkw)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ka, bm, bkw), lambda g, i, j, k: (g, 0, i, k)),
            pl.BlockSpec((1, kb, bn, bkw), lambda g, i, j, k: (g, 0, j, k)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, i, j, k: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, m, n), jnp.int32),
        interpret=interpret,
    )(a_planes, b_planes)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bkw", "chunk_words", "interpret")
)
def kbit_plane_gemm_batched_pallas(
    a_planes: jax.Array,  # (E, ka, M, Kw) uint32, pre-padded
    b_planes: jax.Array,  # (E, kb, N, Kw) uint32
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bkw: int = DEFAULT_BKW,
    chunk_words: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """Expert-batched weighted plane popcount: (E, M, N) int32 S."""
    kernel = functools.partial(_kbit_kernel_batched, chunk_words=chunk_words)
    return _grid_call_batched(kernel, a_planes, b_planes, bm, bn, bkw,
                              interpret)
