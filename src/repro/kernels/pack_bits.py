"""Pallas kernel: binarize (sign) + bit-pack along the last axis.

This is the "binarize input" stage the paper measures in Figure 1
(``binarize input and xnor_64_omp``): activations arrive as floats and must
be packed before the xnor GEMM.  One fused VMEM pass: read a (bm, bkw*32)
float tile, emit a (bm, bkw) uint32 tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bitpack import WORD_BITS

DEFAULT_BM = 256
DEFAULT_BKW = 32  # words per block: 32 * 32 = 1024 floats per row-block


def _pack_kernel(x_ref, out_ref):
    x = x_ref[...]  # (bm, bkw * 32) float
    bm, kbits = x.shape
    bits = (x >= 0).astype(jnp.uint32).reshape(bm, kbits // WORD_BITS, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    out_ref[...] = (bits << shifts).sum(axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("bm", "bkw", "interpret"))
def pack_sign_pallas(
    x: jax.Array,  # (M, K) float; M % bm == 0, K % (bkw*32) == 0 (pre-padded)
    *,
    bm: int = DEFAULT_BM,
    bkw: int = DEFAULT_BKW,
    interpret: bool = True,
) -> jax.Array:
    """Returns (M, K/32) uint32.  Pad K with negative values (bit 0) first;
    ops.py handles the padding so pad bits are 0 in both GEMM operands."""
    m, k = x.shape
    kb = bkw * WORD_BITS
    assert m % bm == 0 and k % kb == 0, (m, bm, k, kb)
    grid = (m // bm, k // kb)
    return pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, kb), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bkw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k // WORD_BITS), jnp.uint32),
        interpret=interpret,
    )(x)
