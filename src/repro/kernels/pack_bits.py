"""Pallas kernels: the fused quantize -> pack activation prologue family.

This is the "binarize input" stage the paper measures in Figure 1
(``binarize input and xnor_64_omp``): activations arrive as floats and must
be quantized and packed before the packed GEMM.  daBNN (Zhang et al., 2019)
attributes most of its speedup to fusing exactly this stage into the GEMM's
data path instead of running it as separate HBM round-trips — the same
argument applies here, so every member of the family is ONE VMEM pass:

``pack_sign_pallas``
    1-bit: read a (bm, bkw*32) float tile, emit a (bm, bkw) uint32 tile of
    sign bits (x >= 0 -> bit 1, the core/bitpack.py convention).

``quant_pack_planes_pallas``
    k-bit (DoReFa Eq. 1): read the same float tile, quantize to integer
    codes via ``quant.act_codes`` (clip to [0, 1], scale, round — called
    directly so the kernel CANNOT drift from the fake-quant train path),
    split into ``a_bits`` bit planes and word-pack each, emitting a
    (a_bits, bm, bkw) plane-stack tile PLUS the int32 code row-sums T the
    dequant rewrite ``(2S - Nw*T)/(Na*Nw)`` needs — so the jnp
    ``act_codes`` -> ``pack_planes`` round trip (three full HBM passes)
    never materializes the (M, K) code tensor.

Both kernels require pre-padded inputs (M to bm, K to bkw*32); pad floats
with a NEGATIVE value so pad bits are 0 (1-bit) / code 0 (k-bit) — zero in
both GEMM operands, contributing nothing (see core/bitpack.py).

``interpret=None`` reads REPRO_PALLAS_INTERPRET like the GEMM kernels —
callers thread ``GemmConfig.interpret`` through ``kernels/dispatch`` so a
real-TPU config compiles the pack stage too instead of silently
interpreting it.

One plane stack serves BOTH k-bit GEMM families — the ``vpu-k*`` plane
popcount kernels and the ``mxu-k*`` int8 code-lane kernels
(kernels/kbit_mxu.py) consume identical (a_bits, M, Kw) stacks + T, so
backend selection never changes this prologue.  Under the tensor-parallel
``"k"`` layout this pass runs INSIDE the shard_map body on each shard's
local K-slab; with ``GemmConfig.overlap_collective`` it is also the
compute the PREVIOUS layer's in-flight ring reduction hides behind —
dispatch's chunked ppermute schedule removes the monolithic psum barrier
that used to separate one layer's reduction from the next layer's pack
(see ``dispatch._ring_chunk_reduce``).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import quant
from repro.core.bitpack import WORD_BITS

DEFAULT_BM = 256
DEFAULT_BKW = 32  # words per block: 32 * 32 = 1024 floats per row-block


def _env_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


def _resolve_interpret(interpret: bool | None) -> bool:
    return interpret if interpret is not None else _env_interpret()


def _pack_words(bits: jax.Array) -> jax.Array:
    """(bm, n_words, 32) {0,1} uint32 -> (bm, n_words) packed words."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return (bits << shifts).sum(axis=-1, dtype=jnp.uint32)


def _pack_kernel(x_ref, out_ref):
    x = x_ref[...]  # (bm, bkw * 32) float
    bm, kbits = x.shape
    bits = (x >= 0).astype(jnp.uint32).reshape(bm, kbits // WORD_BITS,
                                               WORD_BITS)
    out_ref[...] = _pack_words(bits)


@functools.partial(jax.jit, static_argnames=("bm", "bkw", "interpret"))
def pack_sign_pallas(
    x: jax.Array,  # (M, K) float; M % bm == 0, K % (bkw*32) == 0 (pre-padded)
    *,
    bm: int = DEFAULT_BM,
    bkw: int = DEFAULT_BKW,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns (M, K/32) uint32.  Pad K with negative values (bit 0) first;
    dispatch.pack_activations handles the padding so pad bits are 0 in both
    GEMM operands."""
    m, k = x.shape
    kb = bkw * WORD_BITS
    assert m % bm == 0 and k % kb == 0, (m, bm, k, kb)
    grid = (m // bm, k // kb)
    return pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, kb), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bkw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k // WORD_BITS), jnp.uint32),
        interpret=_resolve_interpret(interpret),
    )(x)


# ---------------------------------------------------------------------------
# k-bit: fused DoReFa quantize -> bit-plane pack (+ code row-sums)
# ---------------------------------------------------------------------------


def _quant_pack_planes_kernel(x_ref, planes_ref, tsum_ref, *, a_bits: int):
    """One (bm, bkw*32) float tile -> (a_bits, bm, bkw) plane words and the
    running int32 code row-sums (accumulated over the sequential K axis)."""
    x = x_ref[...]  # (bm, bkw * 32) float
    bm, kbits = x.shape
    codes = quant.act_codes(x, a_bits)  # (bm, kbits) uint32 — Eq. 1 codes
    cw = codes.reshape(bm, kbits // WORD_BITS, WORD_BITS)
    for i in range(a_bits):
        planes_ref[i, :, :] = _pack_words((cw >> jnp.uint32(i)) & jnp.uint32(1))

    k_step = pl.program_id(1)

    @pl.when(k_step == 0)
    def _init():
        tsum_ref[...] = jnp.zeros_like(tsum_ref)

    tsum_ref[...] += codes.astype(jnp.int32).sum(axis=-1, keepdims=True)


@functools.partial(
    jax.jit, static_argnames=("a_bits", "bm", "bkw", "interpret")
)
def quant_pack_planes_pallas(
    x: jax.Array,  # (M, K) float, pre-padded (M % bm == 0, K % (bkw*32) == 0)
    a_bits: int,
    *,
    bm: int = DEFAULT_BM,
    bkw: int = DEFAULT_BKW,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused DoReFa activation prologue: quantize (clip -> codes) and
    plane-pack in one VMEM pass.

    Returns ``(planes, t_sum)``: an (a_bits, M, K/32) uint32 plane stack
    (bit-identical to ``bitpack.pack_planes(quant.act_codes(x, a_bits))``)
    and the (M, 1) int32 code row-sums.  Pad K with negative floats (code
    0) so pad bits are 0 in every plane and contribute 0 to both the plane
    GEMM and T."""
    m, k = x.shape
    kb = bkw * WORD_BITS
    assert m % bm == 0 and k % kb == 0, (m, bm, k, kb)
    assert 2 <= a_bits <= 8, a_bits
    grid = (m // bm, k // kb)  # K innermost: sequential row-sum accumulation
    kernel = functools.partial(_quant_pack_planes_kernel, a_bits=a_bits)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, kb), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((a_bits, bm, bkw), lambda i, j: (0, i, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((a_bits, m, k // WORD_BITS), jnp.uint32),
            jax.ShapeDtypeStruct((m, 1), jnp.int32),
        ],
        interpret=_resolve_interpret(interpret),
    )(x)
