"""Gather-free fused decode attention: a Pallas flash-decode kernel over
the KV cache's own storage — paged pool blocks consumed THROUGH the
per-slot block tables, or contiguous slabs viewed as an identity-table
pool — with optional quantized (int8 / 1-bit-scaled) KV dequantized per
block tile in VMEM.

Why this kernel exists: the serving hot path used to call
``kv.gather(cache)`` every decode step, every layer — on the paged layout
that materialises a full dense ``(B, cache_len, KVH, Dh)`` K AND V copy
via pool indexing before ``_sdpa`` sweeps the entire static cache length
under a mask.  Attention was the dominant per-step byte mover (the GEMMs
are packed; the KV was not).  This kernel reads each mapped cache block
in place exactly once:

* **grid** ``(B, KVH, ceil(bps / spb))`` — batch x kv-head x split-KV
  steps, the split axis innermost and sequential, so the online-softmax
  running state (m, l, acc) lives in VMEM scratch across the splits of
  one (b, h) pair and the partial-max/sum combine happens as the splits
  retire; the normalised output is written once at the last split.
* **block tables** — each split step covers ``spb`` table entries of the
  query's slot.  Unmapped entries (-1: slot shorter than the table, or a
  retired slot) are skipped at the grid level (``pl.when`` — no loads,
  no FLOPs), which is also what keeps junk blocks out of the softmax:
  a skipped block contributes exactly nothing to (m, l, acc).
* **per-row length masking** — ``pool_pos`` rides along per block; rows
  carry -1 for never-written / truncated / write-masked positions and the
  in-kernel mask reproduces ``nn/attention._mask`` exactly (pos >= 0,
  causal, sliding window), so ragged lengths, speculative rollback and
  retired rows all fall out of the position plane.
* **quantized KV** (``kv_bits``): 8 -> int8 codes + per-(head, dh-group)
  absmax scales; 1 -> sign bytes (8 lanes per uint8) + per-head alpha
  (the XNOR tier, mean-|x| a la BMXNet Eq. 1).  The kernel dequantises
  one (block_size, Dh) tile at a time in VMEM — HBM only ever moves the
  narrow codes, 2-4x (int8) to ~16x (1-bit) fewer KV bytes per step.

The contiguous layout routes through the SAME kernel: a ``(B, L, ...)``
slab reshapes (free) to a ``(B * L/t, t, ...)`` pool with an arange block
table, where the tile ``t`` is the autotunable split-KV block.  Queries
are a ``(B, C)`` tile — C == 1 is plain decode, C > 1 is the chunked-
prefill / speculative-verify window (per-row causal masking from the
absolute positions, exactly like the jnp path).

Like every kernel here it runs in interpret mode on CPU hosts
(REPRO_PALLAS_INTERPRET, same convention as pack_bits.py).  On real TPUs
the scalar block-table reads belong in SMEM via
``pltpu.PrefetchScalarGridSpec`` — a lowering detail the interpret rig
does not exercise; the dynamic-index loads below are the portable
spelling.

Numerics vs the gather oracle (``kv.gather`` + ``_sdpa``): scores and
softmax run in fp32 with the same scale/softcap/mask semantics; only the
summation ORDER differs (block-wise online rescale vs one full-length
softmax), so fp-KV results agree to tight fp32 allclose — the CI bench
family gates that, plus greedy token identity on the serve rig.
Quantized-KV rows agree with the oracle reading the SAME quantized pool
(both dequantise identical codes) and carry a measured error bound vs
the fp reference.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38

DEFAULT_CTG_TILE = 512  # contiguous split-KV tile (tokens per grid step)
DEFAULT_PGD_SPB = 4  # paged table entries per grid step


def _env_interpret() -> bool:
    """Pallas interpret-mode default (shared convention: pack_bits.py)."""
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


def _resolve_interpret(interpret: bool | None) -> bool:
    return _env_interpret() if interpret is None else bool(interpret)


# ---------------------------------------------------------------------------
# Quantized KV storage codecs — shared by the cache write paths
# (nn/attention.py quantises on fill) and the in-kernel dequant below.
# ---------------------------------------------------------------------------


def kv_scale_groups(d_head: int) -> int:
    """dh-group count for the int8 absmax scales: 32-channel groups when
    Dh divides, else one group per head (smoke heads are Dh=16)."""
    return d_head // 32 if d_head % 32 == 0 else 1


def kv_quantize(bits: int, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """fp (..., KVH, Dh) -> (codes, scale).

    * bits == 8: int8 codes (..., KVH, Dh), fp32 absmax scales
      (..., KVH, n_groups) per (head, dh-group) — symmetric round-to-
      nearest, absmax/127.
    * bits == 1: sign bytes (..., KVH, Dh/8) uint8 (lane i of byte w is
      element 8w+i, sign(0) = +1) + per-head fp32 alpha (..., KVH) =
      mean |x| over Dh (XNOR-Net Eq. 1 applied to the cache).
    """
    xf = x.astype(jnp.float32)
    dh = x.shape[-1]
    if bits == 8:
        g = kv_scale_groups(dh)
        grp = xf.reshape(*x.shape[:-1], g, dh // g)
        amax = jnp.abs(grp).max(axis=-1)
        scale = jnp.maximum(amax / 127.0, 1e-30)
        codes = jnp.clip(jnp.round(grp / scale[..., None]), -127, 127)
        return codes.reshape(x.shape).astype(jnp.int8), scale
    if bits == 1:
        if dh % 8:
            raise ValueError(f"kv_bits=1 needs d_head % 8 == 0, got {dh}")
        alpha = jnp.abs(xf).mean(axis=-1)
        bits_ = (xf >= 0).astype(jnp.uint8).reshape(*x.shape[:-1], dh // 8, 8)
        weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
        words = (bits_ * weights).sum(axis=-1, dtype=jnp.uint8)
        return words, alpha
    raise ValueError(f"kv_bits must be 8 or 1, got {bits}")


def kv_dequantize(bits: int, codes: jax.Array, scale: jax.Array,
                  d_head: int, dtype=jnp.float32) -> jax.Array:
    """Invert :func:`kv_quantize`: (codes, scale) -> fp (..., KVH, Dh)."""
    if bits == 8:
        g = kv_scale_groups(d_head)
        grp = codes.astype(jnp.float32).reshape(
            *codes.shape[:-1], g, d_head // g)
        return (grp * scale[..., None]).reshape(
            *codes.shape[:-1], d_head).astype(dtype)
    if bits == 1:
        shifts = jnp.arange(8, dtype=jnp.uint8)
        b = (codes[..., None] >> shifts) & jnp.uint8(1)
        signs = (2.0 * b.astype(jnp.float32) - 1.0).reshape(
            *codes.shape[:-1], d_head)
        return (signs * scale[..., None]).astype(dtype)
    raise ValueError(f"kv_bits must be 8 or 1, got {bits}")


def kv_code_shapes(bits: int | None, kvh: int, dh: int, dtype):
    """Per-token trailing (shape, dtype) pairs for the K (or V) leaf and
    its scale leaf under a given storage tier; scale entry is None for fp.
    Used by both cache layouts' ``init`` so allocation cannot drift from
    the codec."""
    if bits is None:
        return ((kvh, dh), dtype), None
    if bits == 8:
        return ((kvh, dh), jnp.int8), ((kvh, kv_scale_groups(dh)),
                                       jnp.float32)
    if bits == 1:
        if dh % 8:
            raise ValueError(f"kv_bits=1 needs d_head % 8 == 0, got {dh}")
        return ((kvh, dh // 8), jnp.uint8), ((kvh,), jnp.float32)
    raise ValueError(f"kv_bits must be None, 8 or 1, got {bits}")


# ---------------------------------------------------------------------------
# The kernel
# ---------------------------------------------------------------------------


def _dequant_tile(kv_bits, codes, scale, dh):
    """One (bs, Dh-coded) VMEM tile -> (bs, Dh) fp32."""
    if kv_bits is None:
        return codes.astype(jnp.float32)
    return kv_dequantize(kv_bits, codes, scale, dh, jnp.float32)


def _make_kernel(*, c, g, dh, bs, bps, spb, n_steps, kv_bits, sm_scale,
                 cap, causal, window):
    """Build the flash-decode kernel body for one static configuration.

    Ref order: table, q_pos, q, [pool_k, (k_scale)], [pool_v, (v_scale)],
    pool_pos, out, then scratch m/l/acc.  All compile-time shape knobs
    arrive through the closure — the repo's kernels are traced per jitted
    configuration anyway.
    """
    cg = c * g

    def kernel(tab_ref, qp_ref, q_ref, *refs):
        if kv_bits is None:
            pk_ref, pv_ref, pp_ref, o_ref, m_ref, l_ref, acc_ref = refs
            ks_ref = vs_ref = None
        else:
            (pk_ref, ks_ref, pv_ref, vs_ref, pp_ref, o_ref,
             m_ref, l_ref, acc_ref) = refs
        h = pl.program_id(1)
        j = pl.program_id(2)

        @pl.when(j == 0)
        def _init():
            m_ref[...] = jnp.full((cg, 1), NEG_INF, jnp.float32)
            l_ref[...] = jnp.zeros((cg, 1), jnp.float32)
            acc_ref[...] = jnp.zeros((cg, dh), jnp.float32)

        qt = q_ref[0, :, 0, :, :].reshape(cg, dh).astype(jnp.float32)
        qp = jnp.repeat(qp_ref[0, :], g).reshape(cg, 1)

        for e in range(spb):
            jj = j * spb + e
            jjc = jnp.minimum(jj, bps - 1)
            blk = tab_ref[0, jjc]
            # grid-level skip: unmapped (-1) table entries and the ragged
            # tail of the last split step cost nothing and add nothing
            mapped = (jj < bps) & (blk >= 0)

            @pl.when(mapped)
            def _accumulate():
                # head indexing happens HERE, not in the pool BlockSpecs:
                # grid-invariant full-pool blocks let the interpret rig's
                # XLA while-loop hoist the pool materialisation out of the
                # grid loop (a per-head BlockSpec slice would be a strided
                # copy per grid step); a TPU lowering would instead DMA
                # `tab[b, jj]`-indexed blocks via PrefetchScalarGridSpec.
                ksc = None if ks_ref is None else ks_ref[blk, :, h]
                vsc = None if vs_ref is None else vs_ref[blk, :, h]
                kt = _dequant_tile(kv_bits, pk_ref[blk, :, h, :], ksc, dh)
                vt = _dequant_tile(kv_bits, pv_ref[blk, :, h, :], vsc, dh)
                kp = pp_ref[blk, :].reshape(1, bs)
                s = jnp.dot(qt, kt.T,
                            preferred_element_type=jnp.float32) * sm_scale
                if cap is not None:
                    s = cap * jnp.tanh(s / cap)
                valid = kp >= 0  # empty / truncated rows carry pos -1
                if causal:
                    valid &= kp <= qp
                if window is not None:
                    valid &= kp > qp - window
                s = jnp.where(valid, s, NEG_INF)
                m_new = jnp.maximum(m_ref[...], s.max(axis=1, keepdims=True))
                alpha = jnp.exp(m_ref[...] - m_new)
                p = jnp.exp(s - m_new)
                l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
                acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
                    p, vt, preferred_element_type=jnp.float32)
                m_ref[...] = m_new

        @pl.when(j == n_steps - 1)
        def _finish():
            # combine: the splits' partial (m, l, acc) have already been
            # merged by the running rescale; normalise and emit.  Fully
            # masked rows (l == 0: empty slot) emit zeros — callers only
            # consume active rows (same contract as write_mask).
            out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-37)
            o_ref[...] = out.reshape(1, c, 1, g, dh)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("block_size", "kv_bits", "sm_scale", "logit_softcap",
                     "causal", "window", "blocks_per_step", "interpret"))
def flash_decode_paged(
    table: jax.Array,  # (B, bps) int32 block ids, -1 = unmapped
    q: jax.Array,  # (B, C, KVH, G, Dh)
    q_pos: jax.Array,  # (B, C) int32 absolute query positions
    pool_k: jax.Array,  # (nb, bs, KVH, Dh) fp | int8 codes | uint8 signs
    pool_v: jax.Array,
    pool_pos: jax.Array,  # (nb, bs) int32, -1 = empty
    k_scale: jax.Array | None = None,  # (nb, bs, KVH[, groups]) fp32
    v_scale: jax.Array | None = None,
    *,
    block_size: int,
    kv_bits: int | None = None,
    sm_scale: float,
    logit_softcap: float | None = None,
    causal: bool = True,
    window: int | None = None,
    blocks_per_step: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused paged flash-decode attention: (B, C, KVH, G, Dh) fp32 out.

    Consumes the paged pool directly through ``table`` — no dense gather;
    see the module docstring for grid/mask/quantisation semantics."""
    b, c, kvh, g, dh = q.shape
    nb, bs = pool_pos.shape
    bps = table.shape[1]
    assert bs == block_size, (bs, block_size)
    spb = blocks_per_step or min(DEFAULT_PGD_SPB, bps)
    n_steps = -(-bps // spb)
    cg = c * g

    grid = (b, kvh, n_steps)
    code_dh = pool_k.shape[-1]
    in_specs = [
        pl.BlockSpec((1, bps), lambda b_, h, j: (b_, 0)),
        pl.BlockSpec((1, c), lambda b_, h, j: (b_, 0)),
        pl.BlockSpec((1, c, 1, g, dh), lambda b_, h, j: (b_, 0, h, 0, 0)),
    ]
    operands = [table, q_pos, q]
    # pool blocks are the FULL arrays at a grid-invariant index — the
    # kernel body does the (block, head) indexing, so the interpret rig
    # hoists the pool materialisation out of the grid loop (see kernel)
    pool_spec = pl.BlockSpec((nb, bs, kvh, code_dh),
                             lambda b_, h, j: (0, 0, 0, 0))
    if kv_bits is None:
        in_specs += [pool_spec, pool_spec]
        operands += [pool_k, pool_v]
    else:
        if kv_bits == 8:
            ng = kv_scale_groups(dh)
            sc_spec = pl.BlockSpec((nb, bs, kvh, ng),
                                   lambda b_, h, j: (0, 0, 0, 0))
        else:
            sc_spec = pl.BlockSpec((nb, bs, kvh),
                                   lambda b_, h, j: (0, 0, 0))
        in_specs += [pool_spec, sc_spec, pool_spec, sc_spec]
        operands += [pool_k, k_scale, pool_v, v_scale]
    in_specs.append(pl.BlockSpec((nb, bs), lambda b_, h, j: (0, 0)))
    operands.append(pool_pos)

    kernel = _make_kernel(
        c=c, g=g, dh=dh, bs=bs, bps=bps, spb=spb, n_steps=n_steps,
        kv_bits=kv_bits, sm_scale=sm_scale, cap=logit_softcap,
        causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, c, 1, g, dh),
                               lambda b_, h, j: (b_, 0, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c, kvh, g, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((cg, 1), jnp.float32),
            pltpu.VMEM((cg, 1), jnp.float32),
            pltpu.VMEM((cg, dh), jnp.float32),
        ],
        interpret=_resolve_interpret(interpret),
    )(*operands)


def flash_decode_contig(
    q: jax.Array,  # (B, C, KVH, G, Dh)
    q_pos: jax.Array,  # (B, C)
    k: jax.Array,  # (B, L, KVH, Dh) fp | codes
    v: jax.Array,
    slot_pos: jax.Array,  # (B, L) int32
    k_scale: jax.Array | None = None,  # (B, L, KVH[, groups])
    v_scale: jax.Array | None = None,
    *,
    kv_bits: int | None = None,
    sm_scale: float,
    logit_softcap: float | None = None,
    causal: bool = True,
    window: int | None = None,
    kv_tile: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Contiguous-slab variant: the per-slot ``(B, L, ...)`` slab is a
    pool of ``L / t`` tiles per slot under an arange block table — a free
    reshape, after which the SAME paged kernel runs.  ``kv_tile`` is the
    split-KV tile (autotuned via :func:`select_attn_tiles`)."""
    b, l = slot_pos.shape
    t = kv_tile or DEFAULT_CTG_TILE
    while l % t:  # tile must divide the slab; fall back toward 1
        t //= 2
    nt = l // t

    def pooled(x):
        return x.reshape(b * nt, t, *x.shape[2:])

    table = jnp.arange(b * nt, dtype=jnp.int32).reshape(b, nt)
    return flash_decode_paged(
        table, q, q_pos, pooled(k), pooled(v), pooled(slot_pos),
        None if k_scale is None else pooled(k_scale),
        None if v_scale is None else pooled(v_scale),
        block_size=t, kv_bits=kv_bits, sm_scale=sm_scale,
        logit_softcap=logit_softcap, causal=causal, window=window,
        blocks_per_step=1, interpret=interpret)


# ---------------------------------------------------------------------------
# Tile selection + autotune — attention entries ride the SAME persisted
# tile cache as the GEMM backends (kernels/dispatch.py), keyed
# (m=B*C, n=cache_len, kw=Dh, backend="attn-ctg"/"attn-pgd"); only the
# TileConfig's ``bkw`` slot is meaningful (contiguous: split-KV tile
# tokens; paged: table entries per grid step).
# ---------------------------------------------------------------------------


def _attn_key(b: int, c: int, cache_len: int, dh: int, layout: str):
    return (b * c, cache_len, dh, f"attn-{layout}")


def select_attn_tiles(b: int, c: int, cache_len: int, dh: int,
                      layout: str) -> int:
    """Tuned split-KV knob for a decode shape, else the default.
    ``layout``: "ctg" (returns the kv tile) | "pgd" (blocks per step)."""
    from repro.kernels import dispatch

    hit = dispatch._tuned_tiles().get(_attn_key(b, c, cache_len, dh, layout))
    if hit is not None:
        return hit.bkw
    return DEFAULT_CTG_TILE if layout == "ctg" else DEFAULT_PGD_SPB


def _tile_candidates(layout: str, cache_len: int, block_size: int):
    if layout == "ctg":
        return sorted({t for t in (64, 128, 256, 512, 1024)
                       if t <= cache_len and cache_len % t == 0}
                      | {cache_len})
    bps = cache_len // block_size
    return sorted({s for s in (1, 2, 4, 8, 16) if s <= bps} | {bps})


def autotune_attn_tiles(b: int, c: int, cache_len: int, kvh: int, dh: int,
                        layout: str, *, g: int = 1, block_size: int = 16,
                        kv_bits: int | None = None, iters: int = 3,
                        interpret: bool | None = None):
    """Time the fused kernel over the split-KV candidates for one decode
    shape and register the winner in dispatch's tuned-tile cache (the
    committed ``benchmarks/tile_cache.json``; ``REPRO_TILE_CACHE`` seeds
    it back at load).  Returns (winner, per-candidate seconds)."""
    import time

    from repro.kernels import dispatch

    key = jax.random.PRNGKey(0)
    kq, kk, kv_, kp = jax.random.split(key, 4)
    q = jax.random.normal(kq, (b, c, kvh, g, dh), jnp.float32)
    q_pos = jnp.broadcast_to(
        jnp.arange(cache_len - c, cache_len, dtype=jnp.int32), (b, c))
    kf = jax.random.normal(kk, (b, cache_len, kvh, dh), jnp.float32)
    vf = jax.random.normal(kv_, (b, cache_len, kvh, dh), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(cache_len, dtype=jnp.int32),
                           (b, cache_len))
    del kp
    sm = dh ** -0.5

    def run_ctg(t):
        return flash_decode_contig(
            q, q_pos, kf, vf, pos, kv_bits=None, sm_scale=sm, kv_tile=t,
            interpret=interpret)

    def run_pgd(s):
        bs = block_size
        nt = cache_len // bs
        table = jnp.arange(b * nt, dtype=jnp.int32).reshape(b, nt)
        return flash_decode_paged(
            table, q, q_pos, kf.reshape(b * nt, bs, kvh, dh),
            vf.reshape(b * nt, bs, kvh, dh), pos.reshape(b * nt, bs),
            block_size=bs, kv_bits=None, sm_scale=sm, blocks_per_step=s,
            interpret=interpret)

    run = run_ctg if layout == "ctg" else run_pgd
    timings = {}
    for cand in _tile_candidates(layout, cache_len, block_size):
        run(cand).block_until_ready()  # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            run(cand).block_until_ready()
        timings[cand] = (time.perf_counter() - t0) / iters
    win = min(timings, key=timings.get)
    dispatch._tuned_tiles()[_attn_key(b, c, cache_len, dh, layout)] = \
        dispatch.TileConfig(bm=b * c, bn=cache_len, bkw=win, chunk_words=win)
    return win, timings
