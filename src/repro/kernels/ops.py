"""Public jit'd wrappers around the binary-GEMM kernels.

``binary_dot(x, w_packed, k_true)`` is what QDense's packed serving path
calls: x is float activations (binarized+packed on the fly, paper Fig. 1's
"binarize input" cost), w_packed is the converter's packed weight, and the
result is the exact ±1 dot product (matching the float training path per
paper §2.2.2).

Backend selection:
  * "vpu"  — Pallas popcount kernel (the literal paper algorithm)
  * "mxu"  — Pallas unpack-to-int8 MXU kernel (TPU-native, beyond-paper)
  * "xla"  — pure-jnp reference (oracle / fallback; also what the multi-pod
             dry-run lowers, since pallas_call in interpret mode is not a
             meaningful target for cost analysis)

On this CPU container Pallas runs in interpret mode; on a real TPU set
``interpret=False`` (ops read REPRO_PALLAS_INTERPRET).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core import bitpack
from repro.kernels import ref
from repro.kernels.pack_bits import pack_sign_pallas
from repro.kernels.xnor_gemm import (
    xnor_dot_mxu_pallas,
    xnor_mismatch_pallas,
)

WORD_BITS = bitpack.WORD_BITS


def _interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad_rows(x: jax.Array, mult: int, value=0) -> jax.Array:
    pad = _round_up(x.shape[0], mult) - x.shape[0]
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad), (0, 0)), constant_values=value)


def _pad_cols(x: jax.Array, mult: int, value=0) -> jax.Array:
    pad = _round_up(x.shape[1], mult) - x.shape[1]
    if pad == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, pad)), constant_values=value)


@functools.partial(jax.jit, static_argnames=("bm", "bkw", "backend"))
def pack_activations(
    x: jax.Array, *, bm: int = 8, bkw: int = 8, backend: str = "pallas"
) -> jax.Array:
    """Binarize+pack (M, K) float -> (M, ceil(K/32)) uint32.

    Rows are NOT padded (output keeps M); K tail bits are 0.
    """
    m, k = x.shape
    kw = bitpack.packed_width(k)
    if backend == "xla":
        return bitpack.pack_sign(x)
    kb = bkw * WORD_BITS
    xp = _pad_cols(x, kb, value=-1.0)  # negative pad -> bit 0
    xp = _pad_rows(xp, bm, value=-1.0)
    out = pack_sign_pallas(xp, bm=bm, bkw=bkw, interpret=_interpret())
    return out[:m, :kw]


@functools.partial(
    jax.jit, static_argnames=("k_true", "backend", "bm", "bn", "bkw")
)
def xnor_gemm(
    a_packed: jax.Array,  # (M, Kw) uint32
    b_packed: jax.Array,  # (N, Kw) uint32  (weights, transposed layout)
    *,
    k_true: int,
    backend: str = "vpu",
    bm: int = 128,
    bn: int = 128,
    bkw: int = 64,
) -> jax.Array:
    """Exact ±1 dot product (M, N) int32 from packed operands."""
    if backend == "xla":
        return ref.xnor_gemm_ref(a_packed, b_packed, k_true)

    m, kw = a_packed.shape
    n = b_packed.shape[0]
    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 8))
    bkw = min(bkw, kw)
    ap = _pad_cols(_pad_rows(a_packed, bm), bkw)
    bp = _pad_cols(_pad_rows(b_packed, bn), bkw)

    if backend == "vpu":
        cw = min(8, bkw)
        while bkw % cw:
            cw -= 1
        mism = xnor_mismatch_pallas(
            ap, bp, bm=bm, bn=bn, bkw=bkw, chunk_words=cw,
            interpret=_interpret(),
        )[:m, :n]
        return k_true - 2 * mism
    if backend == "mxu":
        padded_dot = xnor_dot_mxu_pallas(
            ap, bp, bm=bm, bn=bn, bkw=bkw, interpret=_interpret()
        )[:m, :n]
        # pad bits (0 in both operands) unpack to (-1)*(-1) = +1 each
        pad_bits = ap.shape[1] * WORD_BITS - k_true
        return padded_dot - pad_bits
    raise ValueError(f"unknown backend {backend!r}")


@functools.partial(
    jax.jit, static_argnames=("k_true", "backend", "out_dtype")
)
def binary_dot(
    x: jax.Array,  # (..., K) float activations
    w_packed: jax.Array,  # (N, Kw) uint32 packed weights
    *,
    k_true: int,
    backend: str = "vpu",
    out_dtype=jnp.float32,
) -> jax.Array:
    """Full packed-serving matmul: binarize+pack x, xnor-GEMM with packed w.

    Returns (..., N) in ``out_dtype`` — numerically identical to
    ``sign(x) @ sign(W)`` computed in floats (paper §2.2.2 invariant).
    """
    lead = x.shape[:-1]
    k = x.shape[-1]
    assert k == k_true, (k, k_true)
    x2 = x.reshape(-1, k)
    if backend == "xla":
        # XLA analog of the MXU kernel: weights stay bit-packed in HBM,
        # unpack to ±1 in-graph and contract on the MXU with fp32
        # accumulation (exact for ±1 up to 2^24 terms).  The popcount
        # reference (ref.xnor_gemm_ref) stays the test oracle — its
        # (M, N, Kw) intermediate is fine for tests but not for lowering
        # 1M-token prefill cells.
        w_pm1 = bitpack.unpack_sign(w_packed, k_true, jnp.bfloat16)  # (N, K)
        xq = jnp.where(x2 >= 0, 1.0, -1.0).astype(jnp.bfloat16)
        dot = jax.lax.dot_general(
            xq, w_pm1,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return dot.astype(out_dtype).reshape(*lead, -1)
    xp = pack_activations(x2, backend="pallas")
    dot = xnor_gemm(xp, w_packed, k_true=k_true, backend=backend)
    return dot.astype(out_dtype).reshape(*lead, -1)
