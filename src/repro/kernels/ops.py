"""Public jit'd wrappers around the binary-GEMM kernels.

Since the dispatch refactor this module is a thin compatibility surface over
``kernels/dispatch.py`` — the single place that owns backend selection, the
tile-size heuristic table, pad-correction arithmetic, and the fused
epilogue.  Benchmarks and tests keep calling these names; layer code should
use :mod:`repro.kernels.dispatch` directly.

Backend selection (see the dispatch registry):
  * "vpu"  — Pallas popcount kernel (the literal paper algorithm)
  * "mxu"  — Pallas unpack-to-int8 MXU kernel (TPU-native, beyond-paper)
  * "xla"  — pure-jnp reference (oracle / fallback; also what the multi-pod
             dry-run lowers, since pallas_call in interpret mode is not a
             meaningful target for cost analysis)

On this CPU container Pallas runs in interpret mode; on a real TPU set
``interpret=False`` (dispatch reads REPRO_PALLAS_INTERPRET).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitpack
from repro.kernels import dispatch

WORD_BITS = bitpack.WORD_BITS


def pack_activations(
    x: jax.Array, *, bm: int = 8, bkw: int = 8, backend: str = "pallas",
    interpret: bool | None = None
) -> jax.Array:
    """Binarize+pack (M, K) float -> (M, ceil(K/32)) uint32.

    Rows are NOT padded (output keeps M); K tail bits are 0.
    ``interpret=None`` reads REPRO_PALLAS_INTERPRET (dispatch threads
    ``GemmConfig.interpret`` through the prologue on the layer path).
    """
    return dispatch.pack_activations(
        x, bm=bm, bkw=bkw, use_pallas=backend != "xla", interpret=interpret
    )


def xnor_gemm(
    a_packed: jax.Array,  # (M, Kw) uint32
    b_packed: jax.Array,  # (N, Kw) uint32  (weights, transposed layout)
    *,
    k_true: int,
    backend: str = "vpu",
    bm: int | None = None,
    bn: int | None = None,
    bkw: int | None = None,
) -> jax.Array:
    """Exact ±1 dot product (M, N) int32 from packed operands."""
    cfg = dispatch.GemmConfig(backend=backend, bm=bm, bn=bn, bkw=bkw)
    return dispatch.packed_gemm(a_packed, b_packed, k_true=k_true, config=cfg)


def binary_dot(
    x: jax.Array,  # (..., K) float activations
    w_packed: jax.Array,  # (N, Kw) uint32 packed weights
    *,
    k_true: int,
    backend: str = "vpu",
    out_dtype=jnp.float32,
) -> jax.Array:
    """Full packed-serving matmul: binarize+pack x, xnor-GEMM with packed w.

    Returns (..., N) in ``out_dtype`` — numerically identical to
    ``sign(x) @ sign(W)`` computed in floats (paper §2.2.2 invariant).
    """
    return dispatch.quant_gemm(
        x,
        w_packed,
        k_true=k_true,
        config=dispatch.GemmConfig(backend=backend),
        epilogue=dispatch.EpilogueSpec(out_dtype=out_dtype),
    )
