"""Packed binary GEMM Pallas kernels — the TPU adaptation of BMXNet's
xnor+popcount GEMM (paper §2.2.1, Listing 3).

Two strategies, both consuming *packed* operands (uint32 words, 32 binary
values per word, packed along K — see core/bitpack.py):

``xnor_gemm_vpu``
    The literal xnor+popcount algorithm on the VPU:
    ``mismatches[i,j] = sum_w popcount(a[i,w] ^ b[j,w])`` with the ±1 dot
    recovered outside as ``dot = K - 2 * mismatches``.  This is Listing 3
    with cache blocking replaced by BlockSpec VMEM tiling and the OpenMP
    loop replaced by the Pallas grid.

``xnor_gemm_mxu``
    TPU-native beyond-paper variant: stream the *packed* words HBM->VMEM
    (32x less traffic than bf16 — the part of the paper's insight that
    matters on TPU), unpack to ±1 int8 *in VMEM*, and contract on the MXU
    with int32 accumulation.  The MXU runs 128x128 MACs/cycle, so once the
    bytes are on-chip it beats lane-wise popcount by a large factor; the
    popcount trick mattered on CPUs because *there* the ALU was the
    bottleneck.  Padding bits unpack to (-1,-1) pairs and inflate the dot by
    ``pad = Kw*32 - k_true``; callers subtract it (ops.py does).

Both kernels tile (M, N, K) with a sequential-K innermost grid axis and an
fp32/int32 accumulator initialised at k==0, the standard TPU matmul pattern.

Both raw outputs are **K-partial-safe**: mismatch counts (VPU) and padded
dots (MXU) over disjoint Kw slices sum exactly — integer addition, no
rounding — which is the seam the tensor-parallel ``shard-*`` dispatch
backends rely on (each mesh shard runs the kernel on its Kw slice, the raw
int32 partials ``psum`` over the contraction axis, and the pad correction
below applies ONCE on the reduced sum).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bitpack import WORD_BITS

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BKW = 64  # words: 64 * 32 = 2048 binary values per K-step


def mxu_pad_inflation(total_words: int, k_true: int) -> int:
    """Pad-bit inflation of the (summed) raw MXU dot: every zero pad bit
    unpacks to ``(-1)·(-1) = +1``, so a contraction that touched
    ``total_words`` packed words of a ``k_true``-bit operand overshoots the
    true ±1 dot by exactly this many.  ``total_words`` is the number of
    words ACTUALLY contracted — one kernel call's post-tile-padding Kw for
    the single-device path, the per-shard padded Kw summed over all shards
    for the tensor-parallel path (the correction is linear in pad words, so
    it applies once on the psum-reduced dot)."""
    return total_words * WORD_BITS - k_true


def _vpu_kernel(a_ref, b_ref, out_ref, *, chunk_words: int):
    """One (bm, bn) tile: accumulate popcount(xor) over this K-block."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bkw = a_ref.shape[1]
    n_chunks = bkw // chunk_words

    def body(c, acc):
        sl = pl.ds(c * chunk_words, chunk_words)
        a = a_ref[:, sl]  # (bm, cw)
        b = b_ref[:, sl]  # (bn, cw)
        x = a[:, None, :] ^ b[None, :, :]  # (bm, bn, cw)
        m = jax.lax.population_count(x).astype(jnp.int32).sum(axis=-1)
        return acc + m

    acc = jax.lax.fori_loop(
        0, n_chunks, body, jnp.zeros(out_ref.shape, jnp.int32)
    )
    out_ref[...] += acc


def _unpack_pm1_i8(words: jax.Array) -> jax.Array:
    """(rows, kw) uint32 -> (rows, kw*32) int8 in {-1, +1} (bit 1 -> +1)."""
    rows, kw = words.shape
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[:, :, None] >> shifts) & jnp.uint32(1)  # (rows, kw, 32)
    pm1 = (2 * bits.astype(jnp.int8) - 1).reshape(rows, kw * WORD_BITS)
    return pm1


def _mxu_kernel(a_ref, b_ref, out_ref):
    """One (bm, bn) tile: unpack packed words in VMEM, contract on the MXU."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = _unpack_pm1_i8(a_ref[...])  # (bm, bkw*32) int8
    b = _unpack_pm1_i8(b_ref[...])  # (bn, bkw*32) int8
    out_ref[...] += jax.lax.dot_general(
        a,
        b,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _grid_call(kernel, a_packed, b_packed, bm, bn, bkw, interpret):
    m, kw = a_packed.shape
    n, kw_b = b_packed.shape
    assert kw == kw_b, (kw, kw_b)
    assert m % bm == 0 and n % bn == 0 and kw % bkw == 0, (
        f"shapes must be pre-padded to block multiples: "
        f"M={m}%{bm}, N={n}%{bn}, Kw={kw}%{bkw}"
    )
    grid = (m // bm, n // bn, kw // bkw)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bkw), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bkw), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(a_packed, b_packed)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bkw", "chunk_words", "interpret")
)
def xnor_mismatch_pallas(
    a_packed: jax.Array,  # (M, Kw) uint32, M % bm == 0, Kw % bkw == 0
    b_packed: jax.Array,  # (N, Kw) uint32, N % bn == 0
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bkw: int = DEFAULT_BKW,
    chunk_words: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """VPU popcount path: returns raw xor-mismatch counts (M, N) int32.

    ``dot = k_true - 2 * mismatches`` (pad bits match, contributing 0).
    """
    kernel = functools.partial(_vpu_kernel, chunk_words=chunk_words)
    return _grid_call(kernel, a_packed, b_packed, bm, bn, bkw, interpret)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bkw", "interpret"))
def xnor_dot_mxu_pallas(
    a_packed: jax.Array,
    b_packed: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bkw: int = DEFAULT_BKW,
    interpret: bool = True,
) -> jax.Array:
    """MXU path: returns the *padded* ±1 dot (M, N) int32.

    True dot = result - (Kw * 32 - k_true): pad bits unpack to (-1)·(-1)=+1.
    """
    return _grid_call(_mxu_kernel, a_packed, b_packed, bm, bn, bkw, interpret)


# ---------------------------------------------------------------------------
# Batched (expert-stacked) variants: a leading grid axis iterates the expert
# dimension, so one pallas_call contracts every expert's packed operands —
# the MoE packed-serving GEMM (kernels/dispatch.py drives it).  Same inner
# tiles as the 2D kernels; BlockSpecs carry a singleton expert block.
# ---------------------------------------------------------------------------


def _vpu_kernel_batched(a_ref, b_ref, out_ref, *, chunk_words: int):
    """One (1, bm, bn) tile of one expert: popcount(xor) over this K-block."""
    k_step = pl.program_id(3)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bkw = a_ref.shape[-1]
    n_chunks = bkw // chunk_words

    def body(c, acc):
        sl = pl.ds(c * chunk_words, chunk_words)
        a = a_ref[0, :, sl]  # (bm, cw)
        b = b_ref[0, :, sl]  # (bn, cw)
        x = a[:, None, :] ^ b[None, :, :]  # (bm, bn, cw)
        m = jax.lax.population_count(x).astype(jnp.int32).sum(axis=-1)
        return acc + m

    acc = jax.lax.fori_loop(
        0, n_chunks, body, jnp.zeros(out_ref.shape[1:], jnp.int32)
    )
    out_ref[0, :, :] += acc


def _mxu_kernel_batched(a_ref, b_ref, out_ref):
    """One (1, bm, bn) tile of one expert: unpack in VMEM, MXU contraction."""
    k_step = pl.program_id(3)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = _unpack_pm1_i8(a_ref[0])  # (bm, bkw*32) int8
    b = _unpack_pm1_i8(b_ref[0])  # (bn, bkw*32) int8
    out_ref[0, :, :] += jax.lax.dot_general(
        a,
        b,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _grid_call_batched(kernel, a_packed, b_packed, bm, bn, bkw, interpret):
    e, m, kw = a_packed.shape
    e_b, n, kw_b = b_packed.shape
    assert e == e_b and kw == kw_b, (a_packed.shape, b_packed.shape)
    assert m % bm == 0 and n % bn == 0 and kw % bkw == 0, (
        f"shapes must be pre-padded to block multiples: "
        f"M={m}%{bm}, N={n}%{bn}, Kw={kw}%{bkw}"
    )
    grid = (e, m // bm, n // bn, kw // bkw)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bkw), lambda g, i, j, k: (g, i, k)),
            pl.BlockSpec((1, bn, bkw), lambda g, i, j, k: (g, j, k)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, i, j, k: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, m, n), jnp.int32),
        interpret=interpret,
    )(a_packed, b_packed)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bkw", "chunk_words", "interpret")
)
def xnor_mismatch_batched_pallas(
    a_packed: jax.Array,  # (E, M, Kw) uint32, pre-padded to block multiples
    b_packed: jax.Array,  # (E, N, Kw) uint32
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bkw: int = DEFAULT_BKW,
    chunk_words: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """Expert-batched VPU popcount path: (E, M, N) int32 mismatch counts."""
    kernel = functools.partial(_vpu_kernel_batched, chunk_words=chunk_words)
    return _grid_call_batched(kernel, a_packed, b_packed, bm, bn, bkw,
                              interpret)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bkw", "interpret"))
def xnor_dot_mxu_batched_pallas(
    a_packed: jax.Array,  # (E, M, Kw) uint32
    b_packed: jax.Array,  # (E, N, Kw) uint32
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bkw: int = DEFAULT_BKW,
    interpret: bool = True,
) -> jax.Array:
    """Expert-batched MXU path: (E, M, N) int32 *padded* dots (see 2D doc)."""
    return _grid_call_batched(_mxu_kernel_batched, a_packed, b_packed,
                              bm, bn, bkw, interpret)
