"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth every kernel test asserts against, and the XLA
fallback used when Pallas is unavailable (``ops.py`` picks the backend).

Semantics (see core/bitpack.py for the bit conventions):

``xnor_gemm_ref(a_packed, b_packed, k_true)`` computes the ±1 dot product

    dot[i, j] = sum_k a[i, k] * b[j, k]        a, b in {-1, +1}

from packed operands, as ``k_true - 2 * popcount(xor)`` — mathematically the
paper's xnor+popcount GEMM (Listing 3) followed by the inverse of Eq. 2.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitpack


def pack_sign_ref(x: jax.Array) -> jax.Array:
    """Binarize (sign, >=0 -> +1) and pack along the last axis."""
    return bitpack.pack_sign(x)


def xnor_gemm_ref(
    a_packed: jax.Array,  # (M, Kw) uint32
    b_packed: jax.Array,  # (N, Kw) uint32   (B stored transposed)
    k_true: int,
    out_dtype=jnp.int32,
) -> jax.Array:
    """±1 dot product from packed bits: (M, N) int32."""
    mism = jax.lax.population_count(a_packed[:, None, :] ^ b_packed[None, :, :])
    mism = mism.astype(out_dtype).sum(axis=-1)
    return k_true - 2 * mism


def xnor_counts_ref(a_packed, b_packed, k_true) -> jax.Array:
    """The paper's raw xnor+popcount output: number of matching bit pairs,
    in [0, k_true] step 1 (Listing 3 semantics)."""
    mism = jax.lax.population_count(a_packed[:, None, :] ^ b_packed[None, :, :])
    return k_true - mism.astype(jnp.int32).sum(axis=-1)


def kbit_gemm_ref(a_planes: jax.Array, b_planes: jax.Array) -> jax.Array:
    """Weighted bit-plane AND popcount (the k-bit integer GEMM):

        S[m, n] = sum_{i, j} 2^(i+j) * popcount(A_i[m] & B_j[n])

    from (ka, M, Kw) x (kb, N, Kw) plane stacks (core/bitpack.pack_planes).
    This is the oracle for kernels/kbit_gemm.py; pad/tail bits are 0 in
    every plane so no correction term exists."""
    ka, kb = a_planes.shape[0], b_planes.shape[0]
    s = jnp.zeros((a_planes.shape[1], b_planes.shape[1]), jnp.int32)
    for i in range(ka):
        for j in range(kb):
            x = a_planes[i][:, None, :] & b_planes[j][None, :, :]
            pc = jax.lax.population_count(x).astype(jnp.int32).sum(axis=-1)
            s = s + (1 << (i + j)) * pc
    return s


def dorefa_gemm_ref(a: jax.Array, w: jax.Array, w_bits: int,
                    a_bits: int) -> jax.Array:
    """Fake-quant DoReFa oracle (the train-path semantics the packed k-bit
    serving path must reproduce): quantize both operands with the paper's
    Eq. 1 quantizers and contract in fp32.  ``a`` is (M, K); ``w`` (K, N)."""
    from repro.core import quant

    xq = quant.quantize_act(a.astype(jnp.float32), a_bits)
    wq = quant.quantize_weight(w.astype(jnp.float32), w_bits)
    return xq @ wq


def sign_gemm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Float oracle: binarize both operands with sign and matmul.

    ``a`` is (M, K); ``b`` is (K, N).  This is the training-path semantics
    (BLAS/MXU dot over ±1 values) that §2.2.2 guarantees to exactly match the
    xnor path.
    """
    sa = jnp.where(a >= 0, 1.0, -1.0).astype(jnp.float32)
    sb = jnp.where(b >= 0, 1.0, -1.0).astype(jnp.float32)
    return sa @ sb
