"""k-bit packed GEMM on the MXU — int8 code-lane contraction of the
DoReFa bit planes (the decode-shape fast path behind ``mxu-k{2,4,8}``).

The VPU plane kernel (kernels/kbit_gemm.py) pays ``ka*kb`` AND+popcount
passes per tile; for w8a8 that is 64 lane-wise sweeps of the K words.
But the weighted plane sum it computes,

    S[m, n] = sum_{i < ka, j < kb} 2^(i+j) * popcount(A_i[m] & B_j[n]),

is exactly the integer dot of the *reassembled* codes ``n_a = sum_i 2^i
A_i`` and ``n_w = sum_j 2^j B_j``:  ``S[m, n] = sum_k n_a[m,k] *
n_w[n,k]``.  So this kernel streams the same packed plane words HBM->VMEM
(k/32 the traffic of int8 codes, k/(8*32) of bf16), reassembles the int8
code lanes per tile in VMEM, and contracts once on the MXU with int32
accumulation — one 128x128 MAC pass instead of ``ka*kb`` popcount sweeps.
That is the same re-planning xnor_gemm.py's MXU path applies to the 1-bit
operands, generalized to bit planes; break-even vs the popcount path is at
``ka*kb ~ 16`` (w4a4), and w8a8 is a clear win (benchmarks/roofline.py
models both).  One carve-out: the unpack cost is M-independent while the
popcount path scales with M, so at batch M=1 popcount does strictly less
element work and keeps single-request decode on hosts that time element
ops (the interpret rig); from M=8 up the MXU path wins outright.

int8 range: a k-bit code spans ``[0, 2^k - 1]``, which for k=8 overflows
int8.  The kernel therefore contracts the *offset* codes ``a_s = n_a -
2^(ka-1)`` and ``b_s = n_w - 2^(kb-1)`` (always in ``[-2^(k-1), 2^(k-1)
- 1]``, an exact int8 fit for k <= 8); S is restored with the binomial
expansion

    S = dot(a_s, b_s) + off_w * rowsum(a_s) + off_a * rowsum(b_s)
        + off_a * off_w * K_pad,        off_* = 2^(bits-1),

where the rowsums and ``K_pad`` run over ALL padded K lanes.  The three
correction terms are rank-1 in (M, N) and independent of the contraction
tiling, so they are NOT computed in the grid: the Pallas kernel
accumulates the pure offset-code dot, and the restore is applied once on
the (M, N) output, with the rowsums taken directly from the PACKED words
(``rowsum(a_s) = sum_i 2^i popcount(A_i) - off_a * K_pad``) — no second
pass over unpacked lanes, nothing rank-1 re-done per K-step.

The identity is exact *per K lane*: a zero pad word unpacks to code 0 in
every plane, its offset lanes are ``(-off_a, -off_w)``, it contributes 0
to every plane popcount, and the four terms cancel to ``0 * 0 = 0``.
Hence — like the popcount path and unlike the 1-bit MXU path — there is
NO pad correction, and the restored S stays **K-partial-safe**: S over
disjoint Kw slices sums exactly (each partial restores with its own local
``K_pad``), so the ``shard-mxu-k*`` dispatch backends psum per-shard
(S, T) pairs with no correction anywhere, identical to ``shard-vpu-k*``.

int32 accumulator bound (the part that differs from the VPU path): the MXU
accumulates the FULL code dot in one int32 partial — worst case ``K * Na *
Nw`` per element before the dequant doubling, vs the popcount path's
``<= K`` per plane-pair pass (weights applied after).  The trace-time
bound dispatch enforces, ``2 * K * Na * Nw < 2^31``, is numerically the
same ceiling (the offset-dot cross terms are all smaller than the
restored S), but dispatch re-derives it for this path separately so the
error message names the single-partial int8 accumulation.

Tiling matches kbit_gemm.py: (M, N, K) grid, sequential-K innermost axis,
int32 accumulator initialised at k==0, plane dim carried whole per block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bitpack import WORD_BITS

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BKW = 16  # words: 16 * 32 = 512 code lanes per K-step


def _unpack_codes_i8(planes: jax.Array, offset: int) -> jax.Array:
    """(k, rows, kw) uint32 plane words -> (rows, kw*32) int8 offset codes.

    Reassembles ``n = sum_i 2^i b_i`` per lane and subtracts ``offset``
    (``2^(k-1)``) so the result fits int8 for every k <= 8.  Zero pad
    words come out as ``-offset`` — see the module docstring for why that
    still contributes exactly 0 to the restored S.

    The whole reassembly runs in the uint8 domain: words bitcast to bytes
    (low byte = lanes 0..7), bits extracted and plane-weighted with uint8
    shift/mask ops (``((byte >> s) << i) & (1 << i)`` — the stray high
    bits the left shift drags along are masked off), and accumulated in
    uint8, which cannot wrap since ``sum_i 2^i <= 255``.  That keeps the
    unpack — the VPU-side cost this backend pays before its single MXU
    pass, and the fixed per-tile cost at decode M — in the narrowest
    lanes: 4x the VPU element density and a quarter the VMEM traffic of
    an int32-domain unpack.  The final ``- offset`` wraps mod 256, which
    IS two's-complement int8 subtraction, so the bitcast to int8 lands
    the exact signed offset code.

    Two trace-time-selected forms of the same arithmetic: wide operands
    (the weight block, clamped-bm activations at prefill M) run a
    per-plane loop — a chain XLA fuses well, throughput-bound; skinny
    operands (the bm <= 4 decode activation rows, where each per-plane
    op touches a few hundred bytes and per-op dispatch IS the cost) fold
    the plane axis into one broadcast shift/mask/reduce bundle instead
    of ``k`` chained ones.
    """
    k, rows, kw = planes.shape
    bytes_ = jax.lax.bitcast_convert_type(planes, jnp.uint8)  # (k,rows,kw,4)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    if rows <= 4:
        pw = jnp.arange(k, dtype=jnp.uint8)[:, None, None, None, None]
        # (k, rows, kw, 4, 8): bit s of plane i, already scaled by 2^i
        t = ((bytes_[..., None] >> shifts) << pw) & (jnp.uint8(1) << pw)
        acc = t.sum(axis=0, dtype=jnp.uint8)
    else:
        acc = None
        for i in range(k):
            t = ((bytes_[i][..., None] >> shifts) << jnp.uint8(i)) & jnp.uint8(
                1 << i
            )  # (rows, kw, 4, 8): bit s of plane i, already scaled by 2^i
            acc = t if acc is None else acc + t
    acc = (acc - jnp.uint8(offset)).reshape(rows, kw * WORD_BITS)
    return jax.lax.bitcast_convert_type(acc, jnp.int8)


def _offset_dot(a_planes, b_planes):
    """The offset-code dot for one K-block: (bm, bn) int32 from (ka, bm,
    bkw)/(kb, bn, bkw) uint32 VMEM blocks.  One MXU contraction, no
    corrections — the rank-1 restore happens once on the grid output."""
    ka = a_planes.shape[0]
    kb = b_planes.shape[0]
    a = _unpack_codes_i8(a_planes, 1 << (ka - 1))  # (bm, bk) int8
    b = _unpack_codes_i8(b_planes, 1 << (kb - 1))  # (bn, bk) int8
    return jax.lax.dot_general(
        a,
        b,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _offset_rowsum(planes: jax.Array, offset: int) -> jax.Array:
    """rowsum of the offset codes over ALL padded K lanes, straight from
    the packed words: (..., k, rows, kw) uint32 -> (..., rows) int32 equal
    to ``sum_lanes (n - offset) = sum_i 2^i popcount(plane_i) -
    offset*K``."""
    k, kw = planes.shape[-3], planes.shape[-1]
    pc = jax.lax.population_count(planes).astype(jnp.int32).sum(axis=-1)
    weights = jnp.int32(1) << jnp.arange(k, dtype=jnp.int32)
    return (pc * weights[:, None]).sum(axis=-2) - jnp.int32(
        offset * kw * WORD_BITS
    )


def _restore_s(dot, a_planes, b_planes):
    """Apply the binomial offset restore to the grid's (..., M, N) dot."""
    ka = a_planes.shape[-3]
    kb = b_planes.shape[-3]
    off_a = 1 << (ka - 1)
    off_b = 1 << (kb - 1)
    k_pad = a_planes.shape[-1] * WORD_BITS
    rs_a = _offset_rowsum(a_planes, off_a)  # (..., M)
    rs_b = _offset_rowsum(b_planes, off_b)  # (..., N)
    return (
        dot
        + jnp.int32(off_b) * rs_a[..., :, None]
        + jnp.int32(off_a) * rs_b[..., None, :]
        + jnp.int32(off_a * off_b * k_pad)
    )


def _mxu_kbit_kernel(a_ref, b_ref, out_ref):
    """One (bm, bn) tile: reassemble codes in VMEM, one MXU contraction."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += _offset_dot(a_ref[...], b_ref[...])


def _grid_call(kernel, a_planes, b_planes, bm, bn, bkw, interpret):
    ka, m, kw = a_planes.shape
    kb, n, kw_b = b_planes.shape
    assert kw == kw_b, (kw, kw_b)
    assert m % bm == 0 and n % bn == 0 and kw % bkw == 0, (
        f"shapes must be pre-padded to block multiples: "
        f"M={m}%{bm}, N={n}%{bn}, Kw={kw}%{bkw}"
    )
    grid = (m // bm, n // bn, kw // bkw)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ka, bm, bkw), lambda i, j, k: (0, i, k)),
            pl.BlockSpec((kb, bn, bkw), lambda i, j, k: (0, j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(a_planes, b_planes)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bkw", "interpret"))
def kbit_mxu_gemm_pallas(
    a_planes: jax.Array,  # (ka, M, Kw) uint32, M % bm == 0, Kw % bkw == 0
    b_planes: jax.Array,  # (kb, N, Kw) uint32, N % bn == 0
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bkw: int = DEFAULT_BKW,
    interpret: bool = True,
) -> jax.Array:
    """int8 code-lane MXU GEMM: returns the same S (M, N) int32 as
    kbit_plane_gemm_pallas, bit-identically (integer arithmetic only)."""
    dot = _grid_call(_mxu_kbit_kernel, a_planes, b_planes, bm, bn, bkw,
                     interpret)
    return _restore_s(dot, a_planes, b_planes)


# ---------------------------------------------------------------------------
# Batched (expert-stacked) variant — the MoE grouped k-bit GEMM: a leading
# grid axis iterates the expert dimension, same inner tiles.
# ---------------------------------------------------------------------------


def _mxu_kbit_kernel_batched(a_ref, b_ref, out_ref):
    """One (1, bm, bn) tile of one expert."""
    k_step = pl.program_id(3)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[0, :, :] += _offset_dot(a_ref[0], b_ref[0])


def _grid_call_batched(kernel, a_planes, b_planes, bm, bn, bkw, interpret):
    e, ka, m, kw = a_planes.shape
    e_b, kb, n, kw_b = b_planes.shape
    assert e == e_b and kw == kw_b, (a_planes.shape, b_planes.shape)
    assert m % bm == 0 and n % bn == 0 and kw % bkw == 0, (
        f"shapes must be pre-padded to block multiples: "
        f"M={m}%{bm}, N={n}%{bn}, Kw={kw}%{bkw}"
    )
    grid = (e, m // bm, n // bn, kw // bkw)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ka, bm, bkw), lambda g, i, j, k: (g, 0, i, k)),
            pl.BlockSpec((1, kb, bn, bkw), lambda g, i, j, k: (g, 0, j, k)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, i, j, k: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, m, n), jnp.int32),
        interpret=interpret,
    )(a_planes, b_planes)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bkw", "interpret"))
def kbit_mxu_gemm_batched_pallas(
    a_planes: jax.Array,  # (E, ka, M, Kw) uint32, pre-padded
    b_planes: jax.Array,  # (E, kb, N, Kw) uint32
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bkw: int = DEFAULT_BKW,
    interpret: bool = True,
) -> jax.Array:
    """Expert-batched int8 code-lane MXU GEMM: (E, M, N) int32 S."""
    dot = _grid_call_batched(_mxu_kbit_kernel_batched, a_planes, b_planes,
                             bm, bn, bkw, interpret)
    return _restore_s(dot, a_planes, b_planes)
