"""Unified quantized-GEMM dispatch — the single execution path for every
binary GEMM in the system (BMXNet §2.2's one-kernel-serves-all invariant).

Every packed contraction — dense, conv-im2col, and the MoE expert stack —
funnels through this module, which owns the four concerns that used to be
scattered across ``core/qlayers.py``, ``kernels/ops.py`` and ``nn/mlp.py``:

1. **binarize + pack** of float activations (paper Fig. 1's "binarize
   input" stage),
2. **backend selection** via a registry (``"vpu"``, ``"mxu"``, ``"xla"``;
   :func:`register_backend` adds more) plus a per-(M, N, Kw) tile-size
   heuristic table (:func:`select_tiles`),
3. **pad-correction arithmetic** — each backend's exact-dot recovery from
   its raw kernel output (``k_true - 2·mismatch`` for popcount, padded-dot
   minus pad bits for the MXU unpack kernel),
4. the **fused epilogue** (:class:`EpilogueSpec`: XNOR-Net alpha scale,
   Eq. 2 xnor-range map, bias, output dtype) — the ONE place this
   arithmetic exists; ``qlayers`` builds specs via
   :func:`epilogue_from_spec` and applies via :func:`apply_epilogue`.

Backend registry (the full bit-width family the paper names in §2.1 —
1-bit XNOR plus DoReFa k-bit; :func:`resolve_backend` maps a base name +
the layer's weight bit width onto the entry that executes it):

===========  ==================  ==========================  ================
backend      operands            kernel                      pad correction
===========  ==================  ==========================  ================
``vpu``      1-bit packed words  xnor+popcount (VPU,         ``k_true - 2*
             (M, Kw)/(N, Kw)     Listing 3)                  mismatch``
``mxu``      1-bit packed words  unpack->int8 in VMEM, MXU   ``- (Kw*32 -
                                 dot                         k_true)``
``xla``      float acts + any    unpack/dequant in-graph,    none (dequant
             packed weights      XLA dot / ragged_dot (the   path)
                                 dry-run lowering target)
``vpu-k2``   2-bit plane stacks  2^(i+j)-weighted AND        none (AND with
             (2, M, Kw)          popcount planes             zero pad words)
``vpu-k4``   4-bit plane stacks  same kernel, 16 plane       none
             (4, M, Kw)          pairs
``vpu-k8``   8-bit plane stacks  same kernel, 64 plane       none
             (8, M, Kw)          pairs
===========  ==================  ==========================  ================

Other w_bits in 2..8 (w3/w5/w6/w7) convert + serve through the ``"xla"``
dequant fallback; :func:`register_backend` can add ``vpu-k3`` etc.
Asymmetric widths (e.g. w4a8) are supported: the plane kernel takes
ka != kb stacks and resolution follows the WEIGHT width.

Entry points:

* :class:`QuantGemmCall` / :func:`quant_gemm` — (…, K) float activations
  against packed weights ((N, Kw) 1-bit words or (w_bits, N, Kw) plane
  stacks), epilogue fused.  ``w_bits``/``a_bits`` select the k-bit path.
* :func:`quant_gemm_grouped` — sorted rows against an (E, N, Kw) (1-bit)
  or (E, w_bits, N, Kw) (k-bit) expert stack with ragged group sizes: the
  MoE packed-serving GEMM.  Pallas backends bucket rows per expert and run
  the batched (expert-grid) kernels so only packed words cross HBM; the
  ``"xla"`` backend lowers to ``lax.ragged_dot`` for dry-run cost analysis.
* :func:`packed_gemm` / :func:`packed_kbit_gemm` — packed-x-packed
  primitives (exact ±1 dot / raw weighted-plane popcount S).

The k-bit fake-quant dot is recovered from the integer plane GEMM as
``(2*S - Nw*T) / (Na*Nw)`` (see kernels/kbit_gemm.py) and then flows
through the SAME fused epilogue as every other path — which is what keeps
w4a4/w8a8 packed serving numerically aligned with the fake-quant train
path (§2.2.2's argument, generalized from 1 bit to the 2..31 family).

On this CPU container Pallas runs in interpret mode; on a real TPU set
``REPRO_PALLAS_INTERPRET=0`` (or ``GemmConfig(interpret=False)``).
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import bitpack, quant
from repro.core.policy import QuantSpec
from repro.kernels import ref
from repro.kernels.kbit_gemm import (
    kbit_plane_gemm_batched_pallas,
    kbit_plane_gemm_pallas,
)
from repro.kernels.pack_bits import pack_sign_pallas
from repro.kernels.xnor_gemm import (
    xnor_dot_mxu_batched_pallas,
    xnor_dot_mxu_pallas,
    xnor_mismatch_batched_pallas,
    xnor_mismatch_pallas,
)

WORD_BITS = bitpack.WORD_BITS


def _env_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


# ---------------------------------------------------------------------------
# Tile selection: a per-backend heuristic table replacing the ad-hoc
# min/round_up/while-divides logic that used to live inline in ops.xnor_gemm.
# Operands are padded up to the selected tile, so any entry is *correct*;
# the table picks the smallest tile that covers the operand (small problems
# avoid padding waste, large problems get the full VMEM-friendly tile).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TileConfig:
    bm: int
    bn: int
    bkw: int  # K-words per step (bkw * 32 binary values)
    chunk_words: int  # vpu inner xor/popcount chunk


# Row-tile ladder: smallest entry >= the operand dim wins (last entry caps).
# K-word ladder likewise.  Separate rows per backend: the MXU kernel unpacks
# to (rows, bkw*32) int8 in VMEM so its K-step is kept smaller; the VPU
# popcount kernel streams words and tolerates a deeper K-block.
_TILE_TABLE: dict[str, dict[str, tuple[int, ...]]] = {
    "vpu": {"rows": (8, 16, 32, 64, 128), "kw": (8, 16, 32, 64)},
    "mxu": {"rows": (8, 16, 32, 64, 128), "kw": (8, 16, 32)},
    # k-bit plane backends stream ka+kb plane stacks per block, so the
    # K-step shrinks as the plane count grows (VMEM per block scales with
    # (ka + kb) * bkw words).
    "vpu-k2": {"rows": (8, 16, 32, 64, 128), "kw": (8, 16, 32)},
    "vpu-k4": {"rows": (8, 16, 32, 64, 128), "kw": (8, 16, 32)},
    "vpu-k8": {"rows": (8, 16, 32, 64, 128), "kw": (8, 16)},
}
_DEFAULT_CHUNK_WORDS = 8


def _pick(size: int, ladder: tuple[int, ...]) -> int:
    for step in ladder:
        if size <= step:
            return step
    return ladder[-1]


def _chunk_for(bkw: int, want: int) -> int:
    """Largest chunk <= ``want`` that divides ``bkw`` — the VPU kernel
    iterates bkw // chunk_words chunks and would silently skip tail words
    otherwise."""
    cw = max(1, min(want, bkw))
    while bkw % cw:
        cw -= 1
    return cw


@functools.lru_cache(maxsize=None)
def select_tiles(m: int, n: int, kw: int, backend: str) -> TileConfig:
    """Heuristic (M, N, Kw) -> tile sizes for ``backend`` (table-driven)."""
    rule = _TILE_TABLE.get(backend, _TILE_TABLE["vpu"])
    bkw = _pick(kw, rule["kw"])
    return TileConfig(
        bm=_pick(m, rule["rows"]),
        bn=_pick(n, rule["rows"]),
        bkw=bkw,
        chunk_words=_chunk_for(bkw, _DEFAULT_CHUNK_WORDS),
    )


# ---------------------------------------------------------------------------
# Config + epilogue specs (static, hashable — safe as jit static args)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GemmConfig:
    """How a quantized GEMM executes: backend + optional tile overrides.

    ``backend`` is a BASE name: layer calls carry the per-layer bit widths
    (from their :class:`QuantSpec`) and :func:`resolve_backend` maps e.g.
    ``("vpu", w_bits=4)`` onto the ``"vpu-k4"`` registry entry.  ``bits``
    is the default bit width for direct callers (benchmarks, ops.py-style
    wrappers) that do not thread a QuantSpec — explicit ``w_bits``/
    ``a_bits`` arguments on the entry points take precedence.

    ``interpret=None`` reads REPRO_PALLAS_INTERPRET (default: interpret,
    the only mode available on this CPU container).
    """

    backend: str = "vpu"
    bm: int | None = None
    bn: int | None = None
    bkw: int | None = None
    chunk_words: int | None = None
    interpret: bool | None = None
    bits: int | None = None

    def tiles(self, m: int, n: int, kw: int,
              backend: str | None = None) -> TileConfig:
        t = select_tiles(m, n, kw, backend or self.backend)
        bkw = self.bkw or t.bkw
        return TileConfig(
            bm=self.bm or t.bm,
            bn=self.bn or t.bn,
            bkw=bkw,
            chunk_words=_chunk_for(bkw, self.chunk_words
                                   or _DEFAULT_CHUNK_WORDS),
        )

    @property
    def _interpret(self) -> bool:
        return self.interpret if self.interpret is not None else _env_interpret()


DEFAULT_GEMM_CONFIG = GemmConfig()


@dataclasses.dataclass(frozen=True)
class EpilogueSpec:
    """What is fused after the ±1 dot: XNOR-Net per-channel alpha, the
    paper's Eq. 2 range map, bias add, and the output cast — in that order
    (the order every pre-dispatch copy of this code used)."""

    scale: bool = False
    xnor_range: bool = False
    bias: bool = False
    out_dtype: Any = jnp.float32


def epilogue_from_spec(
    qspec: QuantSpec, *, bias: bool, out_dtype
) -> EpilogueSpec:
    """Map a layer's :class:`QuantSpec` to the fused epilogue it implies.

    The Eq. 2 range map only applies to true 1-bit GEMMs, and the alpha
    scale never applies to full-precision layers — both rules live here so
    layer code cannot drift."""
    return EpilogueSpec(
        scale=qspec.scale and not qspec.is_fp,
        xnor_range=(
            qspec.xnor_range and qspec.is_binary and qspec.a_bits == 1
        ),
        bias=bias,
        out_dtype=out_dtype,
    )


def apply_epilogue(
    y: jax.Array,
    *,
    k_true: int,
    epilogue: EpilogueSpec,
    scale: jax.Array | None = None,
    bias: jax.Array | None = None,
) -> jax.Array:
    """THE epilogue: ``((y * scale) |> Eq.2(k_true)) + bias -> out_dtype``.

    Both execution paths (fake-quant train and packed serving) call this,
    which is what keeps them bit-exact per paper §2.2.2."""
    if epilogue.scale:
        assert scale is not None, "epilogue.scale set but no scale operand"
        y = y * scale
    if epilogue.xnor_range:
        y = quant.xnor_range_map(y, k_true)
    if epilogue.bias:
        assert bias is not None, "epilogue.bias set but no bias operand"
        y = y + bias
    return y.astype(epilogue.out_dtype)


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Backend:
    """One way to execute the packed quantized GEMM.

    1-bit surface (``bits == 1``):

    ``gemm(a_packed, b_packed, k_true, tiles, interpret) -> (M, N) int32``
    must return the EXACT ±1 dot (pad correction included).

    ``gemm_grouped(buckets, w_stack, k_true, tiles, interpret)`` contracts
    an (E, M, Kw) activation bucket against an (E, N, Kw) weight stack.

    ``from_float``: optional shortcut taking raw float activations —
    backends that never materialise packed activations (the XLA
    unpack-and-MXU fallback) set it and skip the pack stage.

    k-bit surface (``bits > 1`` plane backends, or the ``from_float_kbit``
    fallbacks on ``"xla"``):

    ``gemm_kbit(a_planes, b_planes, tiles, interpret) -> (M, N) int32``
    returns the raw weighted-plane popcount S (plane counts are read off
    the stacks' leading dims; no pad correction exists on this path).

    ``gemm_kbit_grouped(buckets, w_stack, tiles, interpret)`` is the
    (E, ka, M, Kw) x (E, kb, N, Kw) expert-batched version.

    ``from_float_kbit(x2, w_planes, a_bits, w_bits, k_true)`` /
    ``from_float_kbit_grouped(x_sorted, w_stack, group_sizes, a_bits,
    w_bits, k_true)`` return the fake-quant DoReFa dot directly from float
    activations (the in-graph dequant path the dry-run lowers).
    """

    name: str
    gemm: Callable
    gemm_grouped: Callable | None = None
    from_float: Callable | None = None
    from_float_grouped: Callable | None = None
    bits: int = 1
    gemm_kbit: Callable | None = None
    gemm_kbit_grouped: Callable | None = None
    from_float_kbit: Callable | None = None
    from_float_kbit_grouped: Callable | None = None


_REGISTRY: dict[str, Backend] = {}


def register_backend(backend: Backend) -> None:
    _REGISTRY[backend.name] = backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown gemm backend {name!r}; registered: "
            f"{sorted(_REGISTRY)}"
        ) from None


def resolve_backend(name: str, w_bits: int) -> str:
    """Map a base backend name + the layer's weight bit width onto the
    registry entry that executes it (the paper's full 1..k family behind
    one config knob):

    * ``w_bits == 1`` — the name is used as-is (the 1-bit entries), except
      that a plane backend down-resolves to ``"vpu"`` (plane entries have
      no ±1 kernel, and per-layer policies mix 1-bit and k-bit layers
      under one configured base name).
    * an entry that already handles ``w_bits`` (a matching ``vpu-kN`` or a
      ``from_float_kbit`` fallback like ``"xla"``) — used as-is.
    * otherwise ``vpu-k{w_bits}`` when registered, else the ``"xla"``
      dequant fallback (w3/w5/... stay correct, just not plane-packed).
    """
    if w_bits <= 1:
        be = _REGISTRY.get(name)
        if be is not None and be.bits > 1:
            return "vpu"
        return name
    be = get_backend(name)  # unknown base names raise here, not fall back
    if be.bits == w_bits or be.from_float_kbit is not None:
        return name
    kname = f"vpu-k{w_bits}"
    return kname if kname in _REGISTRY else "xla"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = _round_up(x.shape[axis], mult) - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pad_tiles(a: jax.Array, b: jax.Array, tiles: TileConfig):
    """Pad (…, M, Kw) and (…, N, Kw) up to tile multiples (zero words)."""
    a = _pad_axis(_pad_axis(a, -2, tiles.bm), -1, tiles.bkw)
    b = _pad_axis(_pad_axis(b, -2, tiles.bn), -1, tiles.bkw)
    return a, b


# --- vpu: the literal paper algorithm (xnor + popcount on the VPU) --------


def _vpu_gemm(ap, bp, k_true, tiles, interpret):
    m, n = ap.shape[0], bp.shape[0]
    ap, bp = _pad_tiles(ap, bp, tiles)
    mism = xnor_mismatch_pallas(
        ap, bp, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw,
        chunk_words=tiles.chunk_words, interpret=interpret,
    )[:m, :n]
    # pad bits are 0 in both operands -> 0 mismatches; Eq. 2 inverse:
    return k_true - 2 * mism


def _vpu_gemm_grouped(buckets, w_stack, k_true, tiles, interpret):
    m, n = buckets.shape[1], w_stack.shape[1]
    buckets, w_stack = _pad_tiles(buckets, w_stack, tiles)
    mism = xnor_mismatch_batched_pallas(
        buckets, w_stack, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw,
        chunk_words=tiles.chunk_words, interpret=interpret,
    )[:, :m, :n]
    return k_true - 2 * mism


# --- mxu: unpack packed words in VMEM, contract on the MXU ----------------


def _mxu_gemm(ap, bp, k_true, tiles, interpret):
    m, n = ap.shape[0], bp.shape[0]
    ap, bp = _pad_tiles(ap, bp, tiles)
    padded_dot = xnor_dot_mxu_pallas(
        ap, bp, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw, interpret=interpret
    )[:m, :n]
    # pad bits (0 in both operands) unpack to (-1)·(-1) = +1 each
    return padded_dot - (ap.shape[-1] * WORD_BITS - k_true)


def _mxu_gemm_grouped(buckets, w_stack, k_true, tiles, interpret):
    m, n = buckets.shape[1], w_stack.shape[1]
    buckets, w_stack = _pad_tiles(buckets, w_stack, tiles)
    padded_dot = xnor_dot_mxu_batched_pallas(
        buckets, w_stack, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw,
        interpret=interpret,
    )[:, :m, :n]
    return padded_dot - (buckets.shape[-1] * WORD_BITS - k_true)


# --- xla: pure-jnp fallback / dry-run lowering target ---------------------


def _xla_gemm(ap, bp, k_true, tiles, interpret):
    del tiles, interpret
    return ref.xnor_gemm_ref(ap, bp, k_true)


def _xla_from_float(x2, w_packed, k_true):
    """Weights stay bit-packed in HBM, unpack to ±1 in-graph and contract
    on the MXU with fp32 accumulation (exact for ±1 up to 2^24 terms).
    The popcount reference (ref.xnor_gemm_ref) stays the test oracle — its
    (M, N, Kw) intermediate is fine for tests but not for lowering
    1M-token prefill cells."""
    w_pm1 = bitpack.unpack_sign(w_packed, k_true, jnp.bfloat16)  # (N, K)
    xq = jnp.where(x2 >= 0, 1.0, -1.0).astype(jnp.bfloat16)
    return jax.lax.dot_general(
        xq, w_pm1,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _xla_from_float_grouped(x_sorted, w_stack, group_sizes, k_true):
    """Ragged-dot lowering of the grouped GEMM: packed words unpack
    in-graph, then ``lax.ragged_dot`` — the shape the dry-run cost model
    understands (no per-expert bucketing materialised)."""
    e, n, _ = w_stack.shape
    w_pm1 = bitpack.unpack_sign(w_stack, k_true, jnp.bfloat16)  # (E, N, K)
    w_ekn = jnp.transpose(w_pm1, (0, 2, 1))  # (E, K, N)
    xq = jnp.where(x_sorted >= 0, 1.0, -1.0).astype(jnp.bfloat16)
    return jax.lax.ragged_dot(xq, w_ekn, group_sizes).astype(jnp.float32)


# --- k-bit plane backends: DoReFa bit-plane popcount (kbit_gemm.py) -------


def _kbit_dequant(s, t_sum, a_bits, w_bits):
    """Integer plane GEMM -> fake-quant DoReFa dot (fp32):

        a_q = n_a/Na,  w_q = (2*n_w - Nw)/Nw
        =>  dot = (2*S - Nw*T) / (Na*Nw)

    with S the weighted-plane popcount and T the activation code row-sums.
    The numerator stays in int32 (a prior fp32 cast of S loses bits past
    2^24 and the subtraction is cancellation-prone); the single fp32
    divide is the only rounding.  ``_check_kbit_accumulator`` bounds every
    term below 2^31."""
    na = (1 << a_bits) - 1
    nw = (1 << w_bits) - 1
    num = 2 * s - jnp.int32(nw) * t_sum
    return num.astype(jnp.float32) / float(na * nw)


def _check_kbit_widths(w_bits: int, a_bits: int) -> None:
    """Reject width combinations the packed path has no semantics for,
    loudly: 1-bit sign values have no unsigned plane form, so mixing a
    1-bit side with a k-bit side would silently compute the wrong
    quantizer (round(clip(x,0,1)) is NOT sign(x))."""
    if w_bits > 1 and a_bits > 1:
        if not (2 <= w_bits <= 8 and 2 <= a_bits <= 8):
            raise ValueError(
                f"packed k-bit GEMM supports widths 2..8, got "
                f"w{w_bits}a{a_bits}"
            )
    elif w_bits > 1 or a_bits > 1:
        raise ValueError(
            f"mixed 1-bit/k-bit widths unsupported: w{w_bits}a{a_bits} "
            "(use both widths 1, or both in 2..8)"
        )


def _check_kbit_accumulator(k_true: int, a_bits: int, w_bits: int) -> None:
    """The plane kernels accumulate S <= K * Na * Nw in int32 (and the
    dequant numerator 2S - Nw*T has the same bound); shapes and widths are
    static, so an oversized contraction fails at trace time instead of
    silently wrapping (w8a8 caps K at ~16k, w4a4 at ~4.7M).  Only the
    integer plane arm needs this — the ``"xla"`` dequant fallback
    contracts in fp32."""
    bound = 2 * k_true * ((1 << a_bits) - 1) * ((1 << w_bits) - 1)
    if bound >= 2**31:
        raise ValueError(
            f"k-bit GEMM overflows its int32 accumulator: K={k_true} at "
            f"w{w_bits}a{a_bits} needs 2*K*Na*Nw = {bound} >= 2^31; split "
            "the contraction or reduce the bit width"
        )


def _pad_planes(a: jax.Array, b: jax.Array, tiles: TileConfig):
    """Pad (…, ka, M, Kw) and (…, kb, N, Kw) plane stacks up to tile
    multiples.  Zero words AND to zero, so padding needs no correction."""
    a = _pad_axis(_pad_axis(a, -2, tiles.bm), -1, tiles.bkw)
    b = _pad_axis(_pad_axis(b, -2, tiles.bn), -1, tiles.bkw)
    return a, b


def _vpu_kbit_gemm(a_planes, b_planes, tiles, interpret):
    m, n = a_planes.shape[1], b_planes.shape[1]
    a_planes, b_planes = _pad_planes(a_planes, b_planes, tiles)
    return kbit_plane_gemm_pallas(
        a_planes, b_planes, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw,
        chunk_words=tiles.chunk_words, interpret=interpret,
    )[:m, :n]


def _vpu_kbit_gemm_grouped(buckets, w_stack, tiles, interpret):
    m, n = buckets.shape[2], w_stack.shape[2]
    buckets, w_stack = _pad_planes(buckets, w_stack, tiles)
    return kbit_plane_gemm_batched_pallas(
        buckets, w_stack, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw,
        chunk_words=tiles.chunk_words, interpret=interpret,
    )[:, :m, :n]


def _xla_kbit_s(a_planes, b_planes, tiles, interpret):
    del tiles, interpret
    return ref.kbit_gemm_ref(a_planes, b_planes)


def _dequant_weight_planes(w_planes, k_true, w_bits):
    """(…, kb, N, Kw) plane stack -> (…, N, K) fp32 DoReFa weight values."""
    codes = bitpack.unpack_planes(jnp.moveaxis(w_planes, -3, 0), k_true)
    nw = float((1 << w_bits) - 1)
    return (2.0 * codes.astype(jnp.float32) - nw) / nw


def _xla_kbit_from_float(x2, w_planes, a_bits, w_bits, k_true):
    """Weights stay plane-packed in HBM (k/32 of fp32 bytes), dequantized
    to fp32 in-graph and contracted on the MXU — the k-bit analogue of
    ``_xla_from_float`` and the shape the dry-run cost model lowers."""
    wq = _dequant_weight_planes(w_planes, k_true, w_bits)  # (N, K)
    xq = quant.quantize_act(x2.astype(jnp.float32), a_bits)
    return jax.lax.dot_general(
        xq, wq,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _xla_kbit_from_float_grouped(x_sorted, w_stack, group_sizes, a_bits,
                                 w_bits, k_true):
    """Ragged-dot lowering of the grouped k-bit GEMM (cf. the 1-bit
    ``_xla_from_float_grouped``)."""
    wq = _dequant_weight_planes(w_stack, k_true, w_bits)  # (E, N, K)
    w_ekn = jnp.transpose(wq, (0, 2, 1))  # (E, K, N)
    xq = quant.quantize_act(x_sorted.astype(jnp.float32), a_bits)
    return jax.lax.ragged_dot(xq, w_ekn, group_sizes)


def _kbit_only(*_args, **_kw):
    raise ValueError(
        "k-bit plane backends execute k-bit GEMMs only; call the entry "
        "points with w_bits/a_bits (or use a 1-bit backend)"
    )


register_backend(Backend("vpu", _vpu_gemm, gemm_grouped=_vpu_gemm_grouped))
register_backend(Backend("mxu", _mxu_gemm, gemm_grouped=_mxu_gemm_grouped))
register_backend(
    Backend(
        "xla",
        _xla_gemm,
        from_float=_xla_from_float,
        from_float_grouped=_xla_from_float_grouped,
        gemm_kbit=_xla_kbit_s,
        from_float_kbit=_xla_kbit_from_float,
        from_float_kbit_grouped=_xla_kbit_from_float_grouped,
    )
)
for _k in (2, 4, 8):
    register_backend(
        Backend(
            f"vpu-k{_k}",
            _kbit_only,
            bits=_k,
            gemm_kbit=_vpu_kbit_gemm,
            gemm_kbit_grouped=_vpu_kbit_gemm_grouped,
        )
    )


# ---------------------------------------------------------------------------
# Activation packing (paper Fig. 1's "binarize input" stage)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("bm", "bkw", "use_pallas",
                                             "interpret"))
def pack_activations(
    x: jax.Array,
    *,
    bm: int = 8,
    bkw: int = 8,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Binarize+pack (M, K) float -> (M, ceil(K/32)) uint32.

    Rows are NOT padded (output keeps M); K tail bits are 0.
    """
    m, k = x.shape
    kw = bitpack.packed_width(k)
    if not use_pallas:
        return bitpack.pack_sign(x)
    kb = bkw * WORD_BITS
    xp = jnp.pad(
        x,
        ((0, _round_up(m, bm) - m), (0, _round_up(k, kb) - k)),
        constant_values=-1.0,  # negative pad -> bit 0
    )
    it = interpret if interpret is not None else _env_interpret()
    out = pack_sign_pallas(xp, bm=bm, bkw=bkw, interpret=it)
    return out[:m, :kw]


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k_true", "config"))
def packed_gemm(
    a_packed: jax.Array,  # (M, Kw) uint32
    b_packed: jax.Array,  # (N, Kw) uint32 (weights, transposed layout)
    *,
    k_true: int,
    config: GemmConfig = DEFAULT_GEMM_CONFIG,
) -> jax.Array:
    """Exact ±1 dot product (M, N) int32 from packed operands."""
    name = resolve_backend(config.backend, 1)
    be = get_backend(name)
    tiles = config.tiles(a_packed.shape[0], b_packed.shape[0],
                         a_packed.shape[1], backend=name)
    return be.gemm(a_packed, b_packed, k_true, tiles, config._interpret)


@functools.partial(jax.jit, static_argnames=("config",))
def packed_kbit_gemm(
    a_planes: jax.Array,  # (ka, M, Kw) uint32 plane stack
    b_planes: jax.Array,  # (kb, N, Kw) uint32 plane stack (weights)
    *,
    config: GemmConfig = DEFAULT_GEMM_CONFIG,
) -> jax.Array:
    """Raw weighted-plane popcount S (M, N) int32 from packed plane stacks
    (plane counts read off the leading dims)."""
    name = resolve_backend(config.backend, b_planes.shape[0])
    be = get_backend(name)
    if be.gemm_kbit is None:
        raise ValueError(f"backend {name!r} has no k-bit kernel")
    _check_kbit_accumulator(a_planes.shape[2] * WORD_BITS,
                            a_planes.shape[0], b_planes.shape[0])
    tiles = config.tiles(a_planes.shape[1], b_planes.shape[1],
                         a_planes.shape[2], backend=name)
    return be.gemm_kbit(a_planes, b_planes, tiles, config._interpret)


def _kbit_dot_from_float(x2, w_planes, *, k_true, config, w_bits, a_bits):
    """(M, K) float acts x (w_bits, N, Kw) plane-packed weights -> the
    fake-quant DoReFa dot (M, N) fp32, pre-epilogue."""
    name = resolve_backend(config.backend, w_bits)
    be = get_backend(name)
    if be.from_float_kbit is not None:
        return be.from_float_kbit(x2, w_planes, a_bits, w_bits, k_true)
    assert w_planes.ndim == 3 and w_planes.shape[0] == w_bits, (
        w_planes.shape, w_bits)
    _check_kbit_accumulator(k_true, a_bits, w_bits)
    codes = quant.act_codes(x2, a_bits)  # (M, K) uint32
    a_planes = bitpack.pack_planes(codes, a_bits)  # (ka, M, Kw)
    tiles = config.tiles(x2.shape[0], w_planes.shape[1],
                         a_planes.shape[-1], backend=name)
    s = be.gemm_kbit(a_planes, w_planes, tiles, config._interpret)
    t_sum = codes.astype(jnp.int32).sum(axis=-1)  # (M,)
    return _kbit_dequant(s, t_sum[:, None], a_bits, w_bits)


@functools.partial(
    jax.jit, static_argnames=("k_true", "config", "epilogue", "w_bits",
                              "a_bits")
)
def quant_gemm(
    x: jax.Array,  # (..., K) float activations
    w_packed: jax.Array,  # (N, Kw) 1-bit words or (w_bits, N, Kw) planes
    *,
    k_true: int,
    config: GemmConfig = DEFAULT_GEMM_CONFIG,
    epilogue: EpilogueSpec = EpilogueSpec(),
    scale: jax.Array | None = None,
    bias: jax.Array | None = None,
    w_bits: int | None = None,
    a_bits: int | None = None,
) -> jax.Array:
    """The quantized GEMM: quantize+pack x, packed GEMM against packed w,
    fused epilogue.  Returns (..., N) in ``epilogue.out_dtype`` —
    numerically identical to the fake-quant training path plus the same
    epilogue (paper §2.2.2 invariant; ``sign(x) @ sign(W)`` at 1 bit, the
    DoReFa Eq. 1 dot at k bits).

    ``w_bits``/``a_bits`` default to ``config.bits`` then 1; widths > 1
    route to the bit-plane backends (see :func:`resolve_backend`)."""
    lead = x.shape[:-1]
    assert x.shape[-1] == k_true, (x.shape, k_true)
    x2 = x.reshape(-1, k_true)
    wb = w_bits or config.bits or 1
    ab = a_bits or config.bits or 1
    if wb > 1 or ab > 1:
        _check_kbit_widths(wb, ab)
    if wb > 1:
        dot = _kbit_dot_from_float(
            x2, w_packed, k_true=k_true, config=config, w_bits=wb,
            a_bits=ab,
        )
        n_out = w_packed.shape[-2]
    else:
        name = resolve_backend(config.backend, 1)
        be = get_backend(name)
        if be.from_float is not None:
            dot = be.from_float(x2, w_packed, k_true)
        else:
            xp = pack_activations(x2, interpret=config._interpret)
            tiles = config.tiles(xp.shape[0], w_packed.shape[0],
                                 xp.shape[1], backend=name)
            dot = be.gemm(xp, w_packed, k_true, tiles, config._interpret)
        n_out = w_packed.shape[0]
    y = apply_epilogue(
        dot.astype(jnp.float32), k_true=k_true, epilogue=epilogue,
        scale=scale, bias=bias,
    )
    return y.reshape(*lead, n_out)


@dataclasses.dataclass(frozen=True)
class QuantGemmCall:
    """A fully-specified quantized GEMM: shape contract + bit widths +
    backend config + fused epilogue.  Layers build one of these and apply
    it; everything else (packing, tiles, backend resolution, pad
    correction, epilogue order) is owned here."""

    k_true: int
    config: GemmConfig = DEFAULT_GEMM_CONFIG
    epilogue: EpilogueSpec = EpilogueSpec()
    w_bits: int = 1
    a_bits: int = 1

    def __call__(
        self,
        x: jax.Array,
        w_packed: jax.Array,
        *,
        scale: jax.Array | None = None,
        bias: jax.Array | None = None,
    ) -> jax.Array:
        return quant_gemm(
            x, w_packed, k_true=self.k_true, config=self.config,
            epilogue=self.epilogue, scale=scale, bias=bias,
            w_bits=self.w_bits, a_bits=self.a_bits,
        )


@functools.partial(
    jax.jit,
    static_argnames=("k_true", "config", "expert_capacity", "out_dtype",
                     "w_bits", "a_bits"),
)
def quant_gemm_grouped(
    x_sorted: jax.Array,  # (T, K) float rows, sorted by group
    w_stack,  # (E, N, Kw) / (E, w_bits, N, Kw) packed experts, or a tuple
    group_sizes: jax.Array,  # (E,) int32, sum <= T
    *,
    k_true: int,
    config: GemmConfig = DEFAULT_GEMM_CONFIG,
    expert_capacity: int | None = None,
    out_dtype=jnp.float32,
    w_bits: int | None = None,
    a_bits: int | None = None,
):
    """Grouped (MoE expert-stacked) packed GEMM.

    Row ``i`` of ``x_sorted`` is contracted against the packed weights of
    its group (groups are contiguous: the first ``group_sizes[0]`` rows
    belong to expert 0, …).  Rows beyond ``sum(group_sizes)`` — MoE
    padding / non-owned rows — return zeros.  Rows overflowing a bucket
    (``expert_capacity``, default T: no drops) are dropped (zeros) on
    EVERY backend — the same contract as the EP capacity slack in
    ``nn/mlp.py``.

    ``w_stack`` may be a tuple of same-shape stacks (MoE up+gate): the
    activations are binarized, packed, and bucketed ONCE and contracted
    against each stack, returning a tuple.

    Pallas backends scatter the packed words into per-expert buckets and
    run the expert-batched xnor kernel, so only packed words cross HBM —
    closing the 32x traffic win the old unpack-to-float expert path
    forfeited.  The bucket layout is dense (E, capacity, Kw): with the
    default full capacity that is E-fold overcompute versus a ragged
    contraction, the price of exactness-by-default — production MoE
    serving should pass the load-balance ``expert_capacity`` (ROADMAP
    lists the capacity-factor wiring as a follow-on).
    """
    stacks = w_stack if isinstance(w_stack, tuple) else (w_stack,)
    t, k = x_sorted.shape
    e = stacks[0].shape[0]
    n = stacks[0].shape[-2]
    assert k == k_true, (k, k_true)
    wb = w_bits or config.bits or 1
    ab = a_bits or config.bits or 1
    if wb > 1 or ab > 1:
        _check_kbit_widths(wb, ab)

    ec = expert_capacity or t
    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    row = jnp.arange(t, dtype=jnp.int32)
    g = jnp.searchsorted(ends, row, side="right").astype(jnp.int32)
    g_safe = jnp.minimum(g, e - 1)
    pos = row - starts[g_safe]
    valid = (g < e) & (pos < ec)

    if wb > 1:
        return _kbit_grouped(
            x_sorted, w_stack, stacks, group_sizes, g, g_safe, pos, valid,
            ec=ec, k_true=k_true, config=config, out_dtype=out_dtype,
            w_bits=wb, a_bits=ab,
        )

    name = resolve_backend(config.backend, 1)
    be = get_backend(name)
    if be.from_float_grouped is not None:
        outs = tuple(
            jnp.where(
                valid[:, None],
                be.from_float_grouped(x_sorted, w, group_sizes, k_true),
                0,
            ).astype(out_dtype)
            for w in stacks
        )
        return outs if isinstance(w_stack, tuple) else outs[0]

    xp = pack_activations(x_sorted, interpret=config._interpret)
    kw = xp.shape[1]
    buckets = jnp.zeros((e, ec, kw), jnp.uint32)
    buckets = buckets.at[g, pos].set(xp, mode="drop")

    tiles = config.tiles(ec, n, kw, backend=name)
    outs = []
    for w in stacks:
        dots = be.gemm_grouped(buckets, w, k_true, tiles,
                               config._interpret)  # (E, ec, N)
        y = dots[g_safe, jnp.minimum(pos, ec - 1)]
        outs.append(jnp.where(valid[:, None], y, 0).astype(out_dtype))
    return tuple(outs) if isinstance(w_stack, tuple) else outs[0]


def _kbit_grouped(x_sorted, w_stack, stacks, group_sizes, g, g_safe, pos,
                  valid, *, ec, k_true, config, out_dtype, w_bits, a_bits):
    """k-bit arm of :func:`quant_gemm_grouped`: activation codes are
    quantized, plane-packed and bucketed ONCE, then each (E, w_bits, N, Kw)
    expert plane stack contracts on the expert-batched plane kernel; the
    ``"xla"`` fallback lowers to ``lax.ragged_dot`` over dequantized
    weights.  Same capacity/validity contract as the 1-bit arm."""
    e = stacks[0].shape[0]
    n = stacks[0].shape[-2]
    name = resolve_backend(config.backend, w_bits)
    be = get_backend(name)

    if be.from_float_kbit_grouped is not None:
        outs = tuple(
            jnp.where(
                valid[:, None],
                be.from_float_kbit_grouped(x_sorted, w, group_sizes,
                                           a_bits, w_bits, k_true),
                0,
            ).astype(out_dtype)
            for w in stacks
        )
        return outs if isinstance(w_stack, tuple) else outs[0]

    _check_kbit_accumulator(k_true, a_bits, w_bits)
    codes = quant.act_codes(x_sorted, a_bits)  # (T, K) uint32
    planes = bitpack.pack_planes(codes, a_bits)  # (ka, T, Kw)
    kw = planes.shape[-1]
    buckets = jnp.zeros((e, ec, a_bits, kw), jnp.uint32)
    buckets = buckets.at[g, pos].set(
        jnp.moveaxis(planes, 0, 1), mode="drop"
    )
    buckets = jnp.moveaxis(buckets, 2, 1)  # (E, ka, ec, kw)

    tiles = config.tiles(ec, n, kw, backend=name)
    t_sum = codes.astype(jnp.int32).sum(axis=-1)  # (T,)
    outs = []
    for w in stacks:
        s = be.gemm_kbit_grouped(buckets, w, tiles,
                                 config._interpret)  # (E, ec, N)
        y = s[g_safe, jnp.minimum(pos, ec - 1)]
        dot = _kbit_dequant(y, t_sum[:, None], a_bits, w_bits)
        outs.append(jnp.where(valid[:, None], dot, 0).astype(out_dtype))
    return tuple(outs) if isinstance(w_stack, tuple) else outs[0]
