"""Unified quantized-GEMM dispatch — the single execution path for every
binary GEMM in the system (BMXNet §2.2's one-kernel-serves-all invariant).

Every packed contraction — dense, conv-im2col, and the MoE expert stack —
funnels through this module, which owns the four concerns that used to be
scattered across ``core/qlayers.py``, ``kernels/ops.py`` and ``nn/mlp.py``:

1. **binarize + pack** of float activations (paper Fig. 1's "binarize
   input" stage),
2. **backend selection** via a registry (``"vpu"``, ``"mxu"``, ``"xla"``;
   :func:`register_backend` adds more) plus a per-(M, N, Kw) tile-size
   heuristic table (:func:`select_tiles`),
3. **pad-correction arithmetic** — each backend's exact-dot recovery from
   its raw kernel output (``k_true - 2·mismatch`` for popcount, padded-dot
   minus pad bits for the MXU unpack kernel),
4. the **fused epilogue** (:class:`EpilogueSpec`: XNOR-Net alpha scale,
   Eq. 2 xnor-range map, bias, output dtype) — the ONE place this
   arithmetic exists; ``qlayers`` builds specs via
   :func:`epilogue_from_spec` and applies via :func:`apply_epilogue`.

Entry points:

* :class:`QuantGemmCall` / :func:`quant_gemm` — (…, K) float activations
  against (N, Kw) packed weights, epilogue fused.
* :func:`quant_gemm_grouped` — sorted rows against an (E, N, Kw) expert
  stack with ragged group sizes: the MoE packed-serving GEMM.  Pallas
  backends bucket rows per expert and run the batched (expert-grid)
  kernels so only packed words cross HBM; the ``"xla"`` backend lowers to
  ``lax.ragged_dot`` for dry-run cost analysis.
* :func:`packed_gemm` — packed-x-packed primitive (what ``ops.xnor_gemm``
  wraps).

On this CPU container Pallas runs in interpret mode; on a real TPU set
``REPRO_PALLAS_INTERPRET=0`` (or ``GemmConfig(interpret=False)``).
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import bitpack, quant
from repro.core.policy import QuantSpec
from repro.kernels import ref
from repro.kernels.pack_bits import pack_sign_pallas
from repro.kernels.xnor_gemm import (
    xnor_dot_mxu_batched_pallas,
    xnor_dot_mxu_pallas,
    xnor_mismatch_batched_pallas,
    xnor_mismatch_pallas,
)

WORD_BITS = bitpack.WORD_BITS


def _env_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


# ---------------------------------------------------------------------------
# Tile selection: a per-backend heuristic table replacing the ad-hoc
# min/round_up/while-divides logic that used to live inline in ops.xnor_gemm.
# Operands are padded up to the selected tile, so any entry is *correct*;
# the table picks the smallest tile that covers the operand (small problems
# avoid padding waste, large problems get the full VMEM-friendly tile).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TileConfig:
    bm: int
    bn: int
    bkw: int  # K-words per step (bkw * 32 binary values)
    chunk_words: int  # vpu inner xor/popcount chunk


# Row-tile ladder: smallest entry >= the operand dim wins (last entry caps).
# K-word ladder likewise.  Separate rows per backend: the MXU kernel unpacks
# to (rows, bkw*32) int8 in VMEM so its K-step is kept smaller; the VPU
# popcount kernel streams words and tolerates a deeper K-block.
_TILE_TABLE: dict[str, dict[str, tuple[int, ...]]] = {
    "vpu": {"rows": (8, 16, 32, 64, 128), "kw": (8, 16, 32, 64)},
    "mxu": {"rows": (8, 16, 32, 64, 128), "kw": (8, 16, 32)},
}
_DEFAULT_CHUNK_WORDS = 8


def _pick(size: int, ladder: tuple[int, ...]) -> int:
    for step in ladder:
        if size <= step:
            return step
    return ladder[-1]


def _chunk_for(bkw: int, want: int) -> int:
    """Largest chunk <= ``want`` that divides ``bkw`` — the VPU kernel
    iterates bkw // chunk_words chunks and would silently skip tail words
    otherwise."""
    cw = max(1, min(want, bkw))
    while bkw % cw:
        cw -= 1
    return cw


@functools.lru_cache(maxsize=None)
def select_tiles(m: int, n: int, kw: int, backend: str) -> TileConfig:
    """Heuristic (M, N, Kw) -> tile sizes for ``backend`` (table-driven)."""
    rule = _TILE_TABLE.get(backend, _TILE_TABLE["vpu"])
    bkw = _pick(kw, rule["kw"])
    return TileConfig(
        bm=_pick(m, rule["rows"]),
        bn=_pick(n, rule["rows"]),
        bkw=bkw,
        chunk_words=_chunk_for(bkw, _DEFAULT_CHUNK_WORDS),
    )


# ---------------------------------------------------------------------------
# Config + epilogue specs (static, hashable — safe as jit static args)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GemmConfig:
    """How a quantized GEMM executes: backend + optional tile overrides.

    ``interpret=None`` reads REPRO_PALLAS_INTERPRET (default: interpret,
    the only mode available on this CPU container).
    """

    backend: str = "vpu"
    bm: int | None = None
    bn: int | None = None
    bkw: int | None = None
    chunk_words: int | None = None
    interpret: bool | None = None

    def tiles(self, m: int, n: int, kw: int) -> TileConfig:
        t = select_tiles(m, n, kw, self.backend)
        bkw = self.bkw or t.bkw
        return TileConfig(
            bm=self.bm or t.bm,
            bn=self.bn or t.bn,
            bkw=bkw,
            chunk_words=_chunk_for(bkw, self.chunk_words
                                   or _DEFAULT_CHUNK_WORDS),
        )

    @property
    def _interpret(self) -> bool:
        return self.interpret if self.interpret is not None else _env_interpret()


DEFAULT_GEMM_CONFIG = GemmConfig()


@dataclasses.dataclass(frozen=True)
class EpilogueSpec:
    """What is fused after the ±1 dot: XNOR-Net per-channel alpha, the
    paper's Eq. 2 range map, bias add, and the output cast — in that order
    (the order every pre-dispatch copy of this code used)."""

    scale: bool = False
    xnor_range: bool = False
    bias: bool = False
    out_dtype: Any = jnp.float32


def epilogue_from_spec(
    qspec: QuantSpec, *, bias: bool, out_dtype
) -> EpilogueSpec:
    """Map a layer's :class:`QuantSpec` to the fused epilogue it implies.

    The Eq. 2 range map only applies to true 1-bit GEMMs, and the alpha
    scale never applies to full-precision layers — both rules live here so
    layer code cannot drift."""
    return EpilogueSpec(
        scale=qspec.scale and not qspec.is_fp,
        xnor_range=(
            qspec.xnor_range and qspec.is_binary and qspec.a_bits == 1
        ),
        bias=bias,
        out_dtype=out_dtype,
    )


def apply_epilogue(
    y: jax.Array,
    *,
    k_true: int,
    epilogue: EpilogueSpec,
    scale: jax.Array | None = None,
    bias: jax.Array | None = None,
) -> jax.Array:
    """THE epilogue: ``((y * scale) |> Eq.2(k_true)) + bias -> out_dtype``.

    Both execution paths (fake-quant train and packed serving) call this,
    which is what keeps them bit-exact per paper §2.2.2."""
    if epilogue.scale:
        assert scale is not None, "epilogue.scale set but no scale operand"
        y = y * scale
    if epilogue.xnor_range:
        y = quant.xnor_range_map(y, k_true)
    if epilogue.bias:
        assert bias is not None, "epilogue.bias set but no bias operand"
        y = y + bias
    return y.astype(epilogue.out_dtype)


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Backend:
    """One way to execute the packed binary GEMM.

    ``gemm(a_packed, b_packed, k_true, tiles, interpret) -> (M, N) int32``
    must return the EXACT ±1 dot (pad correction included).

    ``gemm_grouped(buckets, w_stack, k_true, tiles, interpret)`` contracts
    an (E, M, Kw) activation bucket against an (E, N, Kw) weight stack.

    ``from_float``: optional shortcut taking raw float activations —
    backends that never materialise packed activations (the XLA
    unpack-and-MXU fallback) set it and skip the pack stage.
    """

    name: str
    gemm: Callable
    gemm_grouped: Callable | None = None
    from_float: Callable | None = None
    from_float_grouped: Callable | None = None


_REGISTRY: dict[str, Backend] = {}


def register_backend(backend: Backend) -> None:
    _REGISTRY[backend.name] = backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown gemm backend {name!r}; registered: "
            f"{sorted(_REGISTRY)}"
        ) from None


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = _round_up(x.shape[axis], mult) - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pad_tiles(a: jax.Array, b: jax.Array, tiles: TileConfig):
    """Pad (…, M, Kw) and (…, N, Kw) up to tile multiples (zero words)."""
    a = _pad_axis(_pad_axis(a, -2, tiles.bm), -1, tiles.bkw)
    b = _pad_axis(_pad_axis(b, -2, tiles.bn), -1, tiles.bkw)
    return a, b


# --- vpu: the literal paper algorithm (xnor + popcount on the VPU) --------


def _vpu_gemm(ap, bp, k_true, tiles, interpret):
    m, n = ap.shape[0], bp.shape[0]
    ap, bp = _pad_tiles(ap, bp, tiles)
    mism = xnor_mismatch_pallas(
        ap, bp, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw,
        chunk_words=tiles.chunk_words, interpret=interpret,
    )[:m, :n]
    # pad bits are 0 in both operands -> 0 mismatches; Eq. 2 inverse:
    return k_true - 2 * mism


def _vpu_gemm_grouped(buckets, w_stack, k_true, tiles, interpret):
    m, n = buckets.shape[1], w_stack.shape[1]
    buckets, w_stack = _pad_tiles(buckets, w_stack, tiles)
    mism = xnor_mismatch_batched_pallas(
        buckets, w_stack, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw,
        chunk_words=tiles.chunk_words, interpret=interpret,
    )[:, :m, :n]
    return k_true - 2 * mism


# --- mxu: unpack packed words in VMEM, contract on the MXU ----------------


def _mxu_gemm(ap, bp, k_true, tiles, interpret):
    m, n = ap.shape[0], bp.shape[0]
    ap, bp = _pad_tiles(ap, bp, tiles)
    padded_dot = xnor_dot_mxu_pallas(
        ap, bp, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw, interpret=interpret
    )[:m, :n]
    # pad bits (0 in both operands) unpack to (-1)·(-1) = +1 each
    return padded_dot - (ap.shape[-1] * WORD_BITS - k_true)


def _mxu_gemm_grouped(buckets, w_stack, k_true, tiles, interpret):
    m, n = buckets.shape[1], w_stack.shape[1]
    buckets, w_stack = _pad_tiles(buckets, w_stack, tiles)
    padded_dot = xnor_dot_mxu_batched_pallas(
        buckets, w_stack, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw,
        interpret=interpret,
    )[:, :m, :n]
    return padded_dot - (buckets.shape[-1] * WORD_BITS - k_true)


# --- xla: pure-jnp fallback / dry-run lowering target ---------------------


def _xla_gemm(ap, bp, k_true, tiles, interpret):
    del tiles, interpret
    return ref.xnor_gemm_ref(ap, bp, k_true)


def _xla_from_float(x2, w_packed, k_true):
    """Weights stay bit-packed in HBM, unpack to ±1 in-graph and contract
    on the MXU with fp32 accumulation (exact for ±1 up to 2^24 terms).
    The popcount reference (ref.xnor_gemm_ref) stays the test oracle — its
    (M, N, Kw) intermediate is fine for tests but not for lowering
    1M-token prefill cells."""
    w_pm1 = bitpack.unpack_sign(w_packed, k_true, jnp.bfloat16)  # (N, K)
    xq = jnp.where(x2 >= 0, 1.0, -1.0).astype(jnp.bfloat16)
    return jax.lax.dot_general(
        xq, w_pm1,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _xla_from_float_grouped(x_sorted, w_stack, group_sizes, k_true):
    """Ragged-dot lowering of the grouped GEMM: packed words unpack
    in-graph, then ``lax.ragged_dot`` — the shape the dry-run cost model
    understands (no per-expert bucketing materialised)."""
    e, n, _ = w_stack.shape
    w_pm1 = bitpack.unpack_sign(w_stack, k_true, jnp.bfloat16)  # (E, N, K)
    w_ekn = jnp.transpose(w_pm1, (0, 2, 1))  # (E, K, N)
    xq = jnp.where(x_sorted >= 0, 1.0, -1.0).astype(jnp.bfloat16)
    return jax.lax.ragged_dot(xq, w_ekn, group_sizes).astype(jnp.float32)


register_backend(Backend("vpu", _vpu_gemm, gemm_grouped=_vpu_gemm_grouped))
register_backend(Backend("mxu", _mxu_gemm, gemm_grouped=_mxu_gemm_grouped))
register_backend(
    Backend(
        "xla",
        _xla_gemm,
        from_float=_xla_from_float,
        from_float_grouped=_xla_from_float_grouped,
    )
)


# ---------------------------------------------------------------------------
# Activation packing (paper Fig. 1's "binarize input" stage)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("bm", "bkw", "use_pallas",
                                             "interpret"))
def pack_activations(
    x: jax.Array,
    *,
    bm: int = 8,
    bkw: int = 8,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Binarize+pack (M, K) float -> (M, ceil(K/32)) uint32.

    Rows are NOT padded (output keeps M); K tail bits are 0.
    """
    m, k = x.shape
    kw = bitpack.packed_width(k)
    if not use_pallas:
        return bitpack.pack_sign(x)
    kb = bkw * WORD_BITS
    xp = jnp.pad(
        x,
        ((0, _round_up(m, bm) - m), (0, _round_up(k, kb) - k)),
        constant_values=-1.0,  # negative pad -> bit 0
    )
    it = interpret if interpret is not None else _env_interpret()
    out = pack_sign_pallas(xp, bm=bm, bkw=bkw, interpret=it)
    return out[:m, :kw]


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k_true", "config"))
def packed_gemm(
    a_packed: jax.Array,  # (M, Kw) uint32
    b_packed: jax.Array,  # (N, Kw) uint32 (weights, transposed layout)
    *,
    k_true: int,
    config: GemmConfig = DEFAULT_GEMM_CONFIG,
) -> jax.Array:
    """Exact ±1 dot product (M, N) int32 from packed operands."""
    be = get_backend(config.backend)
    tiles = config.tiles(a_packed.shape[0], b_packed.shape[0],
                         a_packed.shape[1])
    return be.gemm(a_packed, b_packed, k_true, tiles, config._interpret)


@functools.partial(
    jax.jit, static_argnames=("k_true", "config", "epilogue")
)
def quant_gemm(
    x: jax.Array,  # (..., K) float activations
    w_packed: jax.Array,  # (N, Kw) uint32 packed weights
    *,
    k_true: int,
    config: GemmConfig = DEFAULT_GEMM_CONFIG,
    epilogue: EpilogueSpec = EpilogueSpec(),
    scale: jax.Array | None = None,
    bias: jax.Array | None = None,
) -> jax.Array:
    """The quantized GEMM: binarize+pack x, xnor-GEMM against packed w,
    fused epilogue.  Returns (..., N) in ``epilogue.out_dtype`` —
    numerically identical to ``sign(x) @ sign(W)`` plus the same epilogue
    on the float training path (paper §2.2.2 invariant)."""
    lead = x.shape[:-1]
    assert x.shape[-1] == k_true, (x.shape, k_true)
    x2 = x.reshape(-1, k_true)
    be = get_backend(config.backend)
    if be.from_float is not None:
        dot = be.from_float(x2, w_packed, k_true)
    else:
        xp = pack_activations(x2, interpret=config._interpret)
        tiles = config.tiles(xp.shape[0], w_packed.shape[0], xp.shape[1])
        dot = be.gemm(xp, w_packed, k_true, tiles, config._interpret)
    y = apply_epilogue(
        dot.astype(jnp.float32), k_true=k_true, epilogue=epilogue,
        scale=scale, bias=bias,
    )
    return y.reshape(*lead, w_packed.shape[0])


@dataclasses.dataclass(frozen=True)
class QuantGemmCall:
    """A fully-specified quantized GEMM: shape contract + backend config +
    fused epilogue.  Layers build one of these and apply it; everything
    else (packing, tiles, pad correction, epilogue order) is owned here."""

    k_true: int
    config: GemmConfig = DEFAULT_GEMM_CONFIG
    epilogue: EpilogueSpec = EpilogueSpec()

    def __call__(
        self,
        x: jax.Array,
        w_packed: jax.Array,
        *,
        scale: jax.Array | None = None,
        bias: jax.Array | None = None,
    ) -> jax.Array:
        return quant_gemm(
            x, w_packed, k_true=self.k_true, config=self.config,
            epilogue=self.epilogue, scale=scale, bias=bias,
        )


@functools.partial(
    jax.jit,
    static_argnames=("k_true", "config", "expert_capacity", "out_dtype"),
)
def quant_gemm_grouped(
    x_sorted: jax.Array,  # (T, K) float rows, sorted by group
    w_stack,  # (E, N, Kw) uint32 packed expert weights, or a tuple of them
    group_sizes: jax.Array,  # (E,) int32, sum <= T
    *,
    k_true: int,
    config: GemmConfig = DEFAULT_GEMM_CONFIG,
    expert_capacity: int | None = None,
    out_dtype=jnp.float32,
):
    """Grouped (MoE expert-stacked) packed GEMM.

    Row ``i`` of ``x_sorted`` is contracted against the packed weights of
    its group (groups are contiguous: the first ``group_sizes[0]`` rows
    belong to expert 0, …).  Rows beyond ``sum(group_sizes)`` — MoE
    padding / non-owned rows — return zeros.  Rows overflowing a bucket
    (``expert_capacity``, default T: no drops) are dropped (zeros) on
    EVERY backend — the same contract as the EP capacity slack in
    ``nn/mlp.py``.

    ``w_stack`` may be a tuple of same-shape stacks (MoE up+gate): the
    activations are binarized, packed, and bucketed ONCE and contracted
    against each stack, returning a tuple.

    Pallas backends scatter the packed words into per-expert buckets and
    run the expert-batched xnor kernel, so only packed words cross HBM —
    closing the 32x traffic win the old unpack-to-float expert path
    forfeited.  The bucket layout is dense (E, capacity, Kw): with the
    default full capacity that is E-fold overcompute versus a ragged
    contraction, the price of exactness-by-default — production MoE
    serving should pass the load-balance ``expert_capacity`` (ROADMAP
    lists the capacity-factor wiring as a follow-on).
    """
    stacks = w_stack if isinstance(w_stack, tuple) else (w_stack,)
    t, k = x_sorted.shape
    e, n, _ = stacks[0].shape
    assert k == k_true, (k, k_true)
    be = get_backend(config.backend)

    ec = expert_capacity or t
    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    row = jnp.arange(t, dtype=jnp.int32)
    g = jnp.searchsorted(ends, row, side="right").astype(jnp.int32)
    g_safe = jnp.minimum(g, e - 1)
    pos = row - starts[g_safe]
    valid = (g < e) & (pos < ec)

    if be.from_float_grouped is not None:
        outs = tuple(
            jnp.where(
                valid[:, None],
                be.from_float_grouped(x_sorted, w, group_sizes, k_true),
                0,
            ).astype(out_dtype)
            for w in stacks
        )
        return outs if isinstance(w_stack, tuple) else outs[0]

    xp = pack_activations(x_sorted, interpret=config._interpret)
    kw = xp.shape[1]
    buckets = jnp.zeros((e, ec, kw), jnp.uint32)
    buckets = buckets.at[g, pos].set(xp, mode="drop")

    tiles = config.tiles(ec, n, kw)
    outs = []
    for w in stacks:
        dots = be.gemm_grouped(buckets, w, k_true, tiles,
                               config._interpret)  # (E, ec, N)
        y = dots[g_safe, jnp.minimum(pos, ec - 1)]
        outs.append(jnp.where(valid[:, None], y, 0).astype(out_dtype))
    return tuple(outs) if isinstance(w_stack, tuple) else outs[0]
