"""Unified quantized-GEMM dispatch — the single execution path for every
binary GEMM in the system (BMXNet §2.2's one-kernel-serves-all invariant).

Every packed contraction — dense, conv-im2col, and the MoE expert stack —
funnels through this module, which owns the four concerns that used to be
scattered across ``core/qlayers.py``, ``kernels/ops.py`` and ``nn/mlp.py``:

1. the **fused activation prologue** (:class:`PrologueSpec`: the
   quantize -> pack stage, paper Fig. 1's "binarize input") — every
   backend DECLARES how its operands are prepared (``Backend.prologue``)
   and the preparation runs as one Pallas VMEM pass
   (``kernels/pack_bits.py``): 1-bit sign -> word-pack, or the fused
   DoReFa clip -> codes -> bit-plane pack (plane stack + code row-sums in
   a single pass — no jnp ``act_codes`` -> ``pack_planes`` HBM round
   trip); ``qlayers`` builds specs via :func:`prologue_from_spec`,
2. **backend selection** via a registry (``"vpu"``, ``"mxu"``, ``"xla"``;
   :func:`register_backend` adds more) plus a per-(M, N, Kw) tile-size
   heuristic table (:func:`select_tiles`) with an optional measured
   **autotuning cache** over it (:func:`autotune_tiles`),
3. **pad-correction arithmetic** — each backend's exact-dot recovery from
   its raw kernel output (``k_true - 2·mismatch`` for popcount, padded-dot
   minus pad bits for the MXU unpack kernel),
4. the **fused epilogue** (:class:`EpilogueSpec`: XNOR-Net alpha scale,
   Eq. 2 xnor-range map, bias, output dtype) — the ONE place this
   arithmetic exists; ``qlayers`` builds specs via
   :func:`epilogue_from_spec` and applies via :func:`apply_epilogue`.

Backend registry (the full bit-width family the paper names in §2.1 —
1-bit XNOR plus DoReFa k-bit; :func:`resolve_backend` maps a base name +
the layer's weight bit width onto the entry that executes it; the
``prologue`` column is each entry's declared activation preparation, see
:class:`PrologueSpec`):

===========  ==================  ======================  ==========  ========
backend      operands            kernel                  pad corr.   prologue
===========  ==================  ======================  ==========  ========
``vpu``      1-bit packed words  xnor+popcount (VPU,     ``k_true -  sign ->
             (M, Kw)/(N, Kw)     Listing 3)              2*mism.``   pack
``mxu``      1-bit packed words  unpack->int8 in VMEM,   ``-(Kw*32   sign ->
                                 MXU dot                 -k_true)``  pack
``xla``      float acts + any    unpack/dequant in-      none        float
             packed weights      graph, XLA dot /                    (none)
                                 ragged_dot (dry-run)
``vpu-k2``   2-bit plane stacks  2^(i+j)-weighted AND    none (AND   fused
             (2, M, Kw)          popcount planes         w/ zero     planes
                                                         pad words)  + T
``vpu-k4``   4-bit plane stacks  same kernel, 16 plane   none        planes
             (4, M, Kw)          pairs                               + T
``vpu-k8``   8-bit plane stacks  same kernel, 64 plane   none        planes
             (8, M, Kw)          pairs                               + T
``mxu-k2``   2-bit plane stacks  reassemble int8 code    none (pad   planes
             (2, M, Kw)          lanes in VMEM, ONE      lanes are   + T
                                 MXU dot (offset trick)  code 0)
``mxu-k4``   4-bit plane stacks  same kernel — replaces  none        planes
             (4, M, Kw)          16 popcount passes                  + T
``mxu-k8``   8-bit plane stacks  same kernel — replaces  none        planes
             (8, M, Kw)          64 popcount passes                  + T
``shard-*``  same as the inner   inner kernel under      on the      inner's,
             backend, mesh-      shard_map: Kw-partial   reduced     INSIDE
             partitioned         raw outputs + psum      sum, ONCE   the body
                                 (or the chunked
                                 ppermute ring when
                                 ``overlap_collective``)
===========  ==================  ======================  ==========  ========

Other w_bits in 2..8 (w3/w5/w6/w7) convert + serve through the ``"xla"``
dequant fallback; :func:`register_backend` can add ``vpu-k3`` etc.
Asymmetric widths (e.g. w4a8) are supported: the plane kernel takes
ka != kb stacks and resolution follows the WEIGHT width.

**Tensor-parallel serving** (the ``shard-`` family: ``shard-vpu``,
``shard-mxu``, ``shard-{vpu,mxu}-k2/k4/k8``): the same Pallas kernels run
under
``shard_map`` on ``GemmConfig.mesh``, with the operand layouts owned by
``dist.sharding.packed_gemm_pspecs`` (the Megatron pair —
``shard_layout="k"`` partitions the packed Kw dimension over
``GemmConfig.shard_axis`` and ``psum``s the RAW integer kernel outputs
(mismatch counts / padded dots / weighted plane popcounts, all exactly
additive over disjoint Kw slices); ``shard_layout="n"`` partitions weight
rows with replicated activations and needs no collective).  Pad
correction and the fused epilogue apply exactly once on the reduced sum,
so sharded results are BIT-IDENTICAL to single-device at any split.  The
activation prologue runs INSIDE the shard_map body on float-activation
entry points: the ``"k"`` layout word-aligns the float K split
(``prologue=True`` pspecs) so each shard quantizes+packs only its local
K-slab — no global-pack-then-reshard hop — and the ``"n"`` layout packs
once and broadcasts the packed words.  The grouped (MoE) form composes
expert parallelism over ``GemmConfig.expert_axis`` with the Kw partition.
:func:`unsharded` strips the family back to its inner single-device
backend — required when a caller is already inside a ``shard_map`` body
(nn/mlp.py's EP path).

Entry points:

* :class:`QuantGemmCall` / :func:`quant_gemm` — (…, K) float activations
  against packed weights ((N, Kw) 1-bit words or (w_bits, N, Kw) plane
  stacks), epilogue fused.  ``w_bits``/``a_bits`` select the k-bit path.
* :func:`quant_gemm_grouped` — sorted rows against an (E, N, Kw) (1-bit)
  or (E, w_bits, N, Kw) (k-bit) expert stack with ragged group sizes: the
  MoE packed-serving GEMM.  Pallas backends bucket rows per expert and run
  the batched (expert-grid) kernels so only packed words cross HBM; the
  ``"xla"`` backend lowers to ``lax.ragged_dot`` for dry-run cost analysis.
* :func:`packed_gemm` / :func:`packed_kbit_gemm` — packed-x-packed
  primitives (exact ±1 dot / raw weighted-plane popcount S).

The k-bit fake-quant dot is recovered from the integer plane GEMM as
``(2*S - Nw*T) / (Na*Nw)`` (see kernels/kbit_gemm.py) and then flows
through the SAME fused epilogue as every other path — which is what keeps
w4a4/w8a8 packed serving numerically aligned with the fake-quant train
path (§2.2.2's argument, generalized from 1 bit to the 2..31 family).

On this CPU container Pallas runs in interpret mode; on a real TPU set
``REPRO_PALLAS_INTERPRET=0`` (or ``GemmConfig(interpret=False)``).
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.core import bitpack, quant
from repro.core.policy import QuantSpec
from repro.dist.sharding import packed_gemm_pspecs
from repro.kernels import ref
from repro.kernels.kbit_gemm import (
    kbit_plane_gemm_batched_pallas,
    kbit_plane_gemm_pallas,
)
from repro.kernels.kbit_mxu import (
    kbit_mxu_gemm_batched_pallas,
    kbit_mxu_gemm_pallas,
)
from repro.kernels.pack_bits import (
    _env_interpret,
    pack_sign_pallas,
    quant_pack_planes_pallas,
)
from repro.kernels.xnor_gemm import (
    mxu_pad_inflation,
    xnor_dot_mxu_batched_pallas,
    xnor_dot_mxu_pallas,
    xnor_mismatch_batched_pallas,
    xnor_mismatch_pallas,
)

WORD_BITS = bitpack.WORD_BITS
# _env_interpret is shared with the pack kernels (repro.kernels.pack_bits)
# so the two modules cannot drift on how REPRO_PALLAS_INTERPRET is read.


# ---------------------------------------------------------------------------
# Tile selection: a per-backend heuristic table replacing the ad-hoc
# min/round_up/while-divides logic that used to live inline in ops.xnor_gemm.
# Operands are padded up to the selected tile, so any entry is *correct*;
# the table picks the smallest tile that covers the operand (small problems
# avoid padding waste, large problems get the full VMEM-friendly tile).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TileConfig:
    bm: int
    bn: int
    bkw: int  # K-words per step (bkw * 32 binary values)
    chunk_words: int  # vpu inner xor/popcount chunk


# Row-tile ladder: smallest entry >= the operand dim wins (last entry caps).
# K-word ladder likewise.  Separate rows per backend: the MXU kernel unpacks
# to (rows, bkw*32) int8 in VMEM so its K-step is kept smaller; the VPU
# popcount kernel streams words and tolerates a deeper K-block.
# The ladders start at 1 row so DECODE shapes (M = batch of 1..7 serving
# requests) clamp bm to next-pow2(M) instead of padding up to an 8-row
# tile — a decode GEMM at M=1 otherwise wastes 8x the VMEM rows and grid
# work on padding.
_DECODE_ROWS = (1, 2, 4, 8, 16, 32, 64, 128)
_TILE_TABLE: dict[str, dict[str, tuple[int, ...]]] = {
    "vpu": {"rows": _DECODE_ROWS, "kw": (8, 16, 32, 64)},
    "mxu": {"rows": _DECODE_ROWS, "kw": (8, 16, 32)},
    # k-bit plane backends stream ka+kb plane stacks per block, so the
    # K-step shrinks as the plane count grows (VMEM per block scales with
    # (ka + kb) * bkw words).
    "vpu-k2": {"rows": _DECODE_ROWS, "kw": (8, 16, 32)},
    "vpu-k4": {"rows": _DECODE_ROWS, "kw": (8, 16, 32)},
    "vpu-k8": {"rows": _DECODE_ROWS, "kw": (8, 16)},
    # int8 code-lane MXU k-bit backends (kernels/kbit_mxu.py): both
    # operands unpack to (rows, bkw*32) int8 in VMEM, so the K-step
    # matches the 1-bit MXU ladder (k8 keeps it shallower — two 8-plane
    # stacks stream per block on top of the int8 lanes, and the unpack
    # intermediates scale with plane count x bkw, so k8 also offers a
    # bkw=4 step that keeps them resident in the fastest tile memory).
    "mxu-k2": {"rows": _DECODE_ROWS, "kw": (8, 16, 32)},
    "mxu-k4": {"rows": _DECODE_ROWS, "kw": (8, 16, 32)},
    "mxu-k8": {"rows": _DECODE_ROWS, "kw": (4, 8, 16)},
}
_DEFAULT_CHUNK_WORDS = 8


def _pick(size: int, ladder: tuple[int, ...]) -> int:
    for step in ladder:
        if size <= step:
            return step
    return ladder[-1]


def _chunk_for(bkw: int, want: int) -> int:
    """Largest chunk <= ``want`` that divides ``bkw`` — the VPU kernel
    iterates bkw // chunk_words chunks and would silently skip tail words
    otherwise."""
    cw = max(1, min(want, bkw))
    while bkw % cw:
        cw -= 1
    return cw


@functools.lru_cache(maxsize=None)
def select_tiles(m: int, n: int, kw: int, backend: str) -> TileConfig:
    """(M, N, Kw) -> tile sizes for ``backend``: a measured autotune-cache
    winner when one exists (:func:`autotune_tiles`), else the heuristic
    table."""
    tuned = _tuned_tiles().get((m, n, kw, backend))
    if tuned is not None:
        return tuned
    rule = _TILE_TABLE.get(backend, _TILE_TABLE["vpu"])
    bkw = _pick(kw, rule["kw"])
    return TileConfig(
        bm=_pick(m, rule["rows"]),
        bn=_pick(n, rule["rows"]),
        bkw=bkw,
        chunk_words=_chunk_for(bkw, _DEFAULT_CHUNK_WORDS),
    )


# ---------------------------------------------------------------------------
# Autotuning cache: measured winners persisted over the heuristic table.
# ---------------------------------------------------------------------------

_TUNED: dict[tuple[int, int, int, str], TileConfig] | None = None


def _tile_cache_path() -> str:
    return os.environ.get("REPRO_TILE_CACHE", "")


def _tuned_tiles() -> dict[tuple[int, int, int, str], TileConfig]:
    """The in-process autotune cache, seeded once from REPRO_TILE_CACHE
    (a JSON file of ``"m,n,kw,backend" -> [bm, bn, bkw, chunk]``) when
    set."""
    global _TUNED
    if _TUNED is None:
        _TUNED = {}
        path = _tile_cache_path()
        if path and os.path.exists(path):
            load_tile_cache(path)
    return _TUNED


def load_tile_cache(path: str) -> int:
    """Load autotuned tile winners from ``path`` into the in-process cache
    (entries win over the heuristic table).  Returns the entry count."""
    import json

    global _TUNED
    if _TUNED is None:
        _TUNED = {}
    with open(path) as f:
        raw = json.load(f)
    for key, vals in raw.items():
        m, n, kw, backend = key.rsplit(",", 3)[0:4]
        _TUNED[(int(m), int(n), int(kw), backend)] = TileConfig(
            int(vals[0]), int(vals[1]), int(vals[2]), int(vals[3]))
    select_tiles.cache_clear()
    return len(raw)


def _save_tile_cache(path: str) -> None:
    import json

    data = {
        f"{m},{n},{kw},{backend}": [t.bm, t.bn, t.bkw, t.chunk_words]
        for (m, n, kw, backend), t in (_TUNED or {}).items()
    }
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)


def _tile_candidates(m: int, n: int, kw: int, backend: str):
    """Candidate tiles around the heuristic pick: the heuristic itself,
    the neighbouring row-tile steps, and the full K-word ladder — a small
    set (<= ~2*2*4) so autotuning one shape stays cheap."""
    rule = _TILE_TABLE.get(backend, _TILE_TABLE["vpu"])

    def near(size: int, ladder: tuple[int, ...]):
        i = ladder.index(_pick(size, ladder))
        return sorted({ladder[i], ladder[min(i + 1, len(ladder) - 1)]})

    for bm in near(m, rule["rows"]):
        for bn in near(n, rule["rows"]):
            for bkw in rule["kw"]:
                yield TileConfig(bm=bm, bn=bn, bkw=bkw,
                                 chunk_words=_chunk_for(
                                     bkw, _DEFAULT_CHUNK_WORDS))


def autotune_tiles(
    m: int,
    n: int,
    kw: int,
    backend: str = "vpu",
    *,
    iters: int = 2,
    repeats: int = 3,
    persist: bool = True,
) -> TileConfig:
    """Benchmark the tile candidates for one (M, N, Kw, backend) problem
    and cache the winner over the heuristic table (the ROADMAP follow-on):
    subsequent :func:`select_tiles` calls — and therefore every
    ``GemmConfig`` without explicit tile overrides — use it.  With
    ``persist`` and REPRO_TILE_CACHE set, winners survive the process in
    the JSON file :func:`load_tile_cache` reads back.

    ``backend`` is a REGISTRY entry name, and the kernel timed is that
    entry's own (plane backends like ``"vpu-k4"`` time their k-bit plane
    kernel — NOT the 1-bit kernel the name would down-resolve to).
    ``shard-*`` names are rejected: sharded GEMMs re-select tiles from
    their per-shard local shapes, so tune the inner backend at
    (M, N, Kw_loc) instead."""
    import time as _time

    import numpy as np

    if backend.startswith(_SHARD_PREFIX):
        raise ValueError(
            f"cannot autotune {backend!r}: shard backends select tiles "
            "from their PER-SHARD shapes — tune the inner backend at the "
            "local (M, N, Kw_loc) instead"
        )
    be = get_backend(backend)
    rng = np.random.default_rng(0)
    if be.bits > 1:
        ap = jnp.asarray(
            rng.integers(0, 2**32, (be.bits, m, kw), dtype=np.uint32))
        bp = jnp.asarray(
            rng.integers(0, 2**32, (be.bits, n, kw), dtype=np.uint32))
    else:
        ap = jnp.asarray(rng.integers(0, 2**32, (m, kw), dtype=np.uint32))
        bp = jnp.asarray(rng.integers(0, 2**32, (n, kw), dtype=np.uint32))
    k_true = kw * WORD_BITS
    best: tuple[float, TileConfig] | None = None
    for cand in _tile_candidates(m, n, kw, backend):
        cfg = GemmConfig(backend=backend, bm=cand.bm, bn=cand.bn,
                         bkw=cand.bkw, chunk_words=cand.chunk_words)

        def run():
            if be.bits > 1:
                return be.gemm_kbit(ap, bp, cand, cfg)
            return be.gemm(ap, bp, k_true, cand, cfg)

        jax.block_until_ready(run())  # compile outside the timed region
        # min over repeated blocks: single-block means on a shared host
        # are noisy enough (2x swings) to crown a wrong winner that then
        # ships in the committed cache
        dt = float("inf")
        for _ in range(repeats):
            t0 = _time.perf_counter()
            for _ in range(iters):
                out = run()
            jax.block_until_ready(out)
            dt = min(dt, (_time.perf_counter() - t0) / iters)
        if best is None or dt < best[0]:
            best = (dt, cand)
    assert best is not None
    _tuned_tiles()[(m, n, kw, backend)] = best[1]
    select_tiles.cache_clear()
    path = _tile_cache_path()
    if persist and path:
        _save_tile_cache(path)
    return best[1]


# ---------------------------------------------------------------------------
# Config + epilogue specs (static, hashable — safe as jit static args)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GemmConfig:
    """How a quantized GEMM executes: backend + optional tile overrides.

    ``backend`` is a BASE name: layer calls carry the per-layer bit widths
    (from their :class:`QuantSpec`) and :func:`resolve_backend` maps e.g.
    ``("vpu", w_bits=4)`` onto the ``"vpu-k4"`` registry entry.  ``bits``
    is the default bit width for direct callers (benchmarks, ops.py-style
    wrappers) that do not thread a QuantSpec — explicit ``w_bits``/
    ``a_bits`` arguments on the entry points take precedence.

    ``interpret=None`` reads REPRO_PALLAS_INTERPRET (default: interpret,
    the only mode available on this CPU container).  The flag governs the
    activation-prologue pack kernels too, not just the GEMM kernels.

    ``fused_prologue`` selects the one-pass Pallas quantize->pack kernels
    for activation preparation (kernels/pack_bits.py); ``False`` falls
    back to the jnp reference path (``bitpack.pack_sign`` /
    ``quant.act_codes`` -> ``bitpack.pack_planes``), kept as the
    equivalence oracle the fused kernels are gated against.

    ``capacity_factor`` bounds MoE expert buckets: the EP path in
    nn/mlp.py sizes its per-shard ``expert_capacity`` as
    ``capacity_factor x`` the balanced share (default 2.0 when unset) —
    bounded-memory packed prefill.  When the bound shrinks the bucket
    total below the row count, the grouped prologue routes first and
    packs per expert bucket, so dropped rows are never quantized or
    packed (see ``_pack_sign_buckets``).

    The ``shard-*`` backends additionally read the tensor-parallel knobs:
    ``mesh`` (the jax Mesh to shard_map over — hashable, so the config
    stays a legal jit static argument; ``QCtx`` fills it from its own mesh
    when a shard backend is configured), ``shard_axis`` (the mesh axis the
    packed Kw dimension partitions over in the ``"k"`` layout, or weight N
    rows in the ``"n"`` layout), ``shard_layout`` (``"k"`` | ``"n"``, see
    ``dist.sharding.packed_gemm_pspecs``), and ``expert_axis`` (optional
    second mesh axis for expert parallelism on the grouped path).

    ``overlap_collective`` switches the ``"k"`` layout's contraction
    reduction from one monolithic ``psum`` (the safe default) to the
    ``collective_matmul``-style ring schedule (:func:`_ring_chunk_reduce`):
    the weight N rows split into per-shard chunks, each shard's raw int32
    partial for one chunk rides a ``ppermute`` ring while the NEXT chunk's
    GEMM runs, so the collective hops hide behind compute — and because no
    full-width psum barrier remains at the layer boundary, the next
    layer's fused in-body quantize->pack prologue starts while the last
    hops drain.  Raw partials are int32 and integer addition is exact in
    any order, so results are BIT-IDENTICAL to the sequential path (CI
    gates this).  Honored by EVERY ``"k"``-layout shard path — dense
    float-activation, packed-operand, and grouped/expert-parallel (1-bit
    and k-bit, all shard families); the ``"n"`` layout has no contraction
    collective to overlap.  On the grouped paths the ring runs inside
    each expert-axis group (the expert axis partitions rows, it never
    reduces); the k-bit T row-sum sliver keeps its plain psum — nothing
    hides behind a collective that small.
    """

    backend: str = "vpu"
    bm: int | None = None
    bn: int | None = None
    bkw: int | None = None
    chunk_words: int | None = None
    interpret: bool | None = None
    bits: int | None = None
    mesh: Any = None
    shard_axis: str = "model"
    shard_layout: str = "k"
    expert_axis: str | None = None
    fused_prologue: bool = True
    capacity_factor: float | None = None
    overlap_collective: bool = False

    def tiles(self, m: int, n: int, kw: int,
              backend: str | None = None) -> TileConfig:
        t = select_tiles(m, n, kw, backend or self.backend)
        bkw = self.bkw or t.bkw
        return TileConfig(
            bm=self.bm or t.bm,
            bn=self.bn or t.bn,
            bkw=bkw,
            chunk_words=_chunk_for(bkw, self.chunk_words
                                   or _DEFAULT_CHUNK_WORDS),
        )

    @property
    def _interpret(self) -> bool:
        return self.interpret if self.interpret is not None else _env_interpret()


DEFAULT_GEMM_CONFIG = GemmConfig()


@dataclasses.dataclass(frozen=True)
class EpilogueSpec:
    """What is fused after the ±1 dot: XNOR-Net per-channel alpha, the
    paper's Eq. 2 range map, bias add, and the output cast — in that order
    (the order every pre-dispatch copy of this code used)."""

    scale: bool = False
    xnor_range: bool = False
    bias: bool = False
    out_dtype: Any = jnp.float32


def epilogue_from_spec(
    qspec: QuantSpec, *, bias: bool, out_dtype
) -> EpilogueSpec:
    """Map a layer's :class:`QuantSpec` to the fused epilogue it implies.

    The Eq. 2 range map only applies to true 1-bit GEMMs, and the alpha
    scale never applies to full-precision layers — both rules live here so
    layer code cannot drift."""
    return EpilogueSpec(
        scale=qspec.scale and not qspec.is_fp,
        xnor_range=(
            qspec.xnor_range and qspec.is_binary and qspec.a_bits == 1
        ),
        bias=bias,
        out_dtype=out_dtype,
    )


def apply_epilogue(
    y: jax.Array,
    *,
    k_true: int,
    epilogue: EpilogueSpec,
    scale: jax.Array | None = None,
    bias: jax.Array | None = None,
) -> jax.Array:
    """THE epilogue: ``((y * scale) |> Eq.2(k_true)) + bias -> out_dtype``.

    Both execution paths (fake-quant train and packed serving) call this,
    which is what keeps them bit-exact per paper §2.2.2."""
    if epilogue.scale:
        assert scale is not None, "epilogue.scale set but no scale operand"
        y = y * scale
    if epilogue.xnor_range:
        y = quant.xnor_range_map(y, k_true)
    if epilogue.bias:
        assert bias is not None, "epilogue.bias set but no bias operand"
        y = y + bias
    return y.astype(epilogue.out_dtype)


@dataclasses.dataclass(frozen=True)
class PrologueSpec:
    """The activation-side twin of :class:`EpilogueSpec`: what happens to
    float activations BEFORE the packed kernel runs (paper Fig. 1's
    "binarize input" stage).  ``kind`` is the executing backend's declared
    operand preparation (``Backend.prologue``):

    * ``"pack_sign"``   — 1-bit: clip/sign -> packed uint32 words
      (one fused Pallas pass, ``pack_bits.pack_sign_pallas``).
    * ``"pack_planes"`` — k-bit DoReFa: clip -> Eq. 1 codes ->
      (a_bits, M, Kw) bit-plane stack PLUS the int32 code row-sums T,
      all in one fused pass (``pack_bits.quant_pack_planes_pallas``).
    * ``"float"``       — operands stay float; the backend quantizes
      in-graph (the ``"xla"`` dequant / dry-run lowering family).

    ``fused=False`` routes through the jnp reference instead
    (``bitpack.pack_sign`` / ``quant.act_codes`` + ``bitpack.pack_planes``)
    — bit-identical by construction, kept as the equivalence oracle.

    ``local=True`` marks prologues that run INSIDE the backend's
    ``shard_map`` body (the ``shard-*`` family's ``"k"`` layout: each
    shard quantizes+packs its word-aligned local K-slab, so no
    global-pack-then-reshard hop exists; the ``"n"`` layout packs once
    and broadcasts).
    """

    kind: str = "pack_sign"
    a_bits: int = 1
    fused: bool = True
    local: bool = False


def resolve_prologue(
    name: str, w_bits: int, a_bits: int,
    config: "GemmConfig" = None,  # type: ignore[assignment]
) -> PrologueSpec:
    """The prologue the (base backend name, bit widths, config) combination
    implies — resolved against the same registry entry that will execute
    the GEMM, so the declared operand prep cannot drift from the kernel."""
    config = config if config is not None else DEFAULT_GEMM_CONFIG
    be = get_backend(resolve_backend(name, w_bits))
    return PrologueSpec(
        kind=be.prologue,
        a_bits=a_bits,
        fused=config.fused_prologue,
        local=(be.name.startswith(_SHARD_PREFIX)
               and config.shard_layout == "k"),
    )


def prologue_from_spec(
    qspec: QuantSpec, *, config: "GemmConfig" = None,  # type: ignore
) -> PrologueSpec:
    """Map a layer's :class:`QuantSpec` + its :class:`GemmConfig` to the
    activation prologue the packed path runs (twin of
    :func:`epilogue_from_spec`)."""
    config = config if config is not None else DEFAULT_GEMM_CONFIG
    wb = 1 if qspec.is_fp else qspec.w_bits
    ab = 1 if qspec.is_fp else qspec.a_bits
    return resolve_prologue(config.backend, wb, ab, config)


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Backend:
    """One way to execute the packed quantized GEMM.

    Every kernel-path callable takes the live :class:`GemmConfig` as its
    last argument (interpret flag, and — for the ``shard-*`` family — the
    mesh/axis/layout knobs).

    1-bit surface (``bits == 1``):

    ``gemm(a_packed, b_packed, k_true, tiles, config) -> (M, N) int32``
    must return the EXACT ±1 dot (pad correction included).

    ``gemm_grouped(buckets, w_stack, k_true, tiles, config)`` contracts
    an (E, M, Kw) activation bucket against an (E, N, Kw) weight stack.

    ``prologue`` declares how the backend's float operands are prepared
    (the :class:`PrologueSpec` kind: ``"pack_sign"`` | ``"pack_planes"``
    | ``"float"``) — the activation-side analogue of the pad-correction
    declaration, resolved by :func:`resolve_prologue`.

    ``from_float``: optional shortcut taking raw float activations
    ``(x2, w_packed, k_true, config)`` — backends that never materialise
    globally-packed activations set it: the XLA unpack-and-MXU fallback
    (quantizes in-graph), and the ``shard-*`` family (quantize+pack runs
    INSIDE the shard_map body on each shard's local K-slab).

    k-bit surface (``bits > 1`` plane backends, or the ``from_float_kbit``
    fallbacks on ``"xla"``):

    ``gemm_kbit(a_planes, b_planes, tiles, config) -> (M, N) int32``
    returns the raw weighted-plane popcount S (plane counts are read off
    the stacks' leading dims; no pad correction exists on this path).

    ``gemm_kbit_grouped(buckets, w_stack, tiles, config)`` is the
    (E, ka, M, Kw) x (E, kb, N, Kw) expert-batched version.

    ``from_float_kbit(x2, w_planes, a_bits, w_bits, k_true, config)`` /
    ``from_float_kbit_grouped(x_sorted, w_stack, group_sizes, a_bits,
    w_bits, k_true, config)`` return the fake-quant DoReFa dot directly
    from float activations (the in-graph dequant path the dry-run lowers,
    and the shard family's fused pack-inside-the-body path).
    """

    name: str
    gemm: Callable
    gemm_grouped: Callable | None = None
    from_float: Callable | None = None
    from_float_grouped: Callable | None = None
    bits: int = 1
    gemm_kbit: Callable | None = None
    gemm_kbit_grouped: Callable | None = None
    from_float_kbit: Callable | None = None
    from_float_kbit_grouped: Callable | None = None
    prologue: str = "pack_sign"


_REGISTRY: dict[str, Backend] = {}


def register_backend(backend: Backend) -> None:
    _REGISTRY[backend.name] = backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown gemm backend {name!r}; registered: "
            f"{sorted(_REGISTRY)}"
        ) from None


_SHARD_PREFIX = "shard-"


def _family(base: str) -> str:
    """The kernel family of an UNPREFIXED backend name: ``"mxu-k4"`` ->
    ``"mxu"``, ``"vpu"`` -> ``"vpu"`` (plane entries are ``family-kN``)."""
    return base.split("-k", 1)[0]


def resolve_backend(name: str, w_bits: int) -> str:
    """Map a base backend name + the layer's weight bit width onto the
    registry entry that executes it (the paper's full 1..k family behind
    one config knob).  Resolution is FAMILY-aware: ``"mxu"`` resolves onto
    the ``mxu-k*`` int8 code-lane entries and ``"vpu"`` onto the plane
    popcount entries (likewise their ``shard-`` twins):

    * ``w_bits == 1`` — the name is used as-is (the 1-bit entries), except
      that a plane backend down-resolves to its family's 1-bit entry
      (``"mxu-k4"`` -> ``"mxu"``, ``"shard-vpu-k2"`` -> ``"shard-vpu"`` —
      plane entries have no ±1 kernel, and per-layer policies mix 1-bit
      and k-bit layers under one configured base name).
    * an entry that already handles ``w_bits`` (a matching ``*-kN`` or a
      ``from_float_kbit`` fallback like ``"xla"``) — used as-is.
    * otherwise the family's ``{family}-k{w_bits}`` when registered
      (``shard-{family}-k{w_bits}`` for shard base names), then
      ``vpu-k{w_bits}`` as the plane fallback, else the ``"xla"`` dequant
      fallback (w3/w5/... stay correct, just not plane-packed).
    """
    prefix = _SHARD_PREFIX if name.startswith(_SHARD_PREFIX) else ""
    base = name[len(prefix):]
    fam = _family(base)
    if w_bits <= 1:
        be = _REGISTRY.get(name)
        if be is not None and be.bits > 1:
            one = prefix + fam
            return one if one in _REGISTRY else prefix + "vpu"
        return name
    be = get_backend(name)  # unknown base names raise here, not fall back
    if be.bits == w_bits or be.from_float_kbit is not None:
        return name
    for fallback_fam in (fam, "vpu"):
        kname = f"{prefix}{fallback_fam}-k{w_bits}"
        if kname in _REGISTRY:
            return kname
    if prefix:
        # the xla dequant fallback is single-device: a shard-* base name
        # at a width with no plane entry silently loses its configured
        # tensor parallelism for that layer — say so, once per combo
        _warn_shard_fallback(name, w_bits)
    return "xla"


@functools.lru_cache(maxsize=None)  # once per (name, w_bits)
def _warn_shard_fallback(name: str, w_bits: int) -> None:
    import warnings

    warnings.warn(
        f"backend {name!r} has no plane entry for w_bits={w_bits}; this "
        "layer falls back to the SINGLE-DEVICE 'xla' dequant path (its "
        "configured tensor parallelism does not apply). Register "
        f"'shard-vpu-k{w_bits}' or use a width in {{2,4,8}} to keep the "
        "GEMM sharded.",
        stacklevel=3,
    )


def unsharded(config: GemmConfig) -> GemmConfig:
    """Strip a config's ``shard-*`` backend back to its inner single-device
    backend (and drop the mesh).  Callers that are ALREADY inside a
    ``shard_map`` body (nn/mlp.py's expert-parallel path) must route their
    GEMMs through this — nesting a shard backend's shard_map inside
    another is an error."""
    if not config.backend.startswith(_SHARD_PREFIX):
        return config
    return dataclasses.replace(
        config, backend=config.backend[len(_SHARD_PREFIX):], mesh=None
    )


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = _round_up(x.shape[axis], mult) - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pad_tiles(a: jax.Array, b: jax.Array, tiles: TileConfig):
    """Pad (…, M, Kw) and (…, N, Kw) up to tile multiples (zero words)."""
    a = _pad_axis(_pad_axis(a, -2, tiles.bm), -1, tiles.bkw)
    b = _pad_axis(_pad_axis(b, -2, tiles.bn), -1, tiles.bkw)
    return a, b


# --- raw kernel seams (shared by single-device and shard backends) --------
# Each returns the kernel's RAW integer output (tile padding handled, rows
# sliced back) plus, for the MXU, the padded word count actually
# contracted.  Raw outputs over disjoint Kw slices sum exactly, so the
# shard backends psum these and correct once on the reduced sum.


def _vpu_raw(ap, bp, tiles, interpret):
    """Raw xor-mismatch counts (m, n) int32 (pad bits are 0 in both
    operands -> 0 mismatches, so no per-call term exists)."""
    m, n = ap.shape[0], bp.shape[0]
    ap, bp = _pad_tiles(ap, bp, tiles)
    return xnor_mismatch_pallas(
        ap, bp, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw,
        chunk_words=tiles.chunk_words, interpret=interpret,
    )[:m, :n]


def _mxu_raw(ap, bp, tiles, interpret):
    """Raw padded MXU dot (m, n) int32 and the word count it contracted."""
    m, n = ap.shape[0], bp.shape[0]
    ap, bp = _pad_tiles(ap, bp, tiles)
    dot = xnor_dot_mxu_pallas(
        ap, bp, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw, interpret=interpret
    )[:m, :n]
    return dot, ap.shape[-1]


def _vpu_raw_grouped(buckets, w_stack, tiles, interpret):
    m, n = buckets.shape[1], w_stack.shape[1]
    buckets, w_stack = _pad_tiles(buckets, w_stack, tiles)
    return xnor_mismatch_batched_pallas(
        buckets, w_stack, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw,
        chunk_words=tiles.chunk_words, interpret=interpret,
    )[:, :m, :n]


def _mxu_raw_grouped(buckets, w_stack, tiles, interpret):
    m, n = buckets.shape[1], w_stack.shape[1]
    buckets, w_stack = _pad_tiles(buckets, w_stack, tiles)
    dot = xnor_dot_mxu_batched_pallas(
        buckets, w_stack, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw,
        interpret=interpret,
    )[:, :m, :n]
    return dot, buckets.shape[-1]


# --- vpu: the literal paper algorithm (xnor + popcount on the VPU) --------


def _vpu_gemm(ap, bp, k_true, tiles, config):
    # Eq. 2 inverse on the raw mismatch count:
    return k_true - 2 * _vpu_raw(ap, bp, tiles, config._interpret)


def _vpu_gemm_grouped(buckets, w_stack, k_true, tiles, config):
    return k_true - 2 * _vpu_raw_grouped(buckets, w_stack, tiles,
                                         config._interpret)


# --- mxu: unpack packed words in VMEM, contract on the MXU ----------------


def _mxu_gemm(ap, bp, k_true, tiles, config):
    padded_dot, words = _mxu_raw(ap, bp, tiles, config._interpret)
    return padded_dot - mxu_pad_inflation(words, k_true)


def _mxu_gemm_grouped(buckets, w_stack, k_true, tiles, config):
    padded_dot, words = _mxu_raw_grouped(buckets, w_stack, tiles,
                                         config._interpret)
    return padded_dot - mxu_pad_inflation(words, k_true)


# --- xla: pure-jnp fallback / dry-run lowering target ---------------------


def _xla_gemm(ap, bp, k_true, tiles, config):
    del tiles, config
    return ref.xnor_gemm_ref(ap, bp, k_true)


def _xla_from_float(x2, w_packed, k_true, config):
    """Weights stay bit-packed in HBM, unpack to ±1 in-graph and contract
    on the MXU with fp32 accumulation (exact for ±1 up to 2^24 terms).
    The popcount reference (ref.xnor_gemm_ref) stays the test oracle — its
    (M, N, Kw) intermediate is fine for tests but not for lowering
    1M-token prefill cells."""
    del config
    w_pm1 = bitpack.unpack_sign(w_packed, k_true, jnp.bfloat16)  # (N, K)
    xq = jnp.where(x2 >= 0, 1.0, -1.0).astype(jnp.bfloat16)
    return jax.lax.dot_general(
        xq, w_pm1,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _xla_from_float_grouped(x_sorted, w_stack, group_sizes, k_true, config):
    """Ragged-dot lowering of the grouped GEMM: packed words unpack
    in-graph, then ``lax.ragged_dot`` — the shape the dry-run cost model
    understands (no per-expert bucketing materialised)."""
    del config
    e, n, _ = w_stack.shape
    w_pm1 = bitpack.unpack_sign(w_stack, k_true, jnp.bfloat16)  # (E, N, K)
    w_ekn = jnp.transpose(w_pm1, (0, 2, 1))  # (E, K, N)
    xq = jnp.where(x_sorted >= 0, 1.0, -1.0).astype(jnp.bfloat16)
    return jax.lax.ragged_dot(xq, w_ekn, group_sizes).astype(jnp.float32)


# --- k-bit plane backends: DoReFa bit-plane popcount (kbit_gemm.py) -------


def _kbit_dequant(s, t_sum, a_bits, w_bits):
    """Integer plane GEMM -> fake-quant DoReFa dot (fp32):

        a_q = n_a/Na,  w_q = (2*n_w - Nw)/Nw
        =>  dot = (2*S - Nw*T) / (Na*Nw)

    with S the weighted-plane popcount and T the activation code row-sums.
    The numerator stays in int32 (a prior fp32 cast of S loses bits past
    2^24 and the subtraction is cancellation-prone); the single fp32
    divide is the only rounding.  ``_check_kbit_accumulator`` bounds every
    term below 2^31."""
    na = (1 << a_bits) - 1
    nw = (1 << w_bits) - 1
    num = 2 * s - jnp.int32(nw) * t_sum
    return num.astype(jnp.float32) / float(na * nw)


def _check_kbit_widths(w_bits: int, a_bits: int) -> None:
    """Reject width combinations the packed path has no semantics for,
    loudly: 1-bit sign values have no unsigned plane form, so mixing a
    1-bit side with a k-bit side would silently compute the wrong
    quantizer (round(clip(x,0,1)) is NOT sign(x))."""
    if w_bits > 1 and a_bits > 1:
        if not (2 <= w_bits <= 8 and 2 <= a_bits <= 8):
            raise ValueError(
                f"packed k-bit GEMM supports widths 2..8, got "
                f"w{w_bits}a{a_bits}"
            )
    elif w_bits > 1 or a_bits > 1:
        raise ValueError(
            f"mixed 1-bit/k-bit widths unsupported: w{w_bits}a{a_bits} "
            "(use both widths 1, or both in 2..8)"
        )


def _check_kbit_accumulator(k_true: int, a_bits: int, w_bits: int) -> None:
    """The plane kernels accumulate S <= K * Na * Nw in int32 (and the
    dequant numerator 2S - Nw*T has the same bound); shapes and widths are
    static, so an oversized contraction fails at trace time instead of
    silently wrapping (w8a8 caps K at ~16k, w4a4 at ~4.7M).  Only the
    integer plane arm needs this — the ``"xla"`` dequant fallback
    contracts in fp32."""
    bound = 2 * k_true * ((1 << a_bits) - 1) * ((1 << w_bits) - 1)
    if bound >= 2**31:
        raise ValueError(
            f"k-bit GEMM overflows its int32 accumulator: K={k_true} at "
            f"w{w_bits}a{a_bits} needs 2*K*Na*Nw = {bound} >= 2^31; split "
            "the contraction or reduce the bit width"
        )


def _check_kbit_accumulator_mxu(k_true: int, a_bits: int,
                                w_bits: int) -> None:
    """Re-derived bound for the int8 code-lane MXU path
    (kernels/kbit_mxu.py): ONE int32 partial per output element
    accumulates the FULL code dot ``S <= K * Na * Nw`` — not the popcount
    path's ``<= K`` per plane-pair pass with the ``2^(i+j)`` weights
    applied after — and the dequant numerator ``2S - Nw*T`` doubles it.
    The offset-dot cross terms the kernel actually sums are each smaller
    than the restored S, so the binding ceiling is numerically the SAME
    ``2 * K * Na * Nw < 2^31`` as the popcount path; it is re-checked
    here separately so the failure names the single-partial int8
    accumulation."""
    bound = 2 * k_true * ((1 << a_bits) - 1) * ((1 << w_bits) - 1)
    if bound >= 2**31:
        raise ValueError(
            f"k-bit MXU GEMM overflows its int32 accumulator: the int8 "
            f"code-lane path sums the full code dot in ONE int32 partial "
            f"per element, and K={k_true} at w{w_bits}a{a_bits} needs "
            f"2*K*Na*Nw = {bound} >= 2^31; split the contraction, reduce "
            "the bit width, or use the plane popcount backend with a "
            "sharded K split"
        )


def _accum_check_for(name: str):
    """The trace-time int32 bound check matching a RESOLVED backend name:
    the ``mxu-k*`` families accumulate the full code dot per partial and
    get the re-derived check; everything else keeps the plane-pair one."""
    base = name[len(_SHARD_PREFIX):] if name.startswith(_SHARD_PREFIX) \
        else name
    return (_check_kbit_accumulator_mxu if _family(base) == "mxu"
            else _check_kbit_accumulator)


def _pad_planes(a: jax.Array, b: jax.Array, tiles: TileConfig):
    """Pad (…, ka, M, Kw) and (…, kb, N, Kw) plane stacks up to tile
    multiples.  Zero words AND to zero, so padding needs no correction."""
    a = _pad_axis(_pad_axis(a, -2, tiles.bm), -1, tiles.bkw)
    b = _pad_axis(_pad_axis(b, -2, tiles.bn), -1, tiles.bkw)
    return a, b


def _vpu_kbit_gemm(a_planes, b_planes, tiles, config):
    m, n = a_planes.shape[1], b_planes.shape[1]
    a_planes, b_planes = _pad_planes(a_planes, b_planes, tiles)
    return kbit_plane_gemm_pallas(
        a_planes, b_planes, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw,
        chunk_words=tiles.chunk_words, interpret=config._interpret,
    )[:m, :n]


def _vpu_kbit_gemm_grouped(buckets, w_stack, tiles, config):
    m, n = buckets.shape[2], w_stack.shape[2]
    buckets, w_stack = _pad_planes(buckets, w_stack, tiles)
    return kbit_plane_gemm_batched_pallas(
        buckets, w_stack, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw,
        chunk_words=tiles.chunk_words, interpret=config._interpret,
    )[:, :m, :n]


def _mxu_kbit_gemm(a_planes, b_planes, tiles, config):
    """int8 code-lane MXU S (kernels/kbit_mxu.py) — bit-identical to
    ``_vpu_kbit_gemm`` (integer arithmetic only), one MXU contraction per
    tile instead of ka*kb popcount passes."""
    m, n = a_planes.shape[1], b_planes.shape[1]
    a_planes, b_planes = _pad_planes(a_planes, b_planes, tiles)
    return kbit_mxu_gemm_pallas(
        a_planes, b_planes, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw,
        interpret=config._interpret,
    )[:m, :n]


def _mxu_kbit_gemm_grouped(buckets, w_stack, tiles, config):
    m, n = buckets.shape[2], w_stack.shape[2]
    buckets, w_stack = _pad_planes(buckets, w_stack, tiles)
    return kbit_mxu_gemm_batched_pallas(
        buckets, w_stack, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw,
        interpret=config._interpret,
    )[:, :m, :n]


# single-device k-bit raw seams per family: the shard-* k-bit backends
# run one of these inside their shard_map bodies
_KBIT_GEMM = {"vpu": _vpu_kbit_gemm, "mxu": _mxu_kbit_gemm}
_KBIT_GEMM_GROUPED = {"vpu": _vpu_kbit_gemm_grouped,
                      "mxu": _mxu_kbit_gemm_grouped}


def _xla_kbit_s(a_planes, b_planes, tiles, config):
    del tiles, config
    return ref.kbit_gemm_ref(a_planes, b_planes)


def _dequant_weight_planes(w_planes, k_true, w_bits):
    """(…, kb, N, Kw) plane stack -> (…, N, K) fp32 DoReFa weight values."""
    codes = bitpack.unpack_planes(jnp.moveaxis(w_planes, -3, 0), k_true)
    nw = float((1 << w_bits) - 1)
    return (2.0 * codes.astype(jnp.float32) - nw) / nw


def _xla_kbit_from_float(x2, w_planes, a_bits, w_bits, k_true, config):
    """Weights stay plane-packed in HBM (k/32 of fp32 bytes), dequantized
    to fp32 in-graph and contracted on the MXU — the k-bit analogue of
    ``_xla_from_float`` and the shape the dry-run cost model lowers."""
    del config
    wq = _dequant_weight_planes(w_planes, k_true, w_bits)  # (N, K)
    xq = quant.quantize_act(x2.astype(jnp.float32), a_bits)
    return jax.lax.dot_general(
        xq, wq,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _xla_kbit_from_float_grouped(x_sorted, w_stack, group_sizes, a_bits,
                                 w_bits, k_true, config):
    """Ragged-dot lowering of the grouped k-bit GEMM (cf. the 1-bit
    ``_xla_from_float_grouped``)."""
    del config
    wq = _dequant_weight_planes(w_stack, k_true, w_bits)  # (E, N, K)
    w_ekn = jnp.transpose(wq, (0, 2, 1))  # (E, K, N)
    xq = quant.quantize_act(x_sorted.astype(jnp.float32), a_bits)
    return jax.lax.ragged_dot(xq, w_ekn, group_sizes)


# --- shard-*: tensor-parallel packed GEMM (shard_map over config.mesh) ----
# The same Pallas kernels run per mesh shard on their operand slice; the
# RAW integer outputs (mismatch counts / padded dots / plane popcounts)
# psum over the contraction axis, and pad correction + epilogue apply once
# on the reduced sum — sharded results are bit-identical to single-device.
# Operand layouts come from dist.sharding.packed_gemm_pspecs; tiles are
# re-selected for the PER-SHARD shapes (the caller's tiles argument covers
# the global operand and is ignored here).


def _shard_ctx(config: GemmConfig, what: str):
    """Validate the tensor-parallel knobs; returns (mesh, contraction
    axis, its size, expert-axis size)."""
    mesh = config.mesh
    if mesh is None:
        raise ValueError(
            f"{what} needs GemmConfig.mesh (a jax Mesh) — thread it via "
            "QCtx(mesh=...) or GemmConfig(mesh=...)"
        )
    sizes = {k: int(v) for k, v in dict(mesh.shape).items()}
    axis = config.shard_axis
    if axis not in sizes:
        raise ValueError(
            f"{what}: shard_axis {axis!r} not on mesh axes {tuple(sizes)}"
        )
    ea = config.expert_axis
    if ea is not None and ea not in sizes:
        raise ValueError(
            f"{what}: expert_axis {ea!r} not on mesh axes {tuple(sizes)}"
        )
    return mesh, axis, sizes[axis], (sizes[ea] if ea else 1)


def _shard_gemm(inner, ap, bp, k_true, tiles, config):
    del tiles  # re-selected for the per-shard shapes below
    if inner not in ("vpu", "mxu"):
        # the raw-seam branches below are kernel-specific; a new 1-bit
        # backend needs its own raw/correction pair wired here
        raise ValueError(f"no sharded raw seam for inner backend {inner!r}")
    mesh, axis, ns, _ = _shard_ctx(config, f"backend 'shard-{inner}'")
    interp = config._interpret
    m, n = ap.shape[0], bp.shape[0]
    if config.shard_layout == "n":
        # column-parallel: each shard runs the full contraction (its own
        # pad correction included) over its slice of weight rows
        part = packed_gemm_pspecs("n", axis)
        bp_p = _pad_axis(bp, 0, ns)
        t = config.tiles(m, bp_p.shape[0] // ns, ap.shape[1], backend=inner)
        inner_be = get_backend(inner)

        def body_n(a_loc, b_loc):
            return inner_be.gemm(a_loc, b_loc, k_true, t, config)

        out = shard_map(body_n, mesh=mesh, in_specs=(part.a, part.w),
                        out_specs=part.out, check_vma=False)(ap, bp_p)
        return out[:, :n]
    part = packed_gemm_pspecs(config.shard_layout, axis)
    ap_p = _pad_axis(ap, 1, ns)  # zero words: 0 mismatches / counted pads
    bp_p = _pad_axis(bp, 1, ns)
    kw_loc = ap_p.shape[1] // ns
    if config.overlap_collective:
        # ring-overlap variant (see _ring_chunk_reduce); bit-identical
        nc = _round_up(n, ns) // ns
        bp_p = _pad_axis(bp_p, 0, ns)
        t = config.tiles(m, nc, kw_loc, backend=inner)

        def body_ring(a_loc, b_loc):
            def chunk(c):
                b_c = jax.lax.dynamic_slice_in_dim(b_loc, c * nc, nc,
                                                   axis=0)
                if inner == "vpu":
                    return _vpu_raw(a_loc, b_c, t, interp)
                return _mxu_raw(a_loc, b_c, t, interp)[0]

            return _ring_chunk_reduce(chunk, axis=part.reduce_axis, ns=ns,
                                      nc=nc)

        raw = shard_map(body_ring, mesh=mesh, in_specs=(part.a, part.w),
                        out_specs=part.out, check_vma=False)(ap_p, bp_p)
        raw = raw[:, :n]
        if inner == "vpu":
            return k_true - 2 * raw
        return raw - mxu_pad_inflation(ns * _round_up(kw_loc, t.bkw),
                                       k_true)
    t = config.tiles(m, n, kw_loc, backend=inner)
    if inner == "vpu":

        def body_vpu(a_loc, b_loc):
            return jax.lax.psum(_vpu_raw(a_loc, b_loc, t, interp),
                                part.reduce_axis)

        mism = shard_map(body_vpu, mesh=mesh, in_specs=(part.a, part.w),
                         out_specs=part.out, check_vma=False)(ap_p, bp_p)
        return k_true - 2 * mism

    def body_mxu(a_loc, b_loc):
        dot, _ = _mxu_raw(a_loc, b_loc, t, interp)
        return jax.lax.psum(dot, part.reduce_axis)

    dot = shard_map(body_mxu, mesh=mesh, in_specs=(part.a, part.w),
                    out_specs=part.out, check_vma=False)(ap_p, bp_p)
    # every shard contracted round_up(kw_loc, bkw) words; correct ONCE
    return dot - mxu_pad_inflation(ns * _round_up(kw_loc, t.bkw), k_true)


def _shard_gemm_grouped(inner, buckets, w_stack, k_true, tiles, config):
    # expert-parallel (config.expert_axis) x Kw-parallel (config.shard_axis)
    # — the grouped path has no "n" layout (dist.sharding docstring), so a
    # configured shard_layout="n" is overridden to "k" here (mixed
    # dense+MoE models legitimately share one config; see
    # quant_gemm_grouped's docstring)
    del tiles
    if inner not in ("vpu", "mxu"):
        raise ValueError(f"no sharded raw seam for inner backend {inner!r}")
    mesh, axis, ns, es = _shard_ctx(
        config, f"backend 'shard-{inner}' (grouped)")
    interp = config._interpret
    e, ec = buckets.shape[0], buckets.shape[1]
    n = w_stack.shape[1]
    part = packed_gemm_pspecs("k", axis, expert_axis=config.expert_axis,
                              grouped=True)
    b_p = _pad_axis(_pad_axis(buckets, 0, es), 2, ns)
    w_p = _pad_axis(_pad_axis(w_stack, 0, es), 2, ns)
    kw_loc = b_p.shape[-1] // ns
    if config.overlap_collective:
        # ring-overlap variant inside each expert-axis group (see
        # _ring_chunk_reduce); bit-identical
        nc = _round_up(n, ns) // ns
        w_p = _pad_axis(w_p, 1, ns)
        t = config.tiles(ec, nc, kw_loc, backend=inner)

        def body_ring(b_loc, wl):
            def chunk(c):
                w_c = jax.lax.dynamic_slice_in_dim(wl, c * nc, nc, axis=1)
                if inner == "vpu":
                    return _vpu_raw_grouped(b_loc, w_c, t, interp)
                return _mxu_raw_grouped(b_loc, w_c, t, interp)[0]

            return _ring_chunk_reduce(chunk, axis=part.reduce_axis, ns=ns,
                                      nc=nc)

        raw = shard_map(body_ring, mesh=mesh, in_specs=(part.a, part.w),
                        out_specs=part.out, check_vma=False)(b_p, w_p)
        raw = raw[..., :n]
        if inner == "vpu":
            return (k_true - 2 * raw)[:e]
        words = ns * _round_up(kw_loc, t.bkw)
        return (raw - mxu_pad_inflation(words, k_true))[:e]
    t = config.tiles(ec, n, kw_loc, backend=inner)
    if inner == "vpu":

        def body_vpu(b_loc, wl):
            return jax.lax.psum(_vpu_raw_grouped(b_loc, wl, t, interp),
                                part.reduce_axis)

        mism = shard_map(body_vpu, mesh=mesh, in_specs=(part.a, part.w),
                         out_specs=part.out, check_vma=False)(b_p, w_p)
        return (k_true - 2 * mism)[:e]

    def body_mxu(b_loc, wl):
        dot, _ = _mxu_raw_grouped(b_loc, wl, t, interp)
        return jax.lax.psum(dot, part.reduce_axis)

    dot = shard_map(body_mxu, mesh=mesh, in_specs=(part.a, part.w),
                    out_specs=part.out, check_vma=False)(b_p, w_p)
    words = ns * _round_up(kw_loc, t.bkw)
    return (dot - mxu_pad_inflation(words, k_true))[:e]


def _shard_kbit_gemm(family, a_planes, b_planes, tiles, config):
    """Tensor-parallel raw S for the ``shard-{family}-k*`` backends:
    ``family`` ("vpu" | "mxu") picks the per-shard kernel; the shard
    structure (pspecs, psum of raw S, no correction anywhere) is
    family-independent — pad words unpack to plane-AND 0 / code 0."""
    del tiles
    kernel = _KBIT_GEMM[family]
    mesh, axis, ns, _ = _shard_ctx(config, f"backend 'shard-{family}-k*'")
    inner = f"{family}-k{b_planes.shape[0]}"  # tile-table row
    m, n = a_planes.shape[1], b_planes.shape[1]
    if config.shard_layout == "n":
        part = packed_gemm_pspecs("n", axis, planes=True)
        b_p = _pad_axis(b_planes, 1, ns)
        t = config.tiles(m, b_p.shape[1] // ns, a_planes.shape[-1],
                         backend=inner)

        def body_n(a_loc, b_loc):
            return kernel(a_loc, b_loc, t, config)

        out = shard_map(body_n, mesh=mesh, in_specs=(part.a, part.w),
                        out_specs=part.out, check_vma=False)(a_planes, b_p)
        return out[:, :n]
    part = packed_gemm_pspecs(config.shard_layout, axis, planes=True)
    a_p = _pad_axis(a_planes, 2, ns)
    b_p = _pad_axis(b_planes, 2, ns)
    if config.overlap_collective:
        # ring-overlap variant (see _ring_chunk_reduce); bit-identical
        nc = _round_up(n, ns) // ns
        b_p = _pad_axis(b_p, 1, ns)
        t = config.tiles(m, nc, a_p.shape[-1] // ns, backend=inner)

        def body_ring(a_loc, b_loc):
            def chunk(c):
                b_c = jax.lax.dynamic_slice_in_dim(b_loc, c * nc, nc,
                                                   axis=1)
                return kernel(a_loc, b_c, t, config)

            return _ring_chunk_reduce(chunk, axis=part.reduce_axis, ns=ns,
                                      nc=nc)

        s = shard_map(body_ring, mesh=mesh, in_specs=(part.a, part.w),
                      out_specs=part.out, check_vma=False)(a_p, b_p)
        return s[:, :n]
    t = config.tiles(m, n, a_p.shape[-1] // ns, backend=inner)

    def body_k(a_loc, b_loc):
        # raw S needs no pad correction anywhere: zero plane words AND to
        # 0 on the popcount path, unpack to code 0 on the int8 MXU path
        return jax.lax.psum(kernel(a_loc, b_loc, t, config),
                            part.reduce_axis)

    return shard_map(body_k, mesh=mesh, in_specs=(part.a, part.w),
                     out_specs=part.out, check_vma=False)(a_p, b_p)


def _shard_kbit_gemm_grouped(family, buckets, w_stack, tiles, config):
    del tiles
    kernel = _KBIT_GEMM_GROUPED[family]
    mesh, axis, ns, es = _shard_ctx(config, f"backend 'shard-{family}-k*' "
                                            "(grouped)")
    e, ec = buckets.shape[0], buckets.shape[2]
    kb, n = w_stack.shape[1], w_stack.shape[2]
    part = packed_gemm_pspecs("k", axis, expert_axis=config.expert_axis,
                              planes=True, grouped=True)
    b_p = _pad_axis(_pad_axis(buckets, 0, es), 3, ns)
    w_p = _pad_axis(_pad_axis(w_stack, 0, es), 3, ns)
    if config.overlap_collective:
        # ring-overlap variant inside each expert-axis group (see
        # _ring_chunk_reduce); bit-identical
        nc = _round_up(n, ns) // ns
        w_p = _pad_axis(w_p, 2, ns)
        t = config.tiles(ec, nc, b_p.shape[-1] // ns,
                         backend=f"{family}-k{kb}")

        def body_ring(b_loc, wl):
            def chunk(c):
                w_c = jax.lax.dynamic_slice_in_dim(wl, c * nc, nc, axis=2)
                return kernel(b_loc, w_c, t, config)

            return _ring_chunk_reduce(chunk, axis=part.reduce_axis, ns=ns,
                                      nc=nc)

        s = shard_map(body_ring, mesh=mesh, in_specs=(part.a, part.w),
                      out_specs=part.out, check_vma=False)(b_p, w_p)
        return s[..., :n][:e]
    t = config.tiles(ec, n, b_p.shape[-1] // ns, backend=f"{family}-k{kb}")

    def body(b_loc, wl):
        return jax.lax.psum(kernel(b_loc, wl, t, config),
                            part.reduce_axis)

    s = shard_map(body, mesh=mesh, in_specs=(part.a, part.w),
                  out_specs=part.out, check_vma=False)(b_p, w_p)
    return s[:e]


# --- shard-* fused prologue: quantize+pack INSIDE the shard_map body ------
# Float-activation entry points route here (Backend.from_float*).  The
# "k" layout word-aligns the float K split (each shard's slab is a whole
# number of packed words), so the words each shard packs are EXACTLY the
# global packed words of that slab and results stay bit-identical — but
# the global-pack-then-reshard hop is gone: floats shard once, and only
# local slabs are quantized+packed.  The "n" layout packs once (fused)
# and broadcasts the packed words.  Float pad is -1.0: bit 0 at 1 bit,
# code 0 after the DoReFa clip — zero words in both operands either way.


def _kw_split(k_true: int, ns: int) -> tuple[int, int]:
    """Word-aligned float K split over ``ns`` shards: returns (K-words per
    shard, padded float K = ns * kw_loc * 32)."""
    kw_pad = _round_up(bitpack.packed_width(k_true), ns)
    return kw_pad // ns, kw_pad * WORD_BITS


def _pad_k_float(x: jax.Array, k_pad: int) -> jax.Array:
    pad = k_pad - x.shape[-1]
    if pad == 0:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths, constant_values=-1.0)  # bit 0 / code 0


def _ring_chunk_reduce(compute_chunk, *, axis, ns, nc):
    """``collective_matmul``-style ring reduce-scatter of N-chunked raw
    int32 partials (``GemmConfig.overlap_collective``).

    ``compute_chunk(c) -> (..., nc) int32`` is this shard's raw partial
    (over its local Kw slab) for output-column chunk ``c`` — any leading
    dims (the dense paths produce ``(m, nc)``, the grouped paths
    ``(e, ec, nc)``); must be called inside a shard_map body over
    ``axis`` with ``ns`` shards.  Instead of one monolithic ``psum`` of
    the full (..., ns*nc) partial — a barrier no compute hides behind —
    each shard walks the ring: compute one chunk's partial, add it to the
    accumulator arriving from the ring predecessor, ``ppermute`` onward,
    and start the NEXT chunk's GEMM while the hop is in flight.  After
    ns-1 hops shard ``i`` owns the fully-reduced chunk ``i``; a final
    ``all_gather`` rebuilds the replicated (..., ns*nc) S.  The chunk
    schedule (shard ``i`` computes chunk ``i + ns - 1 - t`` at step
    ``t``) is exactly the reduce-scatter matmul of Wang et al.'s
    collective-matmul decomposition, applied to the raw integer partials.

    Because every partial is int32 and integer addition is exact in any
    order, the result is BIT-IDENTICAL to the sequential psum — CI gates
    overlap-on vs overlap-off on equality, not tolerance.  ``ns == 1``
    degenerates to a single chunk computation with no collective."""
    if ns == 1:
        return compute_chunk(0)
    idx = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % ns) for i in range(ns)]
    acc = compute_chunk((idx + ns - 1) % ns)
    for t in range(1, ns):
        acc = jax.lax.ppermute(acc, axis, perm)
        acc = acc + compute_chunk((idx + ns - 1 - t) % ns)
    gathered = jax.lax.all_gather(acc, axis, axis=0)  # (ns, ..., nc)
    gathered = jnp.moveaxis(gathered, 0, -2)          # (..., ns, nc)
    return gathered.reshape(*gathered.shape[:-2], ns * nc)


def _shard_from_float(inner, x2, w_packed, k_true, config):
    """1-bit tensor-parallel GEMM from float activations, prologue inside
    the shard_map body (see the section comment)."""
    mesh, axis, ns, _ = _shard_ctx(config, f"backend 'shard-{inner}'")
    interp = config._interpret
    fused = config.fused_prologue
    if config.shard_layout == "n":
        # column-parallel: pack ONCE (fused), broadcast packed words, and
        # delegate to the packed-operand "n" branch (no collective)
        xp = pack_activations(x2, use_pallas=fused, interpret=interp)
        return _shard_gemm(inner, xp, w_packed, k_true, None, config)
    m, n = x2.shape[0], w_packed.shape[0]
    kw_loc, k_pad = _kw_split(k_true, ns)
    x_p = _pad_k_float(x2, k_pad)
    w_p = _pad_axis(w_packed, 1, ns)
    part = packed_gemm_pspecs("k", axis, prologue=True)
    if config.overlap_collective:
        # ring-overlap variant: raw partials reduce-scatter chunk-wise
        # (see _ring_chunk_reduce) instead of one psum; bit-identical
        nc = _round_up(n, ns) // ns
        w_p = _pad_axis(w_p, 0, ns)
        t = config.tiles(m, nc, kw_loc, backend=inner)

        def body_ring(a_loc, b_loc):
            ap = pack_activations(a_loc, use_pallas=fused, interpret=interp)

            def chunk(c):
                b_c = jax.lax.dynamic_slice_in_dim(b_loc, c * nc, nc,
                                                   axis=0)
                if inner == "vpu":
                    return _vpu_raw(ap, b_c, t, interp)
                return _mxu_raw(ap, b_c, t, interp)[0]

            return _ring_chunk_reduce(chunk, axis=part.reduce_axis, ns=ns,
                                      nc=nc)

        raw = shard_map(body_ring, mesh=mesh, in_specs=(part.a, part.w),
                        out_specs=part.out, check_vma=False)(x_p, w_p)
        raw = raw[:, :n]
        if inner == "vpu":
            return k_true - 2 * raw
        return raw - mxu_pad_inflation(ns * _round_up(kw_loc, t.bkw),
                                       k_true)
    t = config.tiles(m, n, kw_loc, backend=inner)
    if inner == "vpu":

        def body_vpu(a_loc, b_loc):
            ap = pack_activations(a_loc, use_pallas=fused, interpret=interp)
            return jax.lax.psum(_vpu_raw(ap, b_loc, t, interp),
                                part.reduce_axis)

        mism = shard_map(body_vpu, mesh=mesh, in_specs=(part.a, part.w),
                         out_specs=part.out, check_vma=False)(x_p, w_p)
        return k_true - 2 * mism

    def body_mxu(a_loc, b_loc):
        ap = pack_activations(a_loc, use_pallas=fused, interpret=interp)
        dot, _ = _mxu_raw(ap, b_loc, t, interp)
        return jax.lax.psum(dot, part.reduce_axis)

    dot = shard_map(body_mxu, mesh=mesh, in_specs=(part.a, part.w),
                    out_specs=part.out, check_vma=False)(x_p, w_p)
    # every shard contracted round_up(kw_loc, bkw) words; correct ONCE
    return dot - mxu_pad_inflation(ns * _round_up(kw_loc, t.bkw), k_true)


def _shard_kbit_from_float(family, x2, w_planes, a_bits, w_bits, k_true,
                           config):
    """k-bit tensor-parallel DoReFa dot from float activations: the fused
    quantize->plane-pack prologue runs inside the shard_map body ("k"
    layout — raw S and the code row-sums T both psum exactly) or once
    before it ("n"); the dequant rewrite runs once on the sums.
    ``family`` ("vpu" | "mxu") picks the per-shard S kernel; with
    ``config.overlap_collective`` the "k" layout reduces S over the
    chunked ppermute ring instead (T, an (M, 1) sliver, keeps the plain
    psum — nothing hides behind a collective that small)."""
    kernel = _KBIT_GEMM[family]
    mesh, axis, ns, _ = _shard_ctx(config, f"backend 'shard-{family}-k*'")
    _accum_check_for(family)(k_true, a_bits, w_bits)
    interp = config._interpret
    fused = config.fused_prologue
    kb, n = w_planes.shape[0], w_planes.shape[1]
    m = x2.shape[0]
    if config.shard_layout == "n":
        planes, t_sum = pack_act_planes(x2, a_bits, fused=fused,
                                        interpret=interp)
        s = _shard_kbit_gemm(family, planes, w_planes, None, config)
        return _kbit_dequant(s, t_sum, a_bits, w_bits)
    kw_loc, k_pad = _kw_split(k_true, ns)
    x_p = _pad_k_float(x2, k_pad)
    w_p = _pad_axis(w_planes, 2, ns)
    part = packed_gemm_pspecs("k", axis, planes=True, prologue=True)
    if config.overlap_collective:
        nc = _round_up(n, ns) // ns
        w_p = _pad_axis(w_p, 1, ns)
        t = config.tiles(m, nc, kw_loc, backend=f"{family}-k{kb}")

        def body_ring(a_loc, b_loc):
            planes_loc, t_loc = pack_act_planes(a_loc, a_bits, fused=fused,
                                                interpret=interp)

            def chunk(c):
                b_c = jax.lax.dynamic_slice_in_dim(b_loc, c * nc, nc,
                                                   axis=1)
                return kernel(planes_loc, b_c, t, config)

            s_loc = _ring_chunk_reduce(chunk, axis=part.reduce_axis,
                                       ns=ns, nc=nc)
            return s_loc, jax.lax.psum(t_loc, part.reduce_axis)

        s, t_sum = shard_map(body_ring, mesh=mesh,
                             in_specs=(part.a, part.w),
                             out_specs=(part.out, part.out),
                             check_vma=False)(x_p, w_p)
        return _kbit_dequant(s[:, :n], t_sum, a_bits, w_bits)
    t = config.tiles(m, n, kw_loc, backend=f"{family}-k{kb}")

    def body(a_loc, b_loc):
        planes_loc, t_loc = pack_act_planes(a_loc, a_bits, fused=fused,
                                            interpret=interp)
        s_loc = kernel(planes_loc, b_loc, t, config)
        return (jax.lax.psum(s_loc, part.reduce_axis),
                jax.lax.psum(t_loc, part.reduce_axis))

    s, t_sum = shard_map(body, mesh=mesh, in_specs=(part.a, part.w),
                         out_specs=(part.out, part.out),
                         check_vma=False)(x_p, w_p)
    return _kbit_dequant(s, t_sum, a_bits, w_bits)


def _kbit_only(*_args, **_kw):
    raise ValueError(
        "k-bit plane backends execute k-bit GEMMs only; call the entry "
        "points with w_bits/a_bits (or use a 1-bit backend)"
    )


register_backend(Backend("vpu", _vpu_gemm, gemm_grouped=_vpu_gemm_grouped,
                         prologue="pack_sign"))
register_backend(Backend("mxu", _mxu_gemm, gemm_grouped=_mxu_gemm_grouped,
                         prologue="pack_sign"))
register_backend(
    Backend(
        "xla",
        _xla_gemm,
        from_float=_xla_from_float,
        from_float_grouped=_xla_from_float_grouped,
        gemm_kbit=_xla_kbit_s,
        from_float_kbit=_xla_kbit_from_float,
        from_float_kbit_grouped=_xla_kbit_from_float_grouped,
        prologue="float",
    )
)
for _fam in ("vpu", "mxu"):
    for _k in (2, 4, 8):
        register_backend(
            Backend(
                f"{_fam}-k{_k}",
                _kbit_only,
                bits=_k,
                gemm_kbit=_KBIT_GEMM[_fam],
                gemm_kbit_grouped=_KBIT_GEMM_GROUPED[_fam],
                prologue="pack_planes",
            )
        )
for _inner in ("vpu", "mxu"):
    register_backend(
        Backend(
            f"shard-{_inner}",
            functools.partial(_shard_gemm, _inner),
            gemm_grouped=functools.partial(_shard_gemm_grouped, _inner),
            from_float=functools.partial(_shard_from_float, _inner),
            prologue="pack_sign",
        )
    )
for _fam in ("vpu", "mxu"):
    for _k in (2, 4, 8):
        register_backend(
            Backend(
                f"shard-{_fam}-k{_k}",
                _kbit_only,
                bits=_k,
                gemm_kbit=functools.partial(_shard_kbit_gemm, _fam),
                gemm_kbit_grouped=functools.partial(
                    _shard_kbit_gemm_grouped, _fam),
                from_float_kbit=functools.partial(
                    _shard_kbit_from_float, _fam),
                prologue="pack_planes",
            )
        )


# ---------------------------------------------------------------------------
# Activation prologue (paper Fig. 1's "binarize input" stage): the fused
# quantize->pack entry points every backend's operand prep routes through.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("bm", "bkw", "use_pallas",
                                             "interpret"))
def pack_activations(
    x: jax.Array,
    *,
    bm: int = 8,
    bkw: int = 8,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Binarize+pack (M, K) float -> (M, ceil(K/32)) uint32.

    Rows are NOT padded (output keeps M); K tail bits are 0.
    ``use_pallas=False`` is the jnp reference (``bitpack.pack_sign``) —
    bit-identical, kept as the equivalence oracle (PrologueSpec.fused).
    ``interpret=None`` reads REPRO_PALLAS_INTERPRET; callers on the
    dispatch path thread ``GemmConfig.interpret`` so a real-TPU config
    compiles the pack stage like the GEMM kernels.
    """
    m, k = x.shape
    kw = bitpack.packed_width(k)
    if not use_pallas:
        return bitpack.pack_sign(x)
    kb = bkw * WORD_BITS
    xp = jnp.pad(
        x,
        ((0, _round_up(m, bm) - m), (0, _round_up(k, kb) - k)),
        constant_values=-1.0,  # negative pad -> bit 0
    )
    out = pack_sign_pallas(xp, bm=bm, bkw=bkw, interpret=interpret)
    return out[:m, :kw]


@functools.partial(jax.jit, static_argnames=("a_bits", "bm", "bkw", "fused",
                                             "interpret"))
def pack_act_planes(
    x: jax.Array,
    a_bits: int,
    *,
    bm: int = 8,
    bkw: int = 8,
    fused: bool = True,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """The k-bit activation prologue: (M, K) float ->
    ``((a_bits, M, ceil(K/32)) uint32 planes, (M, 1) int32 code row-sums)``
    in ONE fused Pallas pass (quantize -> plane-pack -> row-sum; the k-bit
    analogue of :func:`pack_activations`).  ``fused=False`` is the jnp
    reference round trip (``quant.act_codes`` -> ``bitpack.pack_planes``),
    bit-identical by construction — the fused kernel calls the same
    ``quant.act_codes`` on each tile."""
    m, k = x.shape
    kw = bitpack.packed_width(k)
    if not fused:
        codes = quant.act_codes(x, a_bits)  # (M, K) uint32
        return (bitpack.pack_planes(codes, a_bits),
                codes.astype(jnp.int32).sum(axis=-1, keepdims=True))
    kb = bkw * WORD_BITS
    xp = jnp.pad(
        x,
        ((0, _round_up(m, bm) - m), (0, _round_up(k, kb) - k)),
        constant_values=-1.0,  # negative pad -> code 0 -> all plane bits 0
    )
    planes, t_sum = quant_pack_planes_pallas(xp, a_bits, bm=bm, bkw=bkw,
                                             interpret=interpret)
    return planes[:, :m, :kw], t_sum[:m]


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k_true", "config"))
def packed_gemm(
    a_packed: jax.Array,  # (M, Kw) uint32
    b_packed: jax.Array,  # (N, Kw) uint32 (weights, transposed layout)
    *,
    k_true: int,
    config: GemmConfig = DEFAULT_GEMM_CONFIG,
) -> jax.Array:
    """Exact ±1 dot product (M, N) int32 from packed operands."""
    name = resolve_backend(config.backend, 1)
    be = get_backend(name)
    tiles = config.tiles(a_packed.shape[0], b_packed.shape[0],
                         a_packed.shape[1], backend=name)
    return be.gemm(a_packed, b_packed, k_true, tiles, config)


@functools.partial(jax.jit, static_argnames=("config",))
def packed_kbit_gemm(
    a_planes: jax.Array,  # (ka, M, Kw) uint32 plane stack
    b_planes: jax.Array,  # (kb, N, Kw) uint32 plane stack (weights)
    *,
    config: GemmConfig = DEFAULT_GEMM_CONFIG,
) -> jax.Array:
    """Raw weighted-plane popcount S (M, N) int32 from packed plane stacks
    (plane counts read off the leading dims)."""
    name = resolve_backend(config.backend, b_planes.shape[0])
    be = get_backend(name)
    if be.gemm_kbit is None:
        raise ValueError(f"backend {name!r} has no k-bit kernel")
    _accum_check_for(name)(a_planes.shape[2] * WORD_BITS,
                           a_planes.shape[0], b_planes.shape[0])
    tiles = config.tiles(a_planes.shape[1], b_planes.shape[1],
                         a_planes.shape[2], backend=name)
    return be.gemm_kbit(a_planes, b_planes, tiles, config)


def _kbit_dot_from_float(x2, w_planes, *, k_true, config, w_bits, a_bits,
                         fused=True):
    """(M, K) float acts x (w_bits, N, Kw) plane-packed weights -> the
    fake-quant DoReFa dot (M, N) fp32, pre-epilogue.  The activation side
    is the fused quantize->plane-pack prologue (:func:`pack_act_planes`) —
    plane stack and the code row-sums T in one Pallas pass, no jnp
    ``act_codes``/``pack_planes`` round trip."""
    name = resolve_backend(config.backend, w_bits)
    be = get_backend(name)
    assert w_planes.ndim == 3 and w_planes.shape[0] == w_bits, (
        w_planes.shape, w_bits)
    if be.from_float_kbit is not None:
        return be.from_float_kbit(x2, w_planes, a_bits, w_bits, k_true,
                                  config)
    _accum_check_for(name)(k_true, a_bits, w_bits)
    a_planes, t_sum = pack_act_planes(
        x2, a_bits, fused=fused, interpret=config._interpret
    )  # (ka, M, Kw), (M, 1)
    tiles = config.tiles(x2.shape[0], w_planes.shape[1],
                         a_planes.shape[-1], backend=name)
    s = be.gemm_kbit(a_planes, w_planes, tiles, config)
    return _kbit_dequant(s, t_sum, a_bits, w_bits)


@functools.partial(
    jax.jit, static_argnames=("k_true", "config", "epilogue", "w_bits",
                              "a_bits", "prologue")
)
def quant_gemm(
    x: jax.Array,  # (..., K) float activations
    w_packed: jax.Array,  # (N, Kw) 1-bit words or (w_bits, N, Kw) planes
    *,
    k_true: int,
    config: GemmConfig = DEFAULT_GEMM_CONFIG,
    epilogue: EpilogueSpec = EpilogueSpec(),
    scale: jax.Array | None = None,
    bias: jax.Array | None = None,
    w_bits: int | None = None,
    a_bits: int | None = None,
    prologue: PrologueSpec | None = None,
) -> jax.Array:
    """The quantized GEMM: fused activation prologue (quantize+pack x),
    packed GEMM against packed w, fused epilogue.  Returns (..., N) in
    ``epilogue.out_dtype`` — numerically identical to the fake-quant
    training path plus the same epilogue (paper §2.2.2 invariant;
    ``sign(x) @ sign(W)`` at 1 bit, the DoReFa Eq. 1 dot at k bits).

    ``w_bits``/``a_bits`` default to ``config.bits`` then 1; widths > 1
    route to the bit-plane backends (see :func:`resolve_backend`).
    ``prologue`` (a :class:`PrologueSpec`, normally built by
    :func:`prologue_from_spec`) selects the fused Pallas quantize->pack
    kernels vs the jnp reference; None derives it from the config."""
    lead = x.shape[:-1]
    assert x.shape[-1] == k_true, (x.shape, k_true)
    x2 = x.reshape(-1, k_true)
    wb = w_bits or config.bits or 1
    ab = a_bits or config.bits or 1
    if wb > 1 or ab > 1:
        _check_kbit_widths(wb, ab)
    fused = prologue.fused if prologue is not None else config.fused_prologue
    if fused != config.fused_prologue:
        # static-arg rewrite so backends that read the config (the shard
        # family packs inside its shard_map body) honor the spec too
        config = dataclasses.replace(config, fused_prologue=fused)
    if wb > 1:
        dot = _kbit_dot_from_float(
            x2, w_packed, k_true=k_true, config=config, w_bits=wb,
            a_bits=ab, fused=fused,
        )
        n_out = w_packed.shape[-2]
    else:
        name = resolve_backend(config.backend, 1)
        be = get_backend(name)
        if be.from_float is not None:
            dot = be.from_float(x2, w_packed, k_true, config)
        else:
            xp = pack_activations(x2, use_pallas=fused,
                                  interpret=config._interpret)
            tiles = config.tiles(xp.shape[0], w_packed.shape[0],
                                 xp.shape[1], backend=name)
            dot = be.gemm(xp, w_packed, k_true, tiles, config)
        n_out = w_packed.shape[0]
    y = apply_epilogue(
        dot.astype(jnp.float32), k_true=k_true, epilogue=epilogue,
        scale=scale, bias=bias,
    )
    return y.reshape(*lead, n_out)


@dataclasses.dataclass(frozen=True)
class QuantGemmCall:
    """A fully-specified quantized GEMM: shape contract + bit widths +
    backend config + fused prologue + fused epilogue.  Layers build one of
    these and apply it; everything else (quantize+pack, tiles, backend
    resolution, pad correction, epilogue order) is owned here."""

    k_true: int
    config: GemmConfig = DEFAULT_GEMM_CONFIG
    epilogue: EpilogueSpec = EpilogueSpec()
    w_bits: int = 1
    a_bits: int = 1
    prologue: PrologueSpec | None = None

    def __call__(
        self,
        x: jax.Array,
        w_packed: jax.Array,
        *,
        scale: jax.Array | None = None,
        bias: jax.Array | None = None,
    ) -> jax.Array:
        return quant_gemm(
            x, w_packed, k_true=self.k_true, config=self.config,
            epilogue=self.epilogue, scale=scale, bias=bias,
            w_bits=self.w_bits, a_bits=self.a_bits,
            prologue=self.prologue,
        )


@functools.partial(
    jax.jit,
    static_argnames=("k_true", "config", "expert_capacity", "out_dtype",
                     "w_bits", "a_bits"),
)
def quant_gemm_grouped(
    x_sorted: jax.Array,  # (T, K) float rows, sorted by group
    w_stack,  # (E, N, Kw) / (E, w_bits, N, Kw) packed experts, or a tuple
    group_sizes: jax.Array,  # (E,) int32, sum <= T
    *,
    k_true: int,
    config: GemmConfig = DEFAULT_GEMM_CONFIG,
    expert_capacity: int | None = None,
    out_dtype=jnp.float32,
    w_bits: int | None = None,
    a_bits: int | None = None,
):
    """Grouped (MoE expert-stacked) packed GEMM.

    Row ``i`` of ``x_sorted`` is contracted against the packed weights of
    its group (groups are contiguous: the first ``group_sizes[0]`` rows
    belong to expert 0, …).  Rows beyond ``sum(group_sizes)`` — MoE
    padding / non-owned rows — return zeros.  Rows overflowing a bucket
    (``expert_capacity``, default T: no drops) are dropped (zeros) on
    EVERY backend — the same contract as the EP capacity slack in
    ``nn/mlp.py``.

    ``w_stack`` may be a tuple of same-shape stacks (MoE up+gate): the
    activations are binarized, packed, and bucketed ONCE and contracted
    against each stack, returning a tuple.

    ``shard-*`` backends run the contraction expert-parallel
    (``config.expert_axis``) x Kw-parallel (``config.shard_axis``); a
    configured ``shard_layout="n"`` applies only to the dense GEMMs of a
    mixed model — the grouped path has no "n" layout and uses "k" here.

    Pallas backends scatter the packed words into per-expert buckets and
    run the expert-batched xnor kernel, so only packed words cross HBM —
    closing the 32x traffic win the old unpack-to-float expert path
    forfeited.  The bucket layout is dense (E, capacity, Kw): with the
    default full capacity that is E-fold overcompute versus a ragged
    contraction, the price of exactness-by-default — production MoE
    serving should pass the load-balance ``expert_capacity`` (ROADMAP
    lists the capacity-factor wiring as a follow-on).
    """
    stacks = w_stack if isinstance(w_stack, tuple) else (w_stack,)
    t, k = x_sorted.shape
    e = stacks[0].shape[0]
    n = stacks[0].shape[-2]
    assert k == k_true, (k, k_true)
    wb = w_bits or config.bits or 1
    ab = a_bits or config.bits or 1
    if wb > 1 or ab > 1:
        _check_kbit_widths(wb, ab)

    ec = expert_capacity or t
    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    row = jnp.arange(t, dtype=jnp.int32)
    g = jnp.searchsorted(ends, row, side="right").astype(jnp.int32)
    g_safe = jnp.minimum(g, e - 1)
    pos = row - starts[g_safe]
    valid = (g < e) & (pos < ec)

    if wb > 1:
        return _kbit_grouped(
            x_sorted, w_stack, stacks, group_sizes, g, g_safe, pos, valid,
            ec=ec, k_true=k_true, config=config, out_dtype=out_dtype,
            w_bits=wb, a_bits=ab,
        )

    name = resolve_backend(config.backend, 1)
    be = get_backend(name)
    if be.from_float_grouped is not None:
        outs = tuple(
            jnp.where(
                valid[:, None],
                be.from_float_grouped(x_sorted, w, group_sizes, k_true,
                                      config),
                0,
            ).astype(out_dtype)
            for w in stacks
        )
        return outs if isinstance(w_stack, tuple) else outs[0]

    buckets = _pack_sign_buckets(x_sorted, g, pos, e, ec, config)
    kw = buckets.shape[-1]

    tiles = config.tiles(ec, n, kw, backend=name)
    outs = []
    for w in stacks:
        dots = be.gemm_grouped(buckets, w, k_true, tiles,
                               config)  # (E, ec, N)
        y = dots[g_safe, jnp.minimum(pos, ec - 1)]
        outs.append(jnp.where(valid[:, None], y, 0).astype(out_dtype))
    return tuple(outs) if isinstance(w_stack, tuple) else outs[0]


def _pack_sign_buckets(x_sorted, g, pos, e, ec, config):
    """The grouped 1-bit prologue: route rows into (E, capacity, Kw)
    packed buckets.  When the capacity bound shrinks the bucket total
    below the row count (E * ec < T — a tight ``expert_capacity``) the
    FLOAT rows are routed first and only the kept bucket rows run through
    the fused pack kernel — rows dropped by the capacity bound are never
    quantized or packed (float bucket slack is -1.0: bit 0), and the pack
    kernel sees strictly fewer rows.  Otherwise routing first would
    quantize MORE rows than it saves (and scatter 32x the bytes), so the
    T rows pack once and the packed words scatter."""
    t, k = x_sorted.shape
    fused = config.fused_prologue
    interp = config._interpret
    if e * ec < t:
        xb = jnp.full((e, ec, k), -1.0, x_sorted.dtype)
        xb = xb.at[g, pos].set(x_sorted, mode="drop")
        xp = pack_activations(xb.reshape(e * ec, k), use_pallas=fused,
                              interpret=interp)
        return xp.reshape(e, ec, -1)
    xp = pack_activations(x_sorted, use_pallas=fused, interpret=interp)
    buckets = jnp.zeros((e, ec, xp.shape[1]), jnp.uint32)
    return buckets.at[g, pos].set(xp, mode="drop")


def _pack_plane_buckets(x_sorted, a_bits, g, g_safe, pos, e, ec, config):
    """Grouped k-bit prologue: fused quantize->plane-pack, bucketed.
    Returns ``((E, ka, capacity, Kw) uint32 buckets, (T, 1) int32 per-row
    code sums T)`` — the same route-first rule as the 1-bit form (only
    when E * ec < T, where routing first strictly shrinks the pack; rows
    dropped by the capacity bound are then never quantized; -1.0 slack
    rows quantize to code 0)."""
    t, k = x_sorted.shape
    fused = config.fused_prologue
    interp = config._interpret
    if e * ec < t:
        xb = jnp.full((e, ec, k), -1.0, x_sorted.dtype)
        xb = xb.at[g, pos].set(x_sorted, mode="drop")
        planes, ts = pack_act_planes(xb.reshape(e * ec, k), a_bits,
                                     fused=fused, interpret=interp)
        kw = planes.shape[-1]
        buckets = jnp.moveaxis(planes.reshape(a_bits, e, ec, kw), 0, 1)
        # per original row: its bucket cell's code sum (dropped/invalid
        # rows read a clamped cell and are zeroed by the validity mask)
        t_rows = ts.reshape(e, ec)[g_safe, jnp.minimum(pos, ec - 1)]
        return buckets, t_rows[:, None]
    planes, ts = pack_act_planes(x_sorted, a_bits, fused=fused,
                                 interpret=interp)  # (ka, T, Kw), (T, 1)
    kw = planes.shape[-1]
    buckets = jnp.zeros((e, ec, a_bits, kw), jnp.uint32)
    buckets = buckets.at[g, pos].set(
        jnp.moveaxis(planes, 0, 1), mode="drop"
    )
    return jnp.moveaxis(buckets, 2, 1), ts  # (E, ka, ec, kw)


def _kbit_grouped(x_sorted, w_stack, stacks, group_sizes, g, g_safe, pos,
                  valid, *, ec, k_true, config, out_dtype, w_bits, a_bits):
    """k-bit arm of :func:`quant_gemm_grouped`: the fused quantize->
    plane-pack prologue runs ONCE (per expert bucket when a capacity
    bound is set — see :func:`_pack_plane_buckets`), then each
    (E, w_bits, N, Kw) expert plane stack contracts on the expert-batched
    plane kernel; the ``"xla"`` fallback lowers to ``lax.ragged_dot`` over
    dequantized weights.  Same capacity/validity contract as the 1-bit
    arm."""
    e = stacks[0].shape[0]
    n = stacks[0].shape[-2]
    name = resolve_backend(config.backend, w_bits)
    be = get_backend(name)

    if be.from_float_kbit_grouped is not None:
        outs = tuple(
            jnp.where(
                valid[:, None],
                be.from_float_kbit_grouped(x_sorted, w, group_sizes,
                                           a_bits, w_bits, k_true, config),
                0,
            ).astype(out_dtype)
            for w in stacks
        )
        return outs if isinstance(w_stack, tuple) else outs[0]

    _accum_check_for(name)(k_true, a_bits, w_bits)
    buckets, t_sum = _pack_plane_buckets(x_sorted, a_bits, g, g_safe, pos,
                                         e, ec, config)
    kw = buckets.shape[-1]

    tiles = config.tiles(ec, n, kw, backend=name)
    outs = []
    for w in stacks:
        s = be.gemm_kbit_grouped(buckets, w, tiles,
                                 config)  # (E, ec, N)
        y = s[g_safe, jnp.minimum(pos, ec - 1)]
        dot = _kbit_dequant(y, t_sum, a_bits, w_bits)
        outs.append(jnp.where(valid[:, None], dot, 0).astype(out_dtype))
    return tuple(outs) if isinstance(w_stack, tuple) else outs[0]
