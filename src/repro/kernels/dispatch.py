"""Unified quantized-GEMM dispatch — the single execution path for every
binary GEMM in the system (BMXNet §2.2's one-kernel-serves-all invariant).

Every packed contraction — dense, conv-im2col, and the MoE expert stack —
funnels through this module, which owns the four concerns that used to be
scattered across ``core/qlayers.py``, ``kernels/ops.py`` and ``nn/mlp.py``:

1. **binarize + pack** of float activations (paper Fig. 1's "binarize
   input" stage),
2. **backend selection** via a registry (``"vpu"``, ``"mxu"``, ``"xla"``;
   :func:`register_backend` adds more) plus a per-(M, N, Kw) tile-size
   heuristic table (:func:`select_tiles`),
3. **pad-correction arithmetic** — each backend's exact-dot recovery from
   its raw kernel output (``k_true - 2·mismatch`` for popcount, padded-dot
   minus pad bits for the MXU unpack kernel),
4. the **fused epilogue** (:class:`EpilogueSpec`: XNOR-Net alpha scale,
   Eq. 2 xnor-range map, bias, output dtype) — the ONE place this
   arithmetic exists; ``qlayers`` builds specs via
   :func:`epilogue_from_spec` and applies via :func:`apply_epilogue`.

Backend registry (the full bit-width family the paper names in §2.1 —
1-bit XNOR plus DoReFa k-bit; :func:`resolve_backend` maps a base name +
the layer's weight bit width onto the entry that executes it):

===========  ==================  ==========================  ================
backend      operands            kernel                      pad correction
===========  ==================  ==========================  ================
``vpu``      1-bit packed words  xnor+popcount (VPU,         ``k_true - 2*
             (M, Kw)/(N, Kw)     Listing 3)                  mismatch``
``mxu``      1-bit packed words  unpack->int8 in VMEM, MXU   ``- (Kw*32 -
                                 dot                         k_true)``
``xla``      float acts + any    unpack/dequant in-graph,    none (dequant
             packed weights      XLA dot / ragged_dot (the   path)
                                 dry-run lowering target)
``vpu-k2``   2-bit plane stacks  2^(i+j)-weighted AND        none (AND with
             (2, M, Kw)          popcount planes             zero pad words)
``vpu-k4``   4-bit plane stacks  same kernel, 16 plane       none
             (4, M, Kw)          pairs
``vpu-k8``   8-bit plane stacks  same kernel, 64 plane       none
             (8, M, Kw)          pairs
``shard-*``  same as the inner   inner kernel under          on the reduced
             backend, mesh-      shard_map: Kw-partial raw   sum, ONCE (see
             partitioned         outputs + int32 psum        below)
===========  ==================  ==========================  ================

Other w_bits in 2..8 (w3/w5/w6/w7) convert + serve through the ``"xla"``
dequant fallback; :func:`register_backend` can add ``vpu-k3`` etc.
Asymmetric widths (e.g. w4a8) are supported: the plane kernel takes
ka != kb stacks and resolution follows the WEIGHT width.

**Tensor-parallel serving** (the ``shard-`` family: ``shard-vpu``,
``shard-mxu``, ``shard-vpu-k2/k4/k8``): the same Pallas kernels run under
``shard_map`` on ``GemmConfig.mesh``, with the operand layouts owned by
``dist.sharding.packed_gemm_pspecs`` (the Megatron pair —
``shard_layout="k"`` partitions the packed Kw dimension over
``GemmConfig.shard_axis`` and ``psum``s the RAW integer kernel outputs
(mismatch counts / padded dots / weighted plane popcounts, all exactly
additive over disjoint Kw slices); ``shard_layout="n"`` partitions weight
rows with replicated activations and needs no collective).  Pad
correction and the fused epilogue apply exactly once on the reduced sum,
so sharded results are BIT-IDENTICAL to single-device at any split.  The
grouped (MoE) form composes expert parallelism over
``GemmConfig.expert_axis`` with the Kw partition.  :func:`unsharded`
strips the family back to its inner single-device backend — required when
a caller is already inside a ``shard_map`` body (nn/mlp.py's EP path).

Entry points:

* :class:`QuantGemmCall` / :func:`quant_gemm` — (…, K) float activations
  against packed weights ((N, Kw) 1-bit words or (w_bits, N, Kw) plane
  stacks), epilogue fused.  ``w_bits``/``a_bits`` select the k-bit path.
* :func:`quant_gemm_grouped` — sorted rows against an (E, N, Kw) (1-bit)
  or (E, w_bits, N, Kw) (k-bit) expert stack with ragged group sizes: the
  MoE packed-serving GEMM.  Pallas backends bucket rows per expert and run
  the batched (expert-grid) kernels so only packed words cross HBM; the
  ``"xla"`` backend lowers to ``lax.ragged_dot`` for dry-run cost analysis.
* :func:`packed_gemm` / :func:`packed_kbit_gemm` — packed-x-packed
  primitives (exact ±1 dot / raw weighted-plane popcount S).

The k-bit fake-quant dot is recovered from the integer plane GEMM as
``(2*S - Nw*T) / (Na*Nw)`` (see kernels/kbit_gemm.py) and then flows
through the SAME fused epilogue as every other path — which is what keeps
w4a4/w8a8 packed serving numerically aligned with the fake-quant train
path (§2.2.2's argument, generalized from 1 bit to the 2..31 family).

On this CPU container Pallas runs in interpret mode; on a real TPU set
``REPRO_PALLAS_INTERPRET=0`` (or ``GemmConfig(interpret=False)``).
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.core import bitpack, quant
from repro.core.policy import QuantSpec
from repro.dist.sharding import packed_gemm_pspecs
from repro.kernels import ref
from repro.kernels.kbit_gemm import (
    kbit_plane_gemm_batched_pallas,
    kbit_plane_gemm_pallas,
)
from repro.kernels.pack_bits import pack_sign_pallas
from repro.kernels.xnor_gemm import (
    mxu_pad_inflation,
    xnor_dot_mxu_batched_pallas,
    xnor_dot_mxu_pallas,
    xnor_mismatch_batched_pallas,
    xnor_mismatch_pallas,
)

WORD_BITS = bitpack.WORD_BITS


def _env_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


# ---------------------------------------------------------------------------
# Tile selection: a per-backend heuristic table replacing the ad-hoc
# min/round_up/while-divides logic that used to live inline in ops.xnor_gemm.
# Operands are padded up to the selected tile, so any entry is *correct*;
# the table picks the smallest tile that covers the operand (small problems
# avoid padding waste, large problems get the full VMEM-friendly tile).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TileConfig:
    bm: int
    bn: int
    bkw: int  # K-words per step (bkw * 32 binary values)
    chunk_words: int  # vpu inner xor/popcount chunk


# Row-tile ladder: smallest entry >= the operand dim wins (last entry caps).
# K-word ladder likewise.  Separate rows per backend: the MXU kernel unpacks
# to (rows, bkw*32) int8 in VMEM so its K-step is kept smaller; the VPU
# popcount kernel streams words and tolerates a deeper K-block.
_TILE_TABLE: dict[str, dict[str, tuple[int, ...]]] = {
    "vpu": {"rows": (8, 16, 32, 64, 128), "kw": (8, 16, 32, 64)},
    "mxu": {"rows": (8, 16, 32, 64, 128), "kw": (8, 16, 32)},
    # k-bit plane backends stream ka+kb plane stacks per block, so the
    # K-step shrinks as the plane count grows (VMEM per block scales with
    # (ka + kb) * bkw words).
    "vpu-k2": {"rows": (8, 16, 32, 64, 128), "kw": (8, 16, 32)},
    "vpu-k4": {"rows": (8, 16, 32, 64, 128), "kw": (8, 16, 32)},
    "vpu-k8": {"rows": (8, 16, 32, 64, 128), "kw": (8, 16)},
}
_DEFAULT_CHUNK_WORDS = 8


def _pick(size: int, ladder: tuple[int, ...]) -> int:
    for step in ladder:
        if size <= step:
            return step
    return ladder[-1]


def _chunk_for(bkw: int, want: int) -> int:
    """Largest chunk <= ``want`` that divides ``bkw`` — the VPU kernel
    iterates bkw // chunk_words chunks and would silently skip tail words
    otherwise."""
    cw = max(1, min(want, bkw))
    while bkw % cw:
        cw -= 1
    return cw


@functools.lru_cache(maxsize=None)
def select_tiles(m: int, n: int, kw: int, backend: str) -> TileConfig:
    """Heuristic (M, N, Kw) -> tile sizes for ``backend`` (table-driven)."""
    rule = _TILE_TABLE.get(backend, _TILE_TABLE["vpu"])
    bkw = _pick(kw, rule["kw"])
    return TileConfig(
        bm=_pick(m, rule["rows"]),
        bn=_pick(n, rule["rows"]),
        bkw=bkw,
        chunk_words=_chunk_for(bkw, _DEFAULT_CHUNK_WORDS),
    )


# ---------------------------------------------------------------------------
# Config + epilogue specs (static, hashable — safe as jit static args)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GemmConfig:
    """How a quantized GEMM executes: backend + optional tile overrides.

    ``backend`` is a BASE name: layer calls carry the per-layer bit widths
    (from their :class:`QuantSpec`) and :func:`resolve_backend` maps e.g.
    ``("vpu", w_bits=4)`` onto the ``"vpu-k4"`` registry entry.  ``bits``
    is the default bit width for direct callers (benchmarks, ops.py-style
    wrappers) that do not thread a QuantSpec — explicit ``w_bits``/
    ``a_bits`` arguments on the entry points take precedence.

    ``interpret=None`` reads REPRO_PALLAS_INTERPRET (default: interpret,
    the only mode available on this CPU container).

    The ``shard-*`` backends additionally read the tensor-parallel knobs:
    ``mesh`` (the jax Mesh to shard_map over — hashable, so the config
    stays a legal jit static argument; ``QCtx`` fills it from its own mesh
    when a shard backend is configured), ``shard_axis`` (the mesh axis the
    packed Kw dimension partitions over in the ``"k"`` layout, or weight N
    rows in the ``"n"`` layout), ``shard_layout`` (``"k"`` | ``"n"``, see
    ``dist.sharding.packed_gemm_pspecs``), and ``expert_axis`` (optional
    second mesh axis for expert parallelism on the grouped path).
    """

    backend: str = "vpu"
    bm: int | None = None
    bn: int | None = None
    bkw: int | None = None
    chunk_words: int | None = None
    interpret: bool | None = None
    bits: int | None = None
    mesh: Any = None
    shard_axis: str = "model"
    shard_layout: str = "k"
    expert_axis: str | None = None

    def tiles(self, m: int, n: int, kw: int,
              backend: str | None = None) -> TileConfig:
        t = select_tiles(m, n, kw, backend or self.backend)
        bkw = self.bkw or t.bkw
        return TileConfig(
            bm=self.bm or t.bm,
            bn=self.bn or t.bn,
            bkw=bkw,
            chunk_words=_chunk_for(bkw, self.chunk_words
                                   or _DEFAULT_CHUNK_WORDS),
        )

    @property
    def _interpret(self) -> bool:
        return self.interpret if self.interpret is not None else _env_interpret()


DEFAULT_GEMM_CONFIG = GemmConfig()


@dataclasses.dataclass(frozen=True)
class EpilogueSpec:
    """What is fused after the ±1 dot: XNOR-Net per-channel alpha, the
    paper's Eq. 2 range map, bias add, and the output cast — in that order
    (the order every pre-dispatch copy of this code used)."""

    scale: bool = False
    xnor_range: bool = False
    bias: bool = False
    out_dtype: Any = jnp.float32


def epilogue_from_spec(
    qspec: QuantSpec, *, bias: bool, out_dtype
) -> EpilogueSpec:
    """Map a layer's :class:`QuantSpec` to the fused epilogue it implies.

    The Eq. 2 range map only applies to true 1-bit GEMMs, and the alpha
    scale never applies to full-precision layers — both rules live here so
    layer code cannot drift."""
    return EpilogueSpec(
        scale=qspec.scale and not qspec.is_fp,
        xnor_range=(
            qspec.xnor_range and qspec.is_binary and qspec.a_bits == 1
        ),
        bias=bias,
        out_dtype=out_dtype,
    )


def apply_epilogue(
    y: jax.Array,
    *,
    k_true: int,
    epilogue: EpilogueSpec,
    scale: jax.Array | None = None,
    bias: jax.Array | None = None,
) -> jax.Array:
    """THE epilogue: ``((y * scale) |> Eq.2(k_true)) + bias -> out_dtype``.

    Both execution paths (fake-quant train and packed serving) call this,
    which is what keeps them bit-exact per paper §2.2.2."""
    if epilogue.scale:
        assert scale is not None, "epilogue.scale set but no scale operand"
        y = y * scale
    if epilogue.xnor_range:
        y = quant.xnor_range_map(y, k_true)
    if epilogue.bias:
        assert bias is not None, "epilogue.bias set but no bias operand"
        y = y + bias
    return y.astype(epilogue.out_dtype)


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Backend:
    """One way to execute the packed quantized GEMM.

    Every kernel-path callable takes the live :class:`GemmConfig` as its
    last argument (interpret flag, and — for the ``shard-*`` family — the
    mesh/axis/layout knobs).

    1-bit surface (``bits == 1``):

    ``gemm(a_packed, b_packed, k_true, tiles, config) -> (M, N) int32``
    must return the EXACT ±1 dot (pad correction included).

    ``gemm_grouped(buckets, w_stack, k_true, tiles, config)`` contracts
    an (E, M, Kw) activation bucket against an (E, N, Kw) weight stack.

    ``from_float``: optional shortcut taking raw float activations —
    backends that never materialise packed activations (the XLA
    unpack-and-MXU fallback) set it and skip the pack stage.

    k-bit surface (``bits > 1`` plane backends, or the ``from_float_kbit``
    fallbacks on ``"xla"``):

    ``gemm_kbit(a_planes, b_planes, tiles, config) -> (M, N) int32``
    returns the raw weighted-plane popcount S (plane counts are read off
    the stacks' leading dims; no pad correction exists on this path).

    ``gemm_kbit_grouped(buckets, w_stack, tiles, config)`` is the
    (E, ka, M, Kw) x (E, kb, N, Kw) expert-batched version.

    ``from_float_kbit(x2, w_planes, a_bits, w_bits, k_true)`` /
    ``from_float_kbit_grouped(x_sorted, w_stack, group_sizes, a_bits,
    w_bits, k_true)`` return the fake-quant DoReFa dot directly from float
    activations (the in-graph dequant path the dry-run lowers).
    """

    name: str
    gemm: Callable
    gemm_grouped: Callable | None = None
    from_float: Callable | None = None
    from_float_grouped: Callable | None = None
    bits: int = 1
    gemm_kbit: Callable | None = None
    gemm_kbit_grouped: Callable | None = None
    from_float_kbit: Callable | None = None
    from_float_kbit_grouped: Callable | None = None


_REGISTRY: dict[str, Backend] = {}


def register_backend(backend: Backend) -> None:
    _REGISTRY[backend.name] = backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown gemm backend {name!r}; registered: "
            f"{sorted(_REGISTRY)}"
        ) from None


_SHARD_PREFIX = "shard-"


def resolve_backend(name: str, w_bits: int) -> str:
    """Map a base backend name + the layer's weight bit width onto the
    registry entry that executes it (the paper's full 1..k family behind
    one config knob):

    * ``w_bits == 1`` — the name is used as-is (the 1-bit entries), except
      that a plane backend down-resolves to its family's 1-bit entry
      (``"vpu"``, or ``"shard-vpu"`` for the tensor-parallel family —
      plane entries have no ±1 kernel, and per-layer policies mix 1-bit
      and k-bit layers under one configured base name).
    * an entry that already handles ``w_bits`` (a matching ``vpu-kN`` or a
      ``from_float_kbit`` fallback like ``"xla"``) — used as-is.
    * otherwise the family's ``vpu-k{w_bits}`` when registered
      (``shard-vpu-k{w_bits}`` for shard base names), else the ``"xla"``
      dequant fallback (w3/w5/... stay correct, just not plane-packed).
    """
    prefix = _SHARD_PREFIX if name.startswith(_SHARD_PREFIX) else ""
    if w_bits <= 1:
        be = _REGISTRY.get(name)
        if be is not None and be.bits > 1:
            return prefix + "vpu"
        return name
    be = get_backend(name)  # unknown base names raise here, not fall back
    if be.bits == w_bits or be.from_float_kbit is not None:
        return name
    kname = f"{prefix}vpu-k{w_bits}"
    if kname in _REGISTRY:
        return kname
    if prefix:
        # the xla dequant fallback is single-device: a shard-* base name
        # at a width with no plane entry silently loses its configured
        # tensor parallelism for that layer — say so, once per combo
        _warn_shard_fallback(name, w_bits)
    return "xla"


@functools.lru_cache(maxsize=None)  # once per (name, w_bits)
def _warn_shard_fallback(name: str, w_bits: int) -> None:
    import warnings

    warnings.warn(
        f"backend {name!r} has no plane entry for w_bits={w_bits}; this "
        "layer falls back to the SINGLE-DEVICE 'xla' dequant path (its "
        "configured tensor parallelism does not apply). Register "
        f"'shard-vpu-k{w_bits}' or use a width in {{2,4,8}} to keep the "
        "GEMM sharded.",
        stacklevel=3,
    )


def unsharded(config: GemmConfig) -> GemmConfig:
    """Strip a config's ``shard-*`` backend back to its inner single-device
    backend (and drop the mesh).  Callers that are ALREADY inside a
    ``shard_map`` body (nn/mlp.py's expert-parallel path) must route their
    GEMMs through this — nesting a shard backend's shard_map inside
    another is an error."""
    if not config.backend.startswith(_SHARD_PREFIX):
        return config
    return dataclasses.replace(
        config, backend=config.backend[len(_SHARD_PREFIX):], mesh=None
    )


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = _round_up(x.shape[axis], mult) - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pad_tiles(a: jax.Array, b: jax.Array, tiles: TileConfig):
    """Pad (…, M, Kw) and (…, N, Kw) up to tile multiples (zero words)."""
    a = _pad_axis(_pad_axis(a, -2, tiles.bm), -1, tiles.bkw)
    b = _pad_axis(_pad_axis(b, -2, tiles.bn), -1, tiles.bkw)
    return a, b


# --- raw kernel seams (shared by single-device and shard backends) --------
# Each returns the kernel's RAW integer output (tile padding handled, rows
# sliced back) plus, for the MXU, the padded word count actually
# contracted.  Raw outputs over disjoint Kw slices sum exactly, so the
# shard backends psum these and correct once on the reduced sum.


def _vpu_raw(ap, bp, tiles, interpret):
    """Raw xor-mismatch counts (m, n) int32 (pad bits are 0 in both
    operands -> 0 mismatches, so no per-call term exists)."""
    m, n = ap.shape[0], bp.shape[0]
    ap, bp = _pad_tiles(ap, bp, tiles)
    return xnor_mismatch_pallas(
        ap, bp, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw,
        chunk_words=tiles.chunk_words, interpret=interpret,
    )[:m, :n]


def _mxu_raw(ap, bp, tiles, interpret):
    """Raw padded MXU dot (m, n) int32 and the word count it contracted."""
    m, n = ap.shape[0], bp.shape[0]
    ap, bp = _pad_tiles(ap, bp, tiles)
    dot = xnor_dot_mxu_pallas(
        ap, bp, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw, interpret=interpret
    )[:m, :n]
    return dot, ap.shape[-1]


def _vpu_raw_grouped(buckets, w_stack, tiles, interpret):
    m, n = buckets.shape[1], w_stack.shape[1]
    buckets, w_stack = _pad_tiles(buckets, w_stack, tiles)
    return xnor_mismatch_batched_pallas(
        buckets, w_stack, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw,
        chunk_words=tiles.chunk_words, interpret=interpret,
    )[:, :m, :n]


def _mxu_raw_grouped(buckets, w_stack, tiles, interpret):
    m, n = buckets.shape[1], w_stack.shape[1]
    buckets, w_stack = _pad_tiles(buckets, w_stack, tiles)
    dot = xnor_dot_mxu_batched_pallas(
        buckets, w_stack, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw,
        interpret=interpret,
    )[:, :m, :n]
    return dot, buckets.shape[-1]


# --- vpu: the literal paper algorithm (xnor + popcount on the VPU) --------


def _vpu_gemm(ap, bp, k_true, tiles, config):
    # Eq. 2 inverse on the raw mismatch count:
    return k_true - 2 * _vpu_raw(ap, bp, tiles, config._interpret)


def _vpu_gemm_grouped(buckets, w_stack, k_true, tiles, config):
    return k_true - 2 * _vpu_raw_grouped(buckets, w_stack, tiles,
                                         config._interpret)


# --- mxu: unpack packed words in VMEM, contract on the MXU ----------------


def _mxu_gemm(ap, bp, k_true, tiles, config):
    padded_dot, words = _mxu_raw(ap, bp, tiles, config._interpret)
    return padded_dot - mxu_pad_inflation(words, k_true)


def _mxu_gemm_grouped(buckets, w_stack, k_true, tiles, config):
    padded_dot, words = _mxu_raw_grouped(buckets, w_stack, tiles,
                                         config._interpret)
    return padded_dot - mxu_pad_inflation(words, k_true)


# --- xla: pure-jnp fallback / dry-run lowering target ---------------------


def _xla_gemm(ap, bp, k_true, tiles, config):
    del tiles, config
    return ref.xnor_gemm_ref(ap, bp, k_true)


def _xla_from_float(x2, w_packed, k_true):
    """Weights stay bit-packed in HBM, unpack to ±1 in-graph and contract
    on the MXU with fp32 accumulation (exact for ±1 up to 2^24 terms).
    The popcount reference (ref.xnor_gemm_ref) stays the test oracle — its
    (M, N, Kw) intermediate is fine for tests but not for lowering
    1M-token prefill cells."""
    w_pm1 = bitpack.unpack_sign(w_packed, k_true, jnp.bfloat16)  # (N, K)
    xq = jnp.where(x2 >= 0, 1.0, -1.0).astype(jnp.bfloat16)
    return jax.lax.dot_general(
        xq, w_pm1,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _xla_from_float_grouped(x_sorted, w_stack, group_sizes, k_true):
    """Ragged-dot lowering of the grouped GEMM: packed words unpack
    in-graph, then ``lax.ragged_dot`` — the shape the dry-run cost model
    understands (no per-expert bucketing materialised)."""
    e, n, _ = w_stack.shape
    w_pm1 = bitpack.unpack_sign(w_stack, k_true, jnp.bfloat16)  # (E, N, K)
    w_ekn = jnp.transpose(w_pm1, (0, 2, 1))  # (E, K, N)
    xq = jnp.where(x_sorted >= 0, 1.0, -1.0).astype(jnp.bfloat16)
    return jax.lax.ragged_dot(xq, w_ekn, group_sizes).astype(jnp.float32)


# --- k-bit plane backends: DoReFa bit-plane popcount (kbit_gemm.py) -------


def _kbit_dequant(s, t_sum, a_bits, w_bits):
    """Integer plane GEMM -> fake-quant DoReFa dot (fp32):

        a_q = n_a/Na,  w_q = (2*n_w - Nw)/Nw
        =>  dot = (2*S - Nw*T) / (Na*Nw)

    with S the weighted-plane popcount and T the activation code row-sums.
    The numerator stays in int32 (a prior fp32 cast of S loses bits past
    2^24 and the subtraction is cancellation-prone); the single fp32
    divide is the only rounding.  ``_check_kbit_accumulator`` bounds every
    term below 2^31."""
    na = (1 << a_bits) - 1
    nw = (1 << w_bits) - 1
    num = 2 * s - jnp.int32(nw) * t_sum
    return num.astype(jnp.float32) / float(na * nw)


def _check_kbit_widths(w_bits: int, a_bits: int) -> None:
    """Reject width combinations the packed path has no semantics for,
    loudly: 1-bit sign values have no unsigned plane form, so mixing a
    1-bit side with a k-bit side would silently compute the wrong
    quantizer (round(clip(x,0,1)) is NOT sign(x))."""
    if w_bits > 1 and a_bits > 1:
        if not (2 <= w_bits <= 8 and 2 <= a_bits <= 8):
            raise ValueError(
                f"packed k-bit GEMM supports widths 2..8, got "
                f"w{w_bits}a{a_bits}"
            )
    elif w_bits > 1 or a_bits > 1:
        raise ValueError(
            f"mixed 1-bit/k-bit widths unsupported: w{w_bits}a{a_bits} "
            "(use both widths 1, or both in 2..8)"
        )


def _check_kbit_accumulator(k_true: int, a_bits: int, w_bits: int) -> None:
    """The plane kernels accumulate S <= K * Na * Nw in int32 (and the
    dequant numerator 2S - Nw*T has the same bound); shapes and widths are
    static, so an oversized contraction fails at trace time instead of
    silently wrapping (w8a8 caps K at ~16k, w4a4 at ~4.7M).  Only the
    integer plane arm needs this — the ``"xla"`` dequant fallback
    contracts in fp32."""
    bound = 2 * k_true * ((1 << a_bits) - 1) * ((1 << w_bits) - 1)
    if bound >= 2**31:
        raise ValueError(
            f"k-bit GEMM overflows its int32 accumulator: K={k_true} at "
            f"w{w_bits}a{a_bits} needs 2*K*Na*Nw = {bound} >= 2^31; split "
            "the contraction or reduce the bit width"
        )


def _pad_planes(a: jax.Array, b: jax.Array, tiles: TileConfig):
    """Pad (…, ka, M, Kw) and (…, kb, N, Kw) plane stacks up to tile
    multiples.  Zero words AND to zero, so padding needs no correction."""
    a = _pad_axis(_pad_axis(a, -2, tiles.bm), -1, tiles.bkw)
    b = _pad_axis(_pad_axis(b, -2, tiles.bn), -1, tiles.bkw)
    return a, b


def _vpu_kbit_gemm(a_planes, b_planes, tiles, config):
    m, n = a_planes.shape[1], b_planes.shape[1]
    a_planes, b_planes = _pad_planes(a_planes, b_planes, tiles)
    return kbit_plane_gemm_pallas(
        a_planes, b_planes, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw,
        chunk_words=tiles.chunk_words, interpret=config._interpret,
    )[:m, :n]


def _vpu_kbit_gemm_grouped(buckets, w_stack, tiles, config):
    m, n = buckets.shape[2], w_stack.shape[2]
    buckets, w_stack = _pad_planes(buckets, w_stack, tiles)
    return kbit_plane_gemm_batched_pallas(
        buckets, w_stack, bm=tiles.bm, bn=tiles.bn, bkw=tiles.bkw,
        chunk_words=tiles.chunk_words, interpret=config._interpret,
    )[:, :m, :n]


def _xla_kbit_s(a_planes, b_planes, tiles, config):
    del tiles, config
    return ref.kbit_gemm_ref(a_planes, b_planes)


def _dequant_weight_planes(w_planes, k_true, w_bits):
    """(…, kb, N, Kw) plane stack -> (…, N, K) fp32 DoReFa weight values."""
    codes = bitpack.unpack_planes(jnp.moveaxis(w_planes, -3, 0), k_true)
    nw = float((1 << w_bits) - 1)
    return (2.0 * codes.astype(jnp.float32) - nw) / nw


def _xla_kbit_from_float(x2, w_planes, a_bits, w_bits, k_true):
    """Weights stay plane-packed in HBM (k/32 of fp32 bytes), dequantized
    to fp32 in-graph and contracted on the MXU — the k-bit analogue of
    ``_xla_from_float`` and the shape the dry-run cost model lowers."""
    wq = _dequant_weight_planes(w_planes, k_true, w_bits)  # (N, K)
    xq = quant.quantize_act(x2.astype(jnp.float32), a_bits)
    return jax.lax.dot_general(
        xq, wq,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _xla_kbit_from_float_grouped(x_sorted, w_stack, group_sizes, a_bits,
                                 w_bits, k_true):
    """Ragged-dot lowering of the grouped k-bit GEMM (cf. the 1-bit
    ``_xla_from_float_grouped``)."""
    wq = _dequant_weight_planes(w_stack, k_true, w_bits)  # (E, N, K)
    w_ekn = jnp.transpose(wq, (0, 2, 1))  # (E, K, N)
    xq = quant.quantize_act(x_sorted.astype(jnp.float32), a_bits)
    return jax.lax.ragged_dot(xq, w_ekn, group_sizes)


# --- shard-*: tensor-parallel packed GEMM (shard_map over config.mesh) ----
# The same Pallas kernels run per mesh shard on their operand slice; the
# RAW integer outputs (mismatch counts / padded dots / plane popcounts)
# psum over the contraction axis, and pad correction + epilogue apply once
# on the reduced sum — sharded results are bit-identical to single-device.
# Operand layouts come from dist.sharding.packed_gemm_pspecs; tiles are
# re-selected for the PER-SHARD shapes (the caller's tiles argument covers
# the global operand and is ignored here).


def _shard_ctx(config: GemmConfig, what: str):
    """Validate the tensor-parallel knobs; returns (mesh, contraction
    axis, its size, expert-axis size)."""
    mesh = config.mesh
    if mesh is None:
        raise ValueError(
            f"{what} needs GemmConfig.mesh (a jax Mesh) — thread it via "
            "QCtx(mesh=...) or GemmConfig(mesh=...)"
        )
    sizes = {k: int(v) for k, v in dict(mesh.shape).items()}
    axis = config.shard_axis
    if axis not in sizes:
        raise ValueError(
            f"{what}: shard_axis {axis!r} not on mesh axes {tuple(sizes)}"
        )
    ea = config.expert_axis
    if ea is not None and ea not in sizes:
        raise ValueError(
            f"{what}: expert_axis {ea!r} not on mesh axes {tuple(sizes)}"
        )
    return mesh, axis, sizes[axis], (sizes[ea] if ea else 1)


def _shard_gemm(inner, ap, bp, k_true, tiles, config):
    del tiles  # re-selected for the per-shard shapes below
    if inner not in ("vpu", "mxu"):
        # the raw-seam branches below are kernel-specific; a new 1-bit
        # backend needs its own raw/correction pair wired here
        raise ValueError(f"no sharded raw seam for inner backend {inner!r}")
    mesh, axis, ns, _ = _shard_ctx(config, f"backend 'shard-{inner}'")
    interp = config._interpret
    m, n = ap.shape[0], bp.shape[0]
    if config.shard_layout == "n":
        # column-parallel: each shard runs the full contraction (its own
        # pad correction included) over its slice of weight rows
        part = packed_gemm_pspecs("n", axis)
        bp_p = _pad_axis(bp, 0, ns)
        t = config.tiles(m, bp_p.shape[0] // ns, ap.shape[1], backend=inner)
        inner_be = get_backend(inner)

        def body_n(a_loc, b_loc):
            return inner_be.gemm(a_loc, b_loc, k_true, t, config)

        out = shard_map(body_n, mesh=mesh, in_specs=(part.a, part.w),
                        out_specs=part.out, check_vma=False)(ap, bp_p)
        return out[:, :n]
    part = packed_gemm_pspecs(config.shard_layout, axis)
    ap_p = _pad_axis(ap, 1, ns)  # zero words: 0 mismatches / counted pads
    bp_p = _pad_axis(bp, 1, ns)
    kw_loc = ap_p.shape[1] // ns
    t = config.tiles(m, n, kw_loc, backend=inner)
    if inner == "vpu":

        def body_vpu(a_loc, b_loc):
            return jax.lax.psum(_vpu_raw(a_loc, b_loc, t, interp),
                                part.reduce_axis)

        mism = shard_map(body_vpu, mesh=mesh, in_specs=(part.a, part.w),
                         out_specs=part.out, check_vma=False)(ap_p, bp_p)
        return k_true - 2 * mism

    def body_mxu(a_loc, b_loc):
        dot, _ = _mxu_raw(a_loc, b_loc, t, interp)
        return jax.lax.psum(dot, part.reduce_axis)

    dot = shard_map(body_mxu, mesh=mesh, in_specs=(part.a, part.w),
                    out_specs=part.out, check_vma=False)(ap_p, bp_p)
    # every shard contracted round_up(kw_loc, bkw) words; correct ONCE
    return dot - mxu_pad_inflation(ns * _round_up(kw_loc, t.bkw), k_true)


def _shard_gemm_grouped(inner, buckets, w_stack, k_true, tiles, config):
    # expert-parallel (config.expert_axis) x Kw-parallel (config.shard_axis)
    # — the grouped path has no "n" layout (dist.sharding docstring), so a
    # configured shard_layout="n" is overridden to "k" here (mixed
    # dense+MoE models legitimately share one config; see
    # quant_gemm_grouped's docstring)
    del tiles
    if inner not in ("vpu", "mxu"):
        raise ValueError(f"no sharded raw seam for inner backend {inner!r}")
    mesh, axis, ns, es = _shard_ctx(
        config, f"backend 'shard-{inner}' (grouped)")
    interp = config._interpret
    e, ec = buckets.shape[0], buckets.shape[1]
    n = w_stack.shape[1]
    part = packed_gemm_pspecs("k", axis, expert_axis=config.expert_axis,
                              grouped=True)
    b_p = _pad_axis(_pad_axis(buckets, 0, es), 2, ns)
    w_p = _pad_axis(_pad_axis(w_stack, 0, es), 2, ns)
    kw_loc = b_p.shape[-1] // ns
    t = config.tiles(ec, n, kw_loc, backend=inner)
    if inner == "vpu":

        def body_vpu(b_loc, wl):
            return jax.lax.psum(_vpu_raw_grouped(b_loc, wl, t, interp),
                                part.reduce_axis)

        mism = shard_map(body_vpu, mesh=mesh, in_specs=(part.a, part.w),
                         out_specs=part.out, check_vma=False)(b_p, w_p)
        return (k_true - 2 * mism)[:e]

    def body_mxu(b_loc, wl):
        dot, _ = _mxu_raw_grouped(b_loc, wl, t, interp)
        return jax.lax.psum(dot, part.reduce_axis)

    dot = shard_map(body_mxu, mesh=mesh, in_specs=(part.a, part.w),
                    out_specs=part.out, check_vma=False)(b_p, w_p)
    words = ns * _round_up(kw_loc, t.bkw)
    return (dot - mxu_pad_inflation(words, k_true))[:e]


def _shard_kbit_gemm(a_planes, b_planes, tiles, config):
    del tiles
    mesh, axis, ns, _ = _shard_ctx(config, "backend 'shard-vpu-k*'")
    inner = f"vpu-k{b_planes.shape[0]}"  # tile-table row (falls back fine)
    m, n = a_planes.shape[1], b_planes.shape[1]
    if config.shard_layout == "n":
        part = packed_gemm_pspecs("n", axis, planes=True)
        b_p = _pad_axis(b_planes, 1, ns)
        t = config.tiles(m, b_p.shape[1] // ns, a_planes.shape[-1],
                         backend=inner)

        def body_n(a_loc, b_loc):
            return _vpu_kbit_gemm(a_loc, b_loc, t, config)

        out = shard_map(body_n, mesh=mesh, in_specs=(part.a, part.w),
                        out_specs=part.out, check_vma=False)(a_planes, b_p)
        return out[:, :n]
    part = packed_gemm_pspecs(config.shard_layout, axis, planes=True)
    a_p = _pad_axis(a_planes, 2, ns)
    b_p = _pad_axis(b_planes, 2, ns)
    t = config.tiles(m, n, a_p.shape[-1] // ns, backend=inner)

    def body_k(a_loc, b_loc):
        # raw S needs no pad correction anywhere: zero plane words AND to 0
        return jax.lax.psum(_vpu_kbit_gemm(a_loc, b_loc, t, config),
                            part.reduce_axis)

    return shard_map(body_k, mesh=mesh, in_specs=(part.a, part.w),
                     out_specs=part.out, check_vma=False)(a_p, b_p)


def _shard_kbit_gemm_grouped(buckets, w_stack, tiles, config):
    del tiles
    mesh, axis, ns, es = _shard_ctx(config, "backend 'shard-vpu-k*' "
                                            "(grouped)")
    e, ec = buckets.shape[0], buckets.shape[2]
    kb, n = w_stack.shape[1], w_stack.shape[2]
    part = packed_gemm_pspecs("k", axis, expert_axis=config.expert_axis,
                              planes=True, grouped=True)
    b_p = _pad_axis(_pad_axis(buckets, 0, es), 3, ns)
    w_p = _pad_axis(_pad_axis(w_stack, 0, es), 3, ns)
    t = config.tiles(ec, n, b_p.shape[-1] // ns, backend=f"vpu-k{kb}")

    def body(b_loc, wl):
        return jax.lax.psum(_vpu_kbit_gemm_grouped(b_loc, wl, t, config),
                            part.reduce_axis)

    s = shard_map(body, mesh=mesh, in_specs=(part.a, part.w),
                  out_specs=part.out, check_vma=False)(b_p, w_p)
    return s[:e]


def _kbit_only(*_args, **_kw):
    raise ValueError(
        "k-bit plane backends execute k-bit GEMMs only; call the entry "
        "points with w_bits/a_bits (or use a 1-bit backend)"
    )


register_backend(Backend("vpu", _vpu_gemm, gemm_grouped=_vpu_gemm_grouped))
register_backend(Backend("mxu", _mxu_gemm, gemm_grouped=_mxu_gemm_grouped))
register_backend(
    Backend(
        "xla",
        _xla_gemm,
        from_float=_xla_from_float,
        from_float_grouped=_xla_from_float_grouped,
        gemm_kbit=_xla_kbit_s,
        from_float_kbit=_xla_kbit_from_float,
        from_float_kbit_grouped=_xla_kbit_from_float_grouped,
    )
)
for _k in (2, 4, 8):
    register_backend(
        Backend(
            f"vpu-k{_k}",
            _kbit_only,
            bits=_k,
            gemm_kbit=_vpu_kbit_gemm,
            gemm_kbit_grouped=_vpu_kbit_gemm_grouped,
        )
    )
for _inner in ("vpu", "mxu"):
    register_backend(
        Backend(
            f"shard-{_inner}",
            functools.partial(_shard_gemm, _inner),
            gemm_grouped=functools.partial(_shard_gemm_grouped, _inner),
        )
    )
for _k in (2, 4, 8):
    register_backend(
        Backend(
            f"shard-vpu-k{_k}",
            _kbit_only,
            bits=_k,
            gemm_kbit=_shard_kbit_gemm,
            gemm_kbit_grouped=_shard_kbit_gemm_grouped,
        )
    )


# ---------------------------------------------------------------------------
# Activation packing (paper Fig. 1's "binarize input" stage)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("bm", "bkw", "use_pallas",
                                             "interpret"))
def pack_activations(
    x: jax.Array,
    *,
    bm: int = 8,
    bkw: int = 8,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Binarize+pack (M, K) float -> (M, ceil(K/32)) uint32.

    Rows are NOT padded (output keeps M); K tail bits are 0.
    """
    m, k = x.shape
    kw = bitpack.packed_width(k)
    if not use_pallas:
        return bitpack.pack_sign(x)
    kb = bkw * WORD_BITS
    xp = jnp.pad(
        x,
        ((0, _round_up(m, bm) - m), (0, _round_up(k, kb) - k)),
        constant_values=-1.0,  # negative pad -> bit 0
    )
    it = interpret if interpret is not None else _env_interpret()
    out = pack_sign_pallas(xp, bm=bm, bkw=bkw, interpret=it)
    return out[:m, :kw]


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k_true", "config"))
def packed_gemm(
    a_packed: jax.Array,  # (M, Kw) uint32
    b_packed: jax.Array,  # (N, Kw) uint32 (weights, transposed layout)
    *,
    k_true: int,
    config: GemmConfig = DEFAULT_GEMM_CONFIG,
) -> jax.Array:
    """Exact ±1 dot product (M, N) int32 from packed operands."""
    name = resolve_backend(config.backend, 1)
    be = get_backend(name)
    tiles = config.tiles(a_packed.shape[0], b_packed.shape[0],
                         a_packed.shape[1], backend=name)
    return be.gemm(a_packed, b_packed, k_true, tiles, config)


@functools.partial(jax.jit, static_argnames=("config",))
def packed_kbit_gemm(
    a_planes: jax.Array,  # (ka, M, Kw) uint32 plane stack
    b_planes: jax.Array,  # (kb, N, Kw) uint32 plane stack (weights)
    *,
    config: GemmConfig = DEFAULT_GEMM_CONFIG,
) -> jax.Array:
    """Raw weighted-plane popcount S (M, N) int32 from packed plane stacks
    (plane counts read off the leading dims)."""
    name = resolve_backend(config.backend, b_planes.shape[0])
    be = get_backend(name)
    if be.gemm_kbit is None:
        raise ValueError(f"backend {name!r} has no k-bit kernel")
    _check_kbit_accumulator(a_planes.shape[2] * WORD_BITS,
                            a_planes.shape[0], b_planes.shape[0])
    tiles = config.tiles(a_planes.shape[1], b_planes.shape[1],
                         a_planes.shape[2], backend=name)
    return be.gemm_kbit(a_planes, b_planes, tiles, config)


def _kbit_dot_from_float(x2, w_planes, *, k_true, config, w_bits, a_bits):
    """(M, K) float acts x (w_bits, N, Kw) plane-packed weights -> the
    fake-quant DoReFa dot (M, N) fp32, pre-epilogue."""
    name = resolve_backend(config.backend, w_bits)
    be = get_backend(name)
    if be.from_float_kbit is not None:
        return be.from_float_kbit(x2, w_planes, a_bits, w_bits, k_true)
    assert w_planes.ndim == 3 and w_planes.shape[0] == w_bits, (
        w_planes.shape, w_bits)
    _check_kbit_accumulator(k_true, a_bits, w_bits)
    codes = quant.act_codes(x2, a_bits)  # (M, K) uint32
    a_planes = bitpack.pack_planes(codes, a_bits)  # (ka, M, Kw)
    tiles = config.tiles(x2.shape[0], w_planes.shape[1],
                         a_planes.shape[-1], backend=name)
    s = be.gemm_kbit(a_planes, w_planes, tiles, config)
    t_sum = codes.astype(jnp.int32).sum(axis=-1)  # (M,)
    return _kbit_dequant(s, t_sum[:, None], a_bits, w_bits)


@functools.partial(
    jax.jit, static_argnames=("k_true", "config", "epilogue", "w_bits",
                              "a_bits")
)
def quant_gemm(
    x: jax.Array,  # (..., K) float activations
    w_packed: jax.Array,  # (N, Kw) 1-bit words or (w_bits, N, Kw) planes
    *,
    k_true: int,
    config: GemmConfig = DEFAULT_GEMM_CONFIG,
    epilogue: EpilogueSpec = EpilogueSpec(),
    scale: jax.Array | None = None,
    bias: jax.Array | None = None,
    w_bits: int | None = None,
    a_bits: int | None = None,
) -> jax.Array:
    """The quantized GEMM: quantize+pack x, packed GEMM against packed w,
    fused epilogue.  Returns (..., N) in ``epilogue.out_dtype`` —
    numerically identical to the fake-quant training path plus the same
    epilogue (paper §2.2.2 invariant; ``sign(x) @ sign(W)`` at 1 bit, the
    DoReFa Eq. 1 dot at k bits).

    ``w_bits``/``a_bits`` default to ``config.bits`` then 1; widths > 1
    route to the bit-plane backends (see :func:`resolve_backend`)."""
    lead = x.shape[:-1]
    assert x.shape[-1] == k_true, (x.shape, k_true)
    x2 = x.reshape(-1, k_true)
    wb = w_bits or config.bits or 1
    ab = a_bits or config.bits or 1
    if wb > 1 or ab > 1:
        _check_kbit_widths(wb, ab)
    if wb > 1:
        dot = _kbit_dot_from_float(
            x2, w_packed, k_true=k_true, config=config, w_bits=wb,
            a_bits=ab,
        )
        n_out = w_packed.shape[-2]
    else:
        name = resolve_backend(config.backend, 1)
        be = get_backend(name)
        if be.from_float is not None:
            dot = be.from_float(x2, w_packed, k_true)
        else:
            xp = pack_activations(x2, interpret=config._interpret)
            tiles = config.tiles(xp.shape[0], w_packed.shape[0],
                                 xp.shape[1], backend=name)
            dot = be.gemm(xp, w_packed, k_true, tiles, config)
        n_out = w_packed.shape[0]
    y = apply_epilogue(
        dot.astype(jnp.float32), k_true=k_true, epilogue=epilogue,
        scale=scale, bias=bias,
    )
    return y.reshape(*lead, n_out)


@dataclasses.dataclass(frozen=True)
class QuantGemmCall:
    """A fully-specified quantized GEMM: shape contract + bit widths +
    backend config + fused epilogue.  Layers build one of these and apply
    it; everything else (packing, tiles, backend resolution, pad
    correction, epilogue order) is owned here."""

    k_true: int
    config: GemmConfig = DEFAULT_GEMM_CONFIG
    epilogue: EpilogueSpec = EpilogueSpec()
    w_bits: int = 1
    a_bits: int = 1

    def __call__(
        self,
        x: jax.Array,
        w_packed: jax.Array,
        *,
        scale: jax.Array | None = None,
        bias: jax.Array | None = None,
    ) -> jax.Array:
        return quant_gemm(
            x, w_packed, k_true=self.k_true, config=self.config,
            epilogue=self.epilogue, scale=scale, bias=bias,
            w_bits=self.w_bits, a_bits=self.a_bits,
        )


@functools.partial(
    jax.jit,
    static_argnames=("k_true", "config", "expert_capacity", "out_dtype",
                     "w_bits", "a_bits"),
)
def quant_gemm_grouped(
    x_sorted: jax.Array,  # (T, K) float rows, sorted by group
    w_stack,  # (E, N, Kw) / (E, w_bits, N, Kw) packed experts, or a tuple
    group_sizes: jax.Array,  # (E,) int32, sum <= T
    *,
    k_true: int,
    config: GemmConfig = DEFAULT_GEMM_CONFIG,
    expert_capacity: int | None = None,
    out_dtype=jnp.float32,
    w_bits: int | None = None,
    a_bits: int | None = None,
):
    """Grouped (MoE expert-stacked) packed GEMM.

    Row ``i`` of ``x_sorted`` is contracted against the packed weights of
    its group (groups are contiguous: the first ``group_sizes[0]`` rows
    belong to expert 0, …).  Rows beyond ``sum(group_sizes)`` — MoE
    padding / non-owned rows — return zeros.  Rows overflowing a bucket
    (``expert_capacity``, default T: no drops) are dropped (zeros) on
    EVERY backend — the same contract as the EP capacity slack in
    ``nn/mlp.py``.

    ``w_stack`` may be a tuple of same-shape stacks (MoE up+gate): the
    activations are binarized, packed, and bucketed ONCE and contracted
    against each stack, returning a tuple.

    ``shard-*`` backends run the contraction expert-parallel
    (``config.expert_axis``) x Kw-parallel (``config.shard_axis``); a
    configured ``shard_layout="n"`` applies only to the dense GEMMs of a
    mixed model — the grouped path has no "n" layout and uses "k" here.

    Pallas backends scatter the packed words into per-expert buckets and
    run the expert-batched xnor kernel, so only packed words cross HBM —
    closing the 32x traffic win the old unpack-to-float expert path
    forfeited.  The bucket layout is dense (E, capacity, Kw): with the
    default full capacity that is E-fold overcompute versus a ragged
    contraction, the price of exactness-by-default — production MoE
    serving should pass the load-balance ``expert_capacity`` (ROADMAP
    lists the capacity-factor wiring as a follow-on).
    """
    stacks = w_stack if isinstance(w_stack, tuple) else (w_stack,)
    t, k = x_sorted.shape
    e = stacks[0].shape[0]
    n = stacks[0].shape[-2]
    assert k == k_true, (k, k_true)
    wb = w_bits or config.bits or 1
    ab = a_bits or config.bits or 1
    if wb > 1 or ab > 1:
        _check_kbit_widths(wb, ab)

    ec = expert_capacity or t
    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    row = jnp.arange(t, dtype=jnp.int32)
    g = jnp.searchsorted(ends, row, side="right").astype(jnp.int32)
    g_safe = jnp.minimum(g, e - 1)
    pos = row - starts[g_safe]
    valid = (g < e) & (pos < ec)

    if wb > 1:
        return _kbit_grouped(
            x_sorted, w_stack, stacks, group_sizes, g, g_safe, pos, valid,
            ec=ec, k_true=k_true, config=config, out_dtype=out_dtype,
            w_bits=wb, a_bits=ab,
        )

    name = resolve_backend(config.backend, 1)
    be = get_backend(name)
    if be.from_float_grouped is not None:
        outs = tuple(
            jnp.where(
                valid[:, None],
                be.from_float_grouped(x_sorted, w, group_sizes, k_true),
                0,
            ).astype(out_dtype)
            for w in stacks
        )
        return outs if isinstance(w_stack, tuple) else outs[0]

    xp = pack_activations(x_sorted, interpret=config._interpret)
    kw = xp.shape[1]
    buckets = jnp.zeros((e, ec, kw), jnp.uint32)
    buckets = buckets.at[g, pos].set(xp, mode="drop")

    tiles = config.tiles(ec, n, kw, backend=name)
    outs = []
    for w in stacks:
        dots = be.gemm_grouped(buckets, w, k_true, tiles,
                               config)  # (E, ec, N)
        y = dots[g_safe, jnp.minimum(pos, ec - 1)]
        outs.append(jnp.where(valid[:, None], y, 0).astype(out_dtype))
    return tuple(outs) if isinstance(w_stack, tuple) else outs[0]


def _kbit_grouped(x_sorted, w_stack, stacks, group_sizes, g, g_safe, pos,
                  valid, *, ec, k_true, config, out_dtype, w_bits, a_bits):
    """k-bit arm of :func:`quant_gemm_grouped`: activation codes are
    quantized, plane-packed and bucketed ONCE, then each (E, w_bits, N, Kw)
    expert plane stack contracts on the expert-batched plane kernel; the
    ``"xla"`` fallback lowers to ``lax.ragged_dot`` over dequantized
    weights.  Same capacity/validity contract as the 1-bit arm."""
    e = stacks[0].shape[0]
    n = stacks[0].shape[-2]
    name = resolve_backend(config.backend, w_bits)
    be = get_backend(name)

    if be.from_float_kbit_grouped is not None:
        outs = tuple(
            jnp.where(
                valid[:, None],
                be.from_float_kbit_grouped(x_sorted, w, group_sizes,
                                           a_bits, w_bits, k_true),
                0,
            ).astype(out_dtype)
            for w in stacks
        )
        return outs if isinstance(w_stack, tuple) else outs[0]

    _check_kbit_accumulator(k_true, a_bits, w_bits)
    codes = quant.act_codes(x_sorted, a_bits)  # (T, K) uint32
    planes = bitpack.pack_planes(codes, a_bits)  # (ka, T, Kw)
    kw = planes.shape[-1]
    buckets = jnp.zeros((e, ec, a_bits, kw), jnp.uint32)
    buckets = buckets.at[g, pos].set(
        jnp.moveaxis(planes, 0, 1), mode="drop"
    )
    buckets = jnp.moveaxis(buckets, 2, 1)  # (E, ka, ec, kw)

    tiles = config.tiles(ec, n, kw, backend=name)
    t_sum = codes.astype(jnp.int32).sum(axis=-1)  # (T,)
    outs = []
    for w in stacks:
        s = be.gemm_kbit_grouped(buckets, w, tiles,
                                 config)  # (E, ec, N)
        y = s[g_safe, jnp.minimum(pos, ec - 1)]
        dot = _kbit_dequant(y, t_sum[:, None], a_bits, w_bits)
        outs.append(jnp.where(valid[:, None], dot, 0).astype(out_dtype))
    return tuple(outs) if isinstance(w_stack, tuple) else outs[0]
