"""Checkpointing with the fault-tolerance properties the cluster needs.

* **atomic**: write to ``step_XXXX.tmp/``, fsync, rename — a preempted save
  never shadows the previous good checkpoint.
* **manifest + checksum**: every save carries a JSON manifest (step, leaf
  paths/shapes/dtypes, adler32 per leaf); restore validates before use and
  falls back to the previous step on corruption.
* **async**: the host copy + serialization runs on a background thread so
  the train loop only blocks for the device->host transfer.
* **elastic restore**: checkpoints are stored as host numpy (mesh-agnostic);
  ``restore(..., shardings=...)`` device_puts into whatever mesh the
  restarted job has — shrink/grow the data axis and the state reshards.
* **retention**: keep the latest N checkpoints.
* **train-state aware**: dataclass pytrees flatten field-wise, so a
  ``train.trainer.TrainState`` (master params + opt state + the 1-bit EF
  gradient-compression residual) saves/restores as one tree and a resumed
  compressed run is bit-identical to an uninterrupted one.
* **packed export**: ``export_packed`` runs the BMXNet model converter on a
  float checkpoint and writes the 1-bit serving artifact (29x smaller —
  paper §2.2.3), which serve.py loads.

Leaves are stored in one ``.npz`` per checkpoint (single-host container; a
multi-host deployment writes one file per host shard — the manifest format
already carries per-leaf metadata to support that layout).
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import zlib
from typing import Any

import jax
import numpy as np

Pytree = Any

_SEP = "|"  # path separator safe for npz keys


def _is_dataclass_node(x: Any) -> bool:
    # dataclass *instances* flatten field-wise (train.trainer.TrainState);
    # excludes dataclass types themselves
    return dataclasses.is_dataclass(x) and not isinstance(x, type)


def _flatten(tree: Pytree, prefix: str = "") -> dict[str, Any]:
    out = {}
    if _is_dataclass_node(tree):
        for f in dataclasses.fields(tree):
            key = f"{prefix}{_SEP}{f.name}" if prefix else f.name
            out.update(_flatten(getattr(tree, f.name), key))
    elif isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{_SEP}{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{_SEP}{i}"))
        if len(tree) == 0:
            out[f"{prefix}{_SEP}__empty__"] = np.zeros((0,))
    else:
        out[prefix] = tree
    return out


def _unflatten_into(template: Pytree, flat: dict[str, Any], prefix: str = ""):
    if _is_dataclass_node(template):
        return type(template)(**{
            f.name: _unflatten_into(
                getattr(template, f.name), flat,
                f"{prefix}{_SEP}{f.name}" if prefix else f.name,
            )
            for f in dataclasses.fields(template)
        })
    if isinstance(template, dict):
        return {
            k: _unflatten_into(v, flat, f"{prefix}{_SEP}{k}" if prefix else str(k))
            for k, v in template.items()
        }
    if isinstance(template, (list, tuple)):
        return type(template)(
            _unflatten_into(v, flat, f"{prefix}{_SEP}{i}")
            for i, v in enumerate(template)
        )
    return flat[prefix]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save --------------------------------------------------------------

    def save(self, step: int, tree: Pytree, *, blocking: bool = True):
        """Device->host now; serialization async unless blocking."""
        flat = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}
        if self._thread is not None:
            self._thread.join()
        if blocking:
            self._write(step, host)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True
            )
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict[str, np.ndarray]):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        manifest = {
            "step": step,
            "leaves": {
                k: {
                    "shape": list(v.shape),
                    "dtype": str(v.dtype),
                    "adler32": zlib.adler32(np.ascontiguousarray(v).tobytes()),
                }
                for k, v in host.items()
            },
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore -------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    continue
        return sorted(out)

    def _validate(self, path: str) -> dict[str, np.ndarray] | None:
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            data = np.load(os.path.join(path, "arrays.npz"))
            host = {}
            for k, meta in manifest["leaves"].items():
                v = data[k]
                if zlib.adler32(np.ascontiguousarray(v).tobytes()) != meta["adler32"]:
                    return None
                host[k] = v
            return host
        except Exception:
            return None

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self, template: Pytree, *, step: int | None = None, shardings=None
    ) -> tuple[int, Pytree] | None:
        """Returns (step, tree) or None.  Walks backwards past corrupt
        checkpoints (fault tolerance)."""
        steps = self.all_steps()
        if step is not None:
            steps = [s for s in steps if s == step]
        for s in reversed(steps):
            host = self._validate(os.path.join(self.dir, f"step_{s:08d}"))
            if host is None:
                continue
            tree = _unflatten_into(template, host)
            if shardings is not None:
                tree = jax.tree.map(
                    lambda x, sh: jax.device_put(x, sh), tree, shardings
                )
            return s, tree
        return None


def export_packed(params: Pytree, policy, path: str) -> "Any":
    """Run the BMXNet converter and save the packed serving checkpoint.
    Returns the SizeReport (compression accounting, paper Table 1)."""
    from repro.core import converter

    packed, report = converter.convert(params, policy)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = {k: np.asarray(v) for k, v in _flatten(packed).items()}
    tmp = path + ".tmp"
    np.savez(tmp, **flat)
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    return report
