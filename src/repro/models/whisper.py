"""Whisper-base backbone (enc-dec transformer).

Per the assignment the conv/mel frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, T_enc, d_model) and the encoder runs plain
bidirectional attention over them.  T_enc is whisper-native 1500; the
assigned seq_len applies to the decoder.  LayerNorm + GELU + learned
positions + tied embedding head, per the paper (arXiv:2212.04356).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.nn import attention as attn_lib
from repro.nn import mlp as mlp_lib
from repro.nn.common import QCtx, embed_init, norm_apply, norm_init, sincos_positions

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class WhisperConfig:
    name: str
    n_layers: int  # per stack
    d_model: int
    n_heads: int
    d_ff: int
    vocab_size: int
    t_enc: int = 1500
    max_dec: int = 448  # grown by configs for the assigned shapes

    @property
    def self_attn(self) -> attn_lib.AttnConfig:
        return attn_lib.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_heads, d_head=self.d_model // self.n_heads,
            use_rope=False, causal=True, full_attn_max_seq=4096,
        )

    @property
    def enc_attn(self) -> attn_lib.AttnConfig:
        return dataclasses.replace(self.self_attn, causal=False)

    @property
    def cross_attn(self) -> attn_lib.AttnConfig:
        return dataclasses.replace(self.self_attn, causal=False)

    @property
    def mlp(self) -> mlp_lib.MLPConfig:
        return mlp_lib.MLPConfig(self.d_model, self.d_ff, act="gelu", gated=False)


def init(key: jax.Array, cfg: WhisperConfig, dtype=jnp.float32) -> Params:
    n = cfg.n_layers
    keys = jax.random.split(key, 2 * n + 2)
    enc_layers, dec_layers = [], []
    for i in range(n):
        ke1, ke2 = jax.random.split(keys[i])
        enc_layers.append({
            "ln1": norm_init("layernorm", cfg.d_model),
            "attn": attn_lib.attn_init(ke1, cfg.enc_attn, dtype=dtype),
            "ln2": norm_init("layernorm", cfg.d_model),
            "mlp": mlp_lib.mlp_init(ke2, cfg.mlp, dtype=dtype),
        })
        kd1, kd2, kd3 = jax.random.split(keys[n + i], 3)
        dec_layers.append({
            "ln1": norm_init("layernorm", cfg.d_model),
            "attn": attn_lib.attn_init(kd1, cfg.self_attn, dtype=dtype),
            "ln_x": norm_init("layernorm", cfg.d_model),
            "xattn": attn_lib.attn_init(kd2, cfg.cross_attn, dtype=dtype),
            "ln2": norm_init("layernorm", cfg.d_model),
            "mlp": mlp_lib.mlp_init(kd3, cfg.mlp, dtype=dtype),
        })
    return {
        "embed": embed_init(keys[-2], cfg.vocab_size, cfg.d_model, dtype),
        "pos_dec": jax.random.normal(keys[-1], (cfg.max_dec, cfg.d_model),
                                     dtype) * 0.01,
        "encoder": {"layers": enc_layers,
                    "ln_post": norm_init("layernorm", cfg.d_model)},
        "decoder": {"layers": dec_layers,
                    "ln_post": norm_init("layernorm", cfg.d_model)},
    }


def encode(params, cfg: WhisperConfig, ctx: QCtx, frames: jax.Array) -> jax.Array:
    """frames: (B, T_enc, d_model) stub embeddings -> encoder output."""
    b, t, _ = frames.shape
    pos_tab = sincos_positions(t, cfg.d_model).astype(ctx.compute_dtype)
    x = frames.astype(ctx.compute_dtype) + pos_tab[None]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    for i, blk in enumerate(params["encoder"]["layers"]):
        path = f"encoder/layers/{i}"
        h = norm_apply("layernorm", blk["ln1"], x)
        x = x + attn_lib.attn_forward(blk["attn"], h, positions, cfg.enc_attn,
                                      ctx, f"{path}/attn")
        h = norm_apply("layernorm", blk["ln2"], x)
        x = x + mlp_lib.mlp_apply(blk["mlp"], h, cfg.mlp, ctx, f"{path}/mlp")
    return norm_apply("layernorm", params["encoder"]["ln_post"], x)


def forward(
    params, cfg: WhisperConfig, ctx: QCtx,
    frames: jax.Array,  # (B, T_enc, d_model) — stub frontend output
    tokens: jax.Array,  # (B, S_dec)
) -> tuple[jax.Array, jax.Array]:
    """Teacher-forced training forward.  Returns (logits, aux=0)."""
    enc = encode(params, cfg, ctx, frames)
    b, t_enc, _ = enc.shape
    enc_pos = jnp.broadcast_to(jnp.arange(t_enc, dtype=jnp.int32), (b, t_enc))

    b, s = tokens.shape
    x = params["embed"]["table"].astype(ctx.compute_dtype)[tokens]
    x = x + params["pos_dec"][:s].astype(ctx.compute_dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    for i, blk in enumerate(params["decoder"]["layers"]):
        path = f"decoder/layers/{i}"
        h = norm_apply("layernorm", blk["ln1"], x)
        x = x + attn_lib.attn_forward(blk["attn"], h, positions, cfg.self_attn,
                                      ctx, f"{path}/attn")
        h = norm_apply("layernorm", blk["ln_x"], x)
        kv = attn_lib.cross_kv(blk["xattn"], enc, cfg.cross_attn, ctx,
                               f"{path}/xattn")
        x = x + attn_lib.attn_forward(blk["xattn"], h, positions,
                                      cfg.cross_attn, ctx, f"{path}/xattn",
                                      kv=kv, kv_positions=enc_pos)
        h = norm_apply("layernorm", blk["ln2"], x)
        x = x + mlp_lib.mlp_apply(blk["mlp"], h, cfg.mlp, ctx, f"{path}/mlp")

    x = norm_apply("layernorm", params["decoder"]["ln_post"], x)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["table"].astype(x.dtype))
    return logits.astype(jnp.float32), jnp.zeros((), jnp.float32)


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------


def init_cache(cfg: WhisperConfig, b: int, cache_len: int, dtype=jnp.bfloat16,
               kv: attn_lib.KVCache | None = None):
    """Whisper serves on the contiguous layout only: the static per-slot
    cross-attention cache (t_enc rows, written once at prefill) has no
    useful block-paging story — serve/engine validates before choosing
    paged."""
    if kv is not None and not isinstance(kv, attn_lib.ContiguousKVCache):
        raise ValueError("whisper serving supports the contiguous KV cache "
                         "layout only (static cross-attention cache)")
    layers = []
    for _ in range(cfg.n_layers):
        layers.append({
            "self": attn_lib.CONTIGUOUS.init(b, cfg.self_attn, cache_len,
                                             dtype),
            "cross": attn_lib.CONTIGUOUS.init(b, cfg.cross_attn, cfg.t_enc,
                                              dtype),
        })
    return {"layers": layers}


def cache_insert(cache, sub, slots: jax.Array,
                 kv: attn_lib.KVCache | None = None):
    """Slot-targeted cache insertion (see models/lm.cache_insert): write a
    (G,)-batch prefill cache — decoder self-cache AND the static
    cross-attention cache — into G slots of the serving batch cache."""
    kv = attn_lib.CONTIGUOUS if kv is None else kv
    return kv.insert(cache, sub, slots)


def cache_reset(cfg: WhisperConfig, cache, slot: jax.Array,
                kv: attn_lib.KVCache | None = None):
    """Retire one serving slot: mark the slot's self- and cross-cache rows
    empty (slot_pos = -1) so attention masks them until readmission."""
    kv = attn_lib.CONTIGUOUS if kv is None else kv
    layers = []
    for lc in cache["layers"]:
        layers.append({
            "self": kv.reset(lc["self"], slot),
            "cross": kv.reset(lc["cross"], slot),
        })
    return {"layers": layers}


def prefill(params, cfg: WhisperConfig, ctx: QCtx, frames, tokens, cache_len):
    """Encode audio, prefill decoder self-cache + static cross-cache."""
    enc = encode(params, cfg, ctx, frames)
    b, t_enc, _ = enc.shape
    enc_pos = jnp.broadcast_to(jnp.arange(t_enc, dtype=jnp.int32), (b, t_enc))
    cache = init_cache(cfg, b, cache_len, ctx.compute_dtype)

    s = tokens.shape[1]
    x = params["embed"]["table"].astype(ctx.compute_dtype)[tokens]
    x = x + params["pos_dec"][:s].astype(ctx.compute_dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    for i, blk in enumerate(params["decoder"]["layers"]):
        path = f"decoder/layers/{i}"
        lc = cache["layers"][i]
        h = norm_apply("layernorm", blk["ln1"], x)
        q, k, v = attn_lib._project_qkv(blk["attn"], h, positions,
                                        cfg.self_attn, ctx, f"{path}/attn")
        lc["self"] = attn_lib.CONTIGUOUS.fill(lc["self"], k, v, positions)
        qg = q.reshape(b, s, cfg.n_heads, 1, cfg.self_attn.d_head)
        if s <= cfg.self_attn.full_attn_max_seq:
            out = attn_lib._sdpa(cfg.self_attn, qg, k, v,
                                 attn_lib._mask(cfg.self_attn, positions, positions))
        else:
            out = attn_lib._sdpa_chunked(cfg.self_attn, qg, k, v, positions,
                                         positions)
        out = out.reshape(b, s, cfg.d_model).astype(ctx.compute_dtype)
        x = x + ctx.dense(blk["attn"]["o"], out, f"{path}/attn/o")

        h = norm_apply("layernorm", blk["ln_x"], x)
        kx, vx = attn_lib.cross_kv(blk["xattn"], enc, cfg.cross_attn, ctx,
                                   f"{path}/xattn")
        lc["cross"] = attn_lib.CONTIGUOUS.fill(lc["cross"], kx, vx, enc_pos)
        x = x + attn_lib.attn_forward(blk["xattn"], h, positions,
                                      cfg.cross_attn, ctx, f"{path}/xattn",
                                      kv=(kx, vx), kv_positions=enc_pos)
        h = norm_apply("layernorm", blk["ln2"], x)
        x = x + mlp_lib.mlp_apply(blk["mlp"], h, cfg.mlp, ctx, f"{path}/mlp")

    x = norm_apply("layernorm", params["decoder"]["ln_post"], x[:, -1:, :])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["table"].astype(x.dtype))
    return logits.astype(jnp.float32), cache


def decode_step(params, cfg: WhisperConfig, ctx: QCtx, cache, tokens, pos):
    """tokens: (B, 1); pos: (B,) decoder position."""
    b = tokens.shape[0]
    x = params["embed"]["table"].astype(ctx.compute_dtype)[tokens]
    x = x + params["pos_dec"].astype(ctx.compute_dtype)[pos][:, None]

    new_layers = []
    for i, blk in enumerate(params["decoder"]["layers"]):
        path = f"decoder/layers/{i}"
        lc = dict(cache["layers"][i])
        h = norm_apply("layernorm", blk["ln1"], x)
        h, sc = attn_lib.attn_decode(blk["attn"], h, pos, lc["self"],
                                     cfg.self_attn, ctx, f"{path}/attn")
        lc["self"] = sc
        x = x + h
        h = norm_apply("layernorm", blk["ln_x"], x)
        h, _ = attn_lib.attn_decode(blk["xattn"], h, pos, lc["cross"],
                                    cfg.cross_attn, ctx, f"{path}/xattn",
                                    cross=True)
        x = x + h
        h = norm_apply("layernorm", blk["ln2"], x)
        x = x + mlp_lib.mlp_apply(blk["mlp"], h, cfg.mlp, ctx, f"{path}/mlp")
        new_layers.append(lc)

    x = norm_apply("layernorm", params["decoder"]["ln_post"], x)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["table"].astype(x.dtype))
    return logits.astype(jnp.float32), {"layers": new_layers}
