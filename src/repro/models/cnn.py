"""Paper-fidelity CNNs: binary LeNet (Listing 2) and ResNet-18.

These are the models BMXNet itself evaluates (Table 1/2).  Block structure
follows the paper exactly: *QActivation -> QConv/QFC -> BatchNorm -> Pool*,
with the first conv and the last FC always full precision.  ResNet-18 keeps
MXNet's 4-ResUnit-stage layout so Table 2's per-stage partial binarization
maps onto policy rules ("stage1" ... "stage4").

BatchNorm here is the inference/training-free variant (per-channel affine
after normalising with batch statistics) — sufficient for the fidelity and
equivalence tests; momentum-tracked running stats are orthogonal to the
paper's contribution.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import qlayers
from repro.nn.common import QCtx

Params = dict[str, Any]


def _bn_init(c: int) -> Params:
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _bn(params: Params, x: jax.Array, eps=1e-5) -> jax.Array:
    mu = x.mean(axis=(0, 1, 2), keepdims=True)
    var = x.var(axis=(0, 1, 2), keepdims=True)
    xn = (x - mu) * jax.lax.rsqrt(var + eps)
    return xn * params["scale"] + params["bias"]


def _pool(x, window=2, stride=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1), (1, stride, stride, 1),
        "VALID",
    )


# --------------------------------------------------------------------------
# LeNet (Table 1, MNIST)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LeNetConfig:
    name: str = "lenet"
    n_classes: int = 10
    c1: int = 64
    c2: int = 64
    fc1: int = 1000  # matches the paper's 4.6MB full-precision size
    in_hw: int = 28
    in_c: int = 1


def lenet_init(key, cfg: LeNetConfig) -> Params:
    ks = jax.random.split(key, 4)
    # VALID 5x5 convs + two 2x2 pools (MXNet LeNet): 28->24->12->8->4,
    # giving fc1 input 4*4*64=1024 and the paper's 4.6MB fp32 size.
    hw = ((cfg.in_hw - 4) // 2 - 4) // 2
    return {
        "first_conv": qlayers.conv_init(ks[0], 5, 5, cfg.in_c, cfg.c1),
        "bn1": _bn_init(cfg.c1),
        "conv2": qlayers.conv_init(ks[1], 5, 5, cfg.c1, cfg.c2),
        "bn2": _bn_init(cfg.c2),
        "fc1": qlayers.dense_init(ks[2], hw * hw * cfg.c2, cfg.fc1),
        "bn3": _bn_init(cfg.fc1),
        "head": qlayers.dense_init(ks[3], cfg.fc1, cfg.n_classes),
    }


def lenet_forward(params, cfg: LeNetConfig, ctx: QCtx, images) -> jax.Array:
    """images: (B, H, W, C) -> logits (B, n_classes).

    Paper Listing 2: conv1 (fp) -> pool -> bn -> QConv -> bn -> pool ->
    QFC -> bn -> tanh -> FC (fp).
    """
    x = images.astype(ctx.compute_dtype)
    x = ctx.conv(params["first_conv"], x, "first_conv", padding="VALID")
    x = jnp.tanh(x)
    x = _pool(x)
    x = _bn(params["bn1"], x)
    x = ctx.conv(params["conv2"], x, "conv2", padding="VALID")
    x = _bn(params["bn2"], x)
    x = _pool(x)
    x = x.reshape(x.shape[0], -1)
    x = ctx.dense(params["fc1"], x, "fc1")
    x = _bn(params["bn3"], x[:, None, None, :])[:, 0, 0, :]
    x = jnp.tanh(x)
    return ctx.dense(params["head"], x, "head").astype(jnp.float32)


# --------------------------------------------------------------------------
# ResNet-18 (Table 1 CIFAR-10 / Table 2 ImageNet)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ResNet18Config:
    name: str = "resnet18"
    n_classes: int = 10
    widths: tuple[int, ...] = (64, 128, 256, 512)
    in_hw: int = 32
    in_c: int = 3
    stem_stride: int = 1  # 1 for CIFAR, 2 (+maxpool) for ImageNet


def resnet18_init(key, cfg: ResNet18Config) -> Params:
    ks = iter(jax.random.split(key, 64))
    p: Params = {
        "first_conv": qlayers.conv_init(next(ks), 3, 3, cfg.in_c, cfg.widths[0]),
        "bn0": _bn_init(cfg.widths[0]),
    }
    c_in = cfg.widths[0]
    for si, c_out in enumerate(cfg.widths):
        stage: Params = {}
        for bi in range(2):  # ResNet-18: two units per stage
            stride = 2 if (bi == 0 and si > 0) else 1
            unit: Params = {
                "bn1": _bn_init(c_in),
                "conv1": qlayers.conv_init(next(ks), 3, 3, c_in, c_out),
                "bn2": _bn_init(c_out),
                "conv2": qlayers.conv_init(next(ks), 3, 3, c_out, c_out),
            }
            if stride != 1 or c_in != c_out:
                unit["proj"] = qlayers.conv_init(next(ks), 1, 1, c_in, c_out)
            stage[f"unit{bi}"] = unit
            c_in = c_out
        p[f"stage{si + 1}"] = stage
    p["bn_final"] = _bn_init(c_in)
    p["head"] = qlayers.dense_init(next(ks), c_in, cfg.n_classes)
    return p


def _res_unit(unit, x, stride, ctx: QCtx, path: str):
    h = _bn(unit["bn1"], x)
    h = ctx.conv(unit["conv1"], h, f"{path}/conv1", stride=stride, padding="SAME")
    h = _bn(unit["bn2"], h)
    h = ctx.conv(unit["conv2"], h, f"{path}/conv2", stride=1, padding="SAME")
    if "proj" in unit:
        x = ctx.conv(unit["proj"], x, f"{path}/proj", stride=stride,
                     padding="SAME")
    return x + h


def resnet18_forward(params, cfg: ResNet18Config, ctx: QCtx, images):
    x = images.astype(ctx.compute_dtype)
    x = ctx.conv(params["first_conv"], x, "first_conv",
                 stride=cfg.stem_stride, padding="SAME")
    x = _bn(params["bn0"], x)
    x = jax.nn.relu(x)
    for si in range(4):
        stage = params[f"stage{si + 1}"]
        for bi in range(2):
            stride = 2 if (bi == 0 and si > 0) else 1
            x = _res_unit(stage[f"unit{bi}"], x, stride, ctx,
                          f"stage{si + 1}/unit{bi}")
    x = _bn(params["bn_final"], x)
    x = jax.nn.relu(x)
    x = x.mean(axis=(1, 2))
    return ctx.dense(params["head"], x, "head").astype(jnp.float32)
