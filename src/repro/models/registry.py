"""Architecture registry: ``--arch <id>`` resolution for every launcher,
test and benchmark."""

from __future__ import annotations

from repro.configs import (
    deepseek_7b,
    deepseek_moe_16b,
    gemma2_27b,
    granite_3_2b,
    internvl2_1b,
    lenet_mnist,
    qwen2_72b,
    qwen2_moe_a27b,
    recurrentgemma_2b,
    resnet18_cifar10,
    rwkv6_7b,
    whisper_base,
)
from repro.configs.common import ArchSpec

_MODULES = (
    recurrentgemma_2b,
    rwkv6_7b,
    deepseek_7b,
    granite_3_2b,
    qwen2_72b,
    gemma2_27b,
    deepseek_moe_16b,
    qwen2_moe_a27b,
    internvl2_1b,
    whisper_base,
    lenet_mnist,
    resnet18_cifar10,
)

ARCHS: dict[str, ArchSpec] = {m.SPEC.arch_id: m.SPEC for m in _MODULES}

# the ten assigned LM-family architectures (the CNNs are paper-fidelity extras)
ASSIGNED = tuple(
    a for a in ARCHS if ARCHS[a].family in ("lm", "whisper")
)


def get(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]
