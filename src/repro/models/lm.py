"""Unified decoder-only LM covering the dense / MoE / hybrid / SSM / VLM
members of the assigned pool.

A model is a stack of blocks; block ``i`` gets a *mixer* (attn, local_attn,
rglru, rwkv6) and an *ffn* (mlp, moe, rwkv_cmix) from cyclic patterns —
which is exactly how the real architectures are specified (gemma2
alternates local/global, recurrentgemma cycles (rglru, rglru, local_attn),
deepseek-moe is dense-FFN for the first layer then MoE, ...).

Every GEMM goes through QCtx.dense, so one `--quant` flag turns any of
these architectures into its BMXNet-binarized variant.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import qlayers
from repro.nn import attention as attn_lib
from repro.nn import mlp as mlp_lib
from repro.nn import rglru as rglru_lib
from repro.nn import rwkv6 as rwkv_lib
from repro.nn.common import QCtx, embed_init, norm_apply, norm_init, softcap

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    vocab_size: int
    mixer_pattern: tuple[str, ...] = ("attn",)
    ffn_pattern: tuple[str, ...] = ("mlp",)
    attn: attn_lib.AttnConfig | None = None
    local_attn: attn_lib.AttnConfig | None = None
    rglru: rglru_lib.RGLRUConfig | None = None
    rwkv: rwkv_lib.RWKV6Config | None = None
    mlp: mlp_lib.MLPConfig | None = None
    moe: mlp_lib.MoEConfig | None = None
    first_dense_layers: int = 0  # deepseek-moe: dense FFN for first layer(s)
    first_dense_mlp: mlp_lib.MLPConfig | None = None
    norm: str = "rmsnorm"
    post_norm: bool = False  # gemma2 post-sublayer norms
    embed_norm: bool = False  # rwkv ln0
    embed_scale: bool = False  # gemma family: x *= sqrt(d)
    tie_embeddings: bool = False
    logit_softcap: float | None = None
    # pad the vocab so embedding/lm_head/logits shard over the model axis
    # (granite 49155, internvl 151655: unpadded => replicated fp32 logits,
    # measured 117 GB/device on internvl train_4k).  0 = no padding.
    vocab_pad_to: int = 0
    max_seq: int = 0  # 0 = rope-only (no learned positions)
    # VLM (stub frontend per assignment: precomputed patch embeddings)
    vision_prefix: int = 0
    d_vision: int = 0

    @property
    def padded_vocab(self) -> int:
        if self.vocab_pad_to:
            m = self.vocab_pad_to
            return (self.vocab_size + m - 1) // m * m
        return self.vocab_size

    def mixer_kind(self, i: int) -> str:
        return self.mixer_pattern[i % len(self.mixer_pattern)]

    def ffn_kind(self, i: int) -> str:
        k = self.ffn_pattern[i % len(self.ffn_pattern)]
        if k == "moe" and i < self.first_dense_layers:
            return "dense_first"
        return k


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def init(key: jax.Array, cfg: LMConfig, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, cfg.n_layers + 3)
    p: Params = {
        "embed": embed_init(keys[0], cfg.padded_vocab, cfg.d_model, dtype)
    }
    if cfg.embed_norm:
        p["embed_ln"] = norm_init(cfg.norm, cfg.d_model)
    if cfg.vision_prefix:
        p["frontend_proj"] = qlayers.dense_init(
            keys[1], cfg.d_vision, cfg.d_model, dtype=dtype
        )
    layers = []
    for i in range(cfg.n_layers):
        layers.append(_block_init(keys[i + 2], i, cfg, dtype))
    p["layers"] = layers
    p["final_norm"] = norm_init(cfg.norm, cfg.d_model)
    if not cfg.tie_embeddings:
        p["lm_head"] = qlayers.dense_init(
            keys[-1], cfg.d_model, cfg.padded_vocab, dtype=dtype
        )
    return p


def _block_init(key, i: int, cfg: LMConfig, dtype) -> Params:
    km, kf = jax.random.split(key)
    mixer = cfg.mixer_kind(i)
    ffn = cfg.ffn_kind(i)
    blk: Params = {"pre_norm": norm_init(cfg.norm, cfg.d_model)}
    if mixer == "attn":
        blk["attn"] = attn_lib.attn_init(km, cfg.attn, dtype=dtype)
    elif mixer == "local_attn":
        blk["attn"] = attn_lib.attn_init(km, cfg.local_attn, dtype=dtype)
    elif mixer == "rglru":
        blk["rglru"] = rglru_lib.rglru_init(km, cfg.rglru, dtype=dtype)
    elif mixer == "rwkv6":
        blk["tmix"] = rwkv_lib.timemix_init(km, cfg.rwkv, dtype=dtype)
    else:
        raise ValueError(mixer)
    if cfg.post_norm:
        blk["post_mixer_norm"] = norm_init(cfg.norm, cfg.d_model)
        blk["post_ffn_norm"] = norm_init(cfg.norm, cfg.d_model)
    blk["pre_ffn_norm"] = norm_init(cfg.norm, cfg.d_model)
    if ffn == "mlp":
        blk["mlp"] = mlp_lib.mlp_init(kf, cfg.mlp, dtype=dtype)
    elif ffn == "dense_first":
        blk["mlp"] = mlp_lib.mlp_init(kf, cfg.first_dense_mlp, dtype=dtype)
    elif ffn == "moe":
        blk["moe"] = mlp_lib.moe_init(kf, cfg.moe, dtype=dtype)
    elif ffn == "rwkv_cmix":
        blk["cmix"] = rwkv_lib.chanmix_init(kf, cfg.rwkv, dtype=dtype)
    else:
        raise ValueError(ffn)
    return blk


# --------------------------------------------------------------------------
# forward (training / prefill)
# --------------------------------------------------------------------------


def _embed(params, cfg: LMConfig, ctx: QCtx, tokens, vision_embeds):
    x = params["embed"]["table"].astype(ctx.compute_dtype)[tokens]
    if cfg.vision_prefix:
        vis = ctx.dense(
            params["frontend_proj"],
            vision_embeds.astype(ctx.compute_dtype),
            "frontend_proj",
        )
        x = jnp.concatenate([vis, x], axis=1)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, ctx.compute_dtype)
    if cfg.embed_norm:
        x = norm_apply(cfg.norm, params["embed_ln"], x)
    return x


def _mixer_forward(blk, i, x, positions, cfg: LMConfig, ctx, path):
    kind = cfg.mixer_kind(i)
    if kind in ("attn", "local_attn"):
        acfg = cfg.attn if kind == "attn" else cfg.local_attn
        return attn_lib.attn_forward(blk["attn"], x, positions, acfg, ctx,
                                     f"{path}/attn")
    if kind == "rglru":
        return rglru_lib.rglru_forward(blk["rglru"], x, cfg.rglru, ctx,
                                       f"{path}/rglru")
    if kind == "rwkv6":
        return rwkv_lib.timemix_forward(blk["tmix"], x, cfg.rwkv, ctx,
                                        f"{path}/tmix")
    raise ValueError(kind)


def _ffn_forward(blk, i, x, cfg: LMConfig, ctx, path):
    kind = cfg.ffn_kind(i)
    if kind == "mlp":
        return mlp_lib.mlp_apply(blk["mlp"], x, cfg.mlp, ctx, f"{path}/mlp"), 0.0
    if kind == "dense_first":
        return (
            mlp_lib.mlp_apply(blk["mlp"], x, cfg.first_dense_mlp, ctx,
                              f"{path}/mlp"),
            0.0,
        )
    if kind == "moe":
        return mlp_lib.moe_apply(blk["moe"], x, cfg.moe, ctx, f"{path}/moe")
    if kind == "rwkv_cmix":
        return (
            rwkv_lib.chanmix_forward(blk["cmix"], x, cfg.rwkv, ctx,
                                     f"{path}/cmix"),
            0.0,
        )
    raise ValueError(kind)


def block_forward(blk, i, x, positions, cfg: LMConfig, ctx: QCtx):
    path = f"layers/{i}"
    h = norm_apply(cfg.norm, blk["pre_norm"], x)
    h = _mixer_forward(blk, i, h, positions, cfg, ctx, path)
    if cfg.post_norm:
        h = norm_apply(cfg.norm, blk["post_mixer_norm"], h)
    x = x + h
    h = norm_apply(cfg.norm, blk["pre_ffn_norm"], x)
    h, aux = _ffn_forward(blk, i, h, cfg, ctx, path)
    if cfg.post_norm:
        h = norm_apply(cfg.norm, blk["post_ffn_norm"], h)
    return x + h, aux


def _logits(params, cfg: LMConfig, ctx: QCtx, x):
    x = norm_apply(cfg.norm, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = jnp.einsum(
            "bsd,vd->bsv", x, params["embed"]["table"].astype(x.dtype)
        )
    else:
        logits = ctx.dense(params["lm_head"], x, "lm_head")
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    return logits


def _cycle_len(cfg: LMConfig) -> int:
    import math
    return math.lcm(len(cfg.mixer_pattern), len(cfg.ffn_pattern))


def forward(
    params: Params,
    cfg: LMConfig,
    ctx: QCtx,
    tokens: jax.Array,  # (B, S_text)
    vision_embeds: jax.Array | None = None,  # (B, P, d_vision)
    remat: bool = False,
    scan_blocks: bool = False,
    seq_parallel: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence causal forward.  Returns (logits (B,S,V), aux loss).

    ``scan_blocks`` runs the (homogeneous-cycle) layer stack as a
    ``lax.scan`` over stacked params — the production pattern: activation
    memory is bounded by one cycle body + per-layer residuals instead of
    the whole unrolled stack.  Requires a cycle-uniform quant policy (layer
    paths collapse to ``layers/cyc<j>``).  The unrolled path is kept for
    cost attribution (XLA cost_analysis counts a loop body only once).
    """
    x = _embed(params, cfg, ctx, tokens, vision_embeds)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    aux_total = jnp.zeros((), jnp.float32)

    # Megatron-style sequence parallelism: constrain the residual stream to
    # sequence-sharding over 'model' between blocks.  GSPMD then turns the
    # per-block TP all-reduces into reduce-scatter + all-gather pairs (half
    # the wire bytes) and the saved residuals shrink by the model-axis size.
    sp = None
    if (seq_parallel and ctx.mesh is not None
            and "model" in ctx.mesh.axis_names and s % dict(ctx.mesh.shape)["model"] == 0):
        from jax.sharding import NamedSharding, PartitionSpec as P

        dp = tuple(a for a in ("pod", "data") if a in ctx.mesh.axis_names)
        sp = NamedSharding(ctx.mesh, P(dp if dp else None, "model", None))

    def constrain(y):
        return jax.lax.with_sharding_constraint(y, sp) if sp is not None else y

    fn = block_forward
    if remat:
        fn = jax.checkpoint(  # cfg/ctx/idx are static pytree-less args
            block_forward, static_argnums=(1, 4, 5), policy=None,
        )

    if not scan_blocks:
        for i, blk in enumerate(params["layers"]):
            x, aux = fn(blk, i, constrain(x), positions, cfg, ctx)
            aux_total = aux_total + aux
        return _logits(params, cfg, ctx, x), aux_total

    cycle = _cycle_len(cfg)
    prefix = cfg.first_dense_layers
    groups = (cfg.n_layers - prefix) // cycle
    tail_start = prefix + groups * cycle  # e.g. recurrentgemma: 26 = 8*3 + 2
    for i in range(prefix):
        x, aux = fn(params["layers"][i], i, x, positions, cfg, ctx)
        aux_total = aux_total + aux

    # stack per cycle position j: leaves get a leading `groups` dim.
    # kind(prefix + g*cycle + j) == kind(prefix + j) since cycle is a
    # multiple of both pattern lengths -> the body is g-independent.
    stacks = tuple(
        jax.tree.map(
            lambda *ls: jnp.stack(ls),
            *[params["layers"][prefix + g * cycle + j] for g in range(groups)],
        )
        for j in range(cycle)
    )

    def body(carry, blks):
        xc, auxc = carry
        for j in range(cycle):
            xc, a = fn(blks[j], prefix + j, constrain(xc), positions, cfg, ctx)
            auxc = auxc + a
        return (constrain(xc), auxc), None

    (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), stacks)
    for i in range(tail_start, cfg.n_layers):
        x, aux = fn(params["layers"][i], i, x, positions, cfg, ctx)
        aux_total = aux_total + aux
    return _logits(params, cfg, ctx, x), aux_total


# --------------------------------------------------------------------------
# serving: prefill + decode
# --------------------------------------------------------------------------


def init_cache(
    cfg: LMConfig, b: int, cache_len: int, dtype=jnp.bfloat16,
    kv: attn_lib.KVCache | None = None,
) -> Params:
    """``kv`` selects the attention cache layout (default contiguous).
    The paged layout supports pure-attention stacks only — ring (local)
    and recurrent layers keep slot-private state that block paging has no
    story for (serve/engine validates before choosing paged)."""
    kv = attn_lib.CONTIGUOUS if kv is None else kv
    layers = []
    for i in range(cfg.n_layers):
        kind = cfg.mixer_kind(i)
        if kind == "attn":
            c = kv.init(b, cfg.attn, cache_len, dtype)
        elif kind == "local_attn":
            c = attn_lib.CONTIGUOUS.init(
                b, cfg.local_attn, min(cfg.local_attn.window, cache_len), dtype
            )
        elif kind == "rglru":
            c = rglru_lib.rglru_cache_init(b, cfg.rglru)
        elif kind == "rwkv6":
            c = {
                "S": jnp.zeros(
                    (b, cfg.rwkv.n_heads, cfg.rwkv.d_head, cfg.rwkv.d_head),
                    jnp.float32,
                ),
                "shift": jnp.zeros((b, cfg.d_model), dtype),
            }
        else:
            raise ValueError(kind)
        if cfg.ffn_kind(i) == "rwkv_cmix":
            c["cm_shift"] = jnp.zeros((b, cfg.d_model), dtype)
        layers.append(c)
    return {"layers": layers}


def cache_insert(cache: Params, sub: Params, slots: jax.Array,
                 kv: attn_lib.KVCache | None = None) -> Params:
    """Slot-targeted cache insertion for the continuous-batching scheduler:
    write a (G,)-batch CONTIGUOUS prefill cache into G slots of the
    serving batch cache.  ``slots``: (G,) int32 slot indices (traced-safe).

    Contiguous layout: every cache leaf is batch-leading (attention
    k/v/slot_pos, rglru h/conv, rwkv S/shift, cm_shift), so one row
    insertion per leaf covers them all.  The inserted ``slot_pos`` rows
    carry -1 beyond the prompt (init_cache default), which is what retires
    the previous occupant's stale rows — ``nn/attention._mask`` masks
    ``pos < 0``.  Paged layout: the sub-cache's valid rows scatter into
    the slots' mapped blocks (``PagedKVCache.insert``); the allocator's
    pos-reset of freshly mapped blocks replaces the full-slot-overwrite
    invariant."""
    kv = attn_lib.CONTIGUOUS if kv is None else kv
    if isinstance(kv, attn_lib.ContiguousKVCache) and kv.kv_bits is None:
        # fp contiguous: cache and sub are structurally identical pytrees,
        # one tree-mapped row insertion covers every leaf.  Quantized
        # contiguous caches carry scale leaves the fp sub-cache lacks, so
        # they take the per-layer path (kv.insert encodes on the way in).
        return jax.tree.map(
            lambda big, small: attn_lib.insert_rows(big, small, slots),
            cache, sub,
        )
    return {"layers": [kv.insert(lc, sub_lc, slots)
                       for lc, sub_lc in zip(cache["layers"], sub["layers"])]}


def cache_reset(cfg: LMConfig, cache: Params, slot: jax.Array,
                kv: attn_lib.KVCache | None = None) -> Params:
    """Retire one serving slot: attention rows become invisible
    (``slot_pos = -1`` / table row unmapped, via ``kv.reset``) and
    recurrent state rows are zeroed.

    Contiguous layout: this is hygiene, not the safety mechanism — the
    shape-static decode step keeps writing the retired slot's junk k/v
    each step, and ``fill`` stores those with VISIBLE positions (>= 0).
    What actually protects the next occupant is :func:`cache_insert`
    overwriting the ENTIRE slot (all rows, recurrent state included) at
    admission — do not weaken that to a partial insert.  Paged layout:
    junk writes would land in POOL blocks that may already belong to
    another slot, so the scheduler additionally write-masks retired rows
    (``decode_step(..., write_mask=active)``)."""
    kv = attn_lib.CONTIGUOUS if kv is None else kv
    layers = []
    for i, lc in enumerate(cache["layers"]):
        lc = dict(lc)
        kind = cfg.mixer_kind(i)
        if kind == "attn":
            lc.update(kv.reset(lc, slot))
        elif kind == "local_attn":
            lc.update(attn_lib.CONTIGUOUS.reset(lc, slot))
        elif kind == "rglru":
            lc["h"] = attn_lib.zero_rows(lc["h"], slot)
            lc["conv"] = attn_lib.zero_rows(lc["conv"], slot)
        elif kind == "rwkv6":
            lc["S"] = attn_lib.zero_rows(lc["S"], slot)
            lc["shift"] = attn_lib.zero_rows(lc["shift"], slot)
        if "cm_shift" in lc:
            lc["cm_shift"] = attn_lib.zero_rows(lc["cm_shift"], slot)
        layers.append(lc)
    return {"layers": layers}


def cache_truncate(cfg: LMConfig, cache: Params, lengths: jax.Array,
                   kv: attn_lib.KVCache | None = None) -> Params:
    """Per-row KV rollback for speculative decode: row ``b`` keeps its
    first ``lengths[b]`` token positions, everything at ``pos >=
    lengths[b]`` becomes invisible again (``kv.truncate`` per attention
    layer).  Rows whose cache is already shorter are no-ops, so one
    batchwide jitted call covers ragged accept lengths.  Pure-attention
    stacks only — recurrent state has no per-position rollback story
    (the same restriction as :func:`decode_window`)."""
    kv = attn_lib.CONTIGUOUS if kv is None else kv
    layers = []
    for i, lc in enumerate(cache["layers"]):
        if cfg.mixer_kind(i) != "attn":
            raise ValueError(
                f"cache_truncate supports pure-attention stacks; layer "
                f"{i} is {cfg.mixer_kind(i)!r}")
        layers.append({**lc, **kv.truncate(lc, lengths)})
    return {"layers": layers}


def decode_step(
    params: Params,
    cfg: LMConfig,
    ctx: QCtx,
    cache: Params,
    tokens: jax.Array,  # (B, 1)
    pos: jax.Array,  # (B,) absolute position of this token
    kv: attn_lib.KVCache | None = None,
    write_mask: jax.Array | None = None,
) -> tuple[jax.Array, Params]:
    """One token for every sequence in the batch.  Returns (logits, cache).

    ``kv`` selects the attention cache layout; ``write_mask`` (B,) bool
    drops cache writes for inactive batch rows (required on the paged
    layout, where recycled blocks make junk writes unsafe)."""
    x = params["embed"]["table"].astype(ctx.compute_dtype)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, ctx.compute_dtype)
    if cfg.embed_norm:
        x = norm_apply(cfg.norm, params["embed_ln"], x)

    new_layers = []
    for i, blk in enumerate(params["layers"]):
        path = f"layers/{i}"
        lc = dict(cache["layers"][i])
        h = norm_apply(cfg.norm, blk["pre_norm"], x)
        kind = cfg.mixer_kind(i)
        if kind in ("attn", "local_attn"):
            acfg = cfg.attn if kind == "attn" else cfg.local_attn
            h, ac = attn_lib.attn_decode(
                blk["attn"], h, pos, lc, acfg, ctx, f"{path}/attn",
                kv=kv if kind == "attn" else None, write_mask=write_mask,
            )
            lc.update(ac)
        elif kind == "rglru":
            h, rc = rglru_lib.rglru_decode(
                blk["rglru"], h, lc, cfg.rglru, ctx, f"{path}/rglru"
            )
            lc.update(rc)
        elif kind == "rwkv6":
            h, tc = rwkv_lib.timemix_decode(
                blk["tmix"], h,
                {"S": lc["S"], "shift": lc["shift"]},
                cfg.rwkv, ctx, f"{path}/tmix",
            )
            lc.update(tc)
        if cfg.post_norm:
            h = norm_apply(cfg.norm, blk["post_mixer_norm"], h)
        x = x + h

        h = norm_apply(cfg.norm, blk["pre_ffn_norm"], x)
        fkind = cfg.ffn_kind(i)
        if fkind == "rwkv_cmix":
            h = rwkv_lib.chanmix_forward(
                blk["cmix"], h, cfg.rwkv, ctx, f"{path}/cmix",
                shift_state=lc["cm_shift"],
            )
            lc["cm_shift"] = norm_apply(
                cfg.norm, blk["pre_ffn_norm"], x
            )[:, 0].astype(lc["cm_shift"].dtype)
        else:
            h, _ = _ffn_forward(blk, i, h, cfg, ctx, path)
        if cfg.post_norm:
            h = norm_apply(cfg.norm, blk["post_ffn_norm"], h)
        x = x + h
        new_layers.append(lc)

    logits = _logits(params, cfg, ctx, x)
    return logits, {"layers": new_layers}


def decode_window(
    params: Params,
    cfg: LMConfig,
    ctx: QCtx,
    cache: Params,
    tokens: jax.Array,  # (B, C)
    pos_start: jax.Array,  # (B,) absolute position of each row's first token
    kv: attn_lib.KVCache,
    write_mask: jax.Array | None = None,
    logits_all: bool = False,
) -> tuple[jax.Array, Params]:
    """A C-token window for every batch row against the cache: the
    serving primitive behind chunked prefill, paged decode (C == 1), AND
    the speculative verify pass (``logits_all=True``).

    Each row's tokens sit at positions ``pos_start[b] + [0..C)``; their
    k/v are stored through ``kv.fill_window`` and attention runs over the
    full gathered cache, so a chunk attends to everything already cached
    for its slot (earlier chunks, refcounted shared-prefix blocks) plus
    itself.  Rows with ``write_mask=False`` (idle or decoding slots while
    another row prefills) compute junk and write nothing.  Pure-attention
    stacks only.  Returns LAST-position logits (B, 1, V) — the only ones
    admission samples from — and the updated cache; ``logits_all=True``
    returns every position's logits (B, C, V) instead, which is how the
    speculative target scores all C proposed continuations in ONE call
    (logit row c conditions on window tokens <= c via the causal mask —
    exactly the sequential decode distribution at each position)."""
    b, c = tokens.shape
    x = params["embed"]["table"].astype(ctx.compute_dtype)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, ctx.compute_dtype)
    if cfg.embed_norm:
        x = norm_apply(cfg.norm, params["embed_ln"], x)
    positions = pos_start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]

    new_layers = []
    for i, blk in enumerate(params["layers"]):
        path = f"layers/{i}"
        if cfg.mixer_kind(i) != "attn":
            raise ValueError(
                f"decode_window supports pure-attention stacks; layer {i} "
                f"is {cfg.mixer_kind(i)!r}")
        lc = dict(cache["layers"][i])
        h = norm_apply(cfg.norm, blk["pre_norm"], x)
        h, ac = attn_lib.attn_window(
            blk["attn"], h, positions, lc, cfg.attn, ctx, f"{path}/attn",
            kv, write_mask=write_mask,
        )
        lc.update(ac)
        if cfg.post_norm:
            h = norm_apply(cfg.norm, blk["post_mixer_norm"], h)
        x = x + h

        h = norm_apply(cfg.norm, blk["pre_ffn_norm"], x)
        h, _ = _ffn_forward(blk, i, h, cfg, ctx, path)
        if cfg.post_norm:
            h = norm_apply(cfg.norm, blk["post_ffn_norm"], h)
        x = x + h
        new_layers.append(lc)

    logits = _logits(params, cfg, ctx, x if logits_all else x[:, -1:, :])
    return logits, {"layers": new_layers}


def prefill(
    params: Params,
    cfg: LMConfig,
    ctx: QCtx,
    tokens: jax.Array,
    cache_len: int,
    vision_embeds: jax.Array | None = None,
) -> tuple[jax.Array, Params]:
    """Process the prompt, build the cache, return last-position logits.

    Implemented as forward + cache extraction for attention layers and a
    state-producing pass for recurrent layers.  For simplicity and
    numerical parity we rerun the mixers' state-producing variants.
    """
    x = _embed(params, cfg, ctx, tokens, vision_embeds)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    cache = init_cache(cfg, b, cache_len, ctx.compute_dtype)

    for i, blk in enumerate(params["layers"]):
        path = f"layers/{i}"
        lc = cache["layers"][i]
        h = norm_apply(cfg.norm, blk["pre_norm"], x)
        kind = cfg.mixer_kind(i)
        if kind in ("attn", "local_attn"):
            acfg = cfg.attn if kind == "attn" else cfg.local_attn
            q, k, v = attn_lib._project_qkv(
                blk["attn"], h, positions, acfg, ctx, f"{path}/attn"
            )
            cache["layers"][i] = {
                **lc, **attn_lib.CONTIGUOUS.fill(lc, k, v, positions)}
            qg = q.reshape(b, s, acfg.n_kv_heads, acfg.groups, acfg.d_head)
            if s <= acfg.full_attn_max_seq:
                out = attn_lib._sdpa(acfg, qg, k, v,
                                     attn_lib._mask(acfg, positions, positions))
            else:
                out = attn_lib._sdpa_chunked(acfg, qg, k, v, positions, positions)
            out = out.reshape(b, s, acfg.n_heads * acfg.d_head)
            h = ctx.dense(blk["attn"]["o"], out.astype(ctx.compute_dtype),
                          f"{path}/attn/o")
        elif kind == "rglru":
            h, state = _rglru_prefill(blk["rglru"], h, cfg.rglru, ctx,
                                      f"{path}/rglru")
            cache["layers"][i] = {**lc, **state}
        elif kind == "rwkv6":
            h, state = _rwkv_prefill(blk["tmix"], h, cfg.rwkv, ctx,
                                     f"{path}/tmix")
            cache["layers"][i] = {**lc, **state}
        if cfg.post_norm:
            h = norm_apply(cfg.norm, blk["post_mixer_norm"], h)
        x = x + h

        hf = norm_apply(cfg.norm, blk["pre_ffn_norm"], x)
        if cfg.ffn_kind(i) == "rwkv_cmix":
            cache["layers"][i]["cm_shift"] = hf[:, -1].astype(ctx.compute_dtype)
            h = rwkv_lib.chanmix_forward(blk["cmix"], hf, cfg.rwkv, ctx,
                                         f"{path}/cmix")
        else:
            h, _ = _ffn_forward(blk, i, hf, cfg, ctx, path)
        if cfg.post_norm:
            h = norm_apply(cfg.norm, blk["post_ffn_norm"], h)
        x = x + h

    logits = _logits(params, cfg, ctx, x[:, -1:, :])
    return logits, cache


def _rglru_prefill(p, x, rcfg, ctx, path):
    """rglru forward + final state (recompute conv tail + h)."""
    y = rglru_lib.rglru_forward(p, x, rcfg, ctx, path)
    # final hidden state: rerun gates on the last conv output
    u = ctx.dense(p["in_x"], x, f"{path}/in_x")
    u_c = rglru_lib._conv_train(p, u)
    a, bterm = rglru_lib._gates(p, u_c)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, bterm), axis=1)
    state = {
        "h": h[:, -1],
        "conv": u[:, -(rcfg.conv_width - 1):, :].astype(jnp.float32),
    }
    return y, state


def _rwkv_prefill(p, x, rcfg, ctx, path):
    xx = rwkv_lib._shift_train(x) - x
    r, k, v, lw, g = rwkv_lib._timemix_pre(p, x, xx, rcfg, ctx, path)
    u = p["bonus_u"].astype(jnp.float32)
    b = x.shape[0]
    s0 = jnp.zeros((b, rcfg.n_heads, rcfg.d_head, rcfg.d_head), jnp.float32)
    y, s_fin = rwkv_lib._wkv_chunked(r, k, v, lw, u, s0, rcfg.chunk, ctx)
    y = rwkv_lib._group_norm(p["gn"], y, rcfg.n_heads, rcfg.d_head)
    y = (y.astype(ctx.compute_dtype)) * g
    out = ctx.dense(p["o"], y, f"{path}/o")
    state = {"S": s_fin, "shift": x[:, -1].astype(ctx.compute_dtype)}
    return out, state
