"""Small jax version-compat shims.

The codebase targets the modern jax surface (``jax.shard_map`` with
``check_vma``, two-argument ``AbstractMesh``); this module papers over the
renames so the same code runs on the 0.4.x series installed here.
"""

from __future__ import annotations

import jax
from jax.sharding import AbstractMesh


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the ``check_vma`` knob on any jax version
    (older releases call it ``check_rep`` and live in jax.experimental)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def abstract_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> AbstractMesh:
    """``AbstractMesh(shape, axes)`` across the signature change (older jax
    takes one tuple of (name, size) pairs)."""
    try:
        return AbstractMesh(shape, axes)
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))
