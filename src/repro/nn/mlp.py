"""Feed-forward blocks: gated MLP (llama/gemma family), plain MLP (whisper),
and MoE with shared + routed experts (deepseek-moe / qwen2-moe).

MoE dispatch is sort-based ragged grouping: tokens are argsorted by expert,
contracted against the stacked expert weights, and scattered back with
their gate weights.  Fake-quant training contracts with
`jax.lax.ragged_dot`; packed serving keeps the expert stacks bit-packed
and contracts with `kernels.dispatch.quant_gemm_grouped` (the batched
xnor kernels).  The router always stays full precision (policy
fp_patterns include "router"); expert GEMMs quantize like any other GEMM
(see DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import qlayers, quant
from repro.kernels import dispatch
from repro.nn.common import ACTIVATIONS, QCtx

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    d_model: int
    d_ff: int
    act: str = "silu"
    gated: bool = True


def mlp_init(key, cfg: MLPConfig, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    p = {
        "up": qlayers.dense_init(ks[0], cfg.d_model, cfg.d_ff, dtype=dtype),
        "down": qlayers.dense_init(ks[1], cfg.d_ff, cfg.d_model, dtype=dtype),
    }
    if cfg.gated:
        p["gate"] = qlayers.dense_init(ks[2], cfg.d_model, cfg.d_ff, dtype=dtype)
    return p


def mlp_apply(params: Params, x, cfg: MLPConfig, ctx: QCtx, path: str):
    act = ACTIVATIONS[cfg.act]
    up = ctx.dense(params["up"], x, f"{path}/up")
    if cfg.gated:
        gate = ctx.dense(params["gate"], x, f"{path}/gate")
        h = act(gate) * up
    else:
        h = act(up)
    return ctx.dense(params["down"], h, f"{path}/down")


# --------------------------------------------------------------------------
# MoE
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_expert: int  # per-expert FFN width (fine-grained)
    n_routed: int
    n_shared: int
    top_k: int
    act: str = "silu"
    n_routed_padded: int | None = None  # pad experts for EP divisibility
    router_scale_norm: bool = True  # normalise top-k gate weights to sum 1

    @property
    def e(self) -> int:
        return self.n_routed_padded or self.n_routed


def moe_init(key, cfg: MoEConfig, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    e, d, f = cfg.e, cfg.d_model, cfg.d_expert
    std_in, std_f = d**-0.5, f**-0.5
    p: Params = {
        "router": qlayers.dense_init(ks[0], d, e, dtype=dtype),
        "experts": {
            "up": jax.random.normal(ks[1], (e, d, f), dtype) * std_in,
            "gate": jax.random.normal(ks[2], (e, d, f), dtype) * std_in,
            "down": jax.random.normal(ks[3], (e, f, d), dtype) * std_f,
        },
    }
    if cfg.n_shared:
        shared_cfg = MLPConfig(d, cfg.d_expert * cfg.n_shared, cfg.act)
        p["shared"] = mlp_init(ks[4], shared_cfg, dtype=dtype)
    return p


def _router_probs(params, x2, cfg: MoEConfig, ctx: QCtx, path: str):
    """(T, E) probs — router forced fp by policy; padded experts masked."""
    logits = ctx.dense(params["router"], x2, f"{path}/router")
    logits = logits.astype(jnp.float32)
    if cfg.n_routed_padded and cfg.n_routed_padded > cfg.n_routed:
        pad_mask = jnp.arange(cfg.e) >= cfg.n_routed
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    return jax.nn.softmax(logits, axis=-1)


def _expert_quant(w, ctx: QCtx, path: str):
    spec = ctx.policy.spec(path)
    if spec.is_fp:
        return w.astype(ctx.compute_dtype)
    return quant.quantize_weight(w.astype(jnp.float32), spec.w_bits).astype(
        ctx.compute_dtype
    )


def _expert_weights(experts: Params, ctx: QCtx, path: str) -> Params:
    """Expert weight bundle — packed-serving aware.

    Fake-quant: ``{"up"/"gate": (E, D, F), "down": (E, F, D)}`` quantized
    stacks for ``lax.ragged_dot``.  Packed serving: the converter's
    ``{name}_packed`` uint32 stacks ``(E, d_out, Kw)`` pass through
    UNTOUCHED — the contraction runs on the packed xnor kernels via
    ``dispatch.quant_gemm_grouped``, so only packed words cross HBM (the
    32x-traffic part of the paper's insight; daBNN makes the same point).
    """
    if "up_packed" in experts:
        return {k: v for k, v in experts.items() if k.endswith("_packed")}
    return {
        name: _expert_quant(experts[name], ctx, path)
        for name in ("up", "gate", "down")
    }


def _moe_compute_local(xs_q, gate_w, gate_idx, ew, cfg: MoEConfig, spec,
                       compute_dtype, gemm_config, e_base, e_count,
                       capacity: int | None):
    """Sort-based ragged expert compute over experts [e_base, e_base+e_count).

    Runs either globally (single device; e_base=0, e_count=E) or per model
    shard inside shard_map (EP).  ``ew`` is the `_expert_weights` bundle:
    fake-quant stacks contract with ``lax.ragged_dot``; packed stacks go
    through the grouped packed GEMM.  Returns the weighted scatter-add
    (T, D).
    """
    t, d = xs_q.shape
    k = gate_idx.shape[1]
    flat_e = gate_idx.reshape(-1)
    e_local = flat_e - e_base
    owned = (e_local >= 0) & (e_local < e_count)
    sort_key = jnp.where(owned, e_local, e_count)  # non-owned last
    order = jnp.argsort(sort_key)
    cap = capacity if capacity is not None else t * k
    sel = order[:cap]
    tok_of = sel // k
    xs = xs_q[tok_of]  # (cap, D)

    gs_full = jnp.bincount(sort_key, length=e_count + 1)[:e_count]
    cum = jnp.cumsum(gs_full)
    gs = (jnp.clip(cum, 0, cap)
          - jnp.clip(cum - gs_full, 0, cap)).astype(jnp.int32)

    act = ACTIVATIONS[cfg.act]
    if "up_packed" in ew:
        # packed serving: rows stay sorted, weights stay bit-packed; the
        # dispatch layer buckets rows per expert and runs the batched
        # xnor / bit-plane kernel (or lowers to ragged_dot on the "xla"
        # backend).  The spec's bit widths route 1-bit stacks to the xnor
        # kernels and k-bit plane stacks to the DoReFa plane kernels.
        hu, hg = dispatch.quant_gemm_grouped(
            xs.astype(jnp.float32), (ew["up_packed"], ew["gate_packed"]),
            gs, k_true=d, config=gemm_config, out_dtype=jnp.float32,
            w_bits=spec.w_bits, a_bits=spec.a_bits)
        h = act(hg) * hu
        ye = dispatch.quant_gemm_grouped(
            h, ew["down_packed"], gs, k_true=cfg.d_expert,
            config=gemm_config, out_dtype=compute_dtype,
            w_bits=spec.w_bits, a_bits=spec.a_bits)
    else:
        hu = jax.lax.ragged_dot(xs, ew["up"], gs)
        hg = jax.lax.ragged_dot(xs, ew["gate"], gs)
        h = act(hg) * hu
        if not spec.is_fp:
            h = quant.quantize_act(h.astype(jnp.float32), spec.a_bits).astype(
                compute_dtype
            )
        ye = jax.lax.ragged_dot(h, ew["down"], gs)  # (cap, D)

    w_sel = gate_w.reshape(-1)[sel]
    w_sel = jnp.where(owned[sel], w_sel, 0.0).astype(ye.dtype)
    return jnp.zeros((t, d), ye.dtype).at[tok_of].add(ye * w_sel[:, None])


def moe_apply(
    params: Params, x, cfg: MoEConfig, ctx: QCtx, path: str
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux load-balance loss scalar).

    Distribution: with ``ctx.mesh`` set, experts are EP-sharded over
    'model' and dispatch runs inside ``shard_map`` — each (data x model)
    shard sorts ITS tokens for ITS experts locally and the partial outputs
    psum over 'model'.  No token all-to-all, and crucially no global
    argsort under GSPMD (the auto-partitioned sort replicated everything:
    measured 70 s/step of collectives on deepseek-moe train_4k)."""
    b, s, d = x.shape
    t = b * s
    x2 = x.reshape(t, d)

    probs = _router_probs(params, x2, cfg, ctx, path)  # (T, E)
    gate_w, gate_idx = jax.lax.top_k(probs, cfg.top_k)  # (T, K)
    if cfg.router_scale_norm:
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
    flat_e = gate_idx.reshape(-1)

    spec = ctx.policy.spec(f"{path}/experts")
    a_q = (
        quant.quantize_act(x2.astype(jnp.float32), spec.a_bits)
        if not spec.is_fp
        else x2
    ).astype(ctx.compute_dtype)

    ew = _expert_weights(params["experts"], ctx, f"{path}/experts")

    mesh = ctx.mesh
    use_ep = (
        mesh is not None
        and "model" in mesh.axis_names
        and cfg.e % dict(mesh.shape)["model"] == 0
    )
    if not use_ep:
        y = _moe_compute_local(a_q, gate_w, gate_idx, ew, cfg, spec,
                               ctx.compute_dtype, ctx.gemm_config,
                               0, cfg.e, None)
    else:
        from jax.sharding import PartitionSpec as P

        msize = dict(mesh.shape)["model"]
        e_loc = cfg.e // msize
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        n_dp = 1
        for a in dp:
            n_dp *= dict(mesh.shape)[a]
        t_loc = t // n_dp if t % n_dp == 0 else t
        # capacity_factor x load-balance slack over the balanced share
        # (GemmConfig.capacity_factor, default 2.0) — overflow rows drop,
        # and the grouped prologue never quantizes/packs dropped rows
        cf = ctx.gemm_config.capacity_factor
        cf = 2.0 if cf is None else cf  # explicit 0.0 must not mean unset
        # (the 64-row floor below still applies at tiny factors)
        cap = min(max(int(cf * t_loc * cfg.top_k) // msize, 64),
                  t_loc * cfg.top_k)

        # inside the EP shard_map body the GEMMs must run single-device:
        # a shard-* backend would nest a second shard_map over the same
        # mesh (dispatch.unsharded strips the family to its inner kernel)
        gemm_config = dispatch.unsharded(ctx.gemm_config)

        def local(xq, gw, gi, ew_loc):
            mi = jax.lax.axis_index("model")
            y_part = _moe_compute_local(
                xq, gw, gi, ew_loc, cfg, spec, ctx.compute_dtype,
                gemm_config, mi * e_loc, e_loc, cap)
            return jax.lax.psum(y_part, "model")

        dspec = P(dp if dp else None)
        from repro.compat import shard_map

        y = shard_map(
            local, mesh=mesh,
            in_specs=(dspec, dspec, dspec, P("model")),
            out_specs=dspec,
            check_vma=False,
        )(a_q, gate_w, gate_idx, ew)

    # ---- shared experts + aux loss ---------------------------------------
    if "shared" in params:
        shared_cfg = MLPConfig(d, cfg.d_expert * cfg.n_shared, cfg.act)
        y = y + mlp_apply(params["shared"], x, shared_cfg, ctx, f"{path}/shared").reshape(t, d)

    # Switch-style load-balance aux: E * sum_e f_e * p_e
    me = probs.mean(0)  # (E,)
    ce = jnp.zeros((cfg.e,), jnp.float32).at[flat_e].add(1.0) / (t * cfg.top_k)
    aux = cfg.n_routed * jnp.sum(me * ce)

    return y.reshape(b, s, d).astype(ctx.compute_dtype), aux
