"""Shared NN building blocks: norms, RoPE, activations, the QCtx handle.

Every internal GEMM in every model goes through ``QCtx.dense`` so the
BMXNet quantization policy (core/policy.py) applies uniformly across the
whole architecture pool.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import qlayers
from repro.core.policy import QuantPolicy
from repro.kernels.dispatch import GemmConfig

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class QCtx:
    """Carries the quantization policy + compute dtype through a model.

    ``gemm_config``: how every packed GEMM executes (backend + tile
    overrides) — threaded into ``kernels/dispatch`` by every layer.  The
    legacy ``xnor_backend="vpu"`` string is accepted as a constructor
    alias and folded into ``gemm_config``.

    ``mesh`` (optional): the physical mesh, enabling shard_map-based layers
    (MoE expert parallelism).  None on single-device runs -> pure-jnp paths.
    When a tensor-parallel ``shard-*`` GEMM backend is configured without
    its own ``GemmConfig.mesh``, this mesh is threaded into the config so
    every layer's packed GEMM shards over it.
    """

    policy: QuantPolicy
    compute_dtype: Any = jnp.bfloat16
    gemm_config: GemmConfig = GemmConfig()
    mesh: Any = None
    xnor_backend: str | None = None  # legacy alias for gemm_config.backend

    def __post_init__(self):
        if self.xnor_backend is not None:
            object.__setattr__(
                self, "gemm_config",
                dataclasses.replace(self.gemm_config,
                                    backend=self.xnor_backend),
            )
            # clear the alias once folded in, so dataclasses.replace(ctx,
            # gemm_config=...) cannot silently re-apply a stale backend
            object.__setattr__(self, "xnor_backend", None)
        if (
            self.mesh is not None
            and self.gemm_config.mesh is None
            and self.gemm_config.backend.startswith("shard-")
        ):
            object.__setattr__(
                self, "gemm_config",
                dataclasses.replace(self.gemm_config, mesh=self.mesh),
            )

    def dense(self, params: Params, x: jax.Array, path: str) -> jax.Array:
        return qlayers.qdense(
            params,
            x,
            self.policy.spec(path),
            compute_dtype=self.compute_dtype,
            gemm_config=self.gemm_config,
        )

    def conv(self, params: Params, x: jax.Array, path: str, **kw) -> jax.Array:
        return qlayers.qconv(
            params,
            x,
            self.policy.spec(path),
            compute_dtype=self.compute_dtype,
            gemm_config=self.gemm_config,
            **kw,
        )


def fp_ctx(compute_dtype=jnp.bfloat16) -> QCtx:
    return QCtx(policy=QuantPolicy.full_precision(), compute_dtype=compute_dtype)


def shard_heads(x: jax.Array, ctx: QCtx) -> jax.Array:
    """Constrain (B, S, H, Dh) to head-sharding over 'model' when possible.

    Used to pin *derived* per-head tensors (e.g. RWKV's data-dependent
    decay, which flows from replicated LoRA weights) to the layout of the
    projected r/k/v — otherwise GSPMD resolves the mixed-layout einsums by
    all-gathering the projections (measured 192 GiB/step on rwkv6-7b
    prefill_32k)."""
    mesh = ctx.mesh
    if mesh is None or "model" not in mesh.axis_names:
        return x
    sizes = dict(mesh.shape)
    if x.ndim != 4 or x.shape[2] % sizes["model"]:
        return x
    import math

    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if dp and x.shape[0] % math.prod(sizes[a] for a in dp):
        dp = ()
    spec = P(dp if dp else None, None, "model", None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dt)


def norm_init(kind: str, d: int) -> Params:
    return rmsnorm_init(d) if kind == "rmsnorm" else layernorm_init(d)


def norm_apply(kind: str, params: Params, x: jax.Array) -> jax.Array:
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (B, S, H, Dh); positions: (B, S) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
}


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"table": jax.random.normal(key, (vocab, d), dtype)}


def embed_lookup(params: Params, tokens: jax.Array, compute_dtype) -> jax.Array:
    return params["table"].astype(compute_dtype)[tokens]


def sincos_positions(seq: int, d: int, max_ts: float = 10000.0) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (seq, d)."""
    half = d // 2
    freq = jnp.exp(-jnp.log(max_ts) * jnp.arange(half) / (half - 1))
    ang = jnp.arange(seq)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
