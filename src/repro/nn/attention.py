"""Attention in all the variants the assigned pool needs.

One implementation covers: MHA/GQA/MQA (grouped einsum — KV is never
materialised per-query-head), causal / bidirectional / sliding-window /
alternating local-global, attn-logit softcapping (gemma2), QKV bias (qwen2),
RoPE, cross-attention (whisper), KV-cache decode with per-batch positions
(ring buffer for local layers), and a chunked online-softmax path for long
prefill (32k) where materialising (S, S) scores would blow HBM.

All projections run through ``QCtx.dense`` => they obey the BMXNet
quantization policy like every other GEMM in the framework.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import qlayers
from repro.kernels import attn_decode as attn_kernels
from repro.kernels.attn_decode import (kv_code_shapes, kv_dequantize,
                                       kv_quantize)
from repro.nn.common import QCtx, rope, softcap

Params = dict[str, Any]

NEG_INF = -2.0e38


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    rope_theta: float = 10000.0
    use_rope: bool = True
    qkv_bias: bool = False
    logit_softcap: float | None = None
    window: int | None = None  # sliding window; None = global
    causal: bool = True
    query_scale: float | None = None  # default d_head ** -0.5
    # chunked-path knobs
    full_attn_max_seq: int = 4096
    chunk_q: int = 512
    chunk_kv: int = 1024
    # decode-attention execution (serving): route attn_decode/attn_window
    # through the Pallas flash-decode kernel (kernels/attn_decode.py)
    # instead of gather + _sdpa.  False keeps the gather path — the
    # oracle the fused kernel is CI-gated against (the fused_prologue
    # idiom).  Cross-attention reads always stay on the gather path.
    fused_attn: bool = False
    # KV-cache storage tier: None = fp compute dtype; 8 = int8 codes +
    # per-(head, dh-group) absmax scales; 1 = packed sign bytes + per-head
    # alpha (the XNOR tier).  The KVCache layout carrying the same value
    # quantises on write; gather() dequantises, so the oracle path reads
    # the identical quantized pool.
    kv_bits: int | None = None

    @property
    def groups(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def scale(self) -> float:
        return self.query_scale if self.query_scale is not None else self.d_head**-0.5


def attn_init(key: jax.Array, cfg: AttnConfig, *, dtype=jnp.float32) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    h, kvh, dh, d = cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_model
    return {
        "q": qlayers.dense_init(kq, d, h * dh, bias=cfg.qkv_bias, dtype=dtype),
        "k": qlayers.dense_init(kk, d, kvh * dh, bias=cfg.qkv_bias, dtype=dtype),
        "v": qlayers.dense_init(kv, d, kvh * dh, bias=cfg.qkv_bias, dtype=dtype),
        "o": qlayers.dense_init(ko, h * dh, d, dtype=dtype),
    }


def _project_qkv(params, x, positions, cfg: AttnConfig, ctx: QCtx, path: str):
    b, s, _ = x.shape
    q = ctx.dense(params["q"], x, f"{path}/q").reshape(b, s, cfg.n_heads, cfg.d_head)
    k = ctx.dense(params["k"], x, f"{path}/k").reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = ctx.dense(params["v"], x, f"{path}/v").reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask(cfg: AttnConfig, q_pos, k_pos):
    """(..., Sq, Sk) bool validity mask from absolute positions."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if cfg.causal:
        m &= kp <= qp
    if cfg.window is not None:
        m &= kp > qp - cfg.window
    m &= kp >= 0  # empty cache slots carry position -1
    return m


def _sdpa(cfg: AttnConfig, q, k, v, mask):
    """Grouped scaled-dot-product attention with softcap.

    q: (B, Sq, KVH, G, Dh); k, v: (B, Sk, KVH, Dh); mask: (B, Sq, Sk) bool.
    Returns (B, Sq, KVH, G, Dh).
    """
    scores = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32
    ) * cfg.scale
    scores = softcap(scores, cfg.logit_softcap)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)


def _sdpa_chunked(cfg: AttnConfig, q, k, v, q_pos, k_pos):
    """Online-softmax attention, O(chunk_q * chunk_kv) score memory.

    Same signature/semantics as _sdpa but mask is derived from positions and
    both sequence axes are processed in chunks (flash-attention recurrence in
    pure jnp; the Pallas variant is a §Perf item).
    """
    b, sq, kvh, g, dh = q.shape
    sk = k.shape[1]
    cq, ck = min(cfg.chunk_q, sq), min(cfg.chunk_kv, sk)
    assert sq % cq == 0, (sq, cq)
    if sk % ck:  # pad KV to a chunk multiple; pad slots masked via pos=-1
        pad = ck - sk % ck
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
        sk += pad
    nq, nk = sq // cq, sk // ck

    qc = q.reshape(b, nq, cq, kvh, g, dh).transpose(1, 0, 2, 3, 4, 5)
    qpc = q_pos.reshape(b, nq, cq).transpose(1, 0, 2)
    kc = k.reshape(b, nk, ck, kvh, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nk, ck, kvh, dh).transpose(1, 0, 2, 3, 4)
    kpc = k_pos.reshape(b, nk, ck).transpose(1, 0, 2)

    def q_block(carry, qb):
        qi, qp = qb

        def kv_block(st, kb):
            m_run, l_run, acc = st
            ki, vi, kp = kb
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qi, ki, preferred_element_type=jnp.float32
            ) * cfg.scale
            s = softcap(s, cfg.logit_softcap)
            valid = _mask(cfg, qp, kp)  # (b, cq, ck)
            s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vi.dtype), vi
            ).astype(jnp.float32)
            return (m_new, l_new, acc), None

        init = (
            jnp.full((b, kvh, g, cq), NEG_INF, jnp.float32),
            jnp.zeros((b, kvh, g, cq), jnp.float32),
            jnp.zeros((b, kvh, g, cq, dh), jnp.float32),
        )
        (m_f, l_f, acc), _ = jax.lax.scan(kv_block, init, (kc, vc, kpc))
        out = acc / jnp.maximum(l_f, 1e-37)[..., None]
        return carry, out.transpose(0, 3, 1, 2, 4)  # (b, cq, kvh, g, dh)

    _, outs = jax.lax.scan(q_block, None, (qc, qpc))  # (nq, b, cq, kvh, g, dh)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, kvh, g, dh)


def attn_forward(
    params: Params,
    x: jax.Array,  # (B, S, D)
    positions: jax.Array,  # (B, S)
    cfg: AttnConfig,
    ctx: QCtx,
    path: str,
    *,
    kv: tuple[jax.Array, jax.Array] | None = None,  # cross-attention K/V src
    kv_positions: jax.Array | None = None,
) -> jax.Array:
    """Full-sequence forward (training / prefill)."""
    b, s, _ = x.shape
    if kv is None:
        q, k, v = _project_qkv(params, x, positions, cfg, ctx, path)
        k_pos = positions
    else:
        q = ctx.dense(params["q"], x, f"{path}/q").reshape(
            b, s, cfg.n_heads, cfg.d_head
        )
        if cfg.use_rope:
            q = rope(q, positions, cfg.rope_theta)
        k, v = kv
        k_pos = kv_positions

    qg = q.reshape(b, s, cfg.n_kv_heads, cfg.groups, cfg.d_head)
    if max(s, k.shape[1]) <= cfg.full_attn_max_seq:
        mask = _mask(cfg, positions, k_pos)
        out = _sdpa(cfg, qg, k, v, mask)
    else:
        out = _sdpa_chunked(cfg, qg, k, v, positions, k_pos)
    out = out.reshape(b, s, cfg.n_heads * cfg.d_head).astype(ctx.compute_dtype)
    return ctx.dense(params["o"], out, f"{path}/o")


def cross_kv(
    params: Params, enc: jax.Array, cfg: AttnConfig, ctx: QCtx, path: str
):
    """Project encoder output to K/V once (whisper prefill)."""
    b, t, _ = enc.shape
    k = ctx.dense(params["k"], enc, f"{path}/k").reshape(b, t, cfg.n_kv_heads, cfg.d_head)
    v = ctx.dense(params["v"], enc, f"{path}/v").reshape(b, t, cfg.n_kv_heads, cfg.d_head)
    return k, v


# --------------------------------------------------------------------------
# KV cache (decode) — one KVCache API, two layouts
# --------------------------------------------------------------------------


def insert_rows(big: jax.Array, small: jax.Array, slots: jax.Array) -> jax.Array:
    """Write the G leading rows of ``small`` into batch rows ``slots`` of
    ``big`` (both batch-leading; ``slots``: (G,) int32, traced-safe).  The
    per-slot building block of the continuous-batching scheduler's cache
    insertion (``ContiguousKVCache.insert`` tree-maps this over every
    cache leaf)."""
    for g in range(small.shape[0]):
        big = jax.lax.dynamic_update_slice_in_dim(
            big, small[g:g + 1].astype(big.dtype), slots[g], axis=0
        )
    return big


def zero_rows(x: jax.Array, slot: jax.Array) -> jax.Array:
    """Zero batch row ``slot`` (recurrent-state reset on slot retirement)."""
    return jax.lax.dynamic_update_slice_in_dim(
        x, jnp.zeros((1,) + x.shape[1:], x.dtype), slot, axis=0
    )


@dataclasses.dataclass(frozen=True)
class KVCache:
    """Layout handle for attention KV caches.

    A KVCache instance is a STATIC descriptor (hashable, jit-closure-safe);
    the cache state itself is a plain dict pytree that flows through the
    jitted serving functions.  Both layouts implement the same surface, so
    model code never branches on which layout is live:

    * ``init(b, cfg, cache_len, dtype)``  -> empty cache pytree
    * ``insert(cache, sub, slots)``       -> write a (G,)-batch prefill
      sub-cache into G batch slots (admission)
    * ``reset(cache, slot)``              -> retire one slot (rows become
      invisible to :func:`_mask`)
    * ``fill(cache, k, v, positions, write_mask=None)`` -> store projected
      k/v at absolute positions
    * ``fill_window(cache, k, v, positions, write_mask=None)`` -> same
      contract but positions are per-row windows ``pos_start[b] + [0..C)``
      at ARBITRARY per-row starts (speculative verify / chunked prefill);
      the paged scatter already handles that, the contiguous layout needs
      a one-hot write instead of its arange-assuming prefill path
    * ``truncate(cache, lengths)``        -> per-row rollback: rows of
      slot ``b`` at positions ``>= lengths[b]`` become invisible to
      :func:`_mask` again (speculative decode rejects a proposed suffix)
    * ``gather(cache)``                   -> ``(k, v, pos)`` dense views
      ``(B, L, KVH, Dh) x2 + (B, L)`` that attention consumes

    Layouts: :class:`ContiguousKVCache` (per-slot (B, L, H, Dh) storage —
    the PR 5 scheduler layout) and :class:`PagedKVCache` (shared block
    pool + per-slot int32 block tables — block-granular allocation and
    refcounted prefix sharing; see serve/engine.py).

    Both layouts optionally store K/V quantized (``kv_bits``: 8 = int8
    codes + per-(head, dh-group) absmax scales, 1 = packed sign bytes +
    per-head alpha — kernels/attn_decode.py owns the codec): ``fill`` /
    ``fill_window`` / ``insert`` quantise the projected fp k/v on write
    (scale leaves ride beside the code leaves through the same one-hot /
    scatter machinery), ``gather`` dequantises, and position/visibility
    bookkeeping (``reset``/``truncate``) is tier-agnostic — it only ever
    touches the position plane.  ``attend`` runs the fused flash-decode
    kernel directly on this layout's own storage (no dense gather; codes
    dequantise per block tile in VMEM) — the ``AttnConfig.fused_attn``
    hot path, with gather + ``_sdpa`` as its oracle."""

    def init(self, b: int, cfg: AttnConfig, cache_len: int,
             dtype=jnp.bfloat16) -> Params:
        raise NotImplementedError

    def insert(self, cache: Params, sub: Params, slots: jax.Array) -> Params:
        raise NotImplementedError

    def reset(self, cache: Params, slot: jax.Array) -> Params:
        raise NotImplementedError

    def fill(self, cache: Params, k, v, positions,
             write_mask: jax.Array | None = None) -> Params:
        raise NotImplementedError

    def fill_window(self, cache: Params, k, v, positions,
                    write_mask: jax.Array | None = None) -> Params:
        """Window write at arbitrary per-row start positions.  The paged
        scatter handles that natively; layouts whose ``fill`` assumes
        aligned prefill positions override this."""
        return self.fill(cache, k, v, positions, write_mask)

    def truncate(self, cache: Params, lengths: jax.Array) -> Params:
        """Roll row ``b`` back to ``lengths[b]`` tokens: positions
        ``>= lengths[b]`` become invisible (and rewritable) again.  Rows
        whose content is already shorter are untouched (no-op), so one
        batchwide call serves ragged speculative accept lengths."""
        raise NotImplementedError

    def gather(self, cache: Params):
        raise NotImplementedError

    def attend(self, cache: Params, q, q_pos, cfg: "AttnConfig",
               interpret: bool | None = None):
        """Fused flash-decode attention straight off this layout's own
        storage (``AttnConfig.fused_attn``); q (B, C, KVH, G, Dh), q_pos
        (B, C) -> (B, C, KVH, G, Dh) fp32.  Value-equivalent to
        ``_sdpa(cfg, q, *gather(cache))`` under :func:`_mask`."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ContiguousKVCache(KVCache):
    """Per-slot contiguous storage: ``k``/``v`` (B, cache_len, KVH, Dh) +
    ``slot_pos`` (B, cache_len) (+ ``k_scale``/``v_scale`` when
    ``kv_bits`` stores codes — base-class docstring).  ``gather`` is free
    for fp (returns the arrays) and a dequant for quantized tiers.
    Local (sliding-window) layers use cache_len == window as a ring."""

    kv_bits: int | None = None

    def _encode(self, k, v) -> Params:
        """Projected fp k/v (B, S, KVH, Dh) -> the storage leaves this
        layout persists for them (codes + scales under ``kv_bits``)."""
        if self.kv_bits is None:
            return {"k": k, "v": v}
        kc, ks = kv_quantize(self.kv_bits, k)
        vc, vs = kv_quantize(self.kv_bits, v)
        return {"k": kc, "k_scale": ks, "v": vc, "v_scale": vs}

    def init(self, b, cfg: AttnConfig, cache_len, dtype=jnp.bfloat16):
        (code, cdt), sc = kv_code_shapes(self.kv_bits, cfg.n_kv_heads,
                                         cfg.d_head, dtype)
        out = {
            "k": jnp.zeros((b, cache_len) + code, cdt),
            "v": jnp.zeros((b, cache_len) + code, cdt),
            "slot_pos": jnp.full((b, cache_len), -1, jnp.int32),
        }
        if sc is not None:
            out["k_scale"] = jnp.zeros((b, cache_len) + sc[0], sc[1])
            out["v_scale"] = jnp.zeros((b, cache_len) + sc[0], sc[1])
        return out

    def insert(self, cache, sub, slots):
        """Batch-row insertion per leaf.  Works on ANY batch-leading cache
        pytree (models tree-map it over attention + recurrent leaves).
        The inserted ``slot_pos`` rows carry -1 beyond the prompt, which
        retires the previous occupant's stale rows.  Quantized tiers
        encode the fp prefill sub-cache's k/v on the way in (the sub-cache
        is always fp contiguous — lm.prefill's scratch layout)."""
        if self.kv_bits is not None and "k_scale" in cache:
            enc = self._encode(sub["k"], sub["v"])
            return {
                name: insert_rows(big, enc.get(name, sub.get(name)), slots)
                for name, big in cache.items()
            }
        return jax.tree.map(
            lambda big, small: insert_rows(big, small, slots), cache, sub
        )

    def reset(self, cache, slot):
        """Retire one batch slot: mark every row of that slot empty
        (``slot_pos = -1``) so :func:`_mask` hides it from future queries.
        K/V bytes are left in place — the next occupant's prefill insertion
        overwrites the whole slot (and carries its own -1 rows past the
        prompt), so stale keys can never become visible again."""
        cache_len = cache["slot_pos"].shape[1]
        slot_pos = jax.lax.dynamic_update_slice(
            cache["slot_pos"], jnp.full((1, cache_len), -1, jnp.int32),
            (slot, 0)
        )
        return {**cache, "slot_pos": slot_pos}

    def fill(self, cache, k, v, positions, write_mask=None):
        """Write to the cache.  k/v: (B, S, KVH, Dh), positions: (B, S).
        Slots are ``pos % cache_len`` (ring for local layers; identity when
        cache_len >= S).  ``write_mask=False`` rows skip the write on the
        S == 1 path; the prefill paths ignore it — storage is slot-
        private, so a junk write from a retired batch row can never leak
        into another request (admission's full-slot ``insert`` overwrite
        is the safety mechanism), the mask is only honored where the
        speculative-decode loop needs idle rows' positions left alone.

        No scatters: scatter onto a model-sharded cache triggers GSPMD
        "involuntary full rematerialization" (the cache gets replicated —
        measured 0.86 s/step of collectives on granite decode_32k).
        Instead:

        * S == 1 (decode, per-batch positions): one-hot select write —
          elementwise, any sharding, SPMD-safe.
        * S > 1 (prefill): positions are the standard arange; the write is
          a dynamic-update-slice (cache_len >= S) or a roll of the last
          cache_len tokens (ring wrap), both SPMD-friendly.  Windows at
          per-row starts go through :meth:`fill_window` instead.
        """
        cache_len = cache["slot_pos"].shape[1]
        s = k.shape[1]
        enc = self._encode(k, v)  # leaf name -> (B, S, ...) storage value
        out = dict(cache)
        if s == 1:
            slots = positions % cache_len  # (B, 1)
            mask = jnp.arange(cache_len)[None, :] == slots  # (B, L)
            if write_mask is not None:
                mask &= write_mask[:, None]
            for name, val in enc.items():
                m = mask.reshape(mask.shape + (1,) * (val.ndim - 2))
                out[name] = jnp.where(m, val.astype(cache[name].dtype),
                                      cache[name])
            out["slot_pos"] = jnp.where(mask, positions, cache["slot_pos"])
            return out

        if s <= cache_len:
            for name, val in enc.items():
                out[name] = jax.lax.dynamic_update_slice(
                    cache[name], val.astype(cache[name].dtype),
                    (0,) * val.ndim)
            out["slot_pos"] = jax.lax.dynamic_update_slice(
                cache["slot_pos"], positions, (0, 0))
            return out

        # ring wrap: keep the last cache_len tokens; token at position p
        # lands in slot p % cache_len, i.e. a cyclic roll by
        # (s - cache_len) % L.
        shift = (s - cache_len) % cache_len
        for name, val in enc.items():
            out[name] = jnp.roll(val[:, s - cache_len:], shift,
                                 axis=1).astype(cache[name].dtype)
        out["slot_pos"] = jnp.roll(positions[:, s - cache_len:], shift, axis=1)
        return out

    def fill_window(self, cache, k, v, positions, write_mask=None):
        """C-token window write at per-row start positions (speculative
        verify, draft restart windows).  One-hot select per window token —
        the same SPMD-safe no-scatter trick as the S == 1 decode path,
        vectorized over C: window token c of row b lands in slot
        ``positions[b, c] % cache_len``.  Within a row the window
        positions are consecutive, so the per-token one-hots never
        collide and the 0/1-coefficient einsum below reproduces a direct
        write bit-exactly (a one-hot sum selects exactly one addend, so
        accumulating in fp32 and casting back to the storage dtype —
        including integer code leaves — is lossless)."""
        cache_len = cache["slot_pos"].shape[1]
        if k.shape[1] == 1:
            return self.fill(cache, k, v, positions, write_mask)
        enc = self._encode(k, v)
        slots = positions % cache_len  # (B, C)
        oh = slots[:, :, None] == jnp.arange(cache_len)[None, None, :]
        if write_mask is not None:
            oh &= write_mask[:, None, None]
        hit = oh.any(axis=1)  # (B, L): does any window token land here?
        ohf = oh.astype(jnp.float32)
        out = dict(cache)
        for name, val in enc.items():
            upd = jnp.einsum("bcl,bc...->bl...", ohf,
                             val.astype(jnp.float32))
            hm = hit.reshape(hit.shape + (1,) * (val.ndim - 2))
            out[name] = jnp.where(hm, upd.astype(cache[name].dtype),
                                  cache[name])
        out["slot_pos"] = jnp.where(
            hit, (oh * positions[:, :, None]).sum(axis=1),
            cache["slot_pos"])
        return out

    def truncate(self, cache, lengths):
        """Rows at positions >= lengths[b] flip to ``slot_pos = -1``:
        invisible to :func:`_mask` and rewritten in place by the next
        window (overwrite-before-read, exactly like slot recycling).  K/V
        bytes stay — same hygiene argument as :meth:`reset`."""
        slot_pos = cache["slot_pos"]
        return {**cache, "slot_pos": jnp.where(
            slot_pos >= lengths[:, None], -1, slot_pos)}

    def gather(self, cache):
        if self.kv_bits is None:
            return cache["k"], cache["v"], cache["slot_pos"]
        dh = cache["k"].shape[-1] * (8 if self.kv_bits == 1 else 1)
        k = kv_dequantize(self.kv_bits, cache["k"], cache["k_scale"], dh)
        v = kv_dequantize(self.kv_bits, cache["v"], cache["v_scale"], dh)
        return k, v, cache["slot_pos"]

    def attend(self, cache, q, q_pos, cfg: AttnConfig, interpret=None):
        b, c = q_pos.shape
        tile = attn_kernels.select_attn_tiles(
            b, c, cache["slot_pos"].shape[1], cfg.d_head, "ctg")
        return attn_kernels.flash_decode_contig(
            q, q_pos, cache["k"], cache["v"], cache["slot_pos"],
            cache.get("k_scale"), cache.get("v_scale"),
            kv_bits=self.kv_bits, sm_scale=cfg.scale,
            logit_softcap=cfg.logit_softcap, causal=cfg.causal,
            window=cfg.window, kv_tile=tile, interpret=interpret)


@dataclasses.dataclass(frozen=True)
class PagedKVCache(KVCache):
    """Block-table paged storage over a shared pool.

    Leaves: ``pool_k``/``pool_v`` (num_blocks, block_size, KVH, Dh)
    (quantized tiers store codes here plus scale pools ``pool_ks``/
    ``pool_vs`` riding the same flat-index scatters — base-class
    docstring), ``pool_pos`` (num_blocks, block_size) int32 absolute
    token positions
    (-1 = empty), ``table`` (B, blocks_per_slot) int32 block ids (-1 =
    unmapped — the whole slot is invisible).  Token at slot-local position
    ``p`` lives in block ``table[b, p // block_size]`` at offset
    ``p % block_size``, so ``gather`` reassembles each slot's tokens in
    position order — the dense view is VALUE-identical to the contiguous
    layout's storage, which is what makes paged serving bit-identical.

    The block table is part of the cache pytree: the host-side allocator
    (serve/engine.BlockAllocator) rewrites table rows and resets freshly
    allocated blocks' ``pool_pos`` at admission; the jitted fill/gather
    below only ever follow the table.  Invariants the allocator maintains:

    * a block is referenced by at most one WRITABLE slot position range;
      refcount > 1 blocks (shared prompt prefixes) are never written —
      chunked prefill starts at the first novel token and decode writes at
      pos >= prompt_len, both past any shared full block;
    * freshly allocated blocks get ``pool_pos = -1`` before the table row
      lands, so a previous occupant's stale keys are invisible;
    * retired slots keep decoding junk in the shape-static step — their
      writes MUST be dropped (``write_mask``), because their freed blocks
      may already belong to another slot.
    """

    block_size: int = 16
    kv_bits: int | None = None

    def _encode(self, k, v) -> Params:
        """Projected fp k/v (B, S, KVH, Dh) -> the pool leaves this layout
        persists for them (codes + scale pools under ``kv_bits``)."""
        if self.kv_bits is None:
            return {"pool_k": k, "pool_v": v}
        kc, ks = kv_quantize(self.kv_bits, k)
        vc, vs = kv_quantize(self.kv_bits, v)
        return {"pool_k": kc, "pool_ks": ks, "pool_v": vc, "pool_vs": vs}

    def _scatter(self, cache, flat, enc, positions):
        """Scatter encoded (B, S, ...) leaves + positions to flattened
        pool indices ``flat`` ((B*S,); invalid -> nb*bs, mode='drop')."""
        nb, bs = cache["pool_pos"].shape
        n = flat.shape[0]
        out = dict(cache)
        for name, val in enc.items():
            pool = cache[name]
            out[name] = (
                pool.reshape((nb * bs,) + pool.shape[2:])
                .at[flat].set(
                    val.astype(pool.dtype).reshape((n,) + pool.shape[2:]),
                    mode="drop")
                .reshape(pool.shape))
        out["pool_pos"] = (cache["pool_pos"].reshape(nb * bs)
                           .at[flat].set(positions.reshape(-1), mode="drop")
                           .reshape(nb, bs))
        return out

    def _flat(self, cache, positions, write_mask):
        """(B, S) flattened pool indices; invalid/masked writes -> index
        num_blocks*block_size, dropped by scatter mode='drop'."""
        bs = self.block_size
        table = cache["table"]
        bps = table.shape[1]
        nb = cache["pool_pos"].shape[0]
        blk_idx = jnp.clip(positions // bs, 0, bps - 1)  # (B, S)
        blk = jnp.take_along_axis(table, blk_idx, axis=1)  # (B, S)
        valid = (positions >= 0) & (positions < bps * bs) & (blk >= 0)
        if write_mask is not None:
            valid &= write_mask[:, None]
        flat = jnp.clip(blk, 0) * bs + positions % bs
        return jnp.where(valid, flat, nb * bs)

    def init(self, b, cfg: AttnConfig, cache_len, dtype=jnp.bfloat16):
        bs = self.block_size
        if cache_len % bs:
            raise ValueError(
                f"cache_len {cache_len} not a multiple of kv block size {bs}")
        bps = cache_len // bs
        nb = b * bps  # the contiguous layout's exact footprint
        (code, cdt), sc = kv_code_shapes(self.kv_bits, cfg.n_kv_heads,
                                         cfg.d_head, dtype)
        out = {
            "pool_k": jnp.zeros((nb, bs) + code, cdt),
            "pool_v": jnp.zeros((nb, bs) + code, cdt),
            "pool_pos": jnp.full((nb, bs), -1, jnp.int32),
            "table": jnp.full((b, bps), -1, jnp.int32),
        }
        if sc is not None:
            out["pool_ks"] = jnp.zeros((nb, bs) + sc[0], sc[1])
            out["pool_vs"] = jnp.zeros((nb, bs) + sc[0], sc[1])
        return out

    def insert(self, cache, sub, slots):
        """Write a (G, L, ...) CONTIGUOUS prefill sub-cache into the G
        slots' mapped blocks (positions from ``sub['slot_pos']``; -1 rows
        are dropped — freshly allocated blocks were already pos-reset by
        the allocator, which replaces the contiguous layout's full-slot
        overwrite invariant)."""
        pos = sub["slot_pos"]  # (G, L)
        table_rows = cache["table"][slots]  # (G, bps)
        flat = self._flat({**cache, "table": table_rows}, pos, None)
        return self._scatter(cache, flat.reshape(-1),
                             self._encode(sub["k"], sub["v"]), pos)

    def reset(self, cache, slot):
        """Retire one slot: unmap its table row (-1) so ``gather`` masks
        the whole slot.  Block bookkeeping (refcount decrement, free-list
        return) is the HOST allocator's job — pool bytes are untouched, so
        a block shared with a live slot keeps serving its holder."""
        bps = cache["table"].shape[1]
        table = jax.lax.dynamic_update_slice(
            cache["table"], jnp.full((1, bps), -1, jnp.int32), (slot, 0)
        )
        return {**cache, "table": table}

    def fill(self, cache, k, v, positions, write_mask=None):
        """Scatter k/v/pos through the block table.  Distinct (row, pos)
        pairs always hit distinct pool entries (the allocator never maps a
        writable position range of two slots onto one block), so the
        scatter is deterministic; ``write_mask=False`` rows (retired or
        still-prefilling slots decoding junk) are dropped entirely."""
        flat = self._flat(cache, positions, write_mask).reshape(-1)
        return self._scatter(cache, flat, self._encode(k, v), positions)

    def truncate(self, cache, lengths):
        """Rollback through the table: every mapped pool row of slot ``b``
        holding a position >= ``lengths[b]`` flips to ``pool_pos = -1`` —
        invisible to :func:`_mask` and rewritable by the next window
        (the speculative verify overwrites the rolled-back range before
        reading it, exactly as decode overwrites a fresh block).

        Safe under sharing: a shared-prefix block's positions are all
        ``< prompt_len <= lengths[b]`` for every holder, so its scattered
        values are unchanged (holders write back identical bytes) — only
        the truncating slot's PRIVATE tail blocks actually flip.  Block
        *ownership* is untouched; the host allocator keeps its refcounts
        (the engine maps a slot's full table at admission, so rollback is
        a visibility change, not a deallocation — tail blocks drain back
        to the allocator at retirement via ``BlockAllocator.trim``)."""
        table = cache["table"]  # (B, bps)
        b, bps = table.shape
        bs = self.block_size
        nb = cache["pool_pos"].shape[0]
        safe = jnp.clip(table, 0)
        pos = cache["pool_pos"][safe]  # (B, bps, bs)
        newpos = jnp.where(pos >= lengths[:, None, None], -1, pos)
        # scatter back through the table; unmapped rows (-1) -> index
        # nb*bs, dropped
        blk = jnp.where(table >= 0, safe, nb)[:, :, None]
        flat = (blk * bs + jnp.arange(bs)[None, None, :]).reshape(-1)
        pool_pos = (cache["pool_pos"].reshape(nb * bs)
                    .at[flat].set(newpos.reshape(-1), mode="drop")
                    .reshape(nb, bs))
        return {**cache, "pool_pos": pool_pos}

    def gather(self, cache):
        """Dense (B, L, KVH, Dh) views via the table — position order, so
        the result matches the contiguous layout's storage bit-for-bit.
        Unmapped table entries (-1) read block 0 but report pos -1, which
        :func:`_mask` hides."""
        table = cache["table"]  # (B, bps)
        b, bps = table.shape
        bs = self.block_size
        safe = jnp.clip(table, 0)
        k = cache["pool_k"][safe]  # (B, bps, bs, KVH, Dh-coded)
        v = cache["pool_v"][safe]
        if self.kv_bits is not None:
            dh_fp = k.shape[-1] * (8 if self.kv_bits == 1 else 1)
            k = kv_dequantize(self.kv_bits, k, cache["pool_ks"][safe], dh_fp)
            v = kv_dequantize(self.kv_bits, v, cache["pool_vs"][safe], dh_fp)
        pos = jnp.where(table[:, :, None] >= 0, cache["pool_pos"][safe], -1)
        kvh, dh = k.shape[-2:]
        return (k.reshape(b, bps * bs, kvh, dh),
                v.reshape(b, bps * bs, kvh, dh),
                pos.reshape(b, bps * bs))

    def attend(self, cache, q, q_pos, cfg: AttnConfig, interpret=None):
        b, c = q_pos.shape
        cache_len = cache["table"].shape[1] * self.block_size
        spb = attn_kernels.select_attn_tiles(b, c, cache_len, cfg.d_head,
                                             "pgd")
        return attn_kernels.flash_decode_paged(
            cache["table"], q, q_pos, cache["pool_k"], cache["pool_v"],
            cache["pool_pos"], cache.get("pool_ks"), cache.get("pool_vs"),
            block_size=self.block_size, kv_bits=self.kv_bits,
            sm_scale=cfg.scale, logit_softcap=cfg.logit_softcap,
            causal=cfg.causal, window=cfg.window,
            blocks_per_step=min(spb, cache["table"].shape[1]),
            interpret=interpret)


CONTIGUOUS = ContiguousKVCache()


def attn_decode(
    params: Params,
    x: jax.Array,  # (B, 1, D)
    pos: jax.Array,  # (B,) int32 — position of this token
    cache: Params,
    cfg: AttnConfig,
    ctx: QCtx,
    path: str,
    *,
    cross: bool = False,
    kv: KVCache | None = None,
    write_mask: jax.Array | None = None,
) -> tuple[jax.Array, Params]:
    """One decode step against the cache; returns (out (B,1,D), new cache).

    ``cross=True`` reads a static cross-attention cache (no write, no mask
    beyond slot validity).  ``kv`` selects the cache layout (default
    contiguous); ``write_mask`` (B,) drops inactive rows' cache writes on
    layouts where block recycling makes junk writes unsafe (paged)."""
    if kv is None:
        kv = CONTIGUOUS
    b = x.shape[0]
    positions = pos[:, None]
    if cross:
        q = ctx.dense(params["q"], x, f"{path}/q").reshape(
            b, 1, cfg.n_heads, cfg.d_head
        )
        if cfg.use_rope:
            q = rope(q, positions, cfg.rope_theta)
    else:
        q, k_new, v_new = _project_qkv(params, x, positions, cfg, ctx, path)
        cache = kv.fill(cache, k_new, v_new, positions, write_mask)

    qg = q.reshape(b, 1, cfg.n_kv_heads, cfg.groups, cfg.d_head)
    if cfg.fused_attn and not cross:
        out = kv.attend(cache, qg, positions, cfg,
                        interpret=ctx.gemm_config._interpret)
    else:
        k, v, k_pos = kv.gather(cache)
        mask = _mask(cfg, positions, k_pos)  # (B, 1, L)
        out = _sdpa(cfg, qg, k, v, mask)
    out = out.reshape(b, 1, cfg.n_heads * cfg.d_head).astype(ctx.compute_dtype)
    return ctx.dense(params["o"], out, f"{path}/o"), cache


def attn_window(
    params: Params,
    x: jax.Array,  # (B, C, D)
    positions: jax.Array,  # (B, C) absolute positions of these tokens
    cache: Params,
    cfg: AttnConfig,
    ctx: QCtx,
    path: str,
    kv: KVCache,
    *,
    write_mask: jax.Array | None = None,
) -> tuple[jax.Array, Params]:
    """A C-token window against the cache: project, store the window's
    k/v, then attend over the FULL gathered cache (the window included —
    causality comes from the position mask).  ``attn_decode`` is the C==1
    special case; chunked prefill is the general one, where each chunk of
    a long prompt attends to everything already cached (earlier chunks,
    shared prefix blocks) plus itself, so one jitted shape serves decode,
    chunked prefill, shared-prefix suffix prefill, and the speculative
    verify window (per-row starts — hence ``fill_window``)."""
    b, c, _ = x.shape
    q, k_new, v_new = _project_qkv(params, x, positions, cfg, ctx, path)
    cache = kv.fill_window(cache, k_new, v_new, positions, write_mask)
    qg = q.reshape(b, c, cfg.n_kv_heads, cfg.groups, cfg.d_head)
    if cfg.fused_attn:
        out = kv.attend(cache, qg, positions, cfg,
                        interpret=ctx.gemm_config._interpret)
    else:
        k, v, k_pos = kv.gather(cache)
        mask = _mask(cfg, positions, k_pos)  # (B, C, L)
        out = _sdpa(cfg, qg, k, v, mask)
    out = out.reshape(b, c, cfg.n_heads * cfg.d_head).astype(ctx.compute_dtype)
    return ctx.dense(params["o"], out, f"{path}/o"), cache
