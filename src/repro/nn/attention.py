"""Attention in all the variants the assigned pool needs.

One implementation covers: MHA/GQA/MQA (grouped einsum — KV is never
materialised per-query-head), causal / bidirectional / sliding-window /
alternating local-global, attn-logit softcapping (gemma2), QKV bias (qwen2),
RoPE, cross-attention (whisper), KV-cache decode with per-batch positions
(ring buffer for local layers), and a chunked online-softmax path for long
prefill (32k) where materialising (S, S) scores would blow HBM.

All projections run through ``QCtx.dense`` => they obey the BMXNet
quantization policy like every other GEMM in the framework.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import qlayers
from repro.nn.common import QCtx, rope, softcap

Params = dict[str, Any]

NEG_INF = -2.0e38


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    rope_theta: float = 10000.0
    use_rope: bool = True
    qkv_bias: bool = False
    logit_softcap: float | None = None
    window: int | None = None  # sliding window; None = global
    causal: bool = True
    query_scale: float | None = None  # default d_head ** -0.5
    # chunked-path knobs
    full_attn_max_seq: int = 4096
    chunk_q: int = 512
    chunk_kv: int = 1024

    @property
    def groups(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def scale(self) -> float:
        return self.query_scale if self.query_scale is not None else self.d_head**-0.5


def attn_init(key: jax.Array, cfg: AttnConfig, *, dtype=jnp.float32) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    h, kvh, dh, d = cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_model
    return {
        "q": qlayers.dense_init(kq, d, h * dh, bias=cfg.qkv_bias, dtype=dtype),
        "k": qlayers.dense_init(kk, d, kvh * dh, bias=cfg.qkv_bias, dtype=dtype),
        "v": qlayers.dense_init(kv, d, kvh * dh, bias=cfg.qkv_bias, dtype=dtype),
        "o": qlayers.dense_init(ko, h * dh, d, dtype=dtype),
    }


def _project_qkv(params, x, positions, cfg: AttnConfig, ctx: QCtx, path: str):
    b, s, _ = x.shape
    q = ctx.dense(params["q"], x, f"{path}/q").reshape(b, s, cfg.n_heads, cfg.d_head)
    k = ctx.dense(params["k"], x, f"{path}/k").reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = ctx.dense(params["v"], x, f"{path}/v").reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask(cfg: AttnConfig, q_pos, k_pos):
    """(..., Sq, Sk) bool validity mask from absolute positions."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if cfg.causal:
        m &= kp <= qp
    if cfg.window is not None:
        m &= kp > qp - cfg.window
    m &= kp >= 0  # empty cache slots carry position -1
    return m


def _sdpa(cfg: AttnConfig, q, k, v, mask):
    """Grouped scaled-dot-product attention with softcap.

    q: (B, Sq, KVH, G, Dh); k, v: (B, Sk, KVH, Dh); mask: (B, Sq, Sk) bool.
    Returns (B, Sq, KVH, G, Dh).
    """
    scores = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32
    ) * cfg.scale
    scores = softcap(scores, cfg.logit_softcap)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)


def _sdpa_chunked(cfg: AttnConfig, q, k, v, q_pos, k_pos):
    """Online-softmax attention, O(chunk_q * chunk_kv) score memory.

    Same signature/semantics as _sdpa but mask is derived from positions and
    both sequence axes are processed in chunks (flash-attention recurrence in
    pure jnp; the Pallas variant is a §Perf item).
    """
    b, sq, kvh, g, dh = q.shape
    sk = k.shape[1]
    cq, ck = min(cfg.chunk_q, sq), min(cfg.chunk_kv, sk)
    assert sq % cq == 0, (sq, cq)
    if sk % ck:  # pad KV to a chunk multiple; pad slots masked via pos=-1
        pad = ck - sk % ck
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
        sk += pad
    nq, nk = sq // cq, sk // ck

    qc = q.reshape(b, nq, cq, kvh, g, dh).transpose(1, 0, 2, 3, 4, 5)
    qpc = q_pos.reshape(b, nq, cq).transpose(1, 0, 2)
    kc = k.reshape(b, nk, ck, kvh, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nk, ck, kvh, dh).transpose(1, 0, 2, 3, 4)
    kpc = k_pos.reshape(b, nk, ck).transpose(1, 0, 2)

    def q_block(carry, qb):
        qi, qp = qb

        def kv_block(st, kb):
            m_run, l_run, acc = st
            ki, vi, kp = kb
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qi, ki, preferred_element_type=jnp.float32
            ) * cfg.scale
            s = softcap(s, cfg.logit_softcap)
            valid = _mask(cfg, qp, kp)  # (b, cq, ck)
            s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vi.dtype), vi
            ).astype(jnp.float32)
            return (m_new, l_new, acc), None

        init = (
            jnp.full((b, kvh, g, cq), NEG_INF, jnp.float32),
            jnp.zeros((b, kvh, g, cq), jnp.float32),
            jnp.zeros((b, kvh, g, cq, dh), jnp.float32),
        )
        (m_f, l_f, acc), _ = jax.lax.scan(kv_block, init, (kc, vc, kpc))
        out = acc / jnp.maximum(l_f, 1e-37)[..., None]
        return carry, out.transpose(0, 3, 1, 2, 4)  # (b, cq, kvh, g, dh)

    _, outs = jax.lax.scan(q_block, None, (qc, qpc))  # (nq, b, cq, kvh, g, dh)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, kvh, g, dh)


def attn_forward(
    params: Params,
    x: jax.Array,  # (B, S, D)
    positions: jax.Array,  # (B, S)
    cfg: AttnConfig,
    ctx: QCtx,
    path: str,
    *,
    kv: tuple[jax.Array, jax.Array] | None = None,  # cross-attention K/V src
    kv_positions: jax.Array | None = None,
) -> jax.Array:
    """Full-sequence forward (training / prefill)."""
    b, s, _ = x.shape
    if kv is None:
        q, k, v = _project_qkv(params, x, positions, cfg, ctx, path)
        k_pos = positions
    else:
        q = ctx.dense(params["q"], x, f"{path}/q").reshape(
            b, s, cfg.n_heads, cfg.d_head
        )
        if cfg.use_rope:
            q = rope(q, positions, cfg.rope_theta)
        k, v = kv
        k_pos = kv_positions

    qg = q.reshape(b, s, cfg.n_kv_heads, cfg.groups, cfg.d_head)
    if max(s, k.shape[1]) <= cfg.full_attn_max_seq:
        mask = _mask(cfg, positions, k_pos)
        out = _sdpa(cfg, qg, k, v, mask)
    else:
        out = _sdpa_chunked(cfg, qg, k, v, positions, k_pos)
    out = out.reshape(b, s, cfg.n_heads * cfg.d_head).astype(ctx.compute_dtype)
    return ctx.dense(params["o"], out, f"{path}/o")


def cross_kv(
    params: Params, enc: jax.Array, cfg: AttnConfig, ctx: QCtx, path: str
):
    """Project encoder output to K/V once (whisper prefill)."""
    b, t, _ = enc.shape
    k = ctx.dense(params["k"], enc, f"{path}/k").reshape(b, t, cfg.n_kv_heads, cfg.d_head)
    v = ctx.dense(params["v"], enc, f"{path}/v").reshape(b, t, cfg.n_kv_heads, cfg.d_head)
    return k, v


# --------------------------------------------------------------------------
# KV cache (decode)
# --------------------------------------------------------------------------


def cache_init(
    b: int, cfg: AttnConfig, cache_len: int, dtype=jnp.bfloat16
) -> Params:
    """Empty cache.  Local layers pass cache_len == cfg.window (ring)."""
    return {
        "k": jnp.zeros((b, cache_len, cfg.n_kv_heads, cfg.d_head), dtype),
        "v": jnp.zeros((b, cache_len, cfg.n_kv_heads, cfg.d_head), dtype),
        "slot_pos": jnp.full((b, cache_len), -1, jnp.int32),
    }


def insert_rows(big: jax.Array, small: jax.Array, slots: jax.Array) -> jax.Array:
    """Write the G leading rows of ``small`` into batch rows ``slots`` of
    ``big`` (both batch-leading; ``slots``: (G,) int32, traced-safe).  The
    per-slot building block of the continuous-batching scheduler's cache
    insertion (models/{lm,whisper}.cache_insert tree-map this over every
    cache leaf)."""
    for g in range(small.shape[0]):
        big = jax.lax.dynamic_update_slice_in_dim(
            big, small[g:g + 1].astype(big.dtype), slots[g], axis=0
        )
    return big


def zero_rows(x: jax.Array, slot: jax.Array) -> jax.Array:
    """Zero batch row ``slot`` (recurrent-state reset on slot retirement)."""
    return jax.lax.dynamic_update_slice_in_dim(
        x, jnp.zeros((1,) + x.shape[1:], x.dtype), slot, axis=0
    )


def cache_reset(cache: Params, slot: jax.Array) -> Params:
    """Retire one batch slot of an attention cache: mark every row of that
    slot empty (``slot_pos = -1``) so :func:`_mask` hides it from future
    queries.  K/V bytes are left in place — the next occupant's prefill
    insertion overwrites the whole slot (and carries its own -1 rows past
    the prompt), so stale keys can never become visible again."""
    cache_len = cache["slot_pos"].shape[1]
    slot_pos = jax.lax.dynamic_update_slice(
        cache["slot_pos"], jnp.full((1, cache_len), -1, jnp.int32), (slot, 0)
    )
    return {**cache, "slot_pos": slot_pos}


def cache_fill(cache: Params, k, v, positions) -> Params:
    """Write to the cache.  k/v: (B, S, KVH, Dh), positions: (B, S).
    Slots are ``pos % cache_len`` (ring for local layers; identity when
    cache_len >= S).

    No scatters: scatter onto a model-sharded cache triggers GSPMD
    "involuntary full rematerialization" (the cache gets replicated —
    measured 0.86 s/step of collectives on granite decode_32k).  Instead:

    * S == 1 (decode, per-batch positions): one-hot select write —
      elementwise, any sharding, SPMD-safe.
    * S > 1 (prefill): positions are the standard arange; the write is a
      dynamic-update-slice (cache_len >= S) or a roll of the last
      cache_len tokens (ring wrap), both SPMD-friendly.
    """
    cache_len = cache["k"].shape[1]
    s = k.shape[1]
    if s == 1:
        slots = positions % cache_len  # (B, 1)
        mask = jnp.arange(cache_len)[None, :] == slots  # (B, L)
        m4 = mask[:, :, None, None]
        return {
            "k": jnp.where(m4, k.astype(cache["k"].dtype), cache["k"]),
            "v": jnp.where(m4, v.astype(cache["v"].dtype), cache["v"]),
            "slot_pos": jnp.where(mask, positions, cache["slot_pos"]),
        }

    if s <= cache_len:
        zero = (0, 0, 0, 0)
        return {
            "k": jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), zero),
            "v": jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), zero),
            "slot_pos": jax.lax.dynamic_update_slice(
                cache["slot_pos"], positions, (0, 0)),
        }

    # ring wrap: keep the last cache_len tokens; token at position p lands
    # in slot p % cache_len, i.e. a cyclic roll by (s - cache_len) % L.
    shift = (s - cache_len) % cache_len
    k_t = jnp.roll(k[:, s - cache_len:], shift, axis=1)
    v_t = jnp.roll(v[:, s - cache_len:], shift, axis=1)
    p_t = jnp.roll(positions[:, s - cache_len:], shift, axis=1)
    return {
        "k": k_t.astype(cache["k"].dtype),
        "v": v_t.astype(cache["v"].dtype),
        "slot_pos": p_t,
    }


def attn_decode(
    params: Params,
    x: jax.Array,  # (B, 1, D)
    pos: jax.Array,  # (B,) int32 — position of this token
    cache: Params,
    cfg: AttnConfig,
    ctx: QCtx,
    path: str,
    *,
    cross: bool = False,
) -> tuple[jax.Array, Params]:
    """One decode step against the cache; returns (out (B,1,D), new cache).

    ``cross=True`` reads a static cross-attention cache (no write, no mask
    beyond slot validity)."""
    b = x.shape[0]
    positions = pos[:, None]
    if cross:
        q = ctx.dense(params["q"], x, f"{path}/q").reshape(
            b, 1, cfg.n_heads, cfg.d_head
        )
        if cfg.use_rope:
            q = rope(q, positions, cfg.rope_theta)
    else:
        q, k_new, v_new = _project_qkv(params, x, positions, cfg, ctx, path)
        cache = cache_fill(cache, k_new, v_new, positions)

    qg = q.reshape(b, 1, cfg.n_kv_heads, cfg.groups, cfg.d_head)
    mask = _mask(cfg, positions, cache["slot_pos"])  # (B, 1, L)
    out = _sdpa(cfg, qg, cache["k"], cache["v"], mask)
    out = out.reshape(b, 1, cfg.n_heads * cfg.d_head).astype(ctx.compute_dtype)
    return ctx.dense(params["o"], out, f"{path}/o"), cache
