"""RWKV-6 "Finch" — attention-free token mixing with data-dependent decay.

Time-mix (WKV6): per head of size ``dh`` the recurrence over a (dh_k, dh_v)
state S is

    y_t = S_{t-1}^T r_t + (r_t · (u ⊙ k_t)) v_t
    S_t = diag(w_t) S_{t-1} + k_t v_t^T ,     w_t = exp(-exp(ŵ_t)) ∈ (0,1)

with ŵ_t data-dependent (token-shift + LoRA).  We evaluate it **chunked**:
inside a chunk of C tokens the pairwise decay ratios

    A_{t-1}/A_s = exp(la_excl[t] - la_incl[s])   (s < t)

have non-positive exponents (la is a running sum of negative log-decays), so
the intra-chunk quadratic form is computed *exactly* in log space with every
exponent bounded above by 0 — no overflow, no rescaling pass.  The chunk
state is carried by ``lax.scan``; decode is the O(1) recurrence.  This is
the TPU-friendly replacement for the sequential CUDA wkv kernel (see
DESIGN.md — chunk quadratics vectorise on the VPU; a Pallas fusion of the
chunk body is a §Perf candidate).

All large projections (r/k/v/g/o, channel-mix) go through QCtx.dense and
quantize under the BMXNet policy; LoRA pieces, decays and norms stay fp.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import qlayers
from repro.nn.common import QCtx

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class RWKV6Config:
    d_model: int
    n_heads: int
    d_head: int
    d_ff: int
    chunk: int = 16
    lora_mix: int = 32
    lora_decay: int = 64


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def timemix_init(key, cfg: RWKV6Config, *, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    std = d**-0.5
    return {
        "mu": jax.random.uniform(ks[0], (6, d), dtype),  # x,w,k,v,r,g
        "mix_w1": jax.random.normal(ks[1], (d, 5 * cfg.lora_mix), dtype) * std,
        "mix_w2": jax.random.normal(ks[2], (5, cfg.lora_mix, d), dtype)
        * cfg.lora_mix**-0.5,
        "decay_w0": jnp.full((d,), -6.0, dtype),
        "decay_w1": jax.random.normal(ks[3], (d, cfg.lora_decay), dtype) * std,
        "decay_w2": jax.random.normal(ks[4], (cfg.lora_decay, d), dtype)
        * cfg.lora_decay**-0.5,
        "bonus_u": jax.random.normal(ks[5], (cfg.n_heads, cfg.d_head), dtype) * 0.1,
        "r": qlayers.dense_init(ks[6], d, d, dtype=dtype),
        "k": qlayers.dense_init(ks[7], d, d, dtype=dtype),
        "v": qlayers.dense_init(ks[8], d, d, dtype=dtype),
        "g": qlayers.dense_init(ks[9], d, d, dtype=dtype),
        "o": qlayers.dense_init(ks[10], d, d, dtype=dtype),
        "gn": {
            "scale": jnp.ones((d,), dtype),
            "bias": jnp.zeros((d,), dtype),
        },
    }


def chanmix_init(key, cfg: RWKV6Config, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "mu": jax.random.uniform(ks[0], (2, cfg.d_model), dtype),  # k, r
        "k": qlayers.dense_init(ks[1], cfg.d_model, cfg.d_ff, dtype=dtype),
        "v": qlayers.dense_init(ks[2], cfg.d_ff, cfg.d_model, dtype=dtype),
        "r": qlayers.dense_init(jax.random.fold_in(key, 3), cfg.d_model,
                                cfg.d_model, dtype=dtype),
    }


# --------------------------------------------------------------------------
# token shift
# --------------------------------------------------------------------------


def _shift_train(x: jax.Array) -> jax.Array:
    """prev-token shift along S; position 0 sees zeros."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]


# --------------------------------------------------------------------------
# WKV6 core
# --------------------------------------------------------------------------


def _wkv_chunked(r, k, v, lw, u, s0, chunk: int, ctx=None):
    """r,k,v,lw: (B, S, H, dh) fp32; u: (H, dh); s0: (B, H, dh, dh).

    Returns (y (B,S,H,dh), s_final).  All exp() arguments are <= 0.
    """
    b, s, h, dh = r.shape
    c = min(chunk, s)
    assert s % c == 0, (s, c)
    n = s // c

    def _pin(x):
        """Keep chunk tensors head-sharded inside the scan body — sharding
        does not propagate into while-loop operands, and unconstrained
        bodies made GSPMD replicate every projection output (measured
        192 x 1 GiB all-gathers on prefill_32k)."""
        if ctx is None:
            return x
        from repro.nn.common import shard_heads
        return shard_heads(x, ctx)

    def per_chunk(s_prev, inp):
        rc, kc, vc, lwc = inp  # (B, C, H, dh)
        rc, kc, vc, lwc = _pin(rc), _pin(kc), _pin(vc), _pin(lwc)
        la_incl = jnp.cumsum(lwc, axis=1)  # (B, C, H, dh), decreasing
        la_excl = la_incl - lwc
        # intra-chunk pairwise decay: exponent la_excl[t] - la_incl[s] <= 0
        # for s < t (cumsum of negatives); masked elsewhere.
        pair = la_excl[:, :, None] - la_incl[:, None, :]  # (B, C, C, H, dh)
        tri = jnp.tril(jnp.ones((c, c), bool), k=-1)[None, :, :, None, None]
        decay = jnp.exp(jnp.where(tri, pair, -jnp.inf))
        scores = jnp.einsum("bthc,bshc,btshc->btsh", rc, kc, decay)
        y_intra = jnp.einsum("btsh,bshc->bthc", scores, vc)
        # diagonal bonus term
        diag = jnp.einsum("bthc,hc,bthc->bth", rc, u, kc)
        y_intra = y_intra + diag[..., None] * vc
        # inter-chunk: state contribution
        rp = rc * jnp.exp(la_excl)
        y_inter = jnp.einsum("bthk,bhkv->bthv", rp, s_prev)
        # state update: exponents la_total - la_incl[s] <= 0
        la_tot = la_incl[:, -1]  # (B, H, dh)
        kd = kc * jnp.exp(la_tot[:, None] - la_incl)
        s_new = jnp.exp(la_tot)[..., None] * s_prev + jnp.einsum(
            "bshk,bshv->bhkv", kd, vc
        )
        return s_new, y_intra + y_inter

    def _pin5(x):
        """Constrain the stacked (n, B, C, H, dh) scan operands — GSPMD
        otherwise replicates while-loop xs and all-gathers every projection
        output feeding them."""
        if ctx is None or getattr(ctx, "mesh", None) is None:
            return x
        mesh = ctx.mesh
        if "model" not in mesh.axis_names or h % dict(mesh.shape)["model"]:
            return x
        import math

        from jax.sharding import NamedSharding, PartitionSpec as P

        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        if dp and b % math.prod(dict(mesh.shape)[a] for a in dp):
            dp = ()
        spec = P(None, dp if dp else None, None, "model", None)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    rs = _pin5(r.reshape(b, n, c, h, dh).transpose(1, 0, 2, 3, 4))
    ks_ = _pin5(k.reshape(b, n, c, h, dh).transpose(1, 0, 2, 3, 4))
    vs = _pin5(v.reshape(b, n, c, h, dh).transpose(1, 0, 2, 3, 4))
    lws = _pin5(lw.reshape(b, n, c, h, dh).transpose(1, 0, 2, 3, 4))
    s_fin, ys = jax.lax.scan(per_chunk, s0, (rs, ks_, vs, lws))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)
    return y, s_fin


def _wkv_step(r, k, v, lw, u, s_prev):
    """Single decode step.  r,k,v,lw: (B, H, dh)."""
    w = jnp.exp(lw)
    y = jnp.einsum("bhk,bhkv->bhv", r, s_prev) + jnp.einsum(
        "bhc,hc,bhc->bh", r, u, k
    )[..., None] * v
    s_new = w[..., None] * s_prev + jnp.einsum("bhk,bhv->bhkv", k, v)
    return y, s_new


# --------------------------------------------------------------------------
# time-mix block
# --------------------------------------------------------------------------


def _ddlerp(p: Params, x, xx):
    """Data-dependent token-shift interpolation -> xw, xk, xv, xr, xg."""
    mu = p["mu"].astype(x.dtype)
    xxx = x + xx * mu[0]
    hid = jnp.tanh(xxx @ p["mix_w1"].astype(x.dtype))
    hid = hid.reshape(*hid.shape[:-1], 5, p["mix_w2"].shape[1])
    dyn = jnp.einsum("...nk,nkd->...nd", hid, p["mix_w2"].astype(x.dtype))
    outs = []
    for i in range(5):  # w, k, v, r, g
        outs.append(x + xx * (mu[i + 1] + dyn[..., i, :]))
    return outs


def _heads(x, h, dh):
    return x.reshape(*x.shape[:-1], h, dh).astype(jnp.float32)


def _group_norm(p, y, h, dh, eps=64e-5):
    """Per-head layernorm (RWKV's GroupNorm(H))."""
    mu = y.mean(-1, keepdims=True)
    var = ((y - mu) ** 2).mean(-1, keepdims=True)
    yn = (y - mu) * jax.lax.rsqrt(var + eps)
    yn = yn.reshape(*y.shape[:-2], h * dh)
    return yn * p["scale"].astype(yn.dtype) + p["bias"].astype(yn.dtype)


def _timemix_pre(params, x, xx, cfg: RWKV6Config, ctx: QCtx, path: str):
    xw, xk, xv, xr, xg = _ddlerp(params, x, xx)
    h, dh = cfg.n_heads, cfg.d_head
    r = _heads(ctx.dense(params["r"], xr, f"{path}/r"), h, dh)
    k = _heads(ctx.dense(params["k"], xk, f"{path}/k"), h, dh)
    v = _heads(ctx.dense(params["v"], xv, f"{path}/v"), h, dh)
    g = jax.nn.silu(ctx.dense(params["g"], xg, f"{path}/g"))
    dec = params["decay_w0"].astype(jnp.float32) + (
        jnp.tanh(xw.astype(jnp.float32) @ params["decay_w1"].astype(jnp.float32))
        @ params["decay_w2"].astype(jnp.float32)
    )
    lw = -jnp.exp(dec)  # log decay, strictly negative
    lw = _heads(lw, h, dh)
    # pin the decay to r/k/v's head-sharding — it flows from replicated
    # LoRA weights and otherwise drags the WKV einsums to replicated layout
    from repro.nn.common import shard_heads
    lw = shard_heads(lw, ctx)
    return r, k, v, lw, g


def timemix_forward(params, x, cfg: RWKV6Config, ctx: QCtx, path: str):
    xx = _shift_train(x) - x
    r, k, v, lw, g = _timemix_pre(params, x, xx, cfg, ctx, path)
    u = params["bonus_u"].astype(jnp.float32)
    b = x.shape[0]
    s0 = jnp.zeros((b, cfg.n_heads, cfg.d_head, cfg.d_head), jnp.float32)
    y, _ = _wkv_chunked(r, k, v, lw, u, s0, cfg.chunk, ctx)
    y = _group_norm(params["gn"], y, cfg.n_heads, cfg.d_head)
    y = (y.astype(ctx.compute_dtype)) * g
    return ctx.dense(params["o"], y, f"{path}/o")


def timemix_decode(params, x, cache, cfg: RWKV6Config, ctx: QCtx, path: str):
    """x: (B, 1, D); cache: {'S': (B,H,dh,dh), 'shift': (B,D)}."""
    xx = cache["shift"][:, None].astype(x.dtype) - x
    r, k, v, lw, g = _timemix_pre(params, x, xx, cfg, ctx, path)
    u = params["bonus_u"].astype(jnp.float32)
    y, s_new = _wkv_step(r[:, 0], k[:, 0], v[:, 0], lw[:, 0], u, cache["S"])
    y = _group_norm(params["gn"], y[:, None], cfg.n_heads, cfg.d_head)
    y = (y.astype(ctx.compute_dtype)) * g
    out = ctx.dense(params["o"], y, f"{path}/o")
    return out, {"S": s_new, "shift": x[:, 0].astype(cache["shift"].dtype)}


# --------------------------------------------------------------------------
# channel-mix block
# --------------------------------------------------------------------------


def chanmix_forward(params, x, cfg: RWKV6Config, ctx: QCtx, path: str,
                    shift_state=None):
    if shift_state is None:
        xx = _shift_train(x) - x
    else:
        xx = shift_state[:, None].astype(x.dtype) - x
    mu = params["mu"].astype(x.dtype)
    xk = x + xx * mu[0]
    xr = x + xx * mu[1]
    rgate = jax.nn.sigmoid(ctx.dense(params["r"], xr, f"{path}/r"))
    kk = ctx.dense(params["k"], xk, f"{path}/k")
    kk = jnp.square(jax.nn.relu(kk))
    return rgate * ctx.dense(params["v"], kk, f"{path}/v")
