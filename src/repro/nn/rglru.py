"""Griffin/RecurrentGemma recurrent block: Conv1D(4) + RG-LRU.

The RG-LRU is a *diagonal* gated linear recurrence:

    r_t = sigmoid(BD_a(u_t));   i_t = sigmoid(BD_x(u_t))
    log a_t = -c * softplus(L) * r_t                 (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Being elementwise it maps to ``lax.associative_scan`` (log-depth) for
training/prefill and an O(1) state update for decode — which is why
recurrentgemma runs the ``long_500k`` cell that full-attention archs skip.

The gate projections are block-diagonal (as in the official model) and stay
full precision (they are small and act as gates — the paper's rule of
keeping non-GEMM auxiliaries fp); the block in/out projections ARE plain
GEMMs and go through QCtx.dense like everything else.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import qlayers
from repro.nn.common import QCtx

Params = dict[str, Any]

_C = 8.0  # Griffin's fixed recurrence sharpness


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    d_rnn: int
    n_blocks: int  # block-diagonal gate blocks (= n_heads in the 2b model)
    conv_width: int = 4


def _bd_init(key, d: int, n_blocks: int, dtype=jnp.float32) -> Params:
    bs = d // n_blocks
    return {
        "w": jax.random.normal(key, (n_blocks, bs, bs), dtype) * bs**-0.5,
        "b": jnp.zeros((d,), dtype),
    }


def _bd_apply(p: Params, x: jax.Array) -> jax.Array:
    """Block-diagonal linear: x (..., D) with D = n_blocks * bs."""
    nb, bs, _ = p["w"].shape
    xb = x.reshape(*x.shape[:-1], nb, bs)
    y = jnp.einsum("...nb,nbc->...nc", xb, p["w"].astype(x.dtype))
    return y.reshape(*x.shape) + p["b"].astype(x.dtype)


def rglru_init(key, cfg: RGLRUConfig, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 6)
    return {
        "in_x": qlayers.dense_init(ks[0], cfg.d_model, cfg.d_rnn, dtype=dtype),
        "in_y": qlayers.dense_init(ks[1], cfg.d_model, cfg.d_rnn, dtype=dtype),
        "conv": {
            "w": jax.random.normal(ks[2], (cfg.conv_width, cfg.d_rnn), dtype)
            * cfg.conv_width**-0.5,
            "b": jnp.zeros((cfg.d_rnn,), dtype),
        },
        "gate_a": _bd_init(ks[3], cfg.d_rnn, cfg.n_blocks, dtype),
        "gate_x": _bd_init(ks[4], cfg.d_rnn, cfg.n_blocks, dtype),
        # Lambda parametrised so a ~ U(0.9, 0.999) at init (Griffin A.2)
        "lam": jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, cfg.d_rnn)) / _C)).astype(dtype),
        "out": qlayers.dense_init(ks[5], cfg.d_rnn, cfg.d_model, dtype=dtype),
    }


def _gates(params, u):
    r = jax.nn.sigmoid(_bd_apply(params["gate_a"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(_bd_apply(params["gate_x"], u).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * u.astype(jnp.float32)
    )
    return a, gated_in


def _conv_train(params, x):
    """Causal depthwise temporal conv, width W: y_t = sum_j w_j x_{t-W+1+j}."""
    w = params["conv"]["w"].astype(x.dtype)  # (W, D)
    width = w.shape[0]
    acc = jnp.zeros_like(x)
    for j in range(width):
        shift = width - 1 - j
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1], :]
        acc = acc + xs * w[j]
    return acc + params["conv"]["b"].astype(x.dtype)


def rglru_forward(
    params: Params, x: jax.Array, cfg: RGLRUConfig, ctx: QCtx, path: str
) -> jax.Array:
    """Training / prefill forward over a full sequence (B, S, D)."""
    y_gate = jax.nn.gelu(ctx.dense(params["in_y"], x, f"{path}/in_y"))
    u = ctx.dense(params["in_x"], x, f"{path}/in_x")
    u = _conv_train(params, u)
    a, b = _gates(params, u)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = (h.astype(ctx.compute_dtype)) * y_gate
    return ctx.dense(params["out"], out, f"{path}/out")


def rglru_cache_init(b: int, cfg: RGLRUConfig, dtype=jnp.float32) -> Params:
    return {
        "h": jnp.zeros((b, cfg.d_rnn), dtype),
        "conv": jnp.zeros((b, cfg.conv_width - 1, cfg.d_rnn), dtype),
    }


def rglru_decode(
    params: Params,
    x: jax.Array,  # (B, 1, D)
    cache: Params,
    cfg: RGLRUConfig,
    ctx: QCtx,
    path: str,
) -> tuple[jax.Array, Params]:
    y_gate = jax.nn.gelu(ctx.dense(params["in_y"], x, f"{path}/in_y"))
    u = ctx.dense(params["in_x"], x, f"{path}/in_x")[:, 0]  # (B, Dr)
    w = params["conv"]["w"].astype(u.dtype)
    hist = jnp.concatenate([cache["conv"].astype(u.dtype), u[:, None]], axis=1)
    u_c = jnp.einsum("bwd,wd->bd", hist, w) + params["conv"]["b"].astype(u.dtype)
    a, bterm = _gates(params, u_c[:, None])
    h = a[:, 0] * cache["h"] + bterm[:, 0]
    out = (h[:, None].astype(ctx.compute_dtype)) * y_gate
    y = ctx.dense(params["out"], out, f"{path}/out")
    new_cache = {"h": h, "conv": hist[:, 1:].astype(cache["conv"].dtype)}
    return y, new_cache
