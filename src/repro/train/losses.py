"""Per-family training losses."""

from __future__ import annotations

import jax
import jax.numpy as jnp

AUX_WEIGHT = 0.01  # MoE load-balance aux coefficient
IGNORE = -1


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean CE over positions where target != IGNORE.  logits (B,S,V) fp32."""
    v = logits.shape[-1]
    mask = (targets != IGNORE).astype(jnp.float32)
    safe_t = jnp.maximum(targets, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe_t[..., None], axis=-1)[..., 0]
    ce = (logz - gold) * mask
    return ce.sum() / jnp.maximum(mask.sum(), 1.0)


def lm_loss(model_forward, params, cfg, ctx, batch, remat=False,
            scan_blocks=False, seq_parallel=False):
    """batch: tokens (B,S), targets (B,S) [+ vision_embeds].  For VLM the
    vision prefix positions get IGNORE targets."""
    vis = batch.get("vision_embeds")
    logits, aux = model_forward(params, cfg, ctx, batch["tokens"], vis,
                                remat=remat, scan_blocks=scan_blocks,
                                seq_parallel=seq_parallel)
    targets = batch["targets"]
    if cfg.vision_prefix:
        pad = jnp.full(
            (targets.shape[0], cfg.vision_prefix), IGNORE, targets.dtype
        )
        targets = jnp.concatenate([pad, targets], axis=1)
    ce = cross_entropy(logits, targets)
    loss = ce + AUX_WEIGHT * aux
    # n_tokens: positions the CE actually covered — the throughput
    # denominator (trainer sums it across microbatches/DP members instead
    # of averaging; see trainer.SUM_AUX_KEYS)
    n_tok = jnp.sum(targets != IGNORE).astype(jnp.float32)
    return loss, {"ce": ce, "aux": aux, "n_tokens": n_tok}


def whisper_loss(model_forward, params, cfg, ctx, batch, remat=False,
                 scan_blocks=False, seq_parallel=False):
    del scan_blocks, seq_parallel  # whisper-base: 6 layers, unrolled is fine
    logits, aux = model_forward(params, cfg, ctx, batch["frames"],
                                batch["tokens"])
    ce = cross_entropy(logits, batch["targets"])
    n_tok = jnp.sum(batch["targets"] != IGNORE).astype(jnp.float32)
    return ce, {"ce": ce, "aux": aux, "n_tokens": n_tok}
