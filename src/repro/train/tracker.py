"""Tracker — the metrics emission layer of the trainer (levanter-style).

A :class:`Tracker` receives one flat ``{name: scalar}`` dict per logging
interval via ``log(metrics, step=...)`` and is closed with ``finish()``.
The trainer and the train bench emit through this seam so every consumer
(the CI bench pipeline, a human tailing a file, a no-op in unit tests)
sees the same stream: loss, tokens/sec, grad-compression ratio, and the
per-layer **bit-flip rate** (fraction of binarized weights whose sign
changed this step — the training-health signal of Bethge et al.
1809.10463: a healthy BNN run starts with high flip rates that decay as
the signs settle; a flat-zero or non-decaying curve is a dead or thrashing
run).

Implementations:

* :class:`NoopTracker` — swallows everything (the default).
* :class:`JsonlTracker` — appends one JSON object per ``log`` call
  (``{"step": N, ...metrics}``) to a file; the artifact the bench-smoke CI
  job uploads next to ``BENCH_ci.json``.
* :class:`CompositeTracker` — fans out to several trackers.

All trackers are context managers (``finish`` on exit) and coerce jax/numpy
scalars to Python floats, so ``log`` can be fed a jitted step's metrics
dict directly.
"""

from __future__ import annotations

import json
import math
from typing import Any, Mapping


def _to_float(v: Any) -> float:
    try:
        return float(v)
    except (TypeError, ValueError):
        return math.nan


class Tracker:
    """Metric sink interface: ``log(metrics, step=...)`` then ``finish()``."""

    def log(self, metrics: Mapping[str, Any], *, step: int) -> None:
        raise NotImplementedError

    def finish(self) -> None:  # idempotent
        pass

    def __enter__(self) -> "Tracker":
        return self

    def __exit__(self, *exc) -> None:
        self.finish()


class NoopTracker(Tracker):
    def log(self, metrics: Mapping[str, Any], *, step: int) -> None:
        pass


class JsonlTracker(Tracker):
    """One JSON object per ``log`` call, appended to ``path``.

    Each line is ``{"step": N, "<name>": <float>, ...}``; lines are flushed
    on write so a crashed run keeps everything logged so far, and the file
    is valid JSONL at every instant (the bench pipeline ingests partial
    files).
    """

    def __init__(self, path: str, *, append: bool = False):
        self.path = path
        self._f = open(path, "a" if append else "w")

    def log(self, metrics: Mapping[str, Any], *, step: int) -> None:
        if self._f is None:
            raise ValueError(f"JsonlTracker({self.path!r}) already finished")
        row = {"step": int(step)}
        row.update({k: _to_float(v) for k, v in metrics.items()})
        self._f.write(json.dumps(row) + "\n")
        self._f.flush()

    def finish(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


class CompositeTracker(Tracker):
    def __init__(self, trackers: list[Tracker]):
        self.trackers = list(trackers)

    def log(self, metrics: Mapping[str, Any], *, step: int) -> None:
        for t in self.trackers:
            t.log(metrics, step=step)

    def finish(self) -> None:
        for t in self.trackers:
            t.finish()


def read_jsonl(path: str) -> list[dict]:
    """Parse a JsonlTracker artifact back into a list of metric rows."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
