"""Train-step factory: grad + clip + AdamW, with microbatch accumulation,
remat, and optional 1-bit cross-member gradient compression.

Two step factories share one gradient-accumulation core:

* ``make_train_step(spec, ...)`` — the single-program step

      (params, opt_state, batch) -> (params, opt_state, metrics)

  suitable for ``jax.jit`` with in/out shardings from dist/sharding.py.
  The same function lowers on 1 CPU device (smoke tests) and on the
  256/512-chip production meshes (dry-run) — that symmetry is the whole
  point.

* ``make_sharded_train_step(spec, ..., train_cfg, mesh)`` — the DP(xTP)
  step over :class:`TrainState`: the whole step runs inside ``shard_map``,
  each data-parallel member computes gradients on its batch shard, and the
  gradient exchange over ``TrainConfig.dp_axis`` is either the plain
  ``psum`` mean (the CI-gated oracle — bit-identical to the single-device
  step with ``microbatch=dp``, because XLA's psum reduces members in ring
  order exactly like the microbatch scan's left fold) or the 1-bit
  error-feedback collective ``dist.compress.compressed_psum`` (the paper's
  ~32x wire shrink, §2.2.3, applied to training traffic).  The EF residual
  is member-local state: :class:`TrainState.ef` leaves carry a leading
  ``(dp, ...)`` member axis sharded over ``dp_axis``, so checkpointing the
  state makes compressed-training resume exact.  Non-DP mesh axes (e.g.
  'model') pass through the body replicated — size 1 on the CPU smoke
  rig; large-model tensor parallelism stays on the GSPMD
  :class:`TrainLayouts` path.

Metrics: both steps emit ``loss``/``ce``/``aux``/``n_tokens`` (summed, not
averaged, across microbatches and members)/``grad_norm``/``lr``; with
``bit_flip_metrics`` they add the 1809.10463 training-health signal — the
per-layer fraction of binarized weights whose master sign changed this
step (``bit_flip/<layer>`` + the weighted overall ``bit_flip_rate``) — and
the compressed step reports the static wire ``grad_compress_ratio``.  Feed
the dict to a ``train.tracker.Tracker``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.configs.common import ArchSpec
from repro.core.policy import PolicySchedule, QuantPolicy
from repro.dist import compress as dist_compress
from repro.models import lm as lm_model
from repro.models import whisper as whisper_model
from repro.nn.common import QCtx
from repro.optim import adamw
from repro.train import losses

Pytree = Any

# aux metric keys accumulated by SUM (not mean) across microbatches and DP
# members — counters, not averages
SUM_AUX_KEYS = ("n_tokens",)


@dataclasses.dataclass(frozen=True)
class TrainLayouts:
    """ZeRO-1 layout pair (pytrees of NamedSharding).

    ``compute``: TP-only (weights replicated across 'data') — what the
    matmuls contract against.  ``master``: fp32 master params + moments
    sharded over ('data' x 'model').  The step casts/constrains between
    them: one bf16 all-gather (params) + one fp32 reduce-scatter (grads)
    per step, instead of GSPMD resharding activations (DESIGN.md §5).
    """

    compute: object
    master: object


@dataclasses.dataclass
class TrainState:
    """Everything a training run must checkpoint to resume exactly.

    ``params``: fp32 master parameters.  ``opt_state``: AdamW moments +
    step.  ``ef``: the member-local 1-bit error-feedback residual pytree —
    leaves shaped ``(dp, *param.shape)`` (leading axis = DP member, sharded
    over the data axis inside the sharded step) when gradient compression
    is on, the empty pytree ``{}`` otherwise.  Registered as a jax pytree
    and understood by ckpt/manager.py, so ``CheckpointManager.save(step,
    state)`` round-trips it bit-exactly.
    """

    params: Pytree
    opt_state: Pytree
    ef: Pytree


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt_state, s.ef), None),
    lambda _, children: TrainState(*children),
)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Knobs of the sharded train step (jit-static).

    ``grad_compress`` selects the 1-bit EF collective for the DP gradient
    exchange; ``microbatch`` is the number of *per-member* sequential
    accumulation chunks; ``bit_flip_metrics`` emits the per-layer
    binarized-sign-flip rates (no-op metrics-wise when the policy has no
    binary GEMMs).
    """

    remat: bool = False
    microbatch: int | None = None
    grad_compress: bool = False
    dp_axis: str = "data"
    scan_blocks: bool = False
    seq_parallel: bool = False
    bit_flip_metrics: bool = False


def _constrain(tree, shardings):
    return jax.tree.map(jax.lax.with_sharding_constraint, tree, shardings)


def _cast_floating(tree, dtype):
    def c(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(c, tree)


def loss_fn_for(spec: ArchSpec) -> Callable:
    if spec.family == "lm":
        return functools.partial(losses.lm_loss, lm_model.forward)
    if spec.family == "whisper":
        return functools.partial(losses.whisper_loss, whisper_model.forward)
    raise ValueError(spec.family)


def _split_micro(batch: dict, n: int) -> dict:
    """(B, ...) -> (n, B/n, ...) for scan-based accumulation."""
    def r(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(r, batch)


def _reduce_aux(aux: dict, reduce_mean, reduce_sum) -> dict:
    if not isinstance(aux, dict):
        return {}
    return {
        k: (reduce_sum(v) if k in SUM_AUX_KEYS else reduce_mean(v))
        for k, v in aux.items()
    }


def _accumulate_grads(grad_fn, params, batch, microbatch):
    """(loss, aux, fp32 grads), averaged over ``microbatch`` sequential
    chunks (left-fold scan; ``SUM_AUX_KEYS`` aux entries summed instead).

    The single-chunk form is the plain ``grad_fn`` call; the DP step's
    psum over members continues the same fold (XLA ring order), which is
    what makes DP(dp) bit-identical to microbatch=dp on one device.
    """
    if not microbatch or microbatch <= 1:
        (loss, aux), grads = grad_fn(params, batch)
        return loss, aux, _cast_floating(grads, jnp.float32)

    micro = _split_micro(batch, microbatch)

    def acc(carry, mb):
        g_acc, l_acc = carry
        (l, aux), g = grad_fn(params, mb)
        g_acc = jax.tree.map(
            lambda a, b: a + b.astype(jnp.float32), g_acc, g
        )
        return (g_acc, l_acc + l), aux

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (g_sum, loss_sum), aux_stack = jax.lax.scan(acc, (g0, 0.0), micro)
    grads = jax.tree.map(lambda g: g / microbatch, g_sum)
    # aux rides along as stacked (microbatch,) scan outputs: average them
    # (sum for counters) so metrics parity holds with the non-microbatch
    # path instead of silently dropping aux
    aux = _reduce_aux(aux_stack, lambda v: v.mean(0), lambda v: v.sum(0))
    return loss_sum / microbatch, aux, grads


# ---------------------------------------------------------------------------
# bit-flip-rate metrics (Bethge et al. 1809.10463 §5: the fraction of
# binarized weights whose sign changed this step — high early, decaying as
# training settles; flat zero = dead, non-decaying = thrashing)
# ---------------------------------------------------------------------------


def binary_weight_paths(params: Pytree, policy: QuantPolicy) -> list[str]:
    """Paths of weight leaves the policy binarizes (w_bits == 1).

    Matches the layer-path convention of nn.common.QCtx: a GEMM weight
    leaf ``.../<layer>/w`` is binarized iff ``policy.spec(".../<layer>")``
    says so.  Pure tree-structure walk — safe on tracers.
    """
    out: list[str] = []

    def rec(node, path):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(v, f"{path}/{k}" if path else str(k))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(v, f"{path}/{i}" if path else str(i))
        elif (path.endswith("/w") and getattr(node, "ndim", 0) >= 2
              and policy.spec(path[:-2]).w_bits == 1):
            out.append(path)

    rec(params, "")
    return out


def _get_by_path(tree: Pytree, path: str):
    node = tree
    for seg in path.split("/"):
        node = node[int(seg)] if isinstance(node, (list, tuple)) else node[seg]
    return node


def bit_flip_metrics(
    policy: QuantPolicy, old_params: Pytree, new_params: Pytree
) -> dict:
    """Per-layer + overall sign-flip rates of the binarized master weights
    between two steps.  ``{}`` when the policy binarizes nothing."""
    paths = binary_weight_paths(old_params, policy)
    if not paths:
        return {}
    out = {}
    flips_total = 0.0
    n_total = 0
    for p in paths:
        a = _get_by_path(old_params, p)
        b = _get_by_path(new_params, p)
        # sign convention of core.quant.binarize: x >= 0 -> +1
        flips = jnp.sum(((a >= 0) != (b >= 0)).astype(jnp.float32))
        out[f"bit_flip/{p[:-2]}"] = flips / a.size
        flips_total = flips_total + flips
        n_total += a.size
    out["bit_flip_rate"] = flips_total / n_total
    return out


# ---------------------------------------------------------------------------
# single-program step (GSPMD / single device)
# ---------------------------------------------------------------------------


def make_train_step(
    spec: ArchSpec,
    cfg: Any,
    ctx: QCtx,
    opt_cfg: adamw.AdamWConfig,
    *,
    remat: bool = True,
    microbatch: int | None = None,
    layouts: TrainLayouts | None = None,
    scan_blocks: bool = False,
    seq_parallel: bool = False,
    bit_flip_metrics_on: bool = False,
):
    """ZeRO-1 step over (master fp32 params, opt state, batch)."""
    loss_fn = loss_fn_for(spec)

    def compute_loss(params, batch):
        return loss_fn(params, cfg, ctx, batch, remat=remat,
                       scan_blocks=scan_blocks, seq_parallel=seq_parallel)

    grad_fn = jax.value_and_grad(compute_loss, has_aux=True)

    def train_step(master, opt_state, batch):
        # master (ZeRO-sharded fp32) -> compute layout (TP-only, bf16):
        # GSPMD lowers the constraint to one bf16 all-gather over 'data'.
        params = _cast_floating(master, ctx.compute_dtype)
        if layouts is not None:
            params = _constrain(params, layouts.compute)

        loss, aux, grads = _accumulate_grads(grad_fn, params, batch,
                                             microbatch)

        # grads -> master layout in fp32: one reduce-scatter over 'data'
        if layouts is not None:
            grads = _constrain(grads, layouts.master)

        new_master, opt_state, opt_metrics = adamw.update(
            grads, opt_state, master, opt_cfg
        )
        metrics = {"loss": loss, **opt_metrics}
        if isinstance(aux, dict):
            metrics.update(aux)
        if bit_flip_metrics_on:
            metrics.update(bit_flip_metrics(ctx.policy, master, new_master))
        return new_master, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# sharded DP(xTP) step over TrainState
# ---------------------------------------------------------------------------


def _axis_size(mesh, axis: str) -> int:
    shape = mesh.shape
    sizes = dict(shape) if hasattr(shape, "keys") else dict(
        zip(mesh.axis_names, shape))
    if axis not in sizes:
        raise ValueError(
            f"dp axis {axis!r} not on mesh axes {tuple(sizes)}"
        )
    return sizes[axis]


def train_state_init(
    spec: ArchSpec,
    cfg: Any,
    key: jax.Array,
    *,
    grad_compress: bool = False,
    dp: int = 1,
) -> TrainState:
    """Fresh :class:`TrainState`; ``ef`` is zeros with a leading ``(dp,)``
    member axis when compressing, the empty pytree otherwise."""
    params, opt_state = init_all(spec, cfg, key)
    ef: Pytree = {}
    if grad_compress:
        ef = jax.tree.map(
            lambda p: jnp.zeros((max(dp, 1),) + p.shape, jnp.float32), params
        )
    return TrainState(params=params, opt_state=opt_state, ef=ef)


def ef_matches(state: TrainState, dp: int) -> bool:
    """Whether ``state.ef`` was produced at this DP degree (elastic resume
    onto a different data-axis size must re-init the residual)."""
    leaves = jax.tree.leaves(state.ef)
    return all(leaf.shape[0] == dp for leaf in leaves)


def make_sharded_train_step(
    spec: ArchSpec,
    cfg: Any,
    ctx: QCtx,
    opt_cfg: adamw.AdamWConfig,
    train_cfg: TrainConfig,
    mesh,
):
    """DP(xTP) step ``(TrainState, batch) -> (TrainState, metrics)``.

    The batch shards over ``train_cfg.dp_axis`` (dim 0 of every leaf);
    params/opt replicate; ``state.ef`` leaves shard their leading member
    axis.  Inside the ``shard_map`` body each member runs the (optionally
    microbatched) gradient computation on its shard, then the gradient
    mean over the DP axis is either ``lax.psum / dp`` (uncompressed — the
    bit-identical oracle) or ``dist.compress.compressed_psum`` (1-bit EF).
    The returned callable is jit-able (``jax.jit(step, donate_argnums=0)``);
    metrics come out replicated.

    TP note: mesh axes other than ``dp_axis`` pass through the body
    replicated, so a 2-D ('data', 'model') mesh works with any model-axis
    size but the body's compute does not partition over 'model' — the
    smoke rig runs model=1; large-model TP training uses the GSPMD
    ``TrainLayouts`` path.  ``ctx`` must therefore not carry a ``shard-*``
    GEMM backend or an MoE mesh (nested shard_map).
    """
    tc = train_cfg
    dp = _axis_size(mesh, tc.dp_axis)
    loss_fn = loss_fn_for(spec)

    def compute_loss(params, batch):
        return loss_fn(params, cfg, ctx, batch, remat=tc.remat,
                       scan_blocks=tc.scan_blocks,
                       seq_parallel=tc.seq_parallel)

    grad_fn = jax.value_and_grad(compute_loss, has_aux=True)

    def body(master, opt_state, ef, batch):
        params = _cast_floating(master, ctx.compute_dtype)
        loss, aux, grads = _accumulate_grads(grad_fn, params, batch,
                                             tc.microbatch)

        extra = {}
        if tc.grad_compress:
            extra["grad_compress_ratio"] = (
                dist_compress.payload_bytes(grads, compressed=False)
                / dist_compress.payload_bytes(grads, compressed=True)
            )
            # residual is member-local: drop this member's leading axis,
            # compress + psum-mean, carry the new residual back
            e_local = jax.tree.map(lambda x: x[0], ef)
            grads, e_new = dist_compress.compressed_psum(
                grads, e_local, tc.dp_axis
            )
            ef = jax.tree.map(lambda x: x[None], e_new)
        else:
            # psum continues the microbatch scan's left fold in ring
            # order -> bit-identical to microbatch=dp on one device
            grads = jax.tree.map(
                lambda g: jax.lax.psum(g, tc.dp_axis) / dp, grads
            )
        loss = jax.lax.psum(loss, tc.dp_axis) / dp
        aux = _reduce_aux(
            aux,
            lambda v: jax.lax.psum(v, tc.dp_axis) / dp,
            lambda v: jax.lax.psum(v, tc.dp_axis),
        )

        new_master, opt_state, opt_metrics = adamw.update(
            grads, opt_state, master, opt_cfg
        )
        metrics = {"loss": loss, **opt_metrics, **aux, **extra}
        if tc.bit_flip_metrics:
            metrics.update(bit_flip_metrics(ctx.policy, master, new_master))
        return new_master, opt_state, ef, metrics

    P = jax.sharding.PartitionSpec
    sharded = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P(), P(tc.dp_axis), P(tc.dp_axis)),
        out_specs=(P(), P(), P(tc.dp_axis), P()),
        check_vma=False,
    )

    def step(state: TrainState, batch):
        params, opt_state, ef, metrics = sharded(
            state.params, state.opt_state, state.ef, batch
        )
        return TrainState(params=params, opt_state=opt_state, ef=ef), metrics

    return step


class PolicyScheduledStep:
    """Host-side dispatcher over a :class:`core.policy.PolicySchedule`.

    ``build_fn(policy) -> step`` is called lazily once per schedule stage
    (a QuantPolicy is jit-static, so each stage owns one compiled step);
    calling ``(state, batch, step=i)`` routes to the stage containing
    ``i``.  Carried state (TrainState / params trees) flows across stage
    boundaries unchanged — only the compiled computation swaps.
    """

    def __init__(self, build_fn: Callable, schedule: PolicySchedule):
        self._build = build_fn
        self.schedule = schedule
        self._cache: dict[int, Callable] = {}

    def __call__(self, state, batch, *, step: int):
        idx = self.schedule.stage_index(step)
        fn = self._cache.get(idx)
        if fn is None:
            fn = self._cache[idx] = self._build(self.schedule.stages[idx][1])
        return fn(state, batch)

    @property
    def compiled_stages(self) -> int:
        return len(self._cache)


def init_all(spec: ArchSpec, cfg: Any, key: jax.Array):
    """(params, opt_state) init for any family."""
    if spec.family == "lm":
        params = lm_model.init(key, cfg)
    elif spec.family == "whisper":
        params = whisper_model.init(key, cfg)
    else:
        raise ValueError(spec.family)
    return params, adamw.init(params)
