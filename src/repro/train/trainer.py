"""Train-step factory: grad + clip + AdamW, with microbatch accumulation,
remat, and optional 1-bit cross-pod gradient compression.

``make_train_step(spec, ...)`` returns a pure function

    (params, opt_state, batch) -> (params, opt_state, metrics)

suitable for ``jax.jit`` with in/out shardings from dist/sharding.py.  The
same function lowers on 1 CPU device (smoke tests) and on the 256/512-chip
production meshes (dry-run) — that symmetry is the whole point.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

import dataclasses

from repro.configs.common import ArchSpec
from repro.models import lm as lm_model
from repro.models import whisper as whisper_model
from repro.nn.common import QCtx
from repro.optim import adamw
from repro.train import losses


@dataclasses.dataclass(frozen=True)
class TrainLayouts:
    """ZeRO-1 layout pair (pytrees of NamedSharding).

    ``compute``: TP-only (weights replicated across 'data') — what the
    matmuls contract against.  ``master``: fp32 master params + moments
    sharded over ('data' x 'model').  The step casts/constrains between
    them: one bf16 all-gather (params) + one fp32 reduce-scatter (grads)
    per step, instead of GSPMD resharding activations (DESIGN.md §5).
    """

    compute: object
    master: object


def _constrain(tree, shardings):
    return jax.tree.map(jax.lax.with_sharding_constraint, tree, shardings)


def _cast_floating(tree, dtype):
    def c(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(c, tree)


def loss_fn_for(spec: ArchSpec) -> Callable:
    if spec.family == "lm":
        return functools.partial(losses.lm_loss, lm_model.forward)
    if spec.family == "whisper":
        return functools.partial(losses.whisper_loss, whisper_model.forward)
    raise ValueError(spec.family)


def _split_micro(batch: dict, n: int) -> dict:
    """(B, ...) -> (n, B/n, ...) for scan-based accumulation."""
    def r(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(
    spec: ArchSpec,
    cfg: Any,
    ctx: QCtx,
    opt_cfg: adamw.AdamWConfig,
    *,
    remat: bool = True,
    microbatch: int | None = None,
    layouts: TrainLayouts | None = None,
    scan_blocks: bool = False,
    seq_parallel: bool = False,
):
    """ZeRO-1 step over (master fp32 params, opt state, batch)."""
    loss_fn = loss_fn_for(spec)

    def compute_loss(params, batch):
        return loss_fn(params, cfg, ctx, batch, remat=remat,
                       scan_blocks=scan_blocks, seq_parallel=seq_parallel)

    grad_fn = jax.value_and_grad(compute_loss, has_aux=True)

    def train_step(master, opt_state, batch):
        # master (ZeRO-sharded fp32) -> compute layout (TP-only, bf16):
        # GSPMD lowers the constraint to one bf16 all-gather over 'data'.
        params = _cast_floating(master, ctx.compute_dtype)
        if layouts is not None:
            params = _constrain(params, layouts.compute)

        if microbatch and microbatch > 1:
            micro = _split_micro(batch, microbatch)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, _aux), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss_sum), _ = jax.lax.scan(acc, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss = loss_sum / microbatch
            aux = {}
        else:
            (loss, aux), grads = grad_fn(params, batch)

        # grads -> master layout in fp32: one reduce-scatter over 'data'
        grads = _cast_floating(grads, jnp.float32)
        if layouts is not None:
            grads = _constrain(grads, layouts.master)

        master, opt_state, opt_metrics = adamw.update(
            grads, opt_state, master, opt_cfg
        )
        metrics = {"loss": loss, **opt_metrics}
        if isinstance(aux, dict):
            metrics.update(aux)
        return master, opt_state, metrics

    return train_step


def init_all(spec: ArchSpec, cfg: Any, key: jax.Array):
    """(params, opt_state) init for any family."""
    if spec.family == "lm":
        params = lm_model.init(key, cfg)
    elif spec.family == "whisper":
        params = whisper_model.init(key, cfg)
    else:
        raise ValueError(spec.family)
    return params, adamw.init(params)
