"""Paper-native: binary LeNet on MNIST (Table 1, Listing 2)."""

from repro.configs.common import ArchSpec
from repro.models.cnn import LeNetConfig

SPEC = ArchSpec(
    arch_id="lenet-mnist",
    family="cnn",
    config=LeNetConfig(),
    smoke=LeNetConfig(c1=8, c2=8, fc1=32, in_hw=20),
)
