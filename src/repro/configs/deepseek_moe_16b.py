"""deepseek-moe-16b [moe] — fine-grained: 2 shared + 64 routed top-6,
first layer dense-FFN. [arXiv:2401.06066]"""

from repro.configs.common import ArchSpec
from repro.models.lm import LMConfig
from repro.nn.attention import AttnConfig
from repro.nn.mlp import MLPConfig, MoEConfig


def _cfg(n_layers, d, heads, kv, dh, d_expert, vocab, n_routed, top_k,
         n_shared, first_ff):
    return LMConfig(
        name="deepseek-moe-16b",
        n_layers=n_layers,
        d_model=d,
        vocab_size=vocab,
        ffn_pattern=("moe",),
        attn=AttnConfig(d_model=d, n_heads=heads, n_kv_heads=kv, d_head=dh,
                        rope_theta=10000.0),
        moe=MoEConfig(d_model=d, d_expert=d_expert, n_routed=n_routed,
                      n_shared=n_shared, top_k=top_k, act="silu",
                      router_scale_norm=False),
        first_dense_layers=1,
        first_dense_mlp=MLPConfig(d_model=d, d_ff=first_ff, act="silu"),
    )


SPEC = ArchSpec(
    arch_id="deepseek-moe-16b",
    family="lm",
    config=_cfg(28, 2048, 16, 16, 128, 1408, 102400, 64, 6, 2, 10944),
    smoke=_cfg(2, 64, 4, 4, 16, 48, 512, 8, 2, 1, 128),
)
