"""qwen2-72b [dense] — GQA kv=8, QKV bias. [arXiv:2407.10671]"""

from repro.configs.common import ArchSpec
from repro.models.lm import LMConfig
from repro.nn.attention import AttnConfig
from repro.nn.mlp import MLPConfig


def _cfg(n_layers, d, heads, kv, dh, ff, vocab):
    return LMConfig(
        name="qwen2-72b",
        n_layers=n_layers,
        d_model=d,
        vocab_size=vocab,
        attn=AttnConfig(d_model=d, n_heads=heads, n_kv_heads=kv, d_head=dh,
                        rope_theta=1_000_000.0, qkv_bias=True),
        mlp=MLPConfig(d_model=d, d_ff=ff, act="silu"),
    )


SPEC = ArchSpec(
    arch_id="qwen2-72b",
    family="lm",
    config=_cfg(80, 8192, 64, 8, 128, 29568, 152064),
    smoke=_cfg(2, 64, 4, 2, 16, 192, 512),
)
