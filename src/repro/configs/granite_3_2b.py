"""granite-3-2b [dense] — GQA kv=8, tied embeddings. [hf:ibm-granite/granite-3.0-2b-base]"""

from repro.configs.common import ArchSpec
from repro.models.lm import LMConfig
from repro.nn.attention import AttnConfig
from repro.nn.mlp import MLPConfig


def _cfg(n_layers, d, heads, kv, dh, ff, vocab):
    return LMConfig(
        name="granite-3-2b",
        n_layers=n_layers,
        d_model=d,
        vocab_size=vocab,
        attn=AttnConfig(d_model=d, n_heads=heads, n_kv_heads=kv, d_head=dh,
                        rope_theta=10000.0),
        mlp=MLPConfig(d_model=d, d_ff=ff, act="silu"),
        tie_embeddings=True,
        vocab_pad_to=256,
    )


SPEC = ArchSpec(
    arch_id="granite-3-2b",
    family="lm",
    config=_cfg(40, 2048, 32, 8, 64, 8192, 49155),
    smoke=_cfg(2, 64, 4, 2, 16, 160, 512),
)
