"""internvl2-1b [vlm] — Qwen2-0.5B backbone + InternViT frontend STUB
(precomputed patch embeddings, 256 positions, d_vision=1024).
[arXiv:2404.16821]"""

from repro.configs.common import ArchSpec
from repro.models.lm import LMConfig
from repro.nn.attention import AttnConfig
from repro.nn.mlp import MLPConfig


def _cfg(n_layers, d, heads, kv, dh, ff, vocab, prefix, d_vision):
    return LMConfig(
        name="internvl2-1b",
        n_layers=n_layers,
        d_model=d,
        vocab_size=vocab,
        attn=AttnConfig(d_model=d, n_heads=heads, n_kv_heads=kv, d_head=dh,
                        rope_theta=1_000_000.0, qkv_bias=True),
        mlp=MLPConfig(d_model=d, d_ff=ff, act="silu"),
        tie_embeddings=True,
        vision_prefix=prefix,
        vocab_pad_to=256,
        d_vision=d_vision,
    )


SPEC = ArchSpec(
    arch_id="internvl2-1b",
    family="lm",
    config=_cfg(24, 896, 14, 2, 64, 4864, 151655, 256, 1024),
    smoke=_cfg(2, 64, 2, 2, 32, 160, 512, 8, 48),
)
