"""ArchSpec — the registry entry every ``configs/<id>.py`` exports.

``config`` is the exact assigned architecture; ``smoke`` is the reduced
same-family variant exercised on CPU by tests (the full config is only ever
lowered abstractly in the dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # "lm" | "whisper" | "cnn"
    config: Any
    smoke: Any
    supports_long: bool = False  # may run the long_500k cell
    notes: str = ""
