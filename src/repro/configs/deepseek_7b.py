"""deepseek-7b [dense] — llama-arch MHA. [arXiv:2401.02954]"""

from repro.configs.common import ArchSpec
from repro.models.lm import LMConfig
from repro.nn.attention import AttnConfig
from repro.nn.mlp import MLPConfig


def _cfg(n_layers, d, heads, kv, dh, ff, vocab):
    return LMConfig(
        name="deepseek-7b",
        n_layers=n_layers,
        d_model=d,
        vocab_size=vocab,
        attn=AttnConfig(d_model=d, n_heads=heads, n_kv_heads=kv, d_head=dh,
                        rope_theta=10000.0),
        mlp=MLPConfig(d_model=d, d_ff=ff, act="silu"),
    )


SPEC = ArchSpec(
    arch_id="deepseek-7b",
    family="lm",
    config=_cfg(30, 4096, 32, 32, 128, 11008, 102400),
    smoke=_cfg(2, 64, 4, 4, 16, 160, 512),
)
