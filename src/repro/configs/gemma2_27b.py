"""gemma2-27b [dense] — alternating local/global attention, logit softcaps,
post-sublayer norms, tied + scaled embeddings. [arXiv:2408.00118]"""

from repro.configs.common import ArchSpec
from repro.models.lm import LMConfig
from repro.nn.attention import AttnConfig
from repro.nn.mlp import MLPConfig


def _cfg(n_layers, d, heads, kv, dh, ff, vocab, window=4096, q_scalar=None):
    base = AttnConfig(
        d_model=d, n_heads=heads, n_kv_heads=kv, d_head=dh,
        rope_theta=10000.0, logit_softcap=50.0,
        query_scale=(q_scalar or dh) ** -0.5,
    )
    import dataclasses
    return LMConfig(
        name="gemma2-27b",
        n_layers=n_layers,
        d_model=d,
        vocab_size=vocab,
        mixer_pattern=("local_attn", "attn"),  # sliding first, then global
        attn=base,
        local_attn=dataclasses.replace(base, window=window),
        mlp=MLPConfig(d_model=d, d_ff=ff, act="gelu"),
        post_norm=True,
        embed_scale=True,
        tie_embeddings=True,
        logit_softcap=30.0,
    )


SPEC = ArchSpec(
    arch_id="gemma2-27b",
    family="lm",
    # 27b uses query_pre_attn_scalar = d_model / n_heads = 144
    config=_cfg(46, 4608, 32, 16, 128, 36864, 256000, q_scalar=144),
    smoke=_cfg(2, 64, 4, 2, 16, 256, 512, window=32),
)
