"""whisper-base [audio] — enc-dec; conv/mel frontend STUB (precomputed
frame embeddings, 1500 positions).  Assigned shapes apply to the decoder.
[arXiv:2212.04356]"""

from repro.configs.common import ArchSpec
from repro.models.whisper import WhisperConfig

SPEC = ArchSpec(
    arch_id="whisper-base",
    family="whisper",
    config=WhisperConfig(
        name="whisper-base", n_layers=6, d_model=512, n_heads=8, d_ff=2048,
        vocab_size=51865, t_enc=1500, max_dec=32768,
    ),
    smoke=WhisperConfig(
        name="whisper-base-smoke", n_layers=2, d_model=64, n_heads=4,
        d_ff=128, vocab_size=512, t_enc=30, max_dec=64,
    ),
)
