"""rwkv6-7b [ssm] — Finch: attention-free, data-dependent decay WKV6.
O(1) decode state => runs long_500k. [arXiv:2404.05892]"""

from repro.configs.common import ArchSpec
from repro.models.lm import LMConfig
from repro.nn.rwkv6 import RWKV6Config


def _cfg(n_layers, d, heads, dh, ff, vocab):
    return LMConfig(
        name="rwkv6-7b",
        n_layers=n_layers,
        d_model=d,
        vocab_size=vocab,
        mixer_pattern=("rwkv6",),
        ffn_pattern=("rwkv_cmix",),
        rwkv=RWKV6Config(d_model=d, n_heads=heads, d_head=dh, d_ff=ff),
        norm="layernorm",
        embed_norm=True,
    )


SPEC = ArchSpec(
    arch_id="rwkv6-7b",
    family="lm",
    config=_cfg(32, 4096, 64, 64, 14336, 65536),
    smoke=_cfg(2, 64, 4, 16, 224, 512),
    supports_long=True,
)
