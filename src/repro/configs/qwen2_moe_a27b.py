"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4 (padded to 64 for EP
divisibility on the 16-way model axis; pad experts are router-masked).
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""

from repro.configs.common import ArchSpec
from repro.models.lm import LMConfig
from repro.nn.attention import AttnConfig
from repro.nn.mlp import MoEConfig


def _cfg(n_layers, d, heads, kv, dh, d_expert, vocab, n_routed, top_k,
         n_shared, n_padded):
    return LMConfig(
        name="qwen2-moe-a2.7b",
        n_layers=n_layers,
        d_model=d,
        vocab_size=vocab,
        ffn_pattern=("moe",),
        attn=AttnConfig(d_model=d, n_heads=heads, n_kv_heads=kv, d_head=dh,
                        rope_theta=1_000_000.0, qkv_bias=True),
        moe=MoEConfig(d_model=d, d_expert=d_expert, n_routed=n_routed,
                      n_shared=n_shared, top_k=top_k, act="silu",
                      n_routed_padded=n_padded, router_scale_norm=False),
    )


SPEC = ArchSpec(
    arch_id="qwen2-moe-a2.7b",
    family="lm",
    config=_cfg(24, 2048, 16, 16, 128, 1408, 151936, 60, 4, 4, 64),
    smoke=_cfg(2, 64, 4, 4, 16, 48, 512, 6, 2, 1, 8),
)
