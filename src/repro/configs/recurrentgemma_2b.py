"""recurrentgemma-2b [hybrid] — Griffin: (RG-LRU, RG-LRU, local-attn) cycle,
MQA kv=1, window 2048.  Sub-quadratic => runs long_500k. [arXiv:2402.19427]"""

from repro.configs.common import ArchSpec
from repro.models.lm import LMConfig
from repro.nn.attention import AttnConfig
from repro.nn.mlp import MLPConfig
from repro.nn.rglru import RGLRUConfig


def _cfg(n_layers, d, heads, kv, dh, ff, vocab, window, n_blocks):
    return LMConfig(
        name="recurrentgemma-2b",
        n_layers=n_layers,
        d_model=d,
        vocab_size=vocab,
        mixer_pattern=("rglru", "rglru", "local_attn"),
        local_attn=AttnConfig(d_model=d, n_heads=heads, n_kv_heads=kv,
                              d_head=dh, rope_theta=10000.0, window=window),
        rglru=RGLRUConfig(d_model=d, d_rnn=d, n_blocks=n_blocks),
        mlp=MLPConfig(d_model=d, d_ff=ff, act="gelu"),
        embed_scale=True,
        tie_embeddings=True,
    )


SPEC = ArchSpec(
    arch_id="recurrentgemma-2b",
    family="lm",
    config=_cfg(26, 2560, 10, 1, 256, 7680, 256000, 2048, 10),
    smoke=_cfg(3, 64, 2, 1, 32, 160, 512, 32, 2),
    supports_long=True,
)
