"""Paper-native: binary ResNet-18 on CIFAR-10 (Table 1) with the 4-stage
layout used for the partial-binarization study (Table 2)."""

from repro.configs.common import ArchSpec
from repro.models.cnn import ResNet18Config

SPEC = ArchSpec(
    arch_id="resnet18-cifar10",
    family="cnn",
    config=ResNet18Config(),
    smoke=ResNet18Config(widths=(8, 8, 16, 16), in_hw=16),
)
