"""Shared launcher flag surface.

``launch/serve.py`` and ``launch/dryrun.py`` expose the same quantized-GEMM
execution knobs (backend choice, shard layout, fused-vs-jnp activation
prologue, MoE capacity factor); this module owns that block once so the
two parsers cannot drift.  Callers pick the flag spelling and default
(serve keeps ``--xnor-backend``/``--backend`` defaulting to ``vpu``,
dryrun keeps ``--gemm-backend`` defaulting to the in-graph ``xla``
lowering) — the parsed value always lands on ``args.gemm_backend``.
"""

from __future__ import annotations

import argparse

from repro.kernels.dispatch import GemmConfig

GEMM_BACKENDS = [
    "xla", "vpu", "mxu",
    "vpu-k2", "vpu-k4", "vpu-k8",
    "shard-vpu", "shard-mxu",
    "shard-vpu-k2", "shard-vpu-k4", "shard-vpu-k8",
]


def add_gemm_flags(ap: argparse.ArgumentParser, *names: str,
                   default: str = "xla", shard: bool = False,
                   help: str | None = None) -> None:
    """The backend/layout flag block.  ``names`` are the flag spellings
    (first is canonical, the rest aliases); ``shard=True`` adds the
    tensor-parallel ``--shard`` / ``--shard-layout`` knobs (dryrun sizes
    its mesh itself, so it leaves them off)."""
    ap.add_argument(*names, dest="gemm_backend", default=default,
                    choices=GEMM_BACKENDS,
                    help=help or (
                        "base GEMM backend; k-bit layers resolve base "
                        "names onto the vpu-k* plane kernels, and the "
                        "shard-* family runs the same kernels tensor-"
                        "parallel"))
    if shard:
        ap.add_argument("--shard", type=int, default=0,
                        help="tensor-parallel ways for shard-* backends "
                             "(1-D 'model' mesh; 0 = all local devices)")
        ap.add_argument("--shard-layout", default="k", choices=["k", "n"],
                        help="shard-* operand layout: 'k' partitions the "
                             "packed contraction (Kw-partial popcount + "
                             "psum; activations quantize+pack INSIDE the "
                             "shard_map body), 'n' partitions weight "
                             "output rows (acts pack once and broadcast)")
    ap.add_argument("--jnp-prologue", action="store_true",
                    help="use the jnp reference quantize->pack path "
                         "instead of the fused Pallas prologue kernels "
                         "(the equivalence oracle; slower)")
    ap.add_argument("--capacity-factor", type=float, default=None,
                    help="MoE expert-capacity factor over the balanced "
                         "share for the EP path (default 2.0); overflow "
                         "rows drop and are never quantized or packed")


def add_attn_flags(ap: argparse.ArgumentParser) -> None:
    """The decode-attention execution/storage flag block (serve-only).
    ``--fused-attn`` swaps the gather + masked-sdpa decode path for the
    Pallas flash-decode kernel reading the KV storage in place
    (kernels/attn_decode.py); ``--kv-bits`` picks the KV storage tier —
    greedy output stays token-identical under fp KV, and quantized tiers
    are gated by their own bench error-bound + serve token rows."""
    ap.add_argument("--fused-attn", action="store_true",
                    help="route decode/window attention through the fused "
                         "Pallas flash-decode kernel (no dense KV gather); "
                         "off = the gather + masked-sdpa oracle path")
    ap.add_argument("--kv-bits", type=int, default=None, choices=[8, 1],
                    help="KV-cache storage tier: 8 = int8 codes + per-"
                         "(head, dh-group) absmax scales, 1 = sign bytes + "
                         "per-head alpha (the XNOR tier); default fp "
                         "compute dtype")


def add_spec_flags(ap: argparse.ArgumentParser) -> None:
    """The speculative-decoding flag block (serve-only).  ``--draft``
    derives a depth-sliced draft model from the loaded float checkpoint
    via ``converter.derive_draft`` — ``w1a1`` binarizes it through the
    packed-GEMM path (the paper's 1-bit deployment mode as the cheap
    proposer), ``fp`` keeps it float (a debugging oracle).  Greedy output
    is token-identical to non-speculative serving either way."""
    ap.add_argument("--draft", default=None, choices=["w1a1", "fp"],
                    help="enable speculative decoding with a depth-sliced "
                         "draft: 'w1a1' binarizes the slice (1-bit packed "
                         "GEMMs), 'fp' keeps it float; greedy outputs stay "
                         "token-identical to non-speculative serving")
    ap.add_argument("--spec-len", type=int, default=2,
                    help="proposed tokens per speculative round (the "
                         "target verifies spec_len + 1 positions in one "
                         "windowed call)")
    ap.add_argument("--draft-layers", type=int, default=None,
                    help="leading layers kept in the draft slice "
                         "(default: n_layers // 4, min 1)")


def gemm_config_from_args(args: argparse.Namespace) -> GemmConfig:
    """A GemmConfig from the flags :func:`add_gemm_flags` installed."""
    return GemmConfig(backend=args.gemm_backend,
                      shard_layout=getattr(args, "shard_layout", "k"),
                      fused_prologue=not args.jnp_prologue,
                      capacity_factor=args.capacity_factor)
