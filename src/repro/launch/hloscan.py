import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""HLO forensics for the dry-run: rank collectives by bytes, attribute them
to source ops, list the largest live buffers.  This is the 'profiler' of
the CPU-only perf loop (§Perf methodology: reason from the lowered IR)."""

import argparse
import collections
import re

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES
from repro.core.policy import QuantPolicy
from repro.dist.sharding import Resolver
from repro.kernels.dispatch import GemmConfig
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.nn.common import QCtx

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "f8": 1, "s8": 1,
          "u8": 1, "pred": 1}


def shape_bytes(dt, dims):
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _BYTES.get(dt, 4)


def build(arch, shape_name, quant="fp", multi_pod=False):
    spec = registry.get(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = (QuantPolicy.full_precision() if quant == "fp"
              else QuantPolicy.binary())
    packed = policy if quant == "binary_packed" and shape.kind != "train" else None
    ctx = QCtx(policy=policy, compute_dtype=jnp.bfloat16,
               gemm_config=GemmConfig(backend="xla"))
    rs = Resolver(mesh)
    cell = specs_lib.make_cell(spec, spec.config, ctx, shape,
                               packed_policy=packed, resolver=rs)
    shardings = tuple(rs.shardings(p) for p in cell.pspecs(rs))
    with mesh:
        jitted = jax.jit(cell.fn, in_shardings=shardings,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    return compiled


def scan_collectives(hlo: str, top: int = 25):
    rows = []
    for line in hlo.splitlines():
        m = re.search(
            r"=\s*(.*?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(-start)?\(", line)
        if not m or "-done(" in line:
            continue
        restype = m.group(1)
        kind = m.group(2)
        nbytes = sum(shape_bytes(d, s) for d, s in _SHAPE_RE.findall(restype))
        meta = re.search(r"metadata={op_name=\"([^\"]*)\"", line)
        rows.append((nbytes, kind, meta.group(1) if meta else line[:120]))
    rows.sort(reverse=True)
    agg = collections.Counter()
    for b, kind, name in rows:
        # collapse the jit scope prefix to the interesting tail
        tail = "/".join(name.split("/")[-4:])
        agg[(kind, tail)] += b
    print(f"== top collectives ({len(rows)} total) ==")
    for (kind, name), b in agg.most_common(top):
        print(f"  {b / 2**30:8.3f} GiB  {kind:<18} {name}")
    total = sum(b for b, _, _ in rows)
    print(f"  total: {total / 2**30:.2f} GiB per device per step")


def scan_buffers(compiled, top: int = 15):
    try:
        stats = compiled.memory_analysis()
        print(f"args={stats.argument_size_in_bytes/2**30:.2f} "
              f"temp={stats.temp_size_in_bytes/2**30:.2f} "
              f"out={stats.output_size_in_bytes/2**30:.2f} "
              f"alias={stats.alias_size_in_bytes/2**30:.2f} GiB")
    except Exception as e:
        print("mem analysis failed:", e)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--quant", default="fp")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()
    compiled = build(args.arch, args.shape, args.quant, args.multi_pod)
    scan_buffers(compiled)
    scan_collectives(compiled.as_text(), args.top)


if __name__ == "__main__":
    main()
