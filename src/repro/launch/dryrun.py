import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
)
# The two lines above MUST run before any jax import (jax locks the device
# count on first init).  Everything below is ordinary.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, without allocating a single model array:

  * proof the sharding config is coherent (compile succeeds),
  * ``compiled.memory_analysis()``  -> bytes/device (fits in 16 GB v5e HBM?),
  * ``compiled.cost_analysis()``    -> HLO FLOPs + bytes for §Roofline,
  * collective bytes parsed from the HLO (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute operand sizes),
  * the resolver's demotion log (which dims could not shard and why).

Results are written as JSON under experiments/dryrun/ and summarised in
EXPERIMENTS.md.  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
      --shape decode_32k [--multi-pod] \
      [--quant fp|binary|wXaY, optionally suffixed _packed]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, ShapeSpec
from repro.dist.sharding import Resolver
from repro.kernels.dispatch import GemmConfig
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.models import lm as lm_model
from repro.models import registry
from repro.nn.common import QCtx

# ---------------------------------------------------------------------------
# hardware model (TPU v5e) — §Roofline constants
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_TUPLE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
# computation defs start at column 0: '%name (args...) -> ...' (args may
# contain nested tuple parens, so only the leading name is parsed)
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s")


def collective_bytes(hlo: str, loop_trip: int | None = None) -> dict:
    """Sum result bytes per collective kind (result size ~ wire traffic per
    device for ring algorithms; all-reduce counted twice: reduce-scatter +
    all-gather phases).

    ``loop_trip``: if given, collectives inside while-loop bodies are
    multiplied by the trip count (scan-over-layers cost correction)."""
    body_names: set[str] = set()
    if loop_trip:
        for line in hlo.splitlines():
            if " while(" in line:
                m = _WHILE_BODY_RE.search(line)
                if m:
                    body_names.add(m.group(1))

    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    current_comp = ""
    for line in hlo.splitlines():
        if line and not line.startswith(" "):
            h = _COMP_HEADER_RE.match(line.strip())
            if h:
                current_comp = h.group(1)
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        if m.group(1):  # simple result shape
            nbytes = _shape_bytes(m.group(1), m.group(2))
        else:  # tuple result: sum components before the op name
            head = line.split(kind)[0]
            nbytes = sum(_shape_bytes(d, s) for d, s in _TUPLE_RE.findall(head))
        if "-done(" in line:
            continue  # avoid double counting start/done pairs
        mult = 2 if kind == "all-reduce" else 1
        if loop_trip and current_comp in body_names:
            mult *= loop_trip
        out[kind] += nbytes * mult
    out["total"] = sum(out.values())
    return out


# ---------------------------------------------------------------------------


def _cost_dict(obj) -> dict:
    """``.cost_analysis()`` compat: older jax returns [dict], newer dict."""
    cost = obj.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _attn_flops_fwd(cfg, shape: ShapeSpec) -> float:
    """Analytic token-mixing flops (fwd): attention is quadratic so the
    6·N·D estimate misses it; inner lax.scan bodies (chunked attention,
    WKV) are counted once by HLO cost analysis, so the roofline compute
    term uses max(HLO, analytic)."""
    b = shape.global_batch
    if shape.kind == "decode":
        s_q, s_kv = 1.0, float(shape.seq_len)
    else:
        s_q = s_kv = float(shape.seq_len)
    total = 0.0
    if getattr(cfg, "n_layers", None) is None:
        return 0.0
    for i in range(cfg.n_layers):
        kind = cfg.mixer_kind(i)
        if kind == "attn":
            a = cfg.attn
            eff = s_kv / 2 if (shape.kind != "decode") else s_kv
            total += 4.0 * b * s_q * eff * a.n_heads * a.d_head
        elif kind == "local_attn":
            a = cfg.local_attn
            w = min(a.window or s_kv, s_kv)
            total += 4.0 * b * s_q * w * a.n_heads * a.d_head
        elif kind == "rwkv6":
            r = cfg.rwkv
            dh, h, c = r.d_head, r.n_heads, r.chunk
            if shape.kind == "decode":
                total += b * h * (4.0 * dh * dh)
            else:
                total += b * s_q * h * (4.0 * dh * dh + 2.0 * c * dh)
        elif kind == "rglru":
            r = cfg.rglru
            bs = r.d_rnn // r.n_blocks
            total += b * s_q * (4.0 * r.d_rnn * bs + 12.0 * r.d_rnn)
    return total


def model_flops(spec, cfg, shape: ShapeSpec) -> float:
    """MODEL_FLOPS: 6·N_active·D (train) / 2·N_active·D (fwd-only) plus the
    analytic token-mixing (attention/recurrence) term."""
    params = specs_lib.abstract_params(spec, cfg)
    total = sum(x.size for x in jax.tree.leaves(params))
    # active params for MoE: replace routed-expert count by top_k
    active = total
    if getattr(cfg, "moe", None) is not None:
        e_params = cfg.moe.e * cfg.moe.d_expert * cfg.d_model * 3
        per_layer_active = cfg.moe.top_k * cfg.moe.d_expert * cfg.d_model * 3
        n_moe_layers = sum(
            1 for i in range(cfg.n_layers) if cfg.ffn_kind(i) == "moe"
        )
        active = total - n_moe_layers * (e_params - per_layer_active)

    if spec.family == "whisper":
        b, s = shape.global_batch, shape.seq_len
        t_enc = cfg.t_enc
        h, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
        if shape.kind == "decode":
            mix = 4.0 * b * (s + t_enc) * h * dh * cfg.n_layers
            return 2.0 * active * b + mix
        enc = 4.0 * b * t_enc * t_enc * h * dh * cfg.n_layers
        dec = (2.0 * b * s * s + 4.0 * b * s * t_enc) * h * dh * cfg.n_layers
        mult = 3.0 if shape.kind == "train" else 1.0
        return mult * (2.0 * active * b * s + enc + dec)

    mix_fwd = _attn_flops_fwd(cfg, shape)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens + 3.0 * mix_fwd
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens + mix_fwd
    return 2.0 * active * shape.global_batch + mix_fwd


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             quant: str, outdir: str | None,
             seq_parallel: bool = False,
             microbatch: int | None = None,
             gemm_backend: str = "xla",
             fused_prologue: bool = True,
             capacity_factor: float | None = None) -> dict:
    spec = registry.get(arch_id)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    if shape_name == "long_500k" and not spec.supports_long:
        return {"arch": arch_id, "shape": shape_name, "status": "skipped",
                "reason": "full-attention arch; sub-quadratic required "
                          "(DESIGN.md §4)"}

    # "fp" | "binary" | "wXaY" (e.g. w4a4) fake-quant, with an optional
    # "_packed" suffix to lower the packed serving layout (1-bit words or
    # k-bit plane stacks via converter.abstract_packed)
    from repro.launch.train import parse_quant

    want_packed = quant.endswith("_packed")
    policy = parse_quant(quant[:-len("_packed")] if want_packed else quant)
    packed = policy if want_packed and shape.kind != "train" else None

    # the "xla" backend is the default lowering: pallas_call in interpret
    # mode is not a meaningful cost-analysis target (see kernels/dispatch).
    # --gemm-backend shard-* lowers the tensor-parallel packed GEMM instead
    # (shard_map over this cell's 'model' axis) — proving the sharded
    # serving graph (activation prologue inside the shard_map body
    # included) partitions coherently at production mesh sizes.
    ctx = QCtx(policy=policy, compute_dtype=jnp.bfloat16,
               gemm_config=GemmConfig(backend=gemm_backend,
                                      fused_prologue=fused_prologue,
                                      capacity_factor=capacity_factor),
               mesh=mesh)
    rs = Resolver(mesh)

    def lower_cell(scan_blocks: bool):
        cell = specs_lib.make_cell(spec, spec.config, ctx, shape,
                                   packed_policy=packed, resolver=rs,
                                   scan_blocks=scan_blocks,
                                   seq_parallel=seq_parallel,
                                   microbatch=microbatch)
        shardings = tuple(rs.shardings(p) for p in cell.pspecs(rs))
        with mesh:
            jitted = jax.jit(cell.fn, in_shardings=shardings,
                             donate_argnums=cell.donate)
            return jitted.lower(*cell.args)

    # Train cells (lm): compile the SCANNED form only (the production
    # pattern; unrolled compiles take 10-25 min for the big archs and the
    # CPU scheduler does not reuse buffers across an unrolled layer loop
    # anyway — measured, DESIGN.md §8).  FLOPs come from cost_analysis on
    # the UNROLLED *lowering* (no compile, global pre-SPMD numbers — a
    # while body is counted once by HLO cost analysis), and in-loop
    # collectives from the scanned HLO are scaled by the trip count.
    scan_train = shape.kind == "train" and spec.family == "lm"
    t0 = time.time()
    lowered = lower_cell(scan_blocks=scan_train)
    t_lower = time.time() - t0
    t0 = time.time()
    with mesh:
        compiled = lowered.compile()
    t_compile = time.time() - t0

    loop_trip = None
    flops_global = None
    if scan_train:
        cfg = spec.config
        cycle = lm_model._cycle_len(cfg)
        loop_trip = (cfg.n_layers - cfg.first_dense_layers) // cycle
        unrolled = lower_cell(scan_blocks=False)
        flops_global = float(_cost_dict(unrolled).get("flops", 0.0))

    # NOTE semantics: after SPMD partitioning both cost_analysis() and
    # memory_analysis() report PER-DEVICE numbers (shapes in the partitioned
    # module are per-shard) — verified against hand-computed cache/param
    # sizes.  'bytes accessed' sums every instruction's operands+outputs
    # (pre-fusion on the CPU backend), i.e. a pessimistic upper bound on HBM
    # traffic; buffer sizes (args+temp+out) are the optimistic lower bound.
    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo, loop_trip=loop_trip)

    if flops_global is None:
        flops = float(cost.get("flops", 0.0))  # per device
        flops_global = flops * n_chips
    else:
        flops = flops_global / n_chips  # global lowering / chips
    bytes_acc = float(cost.get("bytes accessed", 0.0))  # per device
    mf = model_flops(spec, spec.config, shape)

    arg_b = int(getattr(mem, "argument_size_in_bytes", 0) or 0)
    tmp_b = int(getattr(mem, "temp_size_in_bytes", 0) or 0)
    out_b = int(getattr(mem, "output_size_in_bytes", 0) or 0)
    alias_b = int(getattr(mem, "alias_size_in_bytes", 0) or 0)
    buffer_traffic = arg_b + tmp_b + out_b - alias_b

    # memory term: buffer traffic (every arg/temp/output buffer crosses HBM
    # at least once).  cost_analysis 'bytes accessed' is recorded alongside
    # but counts per-instruction I/O pre-fusion (measured 500x too high on
    # the CPU backend) — see EXPERIMENTS.md §Roofline for the methodology.
    # compute term: max(HLO, analytic) — HLO undercounts inner lax.scan
    # bodies (chunked attention at 32k, WKV chunks), analytic misses
    # elementwise/softmax overheads; the max is the defensible lower bound.
    compute_s = max(flops, mf / n_chips) / PEAK_FLOPS
    memory_s = buffer_traffic / HBM_BW
    coll_s = coll["total"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    bottleneck = max(terms, key=terms.get)

    rec = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "quant": quant + ("+sp" if seq_parallel else "")
                 + (f"+mb{microbatch}" if microbatch else ""),
        "status": "ok",
        "chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "argument_bytes": arg_b,
        "temp_bytes": tmp_b,
        "output_bytes": out_b,
        "alias_bytes": alias_b,
        "peak_bytes": arg_b + tmp_b,
        "buffer_traffic_lb": buffer_traffic,
        "hlo_flops": flops,
        "hlo_bytes": bytes_acc,
        "hlo_flops_global": flops_global,
        "model_flops": mf,
        "useful_flop_frac": mf / flops_global if flops_global else None,
        "collectives": coll,
        "roofline": terms,
        "bottleneck": bottleneck,
        "step_time_lb_s": max(terms.values()),
        "demotions": rs.demotion_log(),
    }
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        tag = f"{arch_id}_{shape_name}_{rec['mesh']}_{quant}"
        with open(os.path.join(outdir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def _fmt(rec: dict) -> str:
    if rec["status"] != "ok":
        return (f"{rec['arch']:<18} {rec['shape']:<12} SKIP "
                f"({rec.get('reason', '')[:60]})")
    t = rec["roofline"]
    return (
        f"{rec['arch']:<18} {rec['shape']:<12} {rec['mesh']:<8} "
        f"{rec['quant']:<13} "
        f"comp={t['compute_s']:.2e}s mem={t['memory_s']:.2e}s "
        f"coll={t['collective_s']:.2e}s -> {rec['bottleneck'][:-2]:<10} "
        f"peak={_gb(rec['peak_bytes'])}/dev "
        f"useful={100 * (rec['useful_flop_frac'] or 0):.0f}% "
        f"compile={rec['compile_s']:.0f}s"
    )


def _gb(b):
    return f"{(b or 0) / 2**30:.2f}GB"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    def quant_arg(s: str) -> str:
        from repro.launch.train import parse_quant
        try:  # validate at parse time (run_cell re-parses)
            parse_quant(s[:-len("_packed")] if s.endswith("_packed") else s)
        except ValueError as e:
            raise argparse.ArgumentTypeError(str(e)) from None
        return s

    ap.add_argument("--quant", default="fp", type=quant_arg,
                    help="fp | binary[_scaled] | wXaY (e.g. w4a4), with "
                         "optional _packed suffix for the packed serving "
                         "layout (e.g. binary_packed, w4a4_packed)")
    from repro.launch import cli

    cli.add_gemm_flags(ap, "--gemm-backend", default="xla",
                       help="dispatch backend the cell lowers (default "
                            "the in-graph xla dequant path; shard-* "
                            "lowers the tensor-parallel packed GEMM on "
                            "the cell's mesh)")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="Megatron-SP residual sharding (train cells)")
    ap.add_argument("--microbatch", type=int, default=None,
                    help="gradient-accumulation microbatches (train cells)")
    ap.add_argument("--all", action="store_true",
                    help="run every assigned (arch x shape) cell")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        for a in registry.ASSIGNED:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    failures = 0
    for arch_id, shape_name in cells:
        try:
            rec = run_cell(arch_id, shape_name, multi_pod=args.multi_pod,
                           quant=args.quant, outdir=args.out,
                           seq_parallel=args.seq_parallel,
                           microbatch=args.microbatch,
                           gemm_backend=args.gemm_backend,
                           fused_prologue=not args.jnp_prologue,
                           capacity_factor=args.capacity_factor)
            print(_fmt(rec), flush=True)
        except Exception as e:  # a failed cell is a bug in the system
            failures += 1
            print(f"{arch_id:<18} {shape_name:<12} FAILED: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
