"""Abstract input specs (ShapeDtypeStruct) for every (arch × shape) cell.

Same pattern as shannon/kernels: weak-type-correct, shardable, zero
allocation.  ``cell_specs`` returns everything the dry-run needs to lower
one cell: the step function, its abstract args, and the matching partition
templates (resolved against a mesh by dist.sharding.Resolver).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.common import ArchSpec
from repro.configs.shapes import ShapeSpec
from repro.core import converter
from repro.models import lm as lm_model
from repro.models import whisper as whisper_model
from repro.nn.common import QCtx
from repro.optim import adamw
from repro.serve import engine
from repro.train import trainer

F32 = jnp.float32
BF16 = jnp.bfloat16
I32 = jnp.int32


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def abstract_params(spec: ArchSpec, cfg) -> Any:
    if spec.family == "lm":
        return jax.eval_shape(lambda: lm_model.init(jax.random.PRNGKey(0), cfg))
    if spec.family == "whisper":
        return jax.eval_shape(
            lambda: whisper_model.init(jax.random.PRNGKey(0), cfg)
        )
    raise ValueError(spec.family)


def cast_floats(tree, dtype):
    def c(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(x.shape, dtype)
        return x
    return jax.tree.map(c, tree)


def abstract_cache(spec: ArchSpec, cfg, b: int, cache_len: int):
    if spec.family == "lm":
        return jax.eval_shape(
            lambda: lm_model.init_cache(cfg, b, cache_len, BF16)
        )
    return jax.eval_shape(
        lambda: whisper_model.init_cache(cfg, b, cache_len, BF16)
    )


@dataclasses.dataclass
class Cell:
    """One lowering target: ``fn(*args)`` with abstract args and a
    function assigning partition specs given a Resolver."""

    name: str
    fn: Callable
    args: tuple
    pspecs: Callable  # Resolver -> tuple of pspec pytrees (per arg)
    donate: tuple[int, ...] = ()  # donated arg indices (state buffers)
    static_kwargs: dict | None = None


def train_cell(spec: ArchSpec, cfg, ctx: QCtx, shape: ShapeSpec,
               opt_cfg: adamw.AdamWConfig | None = None,
               resolver=None, microbatch: int | None = None,
               scan_blocks: bool = False, seq_parallel: bool = False) -> Cell:
    """ZeRO-1 train cell: args are (master fp32, opt_state, batch) in the
    MASTER layout; the step itself constrains to the compute layout (needs
    the resolver/mesh up front, hence the extra arg)."""
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    params = abstract_params(spec, cfg)
    opt_state = {
        "m": cast_floats(params, F32),
        "v": cast_floats(params, F32),
        "step": sds((), I32),
    }
    b, s = shape.global_batch, shape.seq_len
    if spec.family == "whisper":
        batch = {
            "frames": sds((b, cfg.t_enc, cfg.d_model), F32),
            "tokens": sds((b, s), I32),
            "targets": sds((b, s), I32),
        }
    else:
        s_text = s - cfg.vision_prefix
        batch = {"tokens": sds((b, s_text), I32),
                 "targets": sds((b, s_text), I32)}
        if cfg.vision_prefix:
            batch["vision_embeds"] = sds(
                (b, cfg.vision_prefix, cfg.d_vision), F32
            )

    layouts = None
    if resolver is not None:
        ov = resolver.attn_overrides(cfg)
        layouts = trainer.TrainLayouts(
            compute=resolver.shardings(resolver.params_pspecs(params, ov)),
            master=resolver.shardings(resolver.master_pspecs(params, ov)),
        )
    step = trainer.make_train_step(
        spec, cfg, ctx, opt_cfg, remat=True, layouts=layouts,
        microbatch=microbatch,
        scan_blocks=scan_blocks and spec.family == "lm",
        seq_parallel=seq_parallel and spec.family == "lm",
    )

    def pspecs(rs):
        p = rs.master_pspecs(params, rs.attn_overrides(cfg))
        return (
            p,
            {"m": p, "v": p, "step": jax.sharding.PartitionSpec()},
            rs.batch_pspecs(batch),
        )

    return Cell("train", step, (params, opt_state, batch), pspecs,
                donate=(0, 1))


def prefill_cell(spec: ArchSpec, cfg, ctx: QCtx, shape: ShapeSpec,
                 packed_policy=None) -> Cell:
    params = abstract_params(spec, cfg)
    params = cast_floats(params, BF16)
    if packed_policy is not None:
        params = converter.abstract_packed(params, packed_policy)
    b, s = shape.global_batch, shape.seq_len
    fn = engine.prefill_fn(spec, cfg, ctx, cache_len=s)
    if spec.family == "whisper":
        args = (params, sds((b, cfg.t_enc, cfg.d_model), BF16),
                sds((b, s), I32))
        batchlike = args[1:]
    elif cfg.vision_prefix:
        args = (params, sds((b, s - cfg.vision_prefix), I32),
                sds((b, cfg.vision_prefix, cfg.d_vision), F32))
        batchlike = args[1:]
    else:
        args = (params, sds((b, s), I32))
        batchlike = args[1:]

    def pspecs(rs):
        return (rs.params_pspecs(params, rs.attn_overrides(cfg)),
                *(rs.batch_pspecs(x) for x in batchlike))

    return Cell("prefill", fn, args, pspecs)


def decode_cell(spec: ArchSpec, cfg, ctx: QCtx, shape: ShapeSpec,
                packed_policy=None) -> Cell:
    params = abstract_params(spec, cfg)
    params = cast_floats(params, BF16)
    if packed_policy is not None:
        params = converter.abstract_packed(params, packed_policy)
    b, s = shape.global_batch, shape.seq_len
    cache = abstract_cache(spec, cfg, b, s)
    fn = engine.serve_step_fn(spec, cfg, ctx)
    args = (params, cache, sds((b, 1), I32), sds((b,), I32))

    def pspecs(rs):
        return (
            rs.params_pspecs(params, rs.attn_overrides(cfg)),
            rs.cache_pspecs(cache),
            rs.batch_pspecs(args[2]),
            rs.batch_pspecs(args[3]),
        )

    return Cell("decode", fn, args, pspecs, donate=(1,))


def make_cell(spec: ArchSpec, cfg, ctx: QCtx, shape: ShapeSpec,
              packed_policy=None, resolver=None,
              microbatch: int | None = None,
              scan_blocks: bool = False, seq_parallel: bool = False) -> Cell:
    if shape.kind == "train":
        return train_cell(spec, cfg, ctx, shape, resolver=resolver,
                          microbatch=microbatch, scan_blocks=scan_blocks,
                          seq_parallel=seq_parallel)
    if shape.kind == "prefill":
        return prefill_cell(spec, cfg, ctx, shape, packed_policy)
    if shape.kind == "decode":
        return decode_cell(spec, cfg, ctx, shape, packed_policy)
    raise ValueError(shape.kind)
