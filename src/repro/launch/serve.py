"""Serving launcher: load a (float or packed) checkpoint and run batched
generation — the paper's deployment mode when ``--packed``.

Generation runs on the continuous-batching scheduler (serve/engine.py):
the default mode feeds one rectangular batch through ``Engine.generate``
(legacy fixed-batch semantics), while ``--request-stream`` submits a
queue of mixed-prompt-length requests — twice as many as there are slots
— straight to ``Scheduler.run`` to exercise slot recycling, per-request
eos (``--eos-id``) and the drained-loop early exit, and prints per-step /
TTFT stats.

``--kv-block-size N`` swaps the per-slot contiguous KV slabs for the
block-table paged pool (greedy tokens stay bit-identical); add
``--prefill-chunk`` to interleave long-prompt prefill with decode steps
and ``--shared-prefix`` to refcount-share already-prefilled prompt-prefix
blocks across requests (the request-stream demo prepends a common
"system prompt" and reports the prefill tokens saved).

``--fused-attn`` routes decode/window attention through the Pallas
flash-decode kernel (kernels/attn_decode.py) — the KV storage is read in
place through the block tables instead of dense-gathered every step —
and ``--kv-bits {8,1}`` stores the KV cache itself quantized (int8
absmax / 1-bit sign + alpha, the paper's memory argument applied to the
cache).  Greedy tokens are identical to the gather path under fp KV.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b --smoke \
      --steps 50 --quant binary --export-packed /tmp/g.packed.npz
  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke \
      --quant binary --packed /tmp/g.packed.npz --prompts 4 --new-tokens 16 \
      --request-stream

k-bit (DoReFa) packed serving uses the same flow with ``--quant w4a4`` /
``--quant w8a8``: the converter emits bit-plane stacks and the dispatch
layer resolves ``--backend vpu`` onto the ``vpu-k4``/``vpu-k8`` plane
kernels per layer (first/last stay fp per policy).

``--draft w1a1 --spec-len s`` turns on speculative decoding: a
depth-sliced, 1-bit-converted draft of the same checkpoint proposes ``s``
tokens per round and the target verifies them in one windowed call
(serve/engine.py docstring has the invariants).  Greedy outputs stay
token-identical to non-speculative serving; the stats line reports the
acceptance rate.  ``--draft fp`` keeps the slice float (debug oracle).

Tensor-parallel packed serving: ``--backend shard-vpu --shard 4`` runs
every packed GEMM under shard_map on a 4-way 'model' mesh (Kw-partial
popcount + psum; bit-identical to single-device — see
kernels/dispatch.py), and k-bit layers resolve onto ``shard-vpu-k*``."""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import converter
from repro.launch import cli
from repro.launch.train import parse_quant
from repro.models import lm as lm_model
from repro.models import registry
from repro.models import whisper as whisper_model
from repro.nn.common import QCtx
from repro.serve.engine import (DraftModel, Engine, EngineConfig, Request,
                                Scheduler)


def load_packed(path: str, template):
    from repro.ckpt.manager import _unflatten_into

    data = np.load(path)
    flat = {k: data[k] for k in data.files}
    return _unflatten_into(template, flat)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="fp")
    ap.add_argument("--packed", default=None,
                    help="packed checkpoint from --export-packed")
    cli.add_gemm_flags(ap, "--xnor-backend", "--backend", default="vpu",
                       shard=True,
                       help="base GEMM backend; k-bit layers resolve base "
                            "names onto the vpu-k* plane kernels, and the "
                            "shard-* family runs the same kernels tensor-"
                            "parallel across --shard devices")
    ap.add_argument("--prompts", type=int, default=4,
                    help="batch width == scheduler KV-cache slots")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds params/prompts AND EngineConfig.seed (the "
                         "sampling key stream when --temperature > 0)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop token: the scheduler retires (and recycles)"
                         " a slot the step it emits this id")
    ap.add_argument("--kv-block-size", type=int, default=None,
                    help="switch the KV cache to the block-table paged "
                         "pool with this block size (lm, pure-attn archs; "
                         "must divide --cache-len); default contiguous "
                         "per-slot slabs")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="paged mode: split prompt prefill into windows "
                         "of this many tokens interleaved with decode "
                         "steps (bounds batchmates' inter-token latency)")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="paged mode: hash full prompt blocks at "
                         "admission and reuse already-prefilled blocks "
                         "across identical-prefix requests (the request-"
                         "stream demo gives every prompt a common prefix "
                         "so the savings show up in the stats line)")
    cli.add_attn_flags(ap)
    cli.add_spec_flags(ap)
    ap.add_argument("--request-stream", action="store_true",
                    help="continuous-batching demo mode: submit 2x "
                         "--prompts requests with mixed prompt lengths to "
                         "the Scheduler queue (slots recycle as requests "
                         "finish) instead of one rectangular batch")
    args = ap.parse_args()

    spec = registry.get(args.arch)
    cfg = spec.smoke if args.smoke else spec.config
    policy = parse_quant(args.quant)
    mesh = None
    if args.gemm_backend.startswith("shard-"):
        ways = args.shard or len(jax.devices())
        mesh = jax.make_mesh((ways,), ("model",))
        print(f"tensor-parallel packed GEMM: {ways}-way "
              f"(layout {args.shard_layout!r})")
    ctx = QCtx(policy=policy, compute_dtype=jnp.float32, mesh=mesh,
               gemm_config=cli.gemm_config_from_args(args))

    key = jax.random.PRNGKey(args.seed)
    if spec.family == "lm":
        params = lm_model.init(key, cfg)
    else:
        params = whisper_model.init(key, cfg)

    draft = None
    if args.draft:
        # slice BEFORE any packed-checkpoint replacement: derive_draft
        # binarizes float weights (a packed target can't be re-sliced)
        if spec.family != "lm":
            raise SystemExit("--draft: speculative serving is lm-only")
        dpolicy = parse_quant("binary" if args.draft == "w1a1" else "fp")
        dparams, dcfg, _ = converter.derive_draft(
            jax.tree.map(np.asarray, params), cfg,
            n_layers=args.draft_layers, policy=dpolicy,
            keep_float=args.draft == "fp")
        draft = DraftModel(cfg=dcfg,
                           params=jax.tree.map(jnp.asarray, dparams),
                           ctx=dataclasses.replace(ctx, policy=dpolicy))
        print(f"speculative draft: {dcfg.n_layers}/{cfg.n_layers} layers "
              f"({args.draft}), spec_len={args.spec_len}")

    if args.packed:
        tmpl, _ = converter.convert(jax.tree.map(np.asarray, params), policy)
        params = load_packed(args.packed, tmpl)
        params = jax.tree.map(jnp.asarray, params)
        print(f"loaded packed checkpoint: {args.packed}")

    ecfg = EngineConfig(batch=args.prompts, cache_len=args.cache_len,
                        max_new_tokens=args.new_tokens,
                        temperature=args.temperature, eos_id=args.eos_id,
                        seed=args.seed,
                        kv_block_size=args.kv_block_size,
                        prefill_chunk=args.prefill_chunk,
                        shared_prefix=args.shared_prefix,
                        draft=draft, spec_len=args.spec_len,
                        fused_attn=args.fused_attn, kv_bits=args.kv_bits)
    eng = Engine(spec, cfg, ctx, params, ecfg)
    if args.fused_attn or args.kv_bits:
        tier = {None: "fp", 8: "int8", 1: "1-bit"}[args.kv_bits]
        print(f"decode attention: "
              f"{'fused flash-decode' if args.fused_attn else 'gather'}"
              f" kernel, {tier} KV storage")

    rng = np.random.default_rng(args.seed)

    def req_kwargs(n):
        kw = {}
        if spec.family == "whisper":
            kw["frames"] = rng.standard_normal(
                (n, cfg.t_enc, cfg.d_model)).astype(np.float32)
        elif getattr(cfg, "vision_prefix", 0):
            kw["vision_embeds"] = rng.standard_normal(
                (n, cfg.vision_prefix, cfg.d_vision)).astype(np.float32)
        return kw

    if args.request_stream:
        n = 2 * args.prompts  # queue depth > slots -> recycling
        lens = [max(2, args.prompt_len - 2 * (i % 4)) for i in range(n)]
        kw = req_kwargs(n)
        shared = None
        if args.shared_prefix:
            # every request opens with the same "system prompt" so later
            # admissions reuse its already-prefilled blocks
            shared = rng.integers(0, cfg.vocab_size,
                                  (args.prompt_len,)).astype(np.int32)
        sched = Scheduler(eng)
        for i, length in enumerate(lens):
            prompt = rng.integers(0, cfg.vocab_size, (length,)).astype(
                np.int32)
            if shared is not None:
                prompt = np.concatenate([shared, prompt])
            sched.submit(Request(
                prompt=prompt,
                prefill_kwargs={k: v[i] for k, v in kw.items()}))
        t0 = time.time()
        results = sched.run()
        dt = time.time() - t0
        stats = sched.stats
        n_tok = sum(len(v) for v in results.values())
        ttft = np.mean(list(stats.t_first.values())) * 1e3
        print(f"served {len(results)} requests (prompt lens {min(lens)}-"
              f"{max(lens)}) on {args.prompts} slots in {dt:.2f}s: "
              f"{n_tok} tokens ({n_tok / dt:.1f} tok/s), "
              f"{stats.steps} decode steps, {stats.prefills} prefills, "
              f"mean TTFT {ttft:.1f}ms")
        if eng.paged:
            print(f"paged KV: {stats.prefill_tokens} prompt tokens "
                  f"prefilled, {stats.shared_tokens} reused from shared "
                  f"prefix blocks")
        if eng.speculative:
            print(f"speculative: {stats.spec_rounds} rounds, acceptance "
                  f"{stats.acceptance_rate:.2f} ({stats.spec_accepted}/"
                  f"{stats.spec_proposed} proposals accepted)")
        tpots = stats.tpots()
        if tpots:
            p50, p95 = np.percentile(tpots, [50, 95]) * 1e3
            print(f"per-token latency: p50 {p50:.1f}ms, p95 {p95:.1f}ms")
        for rid in sorted(results)[:4]:
            print(f"  rid={rid} ({len(results[rid])} tok): "
                  f"{results[rid][:10]}")
        return

    prompts = rng.integers(0, cfg.vocab_size,
                           (args.prompts, args.prompt_len)).astype(np.int32)
    kwargs = {k: jnp.asarray(v) for k, v in req_kwargs(args.prompts).items()}

    t0 = time.time()
    out = eng.generate(prompts, **kwargs)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({out.size / dt:.1f} tok/s)")
    if eng.speculative:
        st = eng.last_stats
        print(f"speculative: {st.spec_rounds} rounds, acceptance "
              f"{st.acceptance_rate:.2f} ({st.spec_accepted}/"
              f"{st.spec_proposed} proposals accepted)")
    print(out[:, :12])


if __name__ == "__main__":
    main()
