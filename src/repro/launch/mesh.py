"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run forces 512 host devices via XLA_FLAGS before any jax
import; tests and the CI benches force 8 virtual host devices the same
way — tests/conftest.py and ci.yml — so the mesh/shard_map paths run on
plain CPU).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 v5e chips) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this process actually has — used by smoke training runs.
    data axis = all local devices, model axis = 1."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_elastic_mesh(model_parallel: int = 16):
    """Elastic restart: rebuild the mesh from the devices that are alive.
    The data axis absorbs whatever is left after reserving the model axis;
    checkpoints restore onto the new topology via ckpt.manager (host numpy
    is mesh-agnostic)."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    while n % mp:
        mp -= 1
    return jax.make_mesh((n // mp, mp), ("data", "model"))
