"""Training launcher — the end-to-end driver (deliverable b).

Runs on whatever devices exist (1 CPU here; the production mesh on a real
cluster).  Supports:

  --arch <id> --smoke            reduced config (CPU-trainable)
  --quant fp|binary|w2a2|...     BMXNet policy for every internal GEMM
  --resume auto                  restart from the latest valid checkpoint
  --grad-compress                sharded DP train step with the 1-bit EF
                                 gradient collective on the 'data' axis
                                 (dist/compress.compressed_psum; the EF
                                 residual rides in TrainState and resumes
                                 exactly)
  --two-stage STEP               1809.10463 two-stage binarization: fp
                                 activations until STEP, then fully binary
                                 (requires a binary --quant)
  --tracker PATH                 JSONL metrics artifact (loss, tokens/sec,
                                 grad-compression ratio, bit-flip rates)
  --export-packed PATH           run the model converter after training

Example (the quickstart driver):
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b --smoke \
      --steps 200 --batch 16 --seq 64 --quant binary --grad-compress \
      --tracker train_metrics.jsonl
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.manager import CheckpointManager, export_packed
from repro.core.policy import PolicySchedule, QuantPolicy
from repro.data import synthetic
from repro.dist.sharding import Resolver
from repro.launch.mesh import make_elastic_mesh
from repro.models import registry
from repro.nn.common import QCtx
from repro.optim import adamw
from repro.train import trainer
from repro.train.tracker import JsonlTracker, NoopTracker


def parse_quant(s: str) -> QuantPolicy:
    if s == "fp":
        return QuantPolicy.full_precision()
    if s == "binary":
        return QuantPolicy.binary()
    if s == "binary_scaled":
        return QuantPolicy.binary(scale=True)
    if s.startswith("w") and "a" in s:  # e.g. w2a4
        w, a = s[1:].split("a")
        return QuantPolicy.quantized(int(w), int(a))
    raise ValueError(f"bad quant {s!r}")


def batch_fn_for(spec, cfg, dcfg):
    if spec.family == "whisper":
        return lambda step: synthetic.whisper_batch_at(
            dcfg, step, cfg.t_enc, cfg.d_model
        )
    if getattr(cfg, "vision_prefix", 0):
        return lambda step: synthetic.vlm_batch_at(
            dcfg, step, cfg.vision_prefix, cfg.d_vision
        )
    return lambda step: synthetic.batch_at(dcfg, step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="fp")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--grad-compress", action="store_true",
                    help="DP shard_map step with 1-bit EF gradient "
                         "compression over the 'data' axis")
    ap.add_argument("--two-stage", type=int, default=0, metavar="STEP",
                    help="two-stage binarization: full-precision "
                         "activations until STEP (1809.10463)")
    ap.add_argument("--tracker", default=None, metavar="PATH",
                    help="write per-log-interval metrics as JSONL")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default=None, choices=[None, "auto"])
    ap.add_argument("--export-packed", default=None)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = registry.get(args.arch)
    cfg = spec.smoke if args.smoke else spec.config
    policy = parse_quant(args.quant)
    if args.two_stage:
        if policy.w_bits != 1:
            raise SystemExit("--two-stage requires a binary --quant")
        schedule = PolicySchedule.two_stage_binarization(
            args.two_stage, scale=policy.scale, xnor_range=policy.xnor_range
        )
    else:
        schedule = PolicySchedule.constant(policy)
    # bit-flip-rate is the binary-training health signal — emit it whenever
    # any schedule stage binarizes weights
    bit_flips = any(p.w_bits == 1 for _, p in schedule.stages)

    mesh = make_elastic_mesh(args.model_parallel)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")
    dp = dict(mesh.shape)["data"]

    opt_cfg = adamw.AdamWConfig(
        lr=args.lr, warmup_steps=max(args.steps // 20, 5),
        total_steps=args.steps,
    )
    state = trainer.train_state_init(
        spec, cfg, jax.random.PRNGKey(args.seed),
        grad_compress=args.grad_compress, dp=dp,
    )

    if not args.grad_compress:
        # GSPMD path: model-axis placement via the resolver (the sharded
        # step instead lets jit place operands from its shard_map specs)
        rs = Resolver(mesh)
        p_sh = rs.shardings(rs.params_pspecs(state.params))
        o_sh = {"m": p_sh, "v": p_sh,
                "step": jax.NamedSharding(mesh, jax.sharding.PartitionSpec())}
        state = trainer.TrainState(
            params=jax.device_put(state.params, p_sh),
            opt_state=jax.device_put(state.opt_state, o_sh),
            ef=state.ef,
        )

    start = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        if args.resume == "auto":
            got = mgr.restore(state)
            if got is not None:
                start, state = got
                if args.grad_compress and not trainer.ef_matches(state, dp):
                    print(f"resumed EF residual was saved at a different DP "
                          f"degree; re-initializing for dp={dp}")
                    state = trainer.TrainState(
                        params=state.params, opt_state=state.opt_state,
                        ef=jax.tree.map(
                            lambda p: jnp.zeros((dp,) + p.shape, jnp.float32),
                            state.params),
                    )
                print(f"resumed from step {start}")

    def build(pol: QuantPolicy):
        c = QCtx(policy=pol, compute_dtype=jnp.float32)
        if args.grad_compress:
            tc = trainer.TrainConfig(
                remat=args.remat, microbatch=args.microbatch or None,
                grad_compress=True, bit_flip_metrics=bit_flips,
            )
            return jax.jit(
                trainer.make_sharded_train_step(spec, cfg, c, opt_cfg, tc,
                                                mesh),
                donate_argnums=(0,),
            )
        base = trainer.make_train_step(
            spec, cfg, c, opt_cfg, remat=args.remat,
            microbatch=args.microbatch or None,
            bit_flip_metrics_on=bit_flips,
        )

        def step(st, batch):
            p, o, m = base(st.params, st.opt_state, batch)
            return trainer.TrainState(params=p, opt_state=o, ef=st.ef), m

        return jax.jit(step, donate_argnums=(0,))

    stepper = trainer.PolicyScheduledStep(build, schedule)
    tracker = JsonlTracker(args.tracker) if args.tracker else NoopTracker()

    dcfg = synthetic.DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed,
    )
    pf = synthetic.Prefetcher(batch_fn_for(spec, cfg, dcfg), start)
    t0 = time.time()
    t_last, i_last = t0, start
    try:
        with mesh:
            for i in range(start, args.steps):
                step, batch = pf.next()
                state, metrics = stepper(state, batch, step=i)
                if (i + 1) % args.log_every == 0 or i == start:
                    m = {k: float(v) for k, v in metrics.items()}
                    now = time.time()
                    dt = now - t_last
                    tok_step = m.get("n_tokens", args.batch * args.seq)
                    m["tokens_per_sec"] = (
                        tok_step * (i + 1 - i_last) / max(dt, 1e-9)
                    )
                    t_last, i_last = now, i + 1
                    extra = ""
                    if "bit_flip_rate" in m:
                        extra += f" flip={m['bit_flip_rate']:.4f}"
                    if "grad_compress_ratio" in m:
                        extra += f" wire={m['grad_compress_ratio']:.1f}x"
                    print(f"step {i + 1:5d} loss={m['loss']:.4f} "
                          f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e} "
                          f"tok/s={m['tokens_per_sec']:.0f}{extra} "
                          f"({now - t0:.1f}s)", flush=True)
                    tracker.log(m, step=i + 1)
                if mgr and (i + 1) % args.ckpt_every == 0:
                    mgr.save(i + 1, state, blocking=False)
    finally:
        pf.close()
        tracker.finish()
    if mgr:
        mgr.save(args.steps, state)
        mgr.wait()

    if args.export_packed:
        host_params = jax.tree.map(np.asarray, state.params)
        report = export_packed(host_params, policy, args.export_packed)
        print("packed export:", report.summary())
        with open(args.export_packed + ".report.json", "w") as f:
            json.dump({"fp32_bytes": report.bytes_fp32,
                       "packed_bytes": report.bytes_after,
                       "ratio": report.ratio}, f)


if __name__ == "__main__":
    main()
