"""Training launcher — the end-to-end driver (deliverable b).

Runs on whatever devices exist (1 CPU here; the production mesh on a real
cluster).  Supports:

  --arch <id> --smoke            reduced config (CPU-trainable)
  --quant fp|binary|w2a2|...     BMXNet policy for every internal GEMM
  --resume auto                  restart from the latest valid checkpoint
  --grad-compress                1-bit EF gradient compression on the pod
                                 axis (multi-pod meshes)
  --export-packed PATH           run the model converter after training

Example (the quickstart driver):
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b --smoke \
      --steps 200 --batch 16 --seq 64 --quant binary
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.manager import CheckpointManager, export_packed
from repro.core.policy import QuantPolicy
from repro.data import synthetic
from repro.dist.sharding import Resolver
from repro.launch.mesh import make_elastic_mesh
from repro.models import registry
from repro.nn.common import QCtx
from repro.optim import adamw
from repro.train import trainer


def parse_quant(s: str) -> QuantPolicy:
    if s == "fp":
        return QuantPolicy.full_precision()
    if s == "binary":
        return QuantPolicy.binary()
    if s == "binary_scaled":
        return QuantPolicy.binary(scale=True)
    if s.startswith("w") and "a" in s:  # e.g. w2a4
        w, a = s[1:].split("a")
        return QuantPolicy.quantized(int(w), int(a))
    raise ValueError(f"bad quant {s!r}")


def batch_fn_for(spec, cfg, dcfg):
    if spec.family == "whisper":
        return lambda step: synthetic.whisper_batch_at(
            dcfg, step, cfg.t_enc, cfg.d_model
        )
    if getattr(cfg, "vision_prefix", 0):
        return lambda step: synthetic.vlm_batch_at(
            dcfg, step, cfg.vision_prefix, cfg.d_vision
        )
    return lambda step: synthetic.batch_at(dcfg, step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="fp")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default=None, choices=[None, "auto"])
    ap.add_argument("--export-packed", default=None)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = registry.get(args.arch)
    cfg = spec.smoke if args.smoke else spec.config
    policy = parse_quant(args.quant)
    ctx = QCtx(policy=policy, compute_dtype=jnp.float32)

    mesh = make_elastic_mesh(args.model_parallel)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    opt_cfg = adamw.AdamWConfig(
        lr=args.lr, warmup_steps=max(args.steps // 20, 5),
        total_steps=args.steps,
    )
    params, opt_state = trainer.init_all(spec, cfg, jax.random.PRNGKey(args.seed))

    rs = Resolver(mesh)
    p_spec = rs.params_pspecs(params)
    p_sh = rs.shardings(p_spec)
    o_sh = {"m": p_sh, "v": p_sh,
            "step": jax.NamedSharding(mesh, jax.sharding.PartitionSpec())}
    params = jax.device_put(params, p_sh)
    opt_state = jax.device_put(opt_state, o_sh)

    start = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        if args.resume == "auto":
            got = mgr.restore({"params": params, "opt": opt_state})
            if got is not None:
                start, tree = got
                params, opt_state = tree["params"], tree["opt"]
                params = jax.device_put(params, p_sh)
                opt_state = jax.device_put(opt_state, o_sh)
                print(f"resumed from step {start}")

    step_fn = jax.jit(
        trainer.make_train_step(
            spec, cfg, ctx, opt_cfg, remat=args.remat,
            microbatch=args.microbatch or None,
        ),
        donate_argnums=(0, 1),
    )

    dcfg = synthetic.DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed,
    )
    pf = synthetic.Prefetcher(batch_fn_for(spec, cfg, dcfg), start)
    t0 = time.time()
    try:
        with mesh:
            for i in range(start, args.steps):
                step, batch = pf.next()
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                if (i + 1) % args.log_every == 0 or i == start:
                    m = {k: float(v) for k, v in metrics.items()}
                    dt = time.time() - t0
                    print(f"step {i + 1:5d} loss={m['loss']:.4f} "
                          f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e} "
                          f"({dt:.1f}s)", flush=True)
                if mgr and (i + 1) % args.ckpt_every == 0:
                    mgr.save(i + 1, {"params": params, "opt": opt_state},
                             blocking=False)
    finally:
        pf.close()
    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt_state})
        mgr.wait()

    if args.export_packed:
        host_params = jax.tree.map(np.asarray, params)
        report = export_packed(host_params, policy, args.export_packed)
        print("packed export:", report.summary())
        with open(args.export_packed + ".report.json", "w") as f:
            json.dump({"fp32_bytes": report.bytes_fp32,
                       "packed_bytes": report.bytes_after,
                       "ratio": report.ratio}, f)


if __name__ == "__main__":
    main()
