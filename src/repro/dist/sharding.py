"""Sharding resolver: partition-spec templates -> mesh-legal PartitionSpecs.

The launchers and the multi-pod dry-run describe *intent* ("shard d_ff over
'model', batch over ('pod', 'data')"); this module resolves intent against a
concrete (or abstract) mesh, demoting any dimension whose size does not
divide the axis product — and logging every demotion, because a silent
demotion is how a 70 s/step collective sneaks into a train loop.

Path-based parameter rules follow the Megatron convention:

* ``embed/table``                 row (vocab) sharded over 'model'
* column-parallel projections (``q/k/v/up/gate/lm_head/...``: ``(d_in,
  d_out)``) shard d_out over 'model'
* row-parallel projections (``o/down/wo/out``) shard d_in over 'model'
* MoE expert stacks ``(E, ...)`` shard the expert axis over 'model' (EP)
* packed binary weights ``w_packed (d_out, Kw)`` shard d_out over 'model'
  except row-parallel layers (their contraction axis is packed — never
  shard packed words)
* norms / biases / scales replicate

``master_pspecs`` additionally spreads the first still-replicated,
divisible dimension of every leaf over 'data' (ZeRO-1 optimizer-state
layout).  KV caches shard (batch, sequence) over (dp-axes, 'model') — the
flash-decoding layout: the cache sequence dim is 'model'-sharded for every
arch regardless of kv-head count.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

Pytree = Any

_DP_AXES = ("pod", "data")  # batch-like axes, outermost first


# ---------------------------------------------------------------------------
# Tensor-parallel packed GEMM layouts (the `shard-*` dispatch backends)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GemmPartition:
    """Operand/result PartitionSpecs for one sharded packed GEMM, plus the
    contraction axis the raw integer partials must ``psum`` over (None when
    the layout needs no collective)."""

    a: P
    w: P
    out: P
    reduce_axis: str | None


def packed_gemm_pspecs(
    layout: str,
    axis: str,
    *,
    expert_axis: str | None = None,
    planes: bool = False,
    grouped: bool = False,
    prologue: bool = False,
) -> GemmPartition:
    """The two tensor-parallel layouts of the packed GEMM — the Megatron
    pair, covering both MLP matmuls without resharding:

    * ``"k"`` — the packed contraction (Kw) dimension partitions over
      ``axis``; every shard computes a Kw-partial raw kernel output
      (xor-mismatch count / padded MXU dot / weighted plane popcount S —
      the ``plane`` specs serve BOTH k-bit families, ``shard-vpu-k*``
      popcount and ``shard-mxu-k*`` int8 code-lane: identical (k, rows,
      Kw) operand layouts) and the INTEGER partials ``psum`` exactly, so
      pad correction and the fused epilogue apply once on the reduced sum
      (row-parallel / down projection: activations arrive K-sharded from
      an "n"-layout up projection).  With
      ``GemmConfig.overlap_collective`` the psum is replaced by the
      N-chunked ppermute ring (dispatch's ``_ring_chunk_reduce``) — the
      operand and output specs are unchanged (the ring's all_gather
      re-replicates the output), only the reduction schedule differs.
    * ``"n"`` — weights partition over their output (N) rows, activations
      replicate, no collective (column-parallel / up+gate projection —
      output arrives N-sharded, feeding the "k"-layout down projection).

    Operand shapes: 1-bit ``a (M, Kw)`` x ``w (N, Kw)``; plane stacks
    ``a (ka, M, Kw)`` x ``w (kb, N, Kw)``; grouped adds a leading expert
    dim that partitions over ``expert_axis`` (expert parallelism — no
    collective on that axis, outputs stay expert-sharded).

    ``prologue=True`` describes the fused-prologue form: the activation
    operand is the (M, K) FLOAT tensor, quantized+packed INSIDE the
    shard_map body (kernels/dispatch's ``shard-*`` ``from_float`` paths).
    Its ``a`` spec is always 2-D — ``"k"`` partitions the float K
    dimension (word-aligned by the dispatch layer so each shard's packed
    slab equals the global words) — while ``w`` and ``out`` keep the
    packed layouts above.  The grouped form has no prologue variant: its
    float rows are routed and packed into expert buckets BEFORE the
    shard_map (see dispatch.quant_gemm_grouped).
    """
    ea = expert_axis
    if prologue and grouped:
        raise ValueError(
            "grouped packed GEMM has no prologue pspecs (expert buckets "
            "are routed and packed before the shard_map body)"
        )
    if layout == "n":
        if grouped:
            raise ValueError(
                "grouped packed GEMM has no 'n' layout (expert stacks "
                "shard over expert_axis x the 'k' contraction axis)"
            )
        if planes:
            return GemmPartition(
                a=P(None, None) if prologue else P(None, None, None),
                w=P(None, axis, None),
                out=P(None, axis), reduce_axis=None,
            )
        return GemmPartition(
            a=P(None, None), w=P(axis, None), out=P(None, axis),
            reduce_axis=None,
        )
    if layout != "k":
        raise ValueError(f"unknown packed-GEMM shard layout {layout!r}; "
                         "expected 'k' or 'n'")
    if grouped:
        if planes:
            return GemmPartition(
                a=P(ea, None, None, axis), w=P(ea, None, None, axis),
                out=P(ea, None, None), reduce_axis=axis,
            )
        return GemmPartition(
            a=P(ea, None, axis), w=P(ea, None, axis),
            out=P(ea, None, None), reduce_axis=axis,
        )
    if planes:
        return GemmPartition(
            a=P(None, axis) if prologue else P(None, None, axis),
            w=P(None, None, axis),
            out=P(None, None), reduce_axis=axis,
        )
    return GemmPartition(
        a=P(None, axis), w=P(None, axis), out=P(None, None),
        reduce_axis=axis,
    )


@dataclasses.dataclass(frozen=True)
class Demotion:
    path: str
    dim: int
    shape: tuple[int, ...]
    wanted: tuple[str, ...]
    got: tuple[str, ...]

    def __str__(self) -> str:
        return (f"{self.path or '<leaf>'}: dim {self.dim} of {self.shape} "
                f"wanted {self.wanted} -> got {self.got or '(replicated)'}")


class Resolver:
    """Resolves pspec templates against one mesh, accumulating demotions."""

    def __init__(self, mesh):
        self.mesh = mesh
        self.axis_sizes = dict(zip(mesh.axis_names, _mesh_shape(mesh)))
        self.demotions: list[Demotion] = []

    # -- core --------------------------------------------------------------

    def resolve(self, template, shape, path: str = "") -> P:
        """Template (one entry per dim: None | axis | tuple of axes) ->
        a PartitionSpec legal on this mesh (non-divisible dims demoted)."""
        entries = []
        for dim, want in enumerate(template):
            entries.append(self._resolve_dim(want, shape, dim, path))
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def _resolve_dim(self, want, shape, dim: int, path: str,
                     log: bool = True):
        if want is None:
            return None
        wanted = (want,) if isinstance(want, str) else tuple(want)
        # axes the mesh actually has (missing axes are not a demotion)
        axes = [a for a in wanted if a in self.axis_sizes]
        got = list(axes)
        while got and shape[dim] % _prod(self.axis_sizes[a] for a in got):
            got.pop(0)  # drop outermost first ('pod' before 'data')
        if log and tuple(got) != tuple(axes):
            self.demotions.append(
                Demotion(path, dim, tuple(shape), tuple(axes), tuple(got))
            )
        if not got:
            return None
        return got[0] if len(got) == 1 else tuple(got)

    def demotion_log(self) -> str:
        return "\n".join(str(d) for d in self.demotions)

    # -- parameters --------------------------------------------------------

    # (regex over the leaf path, template builder given the leaf shape).
    # First match wins; ``None`` from a builder falls through to defaults.
    _ROW_PARALLEL = r"/(o|down|wo|out|proj_out)/(w|w_packed)$"

    def _param_template(self, path: str, shape, overrides):
        for pat, tpl in (overrides or {}).items():
            if re.search(pat, path):
                return tpl
        ndim = len(shape)
        if re.search(r"embed/table$", path):
            return ("model", None)
        if re.search(self._ROW_PARALLEL, path):
            # row-parallel: shard d_in; packed form has d_in bit-packed in
            # Kw words (never sharded) so the packed leaf replicates
            return ("model", None) if path.endswith("/w") else (None, None)
        if path.endswith("_packed") or path.endswith("w_packed"):
            # packed leaves: (d_out, Kw) or expert stack (E, d_out, Kw)
            return ("model",) + (None,) * (ndim - 1)
        if path.endswith("/w") and ndim == 2:
            return (None, "model")  # column-parallel default
        if path.endswith("/w") and ndim == 4:
            return (None, None, None, "model")  # conv HWIO: shard c_out
        if ndim == 3 and re.search(r"experts/", path):
            return ("model", None, None)  # EP: expert axis over 'model'
        return (None,) * ndim  # norms, biases, scales, metadata

    def params_pspecs(self, params: Pytree, overrides=None) -> Pytree:
        """Compute-layout PartitionSpecs for a parameter pytree."""
        return self._map_with_path(
            params,
            lambda path, leaf: self.resolve(
                self._param_template(path, leaf.shape, overrides),
                leaf.shape, path,
            ),
        )

    def master_pspecs(self, params: Pytree, overrides=None) -> Pytree:
        """ZeRO-1 master/optimizer layout: the compute layout plus 'data'
        on the first still-replicated divisible dim of every leaf."""

        def one(path, leaf):
            tpl = list(self._param_template(path, leaf.shape, overrides))
            # log=False: the compute-layout pass (params_pspecs) already
            # records these demotions; logging here would double-count
            resolved = [
                self._resolve_dim(w, leaf.shape, d, path, log=False)
                for d, w in enumerate(tpl)
            ]
            data = self.axis_sizes.get("data")
            if data:
                for d, entry in enumerate(resolved):
                    if entry is None and leaf.shape[d] % data == 0:
                        resolved[d] = "data"
                        break
            while resolved and resolved[-1] is None:
                resolved.pop()
            return P(*resolved)

        return self._map_with_path(params, one)

    def attn_overrides(self, cfg) -> dict:
        """Per-arch parameter-rule overrides.

        GQA K/V projections whose head count does not divide the 'model'
        axis must replicate their output dim (head-granular sharding would
        split a head across shards even when the flat width divides)."""
        attn = getattr(cfg, "attn", None)
        msize = self.axis_sizes.get("model", 1)
        if attn is None or msize <= 1:
            return {}
        n_kv = getattr(attn, "n_kv_heads", None) or attn.n_heads
        if n_kv % msize == 0:
            return {}
        return {r"attn/(k|v)/(w|w_packed)$": (None, None)}

    def gemm_pspecs(self, layout: str, axis: str = "model",
                    **kw) -> GemmPartition:
        """:func:`packed_gemm_pspecs` validated against this mesh (unknown
        axes raise here instead of deep inside shard_map)."""
        ea = kw.get("expert_axis")
        for name in (axis,) + ((ea,) if ea else ()):
            if name not in self.axis_sizes:
                raise ValueError(
                    f"packed-GEMM shard axis {name!r} not on mesh axes "
                    f"{tuple(self.axis_sizes)}"
                )
        return packed_gemm_pspecs(layout, axis, **kw)

    # -- activations / state ----------------------------------------------

    def batch_pspecs(self, batch: Pytree) -> Pytree:
        """Batch-like tensors: dim 0 over the data axes, rest replicated."""
        return self._map_with_path(
            batch,
            lambda path, leaf: self.resolve(
                (_DP_AXES,) + (None,) * (len(leaf.shape) - 1),
                leaf.shape, path,
            ),
        )

    def cache_pspecs(self, cache: Pytree) -> Pytree:
        """KV-cache / recurrent-state layout: (batch, seq-or-state, ...) ->
        (data axes, 'model', ...) — the flash-decoding layout (cache
        sequence dim over 'model' for every arch; kv-head count
        irrelevant)."""

        def one(path, leaf):
            ndim = len(leaf.shape)
            if ndim < 2:
                return P()
            tpl = (_DP_AXES, "model") + (None,) * (ndim - 2)
            return self.resolve(tpl, leaf.shape, path)

        return self._map_with_path(cache, one)

    # -- utilities ---------------------------------------------------------

    def shardings(self, pspecs: Pytree) -> Pytree:
        """PartitionSpec pytree -> NamedSharding pytree on this mesh."""
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s),
            pspecs,
            is_leaf=lambda x: isinstance(x, P),
        )

    @staticmethod
    def _map_with_path(tree: Pytree, fn) -> Pytree:
        def rec(node, path):
            if isinstance(node, dict):
                return {
                    k: rec(v, f"{path}/{k}" if path else str(k))
                    for k, v in node.items()
                }
            if isinstance(node, (list, tuple)):
                return type(node)(
                    rec(v, f"{path}/{i}" if path else str(i))
                    for i, v in enumerate(node)
                )
            return fn(path, node)

        return rec(tree, "")


def _mesh_shape(mesh) -> tuple[int, ...]:
    shape = mesh.shape
    if hasattr(shape, "values"):  # Mesh/AbstractMesh expose an axis dict
        return tuple(shape.values())
    return tuple(shape)


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= x
    return out
