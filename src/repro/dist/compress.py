"""1-bit error-feedback gradient compression — the paper's binarization
trick applied to the wire (signSGD-with-memory / EF-SGD, Seide et al. 2014;
Karimireddy et al. 2019).

Each leaf gradient is compressed to ``sign(g + e) * mean|g + e|`` — one bit
per element plus one fp32 scale — and the quantization residual ``e`` is
carried to the next step (error feedback), so the running sum of compressed
gradients tracks the running sum of true gradients to within one step's
residual.  On the wire this is the same 32x shrink the paper gets for
weights (§2.2.3), here for the gradient all-reduce on the slow ('pod')
axis.

``compressed_psum`` is the collective form used inside ``shard_map``: each
member compresses locally, the compressed leaves are averaged over the
named axis, and the residual state stays local.  The trainer caller
(train/trainer.make_sharded_train_step) uses it like this::

    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map

    def body(params, ef, batch):          # ef leaves: (dp, *param.shape)
        grads = grad_fn(params, batch)    # per-member grads on the shard
        e_local = jax.tree.map(lambda x: x[0], ef)   # this member's slice
        grads, e_new = compressed_psum(grads, e_local, "data")
        return update(params, grads), jax.tree.map(lambda x: x[None], e_new)

    step = shard_map(body, mesh=mesh,
                     in_specs=(P(), P("data"), P("data")),
                     out_specs=(P(), P("data")), check_vma=False)

The residual pytree is carried in ``TrainState.ef`` with a leading sharded
member axis, so checkpointing the state (ckpt/manager.py) makes a resumed
compressed run bit-identical to an uninterrupted one.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


def compress_leaf(
    g: jax.Array, e: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Compress one leaf with error feedback.

    Returns ``(c, e_new)`` where ``c = sign(g + e) * mean|g + e|`` is the
    1-bit representable compressed gradient (as a dense float array) and
    ``e_new = (g + e) - c`` is the residual to feed back next step.
    """
    acc = g + e
    scale = jnp.mean(jnp.abs(acc))
    c = jnp.where(acc >= 0, scale, -scale).astype(g.dtype)
    return c, acc - c


def ef_init(grads: Pytree) -> Pytree:
    """Zero error-feedback state with the gradient tree's structure."""
    return jax.tree.map(jnp.zeros_like, grads)


def compress(grads: Pytree, ef: Pytree) -> tuple[Pytree, Pytree]:
    """Tree-wise :func:`compress_leaf`: returns (compressed, new ef)."""
    g_leaves, treedef = jax.tree.flatten(grads)
    e_leaves = jax.tree.leaves(ef)
    pairs = [compress_leaf(g, e) for g, e in zip(g_leaves, e_leaves)]
    comp = jax.tree.unflatten(treedef, [c for c, _ in pairs])
    ef_new = jax.tree.unflatten(treedef, [e for _, e in pairs])
    return comp, ef_new


def payload_bytes(grads: Pytree, *, compressed: bool) -> int:
    """Wire bytes for one gradient exchange.

    Uncompressed: fp32 per element.  Compressed: 1 bit per element (packed
    into bytes) + one fp32 scale per leaf — the paper's ~32x shrink.
    """
    total = 0
    for leaf in jax.tree.leaves(grads):
        if compressed:
            total += -(-leaf.size // 8) + 4
        else:
            total += leaf.size * 4
    return total


def compressed_psum(
    grads: Pytree, ef: Pytree, axis_name: str
) -> tuple[Pytree, Pytree]:
    """Compress locally, average the compressed leaves over ``axis_name``.

    Must run inside ``shard_map``/``pmap`` with ``axis_name`` bound.  The
    error-feedback state stays member-local (each member corrects its own
    quantization error next step).  Returns (averaged grads, new ef).
    """
    comp, ef_new = compress(grads, ef)
    n = jax.lax.psum(1, axis_name)
    mean = jax.tree.map(
        lambda c: jax.lax.psum(c, axis_name) / n, comp
    )
    return mean, ef_new
