"""Distribution concerns: the sharding resolver (``dist.sharding``) and
1-bit error-feedback gradient compression (``dist.compress``)."""
