"""Serving engine: continuous-batching scheduler over the packed-GEMM
decode step.

``Scheduler`` owns a FIFO request queue and ``EngineConfig.batch`` KV-cache
slots.  Its loop:

* **admission** — free slots are filled from the queue head: the maximal
  run of queued requests with the same prompt length prefills together
  (one jitted call), the per-request caches are written into their slots
  with ``models/{lm,whisper}.cache_insert`` (a batch-row insertion per
  cache leaf), and the first token is sampled from the prefill logits.
  Each slot runs its own position stream starting at 0 — the per-batch
  ``pos`` plumbing in ``nn/attention`` — and the inserted cache carries
  ``slot_pos = -1`` beyond the prompt, which is what makes the previous
  occupant's stale rows invisible (``_mask`` hides ``pos < 0``).
* **decode** — ONE shape-static jitted step for the whole batch (fixed
  ``batch`` x ``cache_len``; retired slots decode junk that the active
  mask zeroes out of sampling, so recycling never recompiles and costs no
  extra host round-trips beyond the one per-step token sync).
* **retirement** — the step a sequence emits its ``eos_id`` or exhausts
  its per-request ``max_new_tokens``, its slot is reset
  (``cache_reset``: slot rows invisible, recurrent state zeroed) and
  immediately eligible for the next queued request.  The reset is
  hygiene only — later decode steps still write the retired slot's junk
  k/v at visible positions; correctness rests on admission's FULL-slot
  ``cache_insert`` overwrite.
* **early exit** — the loop ends the step the queue and the batch are
  both drained; nobody pays for a fixed-horizon drain.

Shape-static jit invariants: one prefill compile per distinct
(group, prompt_len) admission shape, one decode compile total, one cache
insert compile per group size.  Greedy outputs are bit-identical to
per-request fixed-batch generation because every per-token op is
batch-row-independent — the one exception is capacity-bounded MoE
routing (`GemmConfig.capacity_factor`), where drops depend on batchmates.

``Engine.generate`` is a thin compatibility wrapper over
``Scheduler.run``: rectangular prompts admit as one full-width group and
decode exactly as the old fixed-batch loop did (same tokens), while
``EngineConfig.eos_id`` now stops rows early (rows pad with the stop
token).

Serving a BMXNet-converted checkpoint (packed params) is the paper's
deployment mode: quantized weights stay bit-packed in HBM — 32x smaller at
1 bit, 32/k at k bits (DoReFa w4a4/w8a8 plane stacks) — and every
quantized GEMM runs through ``kernels/dispatch`` — backend and tile choice
follow the ``QCtx.gemm_config`` threaded into every layer, and each
layer's ``QuantSpec`` bit widths pick the xnor or bit-plane kernels — the
decode memory-roofline win analysed in EXPERIMENTS.md.

Tensor-parallel serving: configure a ``shard-*`` backend (e.g.
``GemmConfig(backend="shard-vpu")``) plus a mesh (``EngineConfig.mesh``,
``GemmConfig.mesh``, or ``QCtx.mesh``) and every packed GEMM runs under
``shard_map`` with the packed K dimension partitioned across devices —
bit-identical logits to the single-device engine (the Kw-partial popcount
psums exactly; see kernels/dispatch.py).  The activation prologue
(quantize+pack, Fig. 1's "binarize input") is dispatch-owned too: one
fused Pallas pass per GEMM, running INSIDE the shard_map body on the
``"k"`` layout — ``GemmConfig.fused_prologue=False`` swaps in the jnp
reference path for A/B checks.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.common import ArchSpec
from repro.kernels.dispatch import GemmConfig
from repro.models import lm as lm_model
from repro.models import whisper as whisper_model
from repro.nn.common import QCtx

Params = dict[str, Any]


@dataclasses.dataclass
class EngineConfig:
    batch: int  # KV-cache slots == the shape-static decode width
    cache_len: int
    max_new_tokens: int = 32  # per-request default budget
    temperature: float = 0.0  # 0 = greedy
    # sequence stop token: a slot retires (and recycles) the step it emits
    # this id.  None = budget-only retirement (the legacy fixed-horizon
    # behaviour for Engine.generate).
    eos_id: int | None = None
    # PRNG seed for sampled decoding (temperature > 0); the key stream
    # splits before EVERY sample, so no key is ever reused.
    seed: int = 0
    # per-engine override of how quantized GEMMs execute (backend + tiles
    # + fused_prologue + capacity_factor); None inherits the QCtx's
    # gemm_config.  Tensor-parallel serving picks a `shard-*` backend here
    # (or on the QCtx) — the shard mesh is `mesh` below when set (the
    # per-engine override always wins), else the GemmConfig's own `mesh`,
    # else the QCtx's mesh.
    gemm_config: GemmConfig | None = None
    # per-engine mesh override for shard-* backends / EP MoE layers
    mesh: Any = None


@dataclasses.dataclass
class Request:
    """One generation request for the scheduler queue.

    ``prefill_kwargs`` holds per-request prefill operands WITHOUT the batch
    dim (lm VLM: ``vision_embeds`` (P, d_vision); whisper: ``frames``
    (T_enc, d_model)); admission stacks them per group.  ``max_new_tokens``
    and ``eos_id`` fall back to the EngineConfig values when None."""

    prompt: np.ndarray  # (S,) int32
    rid: int | None = None  # assigned by Scheduler.submit when None
    max_new_tokens: int | None = None
    eos_id: int | None = None
    # suppress eos-retirement until this many tokens have been emitted
    # (the standard `min_tokens` sampling knob)
    min_tokens: int = 0
    prefill_kwargs: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class SlotState:
    """Host-side mirror of one occupied KV-cache slot."""

    rid: int
    prompt_len: int
    budget: int  # tokens still allowed (including not-yet-emitted)
    eos_id: int | None
    min_tokens: int = 0
    tokens: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class SchedulerStats:
    steps: int = 0  # jitted decode steps executed
    prefills: int = 0  # jitted prefill (admission) calls
    admissions: list = dataclasses.field(default_factory=list)  # (rid, slot)
    t_first: dict = dataclasses.field(default_factory=dict)  # rid -> s
    t_done: dict = dataclasses.field(default_factory=dict)  # rid -> s


class Engine:
    """Owns the jitted model entry points + the QCtx/GemmConfig wiring.

    ``generate`` keeps the legacy fixed-batch surface; request-level
    serving goes through :class:`Scheduler` directly."""

    def __init__(self, spec: ArchSpec, cfg, ctx: QCtx, params: Params,
                 ecfg: EngineConfig):
        gc = ecfg.gemm_config if ecfg.gemm_config is not None \
            else ctx.gemm_config
        if ecfg.mesh is not None:
            ctx = dataclasses.replace(ctx, mesh=ecfg.mesh)
            if gc.backend.startswith("shard-"):
                # force the per-engine mesh onto the shard config — a mesh
                # already threaded in from QCtx.mesh must not win here
                gc = dataclasses.replace(gc, mesh=ecfg.mesh)
        if gc is not ctx.gemm_config:
            # replace() re-runs QCtx.__post_init__, which threads ctx.mesh
            # into a shard-* gemm_config that carries none of its own
            ctx = dataclasses.replace(ctx, gemm_config=gc)
        self.spec, self.cfg, self.ctx, self.ecfg = spec, cfg, ctx, ecfg
        self.params = params
        fam = spec.family
        mod = lm_model if fam == "lm" else whisper_model
        self._mod = mod

        if fam == "whisper":
            def _prefill(params, tokens, frames):
                return mod.prefill(params, cfg, ctx, frames, tokens,
                                   cache_len=ecfg.cache_len)
        else:
            def _prefill(params, tokens, **kw):
                return mod.prefill(params, cfg, ctx, tokens,
                                   cache_len=ecfg.cache_len, **kw)

        def _decode(params, cache, tokens, pos):
            return mod.decode_step(params, cfg, ctx, cache, tokens, pos)

        def _reset(cache, slot):
            return mod.cache_reset(cfg, cache, slot)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)
        self._insert = jax.jit(mod.cache_insert)
        self._reset = jax.jit(_reset)

    def init_cache(self) -> Params:
        """A fresh all-slots-empty serving cache (batch x cache_len)."""
        return self._mod.init_cache(self.cfg, self.ecfg.batch,
                                    self.ecfg.cache_len,
                                    self.ctx.compute_dtype)

    @property
    def pos_offset(self) -> int:
        """Decode positions start at prompt_len + this (VLM vision prefix
        rows sit before the text prompt in the cache)."""
        if self.spec.family == "whisper":
            return 0
        return getattr(self.cfg, "vision_prefix", 0)

    def _sample(self, logits: jax.Array, key,
                active: jax.Array | None = None) -> jax.Array:
        last = logits[:, -1, :]
        if self.ecfg.temperature <= 0:
            tok = jnp.argmax(last, axis=-1)
        else:
            tok = jax.random.categorical(key, last / self.ecfg.temperature)
        if active is not None:
            # retired slots decode junk; pin them to 0 so nothing
            # downstream has to special-case per-slot on the host
            tok = jnp.where(active, tok, 0)
        return tok.astype(jnp.int32)

    def generate(self, prompts: np.ndarray, **prefill_kwargs) -> np.ndarray:
        """prompts: (B, S_prompt) int32 -> (B, max_new_tokens) int32.

        Compatibility wrapper over :class:`Scheduler`: the rectangular
        batch admits as one group (a single batched prefill, exactly the
        old fixed-batch path) and greedy outputs are unchanged.  With
        ``EngineConfig.eos_id`` set, rows that stop early are padded with
        the stop token out to ``max_new_tokens``."""
        prompts = np.asarray(prompts)
        b, _ = prompts.shape
        sched = Scheduler(self)
        for i in range(b):
            kw = {k: np.asarray(v)[i] for k, v in prefill_kwargs.items()}
            sched.submit(Request(prompt=prompts[i], rid=i,
                                 prefill_kwargs=kw))
        results = sched.run()
        self.last_stats = sched.stats  # step/admission accounting
        n = self.ecfg.max_new_tokens
        out = np.zeros((b, n), np.int32)
        for i in range(b):
            toks = results[i]
            out[i, :len(toks)] = toks
            if 0 < len(toks) < n:  # early EOS: pad with the stop token
                out[i, len(toks):] = toks[-1]
        return out


class Scheduler:
    """Continuous-batching scheduler over an :class:`Engine`.

    ``submit`` queues requests; ``run`` drives admission / decode /
    retirement until queue and batch drain, returning
    ``{rid: (n_tokens,) int32}`` (the emitted stream, ending with the eos
    token when one triggered retirement).  ``stats`` records decode-step
    and admission counts plus per-request first-token / completion times
    (relative to the ``run`` start) for throughput accounting."""

    def __init__(self, engine: Engine):
        self.eng = engine
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: list[SlotState | None] = [None] * engine.ecfg.batch
        self.stats = SchedulerStats()
        self._results: dict[int, np.ndarray] = {}
        self._next_rid = 0

    def submit(self, request: Request) -> int:
        if request.rid is None:
            request.rid = self._next_rid
        taken = ({r.rid for r in self.queue} | set(self._results)
                 | {s.rid for s in self.slots if s is not None})
        if request.rid in taken:
            raise ValueError(f"duplicate rid {request.rid}: results are "
                             "keyed by rid, a collision would drop one "
                             "request's stream")
        self._next_rid = max(self._next_rid, request.rid) + 1
        self.queue.append(request)
        return request.rid

    # -- internals ---------------------------------------------------------

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def _retire(self, i: int, st: SlotState) -> None:
        self._results[st.rid] = np.asarray(st.tokens, np.int32)
        self.stats.t_done[st.rid] = self._now()
        self.slots[i] = None

    def _emit(self, i: int, st: SlotState, token: int) -> bool:
        """Record one emitted token; retire the slot on eos / budget
        exhaustion.  Returns True when the slot retired."""
        if not st.tokens:
            self.stats.t_first[st.rid] = self._now()
        st.tokens.append(token)
        st.budget -= 1
        if st.budget <= 0 or (st.eos_id is not None and token == st.eos_id
                              and len(st.tokens) >= st.min_tokens):
            self._retire(i, st)
            return True
        return False

    def _admit(self, cache, tok, pos, key):
        """Fill free slots from the queue head.  The maximal FIFO run of
        same-prompt-length requests prefills as ONE jitted call (so the
        rectangular ``generate`` batch keeps its single batched prefill);
        each request's cache rows land in its slot via ``cache_insert``
        and its first token comes from the prefill logits."""
        eng, ecfg = self.eng, self.eng.ecfg
        free = [i for i, s in enumerate(self.slots) if s is None]
        while free and self.queue:
            head_len = len(self.queue[0].prompt)
            group: list[Request] = [self.queue.popleft()]
            while (self.queue and len(group) < len(free)
                   and len(self.queue[0].prompt) == head_len):
                group.append(self.queue.popleft())
            taken, free = free[:len(group)], free[len(group):]

            prompts = np.stack([np.asarray(r.prompt) for r in group])
            kw = {
                k: jnp.asarray(
                    np.stack([np.asarray(r.prefill_kwargs[k]) for r in group])
                )
                for k in group[0].prefill_kwargs
            }
            logits, sub_cache = eng._prefill(
                eng.params, jnp.asarray(prompts, jnp.int32), **kw)
            self.stats.prefills += 1
            key, sub = jax.random.split(key)
            first = np.asarray(eng._sample(logits, sub))
            cache = eng._insert(cache, sub_cache,
                                jnp.asarray(taken, jnp.int32))
            start_pos = prompts.shape[1] + eng.pos_offset
            for g, i in enumerate(taken):
                r = group[g]
                st = SlotState(
                    rid=r.rid, prompt_len=len(r.prompt),
                    budget=(r.max_new_tokens if r.max_new_tokens is not None
                            else ecfg.max_new_tokens),
                    eos_id=(r.eos_id if r.eos_id is not None
                            else ecfg.eos_id),
                    min_tokens=r.min_tokens,
                )
                self.slots[i] = st
                self.stats.admissions.append((r.rid, i))
                if st.budget <= 0:  # zero-token request: empty stream
                    self._retire(i, st)
                    free.append(i)
                elif self._emit(i, st, int(first[g])):
                    free.append(i)  # eos/budget hit on the first token
                else:
                    tok[i] = first[g]
                    pos[i] = start_pos
        return cache, tok, pos, key

    def run(self) -> dict[int, np.ndarray]:
        eng, ecfg = self.eng, self.eng.ecfg
        self._t0 = time.perf_counter()
        cache = eng.init_cache()
        b = ecfg.batch
        tok = np.zeros((b,), np.int32)
        pos = np.zeros((b,), np.int32)
        key = jax.random.PRNGKey(ecfg.seed)

        while self.queue or any(s is not None for s in self.slots):
            cache, tok, pos, key = self._admit(cache, tok, pos, key)
            active = np.array([s is not None for s in self.slots])
            if not active.any():
                continue  # everything admitted retired on its first token
            logits, cache = eng._decode(
                eng.params, cache, jnp.asarray(tok)[:, None],
                jnp.asarray(pos))
            key, sub = jax.random.split(key)
            sampled = np.asarray(
                eng._sample(logits, sub, jnp.asarray(active)))
            self.stats.steps += 1
            pos = np.where(active, pos + 1, pos).astype(np.int32)
            tok = np.where(active, sampled, tok).astype(np.int32)
            for i in range(b):
                st = self.slots[i]
                if st is not None and self._emit(i, st, int(sampled[i])):
                    cache = eng._reset(cache, jnp.int32(i))
        return self._results


def serve_step_fn(spec: ArchSpec, cfg, ctx: QCtx):
    """The pure decode step the dry-run lowers (one token, full cache)."""
    mod = lm_model if spec.family == "lm" else whisper_model

    def serve_step(params, cache, tokens, pos):
        return mod.decode_step(params, cfg, ctx, cache, tokens, pos)

    return serve_step


def prefill_fn(spec: ArchSpec, cfg, ctx: QCtx, cache_len: int):
    mod = lm_model if spec.family == "lm" else whisper_model

    if spec.family == "whisper":
        def prefill(params, frames, tokens):
            return mod.prefill(params, cfg, ctx, frames, tokens,
                               cache_len=cache_len)
    elif getattr(cfg, "vision_prefix", 0):
        def prefill(params, tokens, vision_embeds):
            return mod.prefill(params, cfg, ctx, tokens, cache_len=cache_len,
                               vision_embeds=vision_embeds)
    else:
        def prefill(params, tokens):
            return mod.prefill(params, cfg, ctx, tokens, cache_len=cache_len)

    return prefill
