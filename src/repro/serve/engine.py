"""Serving engine: batched prefill + fixed-batch greedy/sampled decode.

The engine keeps one fixed-capacity KV cache; per-slot positions allow
sequences of different lengths in the same batch (``pos`` is per-batch in
attn_decode).  ``Engine.generate`` is a fixed-batch loop: every sequence
decodes for ``max_new_tokens`` steps and slots are NOT recycled when a
sequence finishes early — true continuous batching (slot recycling off the
per-slot positions) is future work; the per-batch ``pos`` plumbing it
needs is already in place.

Serving a BMXNet-converted checkpoint (packed params) is the paper's
deployment mode: quantized weights stay bit-packed in HBM — 32x smaller at
1 bit, 32/k at k bits (DoReFa w4a4/w8a8 plane stacks) — and every
quantized GEMM runs through ``kernels/dispatch`` — backend and tile choice
follow the ``QCtx.gemm_config`` threaded into every layer, and each
layer's ``QuantSpec`` bit widths pick the xnor or bit-plane kernels — the
decode memory-roofline win analysed in EXPERIMENTS.md.

Tensor-parallel serving: configure a ``shard-*`` backend (e.g.
``GemmConfig(backend="shard-vpu")``) plus a mesh (``EngineConfig.mesh``,
``GemmConfig.mesh``, or ``QCtx.mesh``) and every packed GEMM runs under
``shard_map`` with the packed K dimension partitioned across devices —
bit-identical logits to the single-device engine (the Kw-partial popcount
psums exactly; see kernels/dispatch.py).  The activation prologue
(quantize+pack, Fig. 1's "binarize input") is dispatch-owned too: one
fused Pallas pass per GEMM, running INSIDE the shard_map body on the
``"k"`` layout — ``GemmConfig.fused_prologue=False`` swaps in the jnp
reference path for A/B checks, and ``GemmConfig.capacity_factor`` bounds
MoE expert buckets (dropped rows are never quantized or packed).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.common import ArchSpec
from repro.kernels.dispatch import GemmConfig
from repro.models import lm as lm_model
from repro.models import whisper as whisper_model
from repro.nn.common import QCtx

Params = dict[str, Any]


@dataclasses.dataclass
class EngineConfig:
    batch: int
    cache_len: int
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 = greedy
    # per-engine override of how quantized GEMMs execute (backend + tiles
    # + fused_prologue + capacity_factor); None inherits the QCtx's
    # gemm_config.  Tensor-parallel serving picks a `shard-*` backend here
    # (or on the QCtx) — the shard mesh is `mesh` below when set (the
    # per-engine override always wins), else the GemmConfig's own `mesh`,
    # else the QCtx's mesh.
    gemm_config: GemmConfig | None = None
    # per-engine mesh override for shard-* backends / EP MoE layers
    mesh: Any = None


class Engine:
    def __init__(self, spec: ArchSpec, cfg, ctx: QCtx, params: Params,
                 ecfg: EngineConfig):
        gc = ecfg.gemm_config if ecfg.gemm_config is not None \
            else ctx.gemm_config
        if ecfg.mesh is not None:
            ctx = dataclasses.replace(ctx, mesh=ecfg.mesh)
            if gc.backend.startswith("shard-"):
                # force the per-engine mesh onto the shard config — a mesh
                # already threaded in from QCtx.mesh must not win here
                gc = dataclasses.replace(gc, mesh=ecfg.mesh)
        if gc is not ctx.gemm_config:
            # replace() re-runs QCtx.__post_init__, which threads ctx.mesh
            # into a shard-* gemm_config that carries none of its own
            ctx = dataclasses.replace(ctx, gemm_config=gc)
        self.spec, self.cfg, self.ctx, self.ecfg = spec, cfg, ctx, ecfg
        self.params = params
        fam = spec.family
        mod = lm_model if fam == "lm" else whisper_model

        def _prefill(params, tokens, **kw):
            return mod.prefill(params, cfg, ctx, tokens,
                               cache_len=ecfg.cache_len, **kw)

        def _decode(params, cache, tokens, pos):
            return mod.decode_step(params, cfg, ctx, cache, tokens, pos)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.ecfg.temperature <= 0:
            return jnp.argmax(logits[:, -1, :], axis=-1)
        return jax.random.categorical(
            key, logits[:, -1, :] / self.ecfg.temperature
        )

    def generate(self, prompts: np.ndarray, **prefill_kwargs) -> np.ndarray:
        """prompts: (B, S_prompt) int32 -> (B, max_new_tokens) int32."""
        b, s = prompts.shape
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      **prefill_kwargs)
        key = jax.random.PRNGKey(0)
        offset = getattr(self.cfg, "vision_prefix", 0)
        pos = jnp.full((b,), s + offset, jnp.int32)
        out = []
        tok = self._sample(logits, key)
        for i in range(self.ecfg.max_new_tokens):
            out.append(np.asarray(tok))
            logits, cache = self._decode(self.params, cache, tok[:, None], pos)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
            pos = pos + 1
        return np.stack(out, axis=1)


def serve_step_fn(spec: ArchSpec, cfg, ctx: QCtx):
    """The pure decode step the dry-run lowers (one token, full cache)."""
    mod = lm_model if spec.family == "lm" else whisper_model

    def serve_step(params, cache, tokens, pos):
        return mod.decode_step(params, cfg, ctx, cache, tokens, pos)

    return serve_step


def prefill_fn(spec: ArchSpec, cfg, ctx: QCtx, cache_len: int):
    mod = lm_model if spec.family == "lm" else whisper_model

    if spec.family == "whisper":
        def prefill(params, frames, tokens):
            return mod.prefill(params, cfg, ctx, frames, tokens,
                               cache_len=cache_len)
    elif getattr(cfg, "vision_prefix", 0):
        def prefill(params, tokens, vision_embeds):
            return mod.prefill(params, cfg, ctx, tokens, cache_len=cache_len,
                               vision_embeds=vision_embeds)
    else:
        def prefill(params, tokens):
            return mod.prefill(params, cfg, ctx, tokens, cache_len=cache_len)

    return prefill
